//! Quickstart: the paper's Figure 6 ping-pong program, verbatim shape.
//!
//! A server opens channel "mychannel" and registers `process_fn` under
//! id 100; a client connects, builds a `string` in the connection's
//! shared heap, and calls — the argument crosses as a native pointer,
//! no serialization anywhere.
//!
//! Run: `cargo run --release --example quickstart`

use rpcool::channel::Rpc;
use rpcool::memory::{ShmPtr, ShmString};
use rpcool::{Rack, SimConfig};

fn main() -> rpcool::Result<()> {
    // A rack with the full cost model (real CXL-class latencies).
    let rack = Rack::new(SimConfig::for_bench());

    // --- Server (Fig. 6a) ---
    let server_env = rack.proc_env(0);
    let rpc = Rpc::open(&server_env, "mychannel")?;
    rpc.add(100, |ctx| {
        // process_fn: read the ping, answer with a heap-allocated pong.
        let ping: ShmString = ctx.arg_val()?;
        assert!(ping.eq_str("ping"));
        ctx.reply_string("pong")
    });
    // --- Client (Fig. 6b) ---
    let client_env = rack.proc_env(1);
    let conn = Rpc::connect(&client_env, "mychannel")?;
    // Inline serving: the sequential-RTT model (see Connection docs) —
    // correct latency accounting on a single-core simulation host.
    conn.attach_inline(&rpc);
    client_env.enter();

    let t0 = std::time::Instant::now();
    let n = 10_000;
    for _ in 0..n {
        let arg = conn.new_string("ping")?;
        let ret = conn.call_ptr(100, arg)?;
        let pong: ShmString = ShmPtr::<ShmString>::from_addr(ret as usize).read()?;
        assert!(pong.eq_str("pong"));
    }
    let el = t0.elapsed();
    println!(
        "quickstart: {n} ping-pong RPCs in {:.2?} ({:.2} µs RTT, {:.0} K req/s)",
        el,
        el.as_secs_f64() * 1e6 / n as f64,
        n as f64 / el.as_secs_f64() / 1e3,
    );

    drop(conn);
    rpc.stop();
    Ok(())
}
