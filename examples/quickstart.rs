//! Quickstart: the paper's Figure 6 ping-pong program, typed API.
//!
//! A server opens channel "mychannel" and registers `process_fn` under
//! id 100 with `serve::<ShmString, ShmString>`; a client connects,
//! builds a `string` in the connection's shared heap, and calls with
//! `call_typed` — the argument crosses as a native pointer, no
//! serialization anywhere, and the reply comes back as a typed
//! `Reply<ShmString>` (no raw address casts in this whole program).
//!
//! Run: `cargo run --release --example quickstart`

use rpcool::channel::{CallOpts, Rpc};
use rpcool::memory::ShmString;
use rpcool::{Rack, SimConfig};

fn main() -> rpcool::Result<()> {
    // A rack with the full cost model (real CXL-class latencies).
    let rack = Rack::new(SimConfig::for_bench());

    // --- Server (Fig. 6a) ---
    let server_env = rack.proc_env(0);
    let rpc = Rpc::open(&server_env, "mychannel")?;
    rpc.serve::<ShmString, ShmString>(100, |ctx, ping| {
        // process_fn: read the ping, answer with a heap-allocated pong.
        assert!(ping.eq_str("ping"));
        ShmString::from_str(ctx.heap, "pong")
    });
    // --- Client (Fig. 6b) ---
    let client_env = rack.proc_env(1);
    let conn = Rpc::connect(&client_env, "mychannel")?;
    // Inline serving: the sequential-RTT model (see Connection docs) —
    // correct latency accounting on a single-core simulation host.
    conn.attach_inline(&rpc);
    client_env.enter();

    let t0 = std::time::Instant::now();
    let n = 10_000;
    for _ in 0..n {
        let ping = ShmString::from_str(conn.heap().as_ref(), "ping")?;
        let pong: ShmString = conn.call_typed(100, &ping, CallOpts::new())?.take()?;
        assert!(pong.eq_str("pong"));
    }
    let el = t0.elapsed();
    println!(
        "quickstart: {n} ping-pong RPCs in {:.2?} ({:.2} µs RTT, {:.0} K req/s)",
        el,
        el.as_secs_f64() * 1e6 / n as f64,
        n as f64 / el.as_secs_f64() / 1e3,
    );

    drop(conn);
    rpc.stop();
    Ok(())
}
