//! CoolDB demo: the paper's JSON document store on shared memory.
//! Builds a NoBench corpus, runs range searches, and contrasts the
//! zero-copy PUT path against the serialized eRPC path side by side.
//!
//! Run: `cargo run --release --example cooldb_demo [ndocs] [nsearches]`

use rpcool::apps::cooldb::{
    run_fig11, serve_net, serve_rpcool, CoolClient, CoolIndex, RpcoolCool,
};
use rpcool::baselines::netrpc::Flavor;
use rpcool::{Rack, SimConfig};
use std::sync::Arc;

fn main() -> rpcool::Result<()> {
    let ndocs: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(5_000);
    let nsearches: usize =
        std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(100);

    let rack = Rack::new(SimConfig::for_bench());

    // --- RPCool (CXL) ---
    let env = rack.proc_env(0);
    let index = CoolIndex::new();
    let server = serve_rpcool(&env, "svc/cooldb", Arc::clone(&index))?;
    let cenv = rack.proc_env(1);
    let db = RpcoolCool::connect(&cenv, "svc/cooldb")?;
    db.conn().attach_inline(&server); // sequential-RTT model (1-core host)
    cenv.enter();
    let (build, search) = run_fig11(&db, ndocs, nsearches, 42)?;
    println!("== CoolDB over {} ==", db.transport_name());
    println!("build  {ndocs} docs      : {build:.2?}");
    println!("search {nsearches} queries   : {search:.2?}");
    println!("index size            : {}", index.len());
    drop(db);
    server.stop();

    // --- eRPC baseline (everything serialized) ---
    let charger = Arc::clone(&rack.pool.charger);
    let (nserver, ndb, _store) = serve_net(Flavor::ERpc, charger);
    ndb.client_inline(&nserver);
    let (nbuild, nsearch) = run_fig11(&ndb, ndocs, nsearches, 42)?;
    println!("\n== CoolDB over {} ==", ndb.transport_name());
    println!("build  {ndocs} docs      : {nbuild:.2?}");
    println!("search {nsearches} queries   : {nsearch:.2?}");
    nserver.stop();

    println!(
        "\nspeedup (RPCool vs eRPC): build {:.2}×, search {:.2}×",
        nbuild.as_secs_f64() / build.as_secs_f64(),
        nsearch.as_secs_f64() / search.as_secs_f64(),
    );
    Ok(())
}
