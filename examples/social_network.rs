//! DeathStarBench SocialNetwork compose-post over RPCool vs ThriftRPC
//! (Figure 12's experiment in miniature): same service graph, same
//! database work, different RPC fabric.
//!
//! Run: `cargo run --release --example social_network [nposts]`

use rpcool::apps::socialnet::{sample_post, RpcoolSocial, SocialState, ThriftSocial};
use rpcool::channel::waiter::SleepPolicy;
use rpcool::metrics::Histogram;
use rpcool::util::Rng;
use rpcool::{Rack, SimConfig};
use std::sync::Arc;
use std::time::Instant;

fn main() -> rpcool::Result<()> {
    let nposts: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(500);
    let rack = Rack::new(SimConfig::for_bench());
    let nusers = 1_000;

    // --- RPCool fabric ---
    let state = SocialState::new(nusers, 16, 1);
    let net = RpcoolSocial::start(&rack, Arc::clone(&state), SleepPolicy::Fixed(1), false, "ex")?;
    net.inline_mode(); // sequential-RTT model on the 1-core host
    let hist = Histogram::new();
    let mut rng = Rng::new(2);
    let t0 = Instant::now();
    for _ in 0..nposts {
        let (user, text) = sample_post(&mut rng, nusers);
        let t = Instant::now();
        net.compose_post(user, &text)?;
        hist.record(t.elapsed());
    }
    let rpcool_wall = t0.elapsed();
    println!("== compose-post over RPCool ==");
    println!(
        "{} posts in {:.2?} — p50 {} p99 {} ({:.0} req/s)",
        nposts,
        rpcool_wall,
        Histogram::fmt_ns(hist.median_ns()),
        Histogram::fmt_ns(hist.p99_ns()),
        nposts as f64 / rpcool_wall.as_secs_f64()
    );
    net.stop();

    // --- Thrift fabric ---
    let state = SocialState::new(nusers, 16, 1);
    let net = ThriftSocial::start(Arc::clone(&rack.pool.charger), Arc::clone(&state));
    net.inline_mode();
    let hist = Histogram::new();
    let mut rng = Rng::new(2);
    let t0 = Instant::now();
    for _ in 0..nposts {
        let (user, text) = sample_post(&mut rng, nusers);
        let t = Instant::now();
        net.compose_post(user, &text)?;
        hist.record(t.elapsed());
    }
    let thrift_wall = t0.elapsed();
    println!("\n== compose-post over ThriftRPC ==");
    println!(
        "{} posts in {:.2?} — p50 {} p99 {} ({:.0} req/s)",
        nposts,
        thrift_wall,
        Histogram::fmt_ns(hist.median_ns()),
        Histogram::fmt_ns(hist.p99_ns()),
        nposts as f64 / thrift_wall.as_secs_f64()
    );
    net.stop();

    println!(
        "\nRPCool vs Thrift wall-time ratio: {:.2}× (paper: comparable — DB+nginx dominate)",
        thrift_wall.as_secs_f64() / rpcool_wall.as_secs_f64()
    );
    Ok(())
}
