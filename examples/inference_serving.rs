//! END-TO-END DRIVER (DESIGN.md §3): load the
//! AOT-compiled transformer (L2 JAX + L1 Pallas, exported as HLO
//! text), serve it behind an RPCool channel (L3), and drive batched
//! next-token requests from multiple clients — reporting latency
//! percentiles and throughput. Proves the full Rust+JAX+Pallas stack
//! composes with Python nowhere on the request path.
//!
//! Run: `make artifacts && cargo run --release --example inference_serving`

use rpcool::inference::{serve_model, InferenceClient};
use rpcool::metrics::Histogram;
use rpcool::runtime::{ModelBundle, PjrtRuntime};
use rpcool::{Rack, SimConfig};
use std::sync::Arc;
use std::time::Instant;

fn main() -> rpcool::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());
    let rt = PjrtRuntime::cpu()?;
    let model = Arc::new(ModelBundle::load(&rt, &dir)?);
    let cfg = model.cfg;
    println!(
        "loaded model: {} layers, d_model {}, seq {}, vocab {} ({} params) on {}",
        cfg.n_layers,
        cfg.d_model,
        cfg.seq,
        cfg.vocab,
        cfg.param_count(),
        rt.platform(),
    );

    let rack = Rack::new(SimConfig::for_bench());
    let env = rack.proc_env(0);
    let server = serve_model(&env, "svc/llm", Arc::clone(&model))?;
    let listener = server.spawn_listener();

    // Warm the executable.
    let warm = InferenceClient::connect(&rack.proc_env(9), "svc/llm", cfg.seq, cfg.vocab)?;
    warm.next_token(&[1, 2, 3])?;

    // Batched load: N clients, each issuing generate() calls.
    let nclients = 4usize;
    let per_client = 16usize;
    let gen_len = 4usize;
    let hist = Arc::new(Histogram::new());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..nclients {
            let rack = Arc::clone(&rack);
            let hist = Arc::clone(&hist);
            s.spawn(move || {
                let cenv = rack.proc_env(1 + c as u32);
                let client =
                    InferenceClient::connect(&cenv, "svc/llm", cfg.seq, cfg.vocab).unwrap();
                cenv.enter();
                let mut prompt = vec![(c as i32) + 1, 7, 13];
                for _ in 0..per_client {
                    let t = Instant::now();
                    let out = client.generate(&prompt, gen_len).unwrap();
                    hist.record(t.elapsed());
                    prompt = out[..3.min(out.len())].to_vec();
                }
            });
        }
    });
    let wall = t0.elapsed();

    let total_reqs = (nclients * per_client * gen_len) as f64;
    println!("\n== inference serving over RPCool (e2e) ==");
    println!("clients            : {nclients}");
    println!("generate() calls   : {}", nclients * per_client);
    println!("next-token RPCs    : {total_reqs}");
    println!("wall time          : {wall:.2?}");
    println!(
        "throughput         : {:.1} tokens/s",
        total_reqs / wall.as_secs_f64()
    );
    println!(
        "generate() latency : p50 {} | p99 {} | max {}",
        Histogram::fmt_ns(hist.median_ns()),
        Histogram::fmt_ns(hist.p99_ns()),
        Histogram::fmt_ns(hist.max_ns()),
    );
    println!(
        "per-token latency  : ~{}",
        Histogram::fmt_ns(hist.median_ns() / gen_len as u64)
    );

    drop(warm);
    server.stop();
    listener.join().unwrap();
    Ok(())
}
