//! Memcached + YCSB: Figure 9's experiment in miniature — the same
//! cache served over RPCool shared memory vs a UNIX domain socket.
//!
//! Run: `cargo run --release --example memcached_ycsb [nkeys] [nops]`

use rpcool::apps::memcached::{run_ycsb, serve_net, serve_rpcool, Cache, KvClient, RpcoolKv};
use rpcool::baselines::netrpc::Flavor;
use rpcool::workloads::ycsb::WorkloadKind;
use rpcool::{Rack, SimConfig};
use std::sync::Arc;

fn main() -> rpcool::Result<()> {
    let nkeys: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(5_000);
    let nops: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(20_000);

    let rack = Rack::new(SimConfig::for_bench());
    println!("workload  {:>12}  {:>12}  speedup", "RPCool", "UDS");

    for kind in [WorkloadKind::A, WorkloadKind::B, WorkloadKind::C] {
        // RPCool.
        let env = rack.proc_env(0);
        let cache = Cache::new(16);
        let server = serve_rpcool(&env, &format!("mc/{}", kind.name()), cache)?;
        let cenv = rack.proc_env(1);
        let kv = RpcoolKv::connect(&cenv, &format!("mc/{}", kind.name()))?;
        kv.conn().attach_inline(&server); // sequential-RTT model
        cenv.enter();
        let (_l, rpcool) = run_ycsb(&kv, kind, nkeys, nops, 7)?;
        drop(kv);
        server.stop();

        // UDS.
        let cache = Cache::new(16);
        let (nserver, nkv) = serve_net(Flavor::Uds, Arc::clone(&rack.pool.charger), cache);
        nkv.client_inline(&nserver);
        let (_l, uds) = run_ycsb(&nkv, kind, nkeys, nops, 7)?;
        nserver.stop();
        let _ = nkv.transport_name();

        println!(
            "YCSB-{}    {:>12.2?}  {:>12.2?}  {:.2}×",
            kind.name(),
            rpcool,
            uds,
            uds.as_secs_f64() / rpcool.as_secs_f64()
        );
    }
    Ok(())
}
