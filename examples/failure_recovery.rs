//! Failure handling walkthrough (paper §4.6/§5.4, Figure 5): a server
//! crashes mid-conversation; the orchestrator's lease machinery
//! notices, notifies the surviving client, and reclaims the orphaned
//! heap once the client lets go. Quotas stop a client from hoarding.
//!
//! Run: `cargo run --release --example failure_recovery`

use rpcool::channel::{CallOpts, Rpc};
use rpcool::orchestrator::Notification;
use rpcool::{Rack, SimConfig};
use std::time::Duration;

fn main() -> rpcool::Result<()> {
    let mut cfg = SimConfig::for_tests(); // fast leases for the demo
    cfg.lease_ttl_ms = 100;
    cfg.lease_renew_ms = 25;
    let rack = Rack::new(cfg);

    // Scenario (a): server crash orphans its heap (Fig. 5a).
    let server_env = rack.proc_env(0);
    let server = Rpc::open(&server_env, "fragile")?;
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v * 2));
    let listener = server.spawn_listener();

    let client_env = rack.proc_env(1);
    let conn = Rpc::connect(&client_env, "fragile")?;
    client_env.enter();
    let arg = conn.new_val(21u64)?;
    println!("call before crash: 21*2 = {}", conn.invoke(1, arg, CallOpts::new())?);
    println!("live heaps: {}", rack.orch.live_heaps());

    // The server "crashes": its listener stops, its leases lapse.
    server.stop();
    listener.join().unwrap();
    drop(server);
    println!("\n-- server crashed (stops renewing its lease) --");
    std::thread::sleep(Duration::from_millis(150));
    let expired = rack.orch.tick();
    println!("orchestrator tick: {expired} lease(s) expired");

    for note in rack.orch.poll_notifications(client_env.proc) {
        match note {
            Notification::PeerFailed { proc, heap_id } => {
                println!("client notified: peer proc {proc} failed (heap {heap_id})")
            }
            Notification::ChannelDown { name } => println!("client notified: channel '{name}' down"),
            Notification::HeapReclaimed { heap_id } => println!("heap {heap_id} reclaimed"),
        }
    }

    // The client may keep reading previously shared data...
    println!("client still reads shared data: {}", unsafe {
        rpcool::memory::ShmPtr::<u64>::from_addr(arg.addr()).read_unchecked()
    });
    // ...but communication fails, and closing releases the heap.
    drop(conn);
    rack.orch.tick();
    println!("after client close: live heaps = {}", rack.orch.live_heaps());

    // Scenario (b): quotas stop a hoarding client (Fig. 5b / §5.4).
    println!("\n-- quota enforcement --");
    let mut cfg = SimConfig::for_tests();
    cfg.quota_bytes = 3 * cfg.heap_bytes;
    let rack2 = Rack::new(cfg);
    let hoarder = rack2.proc_env(5);
    for i in 0..4 {
        match rack2.orch.create_heap(&format!("h{i}"), rack2.cfg.heap_bytes, hoarder.proc) {
            Ok(_) => println!("mapped heap {i} (held {} MiB)", rack2.orch.quota_held(hoarder.proc) >> 20),
            Err(e) => println!("heap {i} denied: {e}"),
        }
    }
    Ok(())
}
