//! RING CONTENTION: multi-threaded clients hammering one connection's
//! data path — the workload the indexed MPMC redesign (ISSUE 2) and
//! the shard striping + batched submission work (ISSUE 3) target. Not
//! a paper figure; this is the repo's own perf trajectory for the hot
//! path (DESIGN.md §7–§8).
//!
//! Three layers:
//! * `ring/raw/*` — the bare `RpcRing` with latency charging off, so
//!   the *structural* cost (ticket CAS, slot touch, padding) is what
//!   is measured, across 1–8 client threads on an 8-slot ring.
//! * `conn/charged/s{S}/t{T}` — full `call_typed` round trips through
//!   a shared connection with the cost model charging, swept over
//!   `ring_shards` ∈ {1, 4} × threads ∈ {1, 4, 8}. Each row carries
//!   per-shard claim counts (`shard{i}_claims`) so the striping is
//!   visible in the JSON record; throughput scaling from s1 → s4 at
//!   t4/t8 is the tentpole's acceptance signal.
//! * `conn/batched/b16` — `call_scalar_batch` pipelining 16 calls per
//!   doorbell on one thread: the amortized-submission point.
//!
//! `charged_ns_per_op` must stay at 2 doorbell signals per RPC for
//! the unbatched rows across hot-path refactors (the batched row is
//! *below* that — 1/16th of a signal on the publish side — which is
//! the whole point).
//!
//! Run: `cargo bench --bench ring_contention [-- --quick]`

use rpcool::benchkit::{fanout, BenchReport, Table};
use rpcool::channel::ring::{RpcRing, NO_SEAL, ST_OK};
use rpcool::channel::{CallOpts, ChannelBuilder, Connection};
use rpcool::memory::Heap;
use rpcool::metrics::Histogram;
use rpcool::{ChargePolicy, Rack, SimConfig};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

fn ring_raw(threads: u64, ops_per_thread: u64) -> (f64, Histogram) {
    let mut cfg = SimConfig::for_tests(); // Skip charging: raw structure
    cfg.charge = ChargePolicy::Skip;
    let pool = rpcool::memory::pool::Pool::new(&cfg).unwrap();
    let heap = Heap::new(&pool, "contend", 1 << 20).unwrap();
    let ring = Arc::new(RpcRing::create(&heap, 8).unwrap());

    let server = Arc::clone(&ring);
    let total = threads * ops_per_thread;
    let srv = std::thread::spawn(move || {
        let mut served = 0u64;
        while served < total {
            if let Some(i) = server.take_request() {
                let f = server.slot(i).func.load(Ordering::Relaxed);
                server.respond(i, ST_OK, f as u64 + 1);
                served += 1;
            } else {
                std::hint::spin_loop();
            }
        }
    });

    let hist = Arc::new(Histogram::new());
    let wall = fanout(threads as usize, |tid| {
        let tid = tid as u64;
        for k in 0..ops_per_thread {
            let t = Instant::now();
            let i = loop {
                if let Some(i) = ring.claim() {
                    break i;
                }
                std::hint::spin_loop();
            };
            ring.publish(i, (tid * ops_per_thread + k) as u32, 0, NO_SEAL, 0, 0);
            while !ring.response_ready(i) {
                std::hint::spin_loop();
            }
            let (st, _ret) = ring.consume(i);
            assert_eq!(st, ST_OK);
            hist.record(t.elapsed());
        }
    });
    srv.join().unwrap();
    (total as f64 / wall.as_secs_f64(), Arc::try_unwrap(hist).ok().unwrap())
}

/// Full `call_typed` round trips with the cost model charging,
/// through a connection with `shards` ring shards served by `shards`
/// listener workers. Returns (ops/s, latency hist, charged ns/op,
/// per-shard claim counts).
fn conn_charged(
    threads: u64,
    ops_per_thread: u64,
    shards: usize,
) -> (f64, Histogram, f64, Vec<u64>) {
    let rack = Rack::new(SimConfig::for_bench());
    let env = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_slots(8)
        .ring_shards(shards)
        .open(&env, "contend")
        .unwrap();
    server.serve::<u64, u64>(1, |_ctx, v| Ok(*v + 1));
    let listeners = server.spawn_listeners(shards);
    let cenv = rack.proc_env(1);
    let conn = Arc::new(Connection::connect(&cenv, "contend").unwrap());

    let charged_before = rack.pool.charger.total_charged_ns();
    let hist = Arc::new(Histogram::new());
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for tid in 0..threads {
        let conn = Arc::clone(&conn);
        let hist = Arc::clone(&hist);
        let env = cenv.clone();
        clients.push(std::thread::spawn(move || {
            env.run(|| {
                for k in 0..ops_per_thread {
                    let v = tid * 1_000_000 + k;
                    let t = Instant::now();
                    let r = conn.call_typed::<u64, u64>(1, &v, CallOpts::new()).unwrap();
                    assert_eq!(r.take().unwrap(), v + 1);
                    hist.record(t.elapsed());
                }
            });
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let wall = t0.elapsed();
    let total = threads * ops_per_thread;
    let charged = (rack.pool.charger.total_charged_ns() - charged_before) as f64 / total as f64;
    let claims = conn.shared.shard_claims();
    drop(conn);
    server.stop();
    for l in listeners {
        l.join().unwrap();
    }
    (total as f64 / wall.as_secs_f64(), Arc::try_unwrap(hist).ok().unwrap(), charged, claims)
}

/// Amortized submission: one thread pipelining `batch` calls per
/// doorbell through `call_scalar_batch`. Returns (ops/s, charged
/// ns/op).
fn conn_batched(batch: usize, ops: u64) -> (f64, f64) {
    let rack = Rack::new(SimConfig::for_bench());
    let env = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_slots(64)
        .open(&env, "contend-batch")
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let listener = server.spawn_listener();
    let cenv = rack.proc_env(1);
    let conn = Connection::connect(&cenv, "contend-batch").unwrap();

    let charged_before = rack.pool.charger.total_charged_ns();
    let vals: Vec<u64> = (0..batch as u64).collect();
    let rounds = ops / batch as u64;
    let t0 = Instant::now();
    cenv.run(|| {
        for _ in 0..rounds {
            let rets = conn.call_scalar_batch::<u64>(1, &vals, CallOpts::new()).unwrap();
            assert_eq!(rets.len(), batch);
        }
    });
    let wall = t0.elapsed();
    let total = rounds * batch as u64;
    let charged = (rack.pool.charger.total_charged_ns() - charged_before) as f64 / total as f64;
    drop(conn);
    server.stop();
    listener.join().unwrap();
    (total as f64 / wall.as_secs_f64(), charged)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let raw_ops: u64 = if quick { 20_000 } else { 200_000 };
    let conn_ops: u64 = if quick { 2_000 } else { 20_000 };
    let mut t = Table::new(&["Scenario", "threads", "ops/s", "p50", "p99", "charged ns/op"]);
    let mut rep = BenchReport::new("ring_contention");

    for threads in [1u64, 2, 4, 8] {
        let (thr, hist) = ring_raw(threads, raw_ops / threads);
        t.row(&[
            "ring/raw".into(),
            format!("{threads}"),
            format!("{thr:.0}"),
            Histogram::fmt_ns(hist.median_ns()),
            Histogram::fmt_ns(hist.p99_ns()),
            "-".into(),
        ]);
        rep.row_hist(&format!("ring/raw/t{threads}"), &hist, thr);
    }

    // The tentpole sweep: does striping the data path convert
    // per-ring throughput into per-connection scalability?
    for shards in [1usize, 4] {
        for threads in [1u64, 4, 8] {
            let (thr, hist, charged, claims) = conn_charged(threads, conn_ops / threads, shards);
            t.row(&[
                format!("conn/charged/s{shards}"),
                format!("{threads}"),
                format!("{thr:.0}"),
                Histogram::fmt_ns(hist.median_ns()),
                Histogram::fmt_ns(hist.p99_ns()),
                format!("{charged:.0}"),
            ]);
            rep.row_hist(&format!("conn/charged/s{shards}/t{threads}"), &hist, thr);
            rep.extra("charged_ns_per_op", charged);
            for (i, c) in claims.iter().enumerate() {
                rep.extra(&format!("shard{i}_claims"), *c as f64);
            }
        }
    }

    let (thr_b, charged_b) = conn_batched(16, conn_ops);
    t.row(&[
        "conn/batched/b16".into(),
        "1".into(),
        format!("{thr_b:.0}"),
        "-".into(),
        "-".into(),
        format!("{charged_b:.0}"),
    ]);
    rep.row("conn/batched/b16", 0.0, 0.0, 1e9 / thr_b, thr_b);
    rep.extra("charged_ns_per_op", charged_b);

    t.print("Ring contention — sharded MPMC data path under multi-threaded clients");
    println!(
        "\ninvariants: unbatched charged ns/op stays at 2 doorbell signals per RPC; the\n\
         batched row amortizes the publish signal across its batch; s4 rows at t4/t8\n\
         must beat their s1 counterparts (per-connection scalability)."
    );
    rep.emit();
}
