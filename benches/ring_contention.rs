//! RING CONTENTION: multi-threaded clients hammering one connection's
//! slot ring — the workload the indexed MPMC redesign targets. Not a
//! paper figure; this is the repo's own perf trajectory for the hot
//! path (see ISSUE 2 / DESIGN.md "Hot path anatomy").
//!
//! Two layers:
//! * `ring/raw/*` — the bare `RpcRing` with latency charging off, so
//!   the *structural* cost (ticket CAS, slot touch, padding) is what
//!   is measured, across 1–8 client threads on an 8-slot ring.
//! * `conn/charged/*` — full `call_typed` round trips through a
//!   shared connection with the cost model charging, including the
//!   lock-free argument arena.
//!
//! Each row reports throughput and per-op latency percentiles;
//! `charged_ns_per_op` must stay constant across hot-path refactors
//! (same number of doorbell events per RPC — the acceptance guard).
//!
//! Run: `cargo bench --bench ring_contention [-- --quick]`

use rpcool::benchkit::{BenchReport, Table};
use rpcool::channel::ring::{RpcRing, NO_SEAL, ST_OK};
use rpcool::channel::{CallOpts, ChannelBuilder, Connection};
use rpcool::memory::Heap;
use rpcool::metrics::Histogram;
use rpcool::{ChargePolicy, Rack, SimConfig};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

fn ring_raw(threads: u64, ops_per_thread: u64) -> (f64, Histogram) {
    let mut cfg = SimConfig::for_tests(); // Skip charging: raw structure
    cfg.charge = ChargePolicy::Skip;
    let pool = rpcool::memory::pool::Pool::new(&cfg).unwrap();
    let heap = Heap::new(&pool, "contend", 1 << 20).unwrap();
    let ring = Arc::new(RpcRing::create(&heap, 8).unwrap());

    let server = Arc::clone(&ring);
    let total = threads * ops_per_thread;
    let srv = std::thread::spawn(move || {
        let mut served = 0u64;
        while served < total {
            if let Some(i) = server.take_request() {
                let f = server.slot(i).func.load(Ordering::Relaxed);
                server.respond(i, ST_OK, f as u64 + 1);
                served += 1;
            } else {
                std::hint::spin_loop();
            }
        }
    });

    let hist = Arc::new(Histogram::new());
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for tid in 0..threads {
        let ring = Arc::clone(&ring);
        let hist = Arc::clone(&hist);
        clients.push(std::thread::spawn(move || {
            for k in 0..ops_per_thread {
                let t = Instant::now();
                let i = loop {
                    if let Some(i) = ring.claim() {
                        break i;
                    }
                    std::hint::spin_loop();
                };
                ring.publish(i, (tid * ops_per_thread + k) as u32, 0, NO_SEAL, 0, 0);
                while !ring.response_ready(i) {
                    std::hint::spin_loop();
                }
                let (st, _ret) = ring.consume(i);
                assert_eq!(st, ST_OK);
                hist.record(t.elapsed());
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    srv.join().unwrap();
    let wall = t0.elapsed();
    (total as f64 / wall.as_secs_f64(), Arc::try_unwrap(hist).ok().unwrap())
}

fn conn_charged(threads: u64, ops_per_thread: u64) -> (f64, Histogram, f64) {
    let rack = Rack::new(SimConfig::for_bench());
    let env = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_slots(8)
        .open(&env, "contend")
        .unwrap();
    server.serve::<u64, u64>(1, |_ctx, v| Ok(*v + 1));
    let listener = server.spawn_listener();
    let cenv = rack.proc_env(1);
    let conn = Arc::new(Connection::connect(&cenv, "contend").unwrap());

    let charged_before = rack.pool.charger.total_charged_ns();
    let hist = Arc::new(Histogram::new());
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for tid in 0..threads {
        let conn = Arc::clone(&conn);
        let hist = Arc::clone(&hist);
        let env = cenv.clone();
        clients.push(std::thread::spawn(move || {
            env.run(|| {
                for k in 0..ops_per_thread {
                    let v = tid * 1_000_000 + k;
                    let t = Instant::now();
                    let r = conn.call_typed::<u64, u64>(1, &v, CallOpts::new()).unwrap();
                    assert_eq!(r.take().unwrap(), v + 1);
                    hist.record(t.elapsed());
                }
            });
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let wall = t0.elapsed();
    let total = threads * ops_per_thread;
    let charged = (rack.pool.charger.total_charged_ns() - charged_before) as f64 / total as f64;
    drop(conn);
    server.stop();
    listener.join().unwrap();
    (total as f64 / wall.as_secs_f64(), Arc::try_unwrap(hist).ok().unwrap(), charged)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let raw_ops: u64 = if quick { 20_000 } else { 200_000 };
    let conn_ops: u64 = if quick { 2_000 } else { 20_000 };
    let mut t = Table::new(&["Scenario", "threads", "ops/s", "p50", "p99", "charged ns/op"]);
    let mut rep = BenchReport::new("ring_contention");

    for threads in [1u64, 2, 4, 8] {
        let (thr, hist) = ring_raw(threads, raw_ops / threads);
        t.row(&[
            "ring/raw".into(),
            format!("{threads}"),
            format!("{thr:.0}"),
            Histogram::fmt_ns(hist.median_ns()),
            Histogram::fmt_ns(hist.p99_ns()),
            "-".into(),
        ]);
        rep.row_hist(&format!("ring/raw/t{threads}"), &hist, thr);
    }

    for threads in [1u64, 4] {
        let (thr, hist, charged) = conn_charged(threads, conn_ops / threads);
        t.row(&[
            "conn/charged".into(),
            format!("{threads}"),
            format!("{thr:.0}"),
            Histogram::fmt_ns(hist.median_ns()),
            Histogram::fmt_ns(hist.p99_ns()),
            format!("{charged:.0}"),
        ]);
        rep.row_hist(&format!("conn/charged/t{threads}"), &hist, thr);
        rep.extra("charged_ns_per_op", charged);
    }

    t.print("Ring contention — MPMC slot ring under multi-threaded clients");
    println!(
        "\ninvariant: charged ns/op stays at 2 doorbell signals per RPC across refactors."
    );
    rep.emit();
}
