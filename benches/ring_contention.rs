//! RING CONTENTION: multi-threaded clients hammering one connection's
//! data path — the workload the indexed MPMC redesign (ISSUE 2), the
//! shard striping + batched submission work (ISSUE 3), and the
//! response-path overhaul (ISSUE 4: drain-k reply coalescing +
//! two-choice striping) target. Not a paper figure; this is the
//! repo's own perf trajectory for the hot path (DESIGN.md §7–§9).
//!
//! Layers:
//! * `ring/raw/*` — the bare `RpcRing` with latency charging off, so
//!   the *structural* cost (ticket CAS, slot touch, padding) is what
//!   is measured, across 1–8 client threads on an 8-slot ring.
//! * `conn/charged/s{S}/t{T}` — full `call_typed` round trips through
//!   a shared connection with the cost model charging, swept over
//!   `ring_shards` ∈ {1, 4} × threads ∈ {1, 4, 8}. Each row carries
//!   per-shard claim counts (`shard{i}_claims`) plus
//!   `signals_per_rpc` (charged ns ÷ cxl_signal_ns ÷ ops).
//! * `conn/charged/s4/t6/{fixed,choice2}` — the striping comparison:
//!   6 threads over 4 shards under fixed thread striping vs
//!   load-aware two-choice. Each row records `claims_spread`
//!   (max − min per-shard claims); two-choice must come in at ≤ half
//!   the fixed spread (ISSUE 4 acceptance, checked by CI).
//! * `conn/batched/s4/t8/b16/drain16` — the charged-doorbell
//!   invariant row: 8 threads × batches of 16 over 4 shards with
//!   drain-k 16. Publish amortized to 1/16 signal per RPC and replies
//!   coalesced by the drain sweep ⇒ `signals_per_rpc` must stay
//!   ≤ 1 + 1/k + ε (CI's doorbell-invariant gate asserts ≤ 1.1;
//!   pre-overhaul this configuration charged ~1.06, unbatched 2.0).
//! * `conn/batched/b16` — single-thread amortized submission, kept
//!   for trajectory continuity with ISSUE 3.
//!
//! Unbatched rows sit in [1 + 1/k, 2] signals per RPC depending on
//! how many replies each serving sweep coalesces; batched rows sit
//! near 1/16 + 1/B. The hard floor of 2 is gone — that is the point.
//!
//! Run: `cargo bench --bench ring_contention [-- --quick]`

use rpcool::benchkit::{fanout, BenchReport, Table};
use rpcool::channel::ring::{RpcRing, NO_SEAL, ST_OK};
use rpcool::channel::{CallOpts, ChannelBuilder, Connection};
use rpcool::memory::Heap;
use rpcool::metrics::Histogram;
use rpcool::{ChargePolicy, Rack, SimConfig};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

fn ring_raw(threads: u64, ops_per_thread: u64) -> (f64, Histogram) {
    let mut cfg = SimConfig::for_tests(); // Skip charging: raw structure
    cfg.charge = ChargePolicy::Skip;
    let pool = rpcool::memory::pool::Pool::new(&cfg).unwrap();
    let heap = Heap::new(&pool, "contend", 1 << 20).unwrap();
    let ring = Arc::new(RpcRing::create(&heap, 8).unwrap());

    let server = Arc::clone(&ring);
    let total = threads * ops_per_thread;
    let srv = std::thread::spawn(move || {
        let mut served = 0u64;
        while served < total {
            if let Some(i) = server.take_request() {
                let f = server.slot(i).func.load(Ordering::Relaxed);
                server.respond(i, ST_OK, f as u64 + 1);
                served += 1;
            } else {
                std::hint::spin_loop();
            }
        }
    });

    let hist = Arc::new(Histogram::new());
    let wall = fanout(threads as usize, |tid| {
        let tid = tid as u64;
        for k in 0..ops_per_thread {
            let t = Instant::now();
            let i = loop {
                if let Some(i) = ring.claim() {
                    break i;
                }
                std::hint::spin_loop();
            };
            ring.publish(i, (tid * ops_per_thread + k) as u32, 0, NO_SEAL, 0, 0);
            while !ring.response_ready(i) {
                std::hint::spin_loop();
            }
            let (st, _ret) = ring.consume(i);
            assert_eq!(st, ST_OK);
            hist.record(t.elapsed());
        }
    });
    srv.join().unwrap();
    (total as f64 / wall.as_secs_f64(), Arc::try_unwrap(hist).ok().unwrap())
}

/// Per-shard claim-count spread: max − min (how evenly traffic
/// actually striped).
fn spread(claims: &[u64]) -> u64 {
    match (claims.iter().max(), claims.iter().min()) {
        (Some(hi), Some(lo)) => hi - lo,
        _ => 0,
    }
}

/// Full `call_typed` round trips with the cost model charging,
/// through a connection with `shards` ring shards served by `shards`
/// listener workers, under fixed or two-choice striping. Returns
/// (ops/s, latency hist, charged ns/op, per-shard claim counts).
fn conn_charged(
    threads: u64,
    ops_per_thread: u64,
    shards: usize,
    two_choice: bool,
) -> (f64, Histogram, f64, Vec<u64>) {
    let rack = Rack::new(SimConfig::for_bench());
    let env = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_slots(8)
        .ring_shards(shards)
        .two_choice(two_choice)
        .open(&env, "contend")
        .unwrap();
    server.serve::<u64, u64>(1, |_ctx, v| Ok(*v + 1));
    let listeners = server.spawn_listeners(shards);
    let cenv = rack.proc_env(1);
    let conn = Arc::new(Connection::connect(&cenv, "contend").unwrap());

    let charged_before = rack.pool.charger.total_charged_ns();
    let hist = Arc::new(Histogram::new());
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for tid in 0..threads {
        let conn = Arc::clone(&conn);
        let hist = Arc::clone(&hist);
        let env = cenv.clone();
        clients.push(std::thread::spawn(move || {
            env.run(|| {
                for k in 0..ops_per_thread {
                    let v = tid * 1_000_000 + k;
                    let t = Instant::now();
                    let r = conn.call_typed::<u64, u64>(1, &v, CallOpts::new()).unwrap();
                    assert_eq!(r.take().unwrap(), v + 1);
                    hist.record(t.elapsed());
                }
            });
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let wall = t0.elapsed();
    let total = threads * ops_per_thread;
    let charged = (rack.pool.charger.total_charged_ns() - charged_before) as f64 / total as f64;
    let claims = conn.shared.shard_claims();
    drop(conn);
    server.stop();
    for l in listeners {
        l.join().unwrap();
    }
    (total as f64 / wall.as_secs_f64(), Arc::try_unwrap(hist).ok().unwrap(), charged, claims)
}

/// Amortized submission: `threads` threads each pipelining `batch`
/// calls per doorbell through `call_scalar_batch` over a sharded,
/// drain-k-served connection — the ISSUE 4 charged-doorbell
/// invariant configuration. Returns (ops/s, charged ns/op, per-shard
/// claim counts).
fn conn_batched(
    threads: u64,
    batch: usize,
    ops_per_thread: u64,
    shards: usize,
    drain_k: usize,
) -> (f64, f64, Vec<u64>) {
    let rack = Rack::new(SimConfig::for_bench());
    let env = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_slots(64)
        .ring_shards(shards)
        .drain_k(drain_k)
        .open(&env, "contend-batch")
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let listeners = server.spawn_listeners(shards);
    let cenv = rack.proc_env(1);
    let conn = Arc::new(Connection::connect(&cenv, "contend-batch").unwrap());

    let charged_before = rack.pool.charger.total_charged_ns();
    let rounds = ops_per_thread / batch as u64;
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for tid in 0..threads {
        let conn = Arc::clone(&conn);
        let env = cenv.clone();
        clients.push(std::thread::spawn(move || {
            env.run(|| {
                let vals: Vec<u64> = (0..batch as u64).map(|k| tid * 1_000_000 + k).collect();
                for _ in 0..rounds {
                    let rets = conn.call_scalar_batch::<u64>(1, &vals, CallOpts::new()).unwrap();
                    assert_eq!(rets.len(), batch);
                }
            });
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let wall = t0.elapsed();
    let total = threads * rounds * batch as u64;
    let charged = (rack.pool.charger.total_charged_ns() - charged_before) as f64 / total as f64;
    let claims = conn.shared.shard_claims();
    drop(conn);
    server.stop();
    for l in listeners {
        l.join().unwrap();
    }
    (total as f64 / wall.as_secs_f64(), charged, claims)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let raw_ops: u64 = if quick { 20_000 } else { 200_000 };
    let conn_ops: u64 = if quick { 2_000 } else { 20_000 };
    let signal_ns = SimConfig::for_bench().cost.cxl_signal_ns as f64;
    let mut t = Table::new(&["Scenario", "threads", "ops/s", "p50", "p99", "signals/RPC"]);
    let mut rep = BenchReport::new("ring_contention");
    // 2ms latency SLO: every histogram row reports its deep tail
    // (p999_ns) and how many samples blew the budget (slo_miss).
    rep.slo(2_000_000);

    for threads in [1u64, 2, 4, 8] {
        let (thr, hist) = ring_raw(threads, raw_ops / threads);
        t.row(&[
            "ring/raw".into(),
            format!("{threads}"),
            format!("{thr:.0}"),
            Histogram::fmt_ns(hist.median_ns()),
            Histogram::fmt_ns(hist.p99_ns()),
            "-".into(),
        ]);
        rep.row_hist(&format!("ring/raw/t{threads}"), &hist, thr);
    }

    // The ISSUE 3 sweep: does striping the data path convert per-ring
    // throughput into per-connection scalability? (two-choice on, the
    // new default)
    for shards in [1usize, 4] {
        for threads in [1u64, 4, 8] {
            let (thr, hist, charged, claims) =
                conn_charged(threads, conn_ops / threads, shards, true);
            let sig = charged / signal_ns;
            t.row(&[
                format!("conn/charged/s{shards}"),
                format!("{threads}"),
                format!("{thr:.0}"),
                Histogram::fmt_ns(hist.median_ns()),
                Histogram::fmt_ns(hist.p99_ns()),
                format!("{sig:.2}"),
            ]);
            rep.row_hist(&format!("conn/charged/s{shards}/t{threads}"), &hist, thr);
            rep.extra("charged_ns_per_op", charged);
            rep.extra("signals_per_rpc", sig);
            for (i, c) in claims.iter().enumerate() {
                rep.extra(&format!("shard{i}_claims"), *c as f64);
            }
        }
    }

    // The ISSUE 4 striping comparison: 6 threads over 4 shards leave
    // fixed striping structurally unbalanced (6 stripes mod 4 ⇒ two
    // shards carry double traffic); two-choice must halve the
    // per-shard claim spread in the same run.
    let mut spreads = [0u64; 2];
    for (idx, two_choice) in [false, true].into_iter().enumerate() {
        let label = if two_choice { "choice2" } else { "fixed" };
        let (thr, hist, charged, claims) = conn_charged(6, conn_ops / 6, 4, two_choice);
        let sp = spread(&claims);
        spreads[idx] = sp;
        t.row(&[
            format!("conn/charged/s4/t6/{label}"),
            "6".into(),
            format!("{thr:.0}"),
            Histogram::fmt_ns(hist.median_ns()),
            Histogram::fmt_ns(hist.p99_ns()),
            format!("{:.2}", charged / signal_ns),
        ]);
        rep.row_hist(&format!("conn/charged/s4/t6/{label}"), &hist, thr);
        rep.extra("charged_ns_per_op", charged);
        rep.extra("signals_per_rpc", charged / signal_ns);
        rep.extra("claims_spread", sp as f64);
        for (i, c) in claims.iter().enumerate() {
            rep.extra(&format!("shard{i}_claims"), *c as f64);
        }
    }

    // The ISSUE 4 charged-doorbell invariant row (shards=4, threads=8,
    // drain-k=16, batch 16): publish amortized per batch, replies
    // coalesced per sweep — CI asserts signals_per_rpc ≤ 1.1 here.
    let (thr_mb, charged_mb, claims_mb) = conn_batched(8, 16, conn_ops / 8, 4, 16);
    let sig_mb = charged_mb / signal_ns;
    t.row(&[
        "conn/batched/s4/t8/b16/drain16".into(),
        "8".into(),
        format!("{thr_mb:.0}"),
        "-".into(),
        "-".into(),
        format!("{sig_mb:.2}"),
    ]);
    rep.row("conn/batched/s4/t8/b16/drain16", 0.0, 0.0, 1e9 / thr_mb, thr_mb);
    rep.extra("charged_ns_per_op", charged_mb);
    rep.extra("signals_per_rpc", sig_mb);
    rep.extra("claims_spread", spread(&claims_mb) as f64);
    for (i, c) in claims_mb.iter().enumerate() {
        rep.extra(&format!("shard{i}_claims"), *c as f64);
    }

    // Single-thread amortized row (trajectory continuity with ISSUE 3).
    let (thr_b, charged_b, _claims_b) = conn_batched(1, 16, conn_ops, 1, 16);
    t.row(&[
        "conn/batched/b16".into(),
        "1".into(),
        format!("{thr_b:.0}"),
        "-".into(),
        "-".into(),
        format!("{:.2}", charged_b / signal_ns),
    ]);
    rep.row("conn/batched/b16", 0.0, 0.0, 1e9 / thr_b, thr_b);
    rep.extra("charged_ns_per_op", charged_b);
    rep.extra("signals_per_rpc", charged_b / signal_ns);

    t.print("Ring contention — sharded MPMC data path under multi-threaded clients");
    println!(
        "\ninvariants: unbatched signals/RPC ∈ [1 + 1/drain_k, 2] (reply doorbells\n\
         coalesce per serving sweep; the old hard floor of 2 is gone); the\n\
         s4/t8/b16/drain16 row must stay ≤ 1.1 signals/RPC; two-choice claim\n\
         spread at s4/t6 must be ≤ half the fixed-striping spread; s4 rows at\n\
         t4/t8 must beat their s1 counterparts (per-connection scalability)."
    );
    println!(
        "striping spread s4/t6: fixed {} vs two-choice {}",
        spreads[0], spreads[1]
    );
    rep.emit();
}
