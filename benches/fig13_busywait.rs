//! FIGURE 13: throughput–latency tradeoff of the busy-wait sleep
//! policy (paper §5.8): 0µs, 5µs, 150µs between poll iterations —
//! plus this repo's fourth point, `park`, where idle pollers block on
//! the connection doorbell instead of timed sleeps.
//!
//! Paper shape: no sleep → best latency, throughput capped by burned
//! CPU; 150µs → higher tail latency, higher peak throughput (polling
//! CPUs yield to workers). On the simulation host we reproduce the
//! *latency* side directly (sleep adds to RTT when a request lands
//! mid-sleep) and report poll-CPU burn as the throughput proxy.
//! `park` should track the 0µs point's latency while burning no idle
//! CPU at all.
//!
//! Run: `cargo bench --bench fig13_busywait [-- --quick]`

use rpcool::apps::socialnet::{sample_post, RpcoolSocial, SocialState};
use rpcool::benchkit::{BenchReport, Table};
use rpcool::channel::waiter::SleepPolicy;
use rpcool::metrics::Histogram;
use rpcool::util::Rng;
use rpcool::{Rack, SimConfig};
use std::time::{Duration, Instant};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let nreq = if quick { 200 } else { 2_000 };
    let nusers = 500;
    let rack = Rack::new(SimConfig::for_bench());
    let mut t = Table::new(&["sleep (µs)", "p50", "p99", "req/s", "server poll wakeups/req"]);
    let mut rep = BenchReport::new("fig13_busywait");
    // 5ms SLO: the 150µs sleep point's tail lives in the hundreds of
    // µs. Set before any row so slo_miss fills (ISSUE 8 audit).
    rep.slo(5_000_000);

    for (label, policy) in [
        ("0", SleepPolicy::Spin),
        ("5", SleepPolicy::Fixed(5)),
        ("150", SleepPolicy::Fixed(150)),
        ("park", SleepPolicy::Park),
    ] {
        let sleep_us: u64 = label.parse().unwrap_or(0);
        let state = SocialState::new(nusers, 16, 1);
        let net =
            RpcoolSocial::start(&rack, state, policy, false, &format!("f13-{label}")).unwrap();
        // NOT inline: the sleep policy only matters with real pollers.
        let hist = Histogram::new();
        let mut rng = Rng::new(4);
        let t0 = Instant::now();
        for _ in 0..nreq {
            let (user, text) = sample_post(&mut rng, nusers);
            let tt = Instant::now();
            net.compose_post(user, &text).unwrap();
            hist.record(tt.elapsed());
        }
        let wall = t0.elapsed();
        let reqs = nreq as f64 / wall.as_secs_f64();
        // Poll-burn proxy for timed sleeps: wakeups ≈ wall/sleep per
        // poller. Parking is event-driven — there is no honest number
        // to derive here, so the park row reports none rather than a
        // fabricated constant the perf trajectory couldn't falsify.
        if policy == SleepPolicy::Park {
            t.row(&[
                label.to_string(),
                Histogram::fmt_ns(hist.median_ns()),
                Histogram::fmt_ns(hist.p99_ns()),
                format!("{reqs:.0}"),
                "event-driven".into(),
            ]);
            rep.row_hist(label, &hist, reqs);
        } else {
            let wakeups =
                4.0 * wall.as_secs_f64() * 1e6 / (sleep_us.max(1) as f64) / nreq as f64;
            t.row(&[
                label.to_string(),
                Histogram::fmt_ns(hist.median_ns()),
                Histogram::fmt_ns(hist.p99_ns()),
                format!("{reqs:.0}"),
                format!("{wakeups:.1}"),
            ]);
            rep.row_hist(label, &hist, reqs);
            rep.extra("poll_wakeups_per_req", wakeups);
        }
        net.stop();
        std::thread::sleep(Duration::from_millis(50));
    }

    t.print("Figure 13 — busy-wait sleep sweep (paper: 0µs best latency/capped throughput; 150µs higher tail, higher peak; park: idle pollers block on the doorbell)");
    rep.emit();
}
