//! OPEN-LOOP LOAD SWEEP: the tail-latency-truth harness (DESIGN.md
//! §13). Not a paper figure; this is the repo's perf trajectory for
//! queueing behaviour under offered load.
//!
//! Every other bench in this repo is closed-loop: the caller waits
//! for each reply before issuing the next request, so a server stall
//! pushes the *rest of the run* back in time and the stall's queueing
//! delay never lands in any recorded latency — coordinated omission.
//! This bench runs every scenario both ways at the SAME interarrival
//! plan and pairs the rows:
//!
//! * `…/closed` — gaps paced from the previous completion, latency
//!   from actual send (the methodology that hides queueing);
//! * `…/open`   — arrivals fixed on the wall clock, latency from the
//!   *scheduled* arrival (`benchkit::run_open_loop`), late sends
//!   counted (`late_sends`/`max_late_ns` extras).
//!
//! CI holds `open p99 ≥ closed p99` on every pair — the gap IS the
//! coordinated omission, and it must be visible, never negative.
//!
//! Layers:
//! * `ol/{dedicated,pooled,elastic}/r{50,90}/{closed,open}` — echo
//!   RPCs against one channel config at 50% / 90% of its calibrated
//!   single-worker closed-loop capacity, 4 open-loop workers striping
//!   one fixed-rate schedule (`Schedule::stripe`).
//! * `ol/{cfg}/burst/{closed,open}` — same configs under a bursty
//!   plan (16-deep back-to-back groups at 70% capacity): the burst
//!   drains fine closed-loop and queues visibly open-loop.
//! * `ol/{cfg}/poisson/{closed,open}` — same configs under a seeded
//!   Poisson plan at 70% capacity: memoryless interarrivals, the
//!   queueing-theory reference workload.
//! * `ol/mixed/{kv,scan,compose}/{closed,open}` — three tenants of
//!   `apps::mixed::MixedTenants` (memcached YCSB-B stream, CoolDB
//!   range scans, socialnet compose storms) loaded *concurrently*
//!   against one rack, each tenant on its own schedule; compose rides
//!   a bursty plan (storms), the others fixed-rate.
//!
//! Charging is skipped (structural wall-clock timing): the sweep
//! measures the ring/doorbell/pool machinery's queueing under load,
//! not simulated CXL spins stacked on top of it.
//!
//! Run: `cargo bench --bench open_loop [-- --quick]`

use rpcool::apps::mixed::MixedTenants;
use rpcool::benchkit::{
    fanout_load, run_closed_paced, run_open_loop, BenchReport, LoadReport, Schedule, Table,
};
use rpcool::channel::{CallOpts, ChannelBuilder, Connection, RpcServer};
use rpcool::metrics::Histogram;
use rpcool::{ChargePolicy, Rack, SimConfig};
use std::sync::Arc;
use std::time::Instant;

/// Open-loop workers striping each schedule in the echo sweep.
const WORKERS: usize = 4;

fn cfg() -> SimConfig {
    let mut c = SimConfig::for_bench();
    c.charge = ChargePolicy::Skip;
    c.pool_bytes = 1 << 30;
    c
}

/// Stand up one echo channel in the named configuration. Returns the
/// server and its dedicated listener handles (empty when pooled).
fn echo_server(
    rack: &Arc<Rack>,
    config: &str,
    name: &str,
) -> (RpcServer, Vec<std::thread::JoinHandle<()>>) {
    let env = rack.proc_env(0);
    let b = ChannelBuilder::from_config(&rack.cfg).heap_bytes(1 << 20).ring_slots(64);
    let (server, handles) = match config {
        "dedicated" => {
            let s = b.ring_shards(2).open(&env, name).unwrap();
            let h = s.spawn_listeners(2);
            (s, h)
        }
        "pooled" => {
            let s = b.ring_shards(2).pool_workers(4).open(&env, name).unwrap();
            let h = s.spawn_listeners(1); // no-op in pooled mode
            (s, h)
        }
        "elastic" => {
            let s = b.ring_shards(8).elastic_shards(true).open(&env, name).unwrap();
            let h = s.spawn_listeners(2);
            (s, h)
        }
        other => panic!("unknown config {other}"),
    };
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    (server, handles)
}

/// Single-worker closed-loop capacity estimate, ops/s: `n` unpaced
/// echo calls. The sweep's offered rates are fractions of
/// `WORKERS ×` this (optimistic on purpose — r90 *should* flirt with
/// saturation; that is where open and closed diverge).
fn calibrate(rack: &Arc<Rack>, name: &str, n: usize) -> f64 {
    let env = rack.proc_env(7);
    let conn = Connection::connect(&env, name).unwrap();
    env.run(|| {
        let t0 = Instant::now();
        for k in 0..n as u64 {
            let r = conn.call_typed::<u64, u64>(1, &k, CallOpts::new()).unwrap();
            assert_eq!(r.take().unwrap(), k + 1);
        }
        n as f64 / t0.elapsed().as_secs_f64()
    })
}

/// Run one schedule against the echo channel in both pacing modes and
/// emit the paired rows. Each worker gets its own proc + connection.
fn echo_pair(
    rep: &mut BenchReport,
    t: &mut Table,
    rack: &Arc<Rack>,
    name: &str,
    label: &str,
    sched: &Schedule,
) {
    let drive = |paced: bool| -> LoadReport {
        fanout_load(WORKERS, sched, |w, sub| {
            let env = rack.proc_env(8 + w as u32);
            let conn = Connection::connect(&env, name).unwrap();
            env.run(|| {
                let op = |i: usize| {
                    let r = conn.call_typed::<u64, u64>(1, &(i as u64), CallOpts::new()).unwrap();
                    assert_eq!(r.take().unwrap(), i as u64 + 1);
                };
                if paced {
                    run_closed_paced(sub, op)
                } else {
                    run_open_loop(sub, op)
                }
            })
        })
    };
    let offered = sched.offered_rate();
    for (mode, load) in [("closed", drive(true)), ("open", drive(false))] {
        let row = format!("{label}/{mode}");
        t.row(&[
            row.clone(),
            format!("{offered:.0}"),
            format!("{:.0}", load.throughput()),
            Histogram::fmt_ns(load.hist.median_ns()),
            Histogram::fmt_ns(load.hist.p99_ns()),
            Histogram::fmt_ns(load.hist.p999_ns()),
            format!("{}", load.late_sends),
        ]);
        rep.row_load(&row, &load, offered);
        rep.extra("workers", WORKERS as f64);
    }
}

/// Unpaced closed-loop rate of `n` steps, ops/s.
fn rate_of(n: usize, mut step: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    for _ in 0..n {
        step();
    }
    n as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let calib_n = if quick { 300 } else { 2_000 };
    let sweep_n = if quick { 600 } else { 4_000 };

    let mut t = Table::new(&["Scenario", "offered/s", "done/s", "p50", "p99", "p99.9", "late"]);
    let mut rep = BenchReport::new("open_loop");
    // 1ms SLO on every row: at r50 essentially nothing should miss
    // it; at r90 the open rows show what the closed rows hide.
    rep.slo(1_000_000);

    // ---- echo sweep: offered load vs channel configuration --------
    for config in ["dedicated", "pooled", "elastic"] {
        let rack = Rack::new(cfg());
        let name = format!("ol-{config}");
        let (server, handles) = echo_server(&rack, config, &name);
        let cap = calibrate(&rack, &name, calib_n) * WORKERS as f64;
        for (tag, frac) in [("r50", 0.5), ("r90", 0.9)] {
            let sched = Schedule::fixed_rate(sweep_n, cap * frac);
            echo_pair(&mut rep, &mut t, &rack, &name, &format!("ol/{config}/{tag}"), &sched);
        }
        // Bursty plan: 16 back-to-back arrivals per group, 70% of
        // capacity on average — the group always outruns the server
        // for a moment, and only the open rows are allowed to see it.
        let sched = Schedule::bursty(sweep_n, cap * 0.7, 16);
        echo_pair(&mut rep, &mut t, &rack, &name, &format!("ol/{config}/burst"), &sched);
        // Poisson plan at the same average rate: memoryless arrivals
        // are the textbook open-loop workload — exponential gaps pile
        // up in runs the fixed-rate plan never produces, so the
        // open/closed divergence shows queueing under *natural*
        // variance, not just engineered bursts.
        let sched = Schedule::poisson(sweep_n, cap * 0.7, 42);
        echo_pair(&mut rep, &mut t, &rack, &name, &format!("ol/{config}/poisson"), &sched);
        server.stop();
        for h in handles {
            h.join().unwrap();
        }
    }

    // ---- mixed tenants: three apps, one rack, concurrent schedules -
    let rack = Rack::new(cfg());
    let (nkeys, ndocs, nusers) = if quick { (500, 100, 100) } else { (2_000, 400, 200) };
    let mixed = MixedTenants::start(&rack, "ol", nkeys, ndocs, nusers, 42).unwrap();

    // Calibrate each tenant's single-worker closed rate.
    let calib_t = if quick { 60 } else { 300 };
    let mut kv = mixed.kv_driver(8, 1).unwrap();
    let mut scan = mixed.scan_driver(9, 2).unwrap();
    let mut compose = mixed.compose_driver(3);
    let kv_rate = rate_of(calib_t, || kv.step().unwrap());
    let scan_rate = rate_of(calib_t / 3 + 1, || {
        scan.step().unwrap();
    });
    let compose_rate = rate_of(calib_t, || {
        compose.step().unwrap();
    });

    let (n_kv, n_scan, n_cp) = if quick { (400, 60, 150) } else { (2_500, 400, 1_000) };
    // 60% of each tenant's solo rate — concurrently, the three
    // tenants contend for the same daemon, so the effective pressure
    // is well above 60%.
    let kv_sched = Schedule::fixed_rate(n_kv, kv_rate * 0.6);
    let scan_sched = Schedule::fixed_rate(n_scan, scan_rate * 0.6);
    // Compose storms: 8-post bursts (the "storm" shape).
    let cp_sched = Schedule::bursty(n_cp, compose_rate * 0.6, 8);

    for paced in [true, false] {
        let mode = if paced { "closed" } else { "open" };
        let (kv_load, scan_load, cp_load) = std::thread::scope(|s| {
            let hk = s.spawn(|| {
                let op = |_i: usize| kv.step().unwrap();
                if paced { run_closed_paced(&kv_sched, op) } else { run_open_loop(&kv_sched, op) }
            });
            let hs = s.spawn(|| {
                let op = |_i: usize| {
                    scan.step().unwrap();
                };
                if paced {
                    run_closed_paced(&scan_sched, op)
                } else {
                    run_open_loop(&scan_sched, op)
                }
            });
            let hc = s.spawn(|| {
                let op = |_i: usize| {
                    compose.step().unwrap();
                };
                if paced {
                    run_closed_paced(&cp_sched, op)
                } else {
                    run_open_loop(&cp_sched, op)
                }
            });
            (hk.join().unwrap(), hs.join().unwrap(), hc.join().unwrap())
        });
        for (tenant, load, sched) in [
            ("kv", kv_load, &kv_sched),
            ("scan", scan_load, &scan_sched),
            ("compose", cp_load, &cp_sched),
        ] {
            let row = format!("ol/mixed/{tenant}/{mode}");
            let offered = sched.offered_rate();
            t.row(&[
                row.clone(),
                format!("{offered:.0}"),
                format!("{:.0}", load.throughput()),
                Histogram::fmt_ns(load.hist.median_ns()),
                Histogram::fmt_ns(load.hist.p99_ns()),
                Histogram::fmt_ns(load.hist.p999_ns()),
                format!("{}", load.late_sends),
            ]);
            rep.row_load(&row, &load, offered);
            rep.extra("workers", 1.0);
        }
    }
    drop(kv);
    drop(scan);
    drop(compose);
    mixed.stop();

    t.print("Open-loop load sweep — scheduled-arrival latency vs closed-loop pacing");
    println!(
        "\ninvariants: on every paired row, open p99 >= closed p99 at the same\n\
         offered load (CI gate) — the difference is the coordinated omission\n\
         closed-loop benches hide; late_sends counts arrivals the generator\n\
         missed by >= 1us, whose backlog the open rows carry in-band."
    );
    rep.emit();
}
