//! FIGURE 10: MongoDB running YCSB A–F over RPCool (CXL) vs UDS, and
//! RPCool-DSM vs TCP(IPoIB).
//!
//! Paper shape: RPCool wins everywhere except YCSB-E (scans move bulk
//! results, which favors the socket's streaming path over far-memory
//! materialization); DSM ≥ 1.34× vs TCP.
//!
//! Run: `cargo bench --bench fig10_mongodb [-- --quick|--full]`

use rpcool::apps::mongodb::{run_ycsb, serve_net, serve_rpcool, DocStore, RpcoolDoc};
use rpcool::baselines::netrpc::Flavor;
use rpcool::benchkit::{BenchReport, Table};
use rpcool::channel::TransportSel;
use rpcool::workloads::ycsb::WorkloadKind;
use rpcool::{Rack, SimConfig};
use std::sync::Arc;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let full = std::env::args().any(|a| a == "--full");
    let (nkeys, nops): (u64, usize) = if full {
        (100_000, 1_000_000)
    } else if quick {
        (1_000, 4_000)
    } else {
        (5_000, 30_000)
    };
    let rack = Rack::new(SimConfig::for_bench());
    let mut t = Table::new(&["Workload", "RPCool", "UDS", "spd", "RPCool(DSM)", "TCP(IPoIB)", "spd"]);
    let mut rep = BenchReport::new("fig10_mongodb");

    for kind in WorkloadKind::all() {
        // RPCool (CXL).
        let env = rack.proc_env(0);
        let store = DocStore::new();
        let server = serve_rpcool(&env, &format!("f10/cxl/{}", kind.name()), store).unwrap();
        let cenv = rack.proc_env(1);
        let db = RpcoolDoc::connect(&cenv, &format!("f10/cxl/{}", kind.name())).unwrap();
        db.conn().attach_inline(&server);
        cenv.enter();
        let (_l, cxl) = run_ycsb(&db, kind, nkeys, nops, 9).unwrap();
        drop(db);
        server.stop();

        // UDS.
        let store = DocStore::new();
        let (srv, db) = serve_net(Flavor::Uds, Arc::clone(&rack.pool.charger), store);
        db.client_inline(&srv);
        let (_l, uds) = run_ycsb(&db, kind, nkeys, nops, 9).unwrap();
        srv.stop();

        // RPCool over DSM.
        let env = rack.proc_env(0);
        let store = DocStore::new();
        let server = serve_rpcool(&env, &format!("f10/dsm/{}", kind.name()), store).unwrap();
        let renv = rack.remote_proc_env();
        let conn = rpcool::channel::Connection::connect_with(
            &renv,
            &format!("f10/dsm/{}", kind.name()),
            TransportSel::Rdma,
        )
        .unwrap();
        conn.attach_inline(&server);
        let db = RpcoolDoc::from_conn(conn).unwrap();
        renv.enter();
        let (_l, dsm) = run_ycsb(&db, kind, nkeys, nops, 9).unwrap();
        drop(db);
        server.stop();

        // TCP over IPoIB.
        let store = DocStore::new();
        let (srv, db) = serve_net(Flavor::Tcp, Arc::clone(&rack.pool.charger), store);
        db.client_inline(&srv);
        let (_l, tcp) = run_ycsb(&db, kind, nkeys, nops, 9).unwrap();
        srv.stop();

        t.row(&[
            format!("YCSB-{}", kind.name()),
            format!("{cxl:.2?}"),
            format!("{uds:.2?}"),
            format!("{:.2}×", uds.as_secs_f64() / cxl.as_secs_f64()),
            format!("{dsm:.2?}"),
            format!("{tcp:.2?}"),
            format!("{:.2}×", tcp.as_secs_f64() / dsm.as_secs_f64()),
        ]);
        for (transport, wall) in
            [("rpcool_cxl", cxl), ("uds", uds), ("rpcool_dsm", dsm), ("tcp", tcp)]
        {
            rep.row(
                &format!("ycsb_{}/{}", kind.name(), transport),
                0.0,
                0.0,
                wall.as_nanos() as f64 / nops as f64,
                nops as f64 / wall.as_secs_f64(),
            );
        }
    }

    t.print(&format!(
        "Figure 10 — MongoDB YCSB ({nkeys} keys, {nops} ops; paper: RPCool wins except E; DSM ≥1.34× vs TCP)"
    ));
    rep.emit();
}
