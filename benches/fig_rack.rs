//! FIG rack: the cluster plane's latency cliff. One rack split into
//! two CXL pods; the same typed no-op call site runs intra-pod
//! (Auto → CXL, ~1.5µs) and cross-pod (Auto → RDMA/DSM, ~17µs), then
//! across workload mixes of 0/25/50/100% cross-pod calls.
//!
//! The point of the figure: transport selection is transparent — the
//! code is identical on both sides of the pod boundary, only the
//! topology differs — and the cost of crossing it is the paper's
//! CXL-vs-RDMA gap (§4.7: software coherence over RDMA beyond the
//! pod), visible in the DSM fault/page counters exported per row.
//!
//! Run: `cargo bench --bench fig_rack` (add `-- --quick`).

use rpcool::benchkit::{fmt_ns, time_op, BenchReport, Table};
use rpcool::channel::{CallOpts, Connection, Rpc};
use rpcool::memory::ShmPtr;
use rpcool::{Rack, SimConfig};
use std::cell::Cell;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n = if quick { 20_000 } else { 200_000 };

    let mut cfg = SimConfig::for_bench();
    cfg.pods = 2; // hosts 0..15 → pod 0, 16..31 → pod 1
    let rack = Rack::new(cfg);
    let mut table = Table::new(&["Mix", "RTT", "Throughput (K req/s)", "Transport"]);
    let mut rep = BenchReport::new("fig_rack");
    // 100µs SLO: far above the ~17µs cross-pod RTT — misses mean
    // queueing, not transport. Set before any row (ISSUE 8 audit).
    rep.slo(100_000);

    // One server in pod 0; both clients use the identical Auto-mode
    // call site — the topology alone picks the fabric.
    let senv = rack.proc_env(0);
    let server = Rpc::open(&senv, "bench/rack").unwrap();
    server.add(1, |_| Ok(0));

    let ienv = rack.proc_env(1); // pod 0: CXL
    let intra = Connection::connect(&ienv, "bench/rack").unwrap();
    intra.attach_inline(&server);
    assert!(!intra.shared.is_dsm(), "in-pod Auto must select CXL");

    let xenv = rack.proc_env(16); // pod 1: RDMA/DSM
    let cross = Connection::connect(&xenv, "bench/rack").unwrap();
    cross.attach_inline(&server);
    assert!(cross.shared.is_dsm(), "cross-pod Auto must select RDMA/DSM");
    let dsm = cross.shared.dsm.as_ref().unwrap().clone();

    // A realistic cross-pod call ships a small argument scope whose
    // pages ping-pong between the pods (that IS the DSM cost) — the
    // client re-touches the page after every call, as in table1a's
    // RDMA row.
    xenv.enter();
    let xscope = cross.create_scope(4096).unwrap();
    let xaddr = xscope.new_val(0u64).unwrap();
    ienv.enter();
    let iscope = intra.create_scope(4096).unwrap();
    let iaddr = iscope.new_val(0u64).unwrap();

    // The mix loop interleaves both clients on one thread: re-bind the
    // right proc identity per call (a thread-local store, noise at
    // µs-scale RTTs).
    let cross_call = || {
        xenv.enter();
        cross.invoke(1, (xaddr, 8), CallOpts::new()).unwrap();
        ShmPtr::<u64>::from_addr(xaddr).write(1).unwrap();
    };
    let intra_call = || {
        ienv.enter();
        intra.invoke(1, (iaddr, 8), CallOpts::new()).unwrap();
    };

    let mut intra_p50 = 0.0f64;
    let mut cross_p50 = 0.0f64;
    for &pct in &[0u64, 25, 50, 100] {
        let label = match pct {
            0 => "rack/intra",
            100 => "rack/cross",
            p if p == 25 => "rack/mix25",
            _ => "rack/mix50",
        };
        // Cross-pod ops dominate the mean, so scale the op count down
        // as the mix gets more expensive.
        let ops = if pct == 0 { n } else { n / 10 };
        let (f0, p0) = dsm.stats();
        let c0 = dsm.charged_ns();
        let i = Cell::new(0u64);
        let op = || {
            let k = i.get();
            i.set(k + 1);
            if (k % 100) < pct {
                cross_call();
            } else {
                intra_call();
            }
        };
        // One per-op-timed population: mean, tail, and the DSM fault
        // deltas below all describe the same `ops` calls (the old
        // two-run split paired a full-run mean with a 10×-smaller
        // run's tail).
        let (mean, hist) = time_op(ops / 100 + 10, ops, &op);
        let (f1, p1) = dsm.stats();
        rep.row_hist(label, &hist, 1e9 / mean);
        rep.extra("cross_pct", pct as f64);
        rep.extra("dsm_faults", (f1 - f0) as f64);
        rep.extra("dsm_pages_transferred", (p1 - p0) as f64);
        rep.extra("dsm_charged_ns", (dsm.charged_ns() - c0) as f64);
        if pct == 0 {
            intra_p50 = hist.median_ns() as f64;
        }
        if pct == 100 {
            cross_p50 = hist.median_ns() as f64;
        }
        table.row(&[
            label.into(),
            fmt_ns(mean),
            format!("{:.2}", 1e6 / mean),
            match pct {
                0 => "CXL".into(),
                100 => "RDMA/DSM".into(),
                p => format!("CXL+{p}% RDMA"),
            },
        ]);
    }

    table.print(
        "Fig rack — intra- vs cross-pod no-op RTT (paper: ~1.5µs CXL vs ~17µs RDMA; \
         the mix rows walk the crossover)",
    );
    println!(
        "[fig_rack] crossover: cross p50 {} vs intra p50 {} ({:.1}x)",
        fmt_ns(cross_p50),
        fmt_ns(intra_p50),
        cross_p50 / intra_p50.max(1.0)
    );
    assert!(
        cross_p50 >= 5.0 * intra_p50,
        "cross-pod RTT must sit well above intra-pod (CXL vs RDMA gap)"
    );

    drop(iscope);
    drop(xscope);
    drop(intra);
    drop(cross);
    server.stop();
    rep.emit();
}
