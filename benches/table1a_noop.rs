//! TABLE 1a: no-op RPC round-trip latency and throughput across
//! frameworks — RPCool (CXL), RPCool Seal+Sandbox, RPCool (RDMA),
//! eRPC, ZhangRPC, gRPC.
//!
//! Paper (µs / K req/s): RPCool 1.5/642.75 · Seal+SB 2.6/377.79 ·
//! RDMA 17.25/57.99 · eRPC 2.9/334.03 · Zhang 10.9/99.69 ·
//! gRPC 5500/0.18.
//!
//! Run: `cargo bench --bench table1a_noop` (add `-- --quick` for a
//! shorter run).

use rpcool::baselines::netrpc::{pair, Flavor};
use rpcool::baselines::zhang::ZhangClient;
use rpcool::benchkit::{fmt_ns, time_op, time_op_mean, BenchReport, Table};
use rpcool::channel::{CallArg, CallOpts, Connection, Rpc, TransportSel};
use rpcool::{Rack, SimConfig};
use std::sync::Arc;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n = if quick { 20_000 } else { 200_000 };
    let n_slow = if quick { 20 } else { 200 }; // for gRPC's ms-class RTT
    let rack = Rack::new(SimConfig::for_bench());
    let mut table = Table::new(&["Framework", "No-op RTT", "Throughput (K req/s)", "Transport"]);
    let mut rep = BenchReport::new("table1a_noop");
    // 20µs SLO: generous for the CXL rows (paper: 1.5–2.6µs), set
    // before any row so slo_miss fills everywhere (ISSUE 8 audit).
    rep.slo(20_000);

    // ---- RPCool (CXL) ----
    let env = rack.proc_env(0);
    let server = Rpc::open(&env, "bench/noop").unwrap();
    server.add(1, |_| Ok(0));
    let cenv = rack.proc_env(1);
    let conn = Connection::connect(&cenv, "bench/noop").unwrap();
    conn.attach_inline(&server);
    cenv.enter();
    // One per-op-timed population: mean, tail, and throughput all
    // describe the same n calls (timer overhead is <2% at µs-scale
    // RTTs). The old split — mean from a big untimed run, tail from a
    // 10×-smaller timed one — paired numbers from different runs.
    let (mean, hist) = time_op(1000, n, || {
        conn.invoke(1, (), CallOpts::new()).unwrap();
    });
    rep.row_hist("RPCool", &hist, 1e9 / mean);
    table.row(&[
        "RPCool".into(),
        fmt_ns(mean),
        format!("{:.2}", 1e6 / mean),
        "CXL".into(),
    ]);

    // ---- RPCool (batched ×16) ----
    // Amortized submission (ISSUE 3): 16 no-ops per doorbell signal
    // through `invoke_batch`. Reported per RPC, not per batch.
    const BATCH: usize = 16;
    let batch_args = [CallArg::NONE; BATCH];
    let mean_batch_total = time_op_mean(64, n / BATCH, || {
        let rets = conn.invoke_batch(1, &batch_args, CallOpts::new()).unwrap();
        assert_eq!(rets.len(), BATCH);
    });
    let mean_batch = mean_batch_total / BATCH as f64;
    rep.row("RPCool (batched x16)", 0.0, 0.0, mean_batch, 1e9 / mean_batch);
    table.row(&[
        "RPCool (batched x16)".into(),
        fmt_ns(mean_batch),
        format!("{:.2}", 1e6 / mean_batch),
        "CXL".into(),
    ]);

    // ---- RPCool (Seal+Sandbox) ----
    let scope = conn.create_scope(4096).unwrap();
    let addr = scope.new_val(0u64).unwrap();
    // Same single-population discipline as the RPCool row.
    let (mean_sb, hist_sb) = time_op(1000, n / 2, || {
        conn.invoke(1, (addr, 8), CallOpts::secure(&scope)).unwrap();
    });
    rep.row_hist("RPCool (Seal+Sandbox)", &hist_sb, 1e9 / mean_sb);
    table.row(&[
        "RPCool (Seal+Sandbox)".into(),
        fmt_ns(mean_sb),
        format!("{:.2}", 1e6 / mean_sb),
        "CXL".into(),
    ]);
    drop(scope);
    drop(conn);
    server.stop();

    // ---- RPCool (RDMA fallback) ----
    let env = rack.proc_env(0);
    let server = Rpc::open(&env, "bench/noop-rdma").unwrap();
    server.add(1, |_| Ok(0));
    let renv = rack.remote_proc_env();
    let conn = Connection::connect_with(&renv, "bench/noop-rdma", TransportSel::Rdma).unwrap();
    conn.attach_inline(&server);
    renv.enter();
    // A realistic no-op still ships a small argument scope whose pages
    // ping-pong between the nodes (that IS the fallback's cost).
    let scope = conn.create_scope(4096).unwrap();
    let addr = scope.new_val(0u64).unwrap();
    let mean_rdma = time_op_mean(100, n / 10, || {
        conn.invoke(1, (addr, 8), CallOpts::new()).unwrap();
        // Touch the page client-side so the next call faults it back.
        rpcool::memory::ShmPtr::<u64>::from_addr(addr).write(1).unwrap();
    });
    rep.row("RPCool (RDMA)", 0.0, 0.0, mean_rdma, 1e9 / mean_rdma);
    table.row(&[
        "RPCool (RDMA)".into(),
        fmt_ns(mean_rdma),
        format!("{:.2}", 1e6 / mean_rdma),
        "RDMA".into(),
    ]);
    drop(scope);
    drop(conn);
    server.stop();

    // ---- eRPC ----
    let (srv, cli) = pair(Flavor::ERpc, Arc::clone(&rack.pool.charger));
    srv.add(1, |_| Ok(vec![]));
    cli.attach_inline(&srv);
    let mean_erpc = time_op_mean(1000, n / 2, || {
        cli.call(1, &[]).unwrap();
    });
    rep.row("eRPC", 0.0, 0.0, mean_erpc, 1e9 / mean_erpc);
    table.row(&[
        "eRPC".into(),
        fmt_ns(mean_erpc),
        format!("{:.2}", 1e6 / mean_erpc),
        "RDMA".into(),
    ]);
    srv.stop();

    // ---- ZhangRPC ----
    let env = rack.proc_env(0);
    let server = Rpc::open(&env, "bench/zhang").unwrap();
    server.add(1, |_| Ok(0));
    let cenv = rack.proc_env(2);
    let zc = ZhangClient::connect(&cenv, "bench/zhang").unwrap();
    zc.conn.attach_inline(&server);
    cenv.enter();
    let obj = zc.alloc.create(0u64).unwrap();
    let mean_z = time_op_mean(1000, n / 10, || {
        zc.call(1, obj).unwrap();
    });
    rep.row("ZhangRPC", 0.0, 0.0, mean_z, 1e9 / mean_z);
    table.row(&[
        "ZhangRPC".into(),
        fmt_ns(mean_z),
        format!("{:.2}", 1e6 / mean_z),
        "CXL".into(),
    ]);
    drop(zc);
    server.stop();

    // ---- gRPC ----
    let (srv, cli) = pair(Flavor::Grpc, Arc::clone(&rack.pool.charger));
    srv.add(1, |_| Ok(vec![]));
    cli.attach_inline(&srv);
    let mean_g = time_op_mean(2, n_slow, || {
        cli.call(1, &[]).unwrap();
    });
    rep.row("gRPC", 0.0, 0.0, mean_g, 1e9 / mean_g);
    table.row(&[
        "gRPC".into(),
        fmt_ns(mean_g),
        format!("{:.2}", 1e6 / mean_g),
        "TCP".into(),
    ]);
    srv.stop();

    table.print("Table 1a — no-op latency & throughput (paper: 1.5µs/642.75 · 2.6µs/377.79 · 17.25µs/57.99 · 2.9µs/334.03 · 10.9µs/99.69 · 5.5ms/0.18)");
    rep.emit();
}
