//! HEAP CHURN: multi-threaded clients hammering one heap's memory
//! plane — the workload the memory-plane overhaul targets (ISSUE 5:
//! thread-cached magazines, the O(1) page-granular seal index, and the
//! lock-free scope pool; DESIGN.md §10). Not a paper figure; this is
//! the repo's perf trajectory for the allocation/permission layer the
//! CoolDB build phase and sealed multi-threaded workloads sit on.
//!
//! Layers (latency charging off throughout — like `ring/raw/*`, the
//! *structural* cost is what is measured):
//!
//! * `alloc/fixed/t{1,4,8}` — `magazine_cap = 0`: every alloc/free
//!   takes the heap's central mutex (the pre-overhaul path). Each row
//!   carries `locks_per_alloc` (central-lock acquisitions ÷ alloc/free
//!   ops — 1.0 by construction here).
//! * `alloc/mag/t{1,4,8}` — the default magazine cap: the same churn
//!   through per-thread size-class magazines. `locks_per_alloc` must
//!   come in at or below 1/8 (CI's memory-plane invariant; the
//!   steady-state expectation at cap 64 is ~2/64).
//! * `check_write/{indexed,scan}/seals{0,1024}` — one write-permission
//!   probe against a heap holding 0 vs 1024 live seals, through the
//!   page-word index (`check_write`) and through the reference O(n)
//!   scan (`check_write_scan`). Each row carries `check_write_ns`;
//!   the indexed rows must not grow with the seal count (CI gate),
//!   while the scan rows document exactly why the index exists.
//! * `scope/pool/t{1,4}` — pop → seal → complete → push_sealed churn
//!   through the lock-free `ScopePool` (batched release at the
//!   default 1024 threshold), threads racing the Treiber free list
//!   and the pending swap-drain.
//!
//! Run: `cargo bench --bench heap_churn [-- --quick]`

use rpcool::benchkit::{fanout, time_op_mean, BenchReport, Table};
use rpcool::memory::heap::Heap;
use rpcool::memory::pool::Pool;
use rpcool::seal::{ScopePool, Sealer};
use rpcool::util::rng::Rng;
use rpcool::SimConfig;
use std::sync::Arc;

/// Threaded alloc/free churn; returns (ops/s, locks-per-op).
fn alloc_churn(threads: u64, ops_per_thread: u64, magazine_cap: usize) -> (f64, f64) {
    let cfg = SimConfig::for_tests();
    let pool = Pool::new(&cfg).unwrap();
    let heap = Heap::new_opts(&pool, "churn", 64 << 20, magazine_cap).unwrap();
    let wall = fanout(threads as usize, |tid| {
        let mut rng = Rng::new(0xC0FFEE ^ (tid as u64) << 13);
        let mut held: Vec<usize> = Vec::with_capacity(8);
        for _ in 0..ops_per_thread {
            // Mixed small classes (the CoolDB build shape); hold a few
            // so free order differs from alloc order.
            let size = rng.range(16, 2049) as usize;
            if let Ok(a) = heap.alloc_bytes(size) {
                held.push(a);
            }
            if held.len() >= 8 {
                // Free oldest-first: worst case for a bump-style
                // cache, honest for a free list.
                heap.free_bytes(held.remove(0));
            }
        }
        for a in held.drain(..) {
            heap.free_bytes(a);
        }
    });
    let total_ops = heap.alloc_ops() as f64;
    let locks_per_op = heap.central_locks() as f64 / total_ops.max(1.0);
    (total_ops / wall.as_secs_f64(), locks_per_op)
}

/// Mean ns of one `check_write` probe with `nseals` live seals, via
/// the page-word index or the O(n) reference scan.
fn check_write_ns(nseals: usize, scan: bool, iters: usize) -> f64 {
    let cfg = SimConfig::for_tests();
    let pool = Pool::new(&cfg).unwrap();
    let heap = Heap::new(&pool, "seals", 64 << 20).unwrap();
    // One page per seal, sealed for proc 1; probes run as proc 2
    // against a mix of sealed-by-other and unsealed pages (the common
    // server-side shape: somebody else's seals are live, yours are
    // not the one being checked).
    let npages = (nseals + 16).next_power_of_two();
    let region = heap.alloc_pages(npages).unwrap();
    for i in 0..nseals {
        heap.seal_range(region.base + i * 4096, 64, 1);
    }
    let mut rng = Rng::new(0x5EA1);
    let addrs: Vec<usize> = (0..256)
        .map(|_| region.base + rng.next_below(npages as u64) as usize * 4096 + 8)
        .collect();
    let mut k = 0usize;
    let mean = time_op_mean(iters / 10, iters, || {
        let addr = addrs[k & 255];
        k += 1;
        let r = if scan {
            heap.check_write_scan(addr, 8, 2)
        } else {
            heap.check_write(addr, 8, 2)
        };
        std::hint::black_box(r.is_ok());
    });
    for i in 0..nseals {
        heap.unseal_range(region.base + i * 4096, 64, 1);
    }
    mean
}

/// Scope churn through the lock-free pool; returns ops/s.
fn scope_churn(threads: u64, ops_per_thread: u64) -> f64 {
    let cfg = SimConfig::for_tests();
    let pool = Pool::new(&cfg).unwrap();
    let heap = Heap::new(&pool, "scopes", 128 << 20).unwrap();
    let sealer = Sealer::new(&cfg, Arc::clone(&heap), Arc::clone(&pool.charger)).unwrap();
    let sp = ScopePool::new(
        Arc::clone(&heap),
        Arc::clone(&sealer),
        4096,
        cfg.batch_release_threshold,
    );
    let wall = fanout(threads as usize, |_tid| {
        for _ in 0..ops_per_thread {
            let scope = sp.pop().unwrap();
            let h = sealer.seal(scope.base(), scope.len(), 1).unwrap();
            sealer.complete(h.idx);
            sp.push_sealed(scope, h).unwrap();
        }
    });
    sp.flush().unwrap();
    (threads * ops_per_thread) as f64 / wall.as_secs_f64()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let alloc_ops: u64 = if quick { 40_000 } else { 400_000 };
    let probe_iters: usize = if quick { 200_000 } else { 2_000_000 };
    let scope_ops: u64 = if quick { 10_000 } else { 100_000 };

    let mut report = BenchReport::new("heap_churn");
    let mut table = Table::new(&["config", "ops/s", "locks/alloc", "check_write ns"]);

    for (label, cap) in [("fixed", 0usize), ("mag", rpcool::memory::heap::DEFAULT_MAGAZINE_CAP)] {
        for threads in [1u64, 4, 8] {
            let (ops, lpa) = alloc_churn(threads, alloc_ops, cap);
            let row = format!("alloc/{label}/t{threads}");
            table.row(&[row.clone(), format!("{ops:.0}"), format!("{lpa:.4}"), "-".into()]);
            report.row(&row, 0.0, 0.0, 1e9 / ops.max(1.0), ops);
            report.extra("locks_per_alloc", lpa);
        }
    }

    for (label, scan) in [("indexed", false), ("scan", true)] {
        for nseals in [0usize, 1024] {
            // The scan at 1024 seals is O(n) per probe — trim iters so
            // the bench stays quick while the row stays honest.
            let iters = if scan && nseals > 0 { probe_iters / 50 } else { probe_iters };
            let ns = check_write_ns(nseals, scan, iters.max(1000));
            let row = format!("check_write/{label}/seals{nseals}");
            table.row(&[row.clone(), "-".into(), "-".into(), format!("{ns:.1}")]);
            report.row(&row, 0.0, 0.0, ns, 0.0);
            report.extra("check_write_ns", ns);
            report.extra("live_seals", nseals as f64);
        }
    }

    for threads in [1u64, 4] {
        let ops = scope_churn(threads, scope_ops);
        let row = format!("scope/pool/t{threads}");
        table.row(&[row.clone(), format!("{ops:.0}"), "-".into(), "-".into()]);
        report.row(&row, 0.0, 0.0, 1e9 / ops.max(1.0), ops);
    }

    table.print("heap_churn — memory-plane structural costs (charging off)");
    report.emit();
}
