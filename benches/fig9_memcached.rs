//! FIGURE 9: Memcached running YCSB (A–D, F; no E — memcached has no
//! SCAN) over four transports: RPCool (CXL), UDS, RPCool (DSM/RDMA),
//! TCP-over-IPoIB.
//!
//! Paper shape: RPCool ≥ 6.0× vs UDS; RPCool-DSM ≥ 2.1× vs TCP.
//! Paper scale: 100K keys / 1M ops; default here is scaled down 10×
//! (pass `--full` for paper scale).
//!
//! Run: `cargo bench --bench fig9_memcached [-- --quick|--full]`

use rpcool::apps::memcached::{run_ycsb, serve_net, serve_rpcool, Cache, RpcoolKv};
use rpcool::baselines::netrpc::Flavor;
use rpcool::benchkit::{BenchReport, Table};
use rpcool::channel::TransportSel;
use rpcool::workloads::ycsb::WorkloadKind;
use rpcool::{Rack, SimConfig};
use std::sync::Arc;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let full = std::env::args().any(|a| a == "--full");
    let (nkeys, nops): (u64, usize) = if full {
        (100_000, 1_000_000)
    } else if quick {
        (2_000, 10_000)
    } else {
        (10_000, 100_000)
    };
    let rack = Rack::new(SimConfig::for_bench());
    let mut t = Table::new(&["Workload", "RPCool", "UDS", "spd", "RPCool(DSM)", "TCP(IPoIB)", "spd"]);
    let mut rep = BenchReport::new("fig9_memcached");

    let workloads =
        [WorkloadKind::A, WorkloadKind::B, WorkloadKind::C, WorkloadKind::D, WorkloadKind::F];

    for kind in workloads {
        // RPCool (CXL).
        let env = rack.proc_env(0);
        let cache = Cache::new(16);
        let server = serve_rpcool(&env, &format!("f9/cxl/{}", kind.name()), cache).unwrap();
        let cenv = rack.proc_env(1);
        let kv = RpcoolKv::connect(&cenv, &format!("f9/cxl/{}", kind.name())).unwrap();
        kv.conn().attach_inline(&server);
        cenv.enter();
        let (_l, cxl) = run_ycsb(&kv, kind, nkeys, nops, 7).unwrap();
        drop(kv);
        server.stop();

        // UDS.
        let cache = Cache::new(16);
        let (srv, kv) = serve_net(Flavor::Uds, Arc::clone(&rack.pool.charger), cache);
        kv.client_inline(&srv);
        let (_l, uds) = run_ycsb(&kv, kind, nkeys, nops, 7).unwrap();
        srv.stop();

        // RPCool over DSM (RDMA fallback).
        let env = rack.proc_env(0);
        let cache = Cache::new(16);
        let server = serve_rpcool(&env, &format!("f9/dsm/{}", kind.name()), cache).unwrap();
        let renv = rack.remote_proc_env();
        let kv = {
            // connect_with RDMA through the same helper type.
            let conn = rpcool::channel::Connection::connect_with(
                &renv,
                &format!("f9/dsm/{}", kind.name()),
                TransportSel::Rdma,
            )
            .unwrap();
            conn.attach_inline(&server);
            rpcool::apps::memcached::RpcoolKv::from_conn(conn).unwrap()
        };
        renv.enter();
        let (_l, dsm) = run_ycsb(&kv, kind, nkeys, nops, 7).unwrap();
        drop(kv);
        server.stop();

        // TCP over IPoIB.
        let cache = Cache::new(16);
        let (srv, kv) = serve_net(Flavor::Tcp, Arc::clone(&rack.pool.charger), cache);
        kv.client_inline(&srv);
        let (_l, tcp) = run_ycsb(&kv, kind, nkeys, nops, 7).unwrap();
        srv.stop();

        t.row(&[
            format!("YCSB-{}", kind.name()),
            format!("{cxl:.2?}"),
            format!("{uds:.2?}"),
            format!("{:.2}×", uds.as_secs_f64() / cxl.as_secs_f64()),
            format!("{dsm:.2?}"),
            format!("{tcp:.2?}"),
            format!("{:.2}×", tcp.as_secs_f64() / dsm.as_secs_f64()),
        ]);
        for (transport, wall) in
            [("rpcool_cxl", cxl), ("uds", uds), ("rpcool_dsm", dsm), ("tcp", tcp)]
        {
            rep.row(
                &format!("ycsb_{}/{}", kind.name(), transport),
                0.0,
                0.0,
                wall.as_nanos() as f64 / nops as f64,
                nops as f64 / wall.as_secs_f64(),
            );
        }
    }

    t.print(&format!(
        "Figure 9 — Memcached YCSB ({nkeys} keys, {nops} ops; paper: RPCool ≥6.0× vs UDS, DSM ≥2.1× vs TCP)"
    ));
    rep.emit();
}
