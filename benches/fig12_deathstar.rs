//! FIGURE 12: DeathStarBench SocialNetwork compose-post — median and
//! P99 latency vs offered load, RPCool vs RPCool (Secure) vs Thrift.
//!
//! Paper shape: all three track closely (the critical path is ~66%
//! databases + Nginx); RPCool's peak throughput exceeds Thrift's.
//! Open-loop driver: requests arrive at the offered rate; latency is
//! measured per request; each point runs for a fixed wall budget
//! (paper: 30 s/point — pass `--full` for that).
//!
//! Run: `cargo bench --bench fig12_deathstar [-- --quick|--full]`

use rpcool::apps::socialnet::{sample_post, RpcoolSocial, SocialState, ThriftSocial};
use rpcool::benchkit::{BenchReport, Table};
use rpcool::channel::waiter::SleepPolicy;
use rpcool::metrics::Histogram;
use rpcool::util::Rng;
use rpcool::{Rack, SimConfig};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Open-loop run at `rate` req/s for `budget`; returns (p50, p99,
/// achieved req/s).
fn run_point(
    mut call: impl FnMut(u64, &str) -> rpcool::Result<u64>,
    nusers: usize,
    rate: f64,
    budget: Duration,
    seed: u64,
) -> (u64, u64, f64) {
    let hist = Histogram::new();
    let mut rng = Rng::new(seed);
    let interval = Duration::from_secs_f64(1.0 / rate);
    let t0 = Instant::now();
    let mut scheduled = t0;
    let mut done = 0u64;
    while t0.elapsed() < budget {
        // Open loop: next arrival is scheduled regardless of service.
        scheduled += interval;
        let now = Instant::now();
        if scheduled > now {
            std::thread::sleep(scheduled - now);
        }
        let (user, text) = sample_post(&mut rng, nusers);
        let t = Instant::now();
        call(user, &text).unwrap();
        // Latency includes queueing delay behind schedule.
        hist.record_ns(t.elapsed().as_nanos() as u64 + (t - scheduled).as_nanos() as u64);
        done += 1;
    }
    (hist.median_ns(), hist.p99_ns(), done as f64 / t0.elapsed().as_secs_f64())
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let full = std::env::args().any(|a| a == "--full");
    let budget = if full {
        Duration::from_secs(30)
    } else if quick {
        Duration::from_millis(600)
    } else {
        Duration::from_secs(3)
    };
    let rates: &[f64] = if quick { &[200.0, 800.0] } else { &[200.0, 500.0, 1000.0, 1500.0, 2000.0] };
    let nusers = 1_000;
    let rack = Rack::new(SimConfig::for_bench());
    let mut t = Table::new(&["Backend", "offered req/s", "achieved", "p50", "p99"]);
    let mut rep = BenchReport::new("fig12_deathstar");

    // RPCool and RPCool (Secure).
    for secure in [false, true] {
        let tag = if secure { "sec" } else { "fast" };
        let state = SocialState::new(nusers, 16, 1);
        let net =
            RpcoolSocial::start(&rack, state, SleepPolicy::Fixed(1), secure, &format!("f12{tag}"))
                .unwrap();
        net.inline_mode();
        for &rate in rates {
            let (p50, p99, ach) =
                run_point(|u, s| net.compose_post(u, s), nusers, rate, budget, 3);
            t.row(&[
                if secure { "RPCool (Secure)".into() } else { "RPCool".into() },
                format!("{rate:.0}"),
                format!("{ach:.0}"),
                Histogram::fmt_ns(p50),
                Histogram::fmt_ns(p99),
            ]);
            rep.row(
                &format!("{}/offered{rate:.0}", if secure { "rpcool_secure" } else { "rpcool" }),
                p50 as f64,
                p99 as f64,
                0.0,
                ach,
            );
        }
        net.stop();
    }

    // Batched compose (ISSUE 4): the whole slice of posts rides
    // invoke_batch per service hop — one publish doorbell per chunk,
    // reply doorbells coalesced by the drain-k serving loop. Closed
    // loop (batching trades per-request latency for throughput), so
    // the row records peak compose throughput.
    {
        const BATCH: usize = 16;
        let state = SocialState::new(nusers, 16, 7);
        let net = RpcoolSocial::start(
            &rack,
            Arc::clone(&state),
            SleepPolicy::Fixed(1),
            false,
            "f12batch",
        )
        .unwrap();
        net.inline_mode();
        let mut rng = Rng::new(8);
        let t0 = Instant::now();
        let mut done = 0u64;
        while t0.elapsed() < budget {
            let posts: Vec<(u64, String)> =
                (0..BATCH).map(|_| sample_post(&mut rng, nusers)).collect();
            let ids = net.compose_post_batch(&posts).unwrap();
            done += ids.len() as u64;
        }
        let thr = done as f64 / t0.elapsed().as_secs_f64();
        t.row(&[
            format!("RPCool (batched x{BATCH})"),
            "closed loop".into(),
            format!("{thr:.0}"),
            "-".into(),
            "-".into(),
        ]);
        rep.row(&format!("rpcool_batched_b{BATCH}"), 0.0, 0.0, 1e9 / thr, thr);
        net.stop();
    }

    // Thrift.
    let state = SocialState::new(nusers, 16, 1);
    let net = ThriftSocial::start(Arc::clone(&rack.pool.charger), state);
    net.inline_mode();
    for &rate in rates {
        let (p50, p99, ach) = run_point(|u, s| net.compose_post(u, s), nusers, rate, budget, 3);
        t.row(&[
            "ThriftRPC".into(),
            format!("{rate:.0}"),
            format!("{ach:.0}"),
            Histogram::fmt_ns(p50),
            Histogram::fmt_ns(p99),
        ]);
        rep.row(&format!("thrift/offered{rate:.0}"), p50 as f64, p99 as f64, 0.0, ach);
    }
    net.stop();

    t.print("Figure 12 — SocialNetwork compose-post latency vs offered load (paper: RPCool ≈ Thrift, higher peak)");
    rep.emit();
}
