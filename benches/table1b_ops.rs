//! TABLE 1b: latency of every RPCool operation — channel lifecycle,
//! cached/uncached sandboxes, seal/release (standard + batched, 1 and
//! 1024 pages), and remote-remote memcpy for the crossover analysis.
//!
//! Paper: no-op CXL 1.5µs · no-op RDMA 17.25µs · sealed+SB 2.6µs ·
//! create 26.5ms · destroy 38.4ms · connect 0.4s · cached SB 0.35µs
//! (1 and 1024 pages) · 8 cached SB 0.47µs · uncached SB 25.57µs ·
//! seal+release 1.1µs/3.46µs · batched 0.65µs/2.95µs ·
//! memcpy 1.26µs/2308µs.
//!
//! Run: `cargo bench --bench table1b_ops` (add `-- --quick`).

use rpcool::benchkit::{fmt_ns, time_op, time_op_mean, BenchReport, Table};
use rpcool::channel::{CallOpts, ChannelBuilder, Connection, Rpc, RpcServer, TransportSel};
use rpcool::memory::Scope;
use rpcool::sandbox::SandboxMgr;
use rpcool::seal::{ScopePool, Sealer};
use rpcool::simproc;
use rpcool::{Rack, SimConfig};
use std::sync::Arc;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // Paper repeats ops 2M times; scale down proportionally.
    let n = if quick { 20_000 } else { 500_000 };
    let rack = Rack::new(SimConfig::for_bench());
    let mut t = Table::new(&["Operation", "Mean Latency", "Paper"]);
    let mut rep = BenchReport::new("table1b_ops");

    // ---------------- RPC ops ----------------
    {
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, "t1b/cxl").unwrap();
        server.add(1, |_| Ok(0));
        let cenv = rack.proc_env(1);
        let conn = Connection::connect(&cenv, "t1b/cxl").unwrap();
        conn.attach_inline(&server);
        cenv.enter();
        let m = time_op_mean(1000, n, || {
            conn.invoke(1, (), CallOpts::new()).unwrap();
        });
        t.row(&["No-op RPCool RPC (CXL)".into(), fmt_ns(m), "1.5 µs".into()]);
        rep.row("No-op RPCool RPC (CXL)", 0.0, 0.0, m, 0.0);

        let scope = conn.create_scope(4096).unwrap();
        let a = scope.new_val(0u64).unwrap();
        let m = time_op_mean(1000, n / 4, || {
            conn.invoke(1, (a, 8), CallOpts::secure(&scope)).unwrap();
        });
        t.row(&["No-op Sealed+Sandboxed RPC (CXL, 1 page)".into(), fmt_ns(m), "2.6 µs".into()]);
        rep.row("No-op Sealed+Sandboxed RPC (CXL, 1 page)", 0.0, 0.0, m, 0.0);
        drop(scope);
        drop(conn);
        server.stop();
    }
    {
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, "t1b/rdma").unwrap();
        server.add(1, |_| Ok(0));
        let renv = rack.remote_proc_env();
        let conn = Connection::connect_with(&renv, "t1b/rdma", TransportSel::Rdma).unwrap();
        conn.attach_inline(&server);
        renv.enter();
        let scope = conn.create_scope(4096).unwrap();
        let a = scope.new_val(0u64).unwrap();
        let m = time_op_mean(100, n / 20, || {
            conn.invoke(1, (a, 8), CallOpts::new()).unwrap();
            rpcool::memory::ShmPtr::<u64>::from_addr(a).write(1).unwrap();
        });
        t.row(&["No-op RPCool RPC (RDMA)".into(), fmt_ns(m), "17.25 µs".into()]);
        rep.row("No-op RPCool RPC (RDMA)", 0.0, 0.0, m, 0.0);
        drop(scope);
        drop(conn);
        server.stop();
    }

    // ------------- channel lifecycle -------------
    {
        let reps = if quick { 3 } else { 10 };
        let env = rack.proc_env(0);
        let mut i = 0;
        let (m, _) = time_op(0, reps, || {
            let s = ChannelBuilder::from_config(&rack.cfg)
                .open(&env, &format!("t1b/ch{i}"))
                .unwrap();
            std::hint::black_box(&s);
            std::mem::forget(s); // destroy timed separately
            i += 1;
        });
        t.row(&["Create Channel".into(), fmt_ns(m), "26.5 ms".into()]);
        rep.row("Create Channel", 0.0, 0.0, m, 0.0);

        let servers: Vec<RpcServer> = (0..reps)
            .map(|j| {
                ChannelBuilder::from_config(&rack.cfg)
                    .open(&env, &format!("t1b/chd{j}"))
                    .unwrap()
            })
            .collect();
        let mut it = servers.into_iter();
        let (m, _) = time_op(0, reps, || {
            drop(it.next().unwrap());
        });
        t.row(&["Destroy Channel".into(), fmt_ns(m), "38.4 ms".into()]);
        rep.row("Destroy Channel", 0.0, 0.0, m, 0.0);

        let server = ChannelBuilder::from_config(&rack.cfg).open(&env, "t1b/conn").unwrap();
        server.add(1, |_| Ok(0));
        let reps = if quick { 2 } else { 5 };
        let mut conns = Vec::new();
        let (m, _) = time_op(0, reps, || {
            let cenv = rack.proc_env(2);
            conns.push(Connection::connect(&cenv, "t1b/conn").unwrap());
        });
        t.row(&["Connect Channel".into(), fmt_ns(m), "0.4 s".into()]);
        rep.row("Connect Channel", 0.0, 0.0, m, 0.0);
        drop(conns);
        server.stop();
    }

    // ------------- sandbox ops -------------
    {
        let heap = rack.orch.create_heap("t1b/sb", 64 << 20, 999).unwrap().0;
        let mgr = SandboxMgr::new(&rack.cfg, Arc::clone(&heap), Arc::clone(&rack.pool.charger));
        simproc::bind(999, 0);

        let scope1 = Scope::create(&heap, 4096).unwrap();
        let m = time_op_mean(100, n, || {
            let g = mgr.begin(scope1.base(), scope1.len()).unwrap();
            drop(g);
        });
        t.row(&["Cached Sandbox Enter+Exit (1 page)".into(), fmt_ns(m), "0.35 µs".into()]);
        rep.row("Cached Sandbox Enter+Exit (1 page)", 0.0, 0.0, m, 0.0);

        let scope1k = Scope::create(&heap, 1024 * 4096).unwrap();
        let m = time_op_mean(100, n, || {
            let g = mgr.begin(scope1k.base(), scope1k.len()).unwrap();
            drop(g);
        });
        t.row(&["Cached Sandbox Enter+Exit (1024 pages)".into(), fmt_ns(m), "0.35 µs".into()]);
        rep.row("Cached Sandbox Enter+Exit (1024 pages)", 0.0, 0.0, m, 0.0);

        // 8 distinct cached sandboxes, cycled — no key reassignment.
        let scopes8: Vec<Scope> =
            (0..8).map(|_| Scope::create(&heap, 4096).unwrap()).collect();
        let mut k = 0usize;
        let m = time_op_mean(100, n, || {
            let s = &scopes8[k & 7];
            k += 1;
            let g = mgr.begin(s.base(), s.len()).unwrap();
            drop(g);
        });
        t.row(&["Cached Multiple Sandbox Enter+Exit (1 page)".into(), fmt_ns(m), "0.47 µs".into()]);
        rep.row("Cached Multiple Sandbox Enter+Exit (1 page)", 0.0, 0.0, m, 0.0);

        // 32 distinct regions with only 14 keys: every entry reassigns.
        let scopes32: Vec<Scope> =
            (0..32).map(|_| Scope::create(&heap, 4096).unwrap()).collect();
        let mut k = 0usize;
        let m = time_op_mean(32, n / 100, || {
            let s = &scopes32[k & 31];
            k += 1;
            let g = mgr.begin(s.base(), s.len()).unwrap();
            drop(g);
        });
        t.row(&["Uncached Sandbox Enter+Exit (1 page)".into(), fmt_ns(m), "25.57 µs".into()]);
        rep.row("Uncached Sandbox Enter+Exit (1 page)", 0.0, 0.0, m, 0.0);
    }

    // ------------- seal / release / memcpy -------------
    {
        let heap = rack.orch.create_heap("t1b/seal", 64 << 20, 998).unwrap().0;
        let sealer = Sealer::new(&rack.cfg, Arc::clone(&heap), Arc::clone(&rack.pool.charger)).unwrap();
        simproc::bind(998, 0);

        for (pages, label, paper) in
            [(1usize, "Seal + standard release, no RPC (1 page)", "1.1 µs"),
             (1024, "Seal + standard release, no RPC (1024 pages)", "3.46 µs")]
        {
            let scope = Scope::create(&heap, pages * 4096).unwrap();
            let m = time_op_mean(100, n / 4, || {
                let h = sealer.seal(scope.base(), scope.len(), 998).unwrap();
                sealer.complete(h.idx);
                sealer.release(h).unwrap();
            });
            t.row(&[label.into(), fmt_ns(m), paper.into()]);
            rep.row(label, 0.0, 0.0, m, 0.0);
        }

        for (pages, label, paper) in
            [(1usize, "Seal + batch release, no RPC (1 page)", "0.65 µs"),
             (1024, "Seal + batch release, no RPC (1024 pages)", "2.95 µs")]
        {
            // Batch threshold bounded so pending scopes fit the heap
            // (1024-page scopes are 4 MiB each).
            let threshold =
                rack.cfg.batch_release_threshold.min((48 << 20) / (pages * 4096)).max(2);
            let pool = ScopePool::new(
                Arc::clone(&heap),
                Arc::clone(&sealer),
                pages * 4096,
                threshold,
            );
            let m = time_op_mean(100, n / 4, || {
                let scope = pool.pop().unwrap();
                let h = sealer.seal(scope.base(), scope.len(), 998).unwrap();
                sealer.complete(h.idx);
                pool.push_sealed(scope, h).unwrap();
            });
            pool.flush().unwrap();
            t.row(&[label.into(), fmt_ns(m), paper.into()]);
            rep.row(label, 0.0, 0.0, m, 0.0);
        }

        // Remote-remote memcpy (both ends in CXL memory).
        for (pages, label, paper) in
            [(1usize, "Remote-remote memcpy (1 page)", "1.26 µs"),
             (1024, "Remote-remote memcpy (1024 pages)", "2308.23 µs")]
        {
            let bytes = pages * 4096;
            let src = heap.alloc_bytes(bytes).unwrap();
            let dst = heap.alloc_bytes(bytes).unwrap();
            let reps = if pages == 1 { n / 2 } else { n / 500 };
            let m = time_op_mean(10, reps, || {
                rack.pool.charger.charge_cxl_copy(bytes);
                unsafe {
                    std::ptr::copy_nonoverlapping(src as *const u8, dst as *mut u8, bytes);
                }
            });
            t.row(&[label.into(), fmt_ns(m), paper.into()]);
            rep.row(label, 0.0, 0.0, m, 0.0);
        }
    }

    t.print("Table 1b — RPCool operation latencies");
    rep.emit();
    println!("\ncrossover check (paper §6.2): seal+sandbox beats memcpy beyond ~2 pages.");
}
