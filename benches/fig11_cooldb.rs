//! FIGURE 11: CoolDB build (NoBench corpus) + search (range queries)
//! across RPCool (CXL), RPCool (RDMA), RPCool (Secure), ZhangRPC, eRPC.
//!
//! Paper shape: RPCool fastest on CXL (4.7× build / 1.3× search vs
//! the fastest other framework); RPCool-RDMA slows markedly on build
//! (page ping-pong); Zhang pays per-object header/ref costs.
//! Paper scale: 100K docs / 1K searches (pass `--full`).
//!
//! Run: `cargo bench --bench fig11_cooldb [-- --quick|--full]`

use rpcool::apps::cooldb::{
    run_fig11, serve_net, serve_rpcool, CoolIndex, RpcoolCool, ZhangCool,
};
use rpcool::baselines::netrpc::Flavor;
use rpcool::benchkit::{BenchReport, Table};
use rpcool::channel::TransportSel;
use rpcool::{Rack, SimConfig};
use std::sync::Arc;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let full = std::env::args().any(|a| a == "--full");
    let (ndocs, nsearches) = if full {
        (100_000, 1_000)
    } else if quick {
        (3_000, 20)
    } else {
        (20_000, 100)
    };
    let mut cfg = SimConfig::for_bench();
    cfg.pool_bytes = 1 << 31; // room for the corpus (shared heap)
    let rack = Rack::new(cfg);
    let mut t = Table::new(&["Framework", "build", "search"]);
    let mut rep = BenchReport::new("fig11_cooldb");
    let rep_row = |rep: &mut BenchReport, label: &str, b: std::time::Duration, s: std::time::Duration| {
        rep.row(&format!("{label}/build"), 0.0, 0.0, b.as_nanos() as f64, 0.0);
        rep.row(&format!("{label}/search"), 0.0, 0.0, s.as_nanos() as f64, 0.0);
    };

    // ---- RPCool (CXL) ----
    let env = rack.proc_env(0);
    let index = CoolIndex::new();
    let server = serve_rpcool(&env, "f11/cxl", Arc::clone(&index)).unwrap();
    let cenv = rack.proc_env(1);
    let db = RpcoolCool::connect(&cenv, "f11/cxl").unwrap();
    db.conn().attach_inline(&server);
    cenv.enter();
    let (b, s) = run_fig11(&db, ndocs, nsearches, 42).unwrap();
    t.row(&["RPCool".into(), format!("{b:.2?}"), format!("{s:.2?}")]);
    rep_row(&mut rep, "rpcool_cxl", b, s);
    let (rp_b, rp_s) = (b, s);
    drop(db);
    server.stop();

    // ---- RPCool (Secure): sealed+sandboxed puts ----
    let env = rack.proc_env(0);
    let index = CoolIndex::new();
    let server = serve_rpcool(&env, "f11/sec", Arc::clone(&index)).unwrap();
    let cenv = rack.proc_env(2);
    let db = RpcoolCool::connect_secure(&cenv, "f11/sec").unwrap();
    db.conn().attach_inline(&server);
    cenv.enter();
    let (b, s) = run_fig11(&db, ndocs, nsearches, 42).unwrap();
    t.row(&["RPCool (Secure)".into(), format!("{b:.2?}"), format!("{s:.2?}")]);
    rep_row(&mut rep, "rpcool_secure", b, s);
    drop(db);
    server.stop();

    // ---- RPCool (RDMA fallback) ----
    let env = rack.proc_env(0);
    let index = CoolIndex::new();
    let server = serve_rpcool(&env, "f11/rdma", Arc::clone(&index)).unwrap();
    let renv = rack.remote_proc_env();
    let db = RpcoolCool::connect_with(&renv, "f11/rdma", TransportSel::Rdma).unwrap();
    db.conn().attach_inline(&server);
    renv.enter();
    // RDMA build at paper scale moves every doc page twice; scale down
    // the doc count to keep the bench bounded, then normalize.
    let nd = ndocs / 4;
    let (b, s) = run_fig11(&db, nd, nsearches, 42).unwrap();
    t.row(&[
        "RPCool (RDMA)".into(),
        format!("{:.2?} (×4 scaled)", b * 4),
        format!("{s:.2?}"),
    ]);
    rep_row(&mut rep, "rpcool_rdma_x4", b * 4, s);
    drop(db);
    server.stop();

    // ---- ZhangRPC ----
    let env = rack.proc_env(0);
    let index = CoolIndex::new();
    let server = serve_rpcool(&env, "f11/zhang", Arc::clone(&index)).unwrap();
    let cenv = rack.proc_env(3);
    let db = ZhangCool::connect(&cenv, "f11/zhang").unwrap();
    db.conn_inline(&server);
    cenv.enter();
    let (b, s) = run_fig11(&db, ndocs, nsearches, 42).unwrap();
    t.row(&["ZhangRPC".into(), format!("{b:.2?}"), format!("{s:.2?}")]);
    rep_row(&mut rep, "zhang", b, s);
    drop(db);
    server.stop();

    // ---- eRPC ----
    let (srv, db, _store) = serve_net(Flavor::ERpc, Arc::clone(&rack.pool.charger));
    db.client_inline(&srv);
    let (b, s) = run_fig11(&db, ndocs, nsearches, 42).unwrap();
    t.row(&["eRPC".into(), format!("{b:.2?}"), format!("{s:.2?}")]);
    rep_row(&mut rep, "erpc", b, s);
    srv.stop();
    let (er_b, er_s) = (b, s);

    t.print(&format!(
        "Figure 11 — CoolDB build ({ndocs} NoBench docs) + search ({nsearches} queries)"
    ));
    println!(
        "\nRPCool vs eRPC: build {:.2}× (paper 4.7× vs fastest), search {:.2}× (paper 1.3×)",
        er_b.as_secs_f64() / rp_b.as_secs_f64(),
        er_s.as_secs_f64() / rp_s.as_secs_f64(),
    );
    rep.emit();
}
