//! FIGURE 1: round-trip time of the raw communication substrates —
//! CXL loads/signals vs RDMA vs TCP vs HTTP. The ladder motivates the
//! whole paper: CXL ≪ RDMA ≪ TCP < HTTP.
//!
//! Run: `cargo bench --bench fig1_rtt`

use rpcool::benchkit::{fmt_ns, time_op_mean, BenchReport, Table};
use rpcool::transport::{LinkKind, SimNicPair, Transport};
use rpcool::{Rack, SimConfig};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n = if quick { 10_000 } else { 100_000 };
    let rack = Rack::new(SimConfig::for_bench());
    let charger = Arc::clone(&rack.pool.charger);
    let mut t = Table::new(&["Protocol", "RTT", "Note"]);
    let mut rep = BenchReport::new("fig1_rtt");

    // CXL: a dependent far-memory load pair (request/response via
    // shared memory — two one-way signal latencies).
    let m = time_op_mean(1000, n, || {
        charger.charge_cxl_signal();
        charger.charge_cxl_signal();
    });
    t.row(&["CXL ld/st".into(), fmt_ns(m), "2× far-memory signal".into()]);
    rep.row("CXL ld/st", 0.0, 0.0, m, 1e9 / m);

    // RDMA / TCP / HTTP2: message out + message back through the NIC
    // model (inline send+recv, costs charged on send).
    for (kind, label, note) in [
        (LinkKind::Rdma, "RDMA (CX-5 class)", "verbs small message"),
        (LinkKind::Uds, "UNIX domain socket", "same-host kernel path"),
        (LinkKind::Tcp, "TCP (IPoIB)", "kernel stack"),
        (LinkKind::Http2, "HTTP/2 (gRPC wire)", "TCP + framing"),
    ] {
        let pair = SimNicPair::new(kind, Arc::clone(&charger));
        let reps = if kind == LinkKind::Http2 { n / 20 } else { n / 4 };
        let m = time_op_mean(100, reps, || {
            pair.a.send(b"ping").unwrap();
            let _ = pair.b.try_recv();
            pair.b.send(b"pong").unwrap();
            let _ = pair.a.recv(Duration::from_secs(1)).unwrap();
        });
        t.row(&[label.into(), fmt_ns(m), note.into()]);
        rep.row(label, 0.0, 0.0, m, 1e9 / m);
    }

    t.print("Figure 1 — RTT comparison of communication protocols");
    rep.emit();
    println!("\nexpected ladder: CXL < RDMA < UDS < TCP < HTTP (paper Fig. 1).");
}
