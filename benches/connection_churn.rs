//! CONNECTION CHURN & CAPACITY: many channels on one host, served by
//! the daemon-wide pooled waiter tree (ISSUE 7) instead of dedicated
//! per-channel listener threads. Not a paper figure; this is the
//! repo's perf trajectory for the capacity plane (DESIGN.md §12).
//!
//! Layers:
//! * `churn/call/dedicated/c{N}` — N channels × 1 dedicated listener
//!   thread each (the pre-ISSUE-7 model: threads scale with channel
//!   count), one connection per channel, a single client sweeping
//!   round-robin. The capacity baseline.
//! * `churn/call/pooled/w{K}/c{N}` — the same sweep with zero
//!   dedicated listeners: every channel registers with the host's
//!   worker pool (K ≤ 8 threads parked on one aggregated doorbell
//!   root). CI's capacity gate holds the w8/c1024 row within 15% of
//!   the dedicated c1024 row — channel count must no longer buy
//!   thread count.
//! * `churn/open_close/pooled/w{K}/c{N}` — connect→call→drop storms
//!   against pooled channels: adoption and retirement churn through
//!   the waiter tree (slot recycling, closed-conn sweeps).
//! * `churn/elastic/{on,off}` — 8 client threads over an 8-shard
//!   connection with a deliberately tiny ring: elastic-on starts at
//!   one active shard and must earn width from claim-fail pressure
//!   (`active_shards_end` extra records where it landed).
//! * `churn/admission/{reject,shed}` — connects beyond `conn_limit`
//!   under each policy; extras carry the orchestrator's admission
//!   counters (admitted/rejected/shed).
//! * `churn/crash/seeded` — crash churn (ISSUE 10): each round arms a
//!   seeded kill against a fresh victim connection, lets its batch
//!   die mid-flight, waits out the lease, and sweeps; the row's
//!   throughput is crash-to-recovered rounds/s and its extras are the
//!   orchestrator's full `fault` CounterSet (kills, reaps,
//!   recoveries, epoch bumps, adoptions, ...).
//! * `churn/acct/{fixed,elastic_off}` — deterministic single-threaded
//!   inline-serving accounting rows. The elastic machinery compiled
//!   in but switched OFF must charge byte-for-byte what the fixed
//!   path charges; CI asserts the two `charged_ns_per_op` extras are
//!   exactly equal.
//!
//! Charging is skipped (accounting still accumulates): capacity rows
//! measure the *structural* cost of fanning k workers over N
//! channels, and a charged 0.4s connect handshake would drown the
//! open/close storm in simulated sleep.
//!
//! Run: `cargo bench --bench connection_churn [-- --quick]`

use rpcool::benchkit::{BenchReport, Table};
use rpcool::channel::{CallOpts, ChannelBuilder, Connection, RpcServer};
use rpcool::config::AdmissionPolicy;
use rpcool::metrics::Histogram;
use rpcool::{ChargePolicy, Rack, SimConfig};
use std::sync::Arc;
use std::time::Instant;

/// Bench config: structural timing (no charged spins), pool big
/// enough for 1k+ connection heaps.
fn cfg() -> SimConfig {
    let mut c = SimConfig::for_bench();
    c.charge = ChargePolicy::Skip;
    c.pool_bytes = 1 << 30;
    c
}

/// Open `channels` channels on host 0 — pooled (`workers` > 0, no
/// listener threads) or dedicated (one listener thread each) — and
/// sweep one client round-robin across one connection per channel.
/// Returns (ops/s, per-call latency hist, dedicated listener threads).
fn capacity(channels: usize, workers: usize, calls_per_chan: u64) -> (f64, Histogram, usize) {
    let rack = Rack::new(cfg());
    let env = rack.proc_env(0);
    let mut servers: Vec<(RpcServer, Vec<std::thread::JoinHandle<()>>)> =
        Vec::with_capacity(channels);
    for i in 0..channels {
        let mut b = ChannelBuilder::from_config(&rack.cfg)
            .heap_bytes(192 << 10)
            .ring_slots(8)
            .ring_shards(1)
            .arg_arena_bytes(0);
        if workers > 0 {
            b = b.pool_workers(workers);
        }
        let server = b.open(&env, &format!("cap{i}")).unwrap();
        server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
        // Pooled channels return no handles — that is the point.
        let handles = server.spawn_listeners(1);
        servers.push((server, handles));
    }
    let nthreads: usize = servers.iter().map(|(_, h)| h.len()).sum();
    assert_eq!(nthreads, if workers > 0 { 0 } else { channels });

    let cenv = rack.proc_env(1);
    let conns: Vec<Connection> = (0..channels)
        .map(|i| Connection::connect(&cenv, &format!("cap{i}")).unwrap())
        .collect();
    let hist = Histogram::new();
    let t0 = Instant::now();
    cenv.run(|| {
        for k in 0..calls_per_chan {
            for conn in &conns {
                let t = Instant::now();
                let r = conn.call_typed::<u64, u64>(1, &k, CallOpts::new()).unwrap();
                assert_eq!(r.take().unwrap(), k + 1);
                hist.record(t.elapsed());
            }
        }
    });
    let wall = t0.elapsed();
    drop(conns);
    for (s, handles) in servers {
        s.stop();
        for h in handles {
            h.join().unwrap();
        }
    }
    let total = channels as u64 * calls_per_chan;
    (total as f64 / wall.as_secs_f64(), hist, nthreads)
}

/// Connect→call→drop storm round-robining over pooled channels:
/// measures full connection lifecycle throughput while the waiter
/// tree adopts and retires slots. Returns (opens/s, per-open hist).
fn open_close_storm(channels: usize, workers: usize, rounds: u64) -> (f64, Histogram) {
    let rack = Rack::new(cfg());
    let env = rack.proc_env(0);
    let servers: Vec<RpcServer> = (0..channels)
        .map(|i| {
            let s = ChannelBuilder::from_config(&rack.cfg)
                .heap_bytes(192 << 10)
                .ring_slots(8)
                .ring_shards(1)
                .arg_arena_bytes(0)
                .pool_workers(workers)
                .open(&env, &format!("storm{i}"))
                .unwrap();
            s.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
            s.spawn_listeners(1); // no-op in pooled mode
            s
        })
        .collect();
    let cenv = rack.proc_env(1);
    let hist = Histogram::new();
    let t0 = Instant::now();
    cenv.run(|| {
        for r in 0..rounds {
            let name = format!("storm{}", r as usize % channels);
            let t = Instant::now();
            let conn = Connection::connect(&cenv, &name).unwrap();
            let ret = conn.call_typed::<u64, u64>(1, &r, CallOpts::new()).unwrap();
            assert_eq!(ret.take().unwrap(), r + 1);
            drop(conn);
            hist.record(t.elapsed());
        }
    });
    let wall = t0.elapsed();
    for s in &servers {
        s.stop();
    }
    (rounds as f64 / wall.as_secs_f64(), hist)
}

/// 8 client threads hammering an 8-shard connection with a tiny ring:
/// elastic-on must earn width under claim-fail pressure. Returns
/// (ops/s, hist, active shards at the end).
fn elastic(on: bool, ops_per_thread: u64) -> (f64, Histogram, usize) {
    let rack = Rack::new(cfg());
    let env = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_slots(4)
        .ring_shards(8)
        .elastic_shards(on)
        .open(&env, "elastic")
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let listeners = server.spawn_listeners(4);
    let cenv = rack.proc_env(1);
    let conn = Arc::new(Connection::connect(&cenv, "elastic").unwrap());

    let hist = Arc::new(Histogram::new());
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for tid in 0..8u64 {
        let conn = Arc::clone(&conn);
        let hist = Arc::clone(&hist);
        let env = cenv.clone();
        clients.push(std::thread::spawn(move || {
            env.run(|| {
                for k in 0..ops_per_thread {
                    let v = tid * 1_000_000 + k;
                    let t = Instant::now();
                    let r = conn.call_typed::<u64, u64>(1, &v, CallOpts::new()).unwrap();
                    assert_eq!(r.take().unwrap(), v + 1);
                    hist.record(t.elapsed());
                }
            });
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let wall = t0.elapsed();
    let active = conn.shared.active_shard_count();
    drop(conn);
    server.stop();
    for l in listeners {
        l.join().unwrap();
    }
    let total = 8 * ops_per_thread;
    (total as f64 / wall.as_secs_f64(), Arc::try_unwrap(hist).ok().unwrap(), active)
}

/// Connect `attempts` clients against a `conn_limit`-capped channel
/// under `policy`; returns the orchestrator's (admitted, rejected,
/// shed) counter deltas.
fn admission(policy: AdmissionPolicy, limit: usize, attempts: usize) -> (u64, u64, u64) {
    use rpcool::orchestrator::{ADM_ADMITTED, ADM_REJECTED, ADM_SHED};
    let rack = Rack::new(cfg());
    let env = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .heap_bytes(192 << 10)
        .ring_slots(8)
        .ring_shards(1)
        .arg_arena_bytes(0)
        .pool_workers(2)
        .admission(policy)
        .conn_limit(limit)
        .open(&env, "admit")
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let cenv = rack.proc_env(1);
    let mut held = Vec::new();
    for k in 0..attempts {
        if let Ok(conn) = Connection::connect(&cenv, "admit") {
            // Shed-class connections still serve — at degraded drain
            // budget — so exercise one call.
            let r = conn.call_typed::<u64, u64>(1, &(k as u64), CallOpts::new()).unwrap();
            assert_eq!(r.take().unwrap(), k as u64 + 1);
            held.push(conn);
        }
    }
    let adm = rack.orch.admission();
    let out = (adm.get(ADM_ADMITTED), adm.get(ADM_REJECTED), adm.get(ADM_SHED));
    drop(held);
    server.stop();
    out
}

/// Crash churn (ISSUE 10): every round connects a fresh victim, arms
/// a seeded client-side kill, lets its batch die mid-flight, waits
/// out the lease, and sweeps — measuring full crash-to-recovered
/// turnaround while a survivor connection keeps being served. The
/// orchestrator's `fault` CounterSet is returned for the report's
/// extras, so the perf trajectory carries the recovery books
/// (kills/reaps/recoveries/epoch bumps/adoptions) alongside the
/// latency numbers.
fn crash_churn(rounds: u64) -> (f64, Histogram, Arc<rpcool::metrics::CounterSet>) {
    use rpcool::fault::{self, FaultPlan, KillPoint};
    use std::sync::atomic::{AtomicBool, Ordering};
    let mut c = cfg();
    c.lease_ttl_ms = 25; // keep the lapse-wait, not the default TTL
    let rack = Rack::new(c);
    let env = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_slots(8)
        .ring_shards(1)
        .pool_workers(2)
        .open(&env, "crashchurn")
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let cenv = rack.proc_env(1);
    let surv = Connection::connect(&cenv, "crashchurn").unwrap();

    // Survivors renew; each round's victim lapses.
    let stop = Arc::new(AtomicBool::new(false));
    let renew = {
        let stop = Arc::clone(&stop);
        let daemon = Arc::clone(server.core().daemon());
        let procs = vec![env.proc, cenv.proc];
        std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                for p in &procs {
                    daemon.renew_all(*p);
                }
                std::thread::sleep(std::time::Duration::from_millis(8));
            }
        })
    };

    let hist = Histogram::new();
    let t0 = Instant::now();
    for r in 0..rounds {
        let vic_env = rack.proc_env(1);
        let vic = Connection::connect(&vic_env, "crashchurn").unwrap();
        let t = Instant::now();
        fault::arm_with_sink(
            FaultPlan::seeded(KillPoint::PreFlush, 0xC4A5_4C41 ^ r, 3).victim(vic_env.proc),
            Arc::downgrade(&rack.orch.fault_counters()),
        );
        std::thread::spawn(move || {
            vic_env.run(|| {
                let vals: Vec<u64> = (0..64).collect();
                let _ = vic.call_scalar_batch::<u64>(1, &vals, CallOpts::new());
                vic.crash();
            })
        })
        .join()
        .unwrap();
        std::thread::sleep(std::time::Duration::from_millis(rack.cfg.lease_ttl_ms + 10));
        rack.orch.tick();
        // Recovered: the survivor must still be served.
        let ok = cenv.run(|| surv.call_scalar::<u64>(1, &r, CallOpts::new())).unwrap();
        assert_eq!(ok, r + 1);
        hist.record(t.elapsed());
    }
    let wall = t0.elapsed();
    fault::disarm();
    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    let counters = rack.orch.fault_counters();
    drop(surv);
    server.stop();
    (rounds as f64 / wall.as_secs_f64(), hist, counters)
}

/// Deterministic single-threaded inline-serving accounting: charged
/// ns per op on a fixed 4-shard channel. `explicit_off` routes
/// through a builder that names the elastic knob (set to off) — the
/// two variants must charge identically, byte for byte.
fn acct(explicit_off: bool, ops: u64) -> f64 {
    let rack = Rack::new(cfg());
    let env = rack.proc_env(0);
    let mut b = ChannelBuilder::from_config(&rack.cfg)
        .ring_slots(8)
        .ring_shards(4)
        .two_choice(false);
    if explicit_off {
        b = b.elastic_shards(false);
    }
    let name = if explicit_off { "acct-off" } else { "acct-fixed" };
    let server = b.open(&env, name).unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let cenv = rack.proc_env(1);
    let conn = Connection::connect(&cenv, name).unwrap();
    conn.attach_inline(&server);
    let before = rack.pool.charger.total_charged_ns();
    cenv.run(|| {
        for k in 0..ops {
            let r = conn.call_typed::<u64, u64>(1, &k, CallOpts::new()).unwrap();
            assert_eq!(r.take().unwrap(), k + 1);
        }
    });
    let charged = rack.pool.charger.total_charged_ns() - before;
    drop(conn);
    server.stop();
    charged as f64 / ops as f64
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let calls_per_chan: u64 = if quick { 2 } else { 10 };
    let storm_rounds: u64 = if quick { 128 } else { 1024 };
    let elastic_ops: u64 = if quick { 2_000 } else { 20_000 };
    let acct_ops: u64 = if quick { 2_000 } else { 20_000 };
    let crash_rounds: u64 = if quick { 2 } else { 6 };

    let mut t = Table::new(&["Scenario", "ops/s", "p50", "p99", "p99.9", "threads"]);
    let mut rep = BenchReport::new("connection_churn");
    // 2ms SLO on every histogram row: the capacity plane is judged on
    // its deep tail, not its median.
    rep.slo(2_000_000);

    // Dedicated baseline: threads scale with channels. Only the
    // gate's comparison point (c1024) spends the thread budget.
    for channels in [64usize, 1024] {
        let (thr, hist, nthreads) = capacity(channels, 0, calls_per_chan);
        t.row(&[
            format!("churn/call/dedicated/c{channels}"),
            format!("{thr:.0}"),
            Histogram::fmt_ns(hist.median_ns()),
            Histogram::fmt_ns(hist.p99_ns()),
            Histogram::fmt_ns(hist.p999_ns()),
            format!("{nthreads}"),
        ]);
        rep.row_hist(&format!("churn/call/dedicated/c{channels}"), &hist, thr);
        rep.extra("listener_threads", nthreads as f64);
        rep.extra("pool_workers", 0.0);
        rep.extra("channels", channels as f64);
    }

    // Pooled: k ≤ 8 workers regardless of channel count; zero
    // dedicated listener threads (asserted inside `capacity`).
    for workers in [2usize, 8] {
        for channels in [64usize, 256, 1024] {
            let (thr, hist, nthreads) = capacity(channels, workers, calls_per_chan);
            t.row(&[
                format!("churn/call/pooled/w{workers}/c{channels}"),
                format!("{thr:.0}"),
                Histogram::fmt_ns(hist.median_ns()),
                Histogram::fmt_ns(hist.p99_ns()),
                Histogram::fmt_ns(hist.p999_ns()),
                format!("{nthreads}"),
            ]);
            rep.row_hist(&format!("churn/call/pooled/w{workers}/c{channels}"), &hist, thr);
            rep.extra("listener_threads", nthreads as f64);
            rep.extra("pool_workers", workers as f64);
            rep.extra("channels", channels as f64);
        }
    }

    // Lifecycle churn through the waiter tree.
    for (workers, channels) in [(2usize, 64usize), (8, 256)] {
        let (thr, hist) = open_close_storm(channels, workers, storm_rounds);
        t.row(&[
            format!("churn/open_close/pooled/w{workers}/c{channels}"),
            format!("{thr:.0}"),
            Histogram::fmt_ns(hist.median_ns()),
            Histogram::fmt_ns(hist.p99_ns()),
            Histogram::fmt_ns(hist.p999_ns()),
            format!("{workers}"),
        ]);
        rep.row_hist(&format!("churn/open_close/pooled/w{workers}/c{channels}"), &hist, thr);
        rep.extra("pool_workers", workers as f64);
        rep.extra("channels", channels as f64);
    }

    // Elastic window: on earns width under pressure; off routes the
    // full capacity from the first call, as always.
    for on in [false, true] {
        let label = if on { "churn/elastic/on" } else { "churn/elastic/off" };
        let (thr, hist, active) = elastic(on, elastic_ops / 8);
        t.row(&[
            label.into(),
            format!("{thr:.0}"),
            Histogram::fmt_ns(hist.median_ns()),
            Histogram::fmt_ns(hist.p99_ns()),
            Histogram::fmt_ns(hist.p999_ns()),
            "-".into(),
        ]);
        rep.row_hist(label, &hist, thr);
        rep.extra("active_shards_end", active as f64);
    }

    // Admission policies at the capacity ceiling.
    let (adm, rej, _) = admission(AdmissionPolicy::Reject, 8, 16);
    t.row(&[
        "churn/admission/reject".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{adm} adm / {rej} rej"),
    ]);
    rep.row("churn/admission/reject", 0.0, 0.0, 0.0, 0.0);
    rep.extra("admitted", adm as f64);
    rep.extra("rejected", rej as f64);
    let (adm, _, shed) = admission(AdmissionPolicy::Shed, 8, 16);
    t.row(&[
        "churn/admission/shed".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{adm} adm / {shed} shed"),
    ]);
    rep.row("churn/admission/shed", 0.0, 0.0, 0.0, 0.0);
    rep.extra("admitted", adm as f64);
    rep.extra("shed", shed as f64);

    // The elastic-off byte-identity gate: identical deterministic
    // workload, identical charge — knob present but off must be the
    // fixed path exactly.
    let fixed_ns = acct(false, acct_ops);
    let off_ns = acct(true, acct_ops);
    for (label, ns) in [("churn/acct/fixed", fixed_ns), ("churn/acct/elastic_off", off_ns)] {
        t.row(&[
            label.into(),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            format!("{ns:.1} ns/op charged"),
        ]);
        rep.row(label, 0.0, 0.0, 0.0, 0.0);
        rep.extra("charged_ns_per_op", ns);
    }

    // Crash churn: seeded kills against fresh victims, lease lapse,
    // sweep, survivor liveness — the fault CounterSet rides along as
    // extras so the perf trajectory carries the recovery books.
    let (thr, hist, fc) = crash_churn(crash_rounds);
    t.row(&[
        "churn/crash/seeded".into(),
        format!("{thr:.1}"),
        Histogram::fmt_ns(hist.median_ns()),
        Histogram::fmt_ns(hist.p99_ns()),
        Histogram::fmt_ns(hist.p999_ns()),
        format!("{} kills", fc.get(rpcool::orchestrator::FLT_KILLS)),
    ]);
    // Crash rounds sit on a deliberate lease-lapse wait, so the 2ms
    // call SLO does not apply to this row's latency columns.
    rep.row("churn/crash/seeded", 0.0, 0.0, 0.0, thr);
    for (name, v) in fc.snapshot() {
        rep.extra(name, v as f64);
    }

    t.print("Connection churn — pooled capacity plane vs dedicated listeners");
    println!(
        "\ninvariants: pooled w8/c1024 throughput must stay within 15% of the\n\
         dedicated c1024 baseline with zero listener threads (CI gate); the\n\
         churn/acct rows must charge *exactly* the same ns/op — the elastic\n\
         knob switched off is the fixed path, byte for byte."
    );
    println!("acct fixed {fixed_ns:.3} ns/op vs elastic-off {off_ns:.3} ns/op");
    rep.emit();
}
