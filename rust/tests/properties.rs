//! Property-based tests over the coordinator's core invariants
//! (mini-proptest from `rpcool::util::prop`): allocator soundness,
//! seal state machine, DSM single-owner protocol, distribution
//! bounds, and representation round-trips.

use rpcool::apps::doc::Val;
use rpcool::baselines::wire::Wire;
use rpcool::config::SimConfig;
use rpcool::dsm::{DsmState, NODE_CLIENT, NODE_SERVER};
use rpcool::memory::{Heap, Pool, Scope};
use rpcool::seal::Sealer;
use rpcool::util::prop::{forall, Gen, PairGen, U64Range, VecGen};
use rpcool::util::Rng;
use rpcool::workloads::zipf::{KeyDist, Zipfian};
use std::sync::Arc;

fn pool() -> Arc<Pool> {
    Pool::new(&SimConfig::for_tests()).unwrap()
}

// ---------------------------------------------------------- allocator

/// Random alloc/free interleavings never hand out overlapping blocks
/// and never lose memory permanently.
#[test]
fn prop_allocator_no_overlap_random_interleavings() {
    let sizes = VecGen { elem: U64Range(1, 20_000), max_len: 120 };
    forall("alloc-no-overlap", 0xA110C, 40, &sizes, |szs| {
        let p = pool();
        let h = Heap::new(&p, "prop", 8 << 20).unwrap();
        let mut live: Vec<(usize, usize)> = Vec::new();
        let mut rng = Rng::new(szs.len() as u64 + 1);
        for &sz in szs {
            let sz = sz as usize;
            // Randomly free one live alloc ~40% of the time.
            if !live.is_empty() && rng.chance(0.4) {
                let i = rng.next_below(live.len() as u64) as usize;
                let (addr, _) = live.swap_remove(i);
                h.free_bytes(addr);
            }
            let Ok(addr) = h.alloc_bytes(sz) else { continue };
            for &(b, bsz) in &live {
                if addr < b + bsz && b < addr + sz {
                    return false; // overlap!
                }
            }
            live.push((addr, sz));
        }
        for (a, _) in live {
            h.free_bytes(a);
        }
        h.live_allocs() == 0
    });
}

/// Full free returns the heap to a state where the original largest
/// allocation still fits (no permanent fragmentation from page-class
/// allocs of the same sizes).
#[test]
fn prop_allocator_recovers_after_free() {
    let sizes = VecGen { elem: U64Range(4_097, 100_000), max_len: 30 };
    forall("alloc-recovers", 0xF4EE, 30, &sizes, |szs| {
        let p = pool();
        let h = Heap::new(&p, "prop2", 8 << 20).unwrap();
        let before = h.free_page_bytes();
        let mut live = Vec::new();
        for &sz in szs {
            if let Ok(a) = h.alloc_bytes(sz as usize) {
                live.push(a);
            }
        }
        for a in live {
            h.free_bytes(a);
        }
        h.free_page_bytes() == before
    });
}

// ---------------------------------------------------------- sealing

/// Random seal/complete/release sequences: release only ever succeeds
/// after complete; sealed ranges always block sender writes; the
/// sealed-count returns to zero when every handle is released.
#[test]
fn prop_seal_state_machine() {
    let ops = VecGen { elem: U64Range(0, 2), max_len: 60 };
    forall("seal-fsm", 0x5EA1, 40, &ops, |ops| {
        let cfg = SimConfig::for_tests();
        let p = pool();
        let h = Heap::new(&p, "seal", 8 << 20).unwrap();
        let sealer = Sealer::new(&cfg, Arc::clone(&h), Arc::clone(&p.charger)).unwrap();
        let scope = Scope::create(&h, 4096).unwrap();
        let mut active: Vec<(rpcool::seal::SealHandle, bool)> = Vec::new();
        for &op in ops {
            match op {
                0 => {
                    // seal (limit in-flight to avoid ring pressure)
                    if active.len() < 16 {
                        let hdl = sealer.seal(scope.base(), scope.len(), 1).unwrap();
                        if h.check_write(scope.base(), 8, 1).is_ok() {
                            return false; // seal must block sender writes
                        }
                        active.push((hdl, false));
                    }
                }
                1 => {
                    // complete the oldest incomplete
                    if let Some(e) = active.iter_mut().find(|e| !e.1) {
                        sealer.complete(e.0.idx);
                        e.1 = true;
                    }
                }
                _ => {
                    // try release the oldest
                    if !active.is_empty() {
                        let (hdl, completed) = active[0];
                        let r = sealer.release(hdl);
                        if completed != r.is_ok() {
                            return false; // release iff completed
                        }
                        if r.is_ok() {
                            active.remove(0);
                        }
                    }
                }
            }
        }
        // Drain.
        for (hdl, completed) in active {
            if !completed {
                sealer.complete(hdl.idx);
            }
            sealer.release(hdl).unwrap();
        }
        h.sealed_count() == 0 && h.check_write(scope.base(), 8, 1).is_ok()
    });
}

// ---------------------------------------------------------- DSM

/// Random two-node access sequences: every page always has exactly one
/// valid owner; a node that just ensured ownership reads its own
/// writes; fault count equals actual ownership flips.
#[test]
fn prop_dsm_single_owner() {
    let accesses = VecGen {
        elem: PairGen(U64Range(0, 1), U64Range(0, 63)),
        max_len: 200,
    };
    forall("dsm-single-owner", 0xD5A, 40, &accesses, |ops| {
        let cfg = SimConfig::for_tests();
        let p = pool();
        let h = Heap::new(&p, "dsm", 64 * 4096).unwrap();
        let d = DsmState::new(&h, cfg.page_bytes);
        let mut owner = vec![NODE_CLIENT; 64];
        let mut expected_faults = 0u64;
        for &(node, page) in ops {
            let node = if node == 0 { NODE_CLIENT } else { NODE_SERVER };
            let addr = h.base() + page as usize * 4096;
            let moved = d.ensure_owned(node, addr, 8).unwrap();
            if owner[page as usize] != node {
                expected_faults += 1;
                if moved != 1 {
                    return false;
                }
                owner[page as usize] = node;
            } else if moved != 0 {
                return false;
            }
        }
        let (faults, pages) = d.stats();
        d.owners_valid() && faults == expected_faults && pages == expected_faults
    });
}

// ------------------------------------------------ distributions & misc

#[test]
fn prop_zipfian_in_bounds_any_n() {
    forall("zipf-bounds", 0x21F, 60, &U64Range(1, 50_000), |&n| {
        let z = Zipfian::new(n);
        let mut rng = Rng::new(n ^ 7);
        (0..500).all(|_| z.next(&mut rng) < n)
    });
}

#[test]
fn prop_keydist_latest_prefers_tail() {
    forall("latest-tail", 0x1A7E57, 20, &U64Range(1_000, 100_000), |&n| {
        let d = KeyDist::latest(n);
        let mut rng = Rng::new(n);
        let hits = (0..2_000).filter(|_| d.next(&mut rng, n) >= n / 2).count();
        hits > 1_200
    });
}

/// Host ⇄ wire ⇄ host round-trip for randomly generated documents.
#[test]
fn prop_doc_wire_roundtrip() {
    struct DocGen;
    impl Gen for DocGen {
        type Value = Val;
        fn generate(&self, rng: &mut Rng) -> Val {
            random_doc(rng, 3)
        }
    }
    forall("doc-wire-roundtrip", 0xD0C, 200, &DocGen, |doc| {
        match Val::from_bytes(&doc.to_bytes()) {
            Ok(back) => back == *doc,
            Err(_) => false,
        }
    });
}

/// Host ⇄ shared-memory ⇄ host round-trip for random documents.
#[test]
fn prop_doc_shm_roundtrip() {
    struct DocGen;
    impl Gen for DocGen {
        type Value = Val;
        fn generate(&self, rng: &mut Rng) -> Val {
            random_doc(rng, 3)
        }
    }
    let p = pool();
    let h = Heap::new(&p, "docs", 32 << 20).unwrap();
    forall("doc-shm-roundtrip", 0x5D0C, 120, &DocGen, |doc| {
        let Ok(shm) = doc.to_shm(h.as_ref()) else { return false };
        let ok = matches!(shm.to_host(), Ok(back) if back == *doc);
        let mut shm = shm;
        shm.deep_free(h.as_ref()).unwrap();
        ok
    });
}

fn random_doc(rng: &mut Rng, depth: usize) -> Val {
    match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
        0 => Val::Null,
        1 => Val::Bool(rng.chance(0.5)),
        2 => Val::Num(rng.next_f64() * 1e6),
        3 => {
            let n = rng.next_below(24) as usize;
            Val::Str(rng.alnum_string(n))
        }
        4 => {
            let n = rng.next_below(5) as usize;
            Val::Arr((0..n).map(|_| random_doc(rng, depth - 1)).collect())
        }
        _ => {
            let n = rng.next_below(5) as usize;
            Val::Obj(
                (0..n)
                    .map(|i| (format!("k{i}"), random_doc(rng, depth - 1)))
                    .collect(),
            )
        }
    }
}

/// Histogram percentiles are monotone and bounded by min/max.
#[test]
fn prop_histogram_percentiles_monotone() {
    let samples = VecGen { elem: U64Range(1, 10_000_000), max_len: 300 };
    forall("hist-monotone", 0x415, 60, &samples, |xs| {
        if xs.is_empty() {
            return true;
        }
        let h = rpcool::metrics::Histogram::new();
        for &x in xs {
            h.record_ns(x);
        }
        let p25 = h.percentile_ns(25.0);
        let p50 = h.percentile_ns(50.0);
        let p99 = h.percentile_ns(99.0);
        p25 <= p50 && p50 <= p99 && p99 <= h.max_ns() * 2
    });
}

/// Wire encoding round-trips arbitrary nested vectors of pairs.
#[test]
fn prop_wire_nested_roundtrip() {
    let gen = VecGen {
        elem: PairGen(U64Range(0, u64::MAX / 2), U64Range(0, 255)),
        max_len: 64,
    };
    forall("wire-nested", 0x3172, 150, &gen, |v| {
        let strings: Vec<(u64, String)> =
            v.iter().map(|(a, b)| (*a, "x".repeat(*b as usize % 40))).collect();
        matches!(
            <Vec<(u64, String)> as Wire>::from_bytes(&strings.to_bytes()),
            Ok(back) if back == strings
        )
    });
}
