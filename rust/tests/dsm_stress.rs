//! Seeded stress/property suite for the generalized DSM ownership
//! protocol (the cluster plane's cross-pod data path): racing writers
//! from several pods hammer one DSM-backed heap while the suite checks
//! the accounting that the rack benchmarks and `BenchReport` extras
//! are built on.
//!
//! Seeding follows `ring_stress`: every scenario is drawn from
//! `util::prop::forall` under the `PROP_SEED` env var (CI sweeps four
//! seeds in debug and release); failures print the seed and the
//! shrunk scenario.
//!
//! Invariants checked on every scenario:
//!
//! * **Exactly-once transfers** — the per-writer sums of
//!   `ensure_owned` return values equal the shared fault/page
//!   counters: no transition is double-counted or lost no matter how
//!   many writers race on the same owner word;
//! * **Owner-map/charger equivalence** — `charged_ns` is exactly
//!   `pages_transferred * page_move_ns`, and the pool charger's delta
//!   matches (DSM costs are charged once, to one place);
//! * **Owner validity** — after the race every page is owned by a
//!   real participant node;
//! * **Settle phase** — one sequential sweep by a single node moves
//!   exactly the pages that node didn't already own, and afterwards
//!   owns everything (the map is coherent, not just valid).

use rpcool::cluster::DsmState;
use rpcool::memory::pool::Pool;
use rpcool::memory::Heap;
use rpcool::util::prop::{forall, Gen, U64Range};
use rpcool::util::rng::Rng;
use rpcool::SimConfig;
use std::sync::Arc;

/// Seed source: `PROP_SEED` env var (CI matrix), fixed default.
fn prop_seed() -> u64 {
    std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x5EED)
}

/// One randomized multi-pod DSM schedule.
#[derive(Clone, Debug)]
struct Scenario {
    /// Participant nodes (pods) sharing the heap.
    nodes: u64,
    /// Racing writer threads (assigned round-robin to nodes, so some
    /// nodes race against themselves too — swaps to the same owner
    /// must not be charged).
    writers: u64,
    /// `ensure_owned` calls per writer.
    ops: u64,
    /// Heap size in DSM pages.
    pages: u64,
    /// Max touched range per call, in bytes.
    max_span: u64,
    /// Salt for the per-writer address streams.
    salt: u64,
}

struct ScenarioGen;

impl Gen for ScenarioGen {
    type Value = Scenario;
    fn generate(&self, rng: &mut Rng) -> Scenario {
        Scenario {
            nodes: rng.range(2, 6),
            writers: rng.range(2, 9),
            ops: rng.range(16, 129),
            pages: rng.range(8, 65),
            max_span: rng.range(1, 3 * 4096),
            salt: rng.next_u64(),
        }
    }
    fn shrink(&self, v: &Scenario) -> Vec<Scenario> {
        let mut out = Vec::new();
        if v.ops > 16 {
            out.push(Scenario { ops: v.ops / 2, ..v.clone() });
        }
        if v.writers > 2 {
            out.push(Scenario { writers: v.writers - 1, ..v.clone() });
        }
        if v.nodes > 2 {
            out.push(Scenario { nodes: v.nodes - 1, ..v.clone() });
        }
        out
    }
}

/// Run one racing-writers scenario; `true` iff every invariant held.
/// The pool is fresh per scenario so the charger delta is attributable
/// to this DSM instance alone.
fn run_scenario(sc: &Scenario) -> bool {
    let cfg = SimConfig::for_tests();
    let pool = Pool::new(&cfg).unwrap();
    let heap = Heap::new(&pool, "dsm-stress", sc.pages as usize * cfg.page_bytes).unwrap();
    // Non-contiguous node ids: pod ids in real topologies need not be
    // dense, and the owner word stores the id verbatim.
    let node_ids: Vec<u32> = (0..sc.nodes as u32).map(|i| i * 7 + 3).collect();
    let dsm = DsmState::new_multi(&heap, cfg.page_bytes, &node_ids, node_ids[0]);
    let charged_before = pool.charger.total_charged_ns();

    let base = heap.base();
    let hlen = heap.len();
    let mut writers = Vec::new();
    for tid in 0..sc.writers {
        let dsm = Arc::clone(&dsm);
        let node = node_ids[(tid % sc.nodes) as usize];
        let (salt, ops, max_span) = (sc.salt, sc.ops, sc.max_span);
        writers.push(std::thread::spawn(move || {
            let mut rng = Rng::new(salt ^ tid.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut moved = 0u64;
            for _ in 0..ops {
                let off = rng.next_below(hlen as u64) as usize;
                let span = (1 + rng.next_below(max_span) as usize).min(hlen - off);
                moved += dsm.ensure_owned(node, base + off, span).unwrap() as u64;
            }
            moved
        }));
    }
    let local_sum: u64 = writers.into_iter().map(|w| w.join().unwrap()).sum();

    // Exactly-once: the per-writer sums partition the shared counters.
    let (faults, pages) = dsm.stats();
    if faults != local_sum || pages != local_sum {
        eprintln!("dsm-race: counters (faults {faults}, pages {pages}) != writer sum {local_sum}");
        return false;
    }
    // Owner-map/charger equivalence: DSM charges exactly
    // pages * page_move_ns, once, to the pool's charger.
    let per_page = DsmState::page_move_ns(&pool.charger.cost);
    let charger_delta = pool.charger.total_charged_ns() - charged_before;
    if dsm.charged_ns() != pages * per_page || charger_delta != pages * per_page {
        eprintln!(
            "dsm-race: charge accounting broke: dsm {} charger {} expect {}",
            dsm.charged_ns(),
            charger_delta,
            pages * per_page
        );
        return false;
    }
    if !dsm.owners_valid() {
        eprintln!("dsm-race: a page ended up owned by a non-participant");
        return false;
    }
    // Settle: one node sweeps the heap sequentially; it must fault
    // exactly the pages it doesn't own and then own all of them.
    let settler = node_ids[0];
    let foreign = (0..dsm.npages())
        .filter(|&i| dsm.owner_of(base + i * cfg.page_bytes) != Some(settler))
        .count();
    let swept = dsm.ensure_owned(settler, base, hlen).unwrap();
    if swept != foreign {
        eprintln!("dsm-race: settle moved {swept} != foreign pages {foreign}");
        return false;
    }
    (0..dsm.npages()).all(|i| dsm.owner_of(base + i * cfg.page_bytes) == Some(settler))
}

/// The main randomized sweep.
#[test]
fn stress_racing_writers_exactly_once() {
    forall("dsm-race", prop_seed(), 24, &ScenarioGen, run_scenario);
}

/// Owner-epoch reclamation replay (the crash-fault plane's DSM half):
/// a seeded transfer schedule runs, then one node "dies" and the
/// sweep reclaims its pages via `reclaim_dead`. Checked per scenario:
///
/// * reclamation swings every corpse-owned page to the heir with
///   exactly one epoch bump each (bumps == pages), and a second sweep
///   finds nothing — reclaim is exactly-once;
/// * reclamation charges *nothing*: transfer counters and `charged_ns`
///   stay exactly `pages_transferred * page_move_ns`;
/// * post-reclaim transfers still work and keep exact accounting (a
///   settle sweep moves exactly the pages the settler didn't own);
/// * the whole history replays: the same seed reproduces identical
///   transfer counts, reclamation counts, and final owner/epoch maps.
#[test]
fn prop_owner_epoch_reclaim_replays_exactly_once() {
    forall("dsm-reclaim", prop_seed(), 16, &U64Range(0, (1 << 48) - 1), |&salt| {
        let cfg = SimConfig::for_tests();
        let pages = 24usize;
        let nodes: Vec<u32> = vec![3, 10, 17];
        let (dead, heir, settler) = (10u32, 3u32, 17u32);

        // One full life: schedule → corpse → reclaim → settle.
        // Returns the books and the final (owner, epoch) map.
        let run = || -> (u64, u64, u64, u64, Vec<(Option<u32>, Option<u32>)>) {
            let pool = Pool::new(&cfg).unwrap();
            let heap = Heap::new(&pool, "dsm-reclaim", pages * cfg.page_bytes).unwrap();
            let dsm = DsmState::new_multi(&heap, cfg.page_bytes, &nodes, nodes[0]);
            let base = heap.base();
            let mut rng = Rng::new(salt ^ 0xC0FF_EE00);
            let mut moved = 0u64;
            for _ in 0..120 {
                let node = nodes[rng.next_below(nodes.len() as u64) as usize];
                let off = rng.next_below((pages * cfg.page_bytes) as u64) as usize;
                let span = (1 + rng.next_below(2 * 4096) as usize).min(heap.len() - off);
                moved += dsm.ensure_owned(node, base + off, span).unwrap() as u64;
            }
            // The corpse's holdings, observed before the sweep.
            let corpse_pages = (0..dsm.npages())
                .filter(|&i| dsm.owner_of(base + i * cfg.page_bytes) == Some(dead))
                .count() as u64;
            let pre: Vec<(u32, u32)> = (0..dsm.npages())
                .map(|i| {
                    let a = base + i * cfg.page_bytes;
                    (dsm.owner_of(a).unwrap(), dsm.epoch_of(a).unwrap())
                })
                .collect();

            let (bumps, reclaimed) = dsm.reclaim_dead(dead, heir);
            if bumps != corpse_pages || reclaimed != corpse_pages {
                eprintln!(
                    "dsm-reclaim: swept ({bumps}, {reclaimed}) != corpse holdings {corpse_pages}"
                );
                return (u64::MAX, 0, 0, 0, Vec::new());
            }
            // Exactly-once: a second sweep of the same corpse is a no-op.
            if dsm.reclaim_dead(dead, heir) != (0, 0) {
                eprintln!("dsm-reclaim: second sweep reclaimed again");
                return (u64::MAX, 0, 0, 0, Vec::new());
            }
            // Every reclaimed page swung to the heir with exactly one
            // epoch bump; every other page is untouched.
            for (i, &(pre_owner, pre_epoch)) in pre.iter().enumerate() {
                let a = base + i * cfg.page_bytes;
                let want = if pre_owner == dead {
                    (Some(heir), Some(pre_epoch + 1))
                } else {
                    (Some(pre_owner), Some(pre_epoch))
                };
                if (dsm.owner_of(a), dsm.epoch_of(a)) != want {
                    eprintln!(
                        "dsm-reclaim: page {i} ({:?}, {:?}) != expected {want:?}",
                        dsm.owner_of(a),
                        dsm.epoch_of(a)
                    );
                    return (u64::MAX, 0, 0, 0, Vec::new());
                }
            }
            // Reclamation charges nothing: the transfer books still
            // read exactly pages_transferred * page_move_ns.
            let (faults, xfer_pages) = dsm.stats();
            let per_page = DsmState::page_move_ns(&pool.charger.cost);
            if faults != moved
                || xfer_pages != moved
                || dsm.charged_ns() != moved * per_page
            {
                eprintln!("dsm-reclaim: reclamation leaked into transfer accounting");
                return (u64::MAX, 0, 0, 0, Vec::new());
            }
            if dsm.reclaim_stats() != (bumps, reclaimed) {
                eprintln!("dsm-reclaim: reclaim_stats disagrees with the sweep's return");
                return (u64::MAX, 0, 0, 0, Vec::new());
            }
            // Post-reclaim transfers keep exact accounting: a settle
            // sweep moves exactly the settler's foreign pages.
            let foreign = (0..dsm.npages())
                .filter(|&i| dsm.owner_of(base + i * cfg.page_bytes) != Some(settler))
                .count();
            let swept = dsm.ensure_owned(settler, base, heap.len()).unwrap();
            if swept != foreign {
                eprintln!("dsm-reclaim: settle moved {swept} != foreign {foreign}");
                return (u64::MAX, 0, 0, 0, Vec::new());
            }
            let map: Vec<(Option<u32>, Option<u32>)> = (0..dsm.npages())
                .map(|i| {
                    let a = base + i * cfg.page_bytes;
                    (dsm.owner_of(a), dsm.epoch_of(a))
                })
                .collect();
            (moved, bumps, reclaimed, swept as u64, map)
        };

        let first = run();
        if first.0 == u64::MAX {
            return false;
        }
        // Replay: the same seed reproduces the identical history.
        let second = run();
        if first != second {
            eprintln!("dsm-reclaim: replay diverged under one seed");
            return false;
        }
        true
    });
}

/// Sequential multi-node schedules against a reference model: a plain
/// `Vec<u32>` owner map replayed op-for-op. `ensure_owned`'s return
/// value and the observable owner of every touched page must match
/// the model exactly.
#[test]
fn prop_sequential_matches_owner_model() {
    forall("dsm-model", prop_seed(), 32, &U64Range(0, (1 << 48) - 1), |&salt| {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let pages = 32usize;
        let heap = Heap::new(&pool, "dsm-model", pages * cfg.page_bytes).unwrap();
        let nodes: Vec<u32> = vec![2, 5, 11, 17];
        let dsm = DsmState::new_multi(&heap, cfg.page_bytes, &nodes, 2);
        let mut model = vec![2u32; pages];
        let mut rng = Rng::new(salt ^ 0xD5A1);
        let mut total_model_moves = 0u64;
        for _ in 0..200 {
            let node = nodes[rng.next_below(nodes.len() as u64) as usize];
            let first = rng.next_below(pages as u64) as usize;
            let span = 1 + rng.next_below(4) as usize;
            let last = (first + span - 1).min(pages - 1);
            let addr = heap.base() + first * cfg.page_bytes;
            let len = (last - first) * cfg.page_bytes + 1;
            let expect: usize = (first..=last).filter(|&i| model[i] != node).count();
            for i in first..=last {
                model[i] = node;
            }
            total_model_moves += expect as u64;
            let moved = dsm.ensure_owned(node, addr, len).unwrap();
            if moved != expect {
                eprintln!("dsm-model: moved {moved} != model {expect}");
                return false;
            }
        }
        for (i, &m) in model.iter().enumerate() {
            let got = dsm.owner_of(heap.base() + i * cfg.page_bytes);
            if got != Some(m) {
                eprintln!("dsm-model: page {i} owner {got:?} != model {m}");
                return false;
            }
        }
        let (faults, pages_moved) = dsm.stats();
        faults == total_model_moves
            && pages_moved == total_model_moves
            && dsm.charged_ns() == pages_moved * DsmState::page_move_ns(&pool.charger.cost)
            && dsm.owners_valid()
    });
}
