//! Seeded stress/property suite for the capacity plane (ISSUE 7):
//! pooled waiter-tree serving, connection open/close churn racing
//! live traffic, elastic shard-window resizing under in-flight
//! batches/async handles, and admission-denied connect paths.
//!
//! Same discipline as `ring_stress`: every test draws randomized
//! schedules from `util::prop::forall`, seeded by `PROP_SEED` (CI
//! sweeps four seeds in debug and release); a failure prints the seed
//! and the shrunk scenario.
//!
//! Invariants checked on every scenario:
//!
//! * no lost wakeups through the aggregated doorbell tree — k pooled
//!   workers (no per-channel listeners) must serve every call on
//!   every channel; a loss surfaces as a call timeout or the
//!   watchdog;
//! * open/close storms racing live traffic never wedge the pool,
//!   cross-wire a response, or strand a connection half-adopted;
//! * the elastic shard window stays a power of two within
//!   [1, capacity], and disabled elastic pins it to capacity;
//! * on clean runs every issued call completes and the per-channel
//!   served counters sum to exactly the issued count;
//! * admission over `conn_limit` fails/queues/sheds by policy — never
//!   by collapse — and shed-class connections still serve.

use rpcool::channel::waiter::SleepPolicy;
use rpcool::channel::{CallOpts, ChannelBuilder, Connection, RpcServer};
use rpcool::config::AdmissionPolicy;
use rpcool::error::RpcError;
use rpcool::fault::{self, FaultPlan, KillPoint};
use rpcool::orchestrator::{FLT_KILLS, FLT_RECOVERIES};
use rpcool::rack::Rack;
use rpcool::util::prop::{forall, Gen, U64Range};
use rpcool::util::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Seed source: `PROP_SEED` env var (CI matrix), fixed default.
fn prop_seed() -> u64 {
    std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x5EED)
}

/// Channel names must be distinct across scenarios (the in-process
/// directory is global).
static CHURN_ID: AtomicUsize = AtomicUsize::new(0);

/// An acceptable per-call outcome while the channel is being torn
/// down; anything else is a bug.
fn teardown_ok<T>(r: &Result<T, RpcError>) -> bool {
    matches!(
        r,
        Err(RpcError::Timeout(_))
            | Err(RpcError::ConnectionClosed)
            | Err(RpcError::ConnectionRefused(_, _))
            | Err(RpcError::ChannelNotFound(_))
    )
}

/// One randomized capacity-plane schedule.
#[derive(Clone, Debug)]
struct ChurnScenario {
    /// Channels sharing the host's worker pool.
    channels: u64,
    /// Pool worker threads (1..=4; the CI capacity row uses 8).
    workers: u64,
    /// Shards per connection = 1 << shards_pow.
    shards_pow: u32,
    clients: u64,
    /// Operations per client.
    ops: u64,
    /// Percent of ops that are connect→call→drop churn instead of a
    /// call on the client's long-lived connection.
    churn_pct: u64,
    /// Percent of remaining ops that are scalar batches (2..=5).
    batch_pct: u64,
    /// Elastic shard window on?
    elastic: bool,
    /// Stop every server mid-run; all calls must still terminate.
    early_stop: bool,
    salt: u64,
}

struct ChurnScenarioGen;

impl Gen for ChurnScenarioGen {
    type Value = ChurnScenario;
    fn generate(&self, rng: &mut Rng) -> ChurnScenario {
        ChurnScenario {
            channels: rng.range(1, 7),
            workers: rng.range(1, 5),
            shards_pow: rng.range(0, 3) as u32,
            clients: rng.range(1, 5),
            ops: rng.range(8, 33),
            churn_pct: rng.range(0, 41),
            batch_pct: rng.range(0, 41),
            elastic: rng.next_below(2) == 1,
            early_stop: rng.next_below(4) == 0,
            salt: rng.next_u64(),
        }
    }
    fn shrink(&self, v: &ChurnScenario) -> Vec<ChurnScenario> {
        let mut out = Vec::new();
        if v.ops > 8 {
            out.push(ChurnScenario { ops: v.ops / 2, ..v.clone() });
        }
        if v.clients > 1 {
            out.push(ChurnScenario { clients: v.clients - 1, ..v.clone() });
        }
        if v.channels > 1 {
            out.push(ChurnScenario { channels: 1, ..v.clone() });
        }
        if v.churn_pct > 0 {
            out.push(ChurnScenario { churn_pct: 0, ..v.clone() });
        }
        if v.early_stop {
            out.push(ChurnScenario { early_stop: false, ..v.clone() });
        }
        out
    }
}

/// Run one pooled-churn scenario; `true` iff every invariant held.
fn run_churn_scenario(sc: &ChurnScenario) -> bool {
    let run = CHURN_ID.fetch_add(1, Ordering::Relaxed);
    let rack = Rack::for_tests();
    let env = rack.proc_env(0);
    let nshards = 1usize << sc.shards_pow;
    let servers: Vec<RpcServer> = (0..sc.channels)
        .map(|i| {
            let s = ChannelBuilder::from_config(&rack.cfg)
                .ring_shards(nshards)
                .ring_slots(8)
                .pool_workers(sc.workers as usize)
                .elastic_shards(sc.elastic)
                .sleep(SleepPolicy::Park)
                .call_timeout(Duration::from_secs(5))
                .open(&env, &format!("churn-{run}-{i}"))
                .unwrap();
            s.serve_scalar::<u64>(1, |_ctx, v| Ok(v.wrapping_mul(3).wrapping_add(1)));
            // Pooled mode: no dedicated listener threads, ever.
            assert!(s.spawn_listeners(1).is_empty(), "pooled channel spawned a listener");
            s
        })
        .collect();

    let cenv = rack.proc_env(1);
    let failed = Arc::new(AtomicBool::new(false));
    let issued = Arc::new(AtomicU64::new(0));
    let completed = Arc::new(AtomicU64::new(0));
    let mut clients = Vec::new();
    for tid in 0..sc.clients {
        let env = cenv.clone();
        let failed = Arc::clone(&failed);
        let issued = Arc::clone(&issued);
        let completed = Arc::clone(&completed);
        let sc = sc.clone();
        clients.push(std::thread::spawn(move || {
            env.run(|| {
                let mut rng = Rng::new(sc.salt ^ tid.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let fail = |what: &str| {
                    eprintln!("churn-stress: client {tid}: {what}");
                    failed.store(true, Ordering::Relaxed);
                };
                let home = format!("churn-{run}-{}", tid % sc.channels);
                let conn = match Connection::connect(&env, &home) {
                    Ok(c) => c,
                    Err(_) if sc.early_stop => return,
                    Err(e) => {
                        fail(&format!("home connect failed: {e:?}"));
                        return;
                    }
                };
                for k in 0..sc.ops {
                    let base = tid * 1_000_000 + k * 100;
                    let mode = rng.next_below(100);
                    if mode < sc.churn_pct {
                        // Connection churn racing live traffic: a
                        // fresh conn to a random channel, one call,
                        // drop — adoption and retirement through the
                        // waiter tree while other clients keep the
                        // pool busy.
                        let target =
                            format!("churn-{run}-{}", rng.next_below(sc.channels));
                        issued.fetch_add(1, Ordering::Relaxed);
                        match Connection::connect(&env, &target) {
                            Ok(eph) => {
                                match eph.call_scalar::<u64>(1, &base, CallOpts::new()) {
                                    Ok(r) => {
                                        completed.fetch_add(1, Ordering::Relaxed);
                                        if r != base.wrapping_mul(3).wrapping_add(1) {
                                            fail(&format!("churn call cross-wired at {base}"));
                                            return;
                                        }
                                    }
                                    ref e if sc.early_stop && teardown_ok(e) => return,
                                    Err(e) => {
                                        fail(&format!("churn call failed: {e:?}"));
                                        return;
                                    }
                                }
                            }
                            ref e if sc.early_stop && teardown_ok(e) => return,
                            Err(e) => {
                                fail(&format!("churn connect failed: {e:?}"));
                                return;
                            }
                        }
                    } else if mode < sc.churn_pct + sc.batch_pct {
                        // Batches keep multiple slots in flight while
                        // the elastic window may be resizing.
                        let n = 2 + rng.next_below(4);
                        let vals: Vec<u64> = (0..n).map(|j| base + j).collect();
                        issued.fetch_add(n, Ordering::Relaxed);
                        match conn.call_scalar_batch::<u64>(1, &vals, CallOpts::new()) {
                            Ok(rets) => {
                                completed.fetch_add(n, Ordering::Relaxed);
                                for (v, r) in vals.iter().zip(&rets) {
                                    if *r != v.wrapping_mul(3).wrapping_add(1) {
                                        fail(&format!("batch cross-wired at {v}"));
                                        return;
                                    }
                                }
                            }
                            ref e if sc.early_stop && teardown_ok(e) => return,
                            Err(e) => {
                                fail(&format!("batch failed: {e:?}"));
                                return;
                            }
                        }
                    } else {
                        issued.fetch_add(1, Ordering::Relaxed);
                        match conn.call_scalar::<u64>(1, &base, CallOpts::new()) {
                            Ok(r) => {
                                completed.fetch_add(1, Ordering::Relaxed);
                                if r != base.wrapping_mul(3).wrapping_add(1) {
                                    fail(&format!("sync cross-wired at {base}"));
                                    return;
                                }
                            }
                            ref e if sc.early_stop && teardown_ok(e) => return,
                            Err(e) => {
                                fail(&format!("sync call failed: {e:?}"));
                                return;
                            }
                        }
                    }
                    // The elastic window must stay a sane power of two
                    // (pinned to capacity when elastic is off).
                    let active = conn.shared.active_shard_count();
                    if !active.is_power_of_two() || active > nshards {
                        fail(&format!("elastic window insane: {active}/{nshards}"));
                        return;
                    }
                    if !sc.elastic && active != nshards {
                        fail(&format!("fixed window drifted: {active}/{nshards}"));
                        return;
                    }
                    for _ in 0..rng.next_below(64) {
                        std::hint::spin_loop();
                    }
                }
            });
        }));
    }

    if sc.early_stop {
        std::thread::sleep(Duration::from_micros(200 + (sc.salt % 3_000)));
        for s in &servers {
            s.stop();
        }
    }

    let deadline = Instant::now() + Duration::from_secs(60);
    for c in clients {
        if Instant::now() > deadline {
            eprintln!("churn-stress: watchdog tripped — a client is wedged");
            return false;
        }
        c.join().unwrap();
    }
    if !sc.early_stop {
        for s in &servers {
            s.stop();
        }
    }
    if failed.load(Ordering::Relaxed) {
        return false;
    }
    if !sc.early_stop {
        let (i, c) = (issued.load(Ordering::Relaxed), completed.load(Ordering::Relaxed));
        if i != c {
            eprintln!("churn-stress: {c}/{i} calls completed without teardown");
            return false;
        }
        let served: u64 = servers.iter().map(|s| s.served()).sum();
        if served != i {
            eprintln!("churn-stress: served {served} != issued {i}");
            return false;
        }
    }
    true
}

/// The main randomized sweep: channel counts, worker counts, shard
/// widths, churn/batch mixes, elastic on/off, and teardown all drawn
/// from the seed.
#[test]
fn stress_pooled_churn_schedules() {
    forall("conn-churn", prop_seed(), 12, &ChurnScenarioGen, run_churn_scenario);
}

/// Open/close storms concentrated: every op is a churn op, many
/// channels on few workers — adoption, retirement, and slot recycling
/// through the waiter tree at maximum rate, swept over the worker
/// count.
#[test]
fn stress_open_close_storm_on_pool() {
    forall("conn-churn-storm", prop_seed(), 8, &U64Range(1, 5), |&w| {
        run_churn_scenario(&ChurnScenario {
            channels: 6,
            workers: w,
            shards_pow: 0,
            clients: 4,
            ops: 16,
            churn_pct: 100,
            batch_pct: 0,
            elastic: false,
            early_stop: false,
            salt: prop_seed() ^ w.wrapping_mul(0xB5AD_4ECE_DA1C_E2A9),
        })
    });
}

/// Elastic resizing concentrated: one channel, wide shard capacity,
/// tiny rings, batch-heavy clients — the claim-fail pressure that
/// grows the window and the quiescence that shrinks it, racing
/// in-flight batches, swept over the client count.
#[test]
fn stress_elastic_resize_under_batches() {
    forall("conn-churn-elastic", prop_seed(), 8, &U64Range(1, 5), |&n| {
        run_churn_scenario(&ChurnScenario {
            channels: 1,
            workers: 2,
            shards_pow: 2,
            clients: n,
            ops: 24,
            churn_pct: 0,
            batch_pct: 60,
            elastic: true,
            early_stop: false,
            salt: prop_seed() ^ n.wrapping_mul(0x2545_F491_4F6C_DD1D),
        })
    });
}

/// Connection churn under injected crashes: every iteration arms a
/// *fresh* seeded [`FaultPlan`] from `PROP_SEED` (different salt per
/// iteration, so a CI seed sweep varies both the kill point's depth
/// and which iteration it lands in) against a fresh victim
/// connection, while a survivor keeps calling on the same pooled
/// channel. After each sweep the books must balance — kills ==
/// recoveries — and the survivor must still be served.
#[test]
fn stress_churn_with_seeded_fault_per_iteration() {
    struct Disarm;
    impl Drop for Disarm {
        fn drop(&mut self) {
            fault::disarm();
        }
    }
    let _d = Disarm;
    let rack = Rack::for_tests();
    let env = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_shards(1)
        .ring_slots(8)
        .pool_workers(2)
        .call_timeout(Duration::from_secs(5))
        .open(&env, "churn-fault")
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let orch = Arc::clone(&rack.orch);
    let f = orch.fault_counters();
    let surv_env = rack.proc_env(1);
    let surv = Connection::connect(&surv_env, "churn-fault").unwrap();

    // Survivors renew throughout (the sweep below enforces lease
    // expiry rack-wide); only each iteration's victim lapses.
    let stop = Arc::new(AtomicBool::new(false));
    let renew = {
        let stop = Arc::clone(&stop);
        let daemon = Arc::clone(server.core().daemon());
        let procs = vec![env.proc, surv_env.proc];
        std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                for p in &procs {
                    daemon.renew_all(*p);
                }
                std::thread::sleep(Duration::from_millis(10));
            }
        })
    };

    for i in 0..3u64 {
        let vic_env = rack.proc_env(1);
        let vic = Connection::connect(&vic_env, "churn-fault").unwrap();
        let point = [KillPoint::PreFlush, KillPoint::MidBatch][(i % 2) as usize];
        fault::arm_with_sink(
            FaultPlan::seeded(point, prop_seed() ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15), 3)
                .victim(vic_env.proc),
            Arc::downgrade(&orch.fault_counters()),
        );
        let victim = std::thread::spawn(move || {
            vic_env.run(|| {
                let vals: Vec<u64> = (0..64).collect();
                let r = vic.call_scalar_batch::<u64>(1, &vals, CallOpts::new());
                assert!(matches!(r, Err(RpcError::Killed(_))), "victim sees Killed: {r:?}");
                vic.crash();
            })
        });
        // Churn racing the crash: the survivor's calls must never be
        // cross-wired or lost while the victim dies next to them.
        for k in 0..8u64 {
            let r = surv_env.run(|| surv.call_scalar::<u64>(1, &k, CallOpts::new()));
            assert_eq!(r.unwrap(), k + 1, "survivor call during iteration {i}");
        }
        victim.join().unwrap();
        assert_eq!(f.get(FLT_KILLS), i + 1, "iteration {i}: fresh seeded plan fired");
        assert!(!fault::armed(), "iteration {i}: injector auto-disarmed");

        std::thread::sleep(Duration::from_millis(rack.cfg.lease_ttl_ms + 30));
        orch.tick();
        assert_eq!(f.get(FLT_RECOVERIES), i + 1, "iteration {i}: kills == recoveries");
        let r = surv_env.run(|| surv.call_scalar::<u64>(1, &99, CallOpts::new()));
        assert_eq!(r.unwrap(), 100, "survivor serves after sweep {i}");
    }

    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    drop(surv);
    server.stop();
}

// ---------------------------------------------------------------------
// admission-denied paths (deterministic, but swept over seeds for the
// connect ordering)

/// Reject: over the ceiling every connect fails with
/// `ConnectionRefused` and under it every connect succeeds — the
/// counts partition exactly.
#[test]
fn admission_reject_partitions_exactly() {
    let rack = Rack::for_tests();
    let env = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_shards(1)
        .ring_slots(8)
        .pool_workers(2)
        .admission(AdmissionPolicy::Reject)
        .conn_limit(3)
        .open(&env, "adm-reject")
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let cenv = rack.proc_env(1);
    let mut held = Vec::new();
    let mut refused = 0usize;
    for k in 0..8u64 {
        match Connection::connect(&cenv, "adm-reject") {
            Ok(conn) => {
                let r = conn.call_scalar::<u64>(1, &k, CallOpts::new()).unwrap();
                assert_eq!(r, k + 1);
                held.push(conn);
            }
            Err(RpcError::ConnectionRefused(name, why)) => {
                assert_eq!(name, "adm-reject");
                assert!(why.contains("admission"), "refusal must name the policy: {why}");
                refused += 1;
            }
            Err(e) => panic!("unexpected admission error: {e:?}"),
        }
    }
    assert_eq!(held.len(), 3, "exactly conn_limit connects admitted");
    assert_eq!(refused, 5, "everything over the ceiling refused");
    // Capacity freed by a close is immediately reusable.
    drop(held.pop());
    let again = Connection::connect(&cenv, "adm-reject").expect("freed capacity readmits");
    let r = again.call_scalar::<u64>(1, &99, CallOpts::new()).unwrap();
    assert_eq!(r, 100);
    server.stop();
}

/// Shed: over the ceiling connects still succeed but are marked
/// shed-class (served at degraded drain budget) — and they serve.
#[test]
fn admission_shed_degrades_but_serves() {
    let rack = Rack::for_tests();
    let env = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_shards(1)
        .ring_slots(8)
        .pool_workers(2)
        .admission(AdmissionPolicy::Shed)
        .conn_limit(2)
        .open(&env, "adm-shed")
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let cenv = rack.proc_env(1);
    let conns: Vec<Connection> =
        (0..5).map(|_| Connection::connect(&cenv, "adm-shed").unwrap()).collect();
    let shed: Vec<bool> = conns.iter().map(|c| c.shared.is_shed()).collect();
    assert_eq!(shed.iter().filter(|s| !**s).count(), 2, "under the ceiling: full-class");
    assert_eq!(shed.iter().filter(|s| **s).count(), 3, "over the ceiling: shed-class");
    for (k, conn) in conns.iter().enumerate() {
        let r = conn.call_scalar::<u64>(1, &(k as u64), CallOpts::new()).unwrap();
        assert_eq!(r, k as u64 + 1, "shed-class connections still serve");
    }
    server.stop();
}

/// Queue: a connect over the ceiling parks until capacity frees (a
/// racing close readmits it) or times out with `Timeout` — never an
/// instant refusal, never a hang past the admission deadline.
#[test]
fn admission_queue_waits_for_capacity() {
    let rack = Rack::for_tests();
    let env = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_shards(1)
        .ring_slots(8)
        .pool_workers(2)
        .admission(AdmissionPolicy::Queue)
        .conn_limit(1)
        .open(&env, "adm-queue")
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let cenv = rack.proc_env(1);
    let first = Connection::connect(&cenv, "adm-queue").unwrap();

    // A racing close frees the slot: the queued connect must land.
    let dropper = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(50));
        drop(first);
    });
    let t0 = Instant::now();
    let second = Connection::connect(&cenv, "adm-queue").expect("queued connect readmitted");
    assert!(t0.elapsed() >= Duration::from_millis(30), "connect should have queued");
    dropper.join().unwrap();
    let r = second.call_scalar::<u64>(1, &7, CallOpts::new()).unwrap();
    assert_eq!(r, 8);

    // Nothing frees: the queued connect times out at the admission
    // deadline instead of hanging.
    let t0 = Instant::now();
    match Connection::connect(&cenv, "adm-queue") {
        Err(RpcError::Timeout(what)) => {
            assert!(what.contains("admission"), "timeout must name admission: {what}");
        }
        other => panic!("expected admission timeout, got {other:?}"),
    }
    assert!(t0.elapsed() >= Duration::from_millis(400), "must wait out the admission window");
    assert!(t0.elapsed() < Duration::from_secs(5), "must not hang past the deadline");
    server.stop();
}
