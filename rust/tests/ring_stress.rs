//! Seeded stress/property suite for the lock-free MPMC slot ring —
//! the most delicate concurrency code in the repo (claim/publish/
//! take_request/respond/consume plus the abandon-tombstone protocol).
//!
//! Every test draws randomized multi-threaded schedules from
//! `util::prop::forall`, seeded by the `PROP_SEED` env var (CI sweeps
//! four seeds in debug and release). A failure prints the seed and
//! the shrunk scenario; rerunning with `PROP_SEED=<seed>` replays the
//! same generated schedules. (Thread interleavings themselves are the
//! OS's — the seed pins every *generated* parameter: ring size,
//! thread counts, call counts, abandon rates, and the jitter streams
//! both sides draw from.)
//!
//! Invariants checked on every scenario:
//!
//! * every consumed response carries exactly its caller's value — no
//!   lost, duplicated, or cross-wired responses across laps;
//! * every abandoned lap is retired exactly once (the client's
//!   `abandon` and the server's `respond` split them perfectly);
//! * the ring ends quiescent with `claimed == taken == total`;
//! * nothing wedges — a watchdog deadline fails the property instead
//!   of hanging the suite.

use rpcool::channel::ring::{RpcRing, NO_SEAL, ST_OK};
use rpcool::channel::waiter::SleepPolicy;
use rpcool::channel::{CallOpts, ChannelBuilder, Connection};
use rpcool::error::RpcError;
use rpcool::memory::pool::Pool;
use rpcool::memory::Heap;
use rpcool::rack::Rack;
use rpcool::util::prop::{forall, Gen, U64Range};
use rpcool::util::rng::Rng;
use rpcool::SimConfig;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Seed source: `PROP_SEED` env var (CI matrix), fixed default.
fn prop_seed() -> u64 {
    std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x5EED)
}

/// One randomized schedule over the ring protocol.
#[derive(Clone, Debug)]
struct Scenario {
    /// Ring size = 1 << ring_pow (4..=16 slots).
    ring_pow: u32,
    clients: u64,
    /// Calls per client.
    calls: u64,
    /// Percent of calls the caller abandons instead of consuming.
    abandon_pct: u64,
    /// Max server-side spin jitter before responding.
    sjit: u64,
    /// Max client-side spin jitter (pre-abandon / between calls).
    cjit: u64,
    /// Salt for the per-run jitter streams.
    salt: u64,
}

struct ScenarioGen;

impl Gen for ScenarioGen {
    type Value = Scenario;
    fn generate(&self, rng: &mut Rng) -> Scenario {
        Scenario {
            ring_pow: rng.range(2, 5) as u32,
            clients: rng.range(1, 5),
            calls: rng.range(8, 81),
            abandon_pct: rng.range(0, 41),
            sjit: rng.range(0, 65),
            cjit: rng.range(0, 65),
            salt: rng.next_u64(),
        }
    }
    fn shrink(&self, v: &Scenario) -> Vec<Scenario> {
        let mut out = Vec::new();
        if v.calls > 8 {
            out.push(Scenario { calls: v.calls / 2, ..v.clone() });
        }
        if v.clients > 1 {
            out.push(Scenario { clients: v.clients - 1, ..v.clone() });
        }
        if v.abandon_pct > 0 {
            out.push(Scenario { abandon_pct: 0, ..v.clone() });
        }
        out
    }
}

/// Run one scenario; `true` iff every invariant held.
fn run_scenario(sc: &Scenario) -> bool {
    let cfg = SimConfig::for_tests();
    let pool = Pool::new(&cfg).unwrap();
    let heap = Heap::new(&pool, "stress", 1 << 20).unwrap();
    let ring = Arc::new(RpcRing::create(&heap, 1usize << sc.ring_pow).unwrap());
    let total = sc.clients * sc.calls;
    let deadline = Instant::now() + Duration::from_secs(20);
    let failed = Arc::new(AtomicBool::new(false));
    let server_discards = Arc::new(AtomicU64::new(0));
    let client_discards = Arc::new(AtomicU64::new(0));
    let abandons = Arc::new(AtomicU64::new(0));

    // Server: serve exactly `total` requests (abandoned calls are
    // still published, so they are still served), echoing a value
    // derived from the request so cross-wiring is detectable.
    let srv = {
        let ring = Arc::clone(&ring);
        let failed = Arc::clone(&failed);
        let discards = Arc::clone(&server_discards);
        let sjit = sc.sjit;
        let salt = sc.salt;
        std::thread::spawn(move || {
            let mut rng = Rng::new(salt ^ 0x5EC0_5EC0);
            let mut served = 0u64;
            while served < total {
                if Instant::now() > deadline {
                    eprintln!("stress: server wedged at {served}/{total}");
                    failed.store(true, Ordering::Relaxed);
                    return;
                }
                if let Some(i) = ring.take_request() {
                    let f = ring.slot(i).func.load(Ordering::Relaxed);
                    for _ in 0..rng.next_below(sjit + 1) {
                        std::hint::spin_loop();
                    }
                    if ring.respond(i, ST_OK, f as u64 * 7 + 1) {
                        discards.fetch_add(1, Ordering::Relaxed);
                    }
                    served += 1;
                } else {
                    std::hint::spin_loop();
                }
            }
        })
    };

    let mut clients = Vec::new();
    for tid in 0..sc.clients {
        let ring = Arc::clone(&ring);
        let failed = Arc::clone(&failed);
        let discards = Arc::clone(&client_discards);
        let abandons = Arc::clone(&abandons);
        let sc = sc.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(sc.salt ^ tid.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            for k in 0..sc.calls {
                let func = (tid * sc.calls + k) as u32; // globally unique
                let want = func as u64 * 7 + 1;
                let i = loop {
                    if Instant::now() > deadline {
                        eprintln!("stress: client {tid} wedged claiming at call {k}");
                        failed.store(true, Ordering::Relaxed);
                        return;
                    }
                    if let Some(i) = ring.claim() {
                        break i;
                    }
                    std::hint::spin_loop();
                };
                ring.publish(i, func, 0, NO_SEAL, 0, 0);
                if rng.next_below(100) < sc.abandon_pct {
                    // Timed-out caller: tombstone the slot at a random
                    // point in the request's lifetime and move on.
                    abandons.fetch_add(1, Ordering::Relaxed);
                    for _ in 0..rng.next_below(sc.cjit + 1) {
                        std::hint::spin_loop();
                    }
                    if let Some((st, ret)) = ring.abandon(i) {
                        // The response had landed: it must be OURS.
                        if st != ST_OK || ret != want {
                            eprintln!(
                                "stress: client {tid} call {k}: abandoned response cross-wired \
                                 (st {st}, ret {ret}, want {want})"
                            );
                            failed.store(true, Ordering::Relaxed);
                            return;
                        }
                        discards.fetch_add(1, Ordering::Relaxed);
                    }
                } else {
                    while !ring.response_ready(i) {
                        if Instant::now() > deadline {
                            eprintln!("stress: client {tid} wedged waiting at call {k}");
                            failed.store(true, Ordering::Relaxed);
                            return;
                        }
                        std::hint::spin_loop();
                    }
                    let (st, ret) = ring.consume(i);
                    if st != ST_OK || ret != want {
                        eprintln!(
                            "stress: client {tid} call {k}: response cross-wired \
                             (st {st}, ret {ret}, want {want})"
                        );
                        failed.store(true, Ordering::Relaxed);
                        return;
                    }
                }
                for _ in 0..rng.next_below(sc.cjit + 1) {
                    std::hint::spin_loop();
                }
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    srv.join().unwrap();
    if failed.load(Ordering::Relaxed) {
        return false;
    }
    // Exactly-once retirement of abandoned laps: whoever lost the
    // tombstone swap did nothing, whoever won retired — so the two
    // discard counters must partition the abandons.
    let sd = server_discards.load(Ordering::Relaxed);
    let cd = client_discards.load(Ordering::Relaxed);
    let ab = abandons.load(Ordering::Relaxed);
    if sd + cd != ab {
        eprintln!("stress: abandon accounting broke: server {sd} + client {cd} != {ab}");
        return false;
    }
    if !ring.quiescent() {
        eprintln!("stress: ring not quiescent after all laps");
        return false;
    }
    if ring.claimed() != total || ring.taken() != total {
        eprintln!(
            "stress: cursors disagree: claimed {} taken {} total {total}",
            ring.claimed(),
            ring.taken()
        );
        return false;
    }
    true
}

/// The main randomized sweep: ring sizes, client counts, abandon
/// rates, and jitter all drawn from the seed.
#[test]
fn stress_randomized_schedules() {
    forall("ring-stress", prop_seed(), 32, &ScenarioGen, run_scenario);
}

/// Abandon-vs-respond races, concentrated: every call is abandoned at
/// a jittered instant while the server races to respond. Either side
/// may win the tombstone swap; the lap must retire exactly once.
/// (This is the schedule that catches a reintroduced abandon-race bug
/// — e.g. `respond` ignoring the tombstone, or `abandon` retiring a
/// lap it lost — as a wedge or a cross-wired late response.)
#[test]
fn stress_abandon_vs_respond_race() {
    forall("ring-abandon-race", prop_seed(), 24, &U64Range(0, 96), |&jit| {
        run_scenario(&Scenario {
            ring_pow: 2,
            clients: 2,
            calls: 96,
            abandon_pct: 100,
            sjit: jit,
            cjit: jit,
            salt: prop_seed() ^ jit.wrapping_mul(0xB5AD_4ECE_DA1C_E2A9),
        })
    });
}

/// Full-ring wraparound + cross-lap ABA, concentrated: more clients
/// than slots on the smallest ring, every slot cycling many laps,
/// with a slice of abandons mixed in. A stale `take_request` stealing
/// a later lap's request (the ABA the lap guard exists for) shows up
/// here as a cross-wired response.
#[test]
fn stress_full_ring_wraparound_aba() {
    forall("ring-wraparound-aba", prop_seed(), 24, &U64Range(0, 64), |&jit| {
        run_scenario(&Scenario {
            ring_pow: 2,
            clients: 4,
            calls: 128,
            abandon_pct: 10,
            sjit: jit,
            cjit: jit / 2,
            salt: prop_seed() ^ jit.wrapping_mul(0x2545_F491_4F6C_DD1D),
        })
    });
}

// ---------------------------------------------------------------------
// connection-level schedules (ISSUE 4): drain-k serving +
// call_scalar_batch + typed/scalar async + multi-worker listeners,
// park-policy waiters against coalesced response epochs, randomized
// teardown.

/// One randomized connection-level schedule.
#[derive(Clone, Debug)]
struct ConnScenario {
    /// Shards = 1 << shards_pow (1..=4).
    shards_pow: u32,
    /// Slots per shard = 1 << slots_pow (4..=16).
    slots_pow: u32,
    /// Server drain budget per shard per sweep.
    drain_k: u64,
    /// Listener workers.
    workers: u64,
    clients: u64,
    /// Operations per client (an op may expand to a whole batch).
    ops: u64,
    /// Percent of ops that are batches (size 2..=6) / async pipelines
    /// (one scalar + one typed handle in flight); the rest are plain
    /// sync calls.
    batch_pct: u64,
    async_pct: u64,
    /// Load-aware striping on?
    two_choice: bool,
    /// Stop the server mid-run: every call must then finish with
    /// Ok/Timeout/ConnectionClosed — never a hang or a wrong value.
    early_stop: bool,
    salt: u64,
}

struct ConnScenarioGen;

impl Gen for ConnScenarioGen {
    type Value = ConnScenario;
    fn generate(&self, rng: &mut Rng) -> ConnScenario {
        ConnScenario {
            shards_pow: rng.range(0, 3) as u32,
            // ≥ 8 slots: with ≤ 4 clients each holding ≤ 1 unconsumed
            // async slot while blocked on a claim, demand can never
            // pin every slot of a shard (no self-induced claim
            // timeouts — see the async arm's depth bound).
            slots_pow: rng.range(3, 5) as u32,
            drain_k: rng.range(1, 33),
            workers: rng.range(1, 4),
            clients: rng.range(1, 5),
            ops: rng.range(6, 25),
            batch_pct: rng.range(0, 51),
            async_pct: rng.range(0, 41),
            two_choice: rng.next_below(2) == 1,
            early_stop: rng.next_below(4) == 0,
            salt: rng.next_u64(),
        }
    }
    fn shrink(&self, v: &ConnScenario) -> Vec<ConnScenario> {
        let mut out = Vec::new();
        if v.ops > 6 {
            out.push(ConnScenario { ops: v.ops / 2, ..v.clone() });
        }
        if v.clients > 1 {
            out.push(ConnScenario { clients: v.clients - 1, ..v.clone() });
        }
        if v.early_stop {
            out.push(ConnScenario { early_stop: false, ..v.clone() });
        }
        if v.batch_pct + v.async_pct > 0 {
            out.push(ConnScenario { batch_pct: 0, async_pct: 0, ..v.clone() });
        }
        out
    }
}

/// Channel names must be distinct across scenarios (the in-process
/// directory is global).
static CONN_STRESS_ID: AtomicUsize = AtomicUsize::new(0);

/// An acceptable outcome under teardown; anything else is a bug.
fn teardown_ok<T>(r: &Result<T, RpcError>) -> bool {
    matches!(r, Err(RpcError::Timeout(_)) | Err(RpcError::ConnectionClosed))
}

/// Run one connection-level scenario; `true` iff every invariant held.
fn run_conn_scenario(sc: &ConnScenario) -> bool {
    let name = format!("conn-stress-{}", CONN_STRESS_ID.fetch_add(1, Ordering::Relaxed));
    let rack = Rack::for_tests();
    let env = rack.proc_env(0);
    // Park policy on both sides: the schedule exercises exactly the
    // coalesced-epoch wakeups (drain-k flush covering many waiters)
    // the ISSUE 4 waiter-protocol argument is about. Short call
    // timeout so a genuinely lost wakeup fails the property fast
    // instead of hanging the suite.
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_shards(1 << sc.shards_pow)
        .ring_slots(1 << sc.slots_pow)
        .drain_k(sc.drain_k as usize)
        .two_choice(sc.two_choice)
        .sleep(SleepPolicy::Park)
        .call_timeout(Duration::from_secs(5))
        .open(&env, &name)
        .unwrap();
    // Func 1: scalar echo; func 2: typed (pointer-reply) echo.
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(v.wrapping_mul(3).wrapping_add(1)));
    server.serve::<u64, u64>(2, |_ctx, v| Ok(v.wrapping_mul(5).wrapping_add(2)));
    let listeners = server.spawn_listeners(sc.workers as usize);
    let cenv = rack.proc_env(1);
    let conn = Arc::new(Connection::connect(&cenv, &name).unwrap());

    let failed = Arc::new(AtomicBool::new(false));
    let issued = Arc::new(AtomicU64::new(0));
    let completed = Arc::new(AtomicU64::new(0));
    let mut clients = Vec::new();
    for tid in 0..sc.clients {
        let conn = Arc::clone(&conn);
        let env = cenv.clone();
        let failed = Arc::clone(&failed);
        let issued = Arc::clone(&issued);
        let completed = Arc::clone(&completed);
        let sc = sc.clone();
        clients.push(std::thread::spawn(move || {
            env.run(|| {
                let mut rng = Rng::new(sc.salt ^ tid.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let fail = |what: &str| {
                    eprintln!("conn-stress: client {tid}: {what}");
                    failed.store(true, Ordering::Relaxed);
                };
                for k in 0..sc.ops {
                    let base = tid * 1_000_000 + k * 100;
                    let mode = rng.next_below(100);
                    if mode < sc.batch_pct {
                        // Batched scalars: one publish doorbell, one
                        // drain-k sweep's worth of coalesced replies.
                        let n = 2 + rng.next_below(5);
                        let vals: Vec<u64> = (0..n).map(|j| base + j).collect();
                        issued.fetch_add(n, Ordering::Relaxed);
                        match conn.call_scalar_batch::<u64>(1, &vals, CallOpts::new()) {
                            Ok(rets) => {
                                completed.fetch_add(n, Ordering::Relaxed);
                                for (v, r) in vals.iter().zip(&rets) {
                                    if *r != v.wrapping_mul(3).wrapping_add(1) {
                                        fail(&format!("batch cross-wired at {v}"));
                                        return;
                                    }
                                }
                            }
                            Err(_) if sc.early_stop => return,
                            Err(e) => {
                                fail(&format!("batch failed: {e:?}"));
                                return;
                            }
                        }
                    } else if mode < sc.batch_pct + sc.async_pct {
                        // Async pipeline: one scalar + one typed
                        // handle in flight together, completed in
                        // order. Depth stays at 2 so a client blocked
                        // claiming its second slot holds at most one
                        // unconsumed ready slot — bounded demand,
                        // progress always possible (deeper pipelines
                        // across clients can legitimately deadlock a
                        // small ring until the call timeout, which is
                        // back-pressure, not a bug, but would make
                        // this property flaky).
                        let depth = 2u64;
                        // After teardown, pending handles are still
                        // drained (their waits must terminate, that IS
                        // the property) but the client then stops —
                        // otherwise every remaining op would eat a
                        // full call timeout and trip the watchdog.
                        let mut torn = false;
                        let mut scalars = Vec::new();
                        let mut typeds = Vec::new();
                        for j in 0..depth {
                            issued.fetch_add(1, Ordering::Relaxed);
                            if j % 2 == 0 {
                                match conn.call_scalar_async(1, &(base + j), CallOpts::new()) {
                                    Ok(h) => scalars.push((base + j, h)),
                                    Err(_) if sc.early_stop => {
                                        torn = true;
                                        break;
                                    }
                                    Err(e) => {
                                        fail(&format!("async submit failed: {e:?}"));
                                        return;
                                    }
                                }
                            } else {
                                match conn.call_typed_async::<u64, u64>(
                                    2,
                                    &(base + j),
                                    CallOpts::new(),
                                ) {
                                    Ok(h) => typeds.push((base + j, h)),
                                    Err(_) if sc.early_stop => {
                                        torn = true;
                                        break;
                                    }
                                    Err(e) => {
                                        fail(&format!("typed submit failed: {e:?}"));
                                        return;
                                    }
                                }
                            }
                        }
                        for (v, h) in scalars {
                            let r = h.wait();
                            match r {
                                Ok(got) => {
                                    completed.fetch_add(1, Ordering::Relaxed);
                                    if got != v.wrapping_mul(3).wrapping_add(1) {
                                        fail(&format!("async cross-wired at {v}"));
                                        return;
                                    }
                                }
                                ref e if sc.early_stop && teardown_ok(e) => torn = true,
                                Err(e) => {
                                    fail(&format!("async wait failed: {e:?}"));
                                    return;
                                }
                            }
                        }
                        for (v, h) in typeds {
                            match h.wait() {
                                Ok(reply) => {
                                    completed.fetch_add(1, Ordering::Relaxed);
                                    match reply.take() {
                                        Ok(got) if got == v.wrapping_mul(5).wrapping_add(2) => {}
                                        other => {
                                            fail(&format!("typed reply wrong at {v}: {other:?}"));
                                            return;
                                        }
                                    }
                                }
                                ref e if sc.early_stop && teardown_ok(e) => torn = true,
                                Err(e) => {
                                    fail(&format!("typed wait failed: {e:?}"));
                                    return;
                                }
                            }
                        }
                        if torn {
                            return;
                        }
                    } else {
                        issued.fetch_add(1, Ordering::Relaxed);
                        match conn.call_scalar::<u64>(1, &base, CallOpts::new()) {
                            Ok(r) => {
                                completed.fetch_add(1, Ordering::Relaxed);
                                if r != base.wrapping_mul(3).wrapping_add(1) {
                                    fail(&format!("sync cross-wired at {base}"));
                                    return;
                                }
                            }
                            Err(_) if sc.early_stop => return,
                            Err(e) => {
                                fail(&format!("sync call failed: {e:?}"));
                                return;
                            }
                        }
                    }
                    for _ in 0..rng.next_below(64) {
                        std::hint::spin_loop();
                    }
                }
            });
        }));
    }

    if sc.early_stop {
        // Randomized teardown: stop the channel while clients are
        // mid-flight. Everything must still terminate (bounded by the
        // call timeout) with an acceptable outcome.
        std::thread::sleep(Duration::from_micros(200 + (sc.salt % 3_000)));
        server.stop();
    }

    let deadline = Instant::now() + Duration::from_secs(60);
    for c in clients {
        if Instant::now() > deadline {
            eprintln!("conn-stress: watchdog tripped — a client is wedged");
            return false;
        }
        c.join().unwrap();
    }
    if !sc.early_stop {
        server.stop();
    }
    for l in listeners {
        l.join().unwrap();
    }
    if failed.load(Ordering::Relaxed) {
        return false;
    }
    if !sc.early_stop {
        let (i, c) = (issued.load(Ordering::Relaxed), completed.load(Ordering::Relaxed));
        if i != c {
            eprintln!("conn-stress: {c}/{i} calls completed without teardown");
            return false;
        }
        if server.served() != i {
            eprintln!("conn-stress: served {} != issued {i}", server.served());
            return false;
        }
        if !conn.shared.quiescent() {
            eprintln!("conn-stress: shards not quiescent after clean run");
            return false;
        }
    }
    true
}

/// The connection-level randomized sweep: shard counts, drain
/// budgets, worker counts, op mixes, striping modes, and teardown all
/// drawn from the seed. Asserts no lost wakeups (Park waiters against
/// coalesced response epochs; a loss surfaces as a timeout/watchdog),
/// no cross-wired or lost responses, and full-accounting quiescence
/// on clean runs.
#[test]
fn stress_connection_level_schedules() {
    forall("conn-stress", prop_seed(), 12, &ConnScenarioGen, run_conn_scenario);
}

/// Drain-k reply coalescing, concentrated: one worker, deep batches,
/// many clients on few shards — the configuration where one
/// flush_respond covers the most waiters at once, swept over the
/// drain budget (including drain_k=1, the per-reply degenerate case).
#[test]
fn stress_drain_k_coalescing_under_batches() {
    forall("conn-drain-k", prop_seed(), 8, &U64Range(1, 33), |&k| {
        run_conn_scenario(&ConnScenario {
            shards_pow: 1,
            slots_pow: 4,
            drain_k: k,
            workers: 1,
            clients: 3,
            ops: 12,
            batch_pct: 70,
            async_pct: 20,
            two_choice: true,
            early_stop: false,
            salt: prop_seed() ^ k.wrapping_mul(0xB5AD_4ECE_DA1C_E2A9),
        })
    });
}
