//! Seeded stress/property suite for the lock-free MPMC slot ring —
//! the most delicate concurrency code in the repo (claim/publish/
//! take_request/respond/consume plus the abandon-tombstone protocol).
//!
//! Every test draws randomized multi-threaded schedules from
//! `util::prop::forall`, seeded by the `PROP_SEED` env var (CI sweeps
//! four seeds in debug and release). A failure prints the seed and
//! the shrunk scenario; rerunning with `PROP_SEED=<seed>` replays the
//! same generated schedules. (Thread interleavings themselves are the
//! OS's — the seed pins every *generated* parameter: ring size,
//! thread counts, call counts, abandon rates, and the jitter streams
//! both sides draw from.)
//!
//! Invariants checked on every scenario:
//!
//! * every consumed response carries exactly its caller's value — no
//!   lost, duplicated, or cross-wired responses across laps;
//! * every abandoned lap is retired exactly once (the client's
//!   `abandon` and the server's `respond` split them perfectly);
//! * the ring ends quiescent with `claimed == taken == total`;
//! * nothing wedges — a watchdog deadline fails the property instead
//!   of hanging the suite.

use rpcool::channel::ring::{RpcRing, NO_SEAL, ST_OK};
use rpcool::memory::pool::Pool;
use rpcool::memory::Heap;
use rpcool::util::prop::{forall, Gen, U64Range};
use rpcool::util::rng::Rng;
use rpcool::SimConfig;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Seed source: `PROP_SEED` env var (CI matrix), fixed default.
fn prop_seed() -> u64 {
    std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x5EED)
}

/// One randomized schedule over the ring protocol.
#[derive(Clone, Debug)]
struct Scenario {
    /// Ring size = 1 << ring_pow (4..=16 slots).
    ring_pow: u32,
    clients: u64,
    /// Calls per client.
    calls: u64,
    /// Percent of calls the caller abandons instead of consuming.
    abandon_pct: u64,
    /// Max server-side spin jitter before responding.
    sjit: u64,
    /// Max client-side spin jitter (pre-abandon / between calls).
    cjit: u64,
    /// Salt for the per-run jitter streams.
    salt: u64,
}

struct ScenarioGen;

impl Gen for ScenarioGen {
    type Value = Scenario;
    fn generate(&self, rng: &mut Rng) -> Scenario {
        Scenario {
            ring_pow: rng.range(2, 5) as u32,
            clients: rng.range(1, 5),
            calls: rng.range(8, 81),
            abandon_pct: rng.range(0, 41),
            sjit: rng.range(0, 65),
            cjit: rng.range(0, 65),
            salt: rng.next_u64(),
        }
    }
    fn shrink(&self, v: &Scenario) -> Vec<Scenario> {
        let mut out = Vec::new();
        if v.calls > 8 {
            out.push(Scenario { calls: v.calls / 2, ..v.clone() });
        }
        if v.clients > 1 {
            out.push(Scenario { clients: v.clients - 1, ..v.clone() });
        }
        if v.abandon_pct > 0 {
            out.push(Scenario { abandon_pct: 0, ..v.clone() });
        }
        out
    }
}

/// Run one scenario; `true` iff every invariant held.
fn run_scenario(sc: &Scenario) -> bool {
    let cfg = SimConfig::for_tests();
    let pool = Pool::new(&cfg).unwrap();
    let heap = Heap::new(&pool, "stress", 1 << 20).unwrap();
    let ring = Arc::new(RpcRing::create(&heap, 1usize << sc.ring_pow).unwrap());
    let total = sc.clients * sc.calls;
    let deadline = Instant::now() + Duration::from_secs(20);
    let failed = Arc::new(AtomicBool::new(false));
    let server_discards = Arc::new(AtomicU64::new(0));
    let client_discards = Arc::new(AtomicU64::new(0));
    let abandons = Arc::new(AtomicU64::new(0));

    // Server: serve exactly `total` requests (abandoned calls are
    // still published, so they are still served), echoing a value
    // derived from the request so cross-wiring is detectable.
    let srv = {
        let ring = Arc::clone(&ring);
        let failed = Arc::clone(&failed);
        let discards = Arc::clone(&server_discards);
        let sjit = sc.sjit;
        let salt = sc.salt;
        std::thread::spawn(move || {
            let mut rng = Rng::new(salt ^ 0x5EC0_5EC0);
            let mut served = 0u64;
            while served < total {
                if Instant::now() > deadline {
                    eprintln!("stress: server wedged at {served}/{total}");
                    failed.store(true, Ordering::Relaxed);
                    return;
                }
                if let Some(i) = ring.take_request() {
                    let f = ring.slot(i).func.load(Ordering::Relaxed);
                    for _ in 0..rng.next_below(sjit + 1) {
                        std::hint::spin_loop();
                    }
                    if ring.respond(i, ST_OK, f as u64 * 7 + 1) {
                        discards.fetch_add(1, Ordering::Relaxed);
                    }
                    served += 1;
                } else {
                    std::hint::spin_loop();
                }
            }
        })
    };

    let mut clients = Vec::new();
    for tid in 0..sc.clients {
        let ring = Arc::clone(&ring);
        let failed = Arc::clone(&failed);
        let discards = Arc::clone(&client_discards);
        let abandons = Arc::clone(&abandons);
        let sc = sc.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(sc.salt ^ tid.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            for k in 0..sc.calls {
                let func = (tid * sc.calls + k) as u32; // globally unique
                let want = func as u64 * 7 + 1;
                let i = loop {
                    if Instant::now() > deadline {
                        eprintln!("stress: client {tid} wedged claiming at call {k}");
                        failed.store(true, Ordering::Relaxed);
                        return;
                    }
                    if let Some(i) = ring.claim() {
                        break i;
                    }
                    std::hint::spin_loop();
                };
                ring.publish(i, func, 0, NO_SEAL, 0, 0);
                if rng.next_below(100) < sc.abandon_pct {
                    // Timed-out caller: tombstone the slot at a random
                    // point in the request's lifetime and move on.
                    abandons.fetch_add(1, Ordering::Relaxed);
                    for _ in 0..rng.next_below(sc.cjit + 1) {
                        std::hint::spin_loop();
                    }
                    if let Some((st, ret)) = ring.abandon(i) {
                        // The response had landed: it must be OURS.
                        if st != ST_OK || ret != want {
                            eprintln!(
                                "stress: client {tid} call {k}: abandoned response cross-wired \
                                 (st {st}, ret {ret}, want {want})"
                            );
                            failed.store(true, Ordering::Relaxed);
                            return;
                        }
                        discards.fetch_add(1, Ordering::Relaxed);
                    }
                } else {
                    while !ring.response_ready(i) {
                        if Instant::now() > deadline {
                            eprintln!("stress: client {tid} wedged waiting at call {k}");
                            failed.store(true, Ordering::Relaxed);
                            return;
                        }
                        std::hint::spin_loop();
                    }
                    let (st, ret) = ring.consume(i);
                    if st != ST_OK || ret != want {
                        eprintln!(
                            "stress: client {tid} call {k}: response cross-wired \
                             (st {st}, ret {ret}, want {want})"
                        );
                        failed.store(true, Ordering::Relaxed);
                        return;
                    }
                }
                for _ in 0..rng.next_below(sc.cjit + 1) {
                    std::hint::spin_loop();
                }
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    srv.join().unwrap();
    if failed.load(Ordering::Relaxed) {
        return false;
    }
    // Exactly-once retirement of abandoned laps: whoever lost the
    // tombstone swap did nothing, whoever won retired — so the two
    // discard counters must partition the abandons.
    let sd = server_discards.load(Ordering::Relaxed);
    let cd = client_discards.load(Ordering::Relaxed);
    let ab = abandons.load(Ordering::Relaxed);
    if sd + cd != ab {
        eprintln!("stress: abandon accounting broke: server {sd} + client {cd} != {ab}");
        return false;
    }
    if !ring.quiescent() {
        eprintln!("stress: ring not quiescent after all laps");
        return false;
    }
    if ring.claimed() != total || ring.taken() != total {
        eprintln!(
            "stress: cursors disagree: claimed {} taken {} total {total}",
            ring.claimed(),
            ring.taken()
        );
        return false;
    }
    true
}

/// The main randomized sweep: ring sizes, client counts, abandon
/// rates, and jitter all drawn from the seed.
#[test]
fn stress_randomized_schedules() {
    forall("ring-stress", prop_seed(), 32, &ScenarioGen, run_scenario);
}

/// Abandon-vs-respond races, concentrated: every call is abandoned at
/// a jittered instant while the server races to respond. Either side
/// may win the tombstone swap; the lap must retire exactly once.
/// (This is the schedule that catches a reintroduced abandon-race bug
/// — e.g. `respond` ignoring the tombstone, or `abandon` retiring a
/// lap it lost — as a wedge or a cross-wired late response.)
#[test]
fn stress_abandon_vs_respond_race() {
    forall("ring-abandon-race", prop_seed(), 24, &U64Range(0, 96), |&jit| {
        run_scenario(&Scenario {
            ring_pow: 2,
            clients: 2,
            calls: 96,
            abandon_pct: 100,
            sjit: jit,
            cjit: jit,
            salt: prop_seed() ^ jit.wrapping_mul(0xB5AD_4ECE_DA1C_E2A9),
        })
    });
}

/// Full-ring wraparound + cross-lap ABA, concentrated: more clients
/// than slots on the smallest ring, every slot cycling many laps,
/// with a slice of abandons mixed in. A stale `take_request` stealing
/// a later lap's request (the ABA the lap guard exists for) shows up
/// here as a cross-wired response.
#[test]
fn stress_full_ring_wraparound_aba() {
    forall("ring-wraparound-aba", prop_seed(), 24, &U64Range(0, 64), |&jit| {
        run_scenario(&Scenario {
            ring_pow: 2,
            clients: 4,
            calls: 128,
            abandon_pct: 10,
            sjit: jit,
            cjit: jit / 2,
            salt: prop_seed() ^ jit.wrapping_mul(0x2545_F491_4F6C_DD1D),
        })
    });
}
