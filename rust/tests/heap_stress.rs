//! Seeded stress/property suite for the memory plane — the
//! thread-cached allocator, the page-granular seal index, and the
//! lock-free scope pool introduced by the memory-plane overhaul
//! (DESIGN.md §10).
//!
//! Like `ring_stress.rs`, every test draws randomized schedules from
//! `util::prop::forall`, seeded by the `PROP_SEED` env var (CI sweeps
//! four seeds in debug and release); a failure prints the seed and the
//! shrunk scenario for exact replay of every *generated* parameter.
//!
//! Invariants:
//!
//! * concurrent `alloc_bytes`/`free_bytes` never hand out overlapping
//!   ranges (payload tags survive randomized hold windows), and the
//!   books balance exactly — `live_allocs == 0`, `live_bytes == 0`,
//!   and the heap reports empty once everything is freed — across
//!   magazine capacities including the fixed path (`magazine_cap=0`);
//! * `check_write` agrees with the O(#seals) scan oracle on every
//!   probe, under randomized multi-proc seal/unseal churn;
//! * a write check can never succeed against a stably-sealed page nor
//!   fail against a stably-unsealed one, while a sealer races it;
//! * magazine spill/refill keeps blocks intact when allocations are
//!   freed by a *different* thread than allocated them (the
//!   cross-thread magazine migration path);
//! * the lock-free `ScopePool` releases every batched seal exactly
//!   once under concurrent threshold-crossing pushers (a double drain
//!   would release a seal twice and trip the COMPLETE gate as
//!   `ReleaseDenied`).

use rpcool::memory::heap::{Heap, ProcId};
use rpcool::memory::pool::Pool;
use rpcool::seal::{ScopePool, Sealer};
use rpcool::util::prop::{forall, Gen};
use rpcool::util::rng::Rng;
use rpcool::SimConfig;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

fn prop_seed() -> u64 {
    std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x4EA9)
}

fn pool() -> Arc<Pool> {
    Pool::new(&SimConfig::for_tests()).unwrap()
}

// ------------------------------------------------------------------
// racing alloc/free: overlap freedom + exact accounting

#[derive(Clone, Debug)]
struct ChurnPlan {
    threads: u64,
    iters: u64,
    /// Allocation sizes are drawn in [16, max_size] — spanning the
    /// small classes and (≥ 4097) the large page path.
    max_size: u64,
    /// Live allocations each thread holds before draining the oldest.
    hold: usize,
    magazine_cap: usize,
    salt: u64,
}

struct ChurnGen;
impl Gen for ChurnGen {
    type Value = ChurnPlan;
    fn generate(&self, rng: &mut Rng) -> ChurnPlan {
        ChurnPlan {
            threads: rng.range(2, 5),
            iters: rng.range(100, 500),
            max_size: rng.range(64, 6000),
            hold: rng.range(0, 8) as usize,
            magazine_cap: [0usize, 4, 64][rng.range(0, 3) as usize],
            salt: rng.next_u64(),
        }
    }
    fn shrink(&self, v: &ChurnPlan) -> Vec<ChurnPlan> {
        let mut out = Vec::new();
        if v.iters > 100 {
            out.push(ChurnPlan { iters: v.iters / 2, ..v.clone() });
        }
        if v.threads > 2 {
            out.push(ChurnPlan { threads: v.threads - 1, ..v.clone() });
        }
        if v.hold > 0 {
            out.push(ChurnPlan { hold: 0, ..v.clone() });
        }
        out
    }
}

#[test]
fn prop_concurrent_alloc_free_exact_accounting() {
    forall("heap-churn-accounting", prop_seed(), 16, &ChurnGen, |plan| {
        let p = pool();
        let h = Heap::new_opts(&p, "churn", 16 << 20, plan.magazine_cap).unwrap();
        let ok = Arc::new(AtomicBool::new(true));
        std::thread::scope(|s| {
            for tid in 0..plan.threads {
                let h = Arc::clone(&h);
                let ok = Arc::clone(&ok);
                let plan = plan.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(plan.salt ^ tid.wrapping_mul(0x9E37_79B9));
                    let mut held: Vec<(usize, usize, u64)> = Vec::new();
                    let verify = |(addr, size, tag): (usize, usize, u64)| {
                        let head = unsafe { std::ptr::read_unaligned(addr as *const u64) };
                        let tail =
                            unsafe { std::ptr::read_unaligned((addr + size - 8) as *const u64) };
                        head == tag && tail == tag
                    };
                    for k in 0..plan.iters {
                        let size = rng.range(16, plan.max_size + 1) as usize;
                        match h.alloc_bytes(size) {
                            Ok(addr) => {
                                let tag = (tid << 48) | k;
                                unsafe {
                                    std::ptr::write_unaligned(addr as *mut u64, tag);
                                    std::ptr::write_unaligned((addr + size - 8) as *mut u64, tag);
                                }
                                held.push((addr, size, tag));
                            }
                            Err(_) => {
                                // OOM under pressure: drain and go on.
                                if let Some(e) = held.pop() {
                                    if !verify(e) {
                                        ok.store(false, Ordering::Relaxed);
                                    }
                                    h.free_bytes(e.0);
                                }
                            }
                        }
                        while held.len() > plan.hold {
                            let e = held.remove(0);
                            if !verify(e) {
                                ok.store(false, Ordering::Relaxed);
                            }
                            h.free_bytes(e.0);
                        }
                    }
                    for e in held.drain(..) {
                        if !verify(e) {
                            ok.store(false, Ordering::Relaxed);
                        }
                        h.free_bytes(e.0);
                    }
                });
            }
        });
        // Exact books: counts and bytes all the way to zero, and the
        // occupancy view agrees (magazine caches are not occupancy).
        ok.load(Ordering::Relaxed)
            && h.live_allocs() == 0
            && h.live_bytes() == 0
            && h.is_empty()
    });
}

// ------------------------------------------------------------------
// seal index vs the O(n) scan oracle

#[derive(Clone, Debug)]
struct SealPlan {
    ops: u64,
    pages: usize,
    procs: u64,
    salt: u64,
}

struct SealGen;
impl Gen for SealGen {
    type Value = SealPlan;
    fn generate(&self, rng: &mut Rng) -> SealPlan {
        SealPlan {
            ops: rng.range(20, 120),
            pages: rng.range(2, 16) as usize,
            procs: rng.range(1, 4),
            salt: rng.next_u64(),
        }
    }
    fn shrink(&self, v: &SealPlan) -> Vec<SealPlan> {
        let mut out = Vec::new();
        if v.ops > 20 {
            out.push(SealPlan { ops: v.ops / 2, ..v.clone() });
        }
        if v.procs > 1 {
            out.push(SealPlan { procs: v.procs - 1, ..v.clone() });
        }
        out
    }
}

#[test]
fn prop_check_write_matches_scan_oracle() {
    forall("seal-index-vs-scan", prop_seed(), 32, &SealGen, |plan| {
        let p = pool();
        let h = Heap::new(&p, "seals", 8 << 20).unwrap();
        let region = h.alloc_pages(plan.pages).unwrap();
        let mut rng = Rng::new(plan.salt);
        let mut live: Vec<(usize, usize, ProcId)> = Vec::new();
        let mut ok = true;
        for _ in 0..plan.ops {
            if rng.range(0, 2) == 0 || live.is_empty() {
                let start = region.base + rng.next_below(region.len as u64 - 64) as usize;
                let len = rng.range(1, 3 * 4096) as usize;
                let len = len.min(region.base + region.len - start);
                let proc = rng.range(1, plan.procs + 1) as ProcId;
                h.seal_range(start, len, proc);
                live.push((start, len, proc));
            } else {
                let i = rng.next_below(live.len() as u64) as usize;
                let (s, l, pr) = live.swap_remove(i);
                h.unseal_range(s, l, pr);
            }
            for _ in 0..24 {
                let addr = region.base + rng.next_below(region.len as u64 - 64) as usize;
                let len = rng.range(1, 128) as usize;
                let proc = rng.range(1, plan.procs + 2) as ProcId;
                if h.check_write(addr, len, proc).is_ok()
                    != h.check_write_scan(addr, len, proc).is_ok()
                {
                    ok = false;
                }
            }
        }
        for (s, l, pr) in live {
            h.unseal_range(s, l, pr);
        }
        ok &= h.sealed_count() == 0;
        // Fully unsealed again: every probe must pass.
        for _ in 0..32 {
            let addr = region.base + rng.next_below(region.len as u64 - 64) as usize;
            ok &= h.check_write(addr, 8, rng.range(1, plan.procs + 2) as ProcId).is_ok();
        }
        h.free_pages(region);
        ok
    });
}

// ------------------------------------------------------------------
// seal vs check_write under a racing sealer

/// Sealer-side state the writers observe: a **monotonically
/// increasing** packed word `cycle * 4 + phase`, with phase 0 =
/// stably unsealed, 1/3 = transitioning, 2 = stably sealed. Phase 2
/// is stored only *after* `seal_range` returns and left *before*
/// `unseal_range` starts. Because the word never repeats, a probe
/// that reads the SAME word before and after its check provably ran
/// with no sealer store in between — so phase 2 means the check
/// executed entirely inside a sealed window (and phase 0 entirely
/// inside an unsealed one). Without the cycle counter a probe
/// spanning a full seal/unseal cycle could observe the transient
/// seal yet read "unsealed" on both sides — a false violation.
const UNSEALED: u64 = 0;
const SEALED: u64 = 2;

#[derive(Clone, Debug)]
struct RacePlan {
    writers: u64,
    cycles: u64,
    probes_per_cycle: u64,
    salt: u64,
}

struct RaceGen;
impl Gen for RaceGen {
    type Value = RacePlan;
    fn generate(&self, rng: &mut Rng) -> RacePlan {
        RacePlan {
            writers: rng.range(1, 4),
            cycles: rng.range(50, 300),
            probes_per_cycle: rng.range(4, 32),
            salt: rng.next_u64(),
        }
    }
    fn shrink(&self, v: &RacePlan) -> Vec<RacePlan> {
        let mut out = Vec::new();
        if v.cycles > 50 {
            out.push(RacePlan { cycles: v.cycles / 2, ..v.clone() });
        }
        if v.writers > 1 {
            out.push(RacePlan { writers: v.writers - 1, ..v.clone() });
        }
        out
    }
}

#[test]
fn prop_write_never_succeeds_against_stably_sealed_page() {
    forall("seal-vs-check-race", prop_seed(), 12, &RaceGen, |plan| {
        let p = pool();
        let h = Heap::new(&p, "race", 4 << 20).unwrap();
        let region = h.alloc_pages(1).unwrap();
        let state = Arc::new(std::sync::atomic::AtomicU64::new(UNSEALED));
        let done = Arc::new(AtomicBool::new(false));
        let ok = Arc::new(AtomicBool::new(true));
        const PROC: ProcId = 7;
        std::thread::scope(|s| {
            {
                let h = Arc::clone(&h);
                let state = Arc::clone(&state);
                let done = Arc::clone(&done);
                s.spawn(move || {
                    for cycle in 0..plan.cycles {
                        state.store(cycle * 4 + 1, Ordering::SeqCst);
                        h.seal_range(region.base, 64, PROC);
                        state.store(cycle * 4 + SEALED, Ordering::SeqCst);
                        std::hint::spin_loop();
                        state.store(cycle * 4 + 3, Ordering::SeqCst);
                        h.unseal_range(region.base, 64, PROC);
                        state.store((cycle + 1) * 4 + UNSEALED, Ordering::SeqCst);
                        std::hint::spin_loop();
                    }
                    done.store(true, Ordering::SeqCst);
                });
            }
            for w in 0..plan.writers {
                let h = Arc::clone(&h);
                let state = Arc::clone(&state);
                let done = Arc::clone(&done);
                let ok = Arc::clone(&ok);
                let plan = plan.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(plan.salt ^ w);
                    while !done.load(Ordering::SeqCst) {
                        for _ in 0..plan.probes_per_cycle {
                            let addr = region.base + rng.next_below(56) as usize;
                            let before = state.load(Ordering::SeqCst);
                            let allowed = h.check_write(addr, 8, PROC).is_ok();
                            let after = state.load(Ordering::SeqCst);
                            // The word never repeats, so before == after
                            // pins the whole probe inside one phase.
                            if before == after {
                                if before % 4 == SEALED && allowed {
                                    ok.store(false, Ordering::Relaxed); // wrote through a seal
                                }
                                if before % 4 == UNSEALED && !allowed {
                                    ok.store(false, Ordering::Relaxed); // phantom seal
                                }
                            }
                        }
                    }
                });
            }
        });
        ok.load(Ordering::Relaxed) && h.sealed_count() == 0
    });
}

// ------------------------------------------------------------------
// magazine spill/refill consistency with cross-thread frees

#[derive(Clone, Debug)]
struct MigratePlan {
    producers: u64,
    items: u64,
    magazine_cap: usize,
    salt: u64,
}

struct MigrateGen;
impl Gen for MigrateGen {
    type Value = MigratePlan;
    fn generate(&self, rng: &mut Rng) -> MigratePlan {
        MigratePlan {
            producers: rng.range(1, 4),
            items: rng.range(200, 1200),
            // Tiny caps force constant refill/spill traffic.
            magazine_cap: [1usize, 2, 8, 64][rng.range(0, 4) as usize],
            salt: rng.next_u64(),
        }
    }
    fn shrink(&self, v: &MigratePlan) -> Vec<MigratePlan> {
        let mut out = Vec::new();
        if v.items > 200 {
            out.push(MigratePlan { items: v.items / 2, ..v.clone() });
        }
        if v.producers > 1 {
            out.push(MigratePlan { producers: v.producers - 1, ..v.clone() });
        }
        out
    }
}

#[test]
fn prop_magazine_spill_refill_with_cross_thread_frees() {
    use std::sync::atomic::AtomicU64;
    forall("magazine-migrate", prop_seed(), 12, &MigrateGen, |plan| {
        let p = pool();
        // 64 MiB: the worst-case backlog (every producer done, nothing
        // consumed yet) must fit without tripping a spurious OOM.
        let h = Heap::new_opts(&p, "mig", 64 << 20, plan.magazine_cap).unwrap();
        // Producers allocate + tag; a consumer verifies + frees, so
        // every block migrates to the consumer's magazine (and its
        // spills) rather than back to the allocating thread's.
        let queue: Arc<Mutex<Vec<(usize, usize, u64)>>> = Arc::new(Mutex::new(Vec::new()));
        let ok = Arc::new(AtomicBool::new(true));
        let producers_left = Arc::new(AtomicU64::new(plan.producers));
        std::thread::scope(|s| {
            for t in 0..plan.producers {
                let h = Arc::clone(&h);
                let queue = Arc::clone(&queue);
                let ok = Arc::clone(&ok);
                let left = Arc::clone(&producers_left);
                let plan = plan.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(plan.salt ^ (t << 7));
                    for k in 0..plan.items {
                        let size = rng.range(16, 4097) as usize; // small classes only
                        match h.alloc_bytes(size) {
                            Ok(addr) => {
                                let tag = (t << 40) | k;
                                unsafe {
                                    std::ptr::write_unaligned(addr as *mut u64, tag);
                                    std::ptr::write_unaligned(
                                        (addr + size - 8) as *mut u64,
                                        tag,
                                    );
                                }
                                queue.lock().unwrap().push((addr, size, tag));
                            }
                            Err(_) => ok.store(false, Ordering::Relaxed),
                        }
                    }
                    left.fetch_sub(1, Ordering::Release);
                });
            }
            {
                let h = Arc::clone(&h);
                let queue = Arc::clone(&queue);
                let ok = Arc::clone(&ok);
                let left = Arc::clone(&producers_left);
                s.spawn(move || loop {
                    let batch: Vec<(usize, usize, u64)> =
                        { queue.lock().unwrap().drain(..).collect() };
                    if batch.is_empty() {
                        // Done once every producer finished AND the
                        // queue is provably drained (re-checked under
                        // the lock after observing the counter).
                        if left.load(Ordering::Acquire) == 0 && queue.lock().unwrap().is_empty()
                        {
                            return;
                        }
                        std::hint::spin_loop();
                        continue;
                    }
                    for (addr, size, tag) in batch {
                        let head = unsafe { std::ptr::read_unaligned(addr as *const u64) };
                        let tail =
                            unsafe { std::ptr::read_unaligned((addr + size - 8) as *const u64) };
                        if head != tag || tail != tag {
                            ok.store(false, Ordering::Relaxed);
                        }
                        h.free_bytes(addr);
                    }
                });
            }
        });
        // The consumer drained everything (its exit condition); the
        // books must balance even though no block was freed by the
        // thread that allocated it.
        ok.load(Ordering::Relaxed)
            && queue.lock().unwrap().is_empty()
            && h.live_allocs() == 0
            && h.live_bytes() == 0
    });
}

// ------------------------------------------------------------------
// lock-free ScopePool: batched release exactly once

#[derive(Clone, Debug)]
struct PoolPlan {
    threads: u64,
    per_thread: u64,
    threshold: usize,
    salt: u64,
}

struct PoolGen;
impl Gen for PoolGen {
    type Value = PoolPlan;
    fn generate(&self, rng: &mut Rng) -> PoolPlan {
        PoolPlan {
            threads: rng.range(2, 5),
            per_thread: rng.range(50, 400),
            threshold: rng.range(1, 64) as usize,
            salt: rng.next_u64(),
        }
    }
    fn shrink(&self, v: &PoolPlan) -> Vec<PoolPlan> {
        let mut out = Vec::new();
        if v.per_thread > 50 {
            out.push(PoolPlan { per_thread: v.per_thread / 2, ..v.clone() });
        }
        if v.threads > 2 {
            out.push(PoolPlan { threads: v.threads - 1, ..v.clone() });
        }
        out
    }
}

#[test]
fn prop_scope_pool_batched_release_exactly_once() {
    forall("scope-pool-exactly-once", prop_seed(), 12, &PoolGen, |plan| {
        let cfg = SimConfig::for_tests();
        let p = pool();
        let h = Heap::new(&p, "pool", 64 << 20).unwrap();
        let sealer = Sealer::new(&cfg, Arc::clone(&h), Arc::clone(&p.charger)).unwrap();
        let sp = ScopePool::new(Arc::clone(&h), Arc::clone(&sealer), 4096, plan.threshold);
        let ok = Arc::new(AtomicBool::new(true));
        std::thread::scope(|s| {
            for t in 0..plan.threads {
                let sp = Arc::clone(&sp);
                let sealer = Arc::clone(&sealer);
                let ok = Arc::clone(&ok);
                let plan = plan.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(plan.salt ^ (t << 9));
                    for _ in 0..plan.per_thread {
                        let scope = match sp.pop() {
                            Ok(sc) => sc,
                            Err(_) => {
                                ok.store(false, Ordering::Relaxed);
                                return;
                            }
                        };
                        let proc = rng.range(1, 4) as ProcId;
                        let hdl = match sealer.seal(scope.base(), scope.len(), proc) {
                            Ok(hd) => hd,
                            Err(_) => {
                                ok.store(false, Ordering::Relaxed);
                                return;
                            }
                        };
                        sealer.complete(hdl.idx);
                        // A double-drained batch would release some
                        // seal twice: second release sees DESC_FREE,
                        // not COMPLETE ⇒ ReleaseDenied surfaces here.
                        if sp.push_sealed(scope, hdl).is_err() {
                            ok.store(false, Ordering::Relaxed);
                            return;
                        }
                    }
                });
            }
        });
        if sp.flush().is_err() {
            return false;
        }
        ok.load(Ordering::Relaxed) && sp.pending_len() == 0 && h.sealed_count() == 0
    });
}
