//! Crash-fault recovery suite (ISSUE 9): one test per kill point.
//!
//! Each test arms a deterministic, seeded [`FaultPlan`], drives a
//! small workload until the victim proc dies *without cleanup* at the
//! worst possible instant, then recovers the way production would:
//! survivors keep renewing their leases, the victim's lapse, and one
//! orchestrator sweep (`Orchestrator::tick`) reclaims everything the
//! corpse stranded. Every test then checks the two halves of the
//! acceptance bar:
//!
//! * **liveness** — pending calls on survivors resolve (as
//!   `PeerFailed`, never a hang), and a fresh connect + call works;
//! * **books balance** — orphaned heaps leave the orchestrator's
//!   registry, stranded ring slots are tombstoned, force-released
//!   seals zero the seal index, leaked scopes free their pages, and
//!   the `FAULT_COUNTERS` line it prints satisfies the CI gate
//!   (`ci/check_fault.sh`): kills ≥ 1 and kills == recoveries.
//!
//! The fault injector is process-global state, so every test
//! serializes on `GATE` (the suite still runs under the default
//! parallel harness). `PROP_SEED` (CI sweeps four seeds) picks the
//! crossing depth wherever the kill point allows one.

use rpcool::channel::{CallOpts, ChannelBuilder, Connection, Rpc, RpcServer};
use rpcool::daemon::Daemon;
use rpcool::error::RpcError;
use rpcool::fault::{self, FaultPlan, KillPoint};
use rpcool::metrics::CounterSet;
use rpcool::orchestrator::{
    FLT_KILLS, FLT_MAGS_FLUSHED, FLT_RECONNECTS, FLT_RECOVERIES, FLT_RETRIES, FLT_SCOPES_FREED,
    FLT_SEALS_FORCED, FLT_SLOTS_REAPED,
};
use rpcool::rack::{ProcEnv, Rack};
use rpcool::RetryPolicy;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// The fault injector (and its crossing budget) is process-global:
/// kill-point tests must not run concurrently.
static GATE: Mutex<()> = Mutex::new(());

/// Seed source: `PROP_SEED` env var (CI matrix), fixed default.
fn prop_seed() -> u64 {
    std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x5EED)
}

/// Disarm on scope exit, even when an assert panics — a leftover
/// armed plan would fire inside the *next* test's workload.
struct DisarmGuard;
impl Drop for DisarmGuard {
    fn drop(&mut self) {
        fault::disarm();
    }
}

/// librpcool's renewal loop, for the survivors only: the victim's
/// lease is the one that must lapse.
fn spawn_renewer(
    daemon: Arc<Daemon>,
    procs: Vec<u32>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        while !stop.load(Ordering::Acquire) {
            for p in &procs {
                daemon.renew_all(*p);
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    })
}

/// The machine-readable line `ci/check_fault.sh` gates on.
fn print_counters(point: &str, f: &CounterSet) {
    println!(
        "FAULT_COUNTERS point={point} kills={} slots_reaped={} seals_forced={} \
         scopes_freed={} mags_flushed={} retries={} reconnects={} recoveries={}",
        f.get(FLT_KILLS),
        f.get(FLT_SLOTS_REAPED),
        f.get(FLT_SEALS_FORCED),
        f.get(FLT_SCOPES_FREED),
        f.get(FLT_MAGS_FLUSHED),
        f.get(FLT_RETRIES),
        f.get(FLT_RECONNECTS),
        f.get(FLT_RECOVERIES),
    );
}

/// Common scaffolding: a one-shard/8-slot echo channel with a
/// dedicated listener, one survivor client, one victim client.
struct CrashRig {
    rack: Arc<Rack>,
    server: RpcServer,
    listener: std::thread::JoinHandle<()>,
    daemon: Arc<Daemon>,
    senv: ProcEnv,
    surv_env: ProcEnv,
    surv: Connection,
    /// `live_heaps` with the survivor connected, before the victim.
    heaps_baseline: usize,
}

fn crash_rig(name: &str) -> CrashRig {
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_shards(1)
        .ring_slots(8)
        .call_timeout(Duration::from_secs(5))
        .open(&senv, name)
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let listener = server.spawn_listener();
    let daemon = Arc::clone(server.core().daemon());
    let surv_env = rack.proc_env(1);
    let surv = Connection::connect(&surv_env, name).unwrap();
    let heaps_baseline = rack.orch.live_heaps();
    CrashRig { rack, server, listener, daemon, senv, surv_env, surv, heaps_baseline }
}

impl CrashRig {
    /// Post-recovery liveness: the survivor's connection still serves,
    /// and a fresh connect is admitted and serves (the victim's
    /// admission slot came back).
    fn assert_survivor_liveness(&self, name: &str) {
        let r = self.surv_env.run(|| self.surv.call_scalar::<u64>(1, &7, CallOpts::new()));
        assert_eq!(r.unwrap(), 8, "survivor serves after recovery");
        let fresh_env = self.rack.proc_env(1);
        let fresh = Connection::connect(&fresh_env, name).expect("fresh connect after recovery");
        let r = fresh_env.run(|| fresh.call_scalar::<u64>(1, &9, CallOpts::new()));
        assert_eq!(r.unwrap(), 10, "fresh connection serves after recovery");
    }

    fn teardown(self) {
        drop(self.surv);
        self.server.stop();
        self.listener.join().unwrap();
    }
}

/// Drive one client-side kill: connect a victim, run `workload` under
/// its identity (it must return the `Killed` error), crash the proc,
/// let its lease lapse while survivors renew, sweep, and check the
/// books. Returns the rig for per-point extra assertions.
fn run_client_kill(
    name: &str,
    point: KillPoint,
    nth: u64,
    workload: impl FnOnce(&Connection) + Send + 'static,
) -> (CrashRig, Arc<CounterSet>) {
    let rig = crash_rig(name);
    let orch = Arc::clone(&rig.rack.orch);
    let vic_env = rig.rack.proc_env(1);
    let vic_proc = vic_env.proc;
    let vic = Connection::connect(&vic_env, name).unwrap();
    assert_eq!(orch.live_heaps(), rig.heaps_baseline + 1, "victim heap mapped");

    // Survivors renew from the start (renewal is strict: a lapsed
    // lease cannot be revived, so the renewer must outpace the TTL
    // across the whole scenario). The victim is never renewed.
    let stop = Arc::new(AtomicBool::new(false));
    let renew = spawn_renewer(
        Arc::clone(&rig.daemon),
        vec![rig.senv.proc, rig.surv_env.proc],
        Arc::clone(&stop),
    );

    fault::arm_with_sink(
        FaultPlan::new(point).victim(vic_proc).nth(nth),
        Arc::downgrade(&orch.fault_counters()),
    );
    std::thread::spawn(move || {
        vic_env.run(|| {
            workload(&vic);
            vic.crash();
        })
    })
    .join()
    .unwrap();
    let f = orch.fault_counters();
    assert_eq!(f.get(FLT_KILLS), 1, "exactly one injected kill fired");
    assert!(!fault::armed(), "injector auto-disarmed");

    // The victim's lease lapses; one sweep recovers everything.
    std::thread::sleep(Duration::from_millis(rig.rack.cfg.lease_ttl_ms + 30));
    orch.tick();
    orch.tick(); // idempotent: no new dead procs, no new recoveries

    assert_eq!(orch.live_heaps(), rig.heaps_baseline, "victim heap reclaimed");
    assert_eq!(f.get(FLT_RECOVERIES), 1, "one dead proc, one recovery");
    rig.assert_survivor_liveness(name);
    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    (rig, f)
}

/// Die after a chunk's `publish_quiet` loop, before `flush_publish`:
/// requests sit fully written with no doorbell. The sweep must
/// tombstone every stranded slot.
#[test]
fn crash_pre_flush_client() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let nth = 1 + prop_seed() % 3; // die on the nth chunk flush
    let (rig, f) = run_client_kill("crash-preflush", KillPoint::PreFlush, nth, |vic| {
        let vals: Vec<u64> = (0..64).collect(); // 8 chunks of 8 slots
        let r = vic.call_scalar_batch::<u64>(1, &vals, CallOpts::new());
        assert!(matches!(r, Err(RpcError::Killed(_))), "victim sees Killed: {r:?}");
    });
    assert!(
        f.get(FLT_SLOTS_REAPED) >= 1,
        "published-but-unflushed slots must be tombstoned, got {}",
        f.get(FLT_SLOTS_REAPED)
    );
    print_counters("pre_flush", &f);
    rig.teardown();
}

/// Die between batch chunks: earlier chunks fully consumed, later
/// ones never claimed — recovery has nothing stranded on the ring but
/// must still reclaim the heap and free the admission slot.
#[test]
fn crash_mid_batch_client() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let nth = 1 + prop_seed() % 3;
    let (rig, f) = run_client_kill("crash-midbatch", KillPoint::MidBatch, nth, |vic| {
        let vals: Vec<u64> = (0..64).collect();
        let r = vic.call_scalar_batch::<u64>(1, &vals, CallOpts::new());
        assert!(matches!(r, Err(RpcError::Killed(_))), "victim sees Killed: {r:?}");
    });
    print_counters("mid_batch", &f);
    rig.teardown();
}

/// Die holding an installed COMPLETE seal: the page words stay set
/// until the sweep revokes the dead proc's descriptors.
#[test]
fn crash_holding_seal_client() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let nth = 1 + prop_seed() % 3; // die on the nth sealed call
    let vheap: Arc<Mutex<Option<Arc<rpcool::memory::heap::Heap>>>> =
        Arc::new(Mutex::new(None));
    let vh = Arc::clone(&vheap);
    let (rig, f) = run_client_kill("crash-seal", KillPoint::HoldingSeal, nth, move |vic| {
        *vh.lock().unwrap() = Some(Arc::clone(vic.heap()));
        let scope = vic.create_scope(4096).unwrap();
        let addr = scope.new_val(5u64).unwrap();
        let mut killed = false;
        for _ in 0..5 {
            match vic.invoke(1, (addr, 8), CallOpts::new().sealed(&scope)) {
                Ok(r) => assert_eq!(r, 6),
                Err(RpcError::Killed(_)) => {
                    killed = true;
                    break;
                }
                Err(e) => panic!("unexpected sealed-call error: {e:?}"),
            }
        }
        assert!(killed, "kill must fire within the sealed-call loop");
        // Died holding the scope too: its Drop never runs.
        std::mem::forget(scope);
    });
    let vheap = vheap.lock().unwrap().take().unwrap();
    assert_eq!(vheap.sealed_count(), 0, "dead proc's seal force-released");
    assert!(f.get(FLT_SEALS_FORCED) >= 1, "force-release counted");
    assert_eq!(f.get(FLT_SCOPES_FREED), 1, "leaked scope swept");
    print_counters("holding_seal", &f);
    rig.teardown();
}

/// Die holding a live scope (before any seal): its pages leak until
/// the sweep frees them through the scope registry.
#[test]
fn crash_holding_scope_client() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let (rig, f) = run_client_kill("crash-scope", KillPoint::HoldingScope, 1, |vic| {
        let scope = vic.create_scope(4096).unwrap();
        let addr = scope.new_val(5u64).unwrap();
        let r = vic.invoke(1, (addr, 8), CallOpts::new().sealed(&scope));
        assert!(matches!(r, Err(RpcError::Killed(_))), "victim sees Killed: {r:?}");
        std::mem::forget(scope);
    });
    assert_eq!(f.get(FLT_SCOPES_FREED), 1, "leaked scope swept");
    print_counters("holding_scope", &f);
    rig.teardown();
}

/// The *server* dies mid-serving (slot taken, no reply). The
/// survivor's in-flight batch must resolve as `PeerFailed` within one
/// lease TTL + sweep — never hang to the call timeout.
#[test]
fn crash_mid_serve_server() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let rig = crash_rig("crash-midserve");
    let orch = Arc::clone(&rig.rack.orch);
    let nth = 1 + prop_seed() % 3; // die on the nth served request

    // Only the *client* renews (the batch connection clones the rig
    // survivor's env, so one proc id covers both connections): the
    // server's lease lapses once the kill stops its serving loop.
    let stop = Arc::new(AtomicBool::new(false));
    let renew =
        spawn_renewer(Arc::clone(&rig.daemon), vec![rig.surv_env.proc], Arc::clone(&stop));

    fault::arm_with_sink(
        FaultPlan::new(KillPoint::MidServe).victim(rig.senv.proc).nth(nth),
        Arc::downgrade(&orch.fault_counters()),
    );
    // The survivor's batch is what the dying server was serving; it
    // must fail over, not hang.
    let surv_env = rig.surv_env.clone();
    let surv = Connection::connect(&surv_env, "crash-midserve").unwrap();
    let pending = std::thread::spawn(move || {
        surv_env.run(|| {
            let vals: Vec<u64> = (0..8).collect();
            let t0 = Instant::now();
            let r = surv.call_scalar_batch::<u64>(1, &vals, CallOpts::new());
            (r, t0.elapsed())
        })
    });

    let f = orch.fault_counters();
    let deadline = Instant::now() + Duration::from_secs(2);
    while f.get(FLT_KILLS) == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(f.get(FLT_KILLS), 1, "server kill fired");

    std::thread::sleep(Duration::from_millis(rig.rack.cfg.lease_ttl_ms + 30));
    orch.tick();

    let (r, elapsed) = pending.join().unwrap();
    assert!(
        matches!(r, Err(RpcError::PeerFailed(_))),
        "survivor's pending batch fails over as PeerFailed: {r:?}"
    );
    assert!(
        elapsed < Duration::from_secs(4),
        "fail-over must beat the 5s call timeout, took {elapsed:?}"
    );
    assert_eq!(f.get(FLT_RECOVERIES), 1, "one dead proc (the server)");
    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    print_counters("mid_serve", &f);
    drop(rig.surv);
    rig.server.stop();
    rig.listener.join().unwrap();
}

/// A parked worker-pool thread dies: the pool serves thin until the
/// sweep's heal hook respawns to the high-water mark.
#[test]
fn crash_parked_worker_heals() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_shards(1)
        .ring_slots(8)
        .pool_workers(2)
        .call_timeout(Duration::from_secs(5))
        .open(&senv, "crash-worker")
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    assert!(server.spawn_listeners(1).is_empty(), "pooled channel, no listeners");
    let daemon = Arc::clone(server.core().daemon());
    let pool = daemon.worker_pool(2);
    assert_eq!(pool.worker_count(), 2);

    let cenv = rack.proc_env(1);
    let conn = Connection::connect(&cenv, "crash-worker").unwrap();
    let r = cenv.run(|| conn.call_scalar::<u64>(1, &1, CallOpts::new()));
    assert_eq!(r.unwrap(), 2, "pooled serving works before the kill");

    // Both endpoints survive this scenario — keep their leases fresh
    // so the sweep's only recovery is the pool heal.
    let stop = Arc::new(AtomicBool::new(false));
    let renew =
        spawn_renewer(Arc::clone(&daemon), vec![senv.proc, cenv.proc], Arc::clone(&stop));

    let orch = Arc::clone(&rack.orch);
    let f = orch.fault_counters();
    // Workers cross the park decision every idle loop; no victim
    // filter (pool threads carry no proc identity).
    fault::arm_with_sink(
        FaultPlan::new(KillPoint::ParkedWorker).nth(1 + prop_seed() % 3),
        Arc::downgrade(&orch.fault_counters()),
    );
    let deadline = Instant::now() + Duration::from_secs(2);
    while (f.get(FLT_KILLS) == 0 || pool.worker_count() != 1) && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(f.get(FLT_KILLS), 1, "a parked worker died");
    assert_eq!(pool.worker_count(), 1, "pool is serving thin");

    orch.tick();
    assert_eq!(pool.worker_count(), 2, "heal respawned to the high-water mark");
    assert_eq!(f.get(FLT_RECOVERIES), 1, "healed worker counts as the recovery");

    let r = cenv.run(|| conn.call_scalar::<u64>(1, &10, CallOpts::new()));
    assert_eq!(r.unwrap(), 11, "pooled serving works after the heal");
    print_counters("parked_worker", &f);
    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    drop(conn);
    server.stop();
}

/// The client-side failure plane end to end: bounded idempotent
/// retries against a dead peer (counted), then reconnect-with-backoff
/// to the channel's replacement (counted).
#[test]
fn retrying_client_reconnects_after_server_crash() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let server = Rpc::open(&senv, "phoenix").unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let listener = server.spawn_listener();
    let daemon = Arc::clone(server.core().daemon());
    let orch = Arc::clone(&rack.orch);
    let f = orch.fault_counters();

    let cenv = rack.proc_env(1);
    let conn = Connection::connect(&cenv, "phoenix").unwrap();
    let r = cenv.run(|| conn.call_scalar::<u64>(1, &1, CallOpts::new()));
    assert_eq!(r.unwrap(), 2);

    // Server crashes: only the client renews; the sweep fails the peer.
    let stop = Arc::new(AtomicBool::new(false));
    let renew = spawn_renewer(Arc::clone(&daemon), vec![cenv.proc], Arc::clone(&stop));
    std::thread::sleep(Duration::from_millis(rack.cfg.lease_ttl_ms + 30));
    orch.tick();

    // Bounded idempotent retries against the dead peer: 3 attempts, 2
    // retries, final error stays PeerFailed.
    let policy = RetryPolicy::new(3)
        .idempotent()
        .seed(prop_seed())
        .backoff_base(Duration::from_micros(100), Duration::from_millis(2));
    let r = cenv.run(|| conn.call_scalar::<u64>(1, &5, CallOpts::new().retry(policy)));
    assert!(matches!(r, Err(RpcError::PeerFailed(_))), "retries exhaust into PeerFailed: {r:?}");
    assert_eq!(f.get(FLT_RETRIES), 2, "attempts - 1 retries counted");

    // Tear the dead channel fully down so the name frees...
    listener.join().unwrap();
    drop(server);
    drop(conn);
    // ...then reconnect-with-backoff while a replacement comes up.
    let renv = rack.proc_env(0);
    let opener = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(40));
        let s2 = Rpc::open(&renv, "phoenix").unwrap();
        s2.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 100));
        let l2 = s2.spawn_listener();
        (s2, l2)
    });
    let c2env = rack.proc_env(1);
    let policy = RetryPolicy::new(100)
        .idempotent()
        .seed(prop_seed())
        .backoff_base(Duration::from_millis(2), Duration::from_millis(8));
    let c2 = Connection::connect_retry(&c2env, "phoenix", policy)
        .expect("reconnect lands once the replacement opens");
    assert!(f.get(FLT_RECONNECTS) >= 1, "failed connect attempts counted as reconnects");
    let (s2, l2) = opener.join().unwrap();
    let r = c2env.run(|| c2.call_scalar::<u64>(1, &5, CallOpts::new()));
    assert_eq!(r.unwrap(), 105, "replacement channel serves the reconnected client");

    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    drop(c2);
    s2.stop();
    l2.join().unwrap();
}
