//! Crash-fault recovery suite (ISSUE 9): one test per kill point.
//!
//! Each test arms a deterministic, seeded [`FaultPlan`], drives a
//! small workload until the victim proc dies *without cleanup* at the
//! worst possible instant, then recovers the way production would:
//! survivors keep renewing their leases, the victim's lapse, and one
//! orchestrator sweep (`Orchestrator::tick`) reclaims everything the
//! corpse stranded. Every test then checks the two halves of the
//! acceptance bar:
//!
//! * **liveness** — pending calls on survivors resolve (as
//!   `PeerFailed`, never a hang), and a fresh connect + call works;
//! * **books balance** — orphaned heaps leave the orchestrator's
//!   registry, stranded ring slots are tombstoned, force-released
//!   seals zero the seal index, leaked scopes free their pages, and
//!   the `FAULT_COUNTERS` line it prints satisfies the CI gate
//!   (`ci/check_fault.sh`): kills ≥ 1 and kills == recoveries.
//!
//! The fault injector is process-global state, so every test
//! serializes on `GATE` (the suite still runs under the default
//! parallel harness). `PROP_SEED` (CI sweeps four seeds) picks the
//! crossing depth wherever the kill point allows one.

use rpcool::channel::{CallOpts, ChannelBuilder, Connection, Rpc, RpcServer, TransportSel};
use rpcool::daemon::Daemon;
use rpcool::error::RpcError;
use rpcool::fault::{self, FaultPlan, KillPoint};
use rpcool::metrics::CounterSet;
use rpcool::orchestrator::{
    FLT_ADOPTIONS, FLT_EPOCH_BUMPS, FLT_KILLS, FLT_MAGS_FLUSHED, FLT_PAGES_RECLAIMED,
    FLT_RECONNECTS, FLT_RECOVERIES, FLT_RETRIES, FLT_SCOPES_FREED, FLT_SEALS_FORCED,
    FLT_SLOTS_REAPED,
};
use rpcool::rack::{ProcEnv, Rack};
use rpcool::RetryPolicy;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// The fault injector (and its crossing budget) is process-global:
/// kill-point tests must not run concurrently.
static GATE: Mutex<()> = Mutex::new(());

/// Seed source: `PROP_SEED` env var (CI matrix), fixed default.
fn prop_seed() -> u64 {
    std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x5EED)
}

/// Disarm on scope exit, even when an assert panics — a leftover
/// armed plan would fire inside the *next* test's workload.
struct DisarmGuard;
impl Drop for DisarmGuard {
    fn drop(&mut self) {
        fault::disarm();
    }
}

/// librpcool's renewal loop, for the survivors only: the victim's
/// lease is the one that must lapse.
fn spawn_renewer(
    daemon: Arc<Daemon>,
    procs: Vec<u32>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        while !stop.load(Ordering::Acquire) {
            for p in &procs {
                daemon.renew_all(*p);
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    })
}

/// The machine-readable line `ci/check_fault.sh` gates on.
fn print_counters(point: &str, f: &CounterSet) {
    println!(
        "FAULT_COUNTERS point={point} kills={} slots_reaped={} seals_forced={} \
         scopes_freed={} mags_flushed={} retries={} reconnects={} recoveries={} \
         epoch_bumps={} pages_reclaimed={} adoptions={}",
        f.get(FLT_KILLS),
        f.get(FLT_SLOTS_REAPED),
        f.get(FLT_SEALS_FORCED),
        f.get(FLT_SCOPES_FREED),
        f.get(FLT_MAGS_FLUSHED),
        f.get(FLT_RETRIES),
        f.get(FLT_RECONNECTS),
        f.get(FLT_RECOVERIES),
        f.get(FLT_EPOCH_BUMPS),
        f.get(FLT_PAGES_RECLAIMED),
        f.get(FLT_ADOPTIONS),
    );
}

/// Common scaffolding: a one-shard/8-slot echo channel with a
/// dedicated listener, one survivor client, one victim client.
struct CrashRig {
    rack: Arc<Rack>,
    server: RpcServer,
    listener: std::thread::JoinHandle<()>,
    daemon: Arc<Daemon>,
    senv: ProcEnv,
    surv_env: ProcEnv,
    surv: Connection,
    /// `live_heaps` with the survivor connected, before the victim.
    heaps_baseline: usize,
}

fn crash_rig(name: &str) -> CrashRig {
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_shards(1)
        .ring_slots(8)
        .call_timeout(Duration::from_secs(5))
        .open(&senv, name)
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let listener = server.spawn_listener();
    let daemon = Arc::clone(server.core().daemon());
    let surv_env = rack.proc_env(1);
    let surv = Connection::connect(&surv_env, name).unwrap();
    let heaps_baseline = rack.orch.live_heaps();
    CrashRig { rack, server, listener, daemon, senv, surv_env, surv, heaps_baseline }
}

impl CrashRig {
    /// Post-recovery liveness: the survivor's connection still serves,
    /// and a fresh connect is admitted and serves (the victim's
    /// admission slot came back).
    fn assert_survivor_liveness(&self, name: &str) {
        let r = self.surv_env.run(|| self.surv.call_scalar::<u64>(1, &7, CallOpts::new()));
        assert_eq!(r.unwrap(), 8, "survivor serves after recovery");
        let fresh_env = self.rack.proc_env(1);
        let fresh = Connection::connect(&fresh_env, name).expect("fresh connect after recovery");
        let r = fresh_env.run(|| fresh.call_scalar::<u64>(1, &9, CallOpts::new()));
        assert_eq!(r.unwrap(), 10, "fresh connection serves after recovery");
    }

    fn teardown(self) {
        drop(self.surv);
        self.server.stop();
        self.listener.join().unwrap();
    }
}

/// Drive one client-side kill: connect a victim, run `workload` under
/// its identity (it must return the `Killed` error), crash the proc,
/// let its lease lapse while survivors renew, sweep, and check the
/// books. Returns the rig for per-point extra assertions.
fn run_client_kill(
    name: &str,
    point: KillPoint,
    nth: u64,
    workload: impl FnOnce(&Connection) + Send + 'static,
) -> (CrashRig, Arc<CounterSet>) {
    let rig = crash_rig(name);
    let orch = Arc::clone(&rig.rack.orch);
    let vic_env = rig.rack.proc_env(1);
    let vic_proc = vic_env.proc;
    let vic = Connection::connect(&vic_env, name).unwrap();
    assert_eq!(orch.live_heaps(), rig.heaps_baseline + 1, "victim heap mapped");

    // Survivors renew from the start (renewal is strict: a lapsed
    // lease cannot be revived, so the renewer must outpace the TTL
    // across the whole scenario). The victim is never renewed.
    let stop = Arc::new(AtomicBool::new(false));
    let renew = spawn_renewer(
        Arc::clone(&rig.daemon),
        vec![rig.senv.proc, rig.surv_env.proc],
        Arc::clone(&stop),
    );

    fault::arm_with_sink(
        FaultPlan::new(point).victim(vic_proc).nth(nth),
        Arc::downgrade(&orch.fault_counters()),
    );
    std::thread::spawn(move || {
        vic_env.run(|| {
            workload(&vic);
            vic.crash();
        })
    })
    .join()
    .unwrap();
    let f = orch.fault_counters();
    assert_eq!(f.get(FLT_KILLS), 1, "exactly one injected kill fired");
    assert!(!fault::armed(), "injector auto-disarmed");

    // The victim's lease lapses; one sweep recovers everything.
    std::thread::sleep(Duration::from_millis(rig.rack.cfg.lease_ttl_ms + 30));
    orch.tick();
    orch.tick(); // idempotent: no new dead procs, no new recoveries

    assert_eq!(orch.live_heaps(), rig.heaps_baseline, "victim heap reclaimed");
    assert_eq!(f.get(FLT_RECOVERIES), 1, "one dead proc, one recovery");
    rig.assert_survivor_liveness(name);
    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    (rig, f)
}

/// Die after a chunk's `publish_quiet` loop, before `flush_publish`:
/// requests sit fully written with no doorbell. The sweep must
/// tombstone every stranded slot.
#[test]
fn crash_pre_flush_client() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let nth = 1 + prop_seed() % 3; // die on the nth chunk flush
    let (rig, f) = run_client_kill("crash-preflush", KillPoint::PreFlush, nth, |vic| {
        let vals: Vec<u64> = (0..64).collect(); // 8 chunks of 8 slots
        let r = vic.call_scalar_batch::<u64>(1, &vals, CallOpts::new());
        assert!(matches!(r, Err(RpcError::Killed(_))), "victim sees Killed: {r:?}");
    });
    assert!(
        f.get(FLT_SLOTS_REAPED) >= 1,
        "published-but-unflushed slots must be tombstoned, got {}",
        f.get(FLT_SLOTS_REAPED)
    );
    print_counters("pre_flush", &f);
    rig.teardown();
}

/// Die between batch chunks: earlier chunks fully consumed, later
/// ones never claimed — recovery has nothing stranded on the ring but
/// must still reclaim the heap and free the admission slot.
#[test]
fn crash_mid_batch_client() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let nth = 1 + prop_seed() % 3;
    let (rig, f) = run_client_kill("crash-midbatch", KillPoint::MidBatch, nth, |vic| {
        let vals: Vec<u64> = (0..64).collect();
        let r = vic.call_scalar_batch::<u64>(1, &vals, CallOpts::new());
        assert!(matches!(r, Err(RpcError::Killed(_))), "victim sees Killed: {r:?}");
    });
    print_counters("mid_batch", &f);
    rig.teardown();
}

/// Die holding an installed COMPLETE seal: the page words stay set
/// until the sweep revokes the dead proc's descriptors.
#[test]
fn crash_holding_seal_client() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let nth = 1 + prop_seed() % 3; // die on the nth sealed call
    let vheap: Arc<Mutex<Option<Arc<rpcool::memory::heap::Heap>>>> =
        Arc::new(Mutex::new(None));
    let vh = Arc::clone(&vheap);
    let (rig, f) = run_client_kill("crash-seal", KillPoint::HoldingSeal, nth, move |vic| {
        *vh.lock().unwrap() = Some(Arc::clone(vic.heap()));
        let scope = vic.create_scope(4096).unwrap();
        let addr = scope.new_val(5u64).unwrap();
        let mut killed = false;
        for _ in 0..5 {
            match vic.invoke(1, (addr, 8), CallOpts::new().sealed(&scope)) {
                Ok(r) => assert_eq!(r, 6),
                Err(RpcError::Killed(_)) => {
                    killed = true;
                    break;
                }
                Err(e) => panic!("unexpected sealed-call error: {e:?}"),
            }
        }
        assert!(killed, "kill must fire within the sealed-call loop");
        // Died holding the scope too: its Drop never runs.
        std::mem::forget(scope);
    });
    let vheap = vheap.lock().unwrap().take().unwrap();
    assert_eq!(vheap.sealed_count(), 0, "dead proc's seal force-released");
    assert!(f.get(FLT_SEALS_FORCED) >= 1, "force-release counted");
    assert_eq!(f.get(FLT_SCOPES_FREED), 1, "leaked scope swept");
    print_counters("holding_seal", &f);
    rig.teardown();
}

/// Die holding a live scope (before any seal): its pages leak until
/// the sweep frees them through the scope registry.
#[test]
fn crash_holding_scope_client() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let (rig, f) = run_client_kill("crash-scope", KillPoint::HoldingScope, 1, |vic| {
        let scope = vic.create_scope(4096).unwrap();
        let addr = scope.new_val(5u64).unwrap();
        let r = vic.invoke(1, (addr, 8), CallOpts::new().sealed(&scope));
        assert!(matches!(r, Err(RpcError::Killed(_))), "victim sees Killed: {r:?}");
        std::mem::forget(scope);
    });
    assert_eq!(f.get(FLT_SCOPES_FREED), 1, "leaked scope swept");
    print_counters("holding_scope", &f);
    rig.teardown();
}

/// The *server* dies mid-serving (slot taken, no reply). The
/// survivor's in-flight batch must resolve as `PeerFailed` within one
/// lease TTL + sweep — never hang to the call timeout.
#[test]
fn crash_mid_serve_server() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let rig = crash_rig("crash-midserve");
    let orch = Arc::clone(&rig.rack.orch);
    let nth = 1 + prop_seed() % 3; // die on the nth served request

    // Only the *client* renews (the batch connection clones the rig
    // survivor's env, so one proc id covers both connections): the
    // server's lease lapses once the kill stops its serving loop.
    let stop = Arc::new(AtomicBool::new(false));
    let renew =
        spawn_renewer(Arc::clone(&rig.daemon), vec![rig.surv_env.proc], Arc::clone(&stop));

    fault::arm_with_sink(
        FaultPlan::new(KillPoint::MidServe).victim(rig.senv.proc).nth(nth),
        Arc::downgrade(&orch.fault_counters()),
    );
    // The survivor's batch is what the dying server was serving; it
    // must fail over, not hang.
    let surv_env = rig.surv_env.clone();
    let surv = Connection::connect(&surv_env, "crash-midserve").unwrap();
    let pending = std::thread::spawn(move || {
        surv_env.run(|| {
            let vals: Vec<u64> = (0..8).collect();
            let t0 = Instant::now();
            let r = surv.call_scalar_batch::<u64>(1, &vals, CallOpts::new());
            (r, t0.elapsed())
        })
    });

    let f = orch.fault_counters();
    let deadline = Instant::now() + Duration::from_secs(2);
    while f.get(FLT_KILLS) == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(f.get(FLT_KILLS), 1, "server kill fired");

    std::thread::sleep(Duration::from_millis(rig.rack.cfg.lease_ttl_ms + 30));
    orch.tick();

    let (r, elapsed) = pending.join().unwrap();
    assert!(
        matches!(r, Err(RpcError::PeerFailed(_))),
        "survivor's pending batch fails over as PeerFailed: {r:?}"
    );
    assert!(
        elapsed < Duration::from_secs(4),
        "fail-over must beat the 5s call timeout, took {elapsed:?}"
    );
    assert_eq!(f.get(FLT_RECOVERIES), 1, "one dead proc (the server)");
    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    print_counters("mid_serve", &f);
    drop(rig.surv);
    rig.server.stop();
    rig.listener.join().unwrap();
}

/// A parked worker-pool thread dies: the pool serves thin until the
/// sweep's heal hook respawns to the high-water mark.
#[test]
fn crash_parked_worker_heals() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_shards(1)
        .ring_slots(8)
        .pool_workers(2)
        .call_timeout(Duration::from_secs(5))
        .open(&senv, "crash-worker")
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    assert!(server.spawn_listeners(1).is_empty(), "pooled channel, no listeners");
    let daemon = Arc::clone(server.core().daemon());
    let pool = daemon.worker_pool(2);
    assert_eq!(pool.worker_count(), 2);

    let cenv = rack.proc_env(1);
    let conn = Connection::connect(&cenv, "crash-worker").unwrap();
    let r = cenv.run(|| conn.call_scalar::<u64>(1, &1, CallOpts::new()));
    assert_eq!(r.unwrap(), 2, "pooled serving works before the kill");

    // Both endpoints survive this scenario — keep their leases fresh
    // so the sweep's only recovery is the pool heal.
    let stop = Arc::new(AtomicBool::new(false));
    let renew =
        spawn_renewer(Arc::clone(&daemon), vec![senv.proc, cenv.proc], Arc::clone(&stop));

    let orch = Arc::clone(&rack.orch);
    let f = orch.fault_counters();
    // Workers cross the park decision every idle loop; no victim
    // filter (pool threads carry no proc identity).
    fault::arm_with_sink(
        FaultPlan::new(KillPoint::ParkedWorker).nth(1 + prop_seed() % 3),
        Arc::downgrade(&orch.fault_counters()),
    );
    let deadline = Instant::now() + Duration::from_secs(2);
    while (f.get(FLT_KILLS) == 0 || pool.worker_count() != 1) && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(f.get(FLT_KILLS), 1, "a parked worker died");
    assert_eq!(pool.worker_count(), 1, "pool is serving thin");

    orch.tick();
    assert_eq!(pool.worker_count(), 2, "heal respawned to the high-water mark");
    assert_eq!(f.get(FLT_RECOVERIES), 1, "healed worker counts as the recovery");

    let r = cenv.run(|| conn.call_scalar::<u64>(1, &10, CallOpts::new()));
    assert_eq!(r.unwrap(), 11, "pooled serving works after the heal");
    print_counters("parked_worker", &f);
    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    drop(conn);
    server.stop();
}

/// The client-side failure plane end to end: bounded idempotent
/// retries against a dead peer (counted), then reconnect-with-backoff
/// to the channel's replacement (counted).
#[test]
fn retrying_client_reconnects_after_server_crash() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let server = Rpc::open(&senv, "phoenix").unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let listener = server.spawn_listener();
    let daemon = Arc::clone(server.core().daemon());
    let orch = Arc::clone(&rack.orch);
    let f = orch.fault_counters();

    let cenv = rack.proc_env(1);
    let conn = Connection::connect(&cenv, "phoenix").unwrap();
    let r = cenv.run(|| conn.call_scalar::<u64>(1, &1, CallOpts::new()));
    assert_eq!(r.unwrap(), 2);

    // Server crashes: only the client renews; the sweep fails the peer.
    let stop = Arc::new(AtomicBool::new(false));
    let renew = spawn_renewer(Arc::clone(&daemon), vec![cenv.proc], Arc::clone(&stop));
    std::thread::sleep(Duration::from_millis(rack.cfg.lease_ttl_ms + 30));
    orch.tick();

    // Bounded idempotent retries against the dead peer: 3 attempts, 2
    // retries, final error stays PeerFailed.
    let policy = RetryPolicy::new(3)
        .idempotent()
        .seed(prop_seed())
        .backoff_base(Duration::from_micros(100), Duration::from_millis(2));
    let r = cenv.run(|| conn.call_scalar::<u64>(1, &5, CallOpts::new().retry(policy)));
    assert!(matches!(r, Err(RpcError::PeerFailed(_))), "retries exhaust into PeerFailed: {r:?}");
    assert_eq!(f.get(FLT_RETRIES), 2, "attempts - 1 retries counted");

    // Tear the dead channel fully down so the name frees...
    listener.join().unwrap();
    drop(server);
    drop(conn);
    // ...then reconnect-with-backoff while a replacement comes up.
    let renv = rack.proc_env(0);
    let opener = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(40));
        let s2 = Rpc::open(&renv, "phoenix").unwrap();
        s2.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 100));
        let l2 = s2.spawn_listener();
        (s2, l2)
    });
    let c2env = rack.proc_env(1);
    let policy = RetryPolicy::new(100)
        .idempotent()
        .seed(prop_seed())
        .backoff_base(Duration::from_millis(2), Duration::from_millis(8));
    let c2 = Connection::connect_retry(&c2env, "phoenix", policy)
        .expect("reconnect lands once the replacement opens");
    assert!(f.get(FLT_RECONNECTS) >= 1, "failed connect attempts counted as reconnects");
    let (s2, l2) = opener.join().unwrap();
    let r = c2env.run(|| c2.call_scalar::<u64>(1, &5, CallOpts::new()));
    assert_eq!(r.unwrap(), 105, "replacement channel serves the reconnected client");

    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    drop(c2);
    s2.stop();
    l2.join().unwrap();
}

/// The server dies *between* a sweep's quiet responds and the
/// coalesced flush: the reply is state-complete in the ring but the
/// doorbell never rings. Recovery's `fail_peer` wake must deliver the
/// finished answer — the pending call resolves `Ok`, never
/// `PeerFailed` and never the full call timeout.
#[test]
fn crash_mid_respond_server() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let rig = crash_rig("crash-midrespond");
    let orch = Arc::clone(&rig.rack.orch);

    let stop = Arc::new(AtomicBool::new(false));
    let renew =
        spawn_renewer(Arc::clone(&rig.daemon), vec![rig.surv_env.proc], Arc::clone(&stop));

    fault::arm_with_sink(
        FaultPlan::new(KillPoint::MidRespond).victim(rig.senv.proc).nth(1),
        Arc::downgrade(&orch.fault_counters()),
    );
    let surv_env = rig.surv_env.clone();
    let surv = Connection::connect(&surv_env, "crash-midrespond").unwrap();
    let pending = std::thread::spawn(move || {
        surv_env.run(|| {
            let t0 = Instant::now();
            let r = surv.call_scalar::<u64>(1, &7, CallOpts::new());
            (r, t0.elapsed())
        })
    });

    let f = orch.fault_counters();
    let deadline = Instant::now() + Duration::from_secs(2);
    while f.get(FLT_KILLS) == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(f.get(FLT_KILLS), 1, "server kill fired between respond and flush");

    std::thread::sleep(Duration::from_millis(rig.rack.cfg.lease_ttl_ms + 30));
    orch.tick();

    let (r, elapsed) = pending.join().unwrap();
    assert_eq!(
        r.expect("quiet reply was complete — recovery delivers it, not PeerFailed"),
        8,
        "the unflushed response is the real answer"
    );
    assert!(
        elapsed < Duration::from_secs(4),
        "recovery wake must beat the 5s call timeout, took {elapsed:?}"
    );
    assert_eq!(f.get(FLT_RECOVERIES), 1, "one dead proc (the server)");
    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    print_counters("mid_respond", &f);
    drop(rig.surv);
    rig.server.stop();
    rig.listener.join().unwrap();
}

/// The server dies *inside* the probed flush: the signal cost is
/// charged, the response status words are published, but the bell
/// never rings. Same resolution contract as `mid_respond` — the
/// parked caller gets its real answer at recovery.
#[test]
fn crash_post_respond_server() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let rig = crash_rig("crash-postrespond");
    let orch = Arc::clone(&rig.rack.orch);

    let stop = Arc::new(AtomicBool::new(false));
    let renew =
        spawn_renewer(Arc::clone(&rig.daemon), vec![rig.surv_env.proc], Arc::clone(&stop));

    fault::arm_with_sink(
        FaultPlan::new(KillPoint::PostRespond).victim(rig.senv.proc).nth(1),
        Arc::downgrade(&orch.fault_counters()),
    );
    let surv_env = rig.surv_env.clone();
    let surv = Connection::connect(&surv_env, "crash-postrespond").unwrap();
    let pending = std::thread::spawn(move || {
        surv_env.run(|| {
            let t0 = Instant::now();
            let r = surv.call_scalar::<u64>(1, &7, CallOpts::new());
            (r, t0.elapsed())
        })
    });

    let f = orch.fault_counters();
    let deadline = Instant::now() + Duration::from_secs(2);
    while f.get(FLT_KILLS) == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(f.get(FLT_KILLS), 1, "server kill fired with the bell unrung");

    std::thread::sleep(Duration::from_millis(rig.rack.cfg.lease_ttl_ms + 30));
    orch.tick();

    let (r, elapsed) = pending.join().unwrap();
    assert_eq!(r.expect("published reply delivered by recovery"), 8);
    assert!(
        elapsed < Duration::from_secs(4),
        "recovery wake must beat the 5s call timeout, took {elapsed:?}"
    );
    assert_eq!(f.get(FLT_RECOVERIES), 1, "one dead proc (the server)");
    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    print_counters("post_respond", &f);
    drop(rig.surv);
    rig.server.stop();
    rig.listener.join().unwrap();
}

/// A cross-pod client dies the instant a DSM page-ownership transfer
/// lands on its node (the CAS succeeded, the proc never used the
/// page). The sweep must reclaim every page the corpse's node owns
/// with an owner-epoch bump, so the corpse's own late CAS — carrying
/// the stale epoch in its compare word — can never land.
#[test]
fn crash_dsm_owner_client() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let rig = crash_rig("crash-dsmowner");
    let orch = Arc::clone(&rig.rack.orch);
    let vic_env = rig.rack.remote_proc_env();
    let vic_proc = vic_env.proc;
    let vic = Connection::connect_with(&vic_env, "crash-dsmowner", TransportSel::Rdma).unwrap();
    assert!(vic.shared.is_dsm(), "out-of-rack victim rides the DSM transport");
    assert_eq!(orch.live_heaps(), rig.heaps_baseline + 1, "victim heap mapped");

    let stop = Arc::new(AtomicBool::new(false));
    let renew = spawn_renewer(
        Arc::clone(&rig.daemon),
        vec![rig.senv.proc, rig.surv_env.proc],
        Arc::clone(&stop),
    );

    // Crossings are transfers *by the victim*: the warm call's
    // server-side faults don't count, so nth=1 is the client-side
    // fault-back of the argument page.
    fault::arm_with_sink(
        FaultPlan::new(KillPoint::DsmOwner).victim(vic_proc).nth(1),
        Arc::downgrade(&orch.fault_counters()),
    );
    let (dsm, addr, server_node, epoch_at_death) = std::thread::spawn(move || {
        vic_env.run(|| {
            let dsm = Arc::clone(vic.shared.dsm.as_ref().unwrap());
            let server_node = vic.shared.server_node;
            let scope = vic.create_scope(4096).unwrap();
            let addr = scope.new_val(5u64).unwrap();
            // Warm call: the server faults the argument page over.
            let r = vic.invoke(1, (addr, 8), CallOpts::new());
            assert_eq!(r.unwrap(), 6, "warm call moves the page to the server node");
            // Second call: the client faults it back — the transfer
            // lands, then the proc dies still owning the page.
            let r = vic.invoke(1, (addr, 8), CallOpts::new());
            assert!(matches!(r, Err(RpcError::Killed(_))), "victim sees Killed: {r:?}");
            std::mem::forget(scope);
            let epoch = dsm.epoch_of(addr);
            vic.crash();
            (dsm, addr, server_node, epoch)
        })
    })
    .join()
    .unwrap();
    let f = orch.fault_counters();
    assert_eq!(f.get(FLT_KILLS), 1, "exactly one injected kill fired");
    assert_eq!(epoch_at_death, Some(0), "live transfers preserve the epoch");

    std::thread::sleep(Duration::from_millis(rig.rack.cfg.lease_ttl_ms + 30));
    orch.tick();
    orch.tick(); // idempotent: reclamation must not double-bump

    assert_eq!(orch.live_heaps(), rig.heaps_baseline, "victim heap reclaimed");
    assert_eq!(f.get(FLT_RECOVERIES), 1, "one dead proc, one recovery");
    let bumps = f.get(FLT_EPOCH_BUMPS);
    let pages = f.get(FLT_PAGES_RECLAIMED);
    assert!(bumps >= 1, "the corpse-owned transfer page was reclaimed");
    assert_eq!(bumps, pages, "exactly one epoch bump per reclaimed page");
    assert_eq!(
        dsm.owner_of(addr),
        Some(server_node),
        "reclaimed pages swing to the surviving server's node"
    );
    assert_eq!(dsm.epoch_of(addr), Some(1), "reclamation advanced the owner epoch");
    rig.assert_survivor_liveness("crash-dsmowner");
    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    print_counters("dsm_owner", &f);
    rig.teardown();
}

/// The tentpole's resurrection path: a channel opened with a standby
/// dies mid-serve; the sweep's death hook adopts it instead of
/// tearing it down. The in-flight idempotent call completes `Ok`
/// through its `RetryPolicy` — no `PeerFailed` ever surfaces — within
/// one lease TTL + sweep, on the *same* client connection.
#[test]
fn standby_adopts_channel_after_server_crash() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let standby_env = rack.proc_env(0); // same pod, fresh proc
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_shards(1)
        .ring_slots(8)
        .shared_heap(true)
        .call_timeout(Duration::from_secs(5))
        .standby(&standby_env)
        .open(&senv, "crash-standby")
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let listener = server.spawn_listener();
    let daemon = Arc::clone(server.core().daemon());
    let orch = Arc::clone(&rack.orch);
    let f = orch.fault_counters();

    let cenv = rack.proc_env(1);
    let conn = Arc::new(Connection::connect(&cenv, "crash-standby").unwrap());
    let r = cenv.run(|| conn.call_scalar::<u64>(1, &1, CallOpts::new()));
    assert_eq!(r.unwrap(), 2, "primary serves before the crash");

    // Only the client renews: the primary's lease lapses, and the
    // standby acquires its own fresh leases at adoption time.
    let stop = Arc::new(AtomicBool::new(false));
    let renew = spawn_renewer(Arc::clone(&daemon), vec![cenv.proc], Arc::clone(&stop));

    fault::arm_with_sink(
        FaultPlan::new(KillPoint::MidServe).victim(senv.proc).nth(1),
        Arc::downgrade(&orch.fault_counters()),
    );
    let policy = RetryPolicy::new(8)
        .idempotent()
        .seed(prop_seed())
        .backoff_base(Duration::from_millis(1), Duration::from_millis(8));
    let cc = Arc::clone(&conn);
    let ce = cenv.clone();
    let pending = std::thread::spawn(move || {
        ce.run(|| {
            let t0 = Instant::now();
            let r = cc.call_scalar::<u64>(1, &7, CallOpts::new().retry(policy));
            (r, t0.elapsed())
        })
    });

    let deadline = Instant::now() + Duration::from_secs(2);
    while f.get(FLT_KILLS) == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(f.get(FLT_KILLS), 1, "primary died mid-serve");

    std::thread::sleep(Duration::from_millis(rack.cfg.lease_ttl_ms + 30));
    orch.tick();
    orch.tick(); // idempotent: one adoption, not two

    let (r, elapsed) = pending.join().unwrap();
    assert_eq!(
        r.expect("idempotent in-flight call completes on the adopted standby"),
        8,
        "no PeerFailed: the retry lands on the resurrected channel"
    );
    assert!(
        elapsed < Duration::from_secs(4),
        "resurrection must complete within one TTL + sweep, took {elapsed:?}"
    );
    assert_eq!(f.get(FLT_ADOPTIONS), 1, "exactly one standby adoption");
    assert_eq!(f.get(FLT_RECOVERIES), 1, "one dead proc swept");
    assert!(
        f.get(FLT_SLOTS_REAPED) >= 1,
        "the mid-serve slot was answered ST_CLOSED by the adoption reap"
    );

    // The same connection keeps serving with no retry needed, and a
    // fresh connect lands on the resurrected endpoint.
    let r = cenv.run(|| conn.call_scalar::<u64>(1, &41, CallOpts::new()));
    assert_eq!(r.unwrap(), 42, "adopted channel serves the surviving connection");
    let fenv = rack.proc_env(1);
    let fresh = Connection::connect(&fenv, "crash-standby").expect("fresh connect after adoption");
    let r = fenv.run(|| fresh.call_scalar::<u64>(1, &9, CallOpts::new()));
    assert_eq!(r.unwrap(), 10, "adopted channel accepts new connections");

    let adopted = RpcServer::take_adopted(&standby_env, "crash-standby")
        .expect("adoption parked the resurrected server handle");
    assert_eq!(adopted.core().env.proc, standby_env.proc, "adopted under the standby identity");
    print_counters("standby_adoption", &f);
    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    drop((conn, fresh));
    adopted.stop();
    listener.join().unwrap();
}

/// Satellite S3: a batch killed mid-chunk on the server side. The
/// adoption reap must consume-or-abandon every published slot —
/// quiet-replied, mid-serve, and never-claimed alike — so the batch
/// resolves promptly and its idempotent retry completes in full
/// against the resurrected server.
#[test]
fn batch_killed_mid_chunk_completes_on_adopted_standby() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let standby_env = rack.proc_env(0);
    let server = ChannelBuilder::from_config(&rack.cfg)
        .ring_shards(1)
        .ring_slots(8)
        .shared_heap(true)
        .call_timeout(Duration::from_secs(5))
        .standby(&standby_env)
        .open(&senv, "crash-standby-batch")
        .unwrap();
    server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let listener = server.spawn_listener();
    let daemon = Arc::clone(server.core().daemon());
    let orch = Arc::clone(&rack.orch);
    let f = orch.fault_counters();

    let cenv = rack.proc_env(1);
    let conn = Connection::connect(&cenv, "crash-standby-batch").unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let renew = spawn_renewer(Arc::clone(&daemon), vec![cenv.proc], Arc::clone(&stop));

    // Die on a seeded request of the first chunk: some slots are
    // quiet-replied, one is stranded PROCESSING, the rest sit
    // published and unclaimed.
    fault::arm_with_sink(
        FaultPlan::new(KillPoint::MidServe).victim(senv.proc).nth(1 + prop_seed() % 3),
        Arc::downgrade(&orch.fault_counters()),
    );
    let policy = RetryPolicy::new(8)
        .idempotent()
        .seed(prop_seed())
        .backoff_base(Duration::from_millis(1), Duration::from_millis(8));
    let ce = cenv.clone();
    let pending = std::thread::spawn(move || {
        ce.run(|| {
            let vals: Vec<u64> = (0..64).collect();
            let r = conn.call_scalar_batch::<u64>(1, &vals, CallOpts::new().retry(policy));
            (r, conn)
        })
    });

    let deadline = Instant::now() + Duration::from_secs(2);
    while f.get(FLT_KILLS) == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(f.get(FLT_KILLS), 1, "server died inside the first chunk");

    std::thread::sleep(Duration::from_millis(rack.cfg.lease_ttl_ms + 30));
    orch.tick();

    let (r, conn) = pending.join().unwrap();
    let rets = r.expect("batch completes idempotently on the adopted standby");
    assert_eq!(rets.len(), 64);
    for (v, got) in (0..64u64).zip(&rets) {
        assert_eq!(*got, v + 1, "batch element {v} served exactly once after the retry");
    }
    assert_eq!(f.get(FLT_ADOPTIONS), 1, "one standby adoption");
    assert!(f.get(FLT_RETRIES) >= 1, "the batch went through its retry policy");
    assert!(
        f.get(FLT_SLOTS_REAPED) >= 1,
        "every published slot of the killed chunk was consumed or abandoned"
    );

    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    drop(conn);
    let adopted = RpcServer::take_adopted(&standby_env, "crash-standby-batch").unwrap();
    adopted.stop();
    listener.join().unwrap();
}

/// Satellite S2 regression: a *stale* server handle — its proc long
/// dead, its channel name since re-opened by a replacement — is
/// finally dropped. The drop must not unregister the replacement's
/// channel, evict its directory entry, or otherwise resurface the old
/// latched death on connections to the replacement.
#[test]
fn late_drop_of_dead_server_handle_does_not_clobber_replacement() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let rack = Rack::for_tests();
    let aenv = rack.proc_env(0);
    let a = Rpc::open(&aenv, "stale-latch").unwrap();
    a.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
    let al = a.spawn_listener();
    let daemon = Arc::clone(a.core().daemon());
    let orch = Arc::clone(&rack.orch);

    let cenv = rack.proc_env(1);
    let c1 = Connection::connect(&cenv, "stale-latch").unwrap();
    assert_eq!(cenv.run(|| c1.call_scalar::<u64>(1, &1, CallOpts::new())).unwrap(), 2);

    // A's lease lapses (only the client renews); the sweep tears the
    // channel down and the old connection latches PeerFailed.
    let stop = Arc::new(AtomicBool::new(false));
    let renew = spawn_renewer(Arc::clone(&daemon), vec![cenv.proc], Arc::clone(&stop));
    std::thread::sleep(Duration::from_millis(rack.cfg.lease_ttl_ms + 30));
    orch.tick();
    let r = cenv.run(|| c1.call_scalar::<u64>(1, &2, CallOpts::new()));
    assert!(matches!(r, Err(RpcError::PeerFailed(_))), "old connection latched: {r:?}");
    al.join().unwrap();

    // A replacement re-opens the name; a retrying client reconnects.
    let benv = rack.proc_env(0);
    let b = Rpc::open(&benv, "stale-latch").expect("name freed by the sweep");
    b.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 100));
    let bl = b.spawn_listener();
    let policy = RetryPolicy::new(10)
        .idempotent()
        .seed(prop_seed())
        .backoff_base(Duration::from_millis(1), Duration::from_millis(4));
    let c2 = Connection::connect_retry(&cenv, "stale-latch", policy).unwrap();
    assert_eq!(cenv.run(|| c2.call_scalar::<u64>(1, &1, CallOpts::new())).unwrap(), 101);

    // The stale handle drops *after* the replacement is serving. Its
    // teardown must be identity-guarded no-ops: the registration
    // belongs to B's proc, the directory entry to B's core.
    drop(c1);
    drop(a);

    // Regression: first call on the live connection after the stale
    // drop — no latched dead_err / PeerFailed may resurface.
    let r = cenv.run(|| c2.call_scalar::<u64>(1, &5, CallOpts::new()));
    assert_eq!(r.expect("no stale death latched onto the replacement"), 105);
    // And the name still resolves for brand-new clients.
    let fenv = rack.proc_env(1);
    let c3 = Connection::connect(&fenv, "stale-latch")
        .expect("stale drop must not evict the replacement's registration");
    assert_eq!(fenv.run(|| c3.call_scalar::<u64>(1, &9, CallOpts::new())).unwrap(), 109);

    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    drop((c2, c3));
    b.stop();
    bl.join().unwrap();
}

/// Satellite S1: a randomized kill schedule — every iteration arms a
/// *fresh* seeded plan (depth drawn from `PROP_SEED`, different salt
/// per iteration) against a fresh victim, and the books must balance
/// cumulatively: kills == recoveries after every sweep, and the
/// channel keeps serving survivors throughout.
#[test]
fn randomized_fault_schedule_balances_books() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let _d = DisarmGuard;
    let rig = crash_rig("crash-sched");
    let orch = Arc::clone(&rig.rack.orch);
    let f = orch.fault_counters();

    let stop = Arc::new(AtomicBool::new(false));
    let renew = spawn_renewer(
        Arc::clone(&rig.daemon),
        vec![rig.senv.proc, rig.surv_env.proc],
        Arc::clone(&stop),
    );

    let points = [KillPoint::PreFlush, KillPoint::MidBatch, KillPoint::HoldingSeal];
    for (i, point) in points.iter().enumerate() {
        let vic_env = rig.rack.proc_env(1);
        let vic = Connection::connect(&vic_env, "crash-sched").unwrap();
        let salt = prop_seed() ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        fault::arm_with_sink(
            FaultPlan::seeded(*point, salt, 3).victim(vic_env.proc),
            Arc::downgrade(&orch.fault_counters()),
        );
        let point = *point;
        std::thread::spawn(move || {
            vic_env.run(|| {
                match point {
                    KillPoint::HoldingSeal => {
                        let scope = vic.create_scope(4096).unwrap();
                        let addr = scope.new_val(5u64).unwrap();
                        let mut killed = false;
                        for _ in 0..5 {
                            match vic.invoke(1, (addr, 8), CallOpts::new().sealed(&scope)) {
                                Ok(r) => assert_eq!(r, 6),
                                Err(RpcError::Killed(_)) => {
                                    killed = true;
                                    break;
                                }
                                Err(e) => panic!("unexpected sealed-call error: {e:?}"),
                            }
                        }
                        assert!(killed, "seeded kill must fire within the sealed loop");
                        std::mem::forget(scope);
                    }
                    _ => {
                        let vals: Vec<u64> = (0..64).collect();
                        let r = vic.call_scalar_batch::<u64>(1, &vals, CallOpts::new());
                        assert!(matches!(r, Err(RpcError::Killed(_))), "Killed: {r:?}");
                    }
                }
                vic.crash();
            })
        })
        .join()
        .unwrap();
        assert_eq!(f.get(FLT_KILLS), i as u64 + 1, "iteration {i}: fresh plan fired");
        assert!(!fault::armed(), "iteration {i}: injector auto-disarmed");

        std::thread::sleep(Duration::from_millis(rig.rack.cfg.lease_ttl_ms + 30));
        orch.tick();
        assert_eq!(
            f.get(FLT_RECOVERIES),
            i as u64 + 1,
            "iteration {i}: books balance after the sweep"
        );
    }
    assert_eq!(f.get(FLT_KILLS), f.get(FLT_RECOVERIES), "cumulative balance");
    rig.assert_survivor_liveness("crash-sched");
    stop.store(true, Ordering::Release);
    renew.join().unwrap();
    rig.teardown();
}
