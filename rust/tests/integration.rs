//! Integration tests: whole-stack scenarios composing channels,
//! protection, orchestration, transports, and applications —
//! the cross-module behaviours no unit test covers.

use rpcool::apps::cooldb::{serve_rpcool as cooldb_serve, CoolClient, CoolIndex, RpcoolCool};
use rpcool::apps::doc::Val;
use rpcool::apps::memcached::{serve_rpcool as mc_serve, Cache, KvClient, RpcoolKv};
use rpcool::channel::{CallOpts, Connection, Rpc, TransportSel};
use rpcool::memory::{ShmPtr, ShmString};
use rpcool::orchestrator::Notification;
use rpcool::workloads::nobench::NumRangeQuery;
use rpcool::{Rack, RpcError, SimConfig};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// The paper's Figure 6 program with real threads on both sides.
#[test]
fn fig6_pingpong_with_live_listener() {
    let rack = Rack::for_tests();
    let env = rack.proc_env(0);
    let rpc = Rpc::open(&env, "it/mychannel").unwrap();
    rpc.serve::<ShmString, ShmString>(100, |ctx, ping| {
        assert!(ping.eq_str("ping"));
        ShmString::from_str(ctx.heap, "pong")
    });
    let t = rpc.spawn_listener();
    let cenv = rack.proc_env(1);
    let conn = Rpc::connect(&cenv, "it/mychannel").unwrap();
    cenv.run(|| {
        for _ in 0..100 {
            let ping = ShmString::from_str(conn.heap().as_ref(), "ping").unwrap();
            let pong: ShmString =
                conn.call_typed(100, &ping, CallOpts::new()).unwrap().take().unwrap();
            assert!(pong.eq_str("pong"));
        }
    });
    drop(conn);
    rpc.stop();
    t.join().unwrap();
}

/// End-to-end failure story: crash → lease expiry via background
/// ticker → notification → heap reclaimed after survivors close.
#[test]
fn crash_recovery_with_background_ticker() {
    let mut cfg = SimConfig::for_tests();
    cfg.lease_ttl_ms = 80;
    cfg.lease_renew_ms = 20;
    let rack = Rack::new(cfg);
    let _ticker = rack.orch.start_ticker();

    let senv = rack.proc_env(0);
    let server = Rpc::open(&senv, "it/fragile").unwrap();
    server.add(1, |_| Ok(7));
    let t = server.spawn_listener();

    let cenv = rack.proc_env(1);
    let conn = Rpc::connect(&cenv, "it/fragile").unwrap();
    assert_eq!(cenv.run(|| conn.invoke(1, (), CallOpts::new())).unwrap(), 7);
    let heap_id = conn.heap().id;

    // Keep the client's lease fresh while the server dies.
    let daemon_renewal = {
        let orch = Arc::clone(&rack.orch);
        let (heap, client_proc) = (heap_id, cenv.proc);
        std::thread::spawn(move || {
            // The client's librpcool renewal loop.
            for _ in 0..12 {
                std::thread::sleep(Duration::from_millis(20));
                let _ = (heap, client_proc);
                // renewal happens through the connection's daemon in
                // close(); here we renew via orchestrator API.
                let _ = orch.renew(rpcool::orchestrator::LeaseId(2));
            }
        })
    };

    server.stop();
    t.join().unwrap();
    drop(server); // channel unregistered; server lease stops renewing

    std::thread::sleep(Duration::from_millis(250));
    let notes = rack.orch.poll_notifications(cenv.proc);
    assert!(
        notes.iter().any(|n| matches!(n, Notification::PeerFailed { .. })),
        "client must learn of the server's death: {notes:?}"
    );

    // Calls now fail (connection closed by channel teardown).
    let e = cenv.run(|| conn.invoke(1, (), CallOpts::new()));
    assert!(e.is_err());
    drop(conn);
    daemon_renewal.join().unwrap();
    rack.orch.tick();
    assert_eq!(rack.orch.live_heaps(), 0, "orphaned heap reclaimed");
}

/// Quota pressure across several live channels on one proc.
#[test]
fn quota_limits_connections() {
    let mut cfg = SimConfig::for_tests();
    cfg.heap_bytes = 1 << 20;
    cfg.quota_bytes = 2 << 20; // room for two connection heaps
    let rack = Rack::new(cfg);
    let senv = rack.proc_env(0);
    let mut servers = Vec::new();
    for i in 0..3 {
        let s = Rpc::open(&senv, &format!("it/quota{i}")).unwrap();
        s.add(1, |_| Ok(0));
        servers.push(s);
    }
    let cenv = rack.proc_env(1);
    let c1 = Rpc::connect(&cenv, "it/quota0").unwrap();
    let _c2 = Rpc::connect(&cenv, "it/quota1").unwrap();
    let e = Rpc::connect(&cenv, "it/quota2").err();
    assert!(
        matches!(e, Some(RpcError::QuotaExceeded { .. })),
        "third heap must exceed the quota: {e:?}"
    );
    // Closing one frees budget.
    drop(c1);
    assert!(Rpc::connect(&cenv, "it/quota2").is_ok());
}

/// Sealing really prevents a concurrent writer racing the handler.
#[test]
fn seal_blocks_concurrent_sender_mutation() {
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let server = Rpc::open(&senv, "it/race").unwrap();
    // The handler reads the argument twice with a pause between; a
    // sender mutation in the window would be seen.
    server.add(1, |ctx| {
        let p: ShmPtr<u64> = ctx.arg_ptr();
        let v1 = p.read()?;
        std::thread::sleep(Duration::from_millis(20));
        let v2 = p.read()?;
        Ok((v1 == v2) as u64)
    });
    let t = server.spawn_listener();
    let cenv = rack.proc_env(1);
    let conn = Arc::new(Rpc::connect(&cenv, "it/race").unwrap());
    let scope = conn.create_scope(4096).unwrap();
    let addr = scope.new_val(1u64).unwrap();

    // Racing writer on another client thread (same proc identity).
    let stop = Arc::new(AtomicU64::new(0));
    let racer = {
        let stop = Arc::clone(&stop);
        let env2 = cenv.clone();
        std::thread::spawn(move || {
            env2.enter();
            let p: ShmPtr<u64> = ShmPtr::from_addr(addr);
            let mut blocked = 0u64;
            while stop.load(Ordering::Acquire) == 0 {
                if p.write(999).is_err() {
                    blocked += 1;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            blocked
        })
    };
    std::thread::sleep(Duration::from_millis(5));
    let consistent =
        cenv.run(|| conn.invoke(1, (addr, 8), CallOpts::new().sealed(&scope))).unwrap();
    assert_eq!(consistent, 1, "handler must see a stable sealed value");
    stop.store(1, Ordering::Release);
    let blocked = racer.join().unwrap();
    assert!(blocked > 0, "the racing writer must have been blocked by the seal");
    drop(scope);
    drop(conn);
    server.stop();
    t.join().unwrap();
}

/// CXL and RDMA clients of the *same* channel coexist; the RDMA one
/// pays page migrations, the CXL one doesn't.
#[test]
fn mixed_transport_clients() {
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let server = Rpc::open(&senv, "it/mixed").unwrap();
    server.add(1, |ctx| {
        let v: u64 = ctx.arg_val()?;
        Ok(v + 1)
    });
    let t = server.spawn_listener();

    let near = rack.proc_env(1);
    let c1 = Connection::connect_with(&near, "it/mixed", TransportSel::Auto).unwrap();
    assert!(!c1.shared.is_dsm());
    let far = rack.remote_proc_env();
    let c2 = Connection::connect_with(&far, "it/mixed", TransportSel::Auto).unwrap();
    assert!(c2.shared.is_dsm());

    near.run(|| {
        let a = c1.new_val(10u64).unwrap();
        assert_eq!(c1.invoke(1, a, CallOpts::new()).unwrap(), 11);
    });
    far.run(|| {
        let a = c2.new_val(20u64).unwrap();
        assert_eq!(c2.invoke(1, a, CallOpts::new()).unwrap(), 21);
    });
    let (faults, _) = c2.shared.dsm.as_ref().unwrap().stats();
    assert!(faults > 0);
    drop((c1, c2));
    server.stop();
    t.join().unwrap();
}

/// Memcached atop RPCool with two concurrent client procs.
#[test]
fn memcached_two_clients_consistency() {
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let cache = Cache::new(8);
    let server = mc_serve(&senv, "it/mc", Arc::clone(&cache)).unwrap();
    let t = server.spawn_listener();

    let mut handles = Vec::new();
    for c in 0..2 {
        let rack = Arc::clone(&rack);
        handles.push(std::thread::spawn(move || {
            let env = rack.proc_env(1 + c);
            let kv = RpcoolKv::connect(&env, "it/mc").unwrap();
            env.enter();
            for i in 0..50 {
                kv.set(&format!("c{c}-k{i}"), format!("v{i}").as_bytes()).unwrap();
            }
            for i in 0..50 {
                assert_eq!(
                    kv.get(&format!("c{c}-k{i}")).unwrap(),
                    Some(format!("v{i}").into_bytes())
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(cache.len(), 100);
    server.stop();
    t.join().unwrap();
}

/// CoolDB ownership transfer: documents PUT by a client remain
/// readable via GET/SEARCH after the client disconnects (the channel
/// heap is shared, Fig. 4b).
#[test]
fn cooldb_ownership_survives_client() {
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let index = CoolIndex::new();
    let server = cooldb_serve(&senv, "it/cool", Arc::clone(&index)).unwrap();
    let t = server.spawn_listener();

    {
        let cenv = rack.proc_env(1);
        let db = RpcoolCool::connect(&cenv, "it/cool").unwrap();
        cenv.run(|| {
            for i in 0..20 {
                db.put(
                    &format!("k{i}"),
                    &Val::Obj(vec![("num".into(), Val::Num(i as f64))]),
                )
                .unwrap();
            }
        });
        // client drops here
    }

    let cenv2 = rack.proc_env(2);
    let db2 = RpcoolCool::connect(&cenv2, "it/cool").unwrap();
    cenv2.run(|| {
        assert_eq!(db2.get_num("k7").unwrap(), Some(7.0));
        assert_eq!(db2.search(NumRangeQuery { lo: 0.0, hi: 10.0 }).unwrap(), 10);
    });
    drop(db2);
    server.stop();
    t.join().unwrap();
}

/// Config file → rack → behaviour: an ablation knob (cxl signal cost)
/// must flow through to measured charges.
#[test]
fn config_overrides_flow_to_charges() {
    let mut cfg = SimConfig::for_tests();
    cfg.apply_kv("cxl_signal_ns", "5000").unwrap();
    let rack = Rack::new(cfg);
    let senv = rack.proc_env(0);
    let server = Rpc::open(&senv, "it/knob").unwrap();
    server.add(1, |_| Ok(0));
    let cenv = rack.proc_env(1);
    let conn = Rpc::connect(&cenv, "it/knob").unwrap();
    conn.attach_inline(&server);
    let before = rack.pool.charger.total_charged_ns();
    cenv.run(|| conn.invoke(1, (), CallOpts::new())).unwrap();
    let delta = rack.pool.charger.total_charged_ns() - before;
    assert!(delta >= 10_000, "2× overridden signal cost must be charged, got {delta}");
}

/// The PJRT-served model behind an RPCool channel (requires `make
/// artifacts`; skips otherwise). The full three-layer stack.
#[test]
fn inference_over_rpcool_e2e() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("model.hlo.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = rpcool::runtime::PjrtRuntime::cpu().unwrap();
    let model = Arc::new(rpcool::runtime::ModelBundle::load(&rt, &dir).unwrap());
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let server = rpcool::inference::serve_model(&senv, "it/llm", Arc::clone(&model)).unwrap();
    let t = server.spawn_listener();
    let cenv = rack.proc_env(1);
    let client = rpcool::inference::InferenceClient::connect(
        &cenv,
        "it/llm",
        model.cfg.seq,
        model.cfg.vocab,
    )
    .unwrap();
    cenv.run(|| {
        let out = client.generate(&[5, 6, 7], 3).unwrap();
        assert_eq!(out.len(), 6);
    });
    drop(client);
    server.stop();
    t.join().unwrap();
}
