//! Adversarial tests: the attack scenarios the paper's safety
//! mechanisms exist to stop (§4.3–§4.5, §5.5). Every test stages an
//! actual attack against a live server and asserts containment.

use rpcool::channel::{CallOpts, ChannelBuilder, Connection, Rpc};
use rpcool::memory::{ShmList, ShmPtr};
use rpcool::orchestrator::Acl;
use rpcool::{Rack, RpcError, SimConfig};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// §4.3's headline attack: a linked list whose tail points at a
/// secret inside the server's address space. The sandboxed handler
/// must fail the traversal rather than aggregate the secret.
#[test]
fn linked_list_tail_aimed_at_server_secret() {
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let server = Rpc::open(&senv, "atk/list").unwrap();

    // The server's "secret key" lives in the connection heap region
    // the server uses for its own state (outside any argument scope).
    let leaked = Arc::new(AtomicU64::new(0));
    let l2 = Arc::clone(&leaked);
    server.add(1, move |ctx| {
        let list: ShmList<u64> = ctx.arg_ptr::<ShmList<u64>>().read()?;
        let sum: u64 = list.iter_collect()?.iter().sum();
        l2.store(sum, Ordering::Relaxed); // would include the secret
        Ok(sum)
    });
    let t = server.spawn_listener();

    let cenv = rack.proc_env(1);
    let conn = Rpc::connect(&cenv, "atk/list").unwrap();
    cenv.run(|| {
        let secret_addr = conn.heap().new_val(0x5EC_0001u64).unwrap();
        let scope = conn.create_scope(8192).unwrap();
        let mut evil: ShmList<u64> = ShmList::new();
        for i in 1..=3 {
            evil.push_back(&scope, i).unwrap();
        }
        evil.corrupt_tail(secret_addr).unwrap();
        let addr = scope.new_val(evil).unwrap();

        // Without the sandbox the traversal would reach the secret;
        // with it, the RPC returns a sandbox-violation error.
        let r = conn.invoke(1, (addr, 64), CallOpts::secure(&scope));
        assert!(
            matches!(r, Err(RpcError::SandboxViolation { .. })),
            "attack must be contained: {r:?}"
        );
    });
    assert_eq!(leaked.load(Ordering::Relaxed), 0, "secret must not be aggregated");
    drop(conn);
    server.stop();
    t.join().unwrap();
}

/// §4.5: a sender mutating arguments mid-flight. With sealing the
/// mutation is blocked; the unsealed control shows the race is real.
#[test]
fn toctou_argument_swap_blocked_by_seal() {
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let server = Rpc::open(&senv, "atk/toctou").unwrap();
    // Validate-then-use handler: reads a length field twice.
    server.add(1, |ctx| {
        let p: ShmPtr<u64> = ctx.arg_ptr();
        let validated = p.read()?;
        if validated > 100 {
            return Err(RpcError::Remote("rejected at validation".into()));
        }
        std::thread::sleep(std::time::Duration::from_millis(15));
        let used = p.read()?; // TOCTOU window
        Ok((validated == used) as u64)
    });
    let t = server.spawn_listener();

    let cenv = rack.proc_env(1);
    let conn = Arc::new(Rpc::connect(&cenv, "atk/toctou").unwrap());
    let scope = conn.create_scope(4096).unwrap();
    let addr = scope.new_val(5u64).unwrap();

    // Attacker thread flips the value during the handler's window.
    let stop = Arc::new(AtomicU64::new(0));
    let attacker = {
        let stop = Arc::clone(&stop);
        let env2 = cenv.clone();
        std::thread::spawn(move || {
            env2.enter();
            let p: ShmPtr<u64> = ShmPtr::from_addr(addr);
            while stop.load(Ordering::Acquire) == 0 {
                let _ = p.write(10_000); // bypass validation if it lands
                std::hint::spin_loop();
            }
        })
    };

    // Sealed call: the attacker cannot write; handler sees one value.
    let stable =
        cenv.run(|| conn.invoke(1, (addr, 8), CallOpts::new().sealed(&scope))).unwrap();
    assert_eq!(stable, 1, "sealed argument must be immutable in flight");
    stop.store(1, Ordering::Release);
    attacker.join().unwrap();
    drop(scope);
    drop(conn);
    server.stop();
    t.join().unwrap();
}

/// A sender lying about a seal: FLAG_SEALED with a bogus descriptor
/// index must be rejected by receiver-side verification (§5.3).
#[test]
fn forged_seal_descriptor_rejected() {
    use rpcool::channel::ring::{FLAG_SEALED, SLOT_RESPONSE, ST_SEAL_INVALID};
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let server = Rpc::open(&senv, "atk/forge").unwrap();
    server.add(1, |_| Ok(42));
    let cenv = rack.proc_env(1);
    let conn = Connection::connect(&cenv, "atk/forge").unwrap();
    conn.attach_inline(&server);
    cenv.enter();

    // Handcraft a "sealed" request with a descriptor idx that was
    // never sealed (shard 0's ring; the attack bypasses striping).
    let arg = conn.heap().new_val(7u64).unwrap();
    let ring = conn.shared.ring();
    let slot = ring.claim().unwrap();
    ring.publish(slot, 1, FLAG_SEALED, 12345, arg, 8);
    // Drive the server inline.
    while ring.slot(slot).state.load(Ordering::Acquire) != SLOT_RESPONSE {
        if let Some(i) = ring.take_request() {
            server.core().handle_slot(&conn.shared, 0, i);
        }
    }
    let (status, _) = ring.consume(slot);
    assert_eq!(status, ST_SEAL_INVALID, "forged seal must be refused");
    drop(conn);
    server.stop();
}

/// PR 2's fault plumbing, staged as an attack: when a sandboxed
/// handler chases an attacker-controlled pointer out of its window,
/// the *real* fault address and the *real* sandbox window must
/// round-trip through `respond_fault`/`consume_detail` to the
/// caller's `RpcError::SandboxViolation` — and an unknown function id
/// must come back verbatim in `NoSuchHandler`. Runs on a sharded
/// connection with two listener workers, so the detail words survive
/// the striped data path too.
#[test]
fn fault_detail_reaches_caller_with_real_addresses() {
    let mut cfg = SimConfig::for_tests();
    cfg.ring_shards = 2;
    let rack = Rack::new(cfg);
    let senv = rack.proc_env(0);
    let server = Rpc::open(&senv, "atk/fault-detail").unwrap();
    // The handler dereferences whatever address the argument names —
    // the attacker aims it at a server-side secret.
    server.add(1, |ctx| {
        let target: u64 = ctx.arg_val()?;
        let v: u64 = ShmPtr::<u64>::from_addr(target as usize).read()?;
        Ok(v)
    });
    let listeners = server.spawn_listeners(2);

    let cenv = rack.proc_env(1);
    let conn = Rpc::connect(&cenv, "atk/fault-detail").unwrap();
    assert_eq!(conn.shared.shard_count(), 2, "config shard knob must reach the connection");
    cenv.run(|| {
        let secret = conn.heap().new_val(0x5EC2u64).unwrap();
        let scope = conn.create_scope(4096).unwrap();
        let addr = scope.new_val(secret as u64).unwrap();
        match conn.invoke(1, (addr, 8), CallOpts::secure(&scope)) {
            Err(RpcError::SandboxViolation { addr: fault, lo, hi }) => {
                assert_eq!(fault, secret, "fault address must name the attacked secret");
                assert!(lo != 0 && hi > lo, "sandbox window must come back: [{lo:#x},{hi:#x})");
                assert!(
                    fault < lo || fault >= hi,
                    "reported address must lie outside the reported window"
                );
            }
            other => panic!("expected detailed sandbox violation, got {other:?}"),
        }
        // Func-id plumbing: the id of a missing handler survives the
        // wire into the typed error.
        let e = conn.call_scalar::<u64>(0xBEEF, &1, CallOpts::new());
        assert!(matches!(e, Err(RpcError::NoSuchHandler(0xBEEF))), "got {e:?}");
    });
    drop(conn);
    server.stop();
    for l in listeners {
        l.join().unwrap();
    }
}

/// §5.5: applications may not mprotect connection-heap pages (that
/// would let a sender unseal its own pages behind the kernel's back).
#[test]
fn app_mprotect_on_heap_denied() {
    let rack = Rack::for_tests();
    let daemon = rpcool::daemon::Daemon::new(0, Arc::clone(&rack.orch));
    let heap = daemon.create_heap("atk/mprot", 1 << 20, 1).unwrap();
    let e = daemon.try_app_mprotect(heap.base());
    assert!(matches!(e, Err(RpcError::AccessDenied(_))));
}

/// ACL bypass attempt: a uid without connect permission.
#[test]
fn acl_gates_connection() {
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let mut acl = Acl::private(senv.uid);
    // Grant exactly one other uid.
    let friend = rack.proc_env(1);
    acl.grant(friend.uid, rpcool::orchestrator::Mode::RWC);
    let server = ChannelBuilder::from_config(&rack.cfg).acl(acl).open(&senv, "atk/acl").unwrap();
    server.add(1, |_| Ok(0));
    let _t = server.spawn_listener();

    assert!(Connection::connect(&friend, "atk/acl").is_ok());
    let stranger = rack.proc_env(2);
    assert!(matches!(
        Connection::connect(&stranger, "atk/acl"),
        Err(RpcError::AccessDenied(_))
    ));
    server.stop();
}

/// Resource-exhaustion: a malicious client trying to hoard shared
/// memory across many connections is stopped by the quota; a scope
/// bomb inside one heap is stopped by heap exhaustion, not pool death.
#[test]
fn hoarding_and_scope_bombs_contained() {
    let mut cfg = SimConfig::for_tests();
    cfg.heap_bytes = 1 << 20;
    cfg.quota_bytes = 4 << 20;
    let rack = Rack::new(cfg);
    let senv = rack.proc_env(0);
    let mut servers = Vec::new();
    for i in 0..8 {
        let s = Rpc::open(&senv, &format!("atk/hoard{i}")).unwrap();
        s.add(1, |_| Ok(0));
        servers.push(s);
    }
    let attacker = rack.proc_env(1);
    let mut conns = Vec::new();
    let mut denied = false;
    for i in 0..8 {
        match Rpc::connect(&attacker, &format!("atk/hoard{i}")) {
            Ok(c) => {
                c.attach_inline(&servers[i]);
                conns.push(c)
            }
            Err(RpcError::QuotaExceeded { .. }) => {
                denied = true;
                break;
            }
            Err(e) => panic!("unexpected: {e}"),
        }
    }
    assert!(denied, "quota must stop the hoarder");
    assert!(conns.len() >= 2, "some connections must fit the quota");

    // Scope bomb within one heap: exhausts that heap only.
    let victim_conn = &conns[0];
    let mut scopes = Vec::new();
    loop {
        match victim_conn.create_scope(64 * 1024) {
            Ok(s) => scopes.push(s),
            Err(RpcError::OutOfMemory { .. }) => break,
            Err(e) => panic!("unexpected: {e}"),
        }
        assert!(scopes.len() < 1000, "heap must exhaust before the pool");
    }
    // Other connections still work.
    attacker.run(|| conns[1].invoke(1, (), CallOpts::new())).unwrap();
}

/// Cross-pod isolation (cluster plane): an attacker in pod 1 holds a
/// DSM-backed connection to a pod-0 server and sends a dangling
/// address aimed at a secret inside a heap *its pod never mapped* (a
/// pod-0-private heap). The transport must have auto-selected
/// RDMA/DSM for the cross-pod hop, and the sandboxed handler must
/// refuse the dereference — pod boundaries don't weaken the
/// connection-heap sandbox, they add a second fence outside it.
#[test]
fn cross_pod_dangling_pointer_contained() {
    let mut cfg = SimConfig::for_tests();
    cfg.rack_hosts = 4;
    cfg.pods = 2;
    let rack = Rack::new(cfg);

    // A pod-0-private heap holding the secret: created through pod 0's
    // daemon and mapped nowhere else — in particular never into the
    // attacker's pod.
    let daemon0 = rpcool::daemon::Daemon::new(0, Arc::clone(&rack.orch));
    assert_eq!(daemon0.pod, 0);
    let private = daemon0.create_heap("atk/xpod-private", 1 << 20, 99).unwrap();
    let secret = private.new_val(0x5EC_2026u64).unwrap();

    // Victim server in pod 0; handler dereferences whatever address
    // the argument names (the fault-detail handler, reused as bait).
    let senv = rack.pod_env(0, 0);
    let server = Rpc::open(&senv, "atk/xpod").unwrap();
    server.add(1, |ctx| {
        let target: u64 = ctx.arg_val()?;
        let v: u64 = ShmPtr::<u64>::from_addr(target as usize).read()?;
        Ok(v)
    });
    let t = server.spawn_listener();

    // Attacker in pod 1: the same `connect` call sites any in-pod
    // client uses, but the topology forces the DSM data path.
    let aenv = rack.pod_env(1, 0);
    assert!(!rack.same_cxl_domain(senv.host, aenv.host), "pods must split the CXL domain");
    let conn = Rpc::connect(&aenv, "atk/xpod").unwrap();
    assert!(conn.shared.is_dsm(), "cross-pod connection must ride RDMA/DSM");
    aenv.run(|| {
        let scope = conn.create_scope(4096).unwrap();
        let addr = scope.new_val(secret as u64).unwrap();
        match conn.invoke(1, (addr, 8), CallOpts::secure(&scope)) {
            Err(RpcError::SandboxViolation { addr: fault, lo, hi }) => {
                assert_eq!(fault, secret, "fault must name the pod-0 secret");
                assert!(
                    fault < lo || fault >= hi,
                    "the foreign heap must lie outside the sandbox window"
                );
            }
            other => panic!("cross-pod attack must be contained, got {other:?}"),
        }
    });
    // The DSM machinery moved argument pages, not the foreign heap:
    // the secret is untouched and still pod-0-private.
    assert_eq!(unsafe { *(secret as *const u64) }, 0x5EC_2026);
    drop(conn);
    server.stop();
    t.join().unwrap();
}

/// Malicious *document*: a ShmVal whose string points at an arbitrary
/// address. Sandboxed processing reports an error; the checked reads
/// never touch the wild address unsandboxed either (bounds unknown).
#[test]
fn wild_document_string_contained() {
    use rpcool::apps::doc::{ShmVal, TAG_STR};
    let rack = Rack::for_tests();
    let senv = rack.proc_env(0);
    let server = Rpc::open(&senv, "atk/doc").unwrap();
    server.add(1, |ctx| {
        let doc: ShmVal = ctx.arg_ptr::<ShmVal>().read()?;
        // Server tries to materialize the document (validation pass).
        let v = doc.to_host()?;
        Ok(v.weight() as u64)
    });
    let t = server.spawn_listener();
    let cenv = rack.proc_env(1);
    let conn = Rpc::connect(&cenv, "atk/doc").unwrap();
    cenv.run(|| {
        let scope = conn.create_scope(4096).unwrap();
        // Build a string whose backing vector we then corrupt to point
        // outside the sandbox (at the connection heap's private area).
        let secret = conn.heap().new_val([0xABu8; 32]).unwrap();
        let evil = ShmVal::str(&scope, "harmless").unwrap();
        assert_eq!(evil.tag, TAG_STR);
        let addr = scope.new_val(evil).unwrap();
        unsafe {
            // ShmVal.str's ShmVec data pointer is the first word of
            // the struct after the tag/num fields; forge it to target
            // the secret.
            let sptr = (addr + std::mem::offset_of!(ShmVal, str)) as *mut usize;
            *sptr = secret;
        }
        let r = conn.invoke(1, (addr, std::mem::size_of::<ShmVal>()), CallOpts::secure(&scope));
        assert!(
            matches!(r, Err(RpcError::SandboxViolation { .. })),
            "forged string pointer must violate the sandbox: {r:?}"
        );
    });
    drop(conn);
    server.stop();
    t.join().unwrap();
}
