//! `rpcool` — the launcher binary.
//!
//! Subcommands:
//!   serve   --artifacts DIR [--channel NAME] [--requests N] [--clients K]
//!           Load the AOT model and serve inference over an RPCool
//!           channel, driving K in-process clients (the e2e driver as
//!           a deployable command).
//!   noop    [--n N] [--config FILE] [k=v ...]
//!           No-op RPC latency/throughput (Table 1a's first row).
//!   ycsb    --app memcached|mongodb --workload A..F [--keys N] [--ops N]
//!           One YCSB cell from Figures 9/10.
//!   config  [k=v ...]
//!           Print the effective cost model / knobs.
//!
//! Any trailing `key=value` pairs override the cost model (see
//! `SimConfig::apply_kv`) for ablations.

use rpcool::benchkit::fmt_ns;
use rpcool::channel::{CallOpts, Connection, Rpc};
use rpcool::inference::{serve_model, InferenceClient};
use rpcool::metrics::Histogram;
use rpcool::runtime::{ModelBundle, PjrtRuntime};
use rpcool::workloads::ycsb::WorkloadKind;
use rpcool::{Rack, SimConfig};
use std::sync::Arc;
use std::time::Instant;

fn parse_flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn apply_overrides(cfg: &mut SimConfig, args: &[String]) {
    for a in args {
        if let Some((k, v)) = a.split_once('=') {
            if !k.starts_with("--") {
                if let Err(e) = cfg.apply_kv(k, v) {
                    eprintln!("config override '{a}': {e}");
                    std::process::exit(2);
                }
            }
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let mut cfg = SimConfig::for_bench();
    if let Some(path) = parse_flag(&args, "--config") {
        cfg = SimConfig::from_file(&path).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
    }
    apply_overrides(&mut cfg, &args);

    match cmd {
        "serve" => cmd_serve(&args, cfg),
        "noop" => cmd_noop(&args, cfg),
        "ycsb" => cmd_ycsb(&args, cfg),
        "config" => print!("{}", cfg.dump()),
        _ => {
            eprintln!(
                "usage: rpcool <serve|noop|ycsb|config> [flags] [k=v ...]\n\
                 see `rust/src/main.rs` header for details"
            );
            std::process::exit(2);
        }
    }
}

fn cmd_serve(args: &[String], cfg: SimConfig) {
    let dir = parse_flag(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    let channel = parse_flag(args, "--channel").unwrap_or_else(|| "svc/llm".into());
    let requests: usize =
        parse_flag(args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(64);
    let clients: usize = parse_flag(args, "--clients").and_then(|v| v.parse().ok()).unwrap_or(2);

    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let model = Arc::new(ModelBundle::load(&rt, &dir).expect("artifacts (run `make artifacts`)"));
    println!(
        "model: {} layers / d{} / seq {} / vocab {} ({} params)",
        model.cfg.n_layers,
        model.cfg.d_model,
        model.cfg.seq,
        model.cfg.vocab,
        model.cfg.param_count()
    );
    let rack = Rack::new(cfg);
    let env = rack.proc_env(0);
    let server = serve_model(&env, &channel, Arc::clone(&model)).unwrap();
    let listener = server.spawn_listener();
    println!("serving '{channel}'; driving {clients} clients × {requests} requests");

    let hist = Arc::new(Histogram::new());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let rack = Arc::clone(&rack);
            let hist = Arc::clone(&hist);
            let channel = channel.clone();
            let (seq, vocab) = (model.cfg.seq, model.cfg.vocab);
            s.spawn(move || {
                let env = rack.proc_env(1 + c as u32);
                let cl = InferenceClient::connect(&env, &channel, seq, vocab).unwrap();
                env.enter();
                for i in 0..requests {
                    let t = Instant::now();
                    cl.next_token(&[c as i32 + 1, i as i32]).unwrap();
                    hist.record(t.elapsed());
                }
            });
        }
    });
    let wall = t0.elapsed();
    let total = (clients * requests) as f64;
    println!(
        "{total} requests in {wall:.2?}: {:.1} req/s — p50 {} p99 {}",
        total / wall.as_secs_f64(),
        Histogram::fmt_ns(hist.median_ns()),
        Histogram::fmt_ns(hist.p99_ns())
    );
    server.stop();
    listener.join().unwrap();
}

fn cmd_noop(args: &[String], cfg: SimConfig) {
    let n: usize = parse_flag(args, "--n").and_then(|v| v.parse().ok()).unwrap_or(200_000);
    let rack = Rack::new(cfg);
    let env = rack.proc_env(0);
    let server = Rpc::open(&env, "cli/noop").unwrap();
    server.add(1, |_| Ok(0));
    let cenv = rack.proc_env(1);
    let conn = Connection::connect(&cenv, "cli/noop").unwrap();
    conn.attach_inline(&server);
    cenv.enter();
    for _ in 0..1000 {
        conn.invoke(1, (), CallOpts::new()).unwrap();
    }
    let t0 = Instant::now();
    for _ in 0..n {
        conn.invoke(1, (), CallOpts::new()).unwrap();
    }
    let el = t0.elapsed();
    let per = el.as_nanos() as f64 / n as f64;
    println!("no-op RPC over CXL: {} RTT, {:.2} K req/s", fmt_ns(per), 1e6 / per);
    drop(conn);
    server.stop();
}

fn cmd_ycsb(args: &[String], cfg: SimConfig) {
    let app = parse_flag(args, "--app").unwrap_or_else(|| "memcached".into());
    let wl = parse_flag(args, "--workload").unwrap_or_else(|| "A".into());
    let keys: u64 = parse_flag(args, "--keys").and_then(|v| v.parse().ok()).unwrap_or(10_000);
    let ops: usize = parse_flag(args, "--ops").and_then(|v| v.parse().ok()).unwrap_or(100_000);
    let kind = match wl.as_str() {
        "A" => WorkloadKind::A,
        "B" => WorkloadKind::B,
        "C" => WorkloadKind::C,
        "D" => WorkloadKind::D,
        "E" => WorkloadKind::E,
        "F" => WorkloadKind::F,
        other => {
            eprintln!("unknown workload {other}");
            std::process::exit(2);
        }
    };
    let rack = Rack::new(cfg);
    match app.as_str() {
        "memcached" => {
            use rpcool::apps::memcached::*;
            let env = rack.proc_env(0);
            let cache = Cache::new(16);
            let server = serve_rpcool(&env, "cli/mc", cache).unwrap();
            let cenv = rack.proc_env(1);
            let kv = RpcoolKv::connect(&cenv, "cli/mc").unwrap();
            kv.conn().attach_inline(&server);
            cenv.enter();
            let (load, run) = run_ycsb(&kv, kind, keys, ops, 7).unwrap();
            println!("memcached YCSB-{wl} over RPCool: load {load:.2?}, run {run:.2?}");
            drop(kv);
            server.stop();
        }
        "mongodb" => {
            use rpcool::apps::mongodb::*;
            let env = rack.proc_env(0);
            let store = DocStore::new();
            let server = serve_rpcool(&env, "cli/mongo", store).unwrap();
            let cenv = rack.proc_env(1);
            let db = RpcoolDoc::connect(&cenv, "cli/mongo").unwrap();
            db.conn().attach_inline(&server);
            cenv.enter();
            let (load, run) = run_ycsb(&db, kind, keys, ops, 7).unwrap();
            println!("mongodb YCSB-{wl} over RPCool: load {load:.2?}, run {run:.2?}");
            drop(db);
            server.stop();
        }
        other => {
            eprintln!("unknown app {other}");
            std::process::exit(2);
        }
    }
}
