//! Bench harness shared by `benches/*` (criterion is unavailable in
//! the offline build; this provides the same discipline: warmup,
//! repeated timed runs, percentile reporting, markdown rows) — plus
//! machine-readable output: every bench emits a `BENCH_<name>.json`
//! via [`BenchReport`], so the repo accumulates a perf trajectory
//! (CI uploads them as artifacts; compare runs with a diff).

use crate::metrics::Histogram;
use std::time::{Duration, Instant};

/// Time `op` over `n` per-op-timed iterations after `warmup`
/// iterations; returns (mean ns/op over the whole run, per-op latency
/// histogram). Mean and tail come from the SAME population — pair
/// them freely in one report row.
pub fn time_op(warmup: usize, n: usize, mut op: impl FnMut()) -> (f64, Histogram) {
    for _ in 0..warmup {
        op();
    }
    let hist = Histogram::new();
    let t_all = Instant::now();
    for _ in 0..n {
        let t = Instant::now();
        op();
        hist.record(t.elapsed());
    }
    let mean = t_all.elapsed().as_nanos() as f64 / n as f64;
    (mean, hist)
}

/// Aggregate-only timing: total wall clock / `n`, no per-op
/// measurements at all — right for sub-µs ops where timer overhead
/// would dominate. Deliberately returns NO histogram: a mean is not a
/// latency distribution, and the old shape (a histogram holding one
/// synthetic mean sample) let benches pair a tail from one run with a
/// throughput from another and call it a single population (ISSUE 8).
/// Want tails? Use [`time_op`] or the open-loop runners below.
pub fn time_op_mean(warmup: usize, n: usize, mut op: impl FnMut()) -> f64 {
    for _ in 0..warmup {
        op();
    }
    let t = Instant::now();
    for _ in 0..n {
        op();
    }
    t.elapsed().as_nanos() as f64 / n as f64
}

// ---------------------------------------------------------------------
// open-loop load generation (ISSUE 8 tentpole)
//
// Closed-loop benches measure each op from its actual send time and
// only issue the next op after the reply: a stalled server silently
// *re-schedules* the offered load, so queueing delay never shows up in
// the recorded distribution — coordinated omission. The open-loop
// harness fixes the arrival times up front and measures every op from
// its *scheduled* arrival: if the generator (or the server) falls
// behind, the backlog is carried into the recorded latency instead of
// vanishing. DESIGN.md §13 has the full argument.

/// A send more than this far behind its scheduled arrival counts as
/// late in [`LoadReport::late_sends`] (spin-wait granularity means
/// every send is some tens of ns "late"; 1µs is signal, not jitter).
pub const LATE_SEND_NS: u64 = 1_000;

/// Deterministic arrival plan: offsets in ns from the run's start,
/// non-decreasing. Construction is pure (no clocks, no global RNG) so
/// a schedule replays identically across runs and workers.
#[derive(Clone, Debug)]
pub struct Schedule {
    arrivals: Vec<u64>,
}

impl Schedule {
    /// `n` arrivals at a fixed `rate` per second (uniform interarrival).
    pub fn fixed_rate(n: usize, rate: f64) -> Schedule {
        assert!(rate > 0.0, "offered rate must be positive");
        let gap = 1e9 / rate;
        Schedule { arrivals: (0..n).map(|i| (i as f64 * gap) as u64).collect() }
    }

    /// Bursty plan: arrivals come in back-to-back groups of `burst`,
    /// groups spaced so the long-run offered rate is still `rate` —
    /// the same load as [`Schedule::fixed_rate`] but maximally clumped.
    pub fn bursty(n: usize, rate: f64, burst: usize) -> Schedule {
        assert!(rate > 0.0, "offered rate must be positive");
        assert!(burst > 0, "burst must be at least 1");
        let group_gap = burst as f64 * 1e9 / rate;
        Schedule { arrivals: (0..n).map(|i| ((i / burst) as f64 * group_gap) as u64).collect() }
    }

    /// Poisson-like plan: interarrival gaps drawn exponential with
    /// mean `1/rate` from a seeded generator — an open-loop stream
    /// with natural burstiness, deterministic per seed.
    pub fn poisson(n: usize, rate: f64, seed: u64) -> Schedule {
        assert!(rate > 0.0, "offered rate must be positive");
        let mean_gap = 1e9 / rate;
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut at = 0.0f64;
        let arrivals = (0..n)
            .map(|_| {
                let here = at as u64;
                // Inverse CDF; clamp u away from 0 so ln stays finite.
                let u = rng.next_f64().max(1e-12);
                at += -u.ln() * mean_gap;
                here
            })
            .collect();
        Schedule { arrivals }
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Scheduled arrival offset of op `i`, ns from the run's start.
    pub fn arrival_ns(&self, i: usize) -> u64 {
        self.arrivals[i]
    }

    /// Span from first to last scheduled arrival.
    pub fn span_ns(&self) -> u64 {
        self.arrivals.last().copied().unwrap_or(0)
    }

    /// The offered load this plan encodes, ops/sec.
    pub fn offered_rate(&self) -> f64 {
        if self.arrivals.len() < 2 || self.span_ns() == 0 {
            return 0.0;
        }
        // n arrivals bound (n-1) gaps.
        (self.arrivals.len() - 1) as f64 * 1e9 / self.span_ns() as f64
    }

    /// Worker `w` of `k` takes every k-th arrival (stride partition):
    /// the union of all stripes is exactly the original plan, so the
    /// aggregate offered load is preserved across a fan-out.
    pub fn stripe(&self, w: usize, k: usize) -> Schedule {
        assert!(k > 0 && w < k, "stripe({w}, {k}) out of range");
        Schedule { arrivals: self.arrivals.iter().copied().skip(w).step_by(k).collect() }
    }
}

/// What one load-generator run measured.
pub struct LoadReport {
    /// Per-op latency. Open-loop: from *scheduled* arrival (queueing
    /// visible). Closed-paced: from actual send (queueing hidden —
    /// kept as the coordinated-omission contrast row).
    pub hist: Histogram,
    /// Ops completed.
    pub ops: u64,
    /// Wall clock of the whole run.
    pub wall: Duration,
    /// Sends that happened ≥ [`LATE_SEND_NS`] after their scheduled
    /// arrival — the generator fell behind and the recorded latency
    /// carries the backlog. Always 0 for closed pacing (re-based).
    pub late_sends: u64,
    /// Worst send lateness seen, ns.
    pub max_late_ns: u64,
}

impl LoadReport {
    /// Completion rate, ops/sec.
    pub fn throughput(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.ops as f64 / self.wall.as_secs_f64()
    }

    /// Fold another worker's report into this one.
    pub fn merge(&mut self, other: &LoadReport) {
        self.hist.merge(&other.hist);
        self.ops += other.ops;
        self.wall = self.wall.max(other.wall);
        self.late_sends += other.late_sends;
        self.max_late_ns = self.max_late_ns.max(other.max_late_ns);
    }

    fn empty() -> LoadReport {
        LoadReport {
            hist: Histogram::new(),
            ops: 0,
            wall: Duration::ZERO,
            late_sends: 0,
            max_late_ns: 0,
        }
    }
}

/// Hybrid sleep/spin until `due` (relative to `t0`): sleep the bulk,
/// spin the last stretch so arrival precision stays at spin (~ns)
/// rather than scheduler (~ms) granularity.
fn wait_until(t0: &Instant, due: Duration) {
    loop {
        let now = t0.elapsed();
        if now >= due {
            return;
        }
        let left = due - now;
        if left > Duration::from_micros(200) {
            std::thread::sleep(left - Duration::from_micros(100));
        } else {
            std::hint::spin_loop();
        }
    }
}

/// Open-loop run: issue `op(i)` once per scheduled arrival; latency
/// is completion time minus *scheduled* arrival time. An op that
/// overruns into the next arrival makes the next send late, and that
/// lateness is carried into the next recorded latency — coordinated
/// omission becomes a visible number instead of a silent re-schedule.
pub fn run_open_loop(sched: &Schedule, mut op: impl FnMut(usize)) -> LoadReport {
    let mut rep = LoadReport::empty();
    let t0 = Instant::now();
    for i in 0..sched.len() {
        let due = Duration::from_nanos(sched.arrival_ns(i));
        wait_until(&t0, due);
        let sent = t0.elapsed();
        let late = (sent.saturating_sub(due)).as_nanos() as u64;
        if late >= LATE_SEND_NS {
            rep.late_sends += 1;
        }
        rep.max_late_ns = rep.max_late_ns.max(late);
        op(i);
        let done = t0.elapsed();
        rep.hist.record_ns((done - due).as_nanos() as u64);
        rep.ops += 1;
    }
    rep.wall = t0.elapsed();
    rep
}

/// Closed-loop twin at matched offered load: the SAME interarrival
/// plan, but each gap is paced from the previous op's *completion*
/// and latency is measured from the actual send. This is exactly the
/// methodology that hides queueing (a stall pushes the whole rest of
/// the plan back), kept as the contrast row the open-loop gate pairs
/// against: at matched offered load, open p99 ≥ closed p99, and the
/// gap IS the coordinated omission.
pub fn run_closed_paced(sched: &Schedule, mut op: impl FnMut(usize)) -> LoadReport {
    let mut rep = LoadReport::empty();
    let t0 = Instant::now();
    let mut resume_at = Duration::ZERO;
    let mut prev_arrival = 0u64;
    for i in 0..sched.len() {
        let gap = sched.arrival_ns(i) - prev_arrival;
        prev_arrival = sched.arrival_ns(i);
        wait_until(&t0, resume_at + Duration::from_nanos(gap));
        let sent = t0.elapsed();
        op(i);
        let done = t0.elapsed();
        rep.hist.record_ns((done - sent).as_nanos() as u64);
        rep.ops += 1;
        resume_at = done; // re-base: the next gap starts at completion
    }
    rep.wall = t0.elapsed();
    rep
}

/// Multi-worker load driver: `workers` scoped threads each run the
/// striped sub-plan `sched.stripe(w, workers)` through `run` (which
/// calls [`run_open_loop`] or [`run_closed_paced`] around its own
/// client state) and the per-worker reports are merged. Aggregate
/// offered load equals the full schedule's.
pub fn fanout_load(
    workers: usize,
    sched: &Schedule,
    run: impl Fn(usize, &Schedule) -> LoadReport + Sync,
) -> LoadReport {
    let mut merged = LoadReport::empty();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let sub = sched.stripe(w, workers);
                let run = &run;
                s.spawn(move || run(w, &sub))
            })
            .collect();
        for h in handles {
            merged.merge(&h.join().unwrap());
        }
    });
    merged
}

/// Fan out `threads` copies of `work(thread_idx)` on scoped threads
/// and return the wall-clock of the whole fan-out (i.e. the slowest
/// worker). The multi-threaded benches' shared harness.
pub fn fanout(threads: usize, work: impl Fn(usize) + Sync) -> Duration {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let w = &work;
            s.spawn(move || w(t));
        }
    });
    t0.elapsed()
}

/// Run `op` repeatedly for at least `dur`, returning ops/sec.
pub fn throughput(dur: Duration, mut op: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    let mut n = 0u64;
    while t0.elapsed() < dur {
        for _ in 0..64 {
            op();
        }
        n += 64;
    }
    n as f64 / t0.elapsed().as_secs_f64()
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Markdown table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n### {title}\n");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s += &format!(" {:w$} |", c, w = widths.get(i).copied().unwrap_or(4));
            }
            s
        };
        println!("{}", fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep += &format!("{}|", "-".repeat(w + 2));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

// ---------------------------------------------------------------------
// machine-readable reports

/// One measured configuration in a bench run.
#[derive(Clone, Debug, Default)]
pub struct BenchRow {
    pub label: String,
    /// Median / p99 latency in ns (0 = not measured).
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Deep tail: p99.9 latency in ns (0 = not measured).
    pub p999_ns: f64,
    pub mean_ns: f64,
    /// Operations per second (0 = not measured).
    pub throughput_ops: f64,
    /// Samples over the report's SLO threshold (0 when no SLO set).
    pub slo_miss: f64,
    /// Free-form extra metrics (name, value).
    pub extra: Vec<(String, f64)>,
}

/// Collects rows and writes `BENCH_<name>.json` — the committed /
/// CI-uploaded perf record. JSON is hand-rolled (the build is
/// dependency-free by design).
pub struct BenchReport {
    name: String,
    rows: Vec<BenchRow>,
    /// Latency SLO applied by [`BenchReport::row_hist`] to fill each
    /// row's `slo_miss` column. None → column stays 0.
    slo_ns: Option<u64>,
    /// Histogram rows recorded so far — ordering audit: `slo()` after
    /// the first of these is a bench bug (those rows silently carry
    /// slo_miss 0).
    hist_rows: usize,
    /// One nudge per report when histogram rows accumulate without an
    /// SLO ever being set.
    slo_warned: bool,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON has no NaN/Inf; clamp to 0 so emitted files always parse.
fn json_num(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        BenchReport {
            name: name.to_string(),
            rows: Vec::new(),
            slo_ns: None,
            hist_rows: 0,
            slo_warned: false,
        }
    }

    /// Set the latency SLO for subsequent [`BenchReport::row_hist`]
    /// calls: each row's `slo_miss` column becomes the number of
    /// samples over `ns`. Call it BEFORE the first histogram row —
    /// rows recorded earlier keep slo_miss 0, which is a silent
    /// all-zero SLO column, so a misordered bench warns in release
    /// and panics under `cargo test`/debug CI (ISSUE 8 audit).
    pub fn slo(&mut self, ns: u64) {
        if self.hist_rows > 0 {
            eprintln!(
                "[bench] WARNING: {}: slo() set after {} histogram row(s) — their slo_miss \
                 columns are stuck at 0; move the slo() call before the first row_hist",
                self.name, self.hist_rows
            );
            if cfg!(debug_assertions) {
                panic!(
                    "BenchReport::slo() must run before the first row_hist (bench '{}')",
                    self.name
                );
            }
        }
        self.slo_ns = Some(ns);
    }

    /// Record a latency-style row (throughput derived where the bench
    /// knows it; pass 0.0 for unmeasured fields). The deep-tail /
    /// SLO columns need a histogram — use [`BenchReport::row_hist`]
    /// to fill them; here they stay 0.
    pub fn row(&mut self, label: &str, p50_ns: f64, p99_ns: f64, mean_ns: f64, thr: f64) {
        self.rows.push(BenchRow {
            label: label.to_string(),
            p50_ns,
            p99_ns,
            p999_ns: 0.0,
            mean_ns,
            throughput_ops: thr,
            slo_miss: 0.0,
            extra: Vec::new(),
        });
    }

    /// Record a row from a histogram + ops/sec, including the deep
    /// tail (p99.9) and — when an SLO was set via
    /// [`BenchReport::slo`] — the over-threshold sample count. Every
    /// histogram row also carries a `samples` extra (the population
    /// size) so CI can sanity-check `slo_miss ≤ samples` on any
    /// schema-2 record.
    pub fn row_hist(&mut self, label: &str, hist: &Histogram, thr: f64) {
        assert!(
            hist.count() > 0,
            "row_hist('{label}') on an empty histogram — this population measured nothing \
             (a mean-only timing has no tail; use time_op or the open-loop runners)"
        );
        if self.slo_ns.is_none() && !self.rows.is_empty() && !self.slo_warned {
            eprintln!(
                "[bench] note: {}: histogram rows accumulating with no SLO set — slo_miss \
                 columns stay 0 (call BenchReport::slo(ns) before the first row to fill them)",
                self.name
            );
            self.slo_warned = true;
        }
        self.hist_rows += 1;
        self.rows.push(BenchRow {
            label: label.to_string(),
            p50_ns: hist.median_ns() as f64,
            p99_ns: hist.p99_ns() as f64,
            p999_ns: hist.p999_ns() as f64,
            mean_ns: hist.mean_ns(),
            throughput_ops: thr,
            slo_miss: self.slo_ns.map(|s| hist.count_over_ns(s) as f64).unwrap_or(0.0),
            extra: vec![("samples".to_string(), hist.count() as f64)],
        });
    }

    /// Record a load-generator row: latency columns from the report's
    /// histogram, throughput from completions over wall clock, plus
    /// the offered-load/lateness extras every open- or closed-loop
    /// row must carry (`offered_ops` is what the schedule asked for;
    /// `late_sends`/`max_late_ns` make generator stalls auditable).
    pub fn row_load(&mut self, label: &str, load: &LoadReport, offered: f64) {
        self.row_hist(label, &load.hist, load.throughput());
        self.extra("offered_ops", offered);
        self.extra("late_sends", load.late_sends as f64);
        self.extra("max_late_ns", load.max_late_ns as f64);
    }

    /// Attach an extra metric to the most recent row.
    pub fn extra(&mut self, key: &str, value: f64) {
        if let Some(r) = self.rows.last_mut() {
            r.extra.push((key.to_string(), value));
        }
    }

    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(&self.name)));
        s.push_str("  \"schema\": 2,\n");
        s.push_str("  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"label\": \"{}\", \"p50_ns\": {}, \"p99_ns\": {}, \"p999_ns\": {}, \"mean_ns\": {}, \"throughput_ops\": {}, \"slo_miss\": {}",
                json_escape(&r.label),
                json_num(r.p50_ns),
                json_num(r.p99_ns),
                json_num(r.p999_ns),
                json_num(r.mean_ns),
                json_num(r.throughput_ops),
                json_num(r.slo_miss),
            ));
            for (k, v) in &r.extra {
                s.push_str(&format!(", \"{}\": {}", json_escape(k), json_num(*v)));
            }
            s.push('}');
            if i + 1 < self.rows.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write `BENCH_<name>.json` into `$BENCH_OUT` (or the current
    /// directory) and return the path. Failures are reported, not
    /// fatal — a read-only checkout must not kill the bench.
    pub fn emit(&self) -> Option<std::path::PathBuf> {
        let dir = std::env::var("BENCH_OUT").unwrap_or_else(|_| ".".into());
        self.emit_to(std::path::Path::new(&dir))
    }

    /// Write `BENCH_<name>.json` into an explicit directory.
    pub fn emit_to(&self, dir: &std::path::Path) -> Option<std::path::PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        match std::fs::write(&path, self.to_json()) {
            Ok(()) => {
                println!("\n[bench] wrote {}", path.display());
                Some(path)
            }
            Err(e) => {
                eprintln!("[bench] could not write {}: {e}", path.display());
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_op_measures() {
        let (mean, hist) = time_op(10, 100, || {
            crate::util::spin::spin_ns(10_000);
        });
        assert!(mean > 5_000.0, "mean {mean}");
        assert!(hist.count() == 100);
    }

    #[test]
    fn time_op_mean_has_no_histogram_to_misuse() {
        // The ISSUE 8 fix: aggregate-only timing returns a bare f64 —
        // pairing a mean from one run with a tail from another is now
        // a compile-time impossibility, not a silent convention.
        let mean = time_op_mean(10, 100, || {
            crate::util::spin::spin_ns(5_000);
        });
        assert!(mean > 2_500.0, "mean {mean}");
    }

    #[test]
    fn fanout_runs_every_worker() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let hits = AtomicU64::new(0);
        let wall = fanout(4, |t| {
            hits.fetch_add(1 + t as u64, Ordering::Relaxed);
        });
        // Each worker t contributes 1 + t: 1 + 2 + 3 + 4.
        assert_eq!(hits.load(Ordering::Relaxed), 10);
        assert!(wall > Duration::ZERO);
    }

    #[test]
    fn throughput_positive() {
        let t = throughput(Duration::from_millis(20), || {
            std::hint::black_box(1 + 1);
        });
        assert!(t > 1000.0);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print("test"); // smoke — just must not panic
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
    }

    #[test]
    fn report_json_is_wellformed() {
        let mut r = BenchReport::new("unit");
        r.row("plain \"quoted\"", 1500.0, 9000.0, 2000.0, 650_000.0);
        r.extra("wakeups", 3.5);
        r.row("nan-guard", f64::NAN, f64::INFINITY, 0.0, 0.0);
        let j = r.to_json();
        assert!(j.contains("\"bench\": \"unit\""));
        assert!(j.contains("\"schema\": 2"));
        assert!(j.contains("plain \\\"quoted\\\""));
        assert!(j.contains("\"wakeups\": 3.5"));
        assert!(j.contains("\"p999_ns\"") && j.contains("\"slo_miss\""));
        assert!(!j.contains("NaN") && !j.contains("inf"), "numbers must stay JSON-legal");
        // Separator discipline: one comma between the two rows.
        assert_eq!(j.matches("},\n").count(), 1);
        // Round-trip sanity without a JSON dep: balanced braces/brackets.
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn slo_columns_fill_from_histogram() {
        let h = Histogram::new();
        for ns in 1..=1000u64 {
            h.record_ns(ns * 1000); // 1µs..1ms
        }
        // No SLO set → column stays 0 (and row_hist warns, not panics).
        let mut r0 = BenchReport::new("slo-unit-none");
        r0.row_hist("no-slo", &h, 0.0);
        assert_eq!(r0.rows[0].slo_miss, 0.0);
        // Correct ordering: slo() before the first row.
        let mut r = BenchReport::new("slo-unit");
        r.slo(500_000);
        r.row_hist("with-slo", &h, 0.0);
        assert!(r.rows[0].slo_miss > 0.0, "half the ramp misses a 500µs SLO");
        assert!(r.rows[0].p999_ns >= r.rows[0].p99_ns);
        // Every histogram row carries its population size for CI's
        // slo_miss ≤ samples sanity gate.
        assert!(r.rows[0].extra.iter().any(|(k, v)| k == "samples" && *v == 1000.0));
        assert!(r.rows[0].slo_miss <= 1000.0);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "must run before the first row_hist")]
    fn slo_after_rows_is_a_bench_bug() {
        let h = Histogram::new();
        h.record_ns(1_000);
        let mut r = BenchReport::new("slo-misordered");
        r.row_hist("early", &h, 0.0);
        r.slo(500); // too late: the row above has slo_miss 0 forever
    }

    #[test]
    #[should_panic(expected = "empty histogram")]
    fn row_hist_rejects_empty_population() {
        let mut r = BenchReport::new("empty-hist");
        r.row_hist("nothing", &Histogram::new(), 0.0);
    }

    #[test]
    fn schedule_plans_are_deterministic_and_partitionable() {
        let s = Schedule::fixed_rate(100, 1e6); // 1µs gaps
        assert_eq!(s.len(), 100);
        assert_eq!(s.arrival_ns(0), 0);
        assert_eq!(s.arrival_ns(99), 99_000);
        assert!((s.offered_rate() / 1e6 - 1.0).abs() < 0.01, "rate {}", s.offered_rate());
        // Stripes partition the plan exactly.
        let mut union: Vec<u64> = (0..4).flat_map(|w| s.stripe(w, 4).arrivals).collect();
        union.sort_unstable();
        assert_eq!(union, s.arrivals);
        // Bursty: same span/rate, arrivals clumped in groups of 8.
        let b = Schedule::bursty(64, 1e6, 8);
        assert_eq!(b.arrival_ns(0), b.arrival_ns(7));
        assert!(b.arrival_ns(8) > b.arrival_ns(7));
        assert!((b.arrival_ns(8) - b.arrival_ns(7)) >= 7_000);
        // Poisson: deterministic per seed, non-decreasing.
        let p1 = Schedule::poisson(50, 1e6, 7);
        let p2 = Schedule::poisson(50, 1e6, 7);
        assert_eq!(p1.arrivals, p2.arrivals);
        assert!(p1.arrivals.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn open_loop_carries_lateness_that_closed_pacing_hides() {
        // Service time 60µs, offered interarrival 20µs: a 3x-over-
        // saturated generator. Open-loop must carry the growing
        // backlog into recorded latency; the closed twin re-bases
        // after every completion and reports flat ~60µs ops.
        let sched = Schedule::fixed_rate(40, 50_000.0);
        let op = |_i: usize| crate::util::spin::spin_ns(60_000);
        let open = run_open_loop(&sched, op);
        let closed = run_closed_paced(&sched, op);
        assert_eq!(open.ops, 40);
        assert_eq!(closed.ops, 40);
        assert!(open.late_sends > 10, "saturated generator must fall behind ({})", open.late_sends);
        assert_eq!(closed.late_sends, 0, "closed pacing re-bases, by construction");
        // The whole point: at identical offered load the open-loop
        // tail dwarfs the closed-loop tail (queueing made visible).
        let (op99, cp99) = (open.hist.p99_ns(), closed.hist.p99_ns());
        assert!(
            op99 >= 2 * cp99,
            "open p99 {op99} must dwarf closed p99 {cp99} under saturation"
        );
        assert!(open.max_late_ns > 0);
    }

    #[test]
    fn fanout_load_merges_striped_workers() {
        let sched = Schedule::fixed_rate(64, 200_000.0); // 5µs gaps
        let merged = fanout_load(4, &sched, |_w, sub| {
            assert_eq!(sub.len(), 16);
            run_open_loop(sub, |_i| crate::util::spin::spin_ns(2_000))
        });
        assert_eq!(merged.ops, 64);
        assert_eq!(merged.hist.count(), 64);
        assert!(merged.wall > Duration::ZERO);
    }

    #[test]
    fn row_load_fills_slo_and_lateness_columns() {
        let sched = Schedule::fixed_rate(32, 100_000.0);
        let load = run_open_loop(&sched, |_| crate::util::spin::spin_ns(3_000));
        let mut r = BenchReport::new("load-unit");
        r.slo(1_000_000);
        r.row_load("ol/unit/open", &load, sched.offered_rate());
        let row = &r.rows[0];
        assert!(row.p50_ns > 0.0);
        for key in ["samples", "offered_ops", "late_sends", "max_late_ns"] {
            assert!(row.extra.iter().any(|(k, _)| k == key), "missing extra {key}");
        }
        assert!(row.slo_miss <= 32.0);
    }

    #[test]
    fn report_emits_to_dir() {
        // emit_to, not emit: tests must not mutate process-global env
        // (BENCH_OUT) while the harness runs suites concurrently.
        let dir = std::env::temp_dir().join(format!("benchkit-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut r = BenchReport::new("emit-test");
        r.row("x", 1.0, 2.0, 1.5, 0.0);
        let path = r.emit_to(&dir).expect("writable dir");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"bench\": \"emit-test\""));
        std::fs::remove_file(&path).ok();
        std::fs::remove_dir(&dir).ok();
    }
}
