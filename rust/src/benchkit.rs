//! Bench harness shared by `benches/*` (criterion is unavailable in
//! the offline build; this provides the same discipline: warmup,
//! repeated timed runs, percentile reporting, markdown rows) — plus
//! machine-readable output: every bench emits a `BENCH_<name>.json`
//! via [`BenchReport`], so the repo accumulates a perf trajectory
//! (CI uploads them as artifacts; compare runs with a diff).

use crate::metrics::Histogram;
use std::time::{Duration, Instant};

/// Time `op` over `n` iterations after `warmup` iterations; returns
/// mean ns/op and a latency histogram (per-op timing only if
/// `per_op`; otherwise total/n, which is right for sub-µs ops where
/// timer overhead would dominate).
pub fn time_op(warmup: usize, n: usize, per_op: bool, mut op: impl FnMut()) -> (f64, Histogram) {
    for _ in 0..warmup {
        op();
    }
    let hist = Histogram::new();
    if per_op {
        let t_all = Instant::now();
        for _ in 0..n {
            let t = Instant::now();
            op();
            hist.record(t.elapsed());
        }
        let mean = t_all.elapsed().as_nanos() as f64 / n as f64;
        (mean, hist)
    } else {
        let t = Instant::now();
        for _ in 0..n {
            op();
        }
        let total = t.elapsed();
        let mean = total.as_nanos() as f64 / n as f64;
        hist.record_ns(mean as u64);
        (mean, hist)
    }
}

/// Fan out `threads` copies of `work(thread_idx)` on scoped threads
/// and return the wall-clock of the whole fan-out (i.e. the slowest
/// worker). The multi-threaded benches' shared harness.
pub fn fanout(threads: usize, work: impl Fn(usize) + Sync) -> Duration {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let w = &work;
            s.spawn(move || w(t));
        }
    });
    t0.elapsed()
}

/// Run `op` repeatedly for at least `dur`, returning ops/sec.
pub fn throughput(dur: Duration, mut op: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    let mut n = 0u64;
    while t0.elapsed() < dur {
        for _ in 0..64 {
            op();
        }
        n += 64;
    }
    n as f64 / t0.elapsed().as_secs_f64()
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Markdown table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n### {title}\n");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s += &format!(" {:w$} |", c, w = widths.get(i).copied().unwrap_or(4));
            }
            s
        };
        println!("{}", fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep += &format!("{}|", "-".repeat(w + 2));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

// ---------------------------------------------------------------------
// machine-readable reports

/// One measured configuration in a bench run.
#[derive(Clone, Debug, Default)]
pub struct BenchRow {
    pub label: String,
    /// Median / p99 latency in ns (0 = not measured).
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Deep tail: p99.9 latency in ns (0 = not measured).
    pub p999_ns: f64,
    pub mean_ns: f64,
    /// Operations per second (0 = not measured).
    pub throughput_ops: f64,
    /// Samples over the report's SLO threshold (0 when no SLO set).
    pub slo_miss: f64,
    /// Free-form extra metrics (name, value).
    pub extra: Vec<(String, f64)>,
}

/// Collects rows and writes `BENCH_<name>.json` — the committed /
/// CI-uploaded perf record. JSON is hand-rolled (the build is
/// dependency-free by design).
pub struct BenchReport {
    name: String,
    rows: Vec<BenchRow>,
    /// Latency SLO applied by [`BenchReport::row_hist`] to fill each
    /// row's `slo_miss` column. None → column stays 0.
    slo_ns: Option<u64>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON has no NaN/Inf; clamp to 0 so emitted files always parse.
fn json_num(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        BenchReport { name: name.to_string(), rows: Vec::new(), slo_ns: None }
    }

    /// Set the latency SLO for subsequent [`BenchReport::row_hist`]
    /// calls: each row's `slo_miss` column becomes the number of
    /// samples over `ns`.
    pub fn slo(&mut self, ns: u64) {
        self.slo_ns = Some(ns);
    }

    /// Record a latency-style row (throughput derived where the bench
    /// knows it; pass 0.0 for unmeasured fields). The deep-tail /
    /// SLO columns need a histogram — use [`BenchReport::row_hist`]
    /// to fill them; here they stay 0.
    pub fn row(&mut self, label: &str, p50_ns: f64, p99_ns: f64, mean_ns: f64, thr: f64) {
        self.rows.push(BenchRow {
            label: label.to_string(),
            p50_ns,
            p99_ns,
            p999_ns: 0.0,
            mean_ns,
            throughput_ops: thr,
            slo_miss: 0.0,
            extra: Vec::new(),
        });
    }

    /// Record a row from a histogram + ops/sec, including the deep
    /// tail (p99.9) and — when an SLO was set via
    /// [`BenchReport::slo`] — the over-threshold sample count.
    pub fn row_hist(&mut self, label: &str, hist: &Histogram, thr: f64) {
        self.rows.push(BenchRow {
            label: label.to_string(),
            p50_ns: hist.median_ns() as f64,
            p99_ns: hist.p99_ns() as f64,
            p999_ns: hist.p999_ns() as f64,
            mean_ns: hist.mean_ns(),
            throughput_ops: thr,
            slo_miss: self.slo_ns.map(|s| hist.count_over_ns(s) as f64).unwrap_or(0.0),
            extra: Vec::new(),
        });
    }

    /// Attach an extra metric to the most recent row.
    pub fn extra(&mut self, key: &str, value: f64) {
        if let Some(r) = self.rows.last_mut() {
            r.extra.push((key.to_string(), value));
        }
    }

    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(&self.name)));
        s.push_str("  \"schema\": 2,\n");
        s.push_str("  \"rows\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"label\": \"{}\", \"p50_ns\": {}, \"p99_ns\": {}, \"p999_ns\": {}, \"mean_ns\": {}, \"throughput_ops\": {}, \"slo_miss\": {}",
                json_escape(&r.label),
                json_num(r.p50_ns),
                json_num(r.p99_ns),
                json_num(r.p999_ns),
                json_num(r.mean_ns),
                json_num(r.throughput_ops),
                json_num(r.slo_miss),
            ));
            for (k, v) in &r.extra {
                s.push_str(&format!(", \"{}\": {}", json_escape(k), json_num(*v)));
            }
            s.push('}');
            if i + 1 < self.rows.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write `BENCH_<name>.json` into `$BENCH_OUT` (or the current
    /// directory) and return the path. Failures are reported, not
    /// fatal — a read-only checkout must not kill the bench.
    pub fn emit(&self) -> Option<std::path::PathBuf> {
        let dir = std::env::var("BENCH_OUT").unwrap_or_else(|_| ".".into());
        self.emit_to(std::path::Path::new(&dir))
    }

    /// Write `BENCH_<name>.json` into an explicit directory.
    pub fn emit_to(&self, dir: &std::path::Path) -> Option<std::path::PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        match std::fs::write(&path, self.to_json()) {
            Ok(()) => {
                println!("\n[bench] wrote {}", path.display());
                Some(path)
            }
            Err(e) => {
                eprintln!("[bench] could not write {}: {e}", path.display());
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_op_measures() {
        let (mean, hist) = time_op(10, 100, true, || {
            crate::util::spin::spin_ns(10_000);
        });
        assert!(mean > 5_000.0, "mean {mean}");
        assert!(hist.count() == 100);
    }

    #[test]
    fn fanout_runs_every_worker() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let hits = AtomicU64::new(0);
        let wall = fanout(4, |t| {
            hits.fetch_add(1 + t as u64, Ordering::Relaxed);
        });
        // Each worker t contributes 1 + t: 1 + 2 + 3 + 4.
        assert_eq!(hits.load(Ordering::Relaxed), 10);
        assert!(wall > Duration::ZERO);
    }

    #[test]
    fn throughput_positive() {
        let t = throughput(Duration::from_millis(20), || {
            std::hint::black_box(1 + 1);
        });
        assert!(t > 1000.0);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print("test"); // smoke — just must not panic
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
    }

    #[test]
    fn report_json_is_wellformed() {
        let mut r = BenchReport::new("unit");
        r.row("plain \"quoted\"", 1500.0, 9000.0, 2000.0, 650_000.0);
        r.extra("wakeups", 3.5);
        r.row("nan-guard", f64::NAN, f64::INFINITY, 0.0, 0.0);
        let j = r.to_json();
        assert!(j.contains("\"bench\": \"unit\""));
        assert!(j.contains("\"schema\": 2"));
        assert!(j.contains("plain \\\"quoted\\\""));
        assert!(j.contains("\"wakeups\": 3.5"));
        assert!(j.contains("\"p999_ns\"") && j.contains("\"slo_miss\""));
        assert!(!j.contains("NaN") && !j.contains("inf"), "numbers must stay JSON-legal");
        // Separator discipline: one comma between the two rows.
        assert_eq!(j.matches("},\n").count(), 1);
        // Round-trip sanity without a JSON dep: balanced braces/brackets.
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn slo_columns_fill_from_histogram() {
        let h = Histogram::new();
        for ns in 1..=1000u64 {
            h.record_ns(ns * 1000); // 1µs..1ms
        }
        let mut r = BenchReport::new("slo-unit");
        r.row_hist("no-slo", &h, 0.0);
        r.slo(500_000);
        r.row_hist("with-slo", &h, 0.0);
        assert_eq!(r.rows[0].slo_miss, 0.0, "no SLO set → column stays 0");
        assert!(r.rows[1].slo_miss > 0.0, "half the ramp misses a 500µs SLO");
        assert!(r.rows[1].p999_ns >= r.rows[1].p99_ns);
    }

    #[test]
    fn report_emits_to_dir() {
        // emit_to, not emit: tests must not mutate process-global env
        // (BENCH_OUT) while the harness runs suites concurrently.
        let dir = std::env::temp_dir().join(format!("benchkit-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut r = BenchReport::new("emit-test");
        r.row("x", 1.0, 2.0, 1.5, 0.0);
        let path = r.emit_to(&dir).expect("writable dir");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"bench\": \"emit-test\""));
        std::fs::remove_file(&path).ok();
        std::fs::remove_dir(&dir).ok();
    }
}
