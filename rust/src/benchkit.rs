//! Bench harness shared by `benches/*` (criterion is unavailable in
//! the offline build; this provides the same discipline: warmup,
//! repeated timed runs, percentile reporting, markdown rows).

use crate::metrics::Histogram;
use std::time::{Duration, Instant};

/// Time `op` over `n` iterations after `warmup` iterations; returns
/// mean ns/op and a latency histogram (per-op timing only if
/// `per_op`; otherwise total/n, which is right for sub-µs ops where
/// timer overhead would dominate).
pub fn time_op(warmup: usize, n: usize, per_op: bool, mut op: impl FnMut()) -> (f64, Histogram) {
    for _ in 0..warmup {
        op();
    }
    let hist = Histogram::new();
    if per_op {
        let t_all = Instant::now();
        for _ in 0..n {
            let t = Instant::now();
            op();
            hist.record(t.elapsed());
        }
        let mean = t_all.elapsed().as_nanos() as f64 / n as f64;
        (mean, hist)
    } else {
        let t = Instant::now();
        for _ in 0..n {
            op();
        }
        let total = t.elapsed();
        let mean = total.as_nanos() as f64 / n as f64;
        hist.record_ns(mean as u64);
        (mean, hist)
    }
}

/// Run `op` repeatedly for at least `dur`, returning ops/sec.
pub fn throughput(dur: Duration, mut op: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    let mut n = 0u64;
    while t0.elapsed() < dur {
        for _ in 0..64 {
            op();
        }
        n += 64;
    }
    n as f64 / t0.elapsed().as_secs_f64()
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Markdown table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n### {title}\n");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s += &format!(" {:w$} |", c, w = widths.get(i).copied().unwrap_or(4));
            }
            s
        };
        println!("{}", fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep += &format!("{}|", "-".repeat(w + 2));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_op_measures() {
        let (mean, hist) = time_op(10, 100, true, || {
            crate::util::spin::spin_ns(10_000);
        });
        assert!(mean > 5_000.0, "mean {mean}");
        assert!(hist.count() == 100);
    }

    #[test]
    fn throughput_positive() {
        let t = throughput(Duration::from_millis(20), || {
            std::hint::black_box(1 + 1);
        });
        assert!(t > 1000.0);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print("test"); // smoke — just must not panic
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
    }
}
