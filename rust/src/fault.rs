//! Deterministic crash-fault injection: the failure plane's harness.
//!
//! The paper's §5.4 failure story (leases detect a dead proc, the
//! orchestrator notifies survivors and reclaims orphaned heaps) is
//! only testable if a proc can die at the *worst possible* instants:
//! holding a claimed-but-unpublished ring slot, holding an installed
//! seal, mid-batch with half its chunk published, parked inside the
//! daemon's worker pool. This module threads named [`KillPoint`]s
//! through those hot paths; a [`FaultPlan`] arms exactly one of them
//! and fires on a chosen (optionally seed-derived) crossing, after
//! which the victim path returns [`RpcError::Killed`] *without
//! running any cleanup* — no abandon tombstone, no seal release, no
//! scope free, no magazine flush. Recovery then has to happen the way
//! it would in production: lease expiry → orchestrator sweep.
//!
//! Determinism: one global plan, one fire. The crossing counter only
//! advances on full matches (point + victim filter), so unrelated
//! traffic cannot consume the shot, and the injector auto-disarms the
//! instant it fires so recovery code paths can never be re-killed.
//! With a fixed seed the nth-crossing choice — and therefore the
//! poisoned state the sweep must clean up — replays exactly.
//!
//! Disarmed cost on the hot path is a single relaxed atomic load.

use crate::error::RpcError;
use crate::memory::heap::ProcId;
use crate::metrics::CounterSet;
use crate::orchestrator::FLT_KILLS;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Weak};

/// The named instants a simulated proc can be killed at. Each maps to
/// one `should_die` probe in the hot path (DESIGN.md §14 has the
/// site-by-site map of what state each kill strands).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KillPoint {
    /// Client, batched submission: after the chunk's `publish_quiet`
    /// loop, before `flush_publish` — requests are visible in slots
    /// but the doorbell never rings.
    PreFlush,
    /// Server, mid-serving: after taking a request (slot PROCESSING),
    /// before `respond` — the server proc dies with the slot held.
    MidServe,
    /// Client: after a sealed call completes, still holding the
    /// COMPLETE seal — it is never released.
    HoldingSeal,
    /// Client: holding a live scope whose pages are never freed.
    HoldingScope,
    /// Client, batched submission: between chunks — earlier chunks
    /// are fully in flight, later ones never happen.
    MidBatch,
    /// A parked daemon worker-pool thread dies (thread-level death:
    /// its CPU share and futex state vanish; nothing it was serving
    /// is cleaned up).
    ParkedWorker,
    /// Server, response side: after `respond_quiet` wrote one or more
    /// RESPONSE slots but before the sweep's `flush_respond` — replies
    /// exist in shared memory, the response doorbell never rings, and
    /// the remaining drained slots of the sweep are never answered.
    MidRespond,
    /// Server, response side: every reply of the sweep is written
    /// *and* flushed state-wise, but the proc dies on the doorbell
    /// threshold — waiters parked on the response bell are stranded
    /// with completed replies they were never signalled about.
    PostRespond,
    /// DSM: die owning a cross-pod page mid-transfer — the owner word
    /// was already swung to the (now dead) node, so every future
    /// accessor faults against a corpse until the sweep advances the
    /// page's epoch and reclaims it.
    DsmOwner,
}

impl KillPoint {
    /// Parse a config-file name (`fault_point` knob).
    pub fn parse(v: &str) -> Option<KillPoint> {
        Some(match v {
            "pre_flush" => KillPoint::PreFlush,
            "mid_serve" => KillPoint::MidServe,
            "holding_seal" => KillPoint::HoldingSeal,
            "holding_scope" => KillPoint::HoldingScope,
            "mid_batch" => KillPoint::MidBatch,
            "parked_worker" => KillPoint::ParkedWorker,
            "mid_respond" => KillPoint::MidRespond,
            "post_respond" => KillPoint::PostRespond,
            "dsm_owner" => KillPoint::DsmOwner,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            KillPoint::PreFlush => "pre_flush",
            KillPoint::MidServe => "mid_serve",
            KillPoint::HoldingSeal => "holding_seal",
            KillPoint::HoldingScope => "holding_scope",
            KillPoint::MidBatch => "mid_batch",
            KillPoint::ParkedWorker => "parked_worker",
            KillPoint::MidRespond => "mid_respond",
            KillPoint::PostRespond => "post_respond",
            KillPoint::DsmOwner => "dsm_owner",
        }
    }

    /// Every kill point, for sweep-style tests.
    pub const ALL: [KillPoint; 9] = [
        KillPoint::PreFlush,
        KillPoint::MidServe,
        KillPoint::HoldingSeal,
        KillPoint::HoldingScope,
        KillPoint::MidBatch,
        KillPoint::ParkedWorker,
        KillPoint::MidRespond,
        KillPoint::PostRespond,
        KillPoint::DsmOwner,
    ];
}

/// One armed kill: which point, which crossing, which victim.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    pub point: KillPoint,
    /// Fire on the nth matching crossing (1-based).
    pub nth: u64,
    /// Restrict matches to one proc's crossings (`None` = any thread;
    /// required for `ParkedWorker`, whose threads carry no identity).
    pub victim: Option<ProcId>,
}

impl FaultPlan {
    pub fn new(point: KillPoint) -> FaultPlan {
        FaultPlan { point, nth: 1, victim: None }
    }

    /// Fire on the nth crossing instead of the first.
    pub fn nth(mut self, n: u64) -> FaultPlan {
        self.nth = n.max(1);
        self
    }

    /// Only crossings by `proc` match (and only they advance the
    /// crossing counter).
    pub fn victim(mut self, proc: ProcId) -> FaultPlan {
        self.victim = Some(proc);
        self
    }

    /// Derive the crossing from a seed: nth in `[1, max_nth]` via one
    /// xorshift round, so a seed sweep kills at different depths of
    /// the same workload, deterministically per seed.
    pub fn seeded(point: KillPoint, seed: u64, max_nth: u64) -> FaultPlan {
        let mut x = seed ^ 0x9E37_79B9_7F4A_7C15;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        FaultPlan { point, nth: 1 + x % max_nth.max(1), victim: None }
    }

    /// The plan named by the config's `fault_point`/`fault_nth`/
    /// `fault_seed` knobs; `None` when `fault_point = none`.
    /// `fault_nth = 0` means seed-derived (crossing in [1, 8]).
    pub fn from_config(cfg: &crate::config::SimConfig) -> Option<FaultPlan> {
        if cfg.fault_point == "none" || cfg.fault_point.is_empty() {
            return None;
        }
        let point = KillPoint::parse(&cfg.fault_point)?;
        Some(if cfg.fault_nth == 0 {
            FaultPlan::seeded(point, cfg.fault_seed, 8)
        } else {
            FaultPlan { point, nth: cfg.fault_nth, victim: None }
        })
    }
}

struct Armed {
    plan: FaultPlan,
    crossings: u64,
    /// Kill-count sink: the owning orchestrator's fault counters.
    /// Weak so a dropped rack never keeps counters alive, and so kill
    /// sites with no orchestrator handle (pool workers) still count.
    sink: Weak<CounterSet>,
}

/// Hot-path gate: one relaxed load decides "no injection" — the cost
/// every probe pays while nothing is armed.
static ACTIVE: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<Armed>> = Mutex::new(None);

/// Arm a plan with no kill-count sink (unit tests).
pub fn arm(plan: FaultPlan) {
    arm_with_sink(plan, Weak::new());
}

/// Arm a plan; fired kills count on `sink`'s `FLT_KILLS`.
pub fn arm_with_sink(plan: FaultPlan, sink: Weak<CounterSet>) {
    *STATE.lock().unwrap() = Some(Armed { plan, crossings: 0, sink });
    ACTIVE.store(true, Ordering::SeqCst);
}

/// Disarm without firing (teardown between test cases).
pub fn disarm() {
    ACTIVE.store(false, Ordering::SeqCst);
    *STATE.lock().unwrap() = None;
}

pub fn armed() -> bool {
    ACTIVE.load(Ordering::SeqCst)
}

/// Probe a kill point: true exactly once, on the armed plan's nth
/// matching crossing, after which the injector disarms itself. The
/// caller must then die *without cleanup* — return
/// [`killed_err`] up the stack (or exit the thread) and leak
/// everything it holds.
#[inline]
pub fn should_die(point: KillPoint) -> bool {
    if !ACTIVE.load(Ordering::Relaxed) {
        return false;
    }
    should_die_slow(point)
}

#[cold]
fn should_die_slow(point: KillPoint) -> bool {
    let mut st = STATE.lock().unwrap();
    let armed = match st.as_mut() {
        Some(a) => a,
        None => return false,
    };
    if armed.plan.point != point {
        return false;
    }
    if let Some(v) = armed.plan.victim {
        if crate::simproc::current_proc() != v {
            return false;
        }
    }
    armed.crossings += 1;
    if armed.crossings < armed.plan.nth {
        return false;
    }
    if let Some(sink) = armed.sink.upgrade() {
        sink.add(FLT_KILLS, 1);
    }
    *st = None;
    ACTIVE.store(false, Ordering::SeqCst);
    true
}

/// The error a killed path surfaces to its own (dead) caller. Only
/// the crash harness observes it — surviving peers see `PeerFailed`
/// after the sweep, never `Killed`.
pub fn killed_err(point: KillPoint) -> RpcError {
    RpcError::Killed(format!("fault injected at kill point '{}'", point.name()))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Every test uses a victim filter with a proc id far outside any
    // range other lib tests bind, so concurrently running tests that
    // legitimately cross kill points can neither fire these plans nor
    // consume their crossing budgets.

    #[test]
    fn fires_once_on_nth_crossing_then_disarms() {
        let victim: ProcId = 900_001;
        crate::simproc::with_identity(victim, 0, || {
            arm(FaultPlan::new(KillPoint::PreFlush).nth(3).victim(victim));
            assert!(!should_die(KillPoint::PreFlush));
            assert!(!should_die(KillPoint::MidBatch), "other points never match");
            assert!(!should_die(KillPoint::PreFlush));
            assert!(should_die(KillPoint::PreFlush), "third crossing fires");
            assert!(!armed(), "auto-disarmed after firing");
            assert!(!should_die(KillPoint::PreFlush), "recovery can't be re-killed");
        });
    }

    #[test]
    fn victim_filter_neither_fires_nor_counts_for_others() {
        let victim: ProcId = 900_002;
        arm(FaultPlan::new(KillPoint::MidServe).victim(victim));
        crate::simproc::with_identity(victim + 1, 0, || {
            assert!(!should_die(KillPoint::MidServe), "wrong proc never dies");
            assert!(!should_die(KillPoint::MidServe));
        });
        crate::simproc::with_identity(victim, 0, || {
            assert!(
                should_die(KillPoint::MidServe),
                "non-victim crossings must not have consumed the shot"
            );
        });
        disarm();
    }

    #[test]
    fn seeded_plan_is_deterministic_and_bounded() {
        let a = FaultPlan::seeded(KillPoint::MidBatch, 42, 8);
        let b = FaultPlan::seeded(KillPoint::MidBatch, 42, 8);
        assert_eq!(a.nth, b.nth, "same seed, same crossing");
        assert!((1..=8).contains(&a.nth));
        let c = FaultPlan::seeded(KillPoint::MidBatch, 43, 8);
        // Not a hard guarantee for every pair, but these two differ.
        assert_ne!(a.nth, c.nth, "seed 42 vs 43 pick different crossings");
    }

    #[test]
    fn kill_point_names_round_trip() {
        for p in KillPoint::ALL {
            assert_eq!(KillPoint::parse(p.name()), Some(p));
        }
        assert_eq!(KillPoint::parse("none"), None);
        assert_eq!(KillPoint::parse("bogus"), None);
    }

    #[test]
    fn config_plan_resolution() {
        let mut cfg = crate::config::SimConfig::for_tests();
        assert!(FaultPlan::from_config(&cfg).is_none(), "default: no injection");
        cfg.apply_kv("fault_point", "holding_seal").unwrap();
        cfg.apply_kv("fault_nth", "5").unwrap();
        let plan = FaultPlan::from_config(&cfg).unwrap();
        assert_eq!(plan.point, KillPoint::HoldingSeal);
        assert_eq!(plan.nth, 5);
        // nth = 0 → seed-derived crossing.
        cfg.apply_kv("fault_nth", "0").unwrap();
        cfg.apply_kv("fault_seed", "7").unwrap();
        let plan = FaultPlan::from_config(&cfg).unwrap();
        assert_eq!(plan.nth, FaultPlan::seeded(KillPoint::HoldingSeal, 7, 8).nth);
        assert!(cfg.apply_kv("fault_point", "bogus").is_err());
    }
}
