//! RDMA fallback: multi-node software coherence (paper §4.7, §5.6).
//!
//! Beyond a CXL pod, hardware coherence is unavailable; RPCool
//! replaces it with a minimalist page-ownership protocol over RDMA:
//! every heap page has exactly one owner node; touching a page you
//! don't own faults, fetches the page from its current owner
//! (unmapping it there), and remaps it locally. Originally a two-node
//! client/server sketch, this is now generalized to an arbitrary set
//! of node ids — in practice the pod ids of the peers sharing the
//! heap — while keeping a single-word-per-page protocol.
//!
//! The owner word is a packed `(epoch << 32) | owner` u64. A live
//! transfer CASes the owner field while *preserving* the epoch, so
//! each ownership transition is still observed by exactly one racer
//! no matter how many writers contend. The epoch exists for crash
//! recovery: when the orchestrator sweep declares a node dead, it
//! reclaims every page the corpse owns by CASing in a surviving heir
//! *and* advancing the epoch. A late transfer CAS issued by the
//! corpse before it died carries the old-epoch word as its compare
//! value — the epoch advance makes that word stale, the CAS fails,
//! and the corpse (being dead) never retries; if the corpse's CAS
//! landed first, the sweep observes the corpse as owner and reclaims
//! anyway. Either order, the sweep wins exactly once.
//!
//! The simulation shares physical memory (it's one process), so a
//! "transfer" is bookkeeping + the calibrated RDMA wire/fault costs —
//! which is precisely what the paper's numbers are made of: the 17µs
//! no-op RTT over RDMA vs 1.5µs over CXL is page-fault + transfer
//! overhead, reproduced here.

use crate::config::CostModel;
use crate::error::{Result, RpcError};
use crate::memory::heap::Heap;
use crate::memory::pool::Charger;
use crate::metrics::CounterSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A DSM node id. In cross-pod connections this is the pod id of the
/// participant (plus a synthetic id for the "far" side when a DSM
/// transport is forced inside one pod).
pub type NodeId = u32;

/// Legacy node ids for the two-node protocol; `DsmState::new` still
/// builds exactly that configuration.
pub const NODE_CLIENT: NodeId = 0;
pub const NODE_SERVER: NodeId = 1;

/// Names of the exported DSM counters, in [`CounterSet`] order. The
/// recovery counters are appended after the transfer trio so existing
/// snapshot indices stay stable.
pub const DSM_COUNTERS: [&str; 5] = [
    "dsm_faults",
    "dsm_pages_transferred",
    "dsm_charged_ns",
    "dsm_epoch_bumps",
    "dsm_pages_reclaimed",
];
const C_FAULTS: usize = 0;
const C_PAGES: usize = 1;
const C_CHARGED_NS: usize = 2;
const C_EPOCH_BUMPS: usize = 3;
const C_RECLAIMED: usize = 4;

/// Pack an owner node id and a recovery epoch into one atomic word.
#[inline]
fn pack(owner: NodeId, epoch: u32) -> u64 {
    ((epoch as u64) << 32) | owner as u64
}

#[inline]
fn word_owner(w: u64) -> NodeId {
    w as u32
}

#[inline]
fn word_epoch(w: u64) -> u32 {
    (w >> 32) as u32
}

/// Ownership + cost state for one DSM-backed heap.
pub struct DsmState {
    heap_base: usize,
    page: usize,
    /// Per-page `(epoch << 32) | owner` word.
    owner: Vec<AtomicU64>,
    /// Sorted, deduplicated set of valid node ids.
    nodes: Vec<NodeId>,
    charger: Arc<Charger>,
    counters: CounterSet,
}

impl DsmState {
    /// Two-node client/server heap; all pages start owned by the
    /// client (it allocates arguments first).
    pub fn new(heap: &Arc<Heap>, page_bytes: usize) -> Arc<DsmState> {
        Self::new_multi(heap, page_bytes, &[NODE_CLIENT, NODE_SERVER], NODE_CLIENT)
    }

    /// General form: `nodes` is the set of participants (e.g. pod
    /// ids), `initial` the node that owns every page at the start.
    pub fn new_multi(
        heap: &Arc<Heap>,
        page_bytes: usize,
        nodes: &[NodeId],
        initial: NodeId,
    ) -> Arc<DsmState> {
        let mut set: Vec<NodeId> = nodes.to_vec();
        set.sort_unstable();
        set.dedup();
        assert!(set.len() >= 2, "DSM needs at least two nodes");
        assert!(set.contains(&initial), "initial owner must be a participant");
        let npages = heap.len() / page_bytes;
        Arc::new(DsmState {
            heap_base: heap.base(),
            page: page_bytes,
            owner: (0..npages).map(|_| AtomicU64::new(pack(initial, 0))).collect(),
            nodes: set,
            charger: Arc::clone(&heap.pool().charger),
            counters: CounterSet::new(&DSM_COUNTERS),
        })
    }

    #[inline]
    fn page_index(&self, addr: usize) -> Option<usize> {
        let off = addr.checked_sub(self.heap_base)?;
        let idx = off / self.page;
        (idx < self.owner.len()).then_some(idx)
    }

    pub fn owner_of(&self, addr: usize) -> Option<NodeId> {
        self.page_index(addr)
            .map(|i| word_owner(self.owner[i].load(Ordering::Acquire)))
    }

    /// Recovery epoch of the page holding `addr` (0 until the first
    /// sweep reclamation touches it).
    pub fn epoch_of(&self, addr: usize) -> Option<u32> {
        self.page_index(addr)
            .map(|i| word_epoch(self.owner[i].load(Ordering::Acquire)))
    }

    /// Fault in every page of `[addr, addr+len)` that `node` does not
    /// own: page-fault trap + RDMA fetch + remap, per page (paper
    /// §5.6: "triggers a page fault, fetches the page from the client,
    /// and re-executes"). Returns pages transferred.
    ///
    /// The epoch-preserving CAS on the owner word makes every
    /// transition exactly-once under racing writers: whichever
    /// racer's CAS lands on a word naming a foreign owner is the one
    /// (and only one) charged for that transfer. Losing a CAS means
    /// some other racer (a transfer or a recovery sweep) changed the
    /// word first; we reload and re-decide against the fresh word.
    ///
    /// Carries the `dsm_owner` kill point: when armed, the calling
    /// proc dies immediately *after* a transfer lands — the owner
    /// word now names a node that will never act again, which is
    /// exactly the stranding the sweep's epoch reclamation exists to
    /// undo.
    pub fn ensure_owned(&self, node: NodeId, addr: usize, len: usize) -> Result<usize> {
        debug_assert!(self.nodes.binary_search(&node).is_ok(), "unknown DSM node {node}");
        let Some(first) = self.page_index(addr) else {
            return Err(RpcError::Runtime(format!("address {addr:#x} outside DSM heap")));
        };
        let last = self
            .page_index(addr + len.max(1) - 1)
            .ok_or_else(|| RpcError::Runtime("range escapes DSM heap".into()))?;
        let mut moved = 0usize;
        let cost = &self.charger.cost;
        for i in first..=last {
            let mut cur = self.owner[i].load(Ordering::Acquire);
            loop {
                if word_owner(cur) == node {
                    break; // already ours — free touch
                }
                let next = pack(node, word_epoch(cur));
                match self.owner[i].compare_exchange(
                    cur,
                    next,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => {
                        // Trap + request/response on the wire + one
                        // page of bandwidth + remap.
                        let move_ns = Self::page_move_ns(cost);
                        self.counters.add(C_FAULTS, 1);
                        self.counters.add(C_PAGES, 1);
                        self.counters.add(C_CHARGED_NS, move_ns);
                        self.charger.charge_ns(move_ns);
                        moved += 1;
                        if crate::fault::should_die(crate::fault::KillPoint::DsmOwner) {
                            crate::memory::heap::park_thread_magazines(
                                crate::simproc::current_proc(),
                            );
                            return Err(crate::fault::killed_err(
                                crate::fault::KillPoint::DsmOwner,
                            ));
                        }
                        break;
                    }
                    Err(w) => cur = w,
                }
            }
        }
        Ok(moved)
    }

    /// Recovery sweep: swing every page owned by `dead` to `heir`,
    /// advancing the page's epoch so any in-flight CAS the corpse
    /// issued against the pre-sweep word can never land afterwards.
    /// Returns `(epoch_bumps, pages_reclaimed)` — equal by
    /// construction when healthy (each successful reclaim CAS is one
    /// bump and one page); counted separately so the CI gate can
    /// catch them drifting apart.
    ///
    /// Reclamation is bookkeeping, not a transfer: nothing is charged
    /// and the transfer counters don't move, so the exactly-once
    /// invariant `charged_ns == pages_transferred * page_move_ns`
    /// survives any number of sweeps. Idempotent: a second sweep for
    /// the same corpse finds no page it owns and returns (0, 0).
    pub fn reclaim_dead(&self, dead: NodeId, heir: NodeId) -> (u64, u64) {
        debug_assert!(self.nodes.binary_search(&heir).is_ok(), "unknown heir node {heir}");
        let mut bumps = 0u64;
        let mut pages = 0u64;
        for o in &self.owner {
            let mut cur = o.load(Ordering::Acquire);
            loop {
                if word_owner(cur) != dead {
                    break;
                }
                let next = pack(heir, word_epoch(cur).wrapping_add(1));
                match o.compare_exchange(cur, next, Ordering::AcqRel, Ordering::Acquire) {
                    Ok(_) => {
                        bumps += 1;
                        pages += 1;
                        break;
                    }
                    Err(w) => cur = w,
                }
            }
        }
        if bumps > 0 {
            self.counters.add(C_EPOCH_BUMPS, bumps);
            self.counters.add(C_RECLAIMED, pages);
        }
        (bumps, pages)
    }

    /// Cost of moving one page between nodes.
    #[inline]
    pub fn page_move_ns(cost: &CostModel) -> u64 {
        cost.dsm_fault_ns + 2 * cost.rdma_oneway_ns + cost.rdma_page_ns
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.counters.get(C_FAULTS), self.counters.get(C_PAGES))
    }

    /// `(epoch_bumps, pages_reclaimed)` recovery totals.
    pub fn reclaim_stats(&self) -> (u64, u64) {
        (self.counters.get(C_EPOCH_BUMPS), self.counters.get(C_RECLAIMED))
    }

    /// Total nanoseconds this DSM instance charged to the pool's
    /// charger — always `pages_transferred * page_move_ns`.
    pub fn charged_ns(&self) -> u64 {
        self.counters.get(C_CHARGED_NS)
    }

    /// The exported counters (for `BenchReport` extras).
    pub fn counters(&self) -> &CounterSet {
        &self.counters
    }

    /// Participant node ids (sorted).
    pub fn nodes(&self) -> &[NodeId] {
        &self.nodes
    }

    pub fn npages(&self) -> usize {
        self.owner.len()
    }

    /// Invariant checker for property tests: every page has exactly
    /// one owner and it is a valid node id.
    pub fn owners_valid(&self) -> bool {
        self.owner
            .iter()
            .all(|o| self.nodes.binary_search(&word_owner(o.load(Ordering::Relaxed))).is_ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::pool::Pool;

    fn dsm() -> (Arc<Pool>, Arc<Heap>, Arc<DsmState>) {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "dsm", 1 << 20).unwrap();
        let d = DsmState::new(&heap, cfg.page_bytes);
        (pool, heap, d)
    }

    #[test]
    fn pages_start_client_owned() {
        let (_p, h, d) = dsm();
        assert_eq!(d.owner_of(h.base()), Some(NODE_CLIENT));
        assert_eq!(d.epoch_of(h.base()), Some(0));
        assert_eq!(d.npages(), 256);
        assert!(d.owners_valid());
        assert_eq!(d.nodes(), &[NODE_CLIENT, NODE_SERVER]);
    }

    #[test]
    fn fault_transfers_ownership_once() {
        let (_p, h, d) = dsm();
        let addr = h.base() + 5000; // page 1
        let moved = d.ensure_owned(NODE_SERVER, addr, 100).unwrap();
        assert_eq!(moved, 1);
        assert_eq!(d.owner_of(addr), Some(NODE_SERVER));
        // Second touch: no fault.
        assert_eq!(d.ensure_owned(NODE_SERVER, addr, 100).unwrap(), 0);
        let (faults, pages) = d.stats();
        assert_eq!((faults, pages), (1, 1));
        // Live transfers never advance the epoch.
        assert_eq!(d.epoch_of(addr), Some(0));
    }

    #[test]
    fn range_spanning_pages_moves_each() {
        let (_p, h, d) = dsm();
        let moved = d.ensure_owned(NODE_SERVER, h.base(), 3 * 4096 + 1).unwrap();
        assert_eq!(moved, 4);
    }

    #[test]
    fn pingpong_ownership() {
        let (_p, h, d) = dsm();
        for round in 0..10 {
            d.ensure_owned(NODE_SERVER, h.base(), 4096).unwrap();
            d.ensure_owned(NODE_CLIENT, h.base(), 4096).unwrap();
            let _ = round;
        }
        let (faults, _) = d.stats();
        assert_eq!(faults, 20, "every bounce faults");
        assert!(d.owners_valid());
    }

    #[test]
    fn out_of_heap_range_rejected() {
        let (_p, h, d) = dsm();
        assert!(d.ensure_owned(NODE_SERVER, h.base() + h.len() + 10, 8).is_err());
        assert!(d.ensure_owned(NODE_SERVER, 0x10, 8).is_err());
    }

    #[test]
    fn multi_node_round_robin_faults_each_hop() {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "dsm-multi", 1 << 20).unwrap();
        let nodes: [NodeId; 4] = [0, 1, 2, 3];
        let d = DsmState::new_multi(&heap, cfg.page_bytes, &nodes, 2);
        assert_eq!(d.owner_of(heap.base()), Some(2));
        // Each hop to a different node is one fault; returning to the
        // current owner is free.
        for round in 0..3 {
            for &n in &nodes {
                d.ensure_owned(n, heap.base(), 8).unwrap();
                d.ensure_owned(n, heap.base(), 8).unwrap(); // idempotent
            }
            let _ = round;
        }
        // Round 1: 0,1,2,3 from initial owner 2 → hops 2→0→1→2→3 = 4
        // faults... but 2→...→2 passes through 2 itself once (free at
        // that step only if already owner). Count explicitly: sequence
        // of owners touched is 0,1,2,3,0,1,2,3,0,1,2,3 starting at 2;
        // every consecutive pair differs, so 12 faults total.
        let (faults, pages) = d.stats();
        assert_eq!(faults, 12);
        assert_eq!(pages, 12);
        assert!(d.owners_valid());
    }

    #[test]
    fn charged_ns_reconciles_with_pages() {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "dsm-acct", 1 << 20).unwrap();
        let d = DsmState::new_multi(&heap, cfg.page_bytes, &[5, 9, 13], 5);
        let before = pool.charger.total_charged_ns();
        d.ensure_owned(9, heap.base(), 3 * cfg.page_bytes).unwrap();
        d.ensure_owned(13, heap.base(), cfg.page_bytes).unwrap();
        let (_, pages) = d.stats();
        assert_eq!(pages, 4);
        let per_page = DsmState::page_move_ns(&pool.charger.cost);
        assert_eq!(d.charged_ns(), pages * per_page);
        assert_eq!(pool.charger.total_charged_ns() - before, d.charged_ns());
        // Counter snapshot carries the same numbers under stable names.
        let snap = d.counters().snapshot();
        assert_eq!(snap[0], ("dsm_faults", 4));
        assert_eq!(snap[1], ("dsm_pages_transferred", 4));
        assert_eq!(snap[2], ("dsm_charged_ns", 4 * per_page));
        assert_eq!(snap[3], ("dsm_epoch_bumps", 0));
        assert_eq!(snap[4], ("dsm_pages_reclaimed", 0));
    }

    #[test]
    fn reclaim_dead_swings_and_bumps_exactly_once() {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "dsm-reclaim", 1 << 20).unwrap();
        let d = DsmState::new_multi(&heap, cfg.page_bytes, &[1, 2, 3], 1);
        // Node 2 takes three pages, then dies.
        d.ensure_owned(2, heap.base(), 3 * cfg.page_bytes).unwrap();
        let charged_before = d.charged_ns();
        let (bumps, pages) = d.reclaim_dead(2, 3);
        assert_eq!((bumps, pages), (3, 3));
        assert_eq!(d.owner_of(heap.base()), Some(3));
        assert_eq!(d.epoch_of(heap.base()), Some(1));
        // Untouched pages keep owner 1, epoch 0.
        assert_eq!(d.owner_of(heap.base() + 4 * cfg.page_bytes), Some(1));
        assert_eq!(d.epoch_of(heap.base() + 4 * cfg.page_bytes), Some(0));
        // Reclamation is bookkeeping: transfer accounting untouched.
        assert_eq!(d.charged_ns(), charged_before);
        assert_eq!(d.stats(), (3, 3));
        assert_eq!(d.reclaim_stats(), (3, 3));
        // Second sweep for the same corpse: nothing left to reclaim.
        assert_eq!(d.reclaim_dead(2, 3), (0, 0));
        assert!(d.owners_valid());
    }

    #[test]
    fn stale_epoch_cas_cannot_win_after_sweep() {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "dsm-stale", 1 << 20).unwrap();
        let d = DsmState::new_multi(&heap, cfg.page_bytes, &[1, 2, 3], 2);
        // A corpse (node 2, the owner) snapshots the word it would use
        // as a CAS compare value for some late protocol step...
        let stale = d.owner[0].load(Ordering::Acquire);
        assert_eq!(word_owner(stale), 2);
        // ...the sweep declares node 2 dead and reclaims first...
        assert_eq!(d.reclaim_dead(2, 1), (d.npages() as u64, d.npages() as u64));
        // ...so the corpse's stale-epoch CAS can never land.
        assert!(d.owner[0]
            .compare_exchange(stale, pack(2, word_epoch(stale)), Ordering::AcqRel, Ordering::Acquire)
            .is_err());
        assert_eq!(d.owner_of(heap.base()), Some(1));
        assert_eq!(d.epoch_of(heap.base()), Some(1));
    }

    #[test]
    fn transfer_after_reclaim_preserves_new_epoch() {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "dsm-epoch", 1 << 20).unwrap();
        let d = DsmState::new_multi(&heap, cfg.page_bytes, &[1, 2, 3], 1);
        d.ensure_owned(2, heap.base(), 8).unwrap();
        d.reclaim_dead(2, 3);
        assert_eq!(d.epoch_of(heap.base()), Some(1));
        // A live transfer on the reclaimed page keeps the bumped epoch.
        d.ensure_owned(1, heap.base(), 8).unwrap();
        assert_eq!(d.owner_of(heap.base()), Some(1));
        assert_eq!(d.epoch_of(heap.base()), Some(1));
        // Transfer accounting: initial 1→2, then 3→1 after reclaim.
        assert_eq!(d.stats(), (2, 2));
    }

    #[test]
    #[should_panic(expected = "at least two nodes")]
    fn single_node_set_rejected() {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "dsm-one", 1 << 20).unwrap();
        let _ = DsmState::new_multi(&heap, cfg.page_bytes, &[7, 7], 7);
    }
}
