//! RDMA fallback: multi-node software coherence (paper §4.7, §5.6).
//!
//! Beyond a CXL pod, hardware coherence is unavailable; RPCool
//! replaces it with a minimalist page-ownership protocol over RDMA:
//! every heap page has exactly one owner node; touching a page you
//! don't own faults, fetches the page from its current owner
//! (unmapping it there), and remaps it locally. Originally a two-node
//! client/server sketch, this is now generalized to an arbitrary set
//! of node ids — in practice the pod ids of the peers sharing the
//! heap — while keeping the same single-word-per-page protocol: an
//! atomic `swap` on the owner word is the entire transfer, so each
//! ownership transition is observed by exactly one racer no matter
//! how many writers contend.
//!
//! The simulation shares physical memory (it's one process), so a
//! "transfer" is bookkeeping + the calibrated RDMA wire/fault costs —
//! which is precisely what the paper's numbers are made of: the 17µs
//! no-op RTT over RDMA vs 1.5µs over CXL is page-fault + transfer
//! overhead, reproduced here.

use crate::config::CostModel;
use crate::error::{Result, RpcError};
use crate::memory::heap::Heap;
use crate::memory::pool::Charger;
use crate::metrics::CounterSet;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

/// A DSM node id. In cross-pod connections this is the pod id of the
/// participant (plus a synthetic id for the "far" side when a DSM
/// transport is forced inside one pod).
pub type NodeId = u32;

/// Legacy node ids for the two-node protocol; `DsmState::new` still
/// builds exactly that configuration.
pub const NODE_CLIENT: NodeId = 0;
pub const NODE_SERVER: NodeId = 1;

/// Names of the exported DSM counters, in [`CounterSet`] order.
pub const DSM_COUNTERS: [&str; 3] = ["dsm_faults", "dsm_pages_transferred", "dsm_charged_ns"];
const C_FAULTS: usize = 0;
const C_PAGES: usize = 1;
const C_CHARGED_NS: usize = 2;

/// Ownership + cost state for one DSM-backed heap.
pub struct DsmState {
    heap_base: usize,
    page: usize,
    /// Per-page owner node id.
    owner: Vec<AtomicU32>,
    /// Sorted, deduplicated set of valid node ids.
    nodes: Vec<NodeId>,
    charger: Arc<Charger>,
    counters: CounterSet,
}

impl DsmState {
    /// Two-node client/server heap; all pages start owned by the
    /// client (it allocates arguments first).
    pub fn new(heap: &Arc<Heap>, page_bytes: usize) -> Arc<DsmState> {
        Self::new_multi(heap, page_bytes, &[NODE_CLIENT, NODE_SERVER], NODE_CLIENT)
    }

    /// General form: `nodes` is the set of participants (e.g. pod
    /// ids), `initial` the node that owns every page at the start.
    pub fn new_multi(
        heap: &Arc<Heap>,
        page_bytes: usize,
        nodes: &[NodeId],
        initial: NodeId,
    ) -> Arc<DsmState> {
        let mut set: Vec<NodeId> = nodes.to_vec();
        set.sort_unstable();
        set.dedup();
        assert!(set.len() >= 2, "DSM needs at least two nodes");
        assert!(set.contains(&initial), "initial owner must be a participant");
        let npages = heap.len() / page_bytes;
        Arc::new(DsmState {
            heap_base: heap.base(),
            page: page_bytes,
            owner: (0..npages).map(|_| AtomicU32::new(initial)).collect(),
            nodes: set,
            charger: Arc::clone(&heap.pool().charger),
            counters: CounterSet::new(&DSM_COUNTERS),
        })
    }

    #[inline]
    fn page_index(&self, addr: usize) -> Option<usize> {
        let off = addr.checked_sub(self.heap_base)?;
        let idx = off / self.page;
        (idx < self.owner.len()).then_some(idx)
    }

    pub fn owner_of(&self, addr: usize) -> Option<NodeId> {
        self.page_index(addr).map(|i| self.owner[i].load(Ordering::Acquire))
    }

    /// Fault in every page of `[addr, addr+len)` that `node` does not
    /// own: page-fault trap + RDMA fetch + remap, per page (paper
    /// §5.6: "triggers a page fault, fetches the page from the client,
    /// and re-executes"). Returns pages transferred.
    ///
    /// The `swap` on the owner word makes every transition
    /// exactly-once under racing writers: whichever racer's swap
    /// observes a foreign previous owner is the one (and only one)
    /// charged for that transfer.
    pub fn ensure_owned(&self, node: NodeId, addr: usize, len: usize) -> Result<usize> {
        debug_assert!(self.nodes.binary_search(&node).is_ok(), "unknown DSM node {node}");
        let Some(first) = self.page_index(addr) else {
            return Err(RpcError::Runtime(format!("address {addr:#x} outside DSM heap")));
        };
        let last = self
            .page_index(addr + len.max(1) - 1)
            .ok_or_else(|| RpcError::Runtime("range escapes DSM heap".into()))?;
        let mut moved = 0usize;
        let cost = &self.charger.cost;
        for i in first..=last {
            let prev = self.owner[i].swap(node, Ordering::AcqRel);
            if prev != node {
                // Trap + request/response on the wire + one page of
                // bandwidth + remap.
                let move_ns = Self::page_move_ns(cost);
                self.counters.add(C_FAULTS, 1);
                self.counters.add(C_PAGES, 1);
                self.counters.add(C_CHARGED_NS, move_ns);
                self.charger.charge_ns(move_ns);
                moved += 1;
            }
        }
        Ok(moved)
    }

    /// Cost of moving one page between nodes.
    #[inline]
    pub fn page_move_ns(cost: &CostModel) -> u64 {
        cost.dsm_fault_ns + 2 * cost.rdma_oneway_ns + cost.rdma_page_ns
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.counters.get(C_FAULTS), self.counters.get(C_PAGES))
    }

    /// Total nanoseconds this DSM instance charged to the pool's
    /// charger — always `pages_transferred * page_move_ns`.
    pub fn charged_ns(&self) -> u64 {
        self.counters.get(C_CHARGED_NS)
    }

    /// The exported counters (for `BenchReport` extras).
    pub fn counters(&self) -> &CounterSet {
        &self.counters
    }

    /// Participant node ids (sorted).
    pub fn nodes(&self) -> &[NodeId] {
        &self.nodes
    }

    pub fn npages(&self) -> usize {
        self.owner.len()
    }

    /// Invariant checker for property tests: every page has exactly
    /// one owner and it is a valid node id.
    pub fn owners_valid(&self) -> bool {
        self.owner
            .iter()
            .all(|o| self.nodes.binary_search(&o.load(Ordering::Relaxed)).is_ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::pool::Pool;

    fn dsm() -> (Arc<Pool>, Arc<Heap>, Arc<DsmState>) {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "dsm", 1 << 20).unwrap();
        let d = DsmState::new(&heap, cfg.page_bytes);
        (pool, heap, d)
    }

    #[test]
    fn pages_start_client_owned() {
        let (_p, h, d) = dsm();
        assert_eq!(d.owner_of(h.base()), Some(NODE_CLIENT));
        assert_eq!(d.npages(), 256);
        assert!(d.owners_valid());
        assert_eq!(d.nodes(), &[NODE_CLIENT, NODE_SERVER]);
    }

    #[test]
    fn fault_transfers_ownership_once() {
        let (_p, h, d) = dsm();
        let addr = h.base() + 5000; // page 1
        let moved = d.ensure_owned(NODE_SERVER, addr, 100).unwrap();
        assert_eq!(moved, 1);
        assert_eq!(d.owner_of(addr), Some(NODE_SERVER));
        // Second touch: no fault.
        assert_eq!(d.ensure_owned(NODE_SERVER, addr, 100).unwrap(), 0);
        let (faults, pages) = d.stats();
        assert_eq!((faults, pages), (1, 1));
    }

    #[test]
    fn range_spanning_pages_moves_each() {
        let (_p, h, d) = dsm();
        let moved = d.ensure_owned(NODE_SERVER, h.base(), 3 * 4096 + 1).unwrap();
        assert_eq!(moved, 4);
    }

    #[test]
    fn pingpong_ownership() {
        let (_p, h, d) = dsm();
        for round in 0..10 {
            d.ensure_owned(NODE_SERVER, h.base(), 4096).unwrap();
            d.ensure_owned(NODE_CLIENT, h.base(), 4096).unwrap();
            let _ = round;
        }
        let (faults, _) = d.stats();
        assert_eq!(faults, 20, "every bounce faults");
        assert!(d.owners_valid());
    }

    #[test]
    fn out_of_heap_range_rejected() {
        let (_p, h, d) = dsm();
        assert!(d.ensure_owned(NODE_SERVER, h.base() + h.len() + 10, 8).is_err());
        assert!(d.ensure_owned(NODE_SERVER, 0x10, 8).is_err());
    }

    #[test]
    fn multi_node_round_robin_faults_each_hop() {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "dsm-multi", 1 << 20).unwrap();
        let nodes: [NodeId; 4] = [0, 1, 2, 3];
        let d = DsmState::new_multi(&heap, cfg.page_bytes, &nodes, 2);
        assert_eq!(d.owner_of(heap.base()), Some(2));
        // Each hop to a different node is one fault; returning to the
        // current owner is free.
        for round in 0..3 {
            for &n in &nodes {
                d.ensure_owned(n, heap.base(), 8).unwrap();
                d.ensure_owned(n, heap.base(), 8).unwrap(); // idempotent
            }
            let _ = round;
        }
        // Round 1: 0,1,2,3 from initial owner 2 → hops 2→0→1→2→3 = 4
        // faults... but 2→...→2 passes through 2 itself once (free at
        // that step only if already owner). Count explicitly: sequence
        // of owners touched is 0,1,2,3,0,1,2,3,0,1,2,3 starting at 2;
        // every consecutive pair differs, so 12 faults total.
        let (faults, pages) = d.stats();
        assert_eq!(faults, 12);
        assert_eq!(pages, 12);
        assert!(d.owners_valid());
    }

    #[test]
    fn charged_ns_reconciles_with_pages() {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "dsm-acct", 1 << 20).unwrap();
        let d = DsmState::new_multi(&heap, cfg.page_bytes, &[5, 9, 13], 5);
        let before = pool.charger.total_charged_ns();
        d.ensure_owned(9, heap.base(), 3 * cfg.page_bytes).unwrap();
        d.ensure_owned(13, heap.base(), cfg.page_bytes).unwrap();
        let (_, pages) = d.stats();
        assert_eq!(pages, 4);
        let per_page = DsmState::page_move_ns(&pool.charger.cost);
        assert_eq!(d.charged_ns(), pages * per_page);
        assert_eq!(pool.charger.total_charged_ns() - before, d.charged_ns());
        // Counter snapshot carries the same numbers under stable names.
        let snap = d.counters().snapshot();
        assert_eq!(snap[0], ("dsm_faults", 4));
        assert_eq!(snap[1], ("dsm_pages_transferred", 4));
        assert_eq!(snap[2], ("dsm_charged_ns", 4 * per_page));
    }

    #[test]
    #[should_panic(expected = "at least two nodes")]
    fn single_node_set_rejected() {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "dsm-one", 1 << 20).unwrap();
        let _ = DsmState::new_multi(&heap, cfg.page_bytes, &[7, 7], 7);
    }
}
