//! Pod map for the simulated rack.
//!
//! Hosts `0..rack_hosts` are inside the rack and are partitioned into
//! `pods` contiguous CXL domains of `hosts_per_pod` hosts each (the
//! last pod absorbs any remainder). Hosts at or beyond `rack_hosts`
//! model machines outside the rack entirely; each one is its own
//! singleton "pod" so nothing is CXL-reachable from them.

use crate::config::SimConfig;

/// Identifier for a CXL pod. Out-of-rack hosts get synthetic pod ids
/// `pods + k`; they never equal an in-rack pod id.
pub type PodId = u32;

/// How a heap ended up mapped into a process: directly over the pod's
/// CXL domain, or via the RDMA-backed software-DSM fallback.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapKind {
    Cxl,
    Dsm,
}

/// Immutable pod layout derived from [`SimConfig`].
#[derive(Clone, Debug)]
pub struct Topology {
    rack_hosts: usize,
    pods: usize,
    hosts_per_pod: usize,
}

impl Topology {
    pub fn from_config(cfg: &SimConfig) -> Topology {
        let pods = cfg.pods.max(1);
        let hosts_per_pod = if cfg.hosts_per_pod == 0 {
            cfg.rack_hosts.div_ceil(pods).max(1)
        } else {
            cfg.hosts_per_pod
        };
        Topology { rack_hosts: cfg.rack_hosts, pods, hosts_per_pod }
    }

    pub fn rack_hosts(&self) -> usize {
        self.rack_hosts
    }

    pub fn pod_count(&self) -> usize {
        self.pods
    }

    pub fn hosts_per_pod(&self) -> usize {
        self.hosts_per_pod
    }

    /// Is `host` one of the rack's CXL-attached machines?
    pub fn in_rack(&self, host: u32) -> bool {
        (host as usize) < self.rack_hosts
    }

    /// Pod id of `host`. In-rack hosts map to `0..pods` (the last pod
    /// absorbs the remainder when the division is uneven); out-of-rack
    /// hosts each get a distinct synthetic pod.
    pub fn pod_of(&self, host: u32) -> PodId {
        if self.in_rack(host) {
            ((host as usize / self.hosts_per_pod).min(self.pods - 1)) as PodId
        } else {
            (self.pods + (host as usize - self.rack_hosts)) as PodId
        }
    }

    /// Hardware cache coherence exists only between two in-rack hosts
    /// in the same pod.
    pub fn cxl_reachable(&self, a: u32, b: u32) -> bool {
        self.in_rack(a) && self.in_rack(b) && self.pod_of(a) == self.pod_of(b)
    }

    /// Synthetic DSM peer id used when an RDMA transport is *forced*
    /// between two endpoints of the same pod (benchmarks, tests, and
    /// explicit `TransportSel::Rdma`): the DSM protocol needs two
    /// distinct node ids for pages to ping-pong between. `PodId::MAX`
    /// can never collide with a real pod id — in-rack pods are
    /// `0..pods` and out-of-rack synthetic pods are `pods + k`, both
    /// bounded by the (host-count-sized) rack configuration.
    pub const FORCED_DSM_PEER: PodId = PodId::MAX;

    /// DSM node ids for a client/server pod pair: each endpoint's own
    /// pod when they differ (the genuine cross-pod case), with the
    /// server remapped to [`Topology::FORCED_DSM_PEER`] when both
    /// share a pod — forcing RDMA inside one pod still needs two
    /// distinct coherence nodes. A topology fact, not a connect-site
    /// sentinel.
    pub fn dsm_peer_nodes(client_pod: PodId, server_pod: PodId) -> (PodId, PodId) {
        if server_pod == client_pod {
            (client_pod, Self::FORCED_DSM_PEER)
        } else {
            (client_pod, server_pod)
        }
    }

    /// The `idx`-th host of `pod` (panics if out of range) — handy for
    /// tests and benches that want "some host in pod 1".
    pub fn host_in_pod(&self, pod: PodId, idx: usize) -> u32 {
        let first = pod as usize * self.hosts_per_pod;
        let end = if (pod as usize) + 1 == self.pods {
            self.rack_hosts
        } else {
            (first + self.hosts_per_pod).min(self.rack_hosts)
        };
        let host = first + idx;
        assert!(
            (pod as usize) < self.pods && host < end,
            "host index {idx} out of range for pod {pod}"
        );
        host as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(rack_hosts: usize, pods: usize, hosts_per_pod: usize) -> SimConfig {
        let mut c = SimConfig::for_tests();
        c.rack_hosts = rack_hosts;
        c.pods = pods;
        c.hosts_per_pod = hosts_per_pod;
        c
    }

    #[test]
    fn single_pod_matches_legacy_semantics() {
        let t = Topology::from_config(&cfg(32, 1, 0));
        assert_eq!(t.pod_count(), 1);
        assert_eq!(t.hosts_per_pod(), 32);
        assert!(t.cxl_reachable(0, 31));
        assert!(!t.cxl_reachable(0, 32));
        assert_eq!(t.pod_of(0), 0);
        assert_eq!(t.pod_of(31), 0);
        // Out-of-rack hosts get distinct synthetic pods.
        assert_eq!(t.pod_of(32), 1);
        assert_eq!(t.pod_of(40), 9);
        assert!(!t.cxl_reachable(32, 32) || t.in_rack(32));
    }

    #[test]
    fn two_pods_partition_the_rack() {
        let t = Topology::from_config(&cfg(4, 2, 0));
        assert_eq!(t.hosts_per_pod(), 2);
        assert_eq!(t.pod_of(0), 0);
        assert_eq!(t.pod_of(1), 0);
        assert_eq!(t.pod_of(2), 1);
        assert_eq!(t.pod_of(3), 1);
        assert!(t.cxl_reachable(0, 1));
        assert!(t.cxl_reachable(2, 3));
        assert!(!t.cxl_reachable(1, 2));
        assert!(!t.cxl_reachable(0, 3));
    }

    #[test]
    fn uneven_division_last_pod_absorbs_remainder() {
        // 10 hosts over 3 pods: hosts_per_pod = ceil(10/3) = 4, so pods
        // own hosts [0..4), [4..8), [8..10).
        let t = Topology::from_config(&cfg(10, 3, 0));
        assert_eq!(t.hosts_per_pod(), 4);
        assert_eq!(t.pod_of(3), 0);
        assert_eq!(t.pod_of(4), 1);
        assert_eq!(t.pod_of(7), 1);
        assert_eq!(t.pod_of(8), 2);
        assert_eq!(t.pod_of(9), 2);
    }

    #[test]
    fn explicit_hosts_per_pod_clamps_trailing_pod() {
        // 8 hosts, pods=2, hosts_per_pod=3: pod 0 = [0..3), pod 1
        // (last) absorbs [3..8).
        let t = Topology::from_config(&cfg(8, 2, 3));
        assert_eq!(t.pod_of(2), 0);
        assert_eq!(t.pod_of(3), 1);
        assert_eq!(t.pod_of(7), 1);
    }

    #[test]
    fn host_in_pod_roundtrips() {
        let t = Topology::from_config(&cfg(8, 2, 0));
        for pod in 0..2u32 {
            for idx in 0..4 {
                let h = t.host_in_pod(pod, idx);
                assert_eq!(t.pod_of(h), pod);
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn host_in_pod_rejects_overflow() {
        let t = Topology::from_config(&cfg(8, 2, 0));
        t.host_in_pod(0, 4);
    }

    #[test]
    fn dsm_peer_nodes_passthrough_across_pods() {
        // Genuine cross-pod pair: both endpoints keep their own pod.
        assert_eq!(Topology::dsm_peer_nodes(0, 1), (0, 1));
        assert_eq!(Topology::dsm_peer_nodes(3, 0), (3, 0));
    }

    #[test]
    fn dsm_peer_nodes_forced_same_pod_gets_synthetic_peer() {
        // Forced RDMA inside one pod: the server side becomes the
        // synthetic far node so pages have two nodes to move between.
        let (c, s) = Topology::dsm_peer_nodes(2, 2);
        assert_eq!(c, 2);
        assert_eq!(s, Topology::FORCED_DSM_PEER);
        assert_ne!(c, s);
    }

    #[test]
    fn forced_dsm_peer_never_collides_with_real_pods() {
        // Real pod ids — in-rack (0..pods) and out-of-rack synthetic
        // (pods + k) — are bounded by host counts; the forced peer
        // sits at the type's ceiling.
        let t = Topology::from_config(&cfg(8, 2, 0));
        for host in 0..64u32 {
            assert_ne!(t.pod_of(host), Topology::FORCED_DSM_PEER);
        }
    }
}
