//! The cluster plane (paper §4.7, §5.6): pods, topology, and the
//! generalized software-coherence layer that stitches them together.
//!
//! A CXL pod does not span a datacenter. This module partitions the
//! simulated rack into `pods` CXL domains of `hosts_per_pod` hosts
//! each ([`Topology`]); hardware cache coherence — and therefore the
//! zero-copy CXL data path — exists only *inside* a pod. A heap is
//! CXL-mapped only in its home pod; mapping it from any other pod
//! yields a DSM-backed mapping ([`MapKind::Dsm`]) whose coherence is
//! software-managed page ownership over RDMA ([`dsm::DsmState`],
//! generalized here from the original two-node sketch to per-page
//! owner = pod id).
//!
//! `Connection::connect` consumes this layer transparently: the same
//! `TransportSel::Auto` call site resolves to CXL for an in-pod peer
//! and to the RDMA/DSM fallback for a cross-pod one.

pub mod dsm;
pub mod topology;

pub use dsm::{DsmState, NodeId, NODE_CLIENT, NODE_SERVER};
pub use topology::{MapKind, PodId, Topology};
