//! Sealing: preventing sender-side concurrent modification of
//! in-flight RPC arguments (paper §4.5, §5.3).
//!
//! Protocol reproduced from the paper's Figure 8:
//!  1. sender `seal()` (simulated syscall): kernel writes a *seal
//!     descriptor* into a sender-read-only circular buffer in shared
//!     memory, then flips the argument pages read-only in the sender's
//!     address space;
//!  2. receiver verifies the seal by reading the descriptor
//!     (`verify`), processes the RPC, and marks it complete;
//!  3. sender `release()`: its kernel checks the descriptor is
//!     COMPLETE (only the receiver can set that — asymmetric mapping),
//!     then restores write permission, paying PTE flips + a TLB
//!     shootdown.
//!
//! `release()`'s TLB shootdown is the expensive part, so `ScopePool`
//! implements the paper's batched release: completed scopes accumulate
//! and are released together, amortizing one shootdown across the
//! batch (threshold 1024 by default).

use crate::config::SimConfig;
use crate::error::{Result, RpcError};
use crate::memory::heap::{Heap, ProcId};
use crate::memory::pool::Charger;
use crate::memory::scope::Scope;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Descriptor states (stored in shared memory).
pub const DESC_FREE: u32 = 0;
pub const DESC_SEALED: u32 = 1;
pub const DESC_COMPLETE: u32 = 2;

/// One seal descriptor in the shared circular buffer. The region is
/// mapped read-only for the sender and read-write for the receiver;
/// the simulation enforces that discipline through this API (the
/// sender-side kernel writes descriptors, the receiver marks
/// completion).
#[repr(C)]
struct SealDescriptor {
    state: AtomicU32,
    _pad: u32,
    start: u64,
    len: u64,
}

/// The descriptor circular buffer, resident in the connection heap.
pub struct SealRing {
    base: usize,
    n: usize,
    next: AtomicU64,
}

impl SealRing {
    pub fn create(heap: &Arc<Heap>, n: usize) -> Result<SealRing> {
        let n = n.next_power_of_two().max(8);
        let bytes = n * std::mem::size_of::<SealDescriptor>();
        let base = heap.alloc_bytes(bytes)?;
        unsafe { std::ptr::write_bytes(base as *mut u8, 0, bytes) };
        Ok(SealRing { base, n, next: AtomicU64::new(0) })
    }

    #[inline]
    fn desc(&self, idx: u64) -> &SealDescriptor {
        let slot = (idx as usize) & (self.n - 1);
        unsafe { &*((self.base + slot * std::mem::size_of::<SealDescriptor>()) as *const SealDescriptor) }
    }

    /// Claim the next descriptor slot (sender-kernel side).
    fn alloc(&self) -> Result<u64> {
        // Bounded retry: if the ring wraps onto a still-sealed slot the
        // application has too many in-flight seals.
        for _ in 0..self.n {
            let idx = self.next.fetch_add(1, Ordering::Relaxed);
            let d = self.desc(idx);
            if d
                .state
                .compare_exchange(DESC_FREE, DESC_SEALED, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
            {
                return Ok(idx);
            }
        }
        Err(RpcError::SealInvalid("descriptor ring exhausted (too many in-flight seals)".into()))
    }
}

/// An active seal, as held by the sender. Released via `Sealer`.
#[derive(Clone, Copy, Debug)]
pub struct SealHandle {
    pub idx: u64,
    pub start: usize,
    pub len: usize,
    pub proc: ProcId,
}

/// Per-endpoint sealing facility (wraps the simulated kernel's
/// `seal()`/`release()` syscalls for one connection heap).
pub struct Sealer {
    heap: Arc<Heap>,
    charger: Arc<Charger>,
    ring: SealRing,
    page: usize,
}

impl Sealer {
    pub fn new(cfg: &SimConfig, heap: Arc<Heap>, charger: Arc<Charger>) -> Result<Arc<Sealer>> {
        let ring = SealRing::create(&heap, 4096)?;
        Ok(Arc::new(Sealer { heap, charger, ring, page: cfg.page_bytes }))
    }

    #[inline]
    fn pages(&self, start: usize, len: usize) -> u64 {
        let lo = start & !(self.page - 1);
        let hi = (start + len).div_ceil(self.page) * self.page;
        ((hi - lo) / self.page) as u64
    }

    /// The `seal()` syscall: write descriptor, flip PTEs read-only.
    pub fn seal(&self, start: usize, len: usize, proc: ProcId) -> Result<SealHandle> {
        let c = &self.charger;
        c.charge_ns(c.cost.seal_syscall_ns + self.pages(start, len) * c.cost.pte_flip_per_page_ns);
        let idx = self.ring.alloc()?;
        let d = self.ring.desc(idx);
        // Kernel writes descriptor fields before publishing state.
        unsafe {
            let dm = d as *const SealDescriptor as *mut SealDescriptor;
            (*dm).start = start as u64;
            (*dm).len = len as u64;
        }
        d.state.store(DESC_SEALED, Ordering::Release);
        self.heap.seal_range(start, len, proc);
        Ok(SealHandle { idx, start, len, proc })
    }

    /// Receiver-side verification (`rpc_call::isSealed()`): read the
    /// descriptor over CXL and check it covers the argument range.
    pub fn verify(&self, idx: u64, start: usize, len: usize) -> bool {
        self.charger.charge_cxl_load();
        let d = self.ring.desc(idx);
        if d.state.load(Ordering::Acquire) != DESC_SEALED {
            return false;
        }
        let ds = d.start as usize;
        let de = ds + d.len as usize;
        ds <= start && start + len <= de
    }

    /// Receiver marks the RPC complete (receiver has RW on the ring).
    pub fn complete(&self, idx: u64) {
        let d = self.ring.desc(idx);
        d.state.store(DESC_COMPLETE, Ordering::Release);
    }

    /// The `release()` syscall: kernel refuses unless COMPLETE, then
    /// restores write access (PTE flips + TLB shootdown).
    pub fn release(&self, h: SealHandle) -> Result<()> {
        let d = self.ring.desc(h.idx);
        if d.state.load(Ordering::Acquire) != DESC_COMPLETE {
            return Err(RpcError::ReleaseDenied(h.idx));
        }
        let c = &self.charger;
        c.charge_ns(
            c.cost.seal_syscall_ns
                + self.pages(h.start, h.len) * c.cost.pte_flip_per_page_ns
                + c.cost.tlb_shootdown_ns,
        );
        self.heap.unseal_range(h.start, h.len, h.proc);
        d.state.store(DESC_FREE, Ordering::Release);
        Ok(())
    }

    /// Batched release: one syscall + one TLB shootdown for the whole
    /// batch (paper §5.3 "Optimizing Sealing").
    pub fn release_batch(&self, hs: &[SealHandle]) -> Result<()> {
        if hs.is_empty() {
            return Ok(());
        }
        // Verify all are complete first — a single incomplete RPC
        // blocks the batch (callers may fall back to single release).
        for h in hs {
            if self.ring.desc(h.idx).state.load(Ordering::Acquire) != DESC_COMPLETE {
                return Err(RpcError::ReleaseDenied(h.idx));
            }
        }
        let c = &self.charger;
        let total_pages: u64 = hs.iter().map(|h| self.pages(h.start, h.len)).sum();
        c.charge_ns(
            c.cost.seal_syscall_ns
                + total_pages * c.cost.pte_flip_per_page_ns
                + c.cost.tlb_shootdown_ns,
        );
        for h in hs {
            self.heap.unseal_range(h.start, h.len, h.proc);
            self.ring.desc(h.idx).state.store(DESC_FREE, Ordering::Release);
        }
        Ok(())
    }

    pub fn heap(&self) -> &Arc<Heap> {
        &self.heap
    }
}

// ------------------------------------------------------------ scope pool

/// A pooled scope checked out of a `ScopePool`.
pub struct PooledScope {
    pub scope: Scope,
}

/// Scope pool with batched seal release (paper §5.3): pop a scope,
/// build arguments, send sealed; on completion hand the scope back
/// with its seal handle — the pool releases seals in batches, and only
/// then do scopes become reusable.
pub struct ScopePool {
    heap: Arc<Heap>,
    sealer: Arc<Sealer>,
    scope_bytes: usize,
    threshold: usize,
    free: Mutex<Vec<Scope>>,
    pending: Mutex<Vec<(Scope, SealHandle)>>,
    flushes: AtomicU64,
}

impl ScopePool {
    pub fn new(
        heap: Arc<Heap>,
        sealer: Arc<Sealer>,
        scope_bytes: usize,
        threshold: usize,
    ) -> Arc<ScopePool> {
        Arc::new(ScopePool {
            heap,
            sealer,
            scope_bytes,
            threshold: threshold.max(1),
            free: Mutex::new(Vec::new()),
            pending: Mutex::new(Vec::new()),
            flushes: AtomicU64::new(0),
        })
    }

    /// Pop a scope (allocating if the pool is dry).
    pub fn pop(&self) -> Result<Scope> {
        if let Some(s) = self.free.lock().unwrap().pop() {
            return Ok(s);
        }
        Scope::create(&self.heap, self.scope_bytes)
    }

    /// Return a scope whose seal is complete; released in a batch once
    /// the threshold accumulates.
    pub fn push_sealed(&self, scope: Scope, handle: SealHandle) -> Result<()> {
        let flush = {
            let mut pending = self.pending.lock().unwrap();
            pending.push((scope, handle));
            pending.len() >= self.threshold
        };
        if flush {
            self.flush()?;
        }
        Ok(())
    }

    /// Return an unsealed scope directly to the free list.
    pub fn push(&self, scope: Scope) {
        scope.reset();
        self.free.lock().unwrap().push(scope);
    }

    /// Release every pending seal in one batch.
    pub fn flush(&self) -> Result<()> {
        let drained: Vec<(Scope, SealHandle)> =
            { self.pending.lock().unwrap().drain(..).collect() };
        if drained.is_empty() {
            return Ok(());
        }
        let handles: Vec<SealHandle> = drained.iter().map(|(_, h)| *h).collect();
        self.sealer.release_batch(&handles)?;
        self.flushes.fetch_add(1, Ordering::Relaxed);
        let mut free = self.free.lock().unwrap();
        for (scope, _) in drained {
            scope.reset();
            free.push(scope);
        }
        Ok(())
    }

    pub fn pending_len(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    pub fn flushes(&self) -> u64 {
        self.flushes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::pool::Pool;
    use crate::memory::ptr::ShmPtr;
    use crate::simproc;

    fn setup() -> (Arc<Pool>, Arc<Heap>, Arc<Sealer>) {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "seal", 32 << 20).unwrap();
        let sealer = Sealer::new(&cfg, Arc::clone(&heap), Arc::clone(&pool.charger)).unwrap();
        (pool, heap, sealer)
    }

    #[test]
    fn seal_protocol_happy_path() {
        simproc::set_enforcement(true);
        let (_p, heap, sealer) = setup();
        let scope = Scope::create(&heap, 4096).unwrap();
        let arg = scope.new_val(41u64).unwrap();
        simproc::with_identity(5, 0, || {
            let h = sealer.seal(scope.base(), scope.len(), 5).unwrap();
            // Sender can no longer write the argument.
            let p: ShmPtr<u64> = ShmPtr::from_addr(arg);
            assert!(p.write(99).is_err());
            // Receiver verifies, processes, completes.
            assert!(sealer.verify(h.idx, arg, 8));
            sealer.complete(h.idx);
            // Sender releases, write access restored.
            sealer.release(h).unwrap();
            assert!(p.write(99).is_ok());
        });
    }

    #[test]
    fn release_before_complete_denied() {
        let (_p, heap, sealer) = setup();
        let scope = Scope::create(&heap, 4096).unwrap();
        let h = sealer.seal(scope.base(), scope.len(), 1).unwrap();
        assert_eq!(sealer.release(h), Err(RpcError::ReleaseDenied(h.idx)));
        sealer.complete(h.idx);
        assert!(sealer.release(h).is_ok());
    }

    #[test]
    fn verify_rejects_unsealed_and_uncovered() {
        let (_p, heap, sealer) = setup();
        let scope = Scope::create(&heap, 2 * 4096).unwrap();
        assert!(!sealer.verify(3, scope.base(), 64), "nothing sealed yet");
        let h = sealer.seal(scope.base(), 4096, 1).unwrap();
        assert!(sealer.verify(h.idx, scope.base(), 4096));
        assert!(
            !sealer.verify(h.idx, scope.base(), 2 * 4096),
            "args extend past the sealed range"
        );
        sealer.complete(h.idx);
        sealer.release(h).unwrap();
    }

    #[test]
    fn batch_release_amortizes_shootdowns() {
        let (_p, heap, sealer) = setup();
        let n = 64;
        let mut handles = Vec::new();
        let scopes: Vec<Scope> = (0..n).map(|_| Scope::create(&heap, 4096).unwrap()).collect();
        for s in &scopes {
            let h = sealer.seal(s.base(), s.len(), 1).unwrap();
            sealer.complete(h.idx);
            handles.push(h);
        }
        let before = heap.pool().charger.total_charged_ns();
        sealer.release_batch(&handles).unwrap();
        let batch_cost = heap.pool().charger.total_charged_ns() - before;
        // One shootdown, not 64.
        let single = CostModelProbe::single_release_cost(&sealer, &heap);
        assert!(
            batch_cost < single * n as u64 / 4,
            "batch {batch_cost}ns should be ≪ {n}×single {single}ns"
        );
        assert_eq!(heap.sealed_count(), 0);
    }

    struct CostModelProbe;
    impl CostModelProbe {
        fn single_release_cost(sealer: &Arc<Sealer>, heap: &Arc<Heap>) -> u64 {
            let s = Scope::create(heap, 4096).unwrap();
            let h = sealer.seal(s.base(), s.len(), 2).unwrap();
            sealer.complete(h.idx);
            let before = heap.pool().charger.total_charged_ns();
            sealer.release(h).unwrap();
            heap.pool().charger.total_charged_ns() - before
        }
    }

    #[test]
    fn scope_pool_flushes_at_threshold() {
        let (_p, heap, sealer) = setup();
        let pool = ScopePool::new(Arc::clone(&heap), Arc::clone(&sealer), 4096, 8);
        for i in 0..20 {
            let scope = pool.pop().unwrap();
            let h = sealer.seal(scope.base(), scope.len(), 1).unwrap();
            sealer.complete(h.idx);
            pool.push_sealed(scope, h).unwrap();
            let _ = i;
        }
        assert_eq!(pool.flushes(), 2, "two threshold flushes at 8 and 16");
        assert_eq!(pool.pending_len(), 4);
        pool.flush().unwrap();
        assert_eq!(pool.pending_len(), 0);
        assert_eq!(heap.sealed_count(), 0);
    }

    #[test]
    fn ring_wraps_and_reuses_slots() {
        let (_p, heap, sealer) = setup();
        let scope = Scope::create(&heap, 4096).unwrap();
        // Far more seals than ring slots; each released promptly.
        for _ in 0..10_000 {
            let h = sealer.seal(scope.base(), scope.len(), 1).unwrap();
            sealer.complete(h.idx);
            sealer.release(h).unwrap();
        }
        assert_eq!(heap.sealed_count(), 0);
    }
}
