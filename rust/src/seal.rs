//! Sealing: preventing sender-side concurrent modification of
//! in-flight RPC arguments (paper §4.5, §5.3).
//!
//! Protocol reproduced from the paper's Figure 8:
//!  1. sender `seal()` (simulated syscall): kernel writes a *seal
//!     descriptor* into a sender-read-only circular buffer in shared
//!     memory, then flips the argument pages read-only in the sender's
//!     address space;
//!  2. receiver verifies the seal by reading the descriptor
//!     (`verify`), processes the RPC, and marks it complete;
//!  3. sender `release()`: its kernel checks the descriptor is
//!     COMPLETE (only the receiver can set that — asymmetric mapping),
//!     then restores write permission, paying PTE flips + a TLB
//!     shootdown.
//!
//! `release()`'s TLB shootdown is the expensive part, so `ScopePool`
//! implements the paper's batched release: completed scopes accumulate
//! and are released together, amortizing one shootdown across the
//! batch (threshold 1024 by default).

use crate::config::SimConfig;
use crate::error::{Result, RpcError};
use crate::memory::heap::{Heap, ProcId};
use crate::memory::pool::Charger;
use crate::memory::scope::Scope;
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Descriptor states (stored in shared memory).
pub const DESC_FREE: u32 = 0;
pub const DESC_SEALED: u32 = 1;
pub const DESC_COMPLETE: u32 = 2;

/// One seal descriptor in the shared circular buffer. The region is
/// mapped read-only for the sender and read-write for the receiver;
/// the simulation enforces that discipline through this API (the
/// sender-side kernel writes descriptors, the receiver marks
/// completion).
#[repr(C)]
struct SealDescriptor {
    state: AtomicU32,
    /// Sealing proc — the failure plane's handle for force-releasing
    /// everything a dead proc left sealed (`Sealer::revoke_proc`).
    proc: AtomicU32,
    start: u64,
    len: u64,
}

/// The descriptor circular buffer, resident in the connection heap.
pub struct SealRing {
    base: usize,
    n: usize,
    next: AtomicU64,
}

impl SealRing {
    pub fn create(heap: &Arc<Heap>, n: usize) -> Result<SealRing> {
        let n = n.next_power_of_two().max(8);
        let bytes = n * std::mem::size_of::<SealDescriptor>();
        let base = heap.alloc_bytes(bytes)?;
        unsafe { std::ptr::write_bytes(base as *mut u8, 0, bytes) };
        Ok(SealRing { base, n, next: AtomicU64::new(0) })
    }

    #[inline]
    fn desc(&self, idx: u64) -> &SealDescriptor {
        let slot = (idx as usize) & (self.n - 1);
        unsafe { &*((self.base + slot * std::mem::size_of::<SealDescriptor>()) as *const SealDescriptor) }
    }

    /// Claim the next descriptor slot (sender-kernel side).
    fn alloc(&self) -> Result<u64> {
        // Bounded retry: if the ring wraps onto a still-sealed slot the
        // application has too many in-flight seals.
        for _ in 0..self.n {
            let idx = self.next.fetch_add(1, Ordering::Relaxed);
            let d = self.desc(idx);
            if d
                .state
                .compare_exchange(DESC_FREE, DESC_SEALED, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
            {
                return Ok(idx);
            }
        }
        Err(RpcError::SealInvalid("descriptor ring exhausted (too many in-flight seals)".into()))
    }
}

/// An active seal, as held by the sender. Released via `Sealer`.
#[derive(Clone, Copy, Debug)]
pub struct SealHandle {
    pub idx: u64,
    pub start: usize,
    pub len: usize,
    pub proc: ProcId,
}

/// Per-endpoint sealing facility (wraps the simulated kernel's
/// `seal()`/`release()` syscalls for one connection heap).
pub struct Sealer {
    heap: Arc<Heap>,
    charger: Arc<Charger>,
    ring: SealRing,
    page: usize,
}

impl Sealer {
    pub fn new(cfg: &SimConfig, heap: Arc<Heap>, charger: Arc<Charger>) -> Result<Arc<Sealer>> {
        let ring = SealRing::create(&heap, 4096)?;
        Ok(Arc::new(Sealer { heap, charger, ring, page: cfg.page_bytes }))
    }

    #[inline]
    fn pages(&self, start: usize, len: usize) -> u64 {
        let lo = start & !(self.page - 1);
        let hi = (start + len).div_ceil(self.page) * self.page;
        ((hi - lo) / self.page) as u64
    }

    /// The `seal()` syscall: write descriptor, flip PTEs read-only.
    pub fn seal(&self, start: usize, len: usize, proc: ProcId) -> Result<SealHandle> {
        let c = &self.charger;
        c.charge_ns(c.cost.seal_syscall_ns + self.pages(start, len) * c.cost.pte_flip_per_page_ns);
        let idx = self.ring.alloc()?;
        let d = self.ring.desc(idx);
        // Kernel writes descriptor fields before publishing state.
        unsafe {
            let dm = d as *const SealDescriptor as *mut SealDescriptor;
            (*dm).start = start as u64;
            (*dm).len = len as u64;
        }
        d.proc.store(proc, Ordering::Relaxed);
        d.state.store(DESC_SEALED, Ordering::Release);
        self.heap.seal_range(start, len, proc);
        Ok(SealHandle { idx, start, len, proc })
    }

    /// Receiver-side verification (`rpc_call::isSealed()`): read the
    /// descriptor over CXL and check it covers the argument range.
    pub fn verify(&self, idx: u64, start: usize, len: usize) -> bool {
        self.charger.charge_cxl_load();
        let d = self.ring.desc(idx);
        if d.state.load(Ordering::Acquire) != DESC_SEALED {
            return false;
        }
        let ds = d.start as usize;
        let de = ds + d.len as usize;
        ds <= start && start + len <= de
    }

    /// Receiver marks the RPC complete (receiver has RW on the ring).
    pub fn complete(&self, idx: u64) {
        let d = self.ring.desc(idx);
        d.state.store(DESC_COMPLETE, Ordering::Release);
    }

    /// The `release()` syscall: kernel refuses unless COMPLETE, then
    /// restores write access (PTE flips + TLB shootdown).
    pub fn release(&self, h: SealHandle) -> Result<()> {
        let d = self.ring.desc(h.idx);
        if d.state.load(Ordering::Acquire) != DESC_COMPLETE {
            return Err(RpcError::ReleaseDenied(h.idx));
        }
        let c = &self.charger;
        c.charge_ns(
            c.cost.seal_syscall_ns
                + self.pages(h.start, h.len) * c.cost.pte_flip_per_page_ns
                + c.cost.tlb_shootdown_ns,
        );
        self.heap.unseal_range(h.start, h.len, h.proc);
        d.state.store(DESC_FREE, Ordering::Release);
        Ok(())
    }

    /// Batched release: one syscall + one TLB shootdown for the whole
    /// batch (paper §5.3 "Optimizing Sealing").
    pub fn release_batch(&self, hs: &[SealHandle]) -> Result<()> {
        if hs.is_empty() {
            return Ok(());
        }
        // Verify all are complete first — a single incomplete RPC
        // blocks the batch (callers may fall back to single release).
        for h in hs {
            if self.ring.desc(h.idx).state.load(Ordering::Acquire) != DESC_COMPLETE {
                return Err(RpcError::ReleaseDenied(h.idx));
            }
        }
        let c = &self.charger;
        let total_pages: u64 = hs.iter().map(|h| self.pages(h.start, h.len)).sum();
        c.charge_ns(
            c.cost.seal_syscall_ns
                + total_pages * c.cost.pte_flip_per_page_ns
                + c.cost.tlb_shootdown_ns,
        );
        for h in hs {
            self.heap.unseal_range(h.start, h.len, h.proc);
            self.ring.desc(h.idx).state.store(DESC_FREE, Ordering::Release);
        }
        Ok(())
    }

    pub fn heap(&self) -> &Arc<Heap> {
        &self.heap
    }

    /// Failure plane: force-release every seal a dead proc holds on
    /// this connection (orchestrator sweep, after lease expiry). The
    /// dead sender will never call `release()`, so its SEALED and
    /// COMPLETE descriptors would pin the argument pages read-only —
    /// and pin the heap's seal words — forever. The COMPLETE gate is
    /// deliberately bypassed: the authority here is the orchestrator
    /// acting as the dead proc's kernel, not the (gone) sender.
    /// Returns the number of seals revoked. No cost is charged — the
    /// dead proc's address space no longer exists, so there are no
    /// PTEs to flip or TLBs to shoot down; only the shared descriptor
    /// and page-word state is cleaned.
    pub fn revoke_proc(&self, dead: ProcId) -> u64 {
        let mut revoked = 0u64;
        for slot in 0..self.ring.n {
            let d = self.ring.desc(slot as u64);
            let st = d.state.load(Ordering::Acquire);
            if (st == DESC_SEALED || st == DESC_COMPLETE)
                && d.proc.load(Ordering::Relaxed) == dead
            {
                self.heap.unseal_range(d.start as usize, d.len as usize, dead);
                d.state.store(DESC_FREE, Ordering::Release);
                revoked += 1;
            }
        }
        revoked
    }
}

// ------------------------------------------------------------ scope pool

/// A pooled scope checked out of a `ScopePool`.
pub struct PooledScope {
    pub scope: Scope,
}

// ---- lock-free plumbing for the pool ----

/// Low 48 bits of a stack head word hold the node pointer; the top 16
/// are a monotonically bumped ABA tag (user-space addresses fit 48
/// bits on the Linux/x86-64 class machines this simulation targets).
const STACK_PTR: u64 = (1 << 48) - 1;

#[inline]
fn stack_word(tag_src: u64, ptr: u64) -> u64 {
    debug_assert_eq!(ptr & !STACK_PTR, 0, "node pointer above 2^48");
    ((tag_src >> 48).wrapping_add(1) << 48) | ptr
}

/// One pool node. Nodes are heap-boxed once and **never deallocated
/// while the pool lives** (popped nodes park on the spare stack for
/// reuse) — that is what makes the Treiber `pop`'s read of a possibly
/// already-popped node's `next` safe: the memory stays valid, and the
/// tag CAS rejects any stale read (the classic ABA defence).
struct PoolNode {
    /// `Some` exactly while the node sits on `free`/`pending`; the
    /// handle is `Some` only for pending (sealed) scopes. Exclusive
    /// access alternates owner via the stacks' AcqRel CASes.
    item: UnsafeCell<Option<(Scope, Option<SealHandle>)>>,
    /// Untagged address of the next node down-stack (0 = end).
    next: AtomicU64,
}

/// Tagged Treiber stack of [`PoolNode`]s.
struct TaggedStack {
    head: AtomicU64,
}

impl TaggedStack {
    const fn new() -> TaggedStack {
        TaggedStack { head: AtomicU64::new(0) }
    }

    fn push(&self, node: *mut PoolNode) {
        let naddr = node as u64;
        let mut cur = self.head.load(Ordering::Relaxed);
        loop {
            unsafe { (*node).next.store(cur & STACK_PTR, Ordering::Relaxed) };
            match self.head.compare_exchange_weak(
                cur,
                stack_word(cur, naddr),
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return,
                Err(c) => cur = c,
            }
        }
    }

    fn pop(&self) -> Option<*mut PoolNode> {
        let mut cur = self.head.load(Ordering::Acquire);
        loop {
            let ptr = (cur & STACK_PTR) as *mut PoolNode;
            if ptr.is_null() {
                return None;
            }
            // Node memory is never freed while the pool lives, so
            // this read is valid even if `ptr` was popped concurrently;
            // the tagged CAS below fails on any interleaving that
            // could have made the value stale.
            let next = unsafe { (*ptr).next.load(Ordering::Relaxed) };
            match self.head.compare_exchange_weak(
                cur,
                stack_word(cur, next),
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Some(ptr),
                Err(c) => cur = c,
            }
        }
    }
}

/// Scope pool with batched seal release (paper §5.3): pop a scope,
/// build arguments, send sealed; on completion hand the scope back
/// with its seal handle — the pool releases seals in batches, and only
/// then do scopes become reusable.
///
/// **Lock-free** since the memory-plane overhaul: the free list is a
/// tagged Treiber stack, the pending set is a push-only list drained
/// whole by an atomic `swap` (drain-by-swap has no ABA window, and it
/// hands each pending scope to exactly one flusher — the
/// exactly-once-release property the stress suite pins), and the
/// threshold trigger is a plain atomic counter. Seal/release *costs*
/// and the COMPLETE-gated batched-release protocol are unchanged.
pub struct ScopePool {
    heap: Arc<Heap>,
    sealer: Arc<Sealer>,
    scope_bytes: usize,
    threshold: usize,
    /// Reusable scopes (each node's item = `Some((scope, None))`).
    free: TaggedStack,
    /// Empty nodes awaiting reuse — the no-deallocation store backing
    /// the ABA argument above.
    spare: TaggedStack,
    /// Untagged head of the push-only pending list (tags are not
    /// needed: pushes link to whatever head they observed, and the
    /// only pop is `swap(0)`).
    pending: AtomicU64,
    pending_n: AtomicUsize,
    flushes: AtomicU64,
}

// Scopes migrate between threads through the node store; they are
// Send+Sync by construction (Arc<Heap> + segment + atomic bump).
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Scope>();
};

impl ScopePool {
    pub fn new(
        heap: Arc<Heap>,
        sealer: Arc<Sealer>,
        scope_bytes: usize,
        threshold: usize,
    ) -> Arc<ScopePool> {
        Arc::new(ScopePool {
            heap,
            sealer,
            scope_bytes,
            threshold: threshold.max(1),
            free: TaggedStack::new(),
            spare: TaggedStack::new(),
            pending: AtomicU64::new(0),
            pending_n: AtomicUsize::new(0),
            flushes: AtomicU64::new(0),
        })
    }

    /// A node to carry `item`: reuse a spare, box a fresh one if none.
    fn node_with(&self, item: (Scope, Option<SealHandle>)) -> *mut PoolNode {
        match self.spare.pop() {
            Some(n) => {
                unsafe { *(*n).item.get() = Some(item) };
                n
            }
            None => Box::into_raw(Box::new(PoolNode {
                item: UnsafeCell::new(Some(item)),
                next: AtomicU64::new(0),
            })),
        }
    }

    /// Take the item out of a node we exclusively own and park the
    /// husk on the spare stack.
    fn take_item(&self, n: *mut PoolNode) -> (Scope, Option<SealHandle>) {
        let item = unsafe { (*(*n).item.get()).take().expect("pool node without item") };
        self.spare.push(n);
        item
    }

    /// Pop a scope (allocating if the pool is dry). Lock-free.
    pub fn pop(&self) -> Result<Scope> {
        if let Some(n) = self.free.pop() {
            return Ok(self.take_item(n).0);
        }
        Scope::create(&self.heap, self.scope_bytes)
    }

    /// Return a scope whose seal is complete; released in a batch once
    /// the threshold accumulates. Lock-free push; the thread whose
    /// push crosses the threshold runs the flush.
    pub fn push_sealed(&self, scope: Scope, handle: SealHandle) -> Result<()> {
        let node = self.node_with((scope, Some(handle)));
        // Count BEFORE linking: flush only subtracts nodes it actually
        // drained, and every drained node was counted first (the link
        // CAS's release publishes the increment to the drainer's
        // swap-acquire) — so the counter can never run negative. A
        // counted-but-not-yet-linked node merely lets a concurrent
        // flush trigger one push early.
        let n = self.pending_n.fetch_add(1, Ordering::Relaxed) + 1;
        let naddr = node as u64;
        let mut cur = self.pending.load(Ordering::Relaxed);
        loop {
            unsafe { (*node).next.store(cur, Ordering::Relaxed) };
            match self.pending.compare_exchange_weak(
                cur,
                naddr,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
        if n >= self.threshold {
            self.flush()?;
        }
        Ok(())
    }

    /// Return an unsealed scope directly to the free list.
    pub fn push(&self, scope: Scope) {
        scope.reset();
        let node = self.node_with((scope, None));
        self.free.push(node);
    }

    /// Release every pending seal in one batch. The `swap` hands the
    /// whole chain to exactly one caller, so concurrent
    /// threshold-crossers each release a disjoint batch (never the
    /// same seal twice — a double release would trip the
    /// COMPLETE-gate as `ReleaseDenied`).
    pub fn flush(&self) -> Result<()> {
        let head = self.pending.swap(0, Ordering::AcqRel);
        if head == 0 {
            return Ok(());
        }
        let mut drained: Vec<(Scope, SealHandle)> = Vec::new();
        let mut p = head as *mut PoolNode;
        while !p.is_null() {
            let next = unsafe { (*p).next.load(Ordering::Relaxed) } as *mut PoolNode;
            let (scope, h) = self.take_item(p);
            drained.push((scope, h.expect("pending scope without seal handle")));
            p = next;
        }
        self.pending_n.fetch_sub(drained.len(), Ordering::Relaxed);
        let handles: Vec<SealHandle> = drained.iter().map(|(_, h)| *h).collect();
        self.sealer.release_batch(&handles)?;
        self.flushes.fetch_add(1, Ordering::Relaxed);
        for (scope, _) in drained {
            scope.reset();
            self.free.push(self.node_with((scope, None)));
        }
        Ok(())
    }

    pub fn pending_len(&self) -> usize {
        self.pending_n.load(Ordering::Relaxed)
    }

    pub fn flushes(&self) -> u64 {
        self.flushes.load(Ordering::Relaxed)
    }
}

impl Drop for ScopePool {
    fn drop(&mut self) {
        // Reclaim every node (scopes inside drop with them, returning
        // their pages to the heap). Exclusive access: &mut self.
        unsafe {
            while let Some(n) = self.free.pop() {
                drop(Box::from_raw(n));
            }
            let mut p = self.pending.swap(0, Ordering::AcqRel) as *mut PoolNode;
            while !p.is_null() {
                let next = (*p).next.load(Ordering::Relaxed) as *mut PoolNode;
                drop(Box::from_raw(p));
                p = next;
            }
            while let Some(n) = self.spare.pop() {
                drop(Box::from_raw(n));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::pool::Pool;
    use crate::memory::ptr::ShmPtr;
    use crate::simproc;

    fn setup() -> (Arc<Pool>, Arc<Heap>, Arc<Sealer>) {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "seal", 32 << 20).unwrap();
        let sealer = Sealer::new(&cfg, Arc::clone(&heap), Arc::clone(&pool.charger)).unwrap();
        (pool, heap, sealer)
    }

    #[test]
    fn seal_protocol_happy_path() {
        simproc::set_enforcement(true);
        let (_p, heap, sealer) = setup();
        let scope = Scope::create(&heap, 4096).unwrap();
        let arg = scope.new_val(41u64).unwrap();
        simproc::with_identity(5, 0, || {
            let h = sealer.seal(scope.base(), scope.len(), 5).unwrap();
            // Sender can no longer write the argument.
            let p: ShmPtr<u64> = ShmPtr::from_addr(arg);
            assert!(p.write(99).is_err());
            // Receiver verifies, processes, completes.
            assert!(sealer.verify(h.idx, arg, 8));
            sealer.complete(h.idx);
            // Sender releases, write access restored.
            sealer.release(h).unwrap();
            assert!(p.write(99).is_ok());
        });
    }

    #[test]
    fn release_before_complete_denied() {
        let (_p, heap, sealer) = setup();
        let scope = Scope::create(&heap, 4096).unwrap();
        let h = sealer.seal(scope.base(), scope.len(), 1).unwrap();
        assert_eq!(sealer.release(h), Err(RpcError::ReleaseDenied(h.idx)));
        sealer.complete(h.idx);
        assert!(sealer.release(h).is_ok());
    }

    #[test]
    fn verify_rejects_unsealed_and_uncovered() {
        let (_p, heap, sealer) = setup();
        let scope = Scope::create(&heap, 2 * 4096).unwrap();
        assert!(!sealer.verify(3, scope.base(), 64), "nothing sealed yet");
        let h = sealer.seal(scope.base(), 4096, 1).unwrap();
        assert!(sealer.verify(h.idx, scope.base(), 4096));
        assert!(
            !sealer.verify(h.idx, scope.base(), 2 * 4096),
            "args extend past the sealed range"
        );
        sealer.complete(h.idx);
        sealer.release(h).unwrap();
    }

    #[test]
    fn batch_release_amortizes_shootdowns() {
        let (_p, heap, sealer) = setup();
        let n = 64;
        let mut handles = Vec::new();
        let scopes: Vec<Scope> = (0..n).map(|_| Scope::create(&heap, 4096).unwrap()).collect();
        for s in &scopes {
            let h = sealer.seal(s.base(), s.len(), 1).unwrap();
            sealer.complete(h.idx);
            handles.push(h);
        }
        let before = heap.pool().charger.total_charged_ns();
        sealer.release_batch(&handles).unwrap();
        let batch_cost = heap.pool().charger.total_charged_ns() - before;
        // One shootdown, not 64.
        let single = CostModelProbe::single_release_cost(&sealer, &heap);
        assert!(
            batch_cost < single * n as u64 / 4,
            "batch {batch_cost}ns should be ≪ {n}×single {single}ns"
        );
        assert_eq!(heap.sealed_count(), 0);
    }

    struct CostModelProbe;
    impl CostModelProbe {
        fn single_release_cost(sealer: &Arc<Sealer>, heap: &Arc<Heap>) -> u64 {
            let s = Scope::create(heap, 4096).unwrap();
            let h = sealer.seal(s.base(), s.len(), 2).unwrap();
            sealer.complete(h.idx);
            let before = heap.pool().charger.total_charged_ns();
            sealer.release(h).unwrap();
            heap.pool().charger.total_charged_ns() - before
        }
    }

    #[test]
    fn scope_pool_flushes_at_threshold() {
        let (_p, heap, sealer) = setup();
        let pool = ScopePool::new(Arc::clone(&heap), Arc::clone(&sealer), 4096, 8);
        for i in 0..20 {
            let scope = pool.pop().unwrap();
            let h = sealer.seal(scope.base(), scope.len(), 1).unwrap();
            sealer.complete(h.idx);
            pool.push_sealed(scope, h).unwrap();
            let _ = i;
        }
        assert_eq!(pool.flushes(), 2, "two threshold flushes at 8 and 16");
        assert_eq!(pool.pending_len(), 4);
        pool.flush().unwrap();
        assert_eq!(pool.pending_len(), 0);
        assert_eq!(heap.sealed_count(), 0);
    }

    #[test]
    fn scope_pool_pop_push_recycles_lock_free() {
        let (_p, heap, sealer) = setup();
        let pool = ScopePool::new(Arc::clone(&heap), Arc::clone(&sealer), 4096, 8);
        let s1 = pool.pop().unwrap();
        let base1 = s1.base();
        pool.push(s1);
        let s2 = pool.pop().unwrap();
        assert_eq!(s2.base(), base1, "free stack recycles the scope");
        pool.push(s2);
        // Node husks recycle through the spare stack: a long pop/push
        // run allocates exactly one scope.
        let free0 = heap.free_page_bytes();
        for _ in 0..1000 {
            let s = pool.pop().unwrap();
            pool.push(s);
        }
        assert_eq!(heap.free_page_bytes(), free0);
    }

    #[test]
    fn scope_pool_concurrent_batched_release() {
        let (_p, heap, sealer) = setup();
        let pool = ScopePool::new(Arc::clone(&heap), Arc::clone(&sealer), 4096, 16);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = Arc::clone(&pool);
                let sealer = Arc::clone(&sealer);
                s.spawn(move || {
                    for _ in 0..200 {
                        let scope = pool.pop().unwrap();
                        let h = sealer.seal(scope.base(), scope.len(), 1).unwrap();
                        sealer.complete(h.idx);
                        // Any double-drain would release a seal twice
                        // and trip the COMPLETE gate as ReleaseDenied.
                        pool.push_sealed(scope, h).unwrap();
                    }
                });
            }
        });
        pool.flush().unwrap();
        assert_eq!(pool.pending_len(), 0);
        assert_eq!(heap.sealed_count(), 0, "every seal released exactly once");
    }

    /// Failure plane: a dead proc's seals (SEALED and COMPLETE alike)
    /// are force-released by `revoke_proc`; survivors' seals are not.
    #[test]
    fn revoke_proc_releases_only_the_dead_procs_seals() {
        let (_p, heap, sealer) = setup();
        let s1 = Scope::create(&heap, 4096).unwrap();
        let s2 = Scope::create(&heap, 4096).unwrap();
        let s3 = Scope::create(&heap, 4096).unwrap();
        let dead: ProcId = 7;
        let alive: ProcId = 8;
        // Dead proc: one still-SEALED, one COMPLETE-but-unreleased.
        let h1 = sealer.seal(s1.base(), s1.len(), dead).unwrap();
        let h2 = sealer.seal(s2.base(), s2.len(), dead).unwrap();
        sealer.complete(h2.idx);
        // Survivor's in-flight seal must be untouched.
        let h3 = sealer.seal(s3.base(), s3.len(), alive).unwrap();
        assert_eq!(heap.sealed_count(), 3);

        assert_eq!(sealer.revoke_proc(dead), 2);
        assert_eq!(heap.sealed_count(), 1, "only the survivor's seal remains");
        assert!(!sealer.verify(h1.idx, s1.base(), 64), "revoked seal no longer verifies");
        assert!(sealer.verify(h3.idx, s3.base(), 64), "survivor still verifies");
        assert_eq!(sealer.revoke_proc(dead), 0, "idempotent: nothing left to revoke");
        // Survivor completes its protocol normally.
        sealer.complete(h3.idx);
        sealer.release(h3).unwrap();
        assert_eq!(heap.sealed_count(), 0);
        let _ = h2;
    }

    #[test]
    fn ring_wraps_and_reuses_slots() {
        let (_p, heap, sealer) = setup();
        let scope = Scope::create(&heap, 4096).unwrap();
        // Far more seals than ring slots; each released promptly.
        for _ in 0..10_000 {
            let h = sealer.seal(scope.base(), scope.len(), 1).unwrap();
            sealer.complete(h.idx);
            sealer.release(h).unwrap();
        }
        assert_eq!(heap.sealed_count(), 0);
    }
}
