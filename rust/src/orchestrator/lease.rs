//! Leases: failure detection + orphaned-heap reclamation (paper §5.4).
//!
//! Every time a proc maps a heap it receives a lease; `librpcool`
//! renews it periodically. If a proc dies (crash = it stops renewing),
//! the lease expires, the orchestrator notifies the other participants
//! and — once the last lease on a heap is gone — reclaims the heap.
//!
//! ## The boundary instant
//!
//! A renew arriving at *exactly* `expires` loses: **expire wins the
//! tie**. `renew` succeeds only while `expires > now` (strict), and
//! `expire` harvests every lease with `expires <= now` (inclusive), so
//! the two predicates partition time with no gap and no overlap — at
//! any instant a lease is either renewable or harvestable, never both,
//! never neither. Failure detection prefers the pessimistic side: a
//! renewal that cuts it to the exact deadline is treated as too late,
//! because a recovery sweep running at that same instant must be able
//! to rely on the lease being dead (`crash_stress` counts on expiry
//! being final once the TTL has fully elapsed).

use crate::memory::heap::ProcId;
use std::collections::HashMap;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LeaseId(pub u64);

#[derive(Clone, Debug)]
pub struct Lease {
    pub id: LeaseId,
    pub heap_id: u64,
    pub proc: ProcId,
    pub expires: Instant,
}

/// Lease table: pure bookkeeping, driven by the orchestrator.
pub struct LeaseTable {
    ttl: Duration,
    next_id: u64,
    leases: HashMap<LeaseId, Lease>,
    /// heap_id → live lease ids (fast per-heap queries).
    by_heap: HashMap<u64, Vec<LeaseId>>,
}

impl LeaseTable {
    pub fn new(ttl: Duration) -> Self {
        LeaseTable { ttl, next_id: 1, leases: HashMap::new(), by_heap: HashMap::new() }
    }

    pub fn grant(&mut self, heap_id: u64, proc: ProcId, now: Instant) -> Lease {
        let id = LeaseId(self.next_id);
        self.next_id += 1;
        let lease = Lease { id, heap_id, proc, expires: now + self.ttl };
        self.leases.insert(id, lease.clone());
        self.by_heap.entry(heap_id).or_default().push(id);
        lease
    }

    /// Renew; returns false if the lease already expired or was
    /// revoked. Strict comparison: at exactly `expires` the renew
    /// fails — expire wins the tie (see module docs).
    pub fn renew(&mut self, id: LeaseId, now: Instant) -> bool {
        match self.leases.get_mut(&id) {
            Some(l) if l.expires > now => {
                l.expires = now + self.ttl;
                true
            }
            _ => false,
        }
    }

    /// Drop a lease voluntarily (clean close).
    pub fn surrender(&mut self, id: LeaseId) {
        if let Some(l) = self.leases.remove(&id) {
            if let Some(v) = self.by_heap.get_mut(&l.heap_id) {
                v.retain(|x| *x != id);
                if v.is_empty() {
                    self.by_heap.remove(&l.heap_id);
                }
            }
        }
    }

    /// Harvest expired leases; returns them (orchestrator notifies &
    /// possibly GCs their heaps). Inclusive comparison: a lease whose
    /// `expires` equals `now` is harvested — the exact complement of
    /// [`LeaseTable::renew`]'s strict check, so the boundary instant
    /// belongs to expiry on both sides (see module docs).
    pub fn expire(&mut self, now: Instant) -> Vec<Lease> {
        let dead: Vec<LeaseId> = self
            .leases
            .values()
            .filter(|l| l.expires <= now)
            .map(|l| l.id)
            .collect();
        let mut out = Vec::with_capacity(dead.len());
        for id in dead {
            if let Some(l) = self.leases.remove(&id) {
                if let Some(v) = self.by_heap.get_mut(&l.heap_id) {
                    v.retain(|x| *x != id);
                    if v.is_empty() {
                        self.by_heap.remove(&l.heap_id);
                    }
                }
                out.push(l);
            }
        }
        out
    }

    /// Procs still holding a live lease on `heap_id`.
    pub fn holders(&self, heap_id: u64) -> Vec<ProcId> {
        self.by_heap
            .get(&heap_id)
            .map(|v| v.iter().filter_map(|id| self.leases.get(id)).map(|l| l.proc).collect())
            .unwrap_or_default()
    }

    pub fn heap_is_orphaned(&self, heap_id: u64) -> bool {
        !self.by_heap.contains_key(&heap_id)
    }

    pub fn live_count(&self) -> usize {
        self.leases.len()
    }

    /// Does `proc` hold any lease still live at `now`? Drives
    /// lease-aware admission (`ServerCore::admit`): a connection whose
    /// client proc no longer holds a live lease does not count against
    /// the ceiling, so crashed clients free their slots as soon as
    /// their leases lapse, without waiting for the sweep.
    pub fn proc_live(&self, proc: ProcId, now: Instant) -> bool {
        self.leases.values().any(|l| l.proc == proc && l.expires > now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn grant_renew_expire_cycle() {
        let mut lt = LeaseTable::new(Duration::from_millis(100));
        let now = t0();
        let l = lt.grant(7, 1, now);
        assert!(lt.renew(l.id, now + Duration::from_millis(50)));
        // Renewal pushed expiry to +150ms.
        assert!(lt.expire(now + Duration::from_millis(120)).is_empty());
        let dead = lt.expire(now + Duration::from_millis(200));
        assert_eq!(dead.len(), 1);
        assert_eq!(dead[0].heap_id, 7);
        assert!(!lt.renew(l.id, now + Duration::from_millis(210)), "expired lease unrenewable");
    }

    #[test]
    fn orphan_detection_when_all_leases_gone() {
        let mut lt = LeaseTable::new(Duration::from_millis(100));
        let now = t0();
        let a = lt.grant(9, 1, now);
        let _b = lt.grant(9, 2, now);
        assert!(!lt.heap_is_orphaned(9));
        lt.surrender(a.id);
        assert!(!lt.heap_is_orphaned(9));
        assert_eq!(lt.holders(9), vec![2]);
        lt.expire(now + Duration::from_millis(500));
        assert!(lt.heap_is_orphaned(9));
    }

    #[test]
    fn boundary_instant_expire_wins() {
        // Both sides of the exact deadline: one tick before `expires`
        // the lease is renewable and unharvestable; at exactly
        // `expires` it is unrenewable and harvestable. No instant is
        // both, no instant is neither.
        let ttl = Duration::from_millis(100);
        let mut lt = LeaseTable::new(ttl);
        let now = t0();
        let l = lt.grant(3, 1, now);
        let deadline = now + ttl;
        let just_before = deadline - Duration::from_nanos(1);

        // ε before the deadline: renew side of the partition.
        assert!(lt.expire(just_before).is_empty(), "live lease must not be harvested early");
        assert!(lt.renew(l.id, just_before), "renew an instant before expiry succeeds");

        // Renewal re-based expiry at just_before + ttl; probe that
        // exact boundary: renew loses the tie, expire takes it.
        let deadline2 = just_before + ttl;
        assert!(!lt.renew(l.id, deadline2), "renew at exactly `expires` must fail");
        let dead = lt.expire(deadline2);
        assert_eq!(dead.len(), 1, "expire at exactly `expires` must harvest");
        assert_eq!(dead[0].id, l.id);
        assert!(!lt.renew(l.id, deadline2), "harvested lease stays dead");
    }

    #[test]
    fn proc_live_tracks_any_live_lease() {
        let ttl = Duration::from_millis(100);
        let mut lt = LeaseTable::new(ttl);
        let now = t0();
        let a = lt.grant(1, 7, now);
        let _b = lt.grant(2, 7, now + Duration::from_millis(50));
        assert!(lt.proc_live(7, now));
        assert!(!lt.proc_live(8, now), "proc with no leases is dead");
        // First lease at its exact deadline: the second keeps proc 7
        // alive (expire-wins applies per lease, liveness is any-of).
        assert!(lt.proc_live(7, now + ttl));
        lt.surrender(a.id);
        // Second lease at its exact deadline: nothing live remains.
        assert!(!lt.proc_live(7, now + Duration::from_millis(150)));
    }

    #[test]
    fn surrender_is_idempotent() {
        let mut lt = LeaseTable::new(Duration::from_millis(100));
        let l = lt.grant(1, 1, t0());
        lt.surrender(l.id);
        lt.surrender(l.id);
        assert_eq!(lt.live_count(), 0);
    }
}
