//! The global orchestrator (paper §4.1, §5.4).
//!
//! One per cluster: tracks shared-memory resources, assigns heaps
//! their cluster-unique addresses (via the pool), registers channels
//! under hierarchical names with POSIX-like ACLs, grants/expires
//! leases, enforces per-process quotas, notifies peers of failures,
//! and garbage-collects orphaned heaps. It resembles the cluster
//! orchestrators datacenters already deploy (the paper's analogy).

pub mod acl;
pub mod lease;
pub mod quota;

pub use acl::{Acl, Mode, Perm, Uid};
pub use lease::{Lease, LeaseId, LeaseTable};
pub use quota::QuotaTable;

use crate::cluster::{MapKind, PodId};
use crate::config::SimConfig;
use crate::error::{Result, RpcError};
use crate::memory::heap::{Heap, ProcId};
use crate::memory::pool::Pool;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Events the orchestrator delivers to participants (polled by
/// librpcool's renewal thread in the real system).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Notification {
    /// A peer holding a lease on a heap you share stopped renewing.
    PeerFailed { proc: ProcId, heap_id: u64 },
    /// A heap you used was orphaned and reclaimed.
    HeapReclaimed { heap_id: u64 },
    /// The channel's server went away.
    ChannelDown { name: String },
}

/// Channel metadata registered with the orchestrator.
#[derive(Clone)]
pub struct ChannelReg {
    pub name: String,
    pub owner_proc: ProcId,
    pub owner_uid: Uid,
    pub acl: Acl,
    pub heap_id: u64,
}

struct Inner {
    leases: LeaseTable,
    quotas: QuotaTable,
    heaps: HashMap<u64, Arc<Heap>>,
    /// heap → pod it was created in. The heap is CXL-mapped only from
    /// this pod; any other pod gets a DSM-backed mapping.
    heap_pods: HashMap<u64, PodId>,
    /// heap → procs that ever mapped it (for failure notification fan-out).
    participants: HashMap<u64, Vec<ProcId>>,
    channels: HashMap<String, ChannelReg>,
    notifications: HashMap<ProcId, Vec<Notification>>,
    reclaimed: u64,
}

/// Admission-counter indices into [`Orchestrator::admission`].
pub const ADM_ADMITTED: usize = 0;
pub const ADM_REJECTED: usize = 1;
pub const ADM_QUEUED: usize = 2;
pub const ADM_SHED: usize = 3;

static ADMISSION_NAMES: [&str; 4] = ["admitted", "rejected", "queued", "shed"];

/// Fault-counter indices into [`Orchestrator::fault`] — the failure
/// plane's observability surface. The balance invariant the crash
/// suite (and `ci/check_fault.sh`) holds: every injected kill is
/// eventually matched by a completed recovery
/// (`kills == recoveries` once the rack quiesces).
pub const FLT_KILLS: usize = 0;
pub const FLT_SLOTS_REAPED: usize = 1;
pub const FLT_SEALS_FORCED: usize = 2;
pub const FLT_SCOPES_FREED: usize = 3;
pub const FLT_MAGS_FLUSHED: usize = 4;
pub const FLT_RETRIES: usize = 5;
pub const FLT_RECONNECTS: usize = 6;
pub const FLT_RECOVERIES: usize = 7;
/// DSM owner-word epochs advanced by the sweep while reclaiming pages
/// from a dead node; must equal [`FLT_PAGES_RECLAIMED`] when healthy.
pub const FLT_EPOCH_BUMPS: usize = 8;
pub const FLT_PAGES_RECLAIMED: usize = 9;
/// Channels resurrected into a registered standby proc instead of
/// being torn down on owner death.
pub const FLT_ADOPTIONS: usize = 10;

static FAULT_NAMES: [&str; 11] = [
    "kills",
    "slots_reaped",
    "seals_forced",
    "scopes_freed",
    "mags_flushed",
    "retries",
    "reconnects",
    "recoveries",
    "epoch_bumps",
    "pages_reclaimed",
    "adoptions",
];

/// A per-proc recovery obligation registered by a plane that owns
/// state a dead proc may have poisoned (today: every open channel's
/// `ServerCore`). Called once per dead proc from the sweep, with the
/// orchestrator's `inner` lock *released* — hooks may call back into
/// the orchestrator (unmap, counters). Return `false` to be pruned
/// (the owning object is gone).
pub type DeathHook = Box<dyn Fn(ProcId) -> bool + Send + Sync>;

/// A per-sweep maintenance obligation (today: the worker pool's
/// heal pass respawning killed workers). Returns `Some(recoveries)`
/// to stay registered — the count lands in `FLT_RECOVERIES` — or
/// `None` to be pruned.
pub type TickHook = Box<dyn Fn() -> Option<u64> + Send + Sync>;

pub struct Orchestrator {
    pub pool: Arc<Pool>,
    cfg: SimConfig,
    inner: Mutex<Inner>,
    ticker_stop: AtomicBool,
    /// Channel-admission accounting (connects admitted / rejected /
    /// queued / admitted-as-shed), host-wide — benches and tests lift
    /// it into reports like the DSM transfer counters.
    admission: crate::metrics::CounterSet,
    /// Failure-plane accounting (see the `FLT_*` indices). `Arc` so
    /// the global fault injector can hold a weak sink for kill counts
    /// fired on threads with no orchestrator handle (pool workers).
    fault: Arc<crate::metrics::CounterSet>,
    /// Recovery obligations run per dead proc during the sweep.
    death_hooks: Mutex<Vec<DeathHook>>,
    /// Maintenance obligations run at the end of every sweep.
    tick_hooks: Mutex<Vec<TickHook>>,
}

impl Orchestrator {
    pub fn new(cfg: &SimConfig, pool: Arc<Pool>) -> Arc<Orchestrator> {
        Arc::new(Orchestrator {
            pool,
            cfg: cfg.clone(),
            inner: Mutex::new(Inner {
                leases: LeaseTable::new(Duration::from_millis(cfg.lease_ttl_ms)),
                quotas: QuotaTable::new(cfg.quota_bytes),
                heaps: HashMap::new(),
                heap_pods: HashMap::new(),
                participants: HashMap::new(),
                channels: HashMap::new(),
                notifications: HashMap::new(),
                reclaimed: 0,
            }),
            ticker_stop: AtomicBool::new(false),
            admission: crate::metrics::CounterSet::new(&ADMISSION_NAMES),
            fault: Arc::new(crate::metrics::CounterSet::new(&FAULT_NAMES)),
            death_hooks: Mutex::new(Vec::new()),
            tick_hooks: Mutex::new(Vec::new()),
        })
    }

    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    /// Channel-admission counters (see the `ADM_*` indices).
    pub fn admission(&self) -> &crate::metrics::CounterSet {
        &self.admission
    }

    /// Failure-plane counters (see the `FLT_*` indices).
    pub fn fault(&self) -> &crate::metrics::CounterSet {
        &self.fault
    }

    /// Shared handle to the fault counters, for the injector's weak
    /// kill-count sink (`fault::arm_with_sink`).
    pub fn fault_counters(&self) -> Arc<crate::metrics::CounterSet> {
        Arc::clone(&self.fault)
    }

    /// Register a recovery obligation run once per dead proc by the
    /// lease sweep. The hook runs with the orchestrator's internal
    /// lock released (it may call back in, and may register further
    /// hooks — standby adoption registers the resurrected channel's
    /// own death hook from inside the dead owner's). Returns `false`
    /// to be pruned.
    pub fn on_proc_death(&self, hook: DeathHook) {
        self.death_hooks.lock().unwrap().push(hook);
    }

    /// Register a per-sweep maintenance pass (e.g. worker-pool heal).
    pub fn on_tick(&self, hook: TickHook) {
        self.tick_hooks.lock().unwrap().push(hook);
    }

    /// Does `proc` hold any live lease right now? Lease-aware
    /// admission asks this per candidate connection.
    pub fn proc_holds_lease(&self, proc: ProcId) -> bool {
        self.inner.lock().unwrap().leases.proc_live(proc, Instant::now())
    }

    // ---------------- heaps ----------------

    /// Create a heap at a cluster-unique address and lease it to
    /// `proc`, with the configured thread-magazine capacity.
    pub fn create_heap(
        &self,
        name: &str,
        bytes: usize,
        proc: ProcId,
    ) -> Result<(Arc<Heap>, LeaseId)> {
        self.create_heap_opts(name, bytes, proc, None)
    }

    /// [`Orchestrator::create_heap`] with a per-heap magazine-capacity
    /// override (`None` = the config's `magazine_cap`; `Some(0)` =
    /// fixed always-lock allocation). Home pod defaults to pod 0.
    pub fn create_heap_opts(
        &self,
        name: &str,
        bytes: usize,
        proc: ProcId,
        magazine_cap: Option<usize>,
    ) -> Result<(Arc<Heap>, LeaseId)> {
        self.create_heap_opts_at(name, bytes, proc, magazine_cap, 0)
    }

    /// [`Orchestrator::create_heap_opts`] placing the heap in an
    /// explicit home pod: the heap's backing CXL memory lives in that
    /// pod's coherence domain.
    pub fn create_heap_opts_at(
        &self,
        name: &str,
        bytes: usize,
        proc: ProcId,
        magazine_cap: Option<usize>,
        home_pod: PodId,
    ) -> Result<(Arc<Heap>, LeaseId)> {
        let cap = magazine_cap.unwrap_or(self.cfg.magazine_cap);
        let heap = Heap::new_opts(&self.pool, name, bytes, cap)?;
        let mut inner = self.inner.lock().unwrap();
        inner.quotas.charge(proc, heap.id, heap.len())?;
        let lease = inner.leases.grant(heap.id, proc, Instant::now());
        inner.participants.entry(heap.id).or_default().push(proc);
        inner.heap_pods.insert(heap.id, home_pod);
        inner.heaps.insert(heap.id, Arc::clone(&heap));
        Ok((heap, lease.id))
    }

    /// Map an existing heap into another proc's address space (pod of
    /// the mapper unknown — treated as a CXL mapping from the heap's
    /// home pod, the legacy single-pod behaviour).
    pub fn map_heap(&self, heap_id: u64, proc: ProcId) -> Result<(Arc<Heap>, LeaseId)> {
        let (heap, lease, _kind) = self.map_heap_inner(heap_id, proc, None)?;
        Ok((heap, lease))
    }

    /// Map an existing heap from a specific pod. Returns the mapping
    /// kind: [`MapKind::Cxl`] if `pod` is the heap's home pod (direct
    /// load/store coherence), [`MapKind::Dsm`] otherwise (software
    /// coherence over RDMA).
    pub fn map_heap_from(
        &self,
        heap_id: u64,
        proc: ProcId,
        pod: PodId,
    ) -> Result<(Arc<Heap>, LeaseId, MapKind)> {
        self.map_heap_inner(heap_id, proc, Some(pod))
    }

    fn map_heap_inner(
        &self,
        heap_id: u64,
        proc: ProcId,
        pod: Option<PodId>,
    ) -> Result<(Arc<Heap>, LeaseId, MapKind)> {
        let mut inner = self.inner.lock().unwrap();
        let heap = inner
            .heaps
            .get(&heap_id)
            .cloned()
            .ok_or(RpcError::LeaseExpired(heap_id))?;
        let home = inner.heap_pods.get(&heap_id).copied().unwrap_or(0);
        let kind = match pod {
            Some(p) if p != home => MapKind::Dsm,
            _ => MapKind::Cxl,
        };
        inner.quotas.charge(proc, heap_id, heap.len())?;
        let lease = inner.leases.grant(heap_id, proc, Instant::now());
        let parts = inner.participants.entry(heap_id).or_default();
        if !parts.contains(&proc) {
            parts.push(proc);
        }
        Ok((heap, lease.id, kind))
    }

    /// Home pod of a live heap.
    pub fn heap_home_pod(&self, heap_id: u64) -> Option<PodId> {
        self.inner.lock().unwrap().heap_pods.get(&heap_id).copied()
    }

    /// Voluntary unmap (clean close): surrender lease, credit quota,
    /// reclaim if orphaned.
    pub fn unmap_heap(&self, lease: LeaseId, proc: ProcId, heap_id: u64) {
        let mut inner = self.inner.lock().unwrap();
        inner.leases.surrender(lease);
        inner.quotas.credit(proc, heap_id);
        if let Some(parts) = inner.participants.get_mut(&heap_id) {
            parts.retain(|p| *p != proc);
        }
        if inner.leases.heap_is_orphaned(heap_id) {
            Self::reclaim_heap(&mut inner, heap_id);
        }
    }

    pub fn renew(&self, lease: LeaseId) -> bool {
        self.inner.lock().unwrap().leases.renew(lease, Instant::now())
    }

    fn reclaim_heap(inner: &mut Inner, heap_id: u64) {
        if inner.heaps.remove(&heap_id).is_some() {
            inner.heap_pods.remove(&heap_id);
            inner.reclaimed += 1;
            let parts = inner.participants.remove(&heap_id).unwrap_or_default();
            for p in parts {
                inner
                    .notifications
                    .entry(p)
                    .or_default()
                    .push(Notification::HeapReclaimed { heap_id });
            }
        }
    }

    pub fn heap(&self, heap_id: u64) -> Option<Arc<Heap>> {
        self.inner.lock().unwrap().heaps.get(&heap_id).cloned()
    }

    pub fn live_heaps(&self) -> usize {
        self.inner.lock().unwrap().heaps.len()
    }

    pub fn reclaimed_heaps(&self) -> u64 {
        self.inner.lock().unwrap().reclaimed
    }

    pub fn quota_held(&self, proc: ProcId) -> usize {
        self.inner.lock().unwrap().quotas.held_by(proc)
    }

    // ---------------- channels ----------------

    pub fn register_channel(&self, reg: ChannelReg) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if inner.channels.contains_key(&reg.name) {
            return Err(RpcError::ChannelExists(reg.name));
        }
        inner.channels.insert(reg.name.clone(), reg);
        Ok(())
    }

    pub fn unregister_channel(&self, name: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.channels.remove(name);
    }

    /// Unregister `name` only if `proc` still owns the registration.
    /// Teardown paths use this instead of [`unregister_channel`] so a
    /// stale handle to a dead (or resurrected) channel dropped *after*
    /// a new owner registered the same name cannot clobber the new
    /// registration — the stale-death-latching bug.
    pub fn unregister_channel_owned(&self, name: &str, proc: ProcId) {
        let mut inner = self.inner.lock().unwrap();
        if inner.channels.get(name).map_or(false, |c| c.owner_proc == proc) {
            inner.channels.remove(name);
        }
    }

    pub fn lookup_channel(&self, name: &str) -> Result<ChannelReg> {
        self.inner
            .lock()
            .unwrap()
            .channels
            .get(name)
            .cloned()
            .ok_or_else(|| RpcError::ChannelNotFound(name.to_string()))
    }

    /// Check a uid may connect to a channel (POSIX-like ACL).
    pub fn check_connect(&self, name: &str, uid: Uid) -> Result<ChannelReg> {
        let reg = self.lookup_channel(name)?;
        if !reg.acl.check(uid, Perm::Connect) {
            return Err(RpcError::AccessDenied(format!("uid {uid} cannot connect to '{name}'")));
        }
        Ok(reg)
    }

    /// Channels under a hierarchical prefix (e.g. `"social/"`).
    pub fn list_channels(&self, prefix: &str) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        let mut v: Vec<String> =
            inner.channels.keys().filter(|k| k.starts_with(prefix)).cloned().collect();
        v.sort();
        v
    }

    // ---------------- failure handling ----------------

    /// One sweep: expire leases, notify survivors, run per-plane
    /// recovery for every proc that lost its last lease, then GC
    /// orphaned heaps. Returns the number of leases that expired.
    ///
    /// The sweep is **phased** so its observable ordering is
    /// deterministic regardless of how many leases expire together
    /// (the lease map iterates in hash order):
    ///
    /// 1. *Notify* (locked): every expired lease credits its quota and
    ///    pushes `PeerFailed` to that heap's surviving participants —
    ///    **all** failure notifications land before any reclamation,
    ///    so a survivor always observes `PeerFailed` for a shared heap
    ///    before (never after) its `HeapReclaimed`. Channels owned by
    ///    procs with no live lease left go down here too.
    /// 2. *Recover* (unlocked): per dead proc, run the registered
    ///    death hooks (channel planes reap ring slots, fail waiters,
    ///    revoke connection seals, detach doorbells), flush its parked
    ///    heap magazines back to central lists, force-free its scopes,
    ///    and force-release its seals through every live heap's
    ///    page-word index. One `FLT_RECOVERIES` per dead proc.
    /// 3. *Reclaim* (relocked): orphaned heaps from the expired set
    ///    are GC'd, pushing `HeapReclaimed` strictly after phase 1's
    ///    notifications.
    /// 4. *Maintain*: tick hooks (worker-pool heal) run; their healed
    ///    counts land in `FLT_RECOVERIES`.
    pub fn tick(&self) -> usize {
        let now = Instant::now();
        // ---- phase 1: expire + notify (all failures before any GC) --
        let (dead, dead_procs, live_heaps) = {
            let mut inner = self.inner.lock().unwrap();
            let dead = inner.leases.expire(now);
            for lease in &dead {
                inner.quotas.credit(lease.proc, lease.heap_id);
                let survivors: Vec<ProcId> = inner
                    .participants
                    .get(&lease.heap_id)
                    .map(|v| v.iter().copied().filter(|p| *p != lease.proc).collect())
                    .unwrap_or_default();
                for s in survivors {
                    inner.notifications.entry(s).or_default().push(Notification::PeerFailed {
                        proc: lease.proc,
                        heap_id: lease.heap_id,
                    });
                }
            }
            // A proc is dead when no live lease of its remains — a
            // proc that lost one of several leases keeps its channels.
            let mut dead_procs: Vec<ProcId> = dead
                .iter()
                .map(|l| l.proc)
                .filter(|p| !inner.leases.proc_live(*p, now))
                .collect();
            dead_procs.sort_unstable();
            dead_procs.dedup();
            for p in &dead_procs {
                let downs: Vec<(String, u64)> = inner
                    .channels
                    .values()
                    .filter(|c| c.owner_proc == *p)
                    .map(|c| (c.name.clone(), c.heap_id))
                    .collect();
                for (name, heap_id) in downs {
                    inner.channels.remove(&name);
                    // Tell everyone who still shares the channel's heap.
                    let heap_holders = inner.leases.holders(heap_id);
                    for h in heap_holders {
                        inner
                            .notifications
                            .entry(h)
                            .or_default()
                            .push(Notification::ChannelDown { name: name.clone() });
                    }
                }
            }
            let live_heaps: Vec<Arc<Heap>> = inner.heaps.values().cloned().collect();
            (dead, dead_procs, live_heaps)
        };
        // ---- phase 2: per-plane recovery, lock released ------------
        for p in &dead_procs {
            self.run_death_hooks(*p);
            let mags = crate::memory::heap::flush_dead_magazines(*p);
            if mags > 0 {
                self.fault.add(FLT_MAGS_FLUSHED, mags);
            }
            let scopes = crate::memory::scope::release_scopes_of(*p);
            if scopes > 0 {
                self.fault.add(FLT_SCOPES_FREED, scopes as u64);
            }
            let mut seals = 0u64;
            for h in &live_heaps {
                seals += h.force_unseal_proc(*p) as u64;
            }
            if seals > 0 {
                self.fault.add(FLT_SEALS_FORCED, seals);
            }
            self.fault.add(FLT_RECOVERIES, 1);
        }
        // ---- phase 3: GC orphaned heaps (after all notifications) --
        {
            let mut inner = self.inner.lock().unwrap();
            for lease in &dead {
                if inner.heaps.contains_key(&lease.heap_id)
                    && inner.leases.heap_is_orphaned(lease.heap_id)
                {
                    Self::reclaim_heap(&mut inner, lease.heap_id);
                }
            }
        }
        // ---- phase 4: maintenance (worker-pool heal, ...) ----------
        let mut hooks = self.tick_hooks.lock().unwrap();
        hooks.retain(|h| match h() {
            Some(recovered) => {
                if recovered > 0 {
                    self.fault.add(FLT_RECOVERIES, recovered);
                }
                true
            }
            None => false,
        });
        dead.len()
    }

    /// Run every registered death hook for one dead proc, pruning the
    /// ones whose owning object is gone. Callers must not hold the
    /// orchestrator's internal lock. The hook list is swapped out for
    /// the duration of the run so a hook may itself register new hooks
    /// (standby adoption does) without deadlocking on the list mutex;
    /// hooks registered mid-run are kept but not invoked for the proc
    /// currently being swept.
    fn run_death_hooks(&self, dead: ProcId) {
        let hooks: Vec<DeathHook> = std::mem::take(&mut *self.death_hooks.lock().unwrap());
        let mut keep: Vec<DeathHook> = hooks.into_iter().filter(|h| h(dead)).collect();
        let mut cur = self.death_hooks.lock().unwrap();
        keep.append(&mut cur);
        *cur = keep;
    }

    /// Poll pending notifications for a proc (drains them).
    pub fn poll_notifications(&self, proc: ProcId) -> Vec<Notification> {
        self.inner.lock().unwrap().notifications.remove(&proc).unwrap_or_default()
    }

    /// Spawn the background ticker (lease sweeper). Call `stop_ticker`
    /// (or drop the rack) to stop it.
    pub fn start_ticker(self: &Arc<Self>) -> std::thread::JoinHandle<()> {
        let this = Arc::clone(self);
        let interval = Duration::from_millis(this.cfg.lease_renew_ms.max(1));
        std::thread::spawn(move || {
            while !this.ticker_stop.load(Ordering::Relaxed) {
                std::thread::sleep(interval);
                this.tick();
            }
        })
    }

    pub fn stop_ticker(&self) {
        self.ticker_stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for Orchestrator {
    fn drop(&mut self) {
        self.stop_ticker();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn orch() -> Arc<Orchestrator> {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        Orchestrator::new(&cfg, pool)
    }

    #[test]
    fn heap_lifecycle_and_quota() {
        let o = orch();
        let (h, lease) = o.create_heap("conn0", 1 << 20, 1).unwrap();
        assert_eq!(o.quota_held(1), h.len());
        assert_eq!(o.live_heaps(), 1);
        o.unmap_heap(lease, 1, h.id);
        assert_eq!(o.quota_held(1), 0);
        assert_eq!(o.live_heaps(), 0, "orphaned heap reclaimed on clean close");
    }

    #[test]
    fn crash_expires_lease_and_notifies_peer() {
        // Paper Fig. 5a: server crash orphans a heap; the orchestrator
        // notices via lease expiry, notifies the client, and reclaims
        // when the client also lets go.
        let o = orch();
        let (h, server_lease) = o.create_heap("conn", 1 << 20, 1).unwrap();
        let (_h2, client_lease) = o.map_heap(h.id, 2).unwrap();
        // Server "crashes": stops renewing. Client keeps renewing.
        for _ in 0..4 {
            std::thread::sleep(Duration::from_millis(20)); // total 80ms > ttl 60ms
            assert!(o.renew(client_lease), "client renewal must stay live");
        }
        let expired = o.tick();
        assert!(expired >= 1);
        let notes = o.poll_notifications(2);
        assert!(
            notes.contains(&Notification::PeerFailed { proc: 1, heap_id: h.id }),
            "client told about server failure: {notes:?}"
        );
        // Client may keep using the heap...
        assert!(o.heap(h.id).is_some());
        // ...until it closes; then the heap is reclaimed.
        o.unmap_heap(client_lease, 2, h.id);
        assert_eq!(o.live_heaps(), 0);
        let _ = server_lease;
    }

    #[test]
    fn peer_failed_fans_out_before_heap_reclaim() {
        // Sweep-ordering pin: when BOTH leases of one shared heap
        // expire in a single sweep, each proc must still observe the
        // other's PeerFailed BEFORE the HeapReclaimed that same sweep
        // produces. The unphased sweep got this wrong in lease-map
        // hash order: whichever lease iterated first could find the
        // heap already orphaned, reclaim it, and delete the
        // participants list the second lease's fan-out needed.
        let o = orch();
        let (h, _l1) = o.create_heap("shared", 1 << 20, 1).unwrap();
        let (_h2, _l2) = o.map_heap(h.id, 2).unwrap();
        std::thread::sleep(Duration::from_millis(80)); // ttl 60ms
        assert_eq!(o.tick(), 2, "both leases expire in one sweep");
        for proc in [1u32, 2u32] {
            let notes = o.poll_notifications(proc);
            let peer = notes
                .iter()
                .position(|n| matches!(n, Notification::PeerFailed { .. }))
                .unwrap_or_else(|| panic!("proc {proc} missing PeerFailed: {notes:?}"));
            let reclaim = notes
                .iter()
                .position(|n| matches!(n, Notification::HeapReclaimed { .. }))
                .unwrap_or_else(|| panic!("proc {proc} missing HeapReclaimed: {notes:?}"));
            assert!(
                peer < reclaim,
                "proc {proc} saw HeapReclaimed before PeerFailed: {notes:?}"
            );
        }
        assert_eq!(o.live_heaps(), 0);
        // Two procs lost their last lease: two completed recoveries.
        assert_eq!(o.fault().get(FLT_RECOVERIES), 2);
    }

    #[test]
    fn total_failure_reclaims_without_survivors() {
        // Paper Fig. 5b / §5.4 "total failure": all procs die, the
        // memory node survives; the orchestrator GCs the heap.
        let o = orch();
        let (h, _l1) = o.create_heap("conn", 1 << 20, 1).unwrap();
        let (_h, _l2) = o.map_heap(h.id, 2).unwrap();
        std::thread::sleep(Duration::from_millis(80));
        o.tick();
        assert_eq!(o.live_heaps(), 0);
        assert_eq!(o.reclaimed_heaps(), 1);
    }

    #[test]
    fn channel_registry_with_acl() {
        let o = orch();
        let (h, _l) = o.create_heap("ch-heap", 1 << 20, 1).unwrap();
        o.register_channel(ChannelReg {
            name: "svc/db".into(),
            owner_proc: 1,
            owner_uid: 100,
            acl: Acl::private(100),
            heap_id: h.id,
        })
        .unwrap();
        assert!(o.check_connect("svc/db", 100).is_ok());
        assert!(o.check_connect("svc/db", 200).is_err());
        assert!(o.register_channel(ChannelReg {
            name: "svc/db".into(),
            owner_proc: 2,
            owner_uid: 2,
            acl: Acl::open(2),
            heap_id: h.id,
        })
        .is_err());
        assert_eq!(o.list_channels("svc/"), vec!["svc/db".to_string()]);
        assert!(matches!(o.check_connect("nope", 1), Err(RpcError::ChannelNotFound(_))));
    }

    #[test]
    fn heap_home_pod_decides_mapping_kind() {
        let o = orch();
        let (h, _l) = o
            .create_heap_opts_at("pod-heap", 1 << 20, 1, None, 1)
            .unwrap();
        assert_eq!(o.heap_home_pod(h.id), Some(1));
        // Mapping from the home pod is direct CXL; from anywhere else
        // it degrades to DSM.
        let (_h, _l2, kind_home) = o.map_heap_from(h.id, 2, 1).unwrap();
        assert_eq!(kind_home, MapKind::Cxl);
        let (_h, _l3, kind_far) = o.map_heap_from(h.id, 3, 0).unwrap();
        assert_eq!(kind_far, MapKind::Dsm);
        // Legacy pod-less mapping stays CXL.
        let (_h, _l4) = o.map_heap(h.id, 4).unwrap();
        // Reclaim drops the pod record too.
        std::thread::sleep(Duration::from_millis(80));
        o.tick();
        assert_eq!(o.live_heaps(), 0);
        assert_eq!(o.heap_home_pod(h.id), None);
    }

    #[test]
    fn quota_blocks_hoarding_client() {
        // §5.4 scenario 3: a client must not amass unbounded shm.
        let mut cfg = SimConfig::for_tests();
        cfg.quota_bytes = 3 << 20;
        let pool = Pool::new(&cfg).unwrap();
        let o = Orchestrator::new(&cfg, pool);
        let (h1, _) = o.create_heap("a", 1 << 20, 1).unwrap();
        let (h2, _) = o.create_heap("b", 1 << 20, 1).unwrap();
        let (_h3, _) = o.create_heap("c", 1 << 20, 1).unwrap();
        let err = o.create_heap("d", 1 << 20, 1).err().unwrap();
        assert!(matches!(err, RpcError::QuotaExceeded { .. }));
        let _ = (h1, h2);
    }
}
