//! Shared-memory quotas (paper §5.4): the orchestrator enforces an
//! administrator-configured per-process cap on mapped shared memory.
//! A heap mapped by several procs counts against *all* of their
//! quotas; mapping beyond the cap is refused until the proc closes
//! enough channels.

use crate::error::{Result, RpcError};
use crate::memory::heap::ProcId;
use std::collections::HashMap;

pub struct QuotaTable {
    quota: usize,
    /// proc → (heap_id → bytes) currently charged.
    held: HashMap<ProcId, HashMap<u64, usize>>,
}

impl QuotaTable {
    pub fn new(quota: usize) -> Self {
        QuotaTable { quota, held: HashMap::new() }
    }

    pub fn quota(&self) -> usize {
        self.quota
    }

    pub fn held_by(&self, proc: ProcId) -> usize {
        self.held.get(&proc).map(|m| m.values().sum()).unwrap_or(0)
    }

    /// Charge `proc` for mapping `heap_id` (`bytes` big). Fails — and
    /// charges nothing — if it would exceed the quota.
    pub fn charge(&mut self, proc: ProcId, heap_id: u64, bytes: usize) -> Result<()> {
        let held = self.held_by(proc);
        let entry = self.held.entry(proc).or_default();
        if entry.contains_key(&heap_id) {
            return Ok(()); // mapping the same heap twice is free
        }
        if held + bytes > self.quota {
            return Err(RpcError::QuotaExceeded { proc, held, quota: self.quota, wanted: bytes });
        }
        entry.insert(heap_id, bytes);
        Ok(())
    }

    /// Release the charge when a proc unmaps a heap.
    pub fn credit(&mut self, proc: ProcId, heap_id: u64) {
        if let Some(m) = self.held.get_mut(&proc) {
            m.remove(&heap_id);
            if m.is_empty() {
                self.held.remove(&proc);
            }
        }
    }

    /// Drop every charge held by `proc` (it died).
    pub fn drop_proc(&mut self, proc: ProcId) {
        self.held.remove(&proc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quota_enforced_per_proc() {
        let mut q = QuotaTable::new(100);
        q.charge(1, 10, 60).unwrap();
        q.charge(2, 10, 60).unwrap(); // other proc has its own budget
        let err = q.charge(1, 11, 60).unwrap_err();
        assert!(matches!(err, RpcError::QuotaExceeded { proc: 1, held: 60, .. }));
        q.credit(1, 10);
        q.charge(1, 11, 60).unwrap();
    }

    #[test]
    fn double_map_is_free() {
        let mut q = QuotaTable::new(100);
        q.charge(1, 10, 80).unwrap();
        q.charge(1, 10, 80).unwrap();
        assert_eq!(q.held_by(1), 80);
    }

    #[test]
    fn shared_heap_counts_against_all() {
        let mut q = QuotaTable::new(100);
        q.charge(1, 5, 90).unwrap();
        q.charge(2, 5, 90).unwrap();
        assert_eq!(q.held_by(1), 90);
        assert_eq!(q.held_by(2), 90);
        q.drop_proc(1);
        assert_eq!(q.held_by(1), 0);
        assert_eq!(q.held_by(2), 90);
    }
}
