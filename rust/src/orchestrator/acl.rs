//! POSIX-like access control lists for channels and shared heaps
//! (paper §4.1: the orchestrator "supports POSIX-like access control
//! lists for the shared memory").

pub type Uid = u32;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Perm {
    Read,
    Write,
    Connect,
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Mode {
    pub read: bool,
    pub write: bool,
    pub connect: bool,
}

impl Mode {
    pub const RWC: Mode = Mode { read: true, write: true, connect: true };
    pub const RO: Mode = Mode { read: true, write: false, connect: false };
    pub const NONE: Mode = Mode { read: false, write: false, connect: false };

    pub fn allows(&self, p: Perm) -> bool {
        match p {
            Perm::Read => self.read,
            Perm::Write => self.write,
            Perm::Connect => self.connect,
        }
    }
}

/// ACL: owner with full rights, per-uid entries, and an "other" mode.
#[derive(Clone, Debug)]
pub struct Acl {
    pub owner: Uid,
    pub entries: Vec<(Uid, Mode)>,
    pub other: Mode,
}

impl Acl {
    /// Owner-only access.
    pub fn private(owner: Uid) -> Acl {
        Acl { owner, entries: Vec::new(), other: Mode::NONE }
    }

    /// World-connectable (the common case for public services).
    pub fn open(owner: Uid) -> Acl {
        Acl { owner, entries: Vec::new(), other: Mode::RWC }
    }

    pub fn grant(&mut self, uid: Uid, mode: Mode) {
        if let Some(e) = self.entries.iter_mut().find(|(u, _)| *u == uid) {
            e.1 = mode;
        } else {
            self.entries.push((uid, mode));
        }
    }

    pub fn revoke(&mut self, uid: Uid) {
        self.entries.retain(|(u, _)| *u != uid);
    }

    pub fn check(&self, uid: Uid, p: Perm) -> bool {
        if uid == self.owner {
            return true;
        }
        if let Some((_, m)) = self.entries.iter().find(|(u, _)| *u == uid) {
            return m.allows(p);
        }
        self.other.allows(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owner_always_allowed() {
        let acl = Acl::private(1);
        assert!(acl.check(1, Perm::Write));
        assert!(!acl.check(2, Perm::Read));
    }

    #[test]
    fn grant_and_revoke() {
        let mut acl = Acl::private(1);
        acl.grant(2, Mode::RO);
        assert!(acl.check(2, Perm::Read));
        assert!(!acl.check(2, Perm::Write));
        acl.grant(2, Mode::RWC);
        assert!(acl.check(2, Perm::Connect));
        acl.revoke(2);
        assert!(!acl.check(2, Perm::Read));
    }

    #[test]
    fn open_acl_allows_everyone() {
        let acl = Acl::open(1);
        assert!(acl.check(99, Perm::Connect));
    }
}
