//! The per-host trusted daemon (paper §5.5).
//!
//! Each OS runs one daemon at start. It is the *only* entity that
//! makes map/unmap syscalls for connection heaps: applications open
//! and close channels/connections through it, and it coordinates with
//! the orchestrator. Applications may call `seal()`/`release()` but
//! never `mprotect()` on connection-heap pages — that restriction is
//! what stops a malicious sender from un-sealing its own pages behind
//! the kernel's back.

use crate::cluster::{MapKind, PodId, Topology};
use crate::error::{Result, RpcError};
use crate::memory::heap::{Heap, ProcId};
use crate::orchestrator::{LeaseId, Orchestrator};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Mapping record the daemon keeps per (proc, heap).
#[derive(Clone, Copy, Debug)]
pub struct Mapping {
    pub lease: LeaseId,
    pub heap_id: u64,
}

pub struct Daemon {
    pub host: u32,
    /// Pod this host (and hence this daemon) lives in.
    pub pod: PodId,
    orch: Arc<Orchestrator>,
    /// proc → heap_id → mapping.
    mappings: Mutex<HashMap<ProcId, HashMap<u64, Mapping>>>,
    maps: AtomicU64,
    denied_mprotects: AtomicU64,
}

impl Daemon {
    pub fn new(host: u32, orch: Arc<Orchestrator>) -> Arc<Daemon> {
        let pod = Topology::from_config(orch.config()).pod_of(host);
        Arc::new(Daemon {
            host,
            pod,
            orch,
            mappings: Mutex::new(HashMap::new()),
            maps: AtomicU64::new(0),
            denied_mprotects: AtomicU64::new(0),
        })
    }

    /// The daemon-wide channel worker pool for this host, grown to at
    /// least `workers` threads. Pools are keyed per (orchestrator,
    /// host) in a process-wide registry — `RpcServer::open`
    /// constructs a fresh `Daemon` value per channel, but all of one
    /// simulated host's channels must share one pool for worker count
    /// to decouple from channel count.
    pub fn worker_pool(&self, workers: usize) -> Arc<crate::channel::pool::WorkerPool> {
        let key = (Arc::as_ptr(&self.orch) as usize, self.host);
        let pool = crate::channel::pool::WorkerPool::for_key(key, workers);
        // Failure plane: workers lost to injected crashes respawn from
        // the orchestrator's recovery sweep (idempotent per pool).
        pool.register_heal(&self.orch);
        pool
    }

    /// Map a connection heap into `proc`'s address space (daemon-only
    /// syscall; charges the orchestrator handshake via the caller's
    /// connect-cost accounting). Maps from this daemon's own pod.
    pub fn map_heap(&self, heap_id: u64, proc: ProcId) -> Result<Arc<Heap>> {
        let (heap, _kind) = self.map_heap_from(heap_id, proc, self.pod)?;
        Ok(heap)
    }

    /// Map a heap on behalf of a proc running in `pod` (the client's
    /// daemon relays through the server's when connecting cross-pod).
    /// Returns the heap and whether the mapping is direct CXL or
    /// DSM-backed.
    pub fn map_heap_from(
        &self,
        heap_id: u64,
        proc: ProcId,
        pod: PodId,
    ) -> Result<(Arc<Heap>, MapKind)> {
        let (heap, lease, kind) = self.orch.map_heap_from(heap_id, proc, pod)?;
        self.mappings
            .lock()
            .unwrap()
            .entry(proc)
            .or_default()
            .insert(heap_id, Mapping { lease, heap_id });
        self.maps.fetch_add(1, Ordering::Relaxed);
        Ok((heap, kind))
    }

    /// Create + map a fresh heap (server opening a channel).
    pub fn create_heap(&self, name: &str, bytes: usize, proc: ProcId) -> Result<Arc<Heap>> {
        self.create_heap_opts(name, bytes, proc, None)
    }

    /// [`Daemon::create_heap`] with a per-heap thread-magazine override
    /// (channel builders pass `ChannelOpts::magazine_cap` through here).
    pub fn create_heap_opts(
        &self,
        name: &str,
        bytes: usize,
        proc: ProcId,
        magazine_cap: Option<usize>,
    ) -> Result<Arc<Heap>> {
        let (heap, lease) =
            self.orch.create_heap_opts_at(name, bytes, proc, magazine_cap, self.pod)?;
        self.mappings
            .lock()
            .unwrap()
            .entry(proc)
            .or_default()
            .insert(heap.id, Mapping { lease, heap_id: heap.id });
        self.maps.fetch_add(1, Ordering::Relaxed);
        Ok(heap)
    }

    /// Unmap on clean close.
    pub fn unmap_heap(&self, heap_id: u64, proc: ProcId) {
        let m = self.mappings.lock().unwrap().get_mut(&proc).and_then(|h| h.remove(&heap_id));
        if let Some(m) = m {
            self.orch.unmap_heap(m.lease, proc, heap_id);
        }
    }

    /// librpcool's periodic lease renewal for everything `proc` maps.
    pub fn renew_all(&self, proc: ProcId) -> usize {
        let leases: Vec<LeaseId> = self
            .mappings
            .lock()
            .unwrap()
            .get(&proc)
            .map(|h| h.values().map(|m| m.lease).collect())
            .unwrap_or_default();
        leases.iter().filter(|l| self.orch.renew(**l)).count()
    }

    /// Simulate a proc crash on this host: its mappings are simply
    /// forgotten (no unmap, no surrender) — lease expiry must clean up.
    pub fn crash_proc(&self, proc: ProcId) {
        self.mappings.lock().unwrap().remove(&proc);
    }

    /// Crash resurrection (paper's CoolDB restart story): adopt a
    /// dead owner's channel into its registered standby proc
    /// ([`crate::channel::ChannelBuilder::standby`]). The standby
    /// re-opens the same shared heap under its own lease, inherits
    /// the handler table, reaps the corpse's half of every surviving
    /// ring, and resumes serving on the same doorbell — in-flight
    /// idempotent calls complete against the resurrected endpoint
    /// instead of surfacing `PeerFailed`. Normally driven by the
    /// recovery sweep's death hook; exposed for tests and tools that
    /// orchestrate adoption by hand. Returns the resurrected server
    /// handle.
    pub fn adopt_channel(
        &self,
        old: &Arc<crate::channel::ServerCore>,
    ) -> Result<crate::channel::RpcServer> {
        crate::channel::adopt_channel_into(old, &self.orch.fault_counters())
    }

    /// Applications may not mprotect connection-heap pages (§5.5).
    pub fn try_app_mprotect(&self, _addr: usize) -> Result<()> {
        self.denied_mprotects.fetch_add(1, Ordering::Relaxed);
        Err(RpcError::AccessDenied(
            "mprotect on connection heap pages is daemon-only (paper §5.5)".into(),
        ))
    }

    pub fn map_count(&self) -> u64 {
        self.maps.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::pool::Pool;

    fn setup() -> (Arc<Orchestrator>, Arc<Daemon>) {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let orch = Orchestrator::new(&cfg, pool);
        let d = Daemon::new(0, Arc::clone(&orch));
        (orch, d)
    }

    #[test]
    fn daemon_mediates_mapping() {
        let (orch, d) = setup();
        let h = d.create_heap("c0", 1 << 20, 1).unwrap();
        let h2 = d.map_heap(h.id, 2).unwrap();
        assert_eq!(h.id, h2.id);
        assert_eq!(d.map_count(), 2);
        assert_eq!(d.renew_all(1), 1);
        d.unmap_heap(h.id, 1);
        d.unmap_heap(h.id, 2);
        assert_eq!(orch.live_heaps(), 0);
    }

    #[test]
    fn crash_leaves_lease_to_expire() {
        let (orch, d) = setup();
        let h = d.create_heap("c0", 1 << 20, 7).unwrap();
        d.crash_proc(7);
        assert_eq!(d.renew_all(7), 0, "crashed proc renews nothing");
        std::thread::sleep(std::time::Duration::from_millis(80));
        orch.tick();
        assert_eq!(orch.live_heaps(), 0, "expired lease → heap reclaimed");
        let _ = h;
    }

    #[test]
    fn cross_pod_mapping_degrades_to_dsm() {
        let mut cfg = SimConfig::for_tests();
        cfg.rack_hosts = 4;
        cfg.pods = 2;
        let pool = Pool::new(&cfg).unwrap();
        let orch = Orchestrator::new(&cfg, pool);
        let d0 = Daemon::new(0, Arc::clone(&orch)); // pod 0
        let d1 = Daemon::new(2, Arc::clone(&orch)); // pod 1
        assert_eq!(d0.pod, 0);
        assert_eq!(d1.pod, 1);
        let h = d0.create_heap("pods", 1 << 20, 1).unwrap();
        assert_eq!(orch.heap_home_pod(h.id), Some(0));
        let (_h, kind) = d0.map_heap_from(h.id, 2, d0.pod).unwrap();
        assert_eq!(kind, MapKind::Cxl, "in-pod mapping is direct CXL");
        let (_h, kind) = d1.map_heap_from(h.id, 3, d1.pod).unwrap();
        assert_eq!(kind, MapKind::Dsm, "cross-pod mapping is DSM-backed");
    }

    #[test]
    fn app_mprotect_denied() {
        let (_o, d) = setup();
        assert!(d.try_app_mprotect(0x1000).is_err());
    }
}
