//! Busy-waiting with adaptive sleep (paper §5.8) and parking.
//!
//! RPCool busy-polls shared memory for new RPCs and completions. To
//! keep CPU burn bounded, it sleeps between iterations depending on
//! CPU load: no sleep under 25% load, 5µs between 25–50%, 150µs above
//! 50%. Figure 13 sweeps these sleeps to show the latency/throughput
//! tradeoff; `SleepPolicy::Fixed` reproduces that sweep.
//!
//! Load here is the fraction of hardware threads occupied by active
//! pollers/workers (a `LoadMonitor` EWMA), standing in for the
//! system-wide CPU load the paper samples.
//!
//! # Parking (`SleepPolicy::Park`)
//!
//! The fourth point on the paper's tradeoff curve: instead of timed
//! sleeps, an idle poller *parks* on a [`Doorbell`] — a futex-style
//! wait object the producer side rings from `publish()`/`respond()`.
//! A parked poller burns zero CPU and wakes on the next doorbell ring
//! rather than at the next sleep tick. The loaded case keeps the
//! spin-first behaviour (a short poll burst before parking), so hot
//! connections never pay the wake-up latency.
//!
//! The doorbell's fast path is wait-free for producers: when no
//! poller has armed the bell, `ring()` is a single atomic load. The
//! residual store-buffer race (a producer may miss a poller arming
//! concurrently) is bounded by `PARK_SLICE_US`: parked waits are
//! sliced, so a lost wake-up costs at most one slice, never a hang.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};
use std::time::{Duration, Instant};

/// Global count of threads currently spinning/working, and the
/// number of "virtual cores" load is measured against.
pub struct LoadMonitor {
    active: AtomicI64,
    cores: AtomicI64,
}

impl LoadMonitor {
    pub const fn new() -> Self {
        LoadMonitor { active: AtomicI64::new(0), cores: AtomicI64::new(8) }
    }

    pub fn set_cores(&self, n: i64) {
        self.cores.store(n.max(1), Ordering::Relaxed);
    }

    pub fn enter(&self) {
        self.active.fetch_add(1, Ordering::Relaxed);
    }

    pub fn exit(&self) {
        self.active.fetch_sub(1, Ordering::Relaxed);
    }

    /// Instantaneous load in [0, ∞): active / cores.
    pub fn load(&self) -> f64 {
        let a = self.active.load(Ordering::Relaxed).max(0) as f64;
        let c = self.cores.load(Ordering::Relaxed) as f64;
        a / c
    }
}

impl Default for LoadMonitor {
    fn default() -> Self {
        Self::new()
    }
}

/// Process-wide monitor (simulated hosts share the physical CPU).
pub static LOAD: LoadMonitor = LoadMonitor::new();

/// Spin iterations before a `Park` waiter actually parks. Keeps the
/// no-wake fast path for responses that arrive within the RTT of a
/// hot connection.
pub const PARK_SPIN_POLLS: u32 = 256;

/// Upper bound on one parked wait. Slicing bounds the cost of the
/// (rare, store-buffer-window) lost wake-up race and lets waiters
/// re-check timeouts/shutdown flags.
pub const PARK_SLICE_US: u64 = 1_000;

/// A futex-style wake-up object: producers `ring()` it after
/// publishing work; idle pollers park on it instead of burning CPU.
///
/// Protocol: a waiter `arm()`s the bell, snapshots `epoch()`, checks
/// its ready condition, and — still finding nothing — calls
/// `wait_past(seen)`, which blocks only while the epoch still equals
/// `seen`. Any ring between the snapshot and the wait advances the
/// epoch, so the wait returns immediately instead of missing the
/// event. `ring()` with no armed waiter is a single atomic load.
///
/// **Coalesced epochs** are the protocol's normal case, not an edge:
/// one ring may cover many completions (the drain-k server's
/// `flush_respond` answers a whole sweep with one signal), and one
/// epoch bump wakes *every* parked waiter (`notify_all`). Each waiter
/// re-scans its own ready condition on every wake and — still not
/// ready — re-parks against a *fresh* epoch snapshot, never the stale
/// one. A waiter whose completion was not in the flushed batch
/// therefore cannot be lost: its own completion is covered by a later
/// flush, which bumps the epoch past whatever snapshot the waiter
/// last took (see DESIGN.md §9 for the full argument).
pub struct Doorbell {
    gen: AtomicU64,
    /// Threads currently inside a park-capable wait section.
    armed: AtomicU32,
    /// Threads currently blocked in `wait_past`.
    parked: AtomicU32,
    mu: Mutex<()>,
    cv: Condvar,
    /// Optional aggregation edge: when set, every `ring()` also marks
    /// this bell's shard bit in its [`WaiterTree`] slot and rings the
    /// tree root — *before* the local armed fast path, because pool
    /// workers park on the root and never arm member bells. Unattached
    /// bells pay one relaxed load (`OnceLock::get`).
    parent: OnceLock<TreeEdge>,
}

impl Doorbell {
    pub fn new() -> Doorbell {
        Doorbell {
            gen: AtomicU64::new(0),
            armed: AtomicU32::new(0),
            parked: AtomicU32::new(0),
            mu: Mutex::new(()),
            cv: Condvar::new(),
            parent: OnceLock::new(),
        }
    }

    pub fn new_arc() -> Arc<Doorbell> {
        Arc::new(Doorbell::new())
    }

    /// Producer side: wake any parked waiters. Wait-free (one atomic
    /// load) when nobody is armed — the doorbell costs the hot path
    /// nothing unless a poller actually parks. Tree-attached bells
    /// additionally propagate to their [`WaiterTree`] regardless of
    /// the local armed count (the tree's waiters live on the root).
    #[inline]
    pub fn ring(&self) {
        if let Some(edge) = self.parent.get() {
            edge.tree.notify(&edge.slot, edge.bit);
        }
        if self.armed.load(Ordering::SeqCst) == 0 {
            return;
        }
        self.gen.fetch_add(1, Ordering::SeqCst);
        if self.parked.load(Ordering::SeqCst) > 0 {
            // Empty critical section: a waiter between its epoch
            // re-check and `cv.wait` holds `mu`, so this lock ensures
            // the notify cannot land in that gap and get lost.
            drop(self.mu.lock().unwrap());
            self.cv.notify_all();
        }
    }

    /// Enter a park-capable wait section (see struct docs).
    pub fn arm(&self) {
        self.armed.fetch_add(1, Ordering::SeqCst);
    }

    pub fn disarm(&self) {
        self.armed.fetch_sub(1, Ordering::SeqCst);
    }

    /// Current ring count. Snapshot *before* checking the ready
    /// condition; pass to [`Doorbell::wait_past`].
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.gen.load(Ordering::SeqCst)
    }

    /// Park until the bell rings past `seen`, at most `slice`.
    /// Callers must hold an `arm()` and should loop, re-checking their
    /// ready condition and timeout between slices.
    pub fn wait_past(&self, seen: u64, slice: Duration) {
        self.parked.fetch_add(1, Ordering::SeqCst);
        let g = self.mu.lock().unwrap();
        if self.gen.load(Ordering::SeqCst) == seen {
            let _ = self.cv.wait_timeout(g, slice).unwrap();
        }
        self.parked.fetch_sub(1, Ordering::SeqCst);
    }

    /// Test/telemetry hook: is anyone parked right now?
    pub fn parked(&self) -> u32 {
        self.parked.load(Ordering::SeqCst)
    }
}

impl Default for Doorbell {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------
// Doorbell aggregation: the WaiterTree

/// One registered connection (or accept queue) inside a
/// [`WaiterTree`]: a 64-bit dirty mask (one bit per ring shard) plus
/// a single-entry guard for the tree's pending queue.
pub struct TreeSlot {
    id: usize,
    /// Bit i set ⇔ shard i rang since the last sweep took the mask.
    dirty: AtomicU64,
    /// 1 while the slot sits in the pending queue (at most one entry
    /// per slot, however many shards ring concurrently).
    queued: AtomicU32,
    /// Deregistered: sweeps and scans skip it; queue entries drain
    /// lazily.
    dead: AtomicBool,
}

impl TreeSlot {
    pub fn id(&self) -> usize {
        self.id
    }
}

/// The edge a member [`Doorbell`] stores: which tree, which slot,
/// which shard bit.
pub struct TreeEdge {
    tree: Arc<WaiterTree>,
    slot: Arc<TreeSlot>,
    bit: u32,
}

/// Epoll-style doorbell aggregation: many connections' request bells
/// register as [`TreeSlot`]s; every member ring marks its shard bit,
/// enqueues the slot (once) on a pending queue, and rings the shared
/// **root** doorbell. A pool of k workers parks on the root alone and
/// sweeps only ready slots — worker count decouples from channel
/// count.
///
/// # Lost-wakeup argument (extends DESIGN.md §9 across aggregation)
///
/// The root bell keeps the coalesced-epoch protocol: workers arm the
/// root once for their lifetime, snapshot its epoch, sweep, and only
/// park when the sweep made no progress — so any member ring between
/// the snapshot and the park bumps the root epoch and the park
/// returns immediately. Within a slot, `pop_ready` clears `queued`
/// *before* swapping out the dirty mask; a ring racing the sweep
/// therefore either lands in the mask the sweep takes, or finds
/// `queued == 0` and re-enqueues the slot (at worst a benign spurious
/// pop). Rings are never dropped: `dirty` is only cleared by the swap
/// that hands the mask to a worker. As a belt-and-braces bound, idle
/// workers full-scan registered slots before parking
/// ([`WaiterTree::scan_ready`]), so even a hypothetically missed
/// queue entry costs at most one park slice.
pub struct WaiterTree {
    root: Arc<Doorbell>,
    slots: RwLock<Vec<Option<Arc<TreeSlot>>>>,
    /// Slots with (probably) nonzero dirty masks, in ring order. A
    /// plain mutexed queue: the `queued` flag admits one push per
    /// sweep per slot, so the lock is off the per-RPC hot path.
    pending: Mutex<VecDeque<Arc<TreeSlot>>>,
}

impl WaiterTree {
    pub fn new_arc() -> Arc<WaiterTree> {
        Arc::new(WaiterTree {
            root: Doorbell::new_arc(),
            slots: RwLock::new(Vec::new()),
            pending: Mutex::new(VecDeque::new()),
        })
    }

    /// The aggregate bell workers arm and park on.
    pub fn root(&self) -> &Arc<Doorbell> {
        &self.root
    }

    /// Register a new slot (lowest free index; registration is rare —
    /// once per connection — so the write lock is fine).
    pub fn register(&self) -> Arc<TreeSlot> {
        let mut slots = self.slots.write().unwrap();
        let id = slots.iter().position(|s| s.is_none()).unwrap_or(slots.len());
        let slot = Arc::new(TreeSlot {
            id,
            dirty: AtomicU64::new(0),
            queued: AtomicU32::new(0),
            dead: AtomicBool::new(false),
        });
        if id == slots.len() {
            slots.push(Some(Arc::clone(&slot)));
        } else {
            slots[id] = Some(Arc::clone(&slot));
        }
        slot
    }

    /// Attach a member bell to `slot` at shard `bit` (≤ 63). One-shot:
    /// a bell belongs to at most one tree for its lifetime.
    pub fn attach(self: &Arc<Self>, bell: &Doorbell, slot: &Arc<TreeSlot>, bit: u32) {
        let _ = bell.parent.set(TreeEdge {
            tree: Arc::clone(self),
            slot: Arc::clone(slot),
            bit: bit.min(63),
        });
    }

    /// Drop a slot: sweeps skip it from now on; its queue entry (if
    /// any) drains lazily on the next pop.
    pub fn deregister(&self, slot: &TreeSlot) {
        slot.dead.store(true, Ordering::Release);
        let mut slots = self.slots.write().unwrap();
        if let Some(entry) = slots.get_mut(slot.id) {
            *entry = None;
        }
    }

    /// Member-ring propagation (called from [`Doorbell::ring`]).
    fn notify(&self, slot: &Arc<TreeSlot>, bit: u32) {
        slot.dirty.fetch_or(1u64 << bit, Ordering::Release);
        if slot.queued.swap(1, Ordering::AcqRel) == 0 {
            self.pending.lock().unwrap().push_back(Arc::clone(slot));
        }
        self.root.ring();
    }

    /// Force-mark shards ready (adoption: requests published before
    /// the slot's bells were attached must not be lost).
    pub fn kick(&self, slot: &Arc<TreeSlot>, mask: u64) {
        slot.dirty.fetch_or(mask, Ordering::Release);
        if slot.queued.swap(1, Ordering::AcqRel) == 0 {
            self.pending.lock().unwrap().push_back(Arc::clone(slot));
        }
        self.root.ring();
    }

    /// Next ready slot: `(slot id, dirty shard mask)`. Clears `queued`
    /// before taking the mask, so a racing ring either lands in the
    /// returned mask or re-enqueues the slot.
    pub fn pop_ready(&self) -> Option<(usize, u64)> {
        loop {
            let slot = self.pending.lock().unwrap().pop_front()?;
            slot.queued.store(0, Ordering::Release);
            let mask = slot.dirty.swap(0, Ordering::AcqRel);
            if slot.dead.load(Ordering::Acquire) {
                continue;
            }
            if mask != 0 {
                return Some((slot.id, mask));
            }
        }
    }

    /// Safety-net full scan (idle workers only): any live slot with a
    /// nonzero dirty mask, queued or not. Bounds starvation at one
    /// park slice without putting O(slots) on the hot path.
    pub fn scan_ready(&self) -> Vec<(usize, u64)> {
        let slots = self.slots.read().unwrap();
        let mut out = Vec::new();
        for s in slots.iter().flatten() {
            if s.dead.load(Ordering::Acquire) {
                continue;
            }
            if s.dirty.load(Ordering::Acquire) != 0 {
                let mask = s.dirty.swap(0, Ordering::AcqRel);
                if mask != 0 {
                    out.push((s.id, mask));
                }
            }
        }
        out
    }

    /// Live registered slots (telemetry/tests).
    pub fn slot_count(&self) -> usize {
        self.slots.read().unwrap().iter().flatten().count()
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SleepPolicy {
    /// Paper §5.8 default: 0 / mid / high µs by load band.
    Adaptive { load_mid: f64, load_high: f64, sleep_mid_us: u64, sleep_high_us: u64 },
    /// Fixed sleep between iterations (Figure 13's sweep points).
    Fixed(u64),
    /// Never sleep.
    Spin,
    /// Spin briefly, then block on the connection's [`Doorbell`] until
    /// `publish()`/`respond()` rings it. Zero CPU burn when idle, no
    /// sleep-tick latency when loaded.
    Park,
}

impl SleepPolicy {
    pub fn from_config(cfg: &crate::config::SimConfig) -> SleepPolicy {
        SleepPolicy::Adaptive {
            load_mid: cfg.busywait_load_mid,
            load_high: cfg.busywait_load_high,
            sleep_mid_us: cfg.busywait_sleep_mid_us,
            sleep_high_us: cfg.busywait_sleep_high_us,
        }
    }

    /// Sleep duration for the current load. (`Park` reports 0: parking
    /// is driven by the doorbell in [`wait_on`], not by timed sleeps.)
    pub fn sleep_us(&self, load: f64) -> u64 {
        match *self {
            SleepPolicy::Spin | SleepPolicy::Park => 0,
            SleepPolicy::Fixed(us) => us,
            SleepPolicy::Adaptive { load_mid, load_high, sleep_mid_us, sleep_high_us } => {
                if load < load_mid {
                    0
                } else if load < load_high {
                    sleep_mid_us
                } else {
                    sleep_high_us
                }
            }
        }
    }
}

/// Outcome of a wait.
#[derive(Debug, PartialEq, Eq)]
pub enum WaitOutcome {
    Ready,
    TimedOut,
}

/// Statistics (iterations vs sleeps) for tuning/telemetry.
#[derive(Default)]
pub struct WaitStats {
    pub polls: AtomicU64,
    pub sleeps: AtomicU64,
}

/// Busy-wait until `ready()` or `timeout`. The paper's poll loop.
/// `SleepPolicy::Park` degrades to a 5µs fixed sleep here because no
/// doorbell is supplied — use [`wait_on`] on paths that have one.
pub fn wait_until(
    policy: SleepPolicy,
    timeout: Duration,
    stats: Option<&WaitStats>,
    ready: impl FnMut() -> bool,
) -> WaitOutcome {
    wait_on(policy, timeout, stats, None, ready)
}

/// Busy-wait until `ready()` or `timeout`, parking on `bell` when the
/// policy is `Park`. The wait is doorbell-correct: the epoch is
/// snapshotted before every `ready()` check, so a ring that lands
/// between the check and the park wakes the waiter immediately.
pub fn wait_on(
    policy: SleepPolicy,
    timeout: Duration,
    stats: Option<&WaitStats>,
    bell: Option<&Doorbell>,
    mut ready: impl FnMut() -> bool,
) -> WaitOutcome {
    let start = Instant::now();
    let park = policy == SleepPolicy::Park && bell.is_some();
    // Armed lazily, only when this waiter is actually about to park:
    // while any waiter is armed, every producer-side `ring()` pays an
    // epoch bump, so the spin phase (the loaded case) keeps the bell
    // silent and `ring()` stays a single load.
    let mut armed = false;
    let mut polls: u32 = 0;
    LOAD.enter();
    let out = loop {
        // Epoch snapshot before the ready check (once armed): a ring
        // that lands between the check and the park advances it, so
        // the park returns immediately.
        let seen = if armed { bell.unwrap().epoch() } else { 0 };
        if ready() {
            break WaitOutcome::Ready;
        }
        if let Some(s) = stats {
            s.polls.fetch_add(1, Ordering::Relaxed);
        }
        let elapsed = start.elapsed();
        if elapsed >= timeout {
            break WaitOutcome::TimedOut;
        }
        if park {
            polls += 1;
            if polls < PARK_SPIN_POLLS {
                std::hint::spin_loop();
                continue;
            }
            if !armed {
                bell.unwrap().arm();
                armed = true;
                // Re-check ready with the bell armed before parking —
                // an event between the last check and arming would
                // otherwise be missed.
                continue;
            }
            let slice = (timeout - elapsed).min(Duration::from_micros(PARK_SLICE_US));
            if let Some(s) = stats {
                s.sleeps.fetch_add(1, Ordering::Relaxed);
            }
            // A parked thread occupies no core: leave the load count
            // while blocked so adaptive pollers elsewhere see the
            // freed CPU.
            LOAD.exit();
            bell.unwrap().wait_past(seen, slice);
            LOAD.enter();
            continue;
        }
        let us = match policy {
            // Park without a bell: nothing to park on; a short fixed
            // sleep keeps the semantics (yield the core when idle).
            SleepPolicy::Park => 5,
            p => p.sleep_us(LOAD.load()),
        };
        if us > 0 {
            if let Some(s) = stats {
                s.sleeps.fetch_add(1, Ordering::Relaxed);
            }
            // A real sleep yields the core — that is the whole point
            // of the adaptive policy (frees CPU for workers).
            std::thread::sleep(Duration::from_micros(us));
        } else {
            std::hint::spin_loop();
        }
    };
    LOAD.exit();
    if armed {
        bell.unwrap().disarm();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn policy_bands_match_paper() {
        let p = SleepPolicy::Adaptive {
            load_mid: 0.25,
            load_high: 0.50,
            sleep_mid_us: 5,
            sleep_high_us: 150,
        };
        assert_eq!(p.sleep_us(0.10), 0);
        assert_eq!(p.sleep_us(0.30), 5);
        assert_eq!(p.sleep_us(0.80), 150);
        assert_eq!(SleepPolicy::Park.sleep_us(0.80), 0);
    }

    #[test]
    fn wait_sees_flag_from_other_thread() {
        let flag = Arc::new(AtomicBool::new(false));
        let f2 = Arc::clone(&flag);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            f2.store(true, Ordering::Release);
        });
        let out = wait_until(SleepPolicy::Spin, Duration::from_secs(1), None, || {
            flag.load(Ordering::Acquire)
        });
        assert_eq!(out, WaitOutcome::Ready);
        t.join().unwrap();
    }

    #[test]
    fn wait_times_out() {
        let out =
            wait_until(SleepPolicy::Fixed(1), Duration::from_millis(5), None, || false);
        assert_eq!(out, WaitOutcome::TimedOut);
    }

    #[test]
    fn park_wait_times_out_without_bell() {
        let out = wait_until(SleepPolicy::Park, Duration::from_millis(5), None, || false);
        assert_eq!(out, WaitOutcome::TimedOut);
    }

    #[test]
    fn parked_waiter_wakes_on_ring() {
        let bell = Doorbell::new_arc();
        let flag = Arc::new(AtomicBool::new(false));
        let (b2, f2) = (Arc::clone(&bell), Arc::clone(&flag));
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            f2.store(true, Ordering::Release);
            b2.ring();
        });
        let t0 = Instant::now();
        let out = wait_on(SleepPolicy::Park, Duration::from_secs(5), None, Some(&bell), || {
            flag.load(Ordering::Acquire)
        });
        assert_eq!(out, WaitOutcome::Ready);
        // Must wake well before a 5s timeout; the ring (or at worst
        // one park slice) bounds the latency.
        assert!(t0.elapsed() < Duration::from_secs(1));
        t.join().unwrap();
    }

    #[test]
    fn ring_without_waiters_is_cheap_and_safe() {
        let bell = Doorbell::new();
        // Not armed: epoch must not advance (fast path short-circuits).
        bell.ring();
        assert_eq!(bell.epoch(), 0);
        bell.arm();
        bell.ring();
        assert!(bell.epoch() > 0);
        bell.disarm();
        assert_eq!(bell.parked(), 0);
    }

    #[test]
    fn wait_past_returns_immediately_on_stale_epoch() {
        let bell = Doorbell::new();
        bell.arm();
        let seen = bell.epoch();
        bell.ring(); // epoch moves past `seen`
        let t0 = Instant::now();
        bell.wait_past(seen, Duration::from_secs(5));
        assert!(t0.elapsed() < Duration::from_millis(500), "stale epoch must not block");
        bell.disarm();
    }

    /// Seed for the doorbell property tests: `PROP_SEED` env var, so
    /// CI can sweep schedules and failures replay exactly.
    fn prop_seed() -> u64 {
        std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xD00B)
    }

    /// The arm/epoch/park protocol's core guarantee: a ring that
    /// lands anywhere between `arm()` and `wait_past()` must wake the
    /// waiter promptly — never cost the full wait. The 5s slice makes
    /// a missed ring visible (the sliced 1ms production wait would
    /// mask it); random spin jitter on both sides sweeps the racy
    /// window around the epoch snapshot.
    #[test]
    fn prop_park_never_misses_ring_between_arm_and_park() {
        use crate::util::prop::{forall, PairGen, U64Range};
        let jitter = PairGen(U64Range(0, 4000), U64Range(0, 4000));
        forall("doorbell-arm-vs-ring", prop_seed(), 32, &jitter, |&(wjit, rjit)| {
            let bell = Doorbell::new_arc();
            let flag = Arc::new(AtomicBool::new(false));
            let (b2, f2) = (Arc::clone(&bell), Arc::clone(&flag));
            let ringer = std::thread::spawn(move || {
                for _ in 0..rjit {
                    std::hint::spin_loop();
                }
                f2.store(true, Ordering::Release);
                b2.ring();
            });
            bell.arm();
            let seen = bell.epoch();
            for _ in 0..wjit {
                std::hint::spin_loop();
            }
            let t0 = Instant::now();
            // flag false here ⇒ the ring has not happened yet (the
            // store precedes it) ⇒ the coming ring must end the wait.
            if !flag.load(Ordering::Acquire) {
                bell.wait_past(seen, Duration::from_secs(5));
            }
            let waked_fast = t0.elapsed() < Duration::from_secs(2);
            ringer.join().unwrap();
            bell.disarm();
            waked_fast && flag.load(Ordering::Acquire)
        });
    }

    /// Full `wait_on(Park)` protocol under a jittered producer: every
    /// step of a produce/consume sequence must come back `Ready` —
    /// across repeated arm/park/disarm cycles, sliced parks, and
    /// producer sleeps straddling the ready-check/park window.
    #[test]
    fn prop_sliced_park_roundtrips_with_jittered_producer() {
        use crate::util::prop::{forall, U64Range};
        use crate::util::rng::Rng;
        forall("doorbell-produce-consume", prop_seed(), 8, &U64Range(0, u64::MAX / 2), |&salt| {
            const STEPS: u64 = 20;
            let bell = Doorbell::new_arc();
            let produced = Arc::new(AtomicU64::new(0));
            let (b2, p2) = (Arc::clone(&bell), Arc::clone(&produced));
            let producer = std::thread::spawn(move || {
                let mut rng = Rng::new(salt);
                for _ in 0..STEPS {
                    std::thread::sleep(Duration::from_micros(rng.next_below(500)));
                    p2.fetch_add(1, Ordering::Release);
                    b2.ring();
                }
            });
            let mut ok = true;
            for k in 1..=STEPS {
                let out =
                    wait_on(SleepPolicy::Park, Duration::from_secs(5), None, Some(&bell), || {
                        produced.load(Ordering::Acquire) >= k
                    });
                ok &= out == WaitOutcome::Ready;
            }
            producer.join().unwrap();
            ok && produced.load(Ordering::Acquire) == STEPS
        });
    }

    /// Coalesced response epochs (the drain-k server's shape): N
    /// waiters park on ONE bell; the producer completes them in
    /// random batches with a single ring per batch. Every waiter must
    /// come back Ready — one bump wakes all, each re-scans its own
    /// slot, the not-yet-served re-park against a fresh epoch and are
    /// woken by a later batch's single ring. A lost wakeup would
    /// surface as a full 5 s wait (the sliced production park would
    /// mask it at 1 ms, so the property uses raw wait_on semantics
    /// with a deadline assertion instead).
    #[test]
    fn prop_coalesced_ring_wakes_every_waiter() {
        use crate::util::prop::{forall, U64Range};
        use crate::util::rng::Rng;
        forall("doorbell-coalesced-epochs", prop_seed(), 8, &U64Range(0, u64::MAX / 2), |&salt| {
            const WAITERS: u64 = 4;
            const ROUNDS: u64 = 8; // each waiter completes once per round
            let bell = Doorbell::new_arc();
            let done = Arc::new((0..WAITERS).map(|_| AtomicU64::new(0)).collect::<Vec<_>>());
            let (b2, d2) = (Arc::clone(&bell), Arc::clone(&done));
            let producer = std::thread::spawn(move || {
                let mut rng = Rng::new(salt ^ 0xC0A1E5CE);
                for round in 1..=ROUNDS {
                    // Serve the round in 1..=WAITERS random batches,
                    // one coalesced ring per batch (never per waiter).
                    let mut order: Vec<usize> = (0..WAITERS as usize).collect();
                    for i in (1..order.len()).rev() {
                        order.swap(i, rng.next_below(i as u64 + 1) as usize);
                    }
                    let mut served = 0usize;
                    while served < order.len() {
                        let batch = 1 + rng.next_below((order.len() - served) as u64) as usize;
                        std::thread::sleep(Duration::from_micros(rng.next_below(300)));
                        for &w in &order[served..served + batch] {
                            d2[w].store(round, Ordering::Release);
                        }
                        b2.ring(); // ONE signal for the whole batch
                        served += batch;
                    }
                }
            });
            let mut workers = Vec::new();
            for w in 0..WAITERS as usize {
                let bell = Arc::clone(&bell);
                let done = Arc::clone(&done);
                workers.push(std::thread::spawn(move || {
                    let mut ok = true;
                    for round in 1..=ROUNDS {
                        let t0 = Instant::now();
                        let out = wait_on(
                            SleepPolicy::Park,
                            Duration::from_secs(10),
                            None,
                            Some(&bell),
                            || done[w].load(Ordering::Acquire) >= round,
                        );
                        ok &= out == WaitOutcome::Ready
                            && t0.elapsed() < Duration::from_secs(5);
                    }
                    ok
                }));
            }
            let ok = workers.into_iter().all(|t| t.join().unwrap());
            producer.join().unwrap();
            ok
        });
    }

    #[test]
    fn tree_ring_marks_dirty_and_pops_once() {
        let tree = WaiterTree::new_arc();
        let slot = tree.register();
        let b0 = Doorbell::new_arc();
        let b2 = Doorbell::new_arc();
        tree.attach(&b0, &slot, 0);
        tree.attach(&b2, &slot, 2);
        // Unattached-bell behaviour is untouched: ring with no armed
        // waiter stays epoch-silent on the member bell itself.
        b0.ring();
        assert_eq!(b0.epoch(), 0, "member bell's own epoch untouched");
        b2.ring();
        b2.ring(); // coalesces into the same pending entry
        let (id, mask) = tree.pop_ready().expect("slot pending");
        assert_eq!(id, slot.id());
        assert_eq!(mask, 0b101, "bits 0 and 2 dirty");
        assert!(tree.pop_ready().is_none(), "one queue entry per sweep");
        assert!(tree.scan_ready().is_empty(), "mask consumed");
    }

    #[test]
    fn tree_kick_and_deregister() {
        let tree = WaiterTree::new_arc();
        let slot = tree.register();
        tree.kick(&slot, 0xF);
        assert_eq!(tree.pop_ready(), Some((slot.id(), 0xF)));
        let dead = tree.register();
        assert_eq!(tree.slot_count(), 2);
        tree.kick(&dead, 1);
        tree.deregister(&dead);
        assert!(tree.pop_ready().is_none(), "dead slots drain silently");
        assert_eq!(tree.slot_count(), 1);
        // Freed index is reused by the next registration.
        let re = tree.register();
        assert_eq!(re.id(), dead.id());
    }

    #[test]
    fn tree_root_rings_on_member_ring() {
        let tree = WaiterTree::new_arc();
        let slot = tree.register();
        let bell = Doorbell::new_arc();
        tree.attach(&bell, &slot, 0);
        tree.root().arm();
        let seen = tree.root().epoch();
        bell.ring();
        assert!(tree.root().epoch() > seen, "member ring bumps the armed root");
        tree.root().disarm();
    }

    /// The aggregated lost-wakeup property: producers ring N member
    /// bells (random slots/shards/timing); one pool-style worker parks
    /// on the ROOT only — arm once, epoch snapshot, sweep
    /// (pop + idle scan), park when no progress. Every produced event
    /// must be served well before the deadline; a wakeup lost across
    /// the aggregation layer would strand the worker a full park cycle
    /// per event and blow it.
    #[test]
    fn prop_tree_never_loses_member_ring() {
        use crate::util::prop::{forall, U64Range};
        use crate::util::rng::Rng;
        forall("waiter-tree-aggregation", prop_seed(), 8, &U64Range(0, u64::MAX / 2), |&salt| {
            const SLOTS: usize = 4;
            const SHARDS: usize = 4;
            const EVENTS: u64 = 200;
            let tree = WaiterTree::new_arc();
            let mut bells = Vec::new();
            let mut slots = Vec::new();
            for _ in 0..SLOTS {
                let slot = tree.register();
                for bit in 0..SHARDS {
                    let b = Doorbell::new_arc();
                    tree.attach(&b, &slot, bit as u32);
                    bells.push(b);
                }
                slots.push(slot);
            }
            let produced = Arc::new(AtomicU64::new(0));
            let served = Arc::new(AtomicU64::new(0));
            let stop = Arc::new(AtomicBool::new(false));
            let worker = {
                let (tree, served, stop) = (Arc::clone(&tree), Arc::clone(&served), Arc::clone(&stop));
                std::thread::spawn(move || {
                    let root = Arc::clone(tree.root());
                    root.arm();
                    while !stop.load(Ordering::Acquire) {
                        let seen = root.epoch();
                        let mut progress = false;
                        while let Some((_id, mask)) = tree.pop_ready() {
                            served.fetch_add(mask.count_ones() as u64, Ordering::AcqRel);
                            progress = true;
                        }
                        if !progress {
                            for (_id, mask) in tree.scan_ready() {
                                served.fetch_add(mask.count_ones() as u64, Ordering::AcqRel);
                                progress = true;
                            }
                        }
                        if !progress {
                            root.wait_past(seen, Duration::from_micros(PARK_SLICE_US));
                        }
                    }
                    root.disarm();
                })
            };
            let mut rng = Rng::new(salt ^ 0x7EE);
            let deadline = Instant::now() + Duration::from_secs(20);
            let mut ok = true;
            for _ in 0..EVENTS {
                // A "ring" marks at most one new dirty bit per (slot,
                // shard): only count events the mask tally will see.
                let b = rng.next_below(bells.len() as u64) as usize;
                bells[b].ring();
                produced.fetch_add(1, Ordering::AcqRel);
                // Wait until the worker caught up — the next ring on
                // the same bit would otherwise coalesce into this one
                // and the mask tally would undercount.
                while served.load(Ordering::Acquire) < produced.load(Ordering::Acquire) {
                    if Instant::now() > deadline {
                        ok = false;
                        break;
                    }
                    std::hint::spin_loop();
                }
                if !ok {
                    break;
                }
                if rng.next_below(4) == 0 {
                    std::thread::sleep(Duration::from_micros(rng.next_below(200)));
                }
            }
            stop.store(true, Ordering::Release);
            tree.root().ring();
            worker.join().unwrap();
            ok && served.load(Ordering::Acquire) == produced.load(Ordering::Acquire)
        });
    }

    #[test]
    fn load_monitor_counts() {
        let m = LoadMonitor::new();
        m.set_cores(4);
        m.enter();
        m.enter();
        assert!((m.load() - 0.5).abs() < 1e-9);
        m.exit();
        m.exit();
        assert_eq!(m.load(), 0.0);
    }
}
