//! Busy-waiting with adaptive sleep (paper §5.8).
//!
//! RPCool busy-polls shared memory for new RPCs and completions. To
//! keep CPU burn bounded, it sleeps between iterations depending on
//! CPU load: no sleep under 25% load, 5µs between 25–50%, 150µs above
//! 50%. Figure 13 sweeps these sleeps to show the latency/throughput
//! tradeoff; `SleepPolicy::Fixed` reproduces that sweep.
//!
//! Load here is the fraction of hardware threads occupied by active
//! pollers/workers (a `LoadMonitor` EWMA), standing in for the
//! system-wide CPU load the paper samples.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Global count of threads currently spinning/working, and the
/// number of "virtual cores" load is measured against.
pub struct LoadMonitor {
    active: AtomicI64,
    cores: AtomicI64,
}

impl LoadMonitor {
    pub const fn new() -> Self {
        LoadMonitor { active: AtomicI64::new(0), cores: AtomicI64::new(8) }
    }

    pub fn set_cores(&self, n: i64) {
        self.cores.store(n.max(1), Ordering::Relaxed);
    }

    pub fn enter(&self) {
        self.active.fetch_add(1, Ordering::Relaxed);
    }

    pub fn exit(&self) {
        self.active.fetch_sub(1, Ordering::Relaxed);
    }

    /// Instantaneous load in [0, ∞): active / cores.
    pub fn load(&self) -> f64 {
        let a = self.active.load(Ordering::Relaxed).max(0) as f64;
        let c = self.cores.load(Ordering::Relaxed) as f64;
        a / c
    }
}

impl Default for LoadMonitor {
    fn default() -> Self {
        Self::new()
    }
}

/// Process-wide monitor (simulated hosts share the physical CPU).
pub static LOAD: LoadMonitor = LoadMonitor::new();

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SleepPolicy {
    /// Paper §5.8 default: 0 / mid / high µs by load band.
    Adaptive { load_mid: f64, load_high: f64, sleep_mid_us: u64, sleep_high_us: u64 },
    /// Fixed sleep between iterations (Figure 13's sweep points).
    Fixed(u64),
    /// Never sleep.
    Spin,
}

impl SleepPolicy {
    pub fn from_config(cfg: &crate::config::SimConfig) -> SleepPolicy {
        SleepPolicy::Adaptive {
            load_mid: cfg.busywait_load_mid,
            load_high: cfg.busywait_load_high,
            sleep_mid_us: cfg.busywait_sleep_mid_us,
            sleep_high_us: cfg.busywait_sleep_high_us,
        }
    }

    /// Sleep duration for the current load.
    pub fn sleep_us(&self, load: f64) -> u64 {
        match *self {
            SleepPolicy::Spin => 0,
            SleepPolicy::Fixed(us) => us,
            SleepPolicy::Adaptive { load_mid, load_high, sleep_mid_us, sleep_high_us } => {
                if load < load_mid {
                    0
                } else if load < load_high {
                    sleep_mid_us
                } else {
                    sleep_high_us
                }
            }
        }
    }
}

/// Outcome of a wait.
#[derive(Debug, PartialEq, Eq)]
pub enum WaitOutcome {
    Ready,
    TimedOut,
}

/// Statistics (iterations vs sleeps) for tuning/telemetry.
#[derive(Default)]
pub struct WaitStats {
    pub polls: AtomicU64,
    pub sleeps: AtomicU64,
}

/// Busy-wait until `ready()` or `timeout`. The paper's poll loop.
pub fn wait_until(
    policy: SleepPolicy,
    timeout: Duration,
    stats: Option<&WaitStats>,
    mut ready: impl FnMut() -> bool,
) -> WaitOutcome {
    let start = Instant::now();
    LOAD.enter();
    let out = loop {
        if ready() {
            break WaitOutcome::Ready;
        }
        if let Some(s) = stats {
            s.polls.fetch_add(1, Ordering::Relaxed);
        }
        if start.elapsed() >= timeout {
            break WaitOutcome::TimedOut;
        }
        let us = policy.sleep_us(LOAD.load());
        if us > 0 {
            if let Some(s) = stats {
                s.sleeps.fetch_add(1, Ordering::Relaxed);
            }
            // A real sleep yields the core — that is the whole point
            // of the adaptive policy (frees CPU for workers).
            std::thread::sleep(Duration::from_micros(us));
        } else {
            std::hint::spin_loop();
        }
    };
    LOAD.exit();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn policy_bands_match_paper() {
        let p = SleepPolicy::Adaptive {
            load_mid: 0.25,
            load_high: 0.50,
            sleep_mid_us: 5,
            sleep_high_us: 150,
        };
        assert_eq!(p.sleep_us(0.10), 0);
        assert_eq!(p.sleep_us(0.30), 5);
        assert_eq!(p.sleep_us(0.80), 150);
    }

    #[test]
    fn wait_sees_flag_from_other_thread() {
        let flag = Arc::new(AtomicBool::new(false));
        let f2 = Arc::clone(&flag);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            f2.store(true, Ordering::Release);
        });
        let out = wait_until(SleepPolicy::Spin, Duration::from_secs(1), None, || {
            flag.load(Ordering::Acquire)
        });
        assert_eq!(out, WaitOutcome::Ready);
        t.join().unwrap();
    }

    #[test]
    fn wait_times_out() {
        let out =
            wait_until(SleepPolicy::Fixed(1), Duration::from_millis(5), None, || false);
        assert_eq!(out, WaitOutcome::TimedOut);
    }

    #[test]
    fn load_monitor_counts() {
        let m = LoadMonitor::new();
        m.set_cores(4);
        m.enter();
        m.enter();
        assert!((m.load() - 0.5).abs() < 1e-9);
        m.exit();
        m.exit();
        assert_eq!(m.load(), 0.0);
    }
}
