//! The RPC slot ring — the shared-memory mailbox a connection's RPCs
//! travel through (paper §4.2, §5.8).
//!
//! One ring per connection lives in the connection heap. The client
//! claims a slot, writes the request descriptor (function id, argument
//! pointer — the argument *data* is already in the heap; this is the
//! zero-serialization trick), and publishes it with a release store:
//! the "doorbell" the server's busy-wait loop observes across the CXL
//! fabric. Responses flow back through the same slot.
//!
//! Slot states cycle EMPTY → CLAIMED → REQUEST → PROCESSING →
//! RESPONSE → EMPTY. Multiple client threads may share a connection
//! (slots are claimed by CAS); each slot is single-producer
//! single-consumer once claimed.

use crate::error::{Result, RpcError};
use crate::memory::heap::Heap;
use crate::memory::pool::Charger;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

pub const SLOT_EMPTY: u32 = 0;
pub const SLOT_CLAIMED: u32 = 1;
pub const SLOT_REQUEST: u32 = 2;
pub const SLOT_PROCESSING: u32 = 3;
pub const SLOT_RESPONSE: u32 = 4;

/// Call flags.
pub const FLAG_SEALED: u32 = 1 << 0;
pub const FLAG_SANDBOXED: u32 = 1 << 1;

/// No seal descriptor attached.
pub const NO_SEAL: u64 = u64::MAX;

/// One request/response slot, resident in shared memory.
#[repr(C)]
pub struct Slot {
    pub state: AtomicU32,
    pub func: AtomicU32,
    pub flags: AtomicU32,
    pub status: AtomicU32,
    /// Seal descriptor index (NO_SEAL if none).
    pub seal_idx: std::sync::atomic::AtomicU64,
    /// Argument pointer + byte length (a native shm pointer!).
    pub arg: std::sync::atomic::AtomicU64,
    pub arg_len: std::sync::atomic::AtomicU64,
    /// Return value (scalar or native shm pointer).
    pub ret: std::sync::atomic::AtomicU64,
}

/// Status codes carried back in `Slot::status`.
pub const ST_OK: u32 = 0;
pub const ST_NO_HANDLER: u32 = 1;
pub const ST_SEAL_INVALID: u32 = 2;
pub const ST_SANDBOX_VIOLATION: u32 = 3;
pub const ST_HANDLER_ERROR: u32 = 4;
pub const ST_CLOSED: u32 = 5;

pub fn status_to_error(status: u32) -> RpcError {
    match status {
        ST_NO_HANDLER => RpcError::NoSuchHandler(0),
        ST_SEAL_INVALID => RpcError::SealInvalid("receiver-side seal verification failed".into()),
        ST_SANDBOX_VIOLATION => {
            RpcError::SandboxViolation { addr: 0, lo: 0, hi: 0 }
        }
        ST_CLOSED => RpcError::ConnectionClosed,
        _ => RpcError::Remote(format!("handler error (status {status})")),
    }
}

/// The ring itself: `n` slots in the connection heap.
pub struct RpcRing {
    base: usize,
    n: usize,
    charger: Arc<Charger>,
    /// One-way doorbell cost: CXL signal for in-rack connections, an
    /// RDMA message for DSM-fallback connections.
    signal_ns: u64,
}

impl RpcRing {
    pub fn create(heap: &Arc<Heap>, n: usize) -> Result<RpcRing> {
        let ns = heap.pool().charger.cost.cxl_signal_ns;
        Self::create_with_signal(heap, n, ns)
    }

    /// Ring whose doorbell models a different link (RDMA fallback).
    pub fn create_with_signal(heap: &Arc<Heap>, n: usize, signal_ns: u64) -> Result<RpcRing> {
        let n = n.next_power_of_two().max(4);
        let bytes = n * std::mem::size_of::<Slot>();
        let base = heap.alloc_bytes(bytes)?;
        unsafe { std::ptr::write_bytes(base as *mut u8, 0, bytes) };
        Ok(RpcRing { base, n, charger: Arc::clone(&heap.pool().charger), signal_ns })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        false
    }

    #[inline]
    pub fn slot(&self, i: usize) -> &Slot {
        debug_assert!(i < self.n);
        unsafe { &*((self.base + i * std::mem::size_of::<Slot>()) as *const Slot) }
    }

    /// Client side: claim an EMPTY slot (CAS scan).
    pub fn claim(&self) -> Option<usize> {
        for i in 0..self.n {
            let s = self.slot(i);
            if s.state
                .compare_exchange(SLOT_EMPTY, SLOT_CLAIMED, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
            {
                return Some(i);
            }
        }
        None
    }

    /// Client side: fill the claimed slot and ring the doorbell.
    #[allow(clippy::too_many_arguments)]
    pub fn publish(
        &self,
        i: usize,
        func: u32,
        flags: u32,
        seal_idx: u64,
        arg: usize,
        arg_len: usize,
    ) {
        let s = self.slot(i);
        s.func.store(func, Ordering::Relaxed);
        s.flags.store(flags, Ordering::Relaxed);
        s.seal_idx.store(seal_idx, Ordering::Relaxed);
        s.arg.store(arg as u64, Ordering::Relaxed);
        s.arg_len.store(arg_len as u64, Ordering::Relaxed);
        s.status.store(ST_OK, Ordering::Relaxed);
        // The doorbell: one cross-fabric signal (or RDMA message).
        self.charger.charge_ns(self.signal_ns);
        s.state.store(SLOT_REQUEST, Ordering::Release);
    }

    /// Server side: find a pending request, transition it to PROCESSING.
    pub fn take_request(&self) -> Option<usize> {
        for i in 0..self.n {
            let s = self.slot(i);
            if s.state.load(Ordering::Acquire) == SLOT_REQUEST
                && s.state
                    .compare_exchange(
                        SLOT_REQUEST,
                        SLOT_PROCESSING,
                        Ordering::AcqRel,
                        Ordering::Relaxed,
                    )
                    .is_ok()
            {
                return Some(i);
            }
        }
        None
    }

    /// Server side: write the response and signal the client.
    pub fn respond(&self, i: usize, status: u32, ret: u64) {
        let s = self.slot(i);
        s.ret.store(ret, Ordering::Relaxed);
        s.status.store(status, Ordering::Relaxed);
        self.charger.charge_ns(self.signal_ns);
        s.state.store(SLOT_RESPONSE, Ordering::Release);
    }

    /// Client side: is the response ready?
    #[inline]
    pub fn response_ready(&self, i: usize) -> bool {
        self.slot(i).state.load(Ordering::Acquire) == SLOT_RESPONSE
    }

    /// Client side: consume the response, freeing the slot.
    pub fn consume(&self, i: usize) -> (u32, u64) {
        let s = self.slot(i);
        let status = s.status.load(Ordering::Relaxed);
        let ret = s.ret.load(Ordering::Relaxed);
        s.state.store(SLOT_EMPTY, Ordering::Release);
        (status, ret)
    }

    /// Any in-flight work? (used by drain/shutdown paths)
    pub fn quiescent(&self) -> bool {
        (0..self.n).all(|i| self.slot(i).state.load(Ordering::Acquire) == SLOT_EMPTY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::pool::Pool;

    fn ring() -> (Arc<Pool>, Arc<Heap>, RpcRing) {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "ring", 1 << 20).unwrap();
        let r = RpcRing::create(&heap, 8).unwrap();
        (pool, heap, r)
    }

    #[test]
    fn request_response_cycle() {
        let (_p, _h, r) = ring();
        let i = r.claim().unwrap();
        r.publish(i, 100, 0, NO_SEAL, 0xAB0, 64);
        let j = r.take_request().unwrap();
        assert_eq!(i, j);
        let s = r.slot(j);
        assert_eq!(s.func.load(Ordering::Relaxed), 100);
        assert_eq!(s.arg.load(Ordering::Relaxed), 0xAB0);
        r.respond(j, ST_OK, 42);
        assert!(r.response_ready(i));
        let (status, ret) = r.consume(i);
        assert_eq!((status, ret), (ST_OK, 42));
        assert!(r.quiescent());
    }

    #[test]
    fn slots_exhaust_then_recycle() {
        let (_p, _h, r) = ring();
        let claimed: Vec<usize> = (0..r.len()).map(|_| r.claim().unwrap()).collect();
        assert_eq!(claimed.len(), 8);
        assert!(r.claim().is_none(), "ring full");
        // Respond to one and it becomes claimable again.
        r.publish(claimed[0], 1, 0, NO_SEAL, 0, 0);
        let i = r.take_request().unwrap();
        r.respond(i, ST_OK, 0);
        r.consume(i);
        assert!(r.claim().is_some());
    }

    #[test]
    fn cross_thread_rpc() {
        let (_p, h, _unused) = ring();
        let r = Arc::new(RpcRing::create(&h, 4).unwrap());
        let server = Arc::clone(&r);
        let t = std::thread::spawn(move || {
            // Serve exactly 100 requests, echoing func+1.
            let mut served = 0;
            while served < 100 {
                if let Some(i) = server.take_request() {
                    let f = server.slot(i).func.load(Ordering::Relaxed);
                    server.respond(i, ST_OK, f as u64 + 1);
                    served += 1;
                }
            }
        });
        for k in 0..100u32 {
            let i = loop {
                if let Some(i) = r.claim() {
                    break i;
                }
            };
            r.publish(i, k, 0, NO_SEAL, 0, 0);
            while !r.response_ready(i) {
                std::hint::spin_loop();
            }
            let (st, ret) = r.consume(i);
            assert_eq!(st, ST_OK);
            assert_eq!(ret, k as u64 + 1);
        }
        t.join().unwrap();
    }
}
