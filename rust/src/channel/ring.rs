//! The RPC slot ring — the shared-memory mailbox a connection's RPCs
//! travel through (paper §4.2, §5.8).
//!
//! One ring per connection lives in the connection heap. The client
//! claims a slot, writes the request descriptor (function id, argument
//! pointer — the argument *data* is already in the heap; this is the
//! zero-serialization trick), and publishes it with a release store:
//! the "doorbell" the server's busy-wait loop observes across the CXL
//! fabric. Responses flow back through the same slot.
//!
//! # Indexed MPMC protocol
//!
//! The ring is a sequence-numbered MPMC queue (crossbeam-style
//! tickets), not a scanned array. Two cache-padded cursors index it:
//!
//! * `head` — the **claim ticket** counter. A client thread reads the
//!   head ticket `t`, checks that slot `t & (n-1)` has sequence `t`
//!   (meaning the previous lap's occupant has been consumed), and
//!   CASes `head` to `t + 1`. One ticket CAS and one slot touch — no
//!   scan, no O(n) anything. If the slot's sequence is *behind* the
//!   ticket, the ring is full and the claim fails (callers park on
//!   the doorbell, they never corrupt state).
//! * `tail` — the **service cursor**. The server checks exactly one
//!   slot (`tail & (n-1)`): if it holds a published `REQUEST`, a CAS
//!   to `PROCESSING` takes it and the cursor advances. Requests are
//!   therefore served in publish order (FIFO), and `take_request` is
//!   one slot touch.
//!
//! Slot states still cycle EMPTY → CLAIMED → REQUEST → PROCESSING →
//! RESPONSE → EMPTY within a lap; the per-slot `seq` counter decides
//! *which lap* a ticket may enter the slot. `consume()` retires the
//! lap by bumping `seq` to `ticket + n`, which is what re-opens the
//! slot to the claim side one full ring-cycle later.
//!
//! Each `Slot` is `#[repr(align(64))]` so neighbouring doorbells never
//! share a cache line — on real CXL hardware a shared line would
//! ping-pong between hosts on every publish/poll pair, exactly the
//! coherence traffic §4.2/§5.8 set out to avoid.
//!
//! Two [`Doorbell`]s make the ring park-aware (§5.8's idle case):
//! `publish()` rings the request bell (shared with the channel's
//! server loop) and the response bell (inline-serving waiters drain
//! requests from inside their response wait, so peer publishes must
//! wake them); `respond()`/`consume()` ring the response bell that
//! claim- and completion-waiters park on. When nobody parks, a ring
//! is one atomic load.

use crate::error::{Result, RpcError};
use crate::memory::heap::Heap;
use crate::memory::pool::Charger;
use crate::util::CachePadded;
use crate::channel::waiter::Doorbell;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

pub const SLOT_EMPTY: u32 = 0;
pub const SLOT_CLAIMED: u32 = 1;
pub const SLOT_REQUEST: u32 = 2;
pub const SLOT_PROCESSING: u32 = 3;
pub const SLOT_RESPONSE: u32 = 4;

/// Call flags.
pub const FLAG_SEALED: u32 = 1 << 0;
pub const FLAG_SANDBOXED: u32 = 1 << 1;

/// No seal descriptor attached.
pub const NO_SEAL: u64 = u64::MAX;

/// One request/response slot, resident in shared memory.
///
/// Cache-line aligned *and* cache-line sized: two slots never share a
/// line, so one connection's doorbell store never invalidates a
/// neighbouring slot a different client thread is polling.
#[repr(C, align(64))]
pub struct Slot {
    /// Lap sequence (the MPMC ticket gate): equals the claim ticket
    /// that may enter this slot; bumped by `n` on consume.
    pub seq: AtomicU64,
    pub state: AtomicU32,
    pub func: AtomicU32,
    pub flags: AtomicU32,
    pub status: AtomicU32,
    /// Abandonment tombstone: set by a timed-out caller that will
    /// never consume; whoever loses the `swap` race (caller vs.
    /// `respond`) does nothing, the winner retires the lap. Keeps one
    /// slow RPC from wedging the whole sequence-gated ring.
    pub abandoned: AtomicU32,
    /// Seal descriptor index (NO_SEAL if none).
    pub seal_idx: AtomicU64,
    /// Argument pointer + byte length (a native shm pointer!). On an
    /// error response these double as the fault-detail words (sandbox
    /// window bounds), written by `respond_fault`.
    pub arg: AtomicU64,
    pub arg_len: AtomicU64,
    /// Return value (scalar or native shm pointer); fault address on
    /// sandbox-violation responses.
    pub ret: AtomicU64,
}

// Layout guards: future field additions must not silently reintroduce
// cache-line sharing between slots.
const _: () = assert!(
    std::mem::size_of::<Slot>() % 64 == 0,
    "Slot must stay a whole number of cache lines"
);
const _: () = assert!(std::mem::align_of::<Slot>() == 64, "Slot must stay cache-line aligned");

/// Status codes carried back in `Slot::status`.
pub const ST_OK: u32 = 0;
pub const ST_NO_HANDLER: u32 = 1;
pub const ST_SEAL_INVALID: u32 = 2;
pub const ST_SANDBOX_VIOLATION: u32 = 3;
pub const ST_HANDLER_ERROR: u32 = 4;
pub const ST_CLOSED: u32 = 5;

/// Decode an error response. `func` is the function id the request
/// carried; `ret`/`aux_lo`/`aux_hi` are the slot's return and
/// argument words, which error responses reuse to carry the remote
/// detail (fault address and sandbox window — see
/// [`RpcRing::respond_fault`]) instead of discarding it.
pub fn status_to_error(status: u32, func: u32, ret: u64, aux_lo: u64, aux_hi: u64) -> RpcError {
    match status {
        ST_NO_HANDLER => RpcError::NoSuchHandler(func),
        ST_SEAL_INVALID => RpcError::SealInvalid("receiver-side seal verification failed".into()),
        ST_SANDBOX_VIOLATION => RpcError::SandboxViolation {
            addr: ret as usize,
            lo: aux_lo as usize,
            hi: aux_hi as usize,
        },
        ST_CLOSED => RpcError::ConnectionClosed,
        _ => RpcError::Remote(format!("handler error (status {status}, func {func})")),
    }
}

/// The ring itself: `n` slots in the connection heap plus two local
/// ticket cursors (each on its own cache line).
pub struct RpcRing {
    base: usize,
    n: usize,
    mask: u64,
    charger: Arc<Charger>,
    /// One-way doorbell cost: CXL signal for in-rack connections, an
    /// RDMA message for DSM-fallback connections.
    signal_ns: u64,
    /// Claim tickets (client side).
    head: CachePadded<AtomicU64>,
    /// Service cursor (server side).
    tail: CachePadded<AtomicU64>,
    /// Rung by `publish()`; the channel's serving loop parks here.
    req_bell: Arc<Doorbell>,
    /// Rung by `respond()`/`consume()`; claim- and completion-waiters
    /// park here.
    resp_bell: Arc<Doorbell>,
}

impl RpcRing {
    pub fn create(heap: &Arc<Heap>, n: usize) -> Result<RpcRing> {
        let ns = heap.pool().charger.cost.cxl_signal_ns;
        Self::create_opts(heap, n, ns, None)
    }

    /// Ring whose doorbell models a different link (RDMA fallback).
    pub fn create_with_signal(heap: &Arc<Heap>, n: usize, signal_ns: u64) -> Result<RpcRing> {
        Self::create_opts(heap, n, signal_ns, None)
    }

    /// Full-control constructor: `req_bell` lets a channel share one
    /// request doorbell across all of its connections' rings, so a
    /// single parked listener wakes for any of them.
    pub fn create_opts(
        heap: &Arc<Heap>,
        n: usize,
        signal_ns: u64,
        req_bell: Option<Arc<Doorbell>>,
    ) -> Result<RpcRing> {
        let n = n.next_power_of_two().max(4);
        let bytes = n * std::mem::size_of::<Slot>();
        // Page-backed so the 64-byte slot alignment actually holds
        // (`alloc_bytes` only guarantees 16).
        let seg = heap.alloc_pages(bytes.div_ceil(heap.page_size()))?;
        let base = seg.base;
        debug_assert_eq!(base % 64, 0);
        unsafe { std::ptr::write_bytes(base as *mut u8, 0, bytes) };
        let ring = RpcRing {
            base,
            n,
            mask: (n - 1) as u64,
            charger: Arc::clone(&heap.pool().charger),
            signal_ns,
            head: CachePadded::new(AtomicU64::new(0)),
            tail: CachePadded::new(AtomicU64::new(0)),
            req_bell: req_bell.unwrap_or_else(Doorbell::new_arc),
            resp_bell: Doorbell::new_arc(),
        };
        // Open every slot to lap 0: slot i admits ticket i.
        for i in 0..n {
            ring.slot(i).seq.store(i as u64, Ordering::Relaxed);
        }
        Ok(ring)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// No in-flight work in any slot (the inverse of "occupied", not
    /// of capacity — see `quiescent`).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.quiescent()
    }

    /// The doorbell `publish()` rings (the serving side parks on it).
    #[inline]
    pub fn req_bell(&self) -> &Arc<Doorbell> {
        &self.req_bell
    }

    /// The doorbell `respond()`/`consume()` ring (claim- and
    /// completion-waiters park on it).
    #[inline]
    pub fn resp_bell(&self) -> &Arc<Doorbell> {
        &self.resp_bell
    }

    #[inline]
    pub fn slot(&self, i: usize) -> &Slot {
        debug_assert!(i < self.n);
        unsafe { &*((self.base + i * std::mem::size_of::<Slot>()) as *const Slot) }
    }

    /// Client side: claim a slot. One ticket CAS plus one slot touch —
    /// never a scan. `None` means the ring is full (every lap ticket
    /// up to `head` is still in flight); callers wait on the response
    /// doorbell, and the claim that would overwrite live state simply
    /// cannot happen (the sequence gate refuses it).
    pub fn claim(&self) -> Option<usize> {
        let mut t = self.head.load(Ordering::Relaxed);
        loop {
            let i = (t & self.mask) as usize;
            let s = self.slot(i);
            let seq = s.seq.load(Ordering::Acquire);
            match seq.cmp(&t) {
                std::cmp::Ordering::Equal => {
                    match self.head.compare_exchange_weak(
                        t,
                        t + 1,
                        Ordering::AcqRel,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => {
                            // The sequence gate made us the slot's
                            // only owner for this lap; a plain store
                            // suffices.
                            s.state.store(SLOT_CLAIMED, Ordering::Relaxed);
                            return Some(i);
                        }
                        Err(h) => t = h,
                    }
                }
                // Previous lap not yet consumed: full.
                std::cmp::Ordering::Less => return None,
                // Another claimer advanced head past us; catch up.
                std::cmp::Ordering::Greater => t = self.head.load(Ordering::Relaxed),
            }
        }
    }

    /// Client side: fill the claimed slot and ring the doorbell.
    #[allow(clippy::too_many_arguments)]
    pub fn publish(
        &self,
        i: usize,
        func: u32,
        flags: u32,
        seal_idx: u64,
        arg: usize,
        arg_len: usize,
    ) {
        let s = self.slot(i);
        s.func.store(func, Ordering::Relaxed);
        s.flags.store(flags, Ordering::Relaxed);
        s.seal_idx.store(seal_idx, Ordering::Relaxed);
        s.arg.store(arg as u64, Ordering::Relaxed);
        s.arg_len.store(arg_len as u64, Ordering::Relaxed);
        s.status.store(ST_OK, Ordering::Relaxed);
        // The doorbell: one cross-fabric signal (or RDMA message).
        self.charger.charge_ns(self.signal_ns);
        s.state.store(SLOT_REQUEST, Ordering::Release);
        self.req_bell.ring();
        // Inline-serving waiters (who drain requests from inside their
        // own response wait) park on the response bell — a peer's
        // publish must wake them too, or it stalls a full park slice.
        // Un-armed, this is one extra atomic load.
        self.resp_bell.ring();
    }

    /// Batched client side: fill a claimed slot *without* ringing or
    /// charging the doorbell. The batch submitter publishes a whole
    /// chunk of slots this way and then pays one cross-fabric signal
    /// via [`RpcRing::flush_publish`] — the amortization behind
    /// `Connection::invoke_batch`. The REQUEST store is still Release,
    /// so a server that happens to poll the slot sees a fully written
    /// descriptor; only the wakeup is deferred to the flush.
    #[allow(clippy::too_many_arguments)]
    pub fn publish_quiet(
        &self,
        i: usize,
        func: u32,
        flags: u32,
        seal_idx: u64,
        arg: usize,
        arg_len: usize,
    ) {
        let s = self.slot(i);
        s.func.store(func, Ordering::Relaxed);
        s.flags.store(flags, Ordering::Relaxed);
        s.seal_idx.store(seal_idx, Ordering::Relaxed);
        s.arg.store(arg as u64, Ordering::Relaxed);
        s.arg_len.store(arg_len as u64, Ordering::Relaxed);
        s.status.store(ST_OK, Ordering::Relaxed);
        s.state.store(SLOT_REQUEST, Ordering::Release);
    }

    /// One doorbell signal covering every preceding
    /// [`RpcRing::publish_quiet`]: k slot writes, one wakeup (and one
    /// charged cross-fabric signal) for the whole batch.
    pub fn flush_publish(&self) {
        self.charger.charge_ns(self.signal_ns);
        self.req_bell.ring();
        self.resp_bell.ring();
    }

    /// Claim tickets issued so far (the head cursor) — per-shard
    /// traffic telemetry for benches and tests.
    #[inline]
    pub fn claimed(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Requests taken so far (the service cursor).
    #[inline]
    pub fn taken(&self) -> u64 {
        self.tail.load(Ordering::Relaxed)
    }

    /// Server side: take the next pending request in publish order,
    /// transitioning it to PROCESSING. One slot touch at the service
    /// cursor — never a scan.
    pub fn take_request(&self) -> Option<usize> {
        loop {
            let t = self.tail.load(Ordering::Acquire);
            let i = (t & self.mask) as usize;
            let s = self.slot(i);
            if s.state.load(Ordering::Acquire) != SLOT_REQUEST {
                // Nothing published at the cursor (earlier tickets may
                // be claimed-but-unpublished; FIFO waits for them).
                return None;
            }
            if s.state
                .compare_exchange(
                    SLOT_REQUEST,
                    SLOT_PROCESSING,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                )
                .is_ok()
            {
                // Lap guard (ABA): between our tail read and the CAS
                // the slot may have completed an entire lap, making
                // the REQUEST we just took belong to ticket t+n, not
                // t. The slot's seq still equals its claim ticket
                // until consume, so a mismatch is detectable — put
                // the request back and retry from the fresh cursor.
                if s.seq.load(Ordering::Acquire) != t {
                    s.state.store(SLOT_REQUEST, Ordering::Release);
                    continue;
                }
                // We are ticket t's rightful taker, and only the
                // rightful taker advances t → t+1, so this cannot
                // race another advance of the same ticket.
                let _ = self.tail.compare_exchange(
                    t,
                    t + 1,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                );
                return Some(i);
            }
            // Lost the take race; the winner is advancing the cursor —
            // retry from the new tail.
        }
    }

    /// Retire a slot's lap: free the state machine and re-open the
    /// slot to the claim ticket one ring-cycle ahead. The EMPTY store
    /// must precede the Release seq store — the sequence store is
    /// what hands the slot to the next claimer. The EMPTY store is
    /// itself Release: `quiescent()`'s Acquire loads gate the
    /// argument-quarantine sweep, which needs a happens-before edge
    /// covering the handler's argument reads (they precede the
    /// retirer's access to the slot on every path). Callers ring the
    /// response bell afterwards (a freed slot may unblock a full-ring
    /// claim waiter).
    #[inline]
    fn retire_lap(&self, s: &Slot) {
        s.state.store(SLOT_EMPTY, Ordering::Release);
        let t = s.seq.load(Ordering::Relaxed);
        s.seq.store(t + self.n as u64, Ordering::Release);
    }

    /// Server side: write the response and signal the client. Returns
    /// `true` when the caller had abandoned the call (timeout) and
    /// this response retired the lap on its behalf — the response
    /// (including any `ret` the handler allocated) was discarded, so
    /// the serving layer must reclaim an owned reply buffer itself.
    pub fn respond(&self, i: usize, status: u32, ret: u64) -> bool {
        self.respond_inner(i, status, ret, false)
    }

    /// Batched server side: write the response *without* ringing or
    /// charging the response doorbell — the reply-side mirror of
    /// [`RpcRing::publish_quiet`]. A drain-k serving sweep answers up
    /// to k requests this way and then pays one cross-fabric signal
    /// via [`RpcRing::flush_respond`] for the whole sweep. The
    /// RESPONSE store is still Release (a polling caller that touches
    /// the slot sees a complete reply); only the wakeup is deferred —
    /// every quiet respond MUST be followed by a `flush_respond` on
    /// this ring before the server blocks, or a parked waiter stalls
    /// a park slice. The abandon-tombstone arbitration is identical
    /// to [`RpcRing::respond`]'s.
    pub fn respond_quiet(&self, i: usize, status: u32, ret: u64) -> bool {
        self.respond_inner(i, status, ret, true)
    }

    fn respond_inner(&self, i: usize, status: u32, ret: u64, quiet: bool) -> bool {
        let s = self.slot(i);
        s.ret.store(ret, Ordering::Relaxed);
        s.status.store(status, Ordering::Relaxed);
        if !quiet {
            self.charger.charge_ns(self.signal_ns);
        }
        s.state.store(SLOT_RESPONSE, Ordering::Release);
        // A timed-out caller will never consume: if it left its
        // tombstone, retire the lap on its behalf (the swap decides a
        // race with a concurrent `abandon` exactly once).
        let discarded = s.abandoned.swap(0, Ordering::SeqCst) == 1;
        if discarded {
            self.retire_lap(s);
        }
        if !quiet {
            self.resp_bell.ring();
        }
        discarded
    }

    /// One response-doorbell signal covering every preceding
    /// [`RpcRing::respond_quiet`] of a serving sweep: k reply writes,
    /// one wakeup (and one charged cross-fabric signal) for the whole
    /// sweep — the reply-side mirror of [`RpcRing::flush_publish`].
    /// Wakes completion waiters, claim waiters blocked on a lap a
    /// quiet respond retired, and inline-serving waiters alike; each
    /// re-scans its own slot (coalesced epochs are the waiter
    /// protocol's normal case, see `waiter.rs`).
    pub fn flush_respond(&self) {
        self.charger.charge_ns(self.signal_ns);
        self.resp_bell.ring();
    }

    /// [`RpcRing::flush_respond`] carrying the `post_respond` kill
    /// point: the sweep's replies are all written (state-wise the
    /// responses exist) and the signal cost is charged, but the proc
    /// dies on the doorbell threshold — the bell never rings, so every
    /// parked waiter sleeps through its own completed reply until the
    /// recovery sweep wakes it. Returns `true` when the kill fired
    /// (the serving layer must then stop, as a dead proc would).
    pub fn flush_respond_probed(&self) -> bool {
        self.charger.charge_ns(self.signal_ns);
        if crate::fault::should_die(crate::fault::KillPoint::PostRespond) {
            return true;
        }
        self.resp_bell.ring();
        false
    }

    /// Server side: error response carrying remote detail. The slot's
    /// `arg`/`arg_len` words are dead on a response, so they carry the
    /// auxiliary fault data (e.g. the sandbox window bounds) back to
    /// the client instead of being discarded. Returns `true` when the
    /// response was discarded into an abandoned lap (see
    /// [`RpcRing::respond`]).
    pub fn respond_fault(&self, i: usize, status: u32, ret: u64, aux_lo: u64, aux_hi: u64) -> bool {
        let s = self.slot(i);
        s.arg.store(aux_lo, Ordering::Relaxed);
        s.arg_len.store(aux_hi, Ordering::Relaxed);
        self.respond(i, status, ret)
    }

    /// Quiet variant of [`RpcRing::respond_fault`] (see
    /// [`RpcRing::respond_quiet`] for the flush contract).
    pub fn respond_fault_quiet(
        &self,
        i: usize,
        status: u32,
        ret: u64,
        aux_lo: u64,
        aux_hi: u64,
    ) -> bool {
        let s = self.slot(i);
        s.arg.store(aux_lo, Ordering::Relaxed);
        s.arg_len.store(aux_hi, Ordering::Relaxed);
        self.respond_quiet(i, status, ret)
    }

    /// Client side: is the response ready?
    #[inline]
    pub fn response_ready(&self, i: usize) -> bool {
        self.slot(i).state.load(Ordering::Acquire) == SLOT_RESPONSE
    }

    /// Client side: consume the response, freeing the slot.
    pub fn consume(&self, i: usize) -> (u32, u64) {
        let (status, ret, _, _) = self.consume_detail(i);
        (status, ret)
    }

    /// Like [`RpcRing::consume`], but also returns the auxiliary
    /// detail words (`arg`/`arg_len`) an error response may carry —
    /// see [`RpcRing::respond_fault`].
    pub fn consume_detail(&self, i: usize) -> (u32, u64, u64, u64) {
        let s = self.slot(i);
        let status = s.status.load(Ordering::Relaxed);
        let ret = s.ret.load(Ordering::Relaxed);
        let aux_lo = s.arg.load(Ordering::Relaxed);
        let aux_hi = s.arg_len.load(Ordering::Relaxed);
        self.retire_lap(s);
        self.resp_bell.ring();
        (status, ret, aux_lo, aux_hi)
    }

    /// Client side: give up on a slot that will never be consumed
    /// (response timeout, connection closed mid-call). Without this,
    /// one abandoned ticket would wedge the sequence-gated ring as
    /// soon as `head` wraps back to its slot. If the server already
    /// responded, the lap retires here and the discarded response's
    /// `(status, ret)` is returned so the caller can reclaim an owned
    /// reply buffer; otherwise a tombstone is left and `respond()`
    /// retires the lap when the (stale) response lands. The request
    /// may still be served in the meantime — same semantics as a late
    /// server pickup before this redesign.
    pub fn abandon(&self, i: usize) -> Option<(u32, u64)> {
        let s = self.slot(i);
        s.abandoned.store(1, Ordering::SeqCst);
        if s.state.load(Ordering::SeqCst) == SLOT_RESPONSE
            && s.abandoned.swap(0, Ordering::SeqCst) == 1
        {
            // Response already landed (and respond() lost or never saw
            // the tombstone race): retire the lap ourselves.
            let status = s.status.load(Ordering::Relaxed);
            let ret = s.ret.load(Ordering::Relaxed);
            self.retire_lap(s);
            self.resp_bell.ring();
            return Some((status, ret));
        }
        None
    }

    /// Any in-flight work? (used by drain/shutdown paths)
    pub fn quiescent(&self) -> bool {
        (0..self.n).all(|i| self.slot(i).state.load(Ordering::Acquire) == SLOT_EMPTY)
    }

    /// Failure plane: reap every slot a dead *client* proc stranded,
    /// so the sequence-gated ring can never wedge on tickets nobody
    /// will consume. Called by the surviving server (under the
    /// orchestrator's death notification) once the peer's lease has
    /// expired — the dead proc's threads are gone, so the only
    /// concurrent actors are this server's own workers, and every arm
    /// below arbitrates against them through the existing
    /// abandon-tombstone protocol:
    ///
    /// * `CLAIMED` — only a crash can strand a claimed-but-never-
    ///   published ticket; nobody else will ever touch it, retire the
    ///   lap directly.
    /// * `REQUEST` — race our own serving loop for it (CAS to
    ///   PROCESSING, same as `take_request`). Winning, tombstone +
    ///   self-respond `ST_CLOSED` retires the lap without running the
    ///   handler; losing, the worker that beat us holds it — leave a
    ///   tombstone so its `respond()` retires the lap.
    /// * `PROCESSING` — a worker is mid-serve; tombstone it
    ///   (`abandon`), its response retires the lap.
    /// * `RESPONSE` — already answered, never to be consumed;
    ///   `abandon` retires it immediately.
    ///
    /// Returns the number of stranded slots acted on. The service
    /// cursor is deliberately left behind: this connection's client is
    /// dead, no new request will ever arrive, and `take_request` at a
    /// reaped (now EMPTY) slot simply reports "nothing pending".
    pub fn reap_dead(&self) -> u64 {
        let mut reaped = 0u64;
        for i in 0..self.n {
            let s = self.slot(i);
            match s.state.load(Ordering::Acquire) {
                SLOT_CLAIMED => {
                    self.retire_lap(s);
                    self.resp_bell.ring();
                    reaped += 1;
                }
                SLOT_REQUEST => {
                    if s.state
                        .compare_exchange(
                            SLOT_REQUEST,
                            SLOT_PROCESSING,
                            Ordering::AcqRel,
                            Ordering::Relaxed,
                        )
                        .is_ok()
                    {
                        s.abandoned.store(1, Ordering::SeqCst);
                        self.respond(i, ST_CLOSED, 0);
                    } else {
                        self.abandon(i);
                    }
                    reaped += 1;
                }
                SLOT_PROCESSING | SLOT_RESPONSE => {
                    self.abandon(i);
                    reaped += 1;
                }
                _ => {}
            }
        }
        reaped
    }

    /// Failure plane, mirror image of [`RpcRing::reap_dead`]: the
    /// *server* proc died and the client is alive — clear the dead
    /// server's half of every in-flight call so the surviving client's
    /// waiters resolve and the slots a standby adopter inherits are
    /// clean. Run by the adoption/teardown path once the owner's lease
    /// has expired, before any resurrected listener starts; the only
    /// concurrent actors are live clients, and every arm arbitrates
    /// against them through the existing state CASes:
    ///
    /// * `REQUEST` — the dead server never picked it up. CAS to
    ///   PROCESSING (exactly the serving loop's `take_request` claim;
    ///   losing the CAS means a resurrected worker already has it) and
    ///   self-respond `ST_CLOSED` *without* a tombstone: the live
    ///   client consumes it, maps `ST_CLOSED` to `ConnectionClosed`,
    ///   and an idempotent retry republishes against the adopted
    ///   endpoint.
    /// * `PROCESSING` — the corpse died mid-serve (`mid_serve`,
    ///   `dsm_owner`); no handler will ever respond. Self-respond
    ///   `ST_CLOSED` the same way.
    /// * `RESPONSE` — the reply is complete (possibly written by a
    ///   `mid_respond`/`post_respond` victim that died before ringing)
    ///   — leave it; the flush below delivers the wakeup the corpse
    ///   never sent.
    /// * `CLAIMED` — a live client owns the ticket and will publish;
    ///   leave it alone.
    ///
    /// Always flushes the response doorbell once at the end, covering
    /// both the self-responses and any stranded quiet replies. Returns
    /// the number of slots answered on the corpse's behalf.
    pub fn reap_server_death(&self) -> u64 {
        let mut reaped = 0u64;
        for i in 0..self.n {
            let s = self.slot(i);
            match s.state.load(Ordering::Acquire) {
                SLOT_REQUEST => {
                    if s.state
                        .compare_exchange(
                            SLOT_REQUEST,
                            SLOT_PROCESSING,
                            Ordering::AcqRel,
                            Ordering::Relaxed,
                        )
                        .is_ok()
                    {
                        self.respond_quiet(i, ST_CLOSED, 0);
                        reaped += 1;
                    }
                }
                SLOT_PROCESSING => {
                    self.respond_quiet(i, ST_CLOSED, 0);
                    reaped += 1;
                }
                _ => {}
            }
        }
        self.flush_respond();
        reaped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::pool::Pool;

    fn ring() -> (Arc<Pool>, Arc<Heap>, RpcRing) {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "ring", 1 << 20).unwrap();
        let r = RpcRing::create(&heap, 8).unwrap();
        (pool, heap, r)
    }

    #[test]
    fn slot_layout_is_padded() {
        assert_eq!(std::mem::size_of::<Slot>(), 64);
        assert_eq!(std::mem::align_of::<Slot>(), 64);
        let (_p, _h, r) = ring();
        assert_eq!((r.slot(0) as *const Slot as usize) % 64, 0);
        let d = r.slot(1) as *const Slot as usize - r.slot(0) as *const Slot as usize;
        assert_eq!(d, 64, "adjacent slots must not share a cache line");
    }

    #[test]
    fn request_response_cycle() {
        let (_p, _h, r) = ring();
        let i = r.claim().unwrap();
        r.publish(i, 100, 0, NO_SEAL, 0xAB0, 64);
        let j = r.take_request().unwrap();
        assert_eq!(i, j);
        let s = r.slot(j);
        assert_eq!(s.func.load(Ordering::Relaxed), 100);
        assert_eq!(s.arg.load(Ordering::Relaxed), 0xAB0);
        r.respond(j, ST_OK, 42);
        assert!(r.response_ready(i));
        let (status, ret) = r.consume(i);
        assert_eq!((status, ret), (ST_OK, 42));
        assert!(r.quiescent());
    }

    #[test]
    fn is_empty_tracks_occupancy() {
        let (_p, _h, r) = ring();
        assert!(r.is_empty(), "fresh ring holds no work");
        let i = r.claim().unwrap();
        assert!(!r.is_empty(), "claimed slot counts as occupied");
        r.publish(i, 1, 0, NO_SEAL, 0, 0);
        assert!(!r.is_empty());
        let j = r.take_request().unwrap();
        r.respond(j, ST_OK, 0);
        assert!(!r.is_empty(), "unconsumed response still occupies its slot");
        r.consume(i);
        assert!(r.is_empty());
        assert_eq!(r.is_empty(), r.quiescent());
    }

    #[test]
    fn slots_exhaust_then_recycle() {
        let (_p, _h, r) = ring();
        let claimed: Vec<usize> = (0..r.len()).map(|_| r.claim().unwrap()).collect();
        assert_eq!(claimed.len(), 8);
        assert!(r.claim().is_none(), "ring full");
        // Respond to one and it becomes claimable again.
        r.publish(claimed[0], 1, 0, NO_SEAL, 0, 0);
        let i = r.take_request().unwrap();
        r.respond(i, ST_OK, 0);
        r.consume(i);
        assert!(r.claim().is_some());
    }

    #[test]
    fn full_ring_blocks_claims_without_corruption() {
        let (_p, _h, r) = ring();
        // Fill every slot, then hammer claim: it must refuse (not
        // recycle a live slot) every time.
        let claimed: Vec<usize> = (0..r.len()).map(|_| r.claim().unwrap()).collect();
        for _ in 0..100 {
            assert!(r.claim().is_none());
        }
        // Publish everything; the server drains in FIFO order and the
        // ring recycles cleanly.
        for (k, &i) in claimed.iter().enumerate() {
            r.publish(i, k as u32, 0, NO_SEAL, 0, 0);
        }
        for _ in 0..r.len() {
            let i = r.take_request().unwrap();
            let f = r.slot(i).func.load(Ordering::Relaxed);
            r.respond(i, ST_OK, f as u64);
        }
        for &i in &claimed {
            let (st, ret) = r.consume(i);
            assert_eq!(st, ST_OK);
            assert_eq!(ret, r.slot(i).func.load(Ordering::Relaxed) as u64);
        }
        assert!(r.quiescent());
        assert!(r.claim().is_some(), "drained ring claims again");
    }

    #[test]
    fn wraparound_many_laps_single_thread() {
        let (_p, _h, r) = ring();
        // 10 laps of the 8-slot ring through the full lifecycle.
        for k in 0..80u32 {
            let i = r.claim().expect("never full with one in flight");
            r.publish(i, k, 0, NO_SEAL, 0, 0);
            let j = r.take_request().unwrap();
            assert_eq!(i, j, "single-stream FIFO serves the slot just published");
            r.respond(j, ST_OK, k as u64 * 3);
            let (st, ret) = r.consume(i);
            assert_eq!((st, ret), (ST_OK, k as u64 * 3));
        }
        assert!(r.quiescent());
    }

    #[test]
    fn cross_thread_rpc() {
        let (_p, h, _unused) = ring();
        let r = Arc::new(RpcRing::create(&h, 4).unwrap());
        let server = Arc::clone(&r);
        let t = std::thread::spawn(move || {
            // Serve exactly 100 requests, echoing func+1.
            let mut served = 0;
            while served < 100 {
                if let Some(i) = server.take_request() {
                    let f = server.slot(i).func.load(Ordering::Relaxed);
                    server.respond(i, ST_OK, f as u64 + 1);
                    served += 1;
                }
            }
        });
        for k in 0..100u32 {
            let i = loop {
                if let Some(i) = r.claim() {
                    break i;
                }
            };
            r.publish(i, k, 0, NO_SEAL, 0, 0);
            while !r.response_ready(i) {
                std::hint::spin_loop();
            }
            let (st, ret) = r.consume(i);
            assert_eq!(st, ST_OK);
            assert_eq!(ret, k as u64 + 1);
        }
        t.join().unwrap();
    }

    /// N client threads × M calls with M·N ≫ ring size: every response
    /// must reach exactly the caller that published its request — no
    /// lost, duplicated, or cross-wired responses across laps.
    #[test]
    fn contended_wraparound_no_lost_or_duplicated_responses() {
        const THREADS: u64 = 4;
        const CALLS: u64 = 64; // 256 calls through an 8-slot ring
        let (_p, h, _unused) = ring();
        let r = Arc::new(RpcRing::create(&h, 8).unwrap());

        let server = Arc::clone(&r);
        let srv = std::thread::spawn(move || {
            let mut served = 0u64;
            while served < THREADS * CALLS {
                if let Some(i) = server.take_request() {
                    let f = server.slot(i).func.load(Ordering::Relaxed);
                    // Echo a value derived from the request so the
                    // caller can detect cross-wired responses.
                    server.respond(i, ST_OK, f as u64 * 7 + 1);
                    served += 1;
                } else {
                    std::hint::spin_loop();
                }
            }
        });

        let mut clients = Vec::new();
        for tid in 0..THREADS {
            let r = Arc::clone(&r);
            clients.push(std::thread::spawn(move || {
                for k in 0..CALLS {
                    let func = (tid * CALLS + k) as u32; // globally unique
                    let i = loop {
                        if let Some(i) = r.claim() {
                            break i;
                        }
                        std::hint::spin_loop();
                    };
                    r.publish(i, func, 0, NO_SEAL, 0, 0);
                    while !r.response_ready(i) {
                        std::hint::spin_loop();
                    }
                    let (st, ret) = r.consume(i);
                    assert_eq!(st, ST_OK);
                    assert_eq!(
                        ret,
                        func as u64 * 7 + 1,
                        "thread {tid} call {k}: response cross-wired"
                    );
                }
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
        srv.join().unwrap();
        assert!(r.quiescent(), "all laps retired");
        // The cursors agree on the total traffic.
        assert_eq!(r.head.load(Ordering::Relaxed), THREADS * CALLS);
        assert_eq!(r.tail.load(Ordering::Relaxed), THREADS * CALLS);
    }

    /// A timed-out caller never consumes; its tombstone must let the
    /// ring keep cycling instead of wedging once `head` wraps back to
    /// the abandoned slot (regression for the sequence-gate redesign).
    #[test]
    fn abandoned_slots_are_reclaimed_not_wedged() {
        let (_p, _h, r) = ring();
        // 3+ full laps of an 8-slot ring, abandoning every call
        // before the server picks it up: the late response must
        // retire each lap.
        for k in 0..28u32 {
            let i = r.claim().unwrap_or_else(|| panic!("ring wedged at call {k}"));
            r.publish(i, k, 0, NO_SEAL, 0, 0);
            let discarded = r.abandon(i); // caller gave up while still queued
            assert!(discarded.is_none(), "no response landed yet");
            let j = r.take_request().expect("abandoned request still served");
            assert!(r.respond(j, ST_OK, 0), "respond() must retire the abandoned lap");
        }
        assert!(r.quiescent(), "late responses retired every abandoned lap");

        // Abandon *after* the response landed: the caller retires it
        // and receives the discarded response for reply reclamation.
        let i = r.claim().unwrap();
        r.publish(i, 1, 0, NO_SEAL, 0, 0);
        let j = r.take_request().unwrap();
        assert!(!r.respond(j, ST_OK, 77), "no tombstone yet: normal response");
        assert_eq!(r.abandon(i), Some((ST_OK, 77)), "caller gets the orphaned reply");
        assert!(r.quiescent());
        assert!(r.claim().is_some(), "ring still cycles after both abandon orders");
    }

    /// Batched submission at the ring level: k quiet publishes, one
    /// flush — the server sees every descriptor, FIFO order holds,
    /// and each caller still gets exactly its own response.
    #[test]
    fn quiet_publish_then_flush_serves_whole_batch() {
        let (_p, _h, r) = ring();
        let slots: Vec<usize> = (0..4).map(|_| r.claim().unwrap()).collect();
        for (k, &i) in slots.iter().enumerate() {
            r.publish_quiet(i, k as u32, 0, NO_SEAL, 0, 0);
        }
        r.flush_publish();
        for _ in 0..slots.len() {
            let j = r.take_request().expect("flushed batch must be fully visible");
            let f = r.slot(j).func.load(Ordering::Relaxed);
            r.respond(j, ST_OK, f as u64 + 10);
        }
        for (k, &i) in slots.iter().enumerate() {
            let (st, ret) = r.consume(i);
            assert_eq!((st, ret), (ST_OK, k as u64 + 10), "batch member {k} cross-wired");
        }
        assert!(r.quiescent());
        assert_eq!(r.claimed(), 4);
        assert_eq!(r.taken(), 4);
    }

    /// Batched replies at the ring level: k quiet responds, one
    /// flush — every caller consumes exactly its own reply, and the
    /// charged doorbell accounting drops from k signals to one.
    #[test]
    fn quiet_respond_then_flush_answers_whole_sweep() {
        let (_p, _h, r) = ring();
        let slots: Vec<usize> = (0..4).map(|_| r.claim().unwrap()).collect();
        for (k, &i) in slots.iter().enumerate() {
            r.publish_quiet(i, k as u32, 0, NO_SEAL, 0, 0);
        }
        r.flush_publish();
        let charged_before = r.charger.total_charged_ns();
        let mut taken = Vec::new();
        for _ in 0..slots.len() {
            let j = r.take_request().unwrap();
            let f = r.slot(j).func.load(Ordering::Relaxed);
            assert!(!r.respond_quiet(j, ST_OK, f as u64 + 5), "no tombstones here");
            taken.push(j);
        }
        r.flush_respond();
        let charged = r.charger.total_charged_ns() - charged_before;
        assert_eq!(
            charged,
            r.signal_ns,
            "4 quiet responds + 1 flush must charge exactly one doorbell signal"
        );
        for (k, &i) in slots.iter().enumerate() {
            assert!(r.response_ready(i), "quiet RESPONSE store must be visible pre-flush");
            let (st, ret) = r.consume(i);
            assert_eq!((st, ret), (ST_OK, k as u64 + 5), "sweep member {k} cross-wired");
        }
        assert!(r.quiescent());
    }

    /// The abandon race is arbitration-identical under quiet responds:
    /// whichever of {abandoning caller, quiet respond} wins the
    /// tombstone swap retires the lap exactly once, and a wholly
    /// quiet sweep still recycles every abandoned slot.
    #[test]
    fn quiet_respond_retires_abandoned_laps() {
        let (_p, _h, r) = ring();
        for k in 0..24u32 {
            let i = r.claim().unwrap_or_else(|| panic!("ring wedged at call {k}"));
            r.publish(i, k, 0, NO_SEAL, 0, 0);
            assert!(r.abandon(i).is_none(), "no response landed yet");
            let j = r.take_request().expect("abandoned request still served");
            assert!(r.respond_quiet(j, ST_OK, 0), "quiet respond must retire the abandoned lap");
        }
        r.flush_respond();
        assert!(r.quiescent(), "quiet responses retired every abandoned lap");
        assert!(r.claim().is_some(), "ring still cycles after a fully-quiet abandon storm");
    }

    /// A parked waiter must wake from the sweep's single coalesced
    /// flush, not from per-reply rings that no longer happen.
    #[test]
    fn parked_waiter_wakes_on_flush_respond() {
        use crate::channel::waiter::{wait_on, SleepPolicy, WaitOutcome};
        let (_p, h, _unused) = ring();
        let r = Arc::new(RpcRing::create(&h, 4).unwrap());
        let i = r.claim().unwrap();
        r.publish(i, 1, 0, NO_SEAL, 0, 0);
        let server = Arc::clone(&r);
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            let j = server.take_request().unwrap();
            server.respond_quiet(j, ST_OK, 9);
            server.flush_respond();
        });
        let out = wait_on(
            SleepPolicy::Park,
            std::time::Duration::from_secs(5),
            None,
            Some(r.resp_bell()),
            || r.response_ready(i),
        );
        assert_eq!(out, WaitOutcome::Ready, "flush_respond must wake the parked waiter");
        assert_eq!(r.consume(i), (ST_OK, 9));
        t.join().unwrap();
    }

    /// Failure plane: a crashed client strands slots in every live
    /// state; `reap_dead` must retire each one and leave the ring
    /// quiescent so the surviving server never wedges on them.
    #[test]
    fn reap_dead_retires_every_stranded_state() {
        let (_p, _h, r) = ring();
        // PROCESSING: taken by a (surviving) worker, not yet answered.
        let req = r.claim().unwrap();
        r.publish(req, 1, 0, NO_SEAL, 0, 0);
        let proc_slot = r.take_request().unwrap();
        assert_eq!(proc_slot, req, "FIFO serves the published slot");
        // RESPONSE: answered, never consumed.
        let req2 = r.claim().unwrap();
        r.publish(req2, 2, 0, NO_SEAL, 0, 0);
        let resp = r.take_request().unwrap();
        assert_eq!(resp, req2);
        r.respond(resp, ST_OK, 9);
        // REQUEST: published, never taken.
        let req3 = r.claim().unwrap();
        r.publish(req3, 3, 0, NO_SEAL, 0, 0);
        // CLAIMED: crashed after claim, before publish.
        let _claimed = r.claim().unwrap();

        assert_eq!(r.reap_dead(), 4, "claimed+request+processing+response slots reaped");
        // The PROCESSING slot retires when the worker's late response
        // hits the tombstone reap_dead left behind.
        assert!(r.respond(proc_slot, ST_OK, 0), "tombstone retires the mid-serve lap");
        assert!(r.quiescent(), "no stranded lap survives the reap");
        // The ring still cycles: reaped laps handed their slots to the
        // next lap's tickets.
        assert!(r.claim().is_some());
        assert_eq!(r.reap_dead(), 1, "the fresh claim is itself reapable");
        assert!(r.quiescent());
    }

    #[test]
    fn quiet_fault_detail_roundtrip() {
        let (_p, _h, r) = ring();
        let i = r.claim().unwrap();
        r.publish(i, 9, 0, NO_SEAL, 0xF00, 8);
        let j = r.take_request().unwrap();
        r.respond_fault_quiet(j, ST_SANDBOX_VIOLATION, 0xBAD, 0x1000, 0x2000);
        r.flush_respond();
        let (st, ret, lo, hi) = r.consume_detail(i);
        assert_eq!(
            status_to_error(st, 9, ret, lo, hi),
            RpcError::SandboxViolation { addr: 0xBAD, lo: 0x1000, hi: 0x2000 },
            "fault detail must survive the quiet reply path"
        );
    }

    #[test]
    fn error_detail_roundtrip() {
        let (_p, _h, r) = ring();
        let i = r.claim().unwrap();
        r.publish(i, 9, 0, NO_SEAL, 0xF00, 8);
        let j = r.take_request().unwrap();
        r.respond_fault(j, ST_SANDBOX_VIOLATION, 0xBAD, 0x1000, 0x2000);
        let (st, ret, lo, hi) = r.consume_detail(i);
        assert_eq!(st, ST_SANDBOX_VIOLATION);
        let e = status_to_error(st, 9, ret, lo, hi);
        assert_eq!(
            e,
            RpcError::SandboxViolation { addr: 0xBAD, lo: 0x1000, hi: 0x2000 },
            "fault detail must survive the wire"
        );
        let e = status_to_error(ST_NO_HANDLER, 42, 0, 0, 0);
        assert_eq!(e, RpcError::NoSuchHandler(42), "func id must survive the wire");
    }
}
