//! Daemon-wide channel worker pool: k workers serving every pooled
//! channel on a host through one [`WaiterTree`].
//!
//! The dedicated-listener model (`RpcServer::spawn_listeners`) ties
//! thread count to channel count — fine for a benchmark, fatal for
//! "one daemon, tens of thousands of channels". Here every pooled
//! connection's per-shard request bells register into a shared
//! [`WaiterTree`]; a pool of at most [`MAX_POOL_WORKERS`] workers
//! parks on the tree's **root** doorbell and sweeps only the slots
//! that actually rang. Worker count is decoupled from channel count:
//! k workers serve 10k+ channels, waking only for ready ones.
//!
//! Pools are keyed per `(orchestrator, host)` — the unit the paper's
//! daemon mediates — in a process-wide registry, so every
//! `RpcServer::open` on one simulated host shares the same pool no
//! matter how many `Daemon` values it constructs.
//!
//! ## Why leftovers can't starve (the budget re-kick)
//!
//! A sweep serves at most the server's drain budget per shard, but the
//! publish rings that announced those requests were consumed when
//! `pop_ready` swapped the dirty mask out. If the budget was exhausted
//! with requests still pending, nobody would ever ring again for them
//! — so the worker re-kicks the shard bit into the tree whenever it
//! drained its full budget. At worst this costs one spurious re-sweep
//! (the "maybe more" bit finds an empty ring); in exchange a flooded
//! shard is rescheduled fairly behind every other ready slot instead
//! of being drained to exhaustion while its neighbours wait.

use super::waiter::{TreeSlot, WaiterTree, LOAD, PARK_SLICE_US};
use super::{ConnShared, ServerCore};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::time::Duration;

/// Hard ceiling on workers per pool — the acceptance bar is "k ≤ 8
/// workers serve ≥ 1k channels", and a larger pool only re-introduces
/// the thread-per-channel scaling this layer exists to kill.
pub const MAX_POOL_WORKERS: usize = 8;

/// What a tree slot maps back to: either a channel's accept queue or
/// one adopted connection. `Weak<ServerCore>` breaks the cycle — the
/// core holds the pool, the pool must not hold the core.
enum Entry {
    /// Slot for a channel's accept path: bit 0 rings when `connect`
    /// enqueues a new connection on `core.accepting`.
    Accept { core: Weak<ServerCore>, slot: Arc<TreeSlot> },
    /// Slot for one adopted connection: bit i rings when shard i
    /// publishes a request.
    Conn {
        core: Weak<ServerCore>,
        conn: Arc<ConnShared>,
        slot: Arc<TreeSlot>,
    },
}

impl Clone for Entry {
    fn clone(&self) -> Entry {
        match self {
            Entry::Accept { core, slot } => Entry::Accept {
                core: Weak::clone(core),
                slot: Arc::clone(slot),
            },
            Entry::Conn { core, conn, slot } => Entry::Conn {
                core: Weak::clone(core),
                conn: Arc::clone(conn),
                slot: Arc::clone(slot),
            },
        }
    }
}

/// Shared pool state: worker threads hold this (not the
/// [`WorkerPool`]), so dropping the last pool handle can stop and
/// join them.
struct PoolInner {
    tree: Arc<WaiterTree>,
    /// Tree-slot id → what to serve when it pops ready.
    entries: RwLock<HashMap<usize, Entry>>,
    stop: AtomicBool,
    nworkers: AtomicUsize,
    /// High-water mark of worker counts ever asked for — what `heal`
    /// restores the pool to after a crash thinned it.
    want: AtomicUsize,
    /// Workers lost to injected crashes ([`KillPoint::ParkedWorker`])
    /// since the last heal.
    dead: AtomicUsize,
}

/// A daemon-wide serving pool (see module docs). Obtained through
/// `Daemon::worker_pool`; shared by every pooled channel of one
/// simulated host.
pub struct WorkerPool {
    inner: Arc<PoolInner>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// One heal hook per pool, no matter how many channels open on it.
    heal_registered: AtomicBool,
}

/// Process-wide pool registry: `(orchestrator ptr, host)` → pool. A
/// linear Vec (not a map) so the static is const-constructible; the
/// registry holds weaks and prunes dead entries on every lookup, so a
/// torn-down rack's pools don't leak.
static POOLS: Mutex<Vec<((usize, u32), Weak<WorkerPool>)>> = Mutex::new(Vec::new());

impl WorkerPool {
    /// The pool for `key`, creating it if absent (or if a previous
    /// pool for the key was dropped), and growing it to at least
    /// `workers` threads (clamped to [`MAX_POOL_WORKERS`]).
    pub fn for_key(key: (usize, u32), workers: usize) -> Arc<WorkerPool> {
        let mut reg = POOLS.lock().unwrap();
        reg.retain(|(_, w)| w.strong_count() > 0);
        if let Some((_, w)) = reg.iter().find(|(k, _)| *k == key) {
            if let Some(pool) = w.upgrade() {
                pool.ensure_workers(workers);
                return pool;
            }
        }
        let pool = Arc::new(WorkerPool {
            inner: Arc::new(PoolInner {
                tree: WaiterTree::new_arc(),
                entries: RwLock::new(HashMap::new()),
                stop: AtomicBool::new(false),
                nworkers: AtomicUsize::new(0),
                want: AtomicUsize::new(0),
                dead: AtomicUsize::new(0),
            }),
            workers: Mutex::new(Vec::new()),
            heal_registered: AtomicBool::new(false),
        });
        reg.push((key, Arc::downgrade(&pool)));
        pool.ensure_workers(workers);
        pool
    }

    /// Grow the pool to at least `k` workers (never shrinks; never
    /// exceeds [`MAX_POOL_WORKERS`]). Channels asking for different
    /// sizes share the high-water mark.
    pub fn ensure_workers(&self, k: usize) {
        let want = k.clamp(1, MAX_POOL_WORKERS);
        self.inner.want.fetch_max(want, Ordering::AcqRel);
        loop {
            let cur = self.inner.nworkers.load(Ordering::Acquire);
            if cur >= want {
                return;
            }
            if self
                .inner
                .nworkers
                .compare_exchange(cur, cur + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue;
            }
            let inner = Arc::clone(&self.inner);
            let handle = std::thread::spawn(move || worker_loop(inner));
            self.workers.lock().unwrap().push(handle);
        }
    }

    /// Current worker count (tests/telemetry).
    pub fn worker_count(&self) -> usize {
        self.inner.nworkers.load(Ordering::Acquire)
    }

    /// Live tree slots (tests/telemetry): adopted connections plus
    /// accept slots.
    pub fn slot_count(&self) -> usize {
        self.inner.tree.slot_count()
    }

    /// Register a channel's accept path: the accept slot pops ready
    /// whenever `connect` rings the channel bell, and serving it
    /// adopts every queued connection into the tree.
    pub fn register_accept(&self, core: &Arc<ServerCore>) {
        let slot = self.inner.tree.register();
        self.inner.tree.attach(&core.bell, &slot, 0);
        self.inner.entries.write().unwrap().insert(
            slot.id(),
            Entry::Accept { core: Arc::downgrade(core), slot: Arc::clone(&slot) },
        );
        // Cover connections that queued before the attach landed.
        self.inner.tree.kick(&slot, 1);
    }

    /// Adopt one accepted connection: register a slot, attach every
    /// shard's request bell at its shard bit, then force-mark all
    /// shards ready — requests published before the bells were
    /// attached never rang the tree, and the kick guarantees the
    /// first sweep finds them anyway. `pub(crate)`: channel
    /// resurrection re-attaches a dead owner's surviving connections
    /// to the standby's core through this same path.
    pub(crate) fn adopt(&self, core: &Arc<ServerCore>, conn: Arc<ConnShared>) {
        let slot = self.inner.tree.register();
        for (i, sh) in conn.shards.iter().enumerate().take(64) {
            self.inner.tree.attach(sh.ring.req_bell(), &slot, i as u32);
        }
        let n = conn.shards.len();
        let mask = if n >= 64 { u64::MAX } else { (1u64 << n) - 1 };
        // Entry must be visible before the kick: a worker may pop the
        // slot the instant the kick enqueues it.
        self.inner.entries.write().unwrap().insert(
            slot.id(),
            Entry::Conn { core: Arc::downgrade(core), conn, slot: Arc::clone(&slot) },
        );
        self.inner.tree.kick(&slot, mask);
    }

    /// Respawn workers lost to injected crashes, back up to the
    /// high-water mark. Returns how many were missing (the healed
    /// count the orchestrator books as recoveries); 0 when the pool
    /// is whole.
    pub fn heal(&self) -> u64 {
        let dead = self.inner.dead.swap(0, Ordering::AcqRel);
        if dead == 0 {
            return 0;
        }
        self.ensure_workers(self.inner.want.load(Ordering::Acquire));
        dead as u64
    }

    /// Hook `heal` into the orchestrator's recovery sweep (phase 4 of
    /// `Orchestrator::tick`). Idempotent per pool; the hook holds a
    /// `Weak` so a dropped pool prunes itself from the sweep.
    pub fn register_heal(self: &Arc<Self>, orch: &crate::orchestrator::Orchestrator) {
        if self.heal_registered.swap(true, Ordering::AcqRel) {
            return;
        }
        let w = Arc::downgrade(self);
        orch.on_tick(Box::new(move || w.upgrade().map(|p| p.heal())));
    }

    /// Drop every slot belonging to `core` (channel teardown).
    /// Idempotent; also called when a sweep finds the core gone.
    pub fn forget_core(&self, core: &Arc<ServerCore>) {
        let target = Arc::as_ptr(core) as usize;
        let mut entries = self.inner.entries.write().unwrap();
        entries.retain(|_, e| {
            let (w, slot) = match e {
                Entry::Accept { core, slot } => (core, slot),
                Entry::Conn { core, slot, .. } => (core, slot),
            };
            let mine = w.as_ptr() as usize == target;
            if mine {
                self.inner.tree.deregister(slot);
            }
            !mine
        });
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.inner.stop.store(true, Ordering::Release);
        self.inner.tree.root().ring();
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// One pool worker: arm the root once for the thread's lifetime,
/// sweep ready slots, park on the root when a full sweep (queue +
/// safety-net scan) made no progress. The lost-wakeup argument is the
/// [`WaiterTree`]'s: any member ring between the epoch snapshot and
/// the park bumps the root epoch, so the park returns immediately.
fn worker_loop(inner: Arc<PoolInner>) {
    let root = Arc::clone(inner.tree.root());
    root.arm();
    LOAD.enter();
    while !inner.stop.load(Ordering::Acquire) {
        let seen = root.epoch();
        let mut progress = false;
        while let Some((sid, mask)) = inner.tree.pop_ready() {
            progress |= serve_slot(&inner, sid, mask);
            if inner.stop.load(Ordering::Acquire) {
                break;
            }
        }
        if !progress {
            // Idle safety net: any dirty slot the queue somehow
            // missed (or that a sibling re-kicked mid-pop) gets one
            // more look before this worker parks.
            for (sid, mask) in inner.tree.scan_ready() {
                progress |= serve_slot(&inner, sid, mask);
            }
        }
        if !progress && !inner.stop.load(Ordering::Acquire) {
            // Kill point: a pool worker dies at its park decision.
            // The thread just vanishes (the OS reclaims its stack;
            // LOAD/arm bookkeeping is the simulated equivalent) and
            // the pool serves thin until the recovery sweep's heal
            // hook respawns to the high-water mark.
            if crate::fault::should_die(crate::fault::KillPoint::ParkedWorker) {
                LOAD.exit();
                root.disarm();
                inner.nworkers.fetch_sub(1, Ordering::AcqRel);
                inner.dead.fetch_add(1, Ordering::AcqRel);
                return;
            }
            LOAD.exit();
            root.wait_past(seen, Duration::from_micros(PARK_SLICE_US));
            LOAD.enter();
        }
    }
    LOAD.exit();
    root.disarm();
}

/// Serve one ready tree slot. Returns whether any request was
/// actually drained (the worker's park decision).
fn serve_slot(inner: &Arc<PoolInner>, sid: usize, mask: u64) -> bool {
    let entry = match inner.entries.read().unwrap().get(&sid) {
        Some(e) => e.clone(),
        None => return false,
    };
    match entry {
        Entry::Accept { core, slot } => {
            let core = match core.upgrade() {
                Some(c) => c,
                None => {
                    drop_slot(inner, sid, &slot);
                    return false;
                }
            };
            if core.stop.load(Ordering::Acquire) {
                drop_slot(inner, sid, &slot);
                return false;
            }
            let adopted = core.adopt_pending();
            let any = !adopted.is_empty();
            let pool = match core.pool.as_ref() {
                Some(p) => Arc::clone(p),
                None => {
                    // A core that lost its pool can never serve this
                    // slot — leaving the entry would re-ring forever.
                    drop_slot(inner, sid, &slot);
                    return false;
                }
            };
            for conn in adopted {
                pool.adopt(&core, conn);
            }
            any
        }
        Entry::Conn { core, conn, slot } => {
            let core = match core.upgrade() {
                Some(c) => c,
                None => {
                    drop_slot(inner, sid, &slot);
                    return false;
                }
            };
            if conn.closed() || core.stop.load(Ordering::Acquire) {
                drop_slot(inner, sid, &slot);
                return false;
            }
            // Shed connections get a minimal budget: they stay live
            // but overload degrades them first, by policy.
            let budget = if conn.is_shed() { 1 } else { core.opts.drain_k.max(1) };
            let mut any = false;
            crate::simproc::with_identity(core.env.proc, core.env.host, || {
                let mut m = mask;
                while m != 0 {
                    let si = m.trailing_zeros() as usize;
                    m &= m - 1;
                    if si >= conn.shards.len() {
                        continue;
                    }
                    let drained = core.serve_shard(&conn, si, budget);
                    any |= drained > 0;
                    if drained == budget {
                        // Budget exhausted with possibly more pending
                        // whose publish rings were already consumed —
                        // reschedule the shard (see module docs).
                        inner.tree.kick(&slot, 1u64 << si);
                    }
                }
            });
            any
        }
    }
}

/// Remove a dead slot (core gone, channel stopped, connection
/// closed): deregister from the tree and drop the entry.
fn drop_slot(inner: &Arc<PoolInner>, sid: usize, slot: &Arc<TreeSlot>) {
    inner.tree.deregister(slot);
    inner.entries.write().unwrap().remove(&sid);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A slot whose core can no longer be upgraded must be *dropped*
    /// by the sweep, not skipped: a skipped entry stays registered and
    /// every later kick re-queues it, so the stale slot would spin the
    /// pool forever.
    #[test]
    fn serve_slot_drops_entry_when_core_gone() {
        let inner = Arc::new(PoolInner {
            tree: WaiterTree::new_arc(),
            entries: RwLock::new(HashMap::new()),
            stop: AtomicBool::new(false),
            nworkers: AtomicUsize::new(0),
            want: AtomicUsize::new(0),
            dead: AtomicUsize::new(0),
        });
        let slot = inner.tree.register();
        inner.entries.write().unwrap().insert(
            slot.id(),
            Entry::Accept { core: Weak::new(), slot: Arc::clone(&slot) },
        );
        inner.tree.kick(&slot, 1);
        let mut served = 0;
        while let Some((sid, mask)) = inner.tree.pop_ready() {
            assert!(!serve_slot(&inner, sid, mask));
            served += 1;
        }
        assert!(served >= 1, "kicked slot must have popped ready");
        assert_eq!(inner.tree.slot_count(), 0, "stale slot deregistered");
        assert!(inner.entries.read().unwrap().is_empty(), "entry dropped");
    }
}
