//! The composable call surface: `CallOpts`, `CallArg`, and `Reply`.
//!
//! The paper presents sealing (§4.5) and sandboxing (§4.4) as
//! *orthogonal, per-RPC* choices. `CallOpts` encodes that directly: a
//! builder whose `sealed` / `sandboxed` / `timeout` / `transport`
//! knobs compose freely, replacing the old fixed matrix of
//! `call` / `call_sealed` / `call_sandboxed` / `call_secure` methods
//! with one `Connection::invoke` core.
//!
//! `Reply<R>` is the typed view of a pointer-returning RPC: it borrows
//! the connection (so it cannot outlive the heap the pointer targets)
//! and decodes the return address through the checked-MMU path instead
//! of leaving callers to cast raw `u64`s.

use crate::error::{Result, RpcError};
use crate::memory::pod::Pod;
use crate::memory::ptr::{ShmPtr, ShmView};
use crate::memory::scope::Scope;
use std::marker::PhantomData;
use std::time::Duration;

use super::{Connection, TransportSel};

/// An RPC argument: a native shared-memory pointer plus its byte
/// length. Built from whatever the caller has on hand:
///
/// * `()` — no argument (`addr = 0`);
/// * `ShmPtr<T>` — length inferred from `T`;
/// * `(addr, len)` — the raw escape hatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CallArg {
    pub addr: usize,
    pub len: usize,
}

impl CallArg {
    /// The empty argument (no shared-memory payload).
    pub const NONE: CallArg = CallArg { addr: 0, len: 0 };

    pub fn new(addr: usize, len: usize) -> CallArg {
        CallArg { addr, len }
    }
}

impl From<()> for CallArg {
    fn from(_: ()) -> CallArg {
        CallArg::NONE
    }
}

impl From<(usize, usize)> for CallArg {
    fn from((addr, len): (usize, usize)) -> CallArg {
        CallArg { addr, len }
    }
}

impl<T: Pod> From<ShmPtr<T>> for CallArg {
    fn from(p: ShmPtr<T>) -> CallArg {
        CallArg { addr: p.addr(), len: std::mem::size_of::<T>() }
    }
}

/// Per-call options. All knobs are orthogonal; any combination is
/// valid (the paper's "RPCool (Secure)" configuration is simply
/// `sealed + sandboxed`).
///
/// ```ignore
/// conn.invoke(F_PUT, arg, CallOpts::new())?;                   // plain
/// conn.invoke(F_PUT, arg, CallOpts::new().sealed(&scope))?;    // §4.5
/// conn.invoke(F_PUT, arg, CallOpts::new().sandboxed())?;       // §4.4
/// conn.invoke(F_PUT, arg, CallOpts::secure(&scope))?;          // both
/// ```
#[derive(Clone, Copy, Default)]
pub struct CallOpts<'s> {
    pub(super) seal: Option<&'s Scope>,
    pub(super) sandbox: bool,
    pub(super) timeout: Option<Duration>,
    pub(super) transport: TransportSel,
}

impl<'s> CallOpts<'s> {
    /// Plain call: no seal, no sandbox, connection-default timeout,
    /// whatever transport the connection negotiated.
    pub fn new() -> CallOpts<'s> {
        CallOpts::default()
    }

    /// The paper's "RPCool (Secure)" shape: sealed *and* sandboxed.
    pub fn secure(scope: &'s Scope) -> CallOpts<'s> {
        CallOpts::new().sealed(scope).sandboxed()
    }

    /// Seal the scope's touched pages for the duration of the call
    /// (sender loses write access until the receiver completes).
    /// Standard single release on return.
    pub fn sealed(mut self, scope: &'s Scope) -> CallOpts<'s> {
        self.seal = Some(scope);
        self
    }

    /// Run the handler inside an MPK sandbox over the argument window.
    pub fn sandboxed(mut self) -> CallOpts<'s> {
        self.sandbox = true;
        self
    }

    /// Override the connection's default call timeout for this call.
    pub fn timeout(mut self, d: Duration) -> CallOpts<'s> {
        self.timeout = Some(d);
        self
    }

    /// Pin the call to a fabric. `Auto` (the default) accepts whatever
    /// the connection negotiated; `Cxl` / `Rdma` fail fast with
    /// `RpcError::Config` if the connection rides the other fabric.
    pub fn transport(mut self, t: TransportSel) -> CallOpts<'s> {
        self.transport = t;
        self
    }

    pub fn is_sealed(&self) -> bool {
        self.seal.is_some()
    }

    pub fn is_sandboxed(&self) -> bool {
        self.sandbox
    }

    pub fn transport_sel(&self) -> TransportSel {
        self.transport
    }

    /// The scope this call seals, if any.
    pub fn seal_scope(&self) -> Option<&'s Scope> {
        self.seal
    }
}

/// The typed result of a pointer-returning RPC (`call_typed`).
///
/// The handler side allocated an `R` in the connection heap (via
/// `CallCtx::reply_val` / `RpcServer::serve`) and returned its
/// address; `Reply` wraps that address with the connection borrow so
/// the pointer cannot outlive the heap, and decodes it through the
/// checked-MMU read path.
///
/// Replies that carry *no* value (optional results, see
/// `RpcServer::serve_opt`) come back as the null address; test with
/// [`Reply::is_none`] or decode with [`Reply::opt`].
///
/// Ownership: `Reply` does **not** free the reply buffer on drop —
/// whether the address points at a fresh server allocation (reclaim
/// it with [`Reply::free`] / [`Reply::take`]) or at long-lived shared
/// state (e.g. CoolDB documents — just read it) is a protocol-level
/// contract between client and handler.
#[must_use = "a Reply borrows the reply buffer; read it (and `free`/`take` server-allocated buffers)"]
pub struct Reply<'c, R: Pod> {
    conn: &'c Connection,
    addr: usize,
    _m: PhantomData<fn() -> R>,
}

impl<'c, R: Pod> Reply<'c, R> {
    pub(super) fn new(conn: &'c Connection, addr: usize) -> Reply<'c, R> {
        Reply { conn, addr, _m: PhantomData }
    }

    /// The raw return word, as the legacy surface exposed it.
    pub fn raw(&self) -> u64 {
        self.addr as u64
    }

    pub fn addr(&self) -> usize {
        self.addr
    }

    /// Did the handler decline to attach a value (null reply)?
    pub fn is_none(&self) -> bool {
        self.addr == 0
    }

    /// Typed pointer to the reply value.
    pub fn ptr(&self) -> ShmPtr<R> {
        ShmPtr::from_addr(self.addr)
    }

    /// Lifetime-bound typed view (cannot outlive this reply's borrow
    /// of the connection heap).
    pub fn view(&self) -> ShmView<'_, R> {
        ShmView::new(self.ptr(), self)
    }

    /// Checked read of the reply value.
    pub fn read(&self) -> Result<R> {
        if self.is_none() {
            return Err(RpcError::Serialization("null reply (handler attached no value)".into()));
        }
        self.ptr().read()
    }

    /// Decode an optional reply: `None` when the handler attached no
    /// value, `Some(read()?)` otherwise.
    pub fn opt(&self) -> Result<Option<R>> {
        if self.is_none() {
            return Ok(None);
        }
        Ok(Some(self.ptr().read()?))
    }

    /// Reclaim a *server-allocated* reply buffer (the top-level `R`
    /// block only; interior container data must be destroyed by the
    /// caller first, exactly as with any heap value). Provenance is
    /// resolved by the connection: replies the handler bump-allocated
    /// in the argument arena recycle lock-free, heap replies go back
    /// through the heap free list.
    pub fn free(self) {
        if self.addr != 0 {
            self.conn.free_reply(self.addr);
        }
    }

    /// Read the value and reclaim the server-allocated buffer in one
    /// step (the buffer is reclaimed even when the read fails, so a
    /// decode error doesn't leak it).
    pub fn take(self) -> Result<R> {
        let v = self.read();
        self.free();
        v
    }
}

impl<R: Pod> std::fmt::Debug for Reply<'_, R> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Reply<{}>({:#x})", std::any::type_name::<R>(), self.addr)
    }
}
