//! The composable call surface: `CallOpts`, `CallArg`, and `Reply`.
//!
//! The paper presents sealing (§4.5) and sandboxing (§4.4) as
//! *orthogonal, per-RPC* choices. `CallOpts` encodes that directly: a
//! builder whose `sealed` / `sandboxed` / `timeout` / `transport`
//! knobs compose freely, replacing the old fixed matrix of
//! `call` / `call_sealed` / `call_sandboxed` / `call_secure` methods
//! with one `Connection::invoke` core.
//!
//! `Reply<R>` is the typed view of a pointer-returning RPC: it borrows
//! the connection (so it cannot outlive the heap the pointer targets)
//! and decodes the return address through the checked-MMU path instead
//! of leaving callers to cast raw `u64`s.

use crate::error::{Result, RpcError};
use crate::memory::pod::Pod;
use crate::memory::ptr::{ShmPtr, ShmView};
use crate::memory::scope::Scope;
use std::marker::PhantomData;
use std::sync::Arc;
use std::time::Duration;

use super::ring::{status_to_error, RpcRing, ST_OK};
use super::waiter::{self, WaitOutcome};
use super::{Connection, Route, ServerCore, TransportSel};

/// An RPC argument: a native shared-memory pointer plus its byte
/// length. Built from whatever the caller has on hand:
///
/// * `()` — no argument (`addr = 0`);
/// * `ShmPtr<T>` — length inferred from `T`;
/// * `(addr, len)` — the raw escape hatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CallArg {
    pub addr: usize,
    pub len: usize,
}

impl CallArg {
    /// The empty argument (no shared-memory payload).
    pub const NONE: CallArg = CallArg { addr: 0, len: 0 };

    pub fn new(addr: usize, len: usize) -> CallArg {
        CallArg { addr, len }
    }
}

impl From<()> for CallArg {
    fn from(_: ()) -> CallArg {
        CallArg::NONE
    }
}

impl From<(usize, usize)> for CallArg {
    fn from((addr, len): (usize, usize)) -> CallArg {
        CallArg { addr, len }
    }
}

impl<T: Pod> From<ShmPtr<T>> for CallArg {
    fn from(p: ShmPtr<T>) -> CallArg {
        CallArg { addr: p.addr(), len: std::mem::size_of::<T>() }
    }
}

/// Client-side retry policy (failure plane): bounded attempts with
/// seeded, jittered exponential backoff.
///
/// Which errors qualify is deliberately conservative:
///
/// * a **claim-phase timeout** ([`RpcError::Timeout`] carrying the
///   slot-claim marker) always retries — the request was never
///   published, so no handler can have observed it;
/// * **transport-level failures** ([`RpcError::PeerFailed`],
///   [`RpcError::ConnectionClosed`], response timeouts) retry only
///   when the caller marked the call [`RetryPolicy::idempotent`]: the
///   request may already have executed on the (now unreachable) peer;
/// * application-level errors (handler status, seal/sandbox faults)
///   never retry — resubmitting would just fail again.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts, including the first (min 1).
    pub attempts: u32,
    /// First backoff; doubles per retry up to `max`.
    pub base: Duration,
    pub max: Duration,
    /// Jitter seed — fixed seed, fixed backoff schedule (the crash
    /// harness replays retries deterministically).
    pub seed: u64,
    /// Caller's declaration that re-executing the RPC is safe.
    pub idempotent: bool,
}

impl RetryPolicy {
    pub fn new(attempts: u32) -> RetryPolicy {
        RetryPolicy {
            attempts: attempts.max(1),
            base: Duration::from_micros(200),
            max: Duration::from_millis(20),
            seed: 1,
            idempotent: false,
        }
    }

    /// Declare the call idempotent: transport-level failures
    /// (peer death, closed connection, response timeout) become
    /// retryable.
    pub fn idempotent(mut self) -> RetryPolicy {
        self.idempotent = true;
        self
    }

    /// Override the first backoff (doubles per retry, capped at `max`).
    pub fn backoff_base(mut self, base: Duration, max: Duration) -> RetryPolicy {
        self.base = base;
        self.max = max.max(base);
        self
    }

    pub fn seed(mut self, seed: u64) -> RetryPolicy {
        self.seed = seed;
        self
    }

    /// Backoff before retry `attempt` (1-based): exponential, capped,
    /// with deterministic xorshift jitter in the upper half of the
    /// window so synchronized clients decorrelate.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let exp = self.base.saturating_mul(1u32 << attempt.min(16).saturating_sub(1));
        let cap = exp.min(self.max).max(self.base);
        let mut x = self.seed ^ ((attempt as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        let ns = cap.as_nanos() as u64;
        Duration::from_nanos(ns / 2 + x % (ns / 2 + 1))
    }

    /// May `e` be retried under this policy? (See the type docs for
    /// the classification.)
    pub fn should_retry(&self, e: &RpcError) -> bool {
        match e {
            RpcError::Timeout(what) if what == super::TIMEOUT_SLOT => true,
            RpcError::PeerFailed(_) | RpcError::ConnectionClosed | RpcError::Timeout(_) => {
                self.idempotent
            }
            _ => false,
        }
    }
}

/// Per-call options. All knobs are orthogonal; any combination is
/// valid (the paper's "RPCool (Secure)" configuration is simply
/// `sealed + sandboxed`).
///
/// ```ignore
/// conn.invoke(F_PUT, arg, CallOpts::new())?;                   // plain
/// conn.invoke(F_PUT, arg, CallOpts::new().sealed(&scope))?;    // §4.5
/// conn.invoke(F_PUT, arg, CallOpts::new().sandboxed())?;       // §4.4
/// conn.invoke(F_PUT, arg, CallOpts::secure(&scope))?;          // both
/// ```
#[derive(Clone, Copy, Default)]
pub struct CallOpts<'s> {
    pub(super) seal: Option<&'s Scope>,
    pub(super) sandbox: bool,
    pub(super) timeout: Option<Duration>,
    pub(super) transport: TransportSel,
    pub(super) retry: Option<RetryPolicy>,
}

impl<'s> CallOpts<'s> {
    /// Plain call: no seal, no sandbox, connection-default timeout,
    /// whatever transport the connection negotiated.
    pub fn new() -> CallOpts<'s> {
        CallOpts::default()
    }

    /// The paper's "RPCool (Secure)" shape: sealed *and* sandboxed.
    pub fn secure(scope: &'s Scope) -> CallOpts<'s> {
        CallOpts::new().sealed(scope).sandboxed()
    }

    /// Seal the scope's touched pages for the duration of the call
    /// (sender loses write access until the receiver completes).
    /// Standard single release on return.
    pub fn sealed(mut self, scope: &'s Scope) -> CallOpts<'s> {
        self.seal = Some(scope);
        self
    }

    /// Run the handler inside an MPK sandbox over the argument window.
    pub fn sandboxed(mut self) -> CallOpts<'s> {
        self.sandbox = true;
        self
    }

    /// Override the connection's default call timeout for this call.
    pub fn timeout(mut self, d: Duration) -> CallOpts<'s> {
        self.timeout = Some(d);
        self
    }

    /// Pin the call to a fabric. `Auto` (the default) accepts whatever
    /// the connection negotiated; `Cxl` / `Rdma` fail fast with
    /// `RpcError::Config` if the connection rides the other fabric.
    pub fn transport(mut self, t: TransportSel) -> CallOpts<'s> {
        self.transport = t;
        self
    }

    pub fn is_sealed(&self) -> bool {
        self.seal.is_some()
    }

    pub fn is_sandboxed(&self) -> bool {
        self.sandbox
    }

    pub fn transport_sel(&self) -> TransportSel {
        self.transport
    }

    /// The scope this call seals, if any.
    pub fn seal_scope(&self) -> Option<&'s Scope> {
        self.seal
    }

    /// Retry the call under `policy` (failure plane): bounded
    /// attempts, jittered exponential backoff, idempotent-only by
    /// default — see [`RetryPolicy`] for which errors qualify.
    pub fn retry(mut self, policy: RetryPolicy) -> CallOpts<'s> {
        self.retry = Some(policy);
        self
    }

    pub fn retry_policy(&self) -> Option<RetryPolicy> {
        self.retry
    }
}

/// The typed result of a pointer-returning RPC (`call_typed`).
///
/// The handler side allocated an `R` in the connection heap (via
/// `CallCtx::reply_val` / `RpcServer::serve`) and returned its
/// address; `Reply` wraps that address with the connection borrow so
/// the pointer cannot outlive the heap, and decodes it through the
/// checked-MMU read path.
///
/// Replies that carry *no* value (optional results, see
/// `RpcServer::serve_opt`) come back as the null address; test with
/// [`Reply::is_none`] or decode with [`Reply::opt`].
///
/// Ownership: `Reply` does **not** free the reply buffer on drop —
/// whether the address points at a fresh server allocation (reclaim
/// it with [`Reply::free`] / [`Reply::take`]) or at long-lived shared
/// state (e.g. CoolDB documents — just read it) is a protocol-level
/// contract between client and handler.
#[must_use = "a Reply borrows the reply buffer; read it (and `free`/`take` server-allocated buffers)"]
pub struct Reply<'c, R: Pod> {
    conn: &'c Connection,
    addr: usize,
    _m: PhantomData<fn() -> R>,
}

impl<'c, R: Pod> Reply<'c, R> {
    pub(super) fn new(conn: &'c Connection, addr: usize) -> Reply<'c, R> {
        Reply { conn, addr, _m: PhantomData }
    }

    /// The raw return word, as the legacy surface exposed it.
    pub fn raw(&self) -> u64 {
        self.addr as u64
    }

    pub fn addr(&self) -> usize {
        self.addr
    }

    /// Did the handler decline to attach a value (null reply)?
    pub fn is_none(&self) -> bool {
        self.addr == 0
    }

    /// Typed pointer to the reply value.
    pub fn ptr(&self) -> ShmPtr<R> {
        ShmPtr::from_addr(self.addr)
    }

    /// Lifetime-bound typed view (cannot outlive this reply's borrow
    /// of the connection heap).
    pub fn view(&self) -> ShmView<'_, R> {
        ShmView::new(self.ptr(), self)
    }

    /// Checked read of the reply value.
    pub fn read(&self) -> Result<R> {
        if self.is_none() {
            return Err(RpcError::Serialization("null reply (handler attached no value)".into()));
        }
        self.ptr().read()
    }

    /// Decode an optional reply: `None` when the handler attached no
    /// value, `Some(read()?)` otherwise.
    pub fn opt(&self) -> Result<Option<R>> {
        if self.is_none() {
            return Ok(None);
        }
        Ok(Some(self.ptr().read()?))
    }

    /// Reclaim a *server-allocated* reply buffer (the top-level `R`
    /// block only; interior container data must be destroyed by the
    /// caller first, exactly as with any heap value). Provenance is
    /// resolved by the connection: replies the handler bump-allocated
    /// in the argument arena recycle lock-free, heap replies go back
    /// through the heap's thread-cached free path (a magazine push —
    /// the central heap mutex is involved only on a magazine spill).
    pub fn free(self) {
        if self.addr != 0 {
            self.conn.free_reply(self.addr);
        }
    }

    /// Read the value and reclaim the server-allocated buffer in one
    /// step (the buffer is reclaimed even when the read fails, so a
    /// decode error doesn't leak it).
    pub fn take(self) -> Result<R> {
        let v = self.read();
        self.free();
        v
    }
}

impl<R: Pod> std::fmt::Debug for Reply<'_, R> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Reply<{}>({:#x})", std::any::type_name::<R>(), self.addr)
    }
}

/// An in-flight asynchronous RPC (`Connection::invoke_async` /
/// `call_scalar_async`): the request is already published; the
/// completion is collected through this handle. Poll with
/// [`CallHandle::ready`]/[`CallHandle::poll`], or block (park-aware,
/// against the shard's response-doorbell epoch) with
/// [`CallHandle::wait`].
///
/// Dropping an unfinished handle **abandons** the call: the slot gets
/// a tombstone so a late response retires the lap (the ring can never
/// wedge), and an argument owned by the handle is quarantined until
/// the rings are quiescent (the server may still read it).
#[must_use = "an async call completes through its handle; dropping it abandons the call"]
pub struct CallHandle<'c> {
    conn: &'c Connection,
    /// The shard lease the submission routed on; released exactly
    /// once, at `finish`/`abandon` (that release is what lets the
    /// submitting thread re-stripe under two-choice once drained).
    route: Route,
    slot: usize,
    func: u32,
    arg: CallArg,
    /// Does the handle own the argument allocation (typed path)?
    own_arg: bool,
    timeout: Duration,
    done: bool,
}

impl<'c> CallHandle<'c> {
    #[allow(clippy::too_many_arguments)]
    pub(super) fn new(
        conn: &'c Connection,
        route: Route,
        slot: usize,
        func: u32,
        arg: CallArg,
        own_arg: bool,
        timeout: Duration,
    ) -> CallHandle<'c> {
        CallHandle { conn, route, slot, func, arg, own_arg, timeout, done: false }
    }

    #[inline]
    fn ring(&self) -> &RpcRing {
        &self.conn.shared.shards[self.route.si].ring
    }

    /// The function id this call invoked.
    pub fn func(&self) -> u32 {
        self.func
    }

    /// The shard the call rode (telemetry/tests).
    pub fn shard(&self) -> usize {
        self.route.si
    }

    /// Has the response landed? One atomic load; never blocks.
    pub fn ready(&self) -> bool {
        self.ring().response_ready(self.slot)
    }

    /// Non-blocking completion attempt: `None` while the response is
    /// in flight, `Some(result)` once it landed (consuming the slot —
    /// the handle is finished afterwards and drops inert).
    pub fn poll(&mut self) -> Option<Result<u64>> {
        if self.done || !self.ready() {
            return None;
        }
        Some(self.finish())
    }

    /// Block until the response lands (parking on the shard's
    /// response doorbell; the submission-time timeout bounds the
    /// wait), then consume it. Inline-attached servers are driven
    /// from this thread exactly as in synchronous calls.
    pub fn wait(mut self) -> Result<u64> {
        let conn = self.conn;
        let (shard, slot) = (self.route.si, self.slot);
        let ring = &conn.shared.shards[shard].ring;
        let inline: Option<Arc<ServerCore>> =
            conn.inline_server.lock().unwrap().as_ref().map(Arc::clone);
        let out = waiter::wait_on(
            conn.opts.sleep,
            self.timeout,
            None,
            Some(ring.resp_bell()),
            || {
                if ring.response_ready(slot) || conn.shared.closed() {
                    return true;
                }
                if let Some(core) = &inline {
                    conn.drain_inline(core, Some((shard, slot)));
                    if ring.response_ready(slot) {
                        return true;
                    }
                }
                false
            },
        );
        if out == WaitOutcome::TimedOut {
            self.abandon();
            return Err(RpcError::Timeout(format!("rpc response (func {})", self.func)));
        }
        if !ring.response_ready(slot) {
            // Failure plane: distinguish a dead peer (orchestrator
            // fan-out after lease expiry) from an orderly close, so
            // retry/reconnect policies can act on it.
            if conn.shared.peer_failed() {
                self.abandon();
                return Err(RpcError::PeerFailed(format!(
                    "peer died with rpc in flight (func {})",
                    self.func
                )));
            }
            if conn.shared.closed() {
                self.abandon();
                return Err(RpcError::ConnectionClosed);
            }
        }
        self.finish()
    }

    /// Consume the landed response, release an owned argument and the
    /// shard lease, and decode the status.
    fn finish(&mut self) -> Result<u64> {
        self.done = true;
        let shard = self.route.si;
        let (status, ret, aux_lo, aux_hi) =
            self.conn.shared.shards[shard].ring.consume_detail(self.slot);
        if self.own_arg {
            // The server is done with the call: the argument releases
            // immediately, against the shard it was allocated on.
            self.conn.release_arg(shard, self.arg.addr);
        }
        self.conn.unroute(&self.route);
        match status {
            ST_OK => Ok(ret),
            other => Err(status_to_error(other, self.func, ret, aux_lo, aux_hi)),
        }
    }

    /// Give up on the call: tombstone the slot (a late response
    /// retires the lap), quarantine an owned argument the server
    /// may still read, and release the shard lease.
    fn abandon(&mut self) {
        if self.done {
            return;
        }
        self.done = true;
        let shard = self.route.si;
        let completed =
            self.conn.abandon_and_reclaim(shard, self.slot, self.arg.addr, self.arg.len);
        if self.own_arg {
            if completed {
                // The response had landed: the server is done with the
                // argument, release it now (the common drop-after-
                // completion path never touches the quarantine).
                self.conn.release_arg(shard, self.arg.addr);
            } else {
                self.conn.quarantine_arg(self.arg.addr);
            }
        }
        self.conn.unroute(&self.route);
    }
}

impl Drop for CallHandle<'_> {
    fn drop(&mut self) {
        self.abandon();
    }
}

impl std::fmt::Debug for CallHandle<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CallHandle(func {}, shard {}, slot {}, {})",
            self.func,
            self.route.si,
            self.slot,
            if self.done { "done" } else if self.ready() { "ready" } else { "in flight" }
        )
    }
}

/// An in-flight **typed** asynchronous RPC
/// (`Connection::call_typed_async::<A, R>`): the same submission and
/// completion machinery as [`CallHandle`], resolving to the
/// [`Reply<R>`] a synchronous `call_typed` would have returned — so
/// apps pipeline pointer-returning RPCs with no raw `u64` casts.
///
/// Dropping an unfinished handle abandons the call exactly like
/// dropping a [`CallHandle`] (the inner handle's `Drop` runs).
#[must_use = "a typed async call completes through its handle; dropping it abandons the call"]
pub struct TypedCallHandle<'c, R: Pod> {
    inner: CallHandle<'c>,
    _m: PhantomData<fn() -> R>,
}

impl<'c, R: Pod> TypedCallHandle<'c, R> {
    pub(super) fn new(inner: CallHandle<'c>) -> TypedCallHandle<'c, R> {
        TypedCallHandle { inner, _m: PhantomData }
    }

    /// The function id this call invoked.
    pub fn func(&self) -> u32 {
        self.inner.func()
    }

    /// The shard the call rode (telemetry/tests).
    pub fn shard(&self) -> usize {
        self.inner.shard()
    }

    /// Has the response landed? One atomic load; never blocks.
    pub fn ready(&self) -> bool {
        self.inner.ready()
    }

    /// Non-blocking completion attempt: `None` while in flight,
    /// `Some(Ok(Reply<R>))` once the response landed (consuming the
    /// slot; the handle drops inert afterwards).
    pub fn poll(&mut self) -> Option<Result<Reply<'c, R>>> {
        let conn = self.inner.conn;
        self.inner.poll().map(|r| r.map(|ret| Reply::new(conn, ret as usize)))
    }

    /// Block until the response lands (park-aware, like
    /// [`CallHandle::wait`]) and decode it as a typed [`Reply<R>`].
    pub fn wait(self) -> Result<Reply<'c, R>> {
        let conn = self.inner.conn;
        let ret = self.inner.wait()?;
        Ok(Reply::new(conn, ret as usize))
    }
}

impl<R: Pod> std::fmt::Debug for TypedCallHandle<'_, R> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Typed{:?}<{}>", self.inner, std::any::type_name::<R>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_policy_classifies_errors() {
        let p = RetryPolicy::new(3);
        // Claim-phase timeout: the request was never published —
        // always retryable, idempotent or not.
        assert!(p.should_retry(&RpcError::Timeout(super::super::TIMEOUT_SLOT.into())));
        // Transport-level failures need the idempotent declaration.
        assert!(!p.should_retry(&RpcError::PeerFailed("x".into())));
        assert!(!p.should_retry(&RpcError::ConnectionClosed));
        assert!(!p.should_retry(&RpcError::Timeout("rpc response (func 1)".into())));
        let p = p.idempotent();
        assert!(p.should_retry(&RpcError::PeerFailed("x".into())));
        assert!(p.should_retry(&RpcError::ConnectionClosed));
        assert!(p.should_retry(&RpcError::Timeout("rpc response (func 1)".into())));
        // Application-level errors never retry.
        assert!(!p.should_retry(&RpcError::NoSuchHandler(7)));
        assert!(!p.should_retry(&RpcError::Remote("handler error".into())));
    }

    #[test]
    fn retry_backoff_is_seeded_bounded_exponential() {
        let p = RetryPolicy::new(8)
            .backoff_base(Duration::from_micros(100), Duration::from_millis(2))
            .seed(42);
        let q = RetryPolicy::new(8)
            .backoff_base(Duration::from_micros(100), Duration::from_millis(2))
            .seed(42);
        for a in 1..8 {
            let d = p.backoff(a);
            assert_eq!(d, q.backoff(a), "same seed, same schedule");
            // Jitter lives in [cap/2, cap]; the cap never exceeds max.
            assert!(d >= Duration::from_micros(50), "attempt {a}: {d:?} below floor");
            assert!(d <= Duration::from_millis(2), "attempt {a}: {d:?} above cap");
        }
        // The window actually grows before the cap bites.
        assert!(
            p.backoff(5) > Duration::from_micros(200),
            "exponential growth: attempt 5 sits in a wider window"
        );
        assert_ne!(
            p.backoff(1),
            p.seed(43).backoff(1),
            "different seeds jitter differently"
        );
    }
}
