//! Channels and connections — RPCool's communication core (paper §4.2).
//!
//! A server *opens* a channel (registered with the orchestrator under
//! a hierarchical name); clients *connect* and receive a `Connection`
//! whose shared-memory heap holds RPC arguments — exchanged by native
//! pointer, never serialized. The per-connection `RpcRing` in that
//! heap carries request/response descriptors; both sides busy-wait
//! with the adaptive-sleep policy of §5.8.
//!
//! Safety hooks are wired here: a call may be **sealed** (sender loses
//! write access until the receiver completes, §4.5) and/or
//! **sandboxed** (the handler runs inside an MPK window over the
//! argument scope, §4.4) — orthogonal, per-RPC choices, exactly as in
//! the paper.
//!
//! # Typed API
//!
//! One core call path, composable per-call options, typed endpoints:
//!
//! * [`Connection::invoke`]`(func, arg, CallOpts)` — the raw core.
//!   `arg` is anything convertible to [`CallArg`]: `()`, a
//!   `ShmPtr<T>`, or `(addr, len)`.
//! * [`CallOpts`] — `sealed(&scope)`, `sandboxed()`, `timeout(d)`,
//!   `transport(sel)`; all orthogonal. `CallOpts::secure(&scope)` is
//!   the paper's sealed+sandboxed configuration.
//! * [`Connection::call_typed`]`::<A, R>(func, &A, opts)` — allocates
//!   the argument (in the sealed scope when one is given, else the
//!   connection heap), invokes, and wraps the returned address in a
//!   [`Reply<R>`] that borrows the connection and decodes through the
//!   checked-MMU path. [`Connection::call_scalar`]`::<A>` is the same
//!   with a raw `u64` reply.
//! * [`RpcServer::serve`]`::<A, R>(func, |ctx, arg: &A| ...)` — typed
//!   handler registration layered over [`RpcServer::add`]; the reply
//!   value is allocated in the connection heap for the client's
//!   `Reply<R>`. `serve_opt` maps `Ok(None)` to the null reply;
//!   `serve_scalar` keeps the raw `u64` return word.
//! * [`ChannelBuilder`] — fluent construction of [`ChannelOpts`]
//!   (heap size, shared-heap topology, ACL, ring slots, sleep policy,
//!   call timeout).
//!
//! ## Migration from the legacy `call_*` variants
//!
//! | old (deprecated)                               | new                                                   |
//! |------------------------------------------------|-------------------------------------------------------|
//! | `conn.call(f, addr, len)`                      | `conn.invoke(f, (addr, len), CallOpts::new())`        |
//! | `conn.call_ptr(f, ptr)`                        | `conn.invoke(f, ptr, CallOpts::new())`                |
//! | `conn.call_sealed(f, &scope, addr, len)`       | `conn.invoke(f, (addr, len), CallOpts::new().sealed(&scope))` |
//! | `conn.call_sandboxed(f, addr, len)`            | `conn.invoke(f, (addr, len), CallOpts::new().sandboxed())`    |
//! | `conn.call_secure(f, &scope, addr, len)`       | `conn.invoke(f, (addr, len), CallOpts::secure(&scope))`       |
//! | `conn.call_sealed_pooled(f, &pool, scope, addr, len)` | `conn.invoke_pooled(f, &pool, scope, (addr, len), CallOpts::new())` |
//!
//! Typed call sites shrink further: hand-rolled
//! `heap.new_val(arg)? … ShmPtr::from_addr(ret as usize).read()?`
//! plumbing becomes `conn.call_typed::<A, R>(f, &arg, opts)?.read()?`.
//!
//! # Sharded data path, batched and async submission
//!
//! A connection's data path is an array of [`Shard`]s (ring + arg
//! arena), sized by [`ChannelBuilder::ring_shards`]. Caller threads
//! stripe across shards — FIFO still holds *within* a shard, which is
//! exactly the per-thread program order that matters — so N threads
//! no longer funnel through one ring's ticket CAS. With
//! [`ChannelBuilder::two_choice`] (the default) the stripe is
//! **load-aware**: a thread with nothing in flight picks the
//! less-loaded of its home shard and one random probe shard
//! (power-of-two-choices over `depth + claim_fails`), and stays
//! pinned to its pick while it has calls in flight — the pin is what
//! keeps per-thread FIFO intact across re-striping, and the
//! contention signal is what routes new callers around a wedged or
//! flooded shard.
//!
//! Listeners ([`RpcServer::listen`], or `k` of them via
//! [`RpcServer::spawn_listeners`]) run a **drain-k serving loop**:
//! each sweep takes up to [`ChannelBuilder::drain_k`] requests per
//! shard per connection, answers them with `respond_quiet`, and rings
//! the shard's response doorbell **once** per sweep
//! (`flush_respond`) — the reply-side mirror of the request side's
//! `publish_quiet`/`flush_publish` amortization, taking the charged
//! doorbell cost of one RPC from 2 signals to 1 + 1/B (B ≤ k the
//! achieved coalesce factor). Each worker starts its sweep at a
//! different shard offset so `k` workers don't convoy on shard 0.
//!
//! Submission amortizes on top of that:
//!
//! * [`Connection::invoke_batch`] / [`Connection::call_scalar_batch`]
//!   publish a slice of calls to this thread's shard with **one**
//!   doorbell signal per chunk (`publish_quiet` × k + `flush_publish`)
//!   instead of one per call.
//! * [`Connection::invoke_async`] / [`Connection::call_scalar_async`]
//!   return a [`CallHandle`]: publish now, `poll()`/`wait()` the
//!   completion later (park-aware, against the shard's response
//!   doorbell epoch), so apps pipeline RPCs instead of blocking
//!   per call. Dropping an unfinished handle abandons the slot —
//!   it can never wedge the ring.
//!   [`Connection::call_typed_async`] is the fully typed variant: a
//!   [`TypedCallHandle<R>`] resolving to the same [`Reply<R>`] a
//!   synchronous `call_typed` returns.

pub mod call;
pub mod pool;
pub mod ring;
pub mod waiter;

pub use call::{CallArg, CallHandle, CallOpts, Reply, RetryPolicy, TypedCallHandle};

use crate::cluster::{DsmState, MapKind, PodId, Topology};
use crate::config::{AdmissionPolicy, SimConfig};
use crate::daemon::Daemon;
use crate::error::{Result, RpcError};
use crate::memory::arena::ArgArena;
use crate::memory::containers::{ShmString, ShmVec};
use crate::memory::heap::Heap;
use crate::memory::pod::Pod;
use crate::memory::ptr::ShmPtr;
use crate::memory::scope::Scope;
use crate::orchestrator::{Acl, ChannelReg};
use crate::rack::ProcEnv;
use crate::sandbox::SandboxMgr;
use crate::seal::{ScopePool, SealHandle, Sealer};
use ring::*;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock, Weak};
use std::time::{Duration, Instant};
use waiter::{Doorbell, SleepPolicy, WaitOutcome, LOAD, PARK_SLICE_US, PARK_SPIN_POLLS};

// ---------------------------------------------------------------------
// channel directory (how connect() finds a live server in-process)

static DIRECTORY: Mutex<Option<HashMap<(u64, String), Weak<ServerCore>>>> = Mutex::new(None);

fn directory_insert(rack_id: u64, name: &str, core: &Arc<ServerCore>) {
    let mut d = DIRECTORY.lock().unwrap();
    d.get_or_insert_with(HashMap::new)
        .insert((rack_id, name.to_string()), Arc::downgrade(core));
}

/// Remove a directory entry only if it still points at `core`: a
/// stale handle to a dead (or resurrected) channel dropped after a
/// new owner re-registered the same name must not evict the new
/// owner's entry (the stale-death-latching bug).
fn directory_remove_if(rack_id: u64, name: &str, core: &Arc<ServerCore>) {
    if let Some(d) = DIRECTORY.lock().unwrap().as_mut() {
        let key = (rack_id, name.to_string());
        if d.get(&key).map_or(false, |w| w.as_ptr() == Arc::as_ptr(core)) {
            d.remove(&key);
        }
    }
}

/// Channels the recovery sweep resurrected into a standby proc, parked
/// until the standby claims them via [`RpcServer::take_adopted`].
static ADOPTED: Mutex<Option<HashMap<(u64, String), Arc<ServerCore>>>> = Mutex::new(None);

fn adopted_insert(rack_id: u64, name: &str, core: &Arc<ServerCore>) {
    ADOPTED
        .lock()
        .unwrap()
        .get_or_insert_with(HashMap::new)
        .insert((rack_id, name.to_string()), Arc::clone(core));
}

fn directory_get(rack_id: u64, name: &str) -> Option<Arc<ServerCore>> {
    DIRECTORY
        .lock()
        .unwrap()
        .as_ref()
        .and_then(|d| d.get(&(rack_id, name.to_string())))
        .and_then(|w| w.upgrade())
}

// ---------------------------------------------------------------------
// thread striping (which shard a caller thread rides)

/// Monotonic stripe ids handed to threads on first use. Round-robin
/// assignment spreads concurrently spawned callers across shards; the
/// id is stable for the thread's lifetime, so a thread always returns
/// to the same shard (per-thread FIFO order is preserved).
static NEXT_STRIPE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static STRIPE: usize = NEXT_STRIPE.fetch_add(1, Ordering::Relaxed);
}

/// This thread's stripe id (assigned on first call, stable after).
pub(crate) fn thread_stripe() -> usize {
    STRIPE.with(|s| *s)
}

thread_local! {
    /// Per-thread probe-RNG state for load-aware striping: seeded from
    /// the thread's stripe id (xorshift64 needs a nonzero word), so
    /// probe sequences are deterministic per stripe yet uncorrelated
    /// across threads.
    static PROBE_RNG: std::cell::Cell<u64> = std::cell::Cell::new(0);
}

/// Next value of this thread's xorshift64 probe stream.
#[inline]
fn probe_rng_next() -> u64 {
    PROBE_RNG.with(|cell| {
        let mut x = cell.get();
        if x == 0 {
            x = crate::util::rng::mix64(thread_stripe() as u64 + 1) | 1;
        }
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        cell.set(x);
        x
    })
}

// ---------------------------------------------------------------------
// load-aware two-choice routing (which shard a call actually rides)

/// Per-(thread × connection) pin: while this thread has calls in
/// flight on a connection, every new call rides the same shard —
/// that is exactly what keeps per-thread FIFO order intact across
/// load-aware re-striping (responses within one shard complete in
/// publish order; across shards they would not).
struct PinEntry {
    /// `Arc::as_ptr` of the connection's `ConnShared` — unique while
    /// the connection lives, and entries are pruned once drained.
    key: usize,
    shard: usize,
    /// In-flight weight this thread routed to `shard`. Decremented by
    /// whoever completes the call (possibly another thread holding a
    /// moved `CallHandle`), hence the shared atomic.
    outstanding: Arc<AtomicU64>,
}

thread_local! {
    static SHARD_PINS: std::cell::RefCell<Vec<PinEntry>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// A routed call's shard lease: which shard the call rides, plus the
/// bookkeeping to undo at completion. `weight == 0` marks an
/// untracked (fixed-striping) route whose release is a no-op.
pub(crate) struct Route {
    pub(crate) si: usize,
    weight: u64,
    pin: Option<Arc<AtomicU64>>,
}

// ---------------------------------------------------------------------
// options

#[derive(Clone)]
pub struct ChannelOpts {
    /// Per-connection heap size (or the single shared heap's size).
    pub heap_bytes: usize,
    /// One heap shared channel-wide (Fig. 4b) vs per-connection (4a).
    pub shared_heap: bool,
    /// ACL; defaults to world-connectable.
    pub acl: Option<Acl>,
    /// RPC ring slots per connection (per shard).
    pub ring_slots: usize,
    /// Ring+arena shards per connection (rounded up to a power of
    /// two, capped at 64). Caller threads stripe across shards by
    /// thread id; listeners drain all shards.
    pub ring_shards: usize,
    pub sleep: SleepPolicy,
    /// Client-side call timeout.
    pub call_timeout: Duration,
    /// Per-connection lock-free argument-arena budget, split evenly
    /// across the shards (0 disables the arenas; typed-call arguments
    /// and replies then always take the heap mutex).
    pub arg_arena_bytes: usize,
    /// Server drain budget: up to `drain_k` requests taken per shard
    /// per serving sweep, answered with `respond_quiet`, then one
    /// coalesced response-doorbell signal per shard per sweep
    /// (`flush_respond`). 1 restores one reply signal per RPC.
    pub drain_k: usize,
    /// Load-aware power-of-two-choices striping: a caller thread with
    /// nothing in flight picks the less-loaded of its home shard and
    /// one probe shard; while it has calls in flight it stays pinned
    /// to its current shard (per-thread FIFO). No-op with one shard.
    pub two_choice: bool,
    /// Per-heap override of the thread-magazine capacity for this
    /// channel's heap(s) (`None` = config `magazine_cap`; `Some(0)` =
    /// fixed always-lock allocation, the pre-overhaul path).
    pub magazine_cap: Option<usize>,
    /// Serve this channel from the daemon-wide worker pool instead of
    /// dedicated listener threads: `k > 0` means "pool with at least
    /// k workers" (clamped to [`pool::MAX_POOL_WORKERS`]); channels on
    /// one host share the pool, so worker count stays decoupled from
    /// channel count. `0` (the default) keeps today's per-channel
    /// listener model byte for byte.
    pub pool_workers: usize,
    /// Elastic shard routing: connections start striping over one
    /// shard and grow/shrink the *active* window (power-of-two steps,
    /// within the fixed capacity `ring_shards`) under sustained
    /// claim-fail pressure / idleness. Off (the default) = today's
    /// fixed striping, untouched.
    pub elastic_shards: bool,
    /// What happens to a connect() beyond `conn_limit` (see
    /// [`AdmissionPolicy`]); irrelevant while `conn_limit == 0`.
    pub admission: AdmissionPolicy,
    /// Live-connection ceiling that arms the admission policy
    /// (0 = unlimited, the default).
    pub conn_limit: usize,
    /// Standby process for crash resurrection: when the channel's
    /// owner proc loses its lease, the recovery sweep adopts the
    /// channel into this proc (`Daemon::adopt_channel`) — re-opening
    /// the same shared heap, re-registering the handler set, reaping
    /// stranded slots — instead of tearing it down. `None` (the
    /// default) keeps teardown-on-death. The standby must live on a
    /// host in the owner's pod (it maps the same heaps directly).
    pub standby: Option<ProcEnv>,
}

impl ChannelOpts {
    pub fn from_config(cfg: &SimConfig) -> Self {
        ChannelOpts {
            heap_bytes: cfg.heap_bytes,
            shared_heap: false,
            acl: None,
            ring_slots: 64,
            ring_shards: cfg.ring_shards,
            sleep: SleepPolicy::from_config(cfg),
            call_timeout: Duration::from_secs(10),
            arg_arena_bytes: 256 << 10,
            drain_k: cfg.drain_k,
            two_choice: cfg.two_choice,
            magazine_cap: None,
            pool_workers: cfg.pool_workers,
            elastic_shards: cfg.elastic_shards,
            admission: cfg.admission,
            conn_limit: cfg.conn_limit,
            standby: None,
        }
    }
}

/// Fluent construction of [`ChannelOpts`] — prefer this over
/// struct-literal mutation of the options.
///
/// ```ignore
/// let server = ChannelBuilder::for_env(&env)
///     .shared_heap(true)
///     .heap_bytes(192 << 20)
///     .open(&env, "cooldb")?;
/// ```
#[derive(Clone)]
pub struct ChannelBuilder {
    opts: ChannelOpts,
    /// Crash-fault plan armed when the channel opens (failure-plane
    /// tests; see [`crate::fault`]).
    fault: Option<crate::fault::FaultPlan>,
}

impl ChannelBuilder {
    pub fn from_config(cfg: &SimConfig) -> ChannelBuilder {
        ChannelBuilder { opts: ChannelOpts::from_config(cfg), fault: None }
    }

    /// Defaults derived from the environment's rack configuration.
    pub fn for_env(env: &ProcEnv) -> ChannelBuilder {
        Self::from_config(&env.rack.cfg)
    }

    /// Per-connection heap size (or the single shared heap's size).
    pub fn heap_bytes(mut self, bytes: usize) -> ChannelBuilder {
        self.opts.heap_bytes = bytes;
        self
    }

    /// One heap shared channel-wide (Fig. 4b) vs per-connection (4a).
    pub fn shared_heap(mut self, shared: bool) -> ChannelBuilder {
        self.opts.shared_heap = shared;
        self
    }

    pub fn acl(mut self, acl: Acl) -> ChannelBuilder {
        self.opts.acl = Some(acl);
        self
    }

    pub fn ring_slots(mut self, slots: usize) -> ChannelBuilder {
        self.opts.ring_slots = slots;
        self
    }

    /// Shard the connection data path: `n` independent rings + arg
    /// arenas per connection (rounded up to a power of two, capped at
    /// 64). Caller threads stripe across shards by thread id, so the
    /// per-connection serialization point scales with `n`; pair with
    /// [`RpcServer::spawn_listeners`] on the serving side.
    pub fn ring_shards(mut self, n: usize) -> ChannelBuilder {
        self.opts.ring_shards = n;
        self
    }

    pub fn sleep(mut self, policy: SleepPolicy) -> ChannelBuilder {
        self.opts.sleep = policy;
        self
    }

    /// Client-side default call timeout (per-call override:
    /// [`CallOpts::timeout`]).
    pub fn call_timeout(mut self, d: Duration) -> ChannelBuilder {
        self.opts.call_timeout = d;
        self
    }

    /// Per-connection argument-arena size (0 disables it).
    pub fn arg_arena_bytes(mut self, bytes: usize) -> ChannelBuilder {
        self.opts.arg_arena_bytes = bytes;
        self
    }

    /// Server drain budget per shard per serving sweep: up to `k`
    /// requests are answered quietly and one coalesced response
    /// doorbell rings per shard per sweep — the reply-side charged
    /// cost per RPC drops from 1 signal to 1/B, where B ≤ k is the
    /// achieved coalesce factor. `k = 1` restores per-reply signals.
    pub fn drain_k(mut self, k: usize) -> ChannelBuilder {
        self.opts.drain_k = k.max(1);
        self
    }

    /// Toggle load-aware two-choice shard striping (see
    /// [`ChannelOpts::two_choice`]; default from the config).
    pub fn two_choice(mut self, on: bool) -> ChannelBuilder {
        self.opts.two_choice = on;
        self
    }

    /// Thread-magazine capacity for this channel's heap(s): how many
    /// free blocks per size class each thread caches in front of the
    /// heap's central lock (`0` = fixed always-lock allocation).
    /// Default from the config's `magazine_cap`.
    pub fn magazine_cap(mut self, cap: usize) -> ChannelBuilder {
        self.opts.magazine_cap = Some(cap);
        self
    }

    /// Serve this channel from the daemon-wide worker pool with at
    /// least `k` workers (clamped to [`pool::MAX_POOL_WORKERS`]; see
    /// [`ChannelOpts::pool_workers`]). `0` keeps dedicated listeners.
    pub fn pool_workers(mut self, k: usize) -> ChannelBuilder {
        self.opts.pool_workers = k;
        self
    }

    /// Toggle elastic shard routing (see
    /// [`ChannelOpts::elastic_shards`]; default from the config).
    pub fn elastic_shards(mut self, on: bool) -> ChannelBuilder {
        self.opts.elastic_shards = on;
        self
    }

    /// Overload policy once `conn_limit` live connections exist (see
    /// [`AdmissionPolicy`]).
    pub fn admission(mut self, policy: AdmissionPolicy) -> ChannelBuilder {
        self.opts.admission = policy;
        self
    }

    /// Live-connection ceiling arming the admission policy
    /// (0 = unlimited).
    pub fn conn_limit(mut self, n: usize) -> ChannelBuilder {
        self.opts.conn_limit = n;
        self
    }

    /// Arm the deterministic crash-fault injector when this channel
    /// opens: the plan's kill point fires on its nth crossing and the
    /// crossing proc dies *without cleanup* — the recovery sweep has
    /// to pick up the pieces. Kills count on the rack's fault
    /// counters. One global injector: the last armed plan wins.
    pub fn fault_plan(mut self, plan: crate::fault::FaultPlan) -> ChannelBuilder {
        self.fault = Some(plan);
        self
    }

    /// Register a standby proc for crash resurrection (see
    /// [`ChannelOpts::standby`]): if the owner dies, the sweep adopts
    /// the channel into `env` instead of tearing it down, and
    /// in-flight idempotent calls complete against the resurrected
    /// endpoint. The standby must be on a host in the owner's pod.
    pub fn standby(mut self, env: &ProcEnv) -> ChannelBuilder {
        self.opts.standby = Some(env.clone());
        self
    }

    pub fn opts(&self) -> &ChannelOpts {
        &self.opts
    }

    /// Open the channel with these options.
    pub fn open(self, env: &ProcEnv, name: &str) -> Result<RpcServer> {
        if let Some(plan) = self.fault {
            crate::fault::arm_with_sink(plan, Arc::downgrade(&env.rack.orch.fault_counters()));
        }
        RpcServer::open(env, name, self.opts)
    }
}

// ---------------------------------------------------------------------
// handler interface

/// What a handler sees: the connection heap and the argument pointer.
pub struct CallCtx<'a> {
    pub heap: &'a Arc<Heap>,
    /// The connection's lock-free argument arena, if one exists;
    /// `reply_*` allocate from it first so the reply path skips the
    /// heap mutex (clients recycle it through `Reply::free`/`take`).
    pub arena: Option<&'a ArgArena>,
    pub func: u32,
    pub arg: usize,
    pub arg_len: usize,
    /// Was the argument verified sealed?
    pub sealed: bool,
    /// Is the handler running inside a sandbox window?
    pub sandboxed: bool,
    /// Sandbox temp heap (malloc redirection target), if sandboxed.
    pub temp: Option<&'a Scope>,
}

impl<'a> CallCtx<'a> {
    /// Typed view of the argument.
    pub fn arg_ptr<T: Pod>(&self) -> ShmPtr<T> {
        ShmPtr::from_addr(self.arg)
    }

    pub fn arg_val<T: Pod>(&self) -> Result<T> {
        self.arg_ptr::<T>().read()
    }

    /// Checked typed decode of the argument: rejects a null pointer
    /// and a declared length too short for `T` before the MMU-checked
    /// read (the decode path `RpcServer::serve` uses).
    pub fn arg_typed<T: Pod>(&self) -> Result<T> {
        if self.arg == 0 {
            return Err(RpcError::Serialization(format!(
                "handler {}: null argument for typed decode",
                self.func
            )));
        }
        let need = std::mem::size_of::<T>();
        if self.arg_len < need {
            return Err(RpcError::Serialization(format!(
                "handler {}: argument is {} bytes, typed decode needs {need}",
                self.func, self.arg_len
            )));
        }
        self.arg_ptr::<T>().read()
    }

    /// Allocate a reply value for the `ret` slot: lock-free from the
    /// connection's argument arena when it has room, else from the
    /// heap. Clients reclaim either through `Reply::free`/`take`
    /// (provenance is resolved there).
    pub fn reply_val<T: Pod>(&self, v: T) -> Result<u64> {
        if let Some(arena) = self.arena {
            if let Some(addr) = arena.alloc_val(v) {
                return Ok(addr as u64);
            }
        }
        Ok(self.heap.new_val(v)? as u64)
    }

    pub fn reply_string(&self, s: &str) -> Result<u64> {
        let shm = ShmString::from_str(self.heap, s)?;
        Ok(self.heap.new_val(shm)? as u64)
    }

    /// Reply with a vector materialized in the connection heap
    /// (symmetric with `Connection::new_vec`).
    pub fn reply_vec<T: Pod>(&self, xs: &[T]) -> Result<u64> {
        let mut v: ShmVec<T> = ShmVec::with_capacity(self.heap.as_ref(), xs.len())?;
        v.extend_from_slice(self.heap.as_ref(), xs)?;
        self.reply_val(v)
    }

    /// The null reply: the handler attaches no value. Clients see
    /// `Reply::is_none()` / `Reply::opt() == Ok(None)`.
    pub fn reply_none(&self) -> Result<u64> {
        Ok(0)
    }

    /// In-sandbox allocation: redirects to the sandbox's temp heap.
    /// Outside a sandbox there is no temp heap to redirect to, so this
    /// fails — allocate from `self.heap` (or use the `reply_*`
    /// helpers) instead.
    pub fn malloc(&self, size: usize) -> Result<usize> {
        match self.temp {
            Some(t) => t.alloc_bytes(size),
            None => Err(RpcError::Runtime(
                "CallCtx::malloc requires a sandboxed call; use ctx.heap or reply_* outside a sandbox"
                    .into(),
            )),
        }
    }
}

pub type Handler = Box<dyn Fn(&CallCtx) -> Result<u64> + Send + Sync>;

// ---------------------------------------------------------------------
// connection state shared by both endpoints (models shm + kernels)

/// One stripe of a connection's data path: a slot ring plus the
/// lock-free argument arena that feeds it. A connection owns
/// `ring_shards` of these; caller threads stripe across them by
/// thread id and listeners drain them all, so the per-connection
/// serialization point scales with the shard count.
pub struct Shard {
    pub ring: RpcRing,
    /// Lock-free bump arena for typed-call arguments and replies
    /// (None when creation failed or was disabled: allocation falls
    /// back to the heap).
    pub arena: Option<ArgArena>,
    /// In-flight calls currently routed to this shard (two-choice
    /// occupancy signal; maintained by `Connection::route`/`unroute`
    /// only when two-choice striping is on).
    pub depth: AtomicU64,
    /// Contention signal: claim attempts that found this shard's ring
    /// full. Halved on each later first-try claim success, so a past
    /// congestion episode decays once the shard sees traffic again —
    /// while a wedged shard (held claims) stays penalized, which is
    /// the point. Traffic-driven decay alone had a blind spot: under
    /// light load a once-congested shard could sit exiled forever
    /// (siblings' depth never climbs past its stale counter, so it is
    /// never re-picked and never gets the claim success that decays
    /// it). A lazy **time-based** decay closes it: whenever the
    /// two-choice pick examines a shard, the counter is halved once
    /// per elapsed [`CLAIM_FAIL_DECAY`] window since the last recorded
    /// fail/decay. A *wedged* shard still stays penalized in practice:
    /// each re-pick that hits its full ring re-charges the counter
    /// (and stamps the clock), so the penalty only drains while the
    /// shard stops failing claims — exactly the "merely stale" case.
    pub claim_fails: AtomicU64,
    /// Nanoseconds (on the connection's clock) of the last claim-fail
    /// charge or time-decay sweep — the lazy-decay reference point.
    fail_stamp_ns: AtomicU64,
}

/// Half-life window of the time-based `claim_fails` decay. Long
/// relative to a claim timeout burst (so a shard that *just* trapped
/// callers stays exiled while they reroute) but short relative to a
/// workload's lifetime — a stale penalty drains in a few hundred ms
/// even if the shard never sees the claim success that traffic-driven
/// decay needs. The cost of decaying a *truly* wedged shard is
/// bounded: one re-picked caller per half-life re-charges the counter
/// (and re-stamps the clock) at its first failed claim.
pub(crate) const CLAIM_FAIL_DECAY: Duration = Duration::from_millis(100);

/// Elastic growth trigger: a shard whose `claim_fails` counter reaches
/// this while routed-to doubles the active window. Low enough that a
/// congested window reacts within one claim-timeout burst, high
/// enough that a single full-ring blip doesn't double the footprint.
pub(crate) const ELASTIC_GROW_FAILS: u64 = 8;

/// Elastic shrink cadence: every this-many route() calls, one caller
/// checks whether the upper half of the active window is quiescent
/// (zero depth, zero claim-fails) and halves it if so. Amortizes the
/// O(active/2) scan to nothing on the hot path.
pub(crate) const ELASTIC_SHRINK_PERIOD: u64 = 1024;

/// How long a `AdmissionPolicy::Queue` connect waits for a live
/// connection to close before giving up with a timeout.
pub(crate) const ADMIT_QUEUE_WAIT: Duration = Duration::from_millis(500);

impl Shard {
    fn new(ring: RpcRing, arena: Option<ArgArena>) -> Shard {
        Shard {
            ring,
            arena,
            depth: AtomicU64::new(0),
            claim_fails: AtomicU64::new(0),
            fail_stamp_ns: AtomicU64::new(0),
        }
    }

    /// Charge one claim fail (ring found full) and stamp the clock so
    /// time-based decay measures from the most recent congestion.
    #[inline]
    fn note_claim_fail(&self, now_ns: u64) {
        self.claim_fails.fetch_add(1, Ordering::Relaxed);
        self.fail_stamp_ns.store(now_ns, Ordering::Relaxed);
    }

    /// Lazy time-based decay: halve `claim_fails` once per elapsed
    /// [`CLAIM_FAIL_DECAY`] window since the last fail/decay. Racy-
    /// lossy like the success decay (a heuristic; lost updates
    /// self-correct on the next sweep).
    pub(crate) fn decay_claim_fails_by_time(&self, now_ns: u64) {
        let f = self.claim_fails.load(Ordering::Relaxed);
        if f == 0 {
            return;
        }
        let last = self.fail_stamp_ns.load(Ordering::Relaxed);
        let win = CLAIM_FAIL_DECAY.as_nanos() as u64;
        let elapsed = now_ns.saturating_sub(last);
        if elapsed < win {
            return;
        }
        let halvings = (elapsed / win).min(63) as u32;
        self.claim_fails.store(f >> halvings, Ordering::Relaxed);
        self.fail_stamp_ns.store(now_ns, Ordering::Relaxed);
    }

    /// The two-choice load estimate: occupancy + recent contention.
    /// One relaxed load each; cheap enough to probe on every pick.
    #[inline]
    pub fn load_estimate(&self) -> u64 {
        self.depth.load(Ordering::Relaxed) + self.claim_fails.load(Ordering::Relaxed)
    }

    /// Halve the contention penalty after a first-try claim success
    /// (racy-lossy on purpose: it is only a heuristic, and lost decays
    /// self-correct on the next success).
    #[inline]
    fn decay_claim_fails(&self) {
        let f = self.claim_fails.load(Ordering::Relaxed);
        if f > 0 {
            self.claim_fails.store(f / 2, Ordering::Relaxed);
        }
    }
}

pub struct ConnShared {
    pub id: u64,
    pub heap: Arc<Heap>,
    /// The sharded data path (never empty; single-shard by default).
    pub shards: Vec<Shard>,
    pub sealer: Arc<Sealer>,
    pub sandbox: Arc<SandboxMgr>,
    pub client_proc: u32,
    pub server_proc: u32,
    /// RDMA-fallback page-ownership state (None ⇒ CXL connection).
    pub dsm: Option<Arc<DsmState>>,
    /// DSM node ids of the two endpoints (the client's pod and the
    /// server's — made distinct even when a DSM transport is forced
    /// inside one pod). Meaningless when `dsm` is None.
    pub client_node: PodId,
    pub server_node: PodId,
    /// Connection birth — the clock the shards' lazy claim-fail decay
    /// measures against.
    born: Instant,
    closed: AtomicBool,
    /// Failure plane: set (together with `closed`) when the
    /// orchestrator's recovery sweep declares the *other* endpoint
    /// dead. Waiters consult it to surface [`RpcError::PeerFailed`]
    /// instead of a bare `ConnectionClosed`, so retry/reconnect
    /// policies can tell a crash from a clean teardown.
    peer_failed: AtomicBool,
    accepted: AtomicBool,
    /// Elastic shard routing on: callers stripe over the *active*
    /// window (`active_shards`), which grows/shrinks in power-of-two
    /// steps inside the fixed capacity `shards.len()`. Off = fixed
    /// striping over all shards, byte for byte the pre-elastic path.
    elastic: bool,
    /// Routing-window width (power of two ≤ `shards.len()`); only
    /// consulted when `elastic`. Servers always sweep ALL capacity
    /// shards, so a shrink needs no handoff coordination: in-flight
    /// requests on deactivated shards complete normally, per-thread
    /// pins keep FIFO threads on their shard until drained, and new
    /// routes simply stop picking the upper half.
    active_shards: AtomicUsize,
    /// Route-call counter driving the periodic shrink check.
    route_ops: AtomicU64,
    /// Admitted shed-class (AdmissionPolicy::Shed over the limit):
    /// served with minimal drain budget so overload degrades this
    /// connection first.
    shed: AtomicBool,
}

impl ConnShared {
    pub fn closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Did the other endpoint die (lease expiry → recovery sweep)?
    pub fn peer_failed(&self) -> bool {
        self.peer_failed.load(Ordering::Acquire)
    }

    /// Recovery sweep: mark the peer dead and wake every waiter. The
    /// peer flag lands before `closed` so a waiter woken by the close
    /// can never observe `closed && !peer_failed` and misreport a
    /// crash as a clean teardown. Every shard's response doorbell
    /// rings so parked callers re-check promptly instead of riding
    /// out their full timeout.
    pub fn fail_peer(&self) {
        self.peer_failed.store(true, Ordering::Release);
        self.closed.store(true, Ordering::Release);
        for sh in &self.shards {
            sh.ring.resp_bell().ring();
            sh.ring.req_bell().ring();
        }
    }

    /// The error a call on a dead connection surfaces: `PeerFailed`
    /// when the recovery sweep declared the other endpoint dead,
    /// plain `ConnectionClosed` for a clean teardown.
    pub(crate) fn dead_err(&self, what: &str) -> RpcError {
        if self.peer_failed() {
            RpcError::PeerFailed(format!("peer process died ({what})"))
        } else {
            RpcError::ConnectionClosed
        }
    }

    /// Nanoseconds since the connection was created (shard decay clock).
    #[inline]
    pub(crate) fn now_ns(&self) -> u64 {
        self.born.elapsed().as_nanos() as u64
    }

    pub fn is_dsm(&self) -> bool {
        self.dsm.is_some()
    }

    /// Shard 0's ring — the entire data path on single-shard
    /// connections (tests and handcrafted-request call sites).
    #[inline]
    pub fn ring(&self) -> &RpcRing {
        &self.shards[0].ring
    }

    #[inline]
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard this thread stripes to (stable per thread, so FIFO
    /// within a shard covers per-thread program order). Elastic
    /// connections stripe over the active window only.
    #[inline]
    pub(crate) fn shard_for_thread(&self) -> (usize, &Shard) {
        // Both the capacity and the active window are powers of two.
        let i = thread_stripe() & (self.route_shards() - 1);
        (i, &self.shards[i])
    }

    /// Width of the routing window: the elastic active count, or the
    /// full capacity when elastic routing is off (one branch — the
    /// fixed path pays no atomics).
    #[inline]
    pub(crate) fn route_shards(&self) -> usize {
        if self.elastic {
            self.active_shards.load(Ordering::Acquire)
        } else {
            self.shards.len()
        }
    }

    /// Elastic active-window width (== capacity when elastic is off).
    pub fn active_shard_count(&self) -> usize {
        self.route_shards()
    }

    /// Admitted as shed-class (served with minimal budget)?
    pub fn is_shed(&self) -> bool {
        self.shed.load(Ordering::Acquire)
    }

    /// Elastic growth hook, called on a failed claim: sustained
    /// pressure (ELASTIC_GROW_FAILS fails recorded against the routed
    /// shard) doubles the active window, up to capacity. The
    /// triggering shard's counter resets so the *next* doubling needs
    /// fresh evidence — otherwise one hot shard's backlog would climb
    /// the window to capacity in one burst.
    pub(crate) fn note_pressure(&self, si: usize) {
        if !self.elastic {
            return;
        }
        if self.shards[si].claim_fails.load(Ordering::Relaxed) < ELASTIC_GROW_FAILS {
            return;
        }
        let cur = self.active_shards.load(Ordering::Acquire);
        if cur >= self.shards.len() {
            return;
        }
        if self
            .active_shards
            .compare_exchange(cur, (cur * 2).min(self.shards.len()), Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            self.shards[si].claim_fails.store(0, Ordering::Relaxed);
        }
    }

    /// Elastic shrink check (amortized: one caller per
    /// ELASTIC_SHRINK_PERIOD route() calls runs it). Halves the
    /// active window when its upper half is fully quiescent — no
    /// in-flight routes, no ring occupancy, no recent claim fails.
    /// Shrink is advisory: servers sweep all capacity shards
    /// regardless, so a request that raced onto a deactivated shard
    /// still completes, and pinned threads drain before re-striping.
    fn maybe_shrink(&self) {
        let cur = self.active_shards.load(Ordering::Acquire);
        if cur <= 1 {
            return;
        }
        let half = cur / 2;
        for sh in &self.shards[half..cur] {
            if sh.depth.load(Ordering::Relaxed) != 0
                || sh.claim_fails.load(Ordering::Relaxed) != 0
                || !sh.ring.quiescent()
            {
                return;
            }
        }
        let _ = self
            .active_shards
            .compare_exchange(cur, half, Ordering::AcqRel, Ordering::Acquire);
    }

    /// Per-route elastic bookkeeping: count the call and run the
    /// periodic shrink check. No-op (never called) when elastic is
    /// off.
    #[inline]
    pub(crate) fn elastic_tick(&self) {
        let n = self.route_ops.fetch_add(1, Ordering::Relaxed);
        if n % ELASTIC_SHRINK_PERIOD == ELASTIC_SHRINK_PERIOD - 1 {
            self.maybe_shrink();
        }
    }

    /// No in-flight work on any shard (drain/shutdown paths and the
    /// argument-quarantine sweep).
    pub fn quiescent(&self) -> bool {
        self.shards.iter().all(|s| s.ring.quiescent())
    }

    /// Per-shard claim-ticket counts — how traffic actually striped
    /// (bench/test telemetry).
    pub fn shard_claims(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.ring.claimed()).collect()
    }

    /// The shard arena holding `addr`, if any (reply/argument
    /// provenance: arena addresses must never reach the heap's
    /// header-tagged free path).
    fn arena_containing(&self, addr: usize) -> Option<&ArgArena> {
        self.shards.iter().filter_map(|s| s.arena.as_ref()).find(|a| a.contains(addr))
    }

    /// Reclaim the reply of a response that was discarded into an
    /// abandoned (timed-out) lap. Only arena provenance is provably
    /// an owned allocation — a heap `ret` word may be a scalar or a
    /// borrowed pointer — and the call's own argument range is
    /// excluded: a handler may echo its argument pointer back, and
    /// that memory belongs to the caller (reclaimed through the
    /// quarantine), so releasing it here would double-release.
    pub(crate) fn reclaim_discarded_reply(&self, ret: u64, arg: usize, arg_len: usize) {
        let addr = ret as usize;
        if addr >= arg && addr < arg + arg_len.max(1) {
            return;
        }
        if let Some(a) = self.arena_containing(addr) {
            a.release(addr);
        }
    }
}

/// Which fabric a connection should ride (paper §4.7: "Channels in
/// RPCool automatically use either CXL-based shared memory or fall
/// back to RDMA").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TransportSel {
    /// CXL when both hosts share the rack, RDMA otherwise.
    #[default]
    Auto,
    Cxl,
    Rdma,
}

// ---------------------------------------------------------------------
// server

struct Accepting {
    queue: Vec<Arc<ConnShared>>,
}

pub struct ServerCore {
    pub name: String,
    pub env: ProcEnv,
    opts: ChannelOpts,
    handlers: RwLock<HashMap<u32, Handler>>,
    conns: Mutex<Vec<Arc<ConnShared>>>,
    accepting: Mutex<Accepting>,
    accept_cv: Condvar,
    stop: AtomicBool,
    /// Set (together with `stop`) when a fault-injection kill point
    /// fired on one of this core's serving threads. A killed proc
    /// died *without cleanup*: the in-process handles that remain must
    /// not run the clean-teardown path (`Drop for RpcServer`) a real
    /// crashed process could never run — the recovery sweep (and a
    /// registered standby) owns the pieces instead.
    killed: AtomicBool,
    next_conn_id: AtomicU64,
    daemon: Arc<Daemon>,
    /// The shared channel-wide heap, if `opts.shared_heap`.
    shared_heap: Mutex<Option<Arc<Heap>>>,
    served: AtomicU64,
    /// Channel-wide request doorbell. Dedicated-listener mode: every
    /// connection's `publish()` rings it, so a single parked listener
    /// wakes for any of them (`SleepPolicy::Park`). Pooled mode:
    /// connections get private per-shard bells instead, and this bell
    /// carries only accept events into the pool's waiter tree.
    bell: Arc<Doorbell>,
    /// The daemon-wide worker pool serving this channel
    /// (`opts.pool_workers > 0`); `None` = dedicated listeners.
    pool: Option<Arc<pool::WorkerPool>>,
}

/// Server-side channel handle (the paper's `RPC rpc; rpc.open(...)`).
pub struct RpcServer {
    core: Arc<ServerCore>,
}

impl RpcServer {
    /// Open a channel: create the registration with the orchestrator
    /// (26.5ms-class operation in the paper's Table 1b).
    pub fn open(env: &ProcEnv, name: &str, opts: ChannelOpts) -> Result<RpcServer> {
        let rack = &env.rack;
        let charger = &rack.pool.charger;
        charger.charge_ns(charger.cost.channel_create_us * 1000);

        let daemon = Daemon::new(env.host, Arc::clone(&rack.orch));
        // Pooled serving: channels on one (orchestrator, host) share
        // the daemon-wide worker pool, so worker count stays
        // decoupled from channel count.
        let wpool = if opts.pool_workers > 0 {
            Some(daemon.worker_pool(opts.pool_workers))
        } else {
            None
        };
        let core = Arc::new(ServerCore {
            name: name.to_string(),
            env: env.clone(),
            opts: opts.clone(),
            handlers: RwLock::new(HashMap::new()),
            conns: Mutex::new(Vec::new()),
            accepting: Mutex::new(Accepting { queue: Vec::new() }),
            accept_cv: Condvar::new(),
            stop: AtomicBool::new(false),
            killed: AtomicBool::new(false),
            next_conn_id: AtomicU64::new(1),
            daemon,
            shared_heap: Mutex::new(None),
            served: AtomicU64::new(0),
            bell: Doorbell::new_arc(),
            pool: wpool,
        });
        if let Some(p) = &core.pool {
            // The accept slot: connect()'s channel-bell ring now pops
            // a pool worker, which adopts the queued connection.
            p.register_accept(&core);
        }

        // Register with the orchestrator: a placeholder heap id is
        // fine until the first connection exists.
        rack.orch.register_channel(ChannelReg {
            name: name.to_string(),
            owner_proc: env.proc,
            owner_uid: env.uid,
            acl: opts.acl.clone().unwrap_or_else(|| Acl::open(env.uid)),
            heap_id: 0,
        })?;
        directory_insert(rack.id, name, &core);

        // Failure plane: when the lease sweep declares a proc dead,
        // this channel reaps whatever that proc stranded.
        register_death_hook(&core);
        Ok(RpcServer { core })
    }

    /// Claim the handle of a channel the recovery sweep resurrected
    /// into this proc (a standby registered via
    /// [`ChannelBuilder::standby`]). Adoption happens inside the
    /// sweep, which has no one to hand the new server to — the handle
    /// parks in a process-global registry until the standby claims
    /// it. Returns `None` if no adoption happened (or it was already
    /// claimed).
    pub fn take_adopted(env: &ProcEnv, name: &str) -> Option<RpcServer> {
        let core =
            ADOPTED.lock().unwrap().as_mut()?.remove(&(env.rack.id, name.to_string()))?;
        Some(RpcServer { core })
    }

    /// Register a handler under a function id (the paper's `rpc.add`).
    /// The raw registration: the handler decodes `CallCtx::arg` itself
    /// and returns the raw `ret` word (a scalar or a native shm
    /// pointer). The typed layers (`serve`, `serve_opt`,
    /// `serve_scalar`) are built on top of this.
    pub fn add(&self, func: u32, f: impl Fn(&CallCtx) -> Result<u64> + Send + Sync + 'static) {
        self.core.handlers.write().unwrap().insert(func, Box::new(f));
    }

    /// Typed handler registration: decode the argument as `A`, run the
    /// handler, allocate its `R` reply in the connection heap. Clients
    /// receive it as a [`Reply<R>`] via `Connection::call_typed` (and
    /// own the reply buffer: `Reply::take`/`Reply::free` reclaim it).
    pub fn serve<A: Pod, R: Pod>(
        &self,
        func: u32,
        f: impl Fn(&CallCtx, &A) -> Result<R> + Send + Sync + 'static,
    ) {
        self.add(func, move |ctx| {
            let arg = ctx.arg_typed::<A>()?;
            let reply = f(ctx, &arg)?;
            ctx.reply_val(reply)
        });
    }

    /// Typed handler with an optional reply: `Ok(None)` becomes the
    /// null reply (`Reply::is_none()` on the client).
    pub fn serve_opt<A: Pod, R: Pod>(
        &self,
        func: u32,
        f: impl Fn(&CallCtx, &A) -> Result<Option<R>> + Send + Sync + 'static,
    ) {
        self.add(func, move |ctx| match f(ctx, &ctx.arg_typed::<A>()?)? {
            Some(reply) => ctx.reply_val(reply),
            None => ctx.reply_none(),
        });
    }

    /// Typed argument, raw `u64` return word (for value-returning
    /// handlers where a heap-allocated reply would be overhead).
    pub fn serve_scalar<A: Pod>(
        &self,
        func: u32,
        f: impl Fn(&CallCtx, &A) -> Result<u64> + Send + Sync + 'static,
    ) {
        self.add(func, move |ctx| f(ctx, &ctx.arg_typed::<A>()?));
    }

    /// Block until a client connects; returns its connection.
    pub fn accept(&self) -> Result<Arc<ConnShared>> {
        let mut acc = self.core.accepting.lock().unwrap();
        loop {
            if let Some(c) = acc.queue.pop() {
                c.accepted.store(true, Ordering::Release);
                self.core.conns.lock().unwrap().push(Arc::clone(&c));
                return Ok(c);
            }
            if self.core.stop.load(Ordering::Acquire) {
                return Err(RpcError::ConnectionClosed);
            }
            let (a, timeout) = self
                .core
                .accept_cv
                .wait_timeout(acc, Duration::from_millis(50))
                .unwrap();
            acc = a;
            let _ = timeout;
        }
    }

    /// Serve every accepted connection until `stop()` — the paper's
    /// `conn->listen()`, generalized over all of the channel's
    /// connections (one event-loop thread, busy-waiting per §5.8).
    pub fn listen(&self) {
        self.listen_worker(0);
    }

    /// One worker of a (possibly multi-worker) serving loop — the
    /// **drain-k** server: each sweep takes up to `drain_k` requests
    /// per shard per connection, answers them with `respond_quiet`,
    /// and rings the shard's response doorbell **once** per sweep
    /// (`flush_respond`), so the reply-side charged cost per RPC is
    /// 1/B signals (B ≤ k the achieved coalesce factor) instead of 1.
    /// Each worker starts its sweep at a different shard offset so `k`
    /// workers don't convoy on shard 0, and the per-sweep budget keeps
    /// the sweep fair — one flooded shard can't starve its siblings
    /// for more than k requests. FIFO within a shard is preserved even
    /// with several workers — `take_request` hands out requests in
    /// ticket order.
    pub fn listen_worker(&self, worker: usize) {
        self.core.env.enter();
        let policy = self.core.opts.sleep;
        let park = policy == SleepPolicy::Park;
        let drain_k = self.core.opts.drain_k.max(1);
        // Armed only while this listener is idle enough to park, so
        // the loaded case keeps every publish()'s `ring()` at a
        // single atomic load.
        let mut armed = false;
        let mut idle_polls: u32 = 0;
        LOAD.enter();
        while !self.core.stop.load(Ordering::Acquire) {
            // Epoch *before* the work scan (once armed): a publish
            // that lands mid-scan advances it, so the park below
            // returns immediately instead of missing the request.
            let seen = if armed { self.core.bell.epoch() } else { 0 };
            // Accept anything pending without blocking.
            {
                let mut acc = self.core.accepting.lock().unwrap();
                while let Some(c) = acc.queue.pop() {
                    c.accepted.store(true, Ordering::Release);
                    self.core.conns.lock().unwrap().push(c);
                }
            }
            let conns: Vec<Arc<ConnShared>> = self.core.conns.lock().unwrap().clone();
            let mut progress = false;
            for conn in &conns {
                let nsh = conn.shards.len();
                // Shed-class connections keep only a minimal budget:
                // admitted under overload, degraded first, by policy.
                let budget = if conn.is_shed() { 1 } else { drain_k };
                loop {
                    let mut took = false;
                    for k in 0..nsh {
                        let si = (worker + k) % nsh;
                        // Drain up to k requests from this shard with
                        // quiet replies, then one coalesced doorbell
                        // for the whole sweep (`serve_shard`, shared
                        // with the worker pool — it carries the
                        // response-side kill points). The flush MUST
                        // run before the worker moves on (and
                        // certainly before it parks): every quiet
                        // respond is covered by a flush on its own
                        // shard, which is the no-lost-wakeup
                        // invariant the waiters rely on.
                        if self.core.serve_shard(conn, si, budget) > 0 {
                            took = true;
                        }
                    }
                    if !took {
                        break;
                    }
                    progress = true;
                    if self.core.stop.load(Ordering::Acquire) {
                        break;
                    }
                }
            }
            if progress {
                idle_polls = 0;
                if armed {
                    self.core.bell.disarm();
                    armed = false;
                }
            } else if park {
                idle_polls += 1;
                if idle_polls >= PARK_SPIN_POLLS {
                    if !armed {
                        // Arm, then rescan once with the bell live —
                        // a publish between the scan and arming would
                        // otherwise be missed until the slice expires.
                        self.core.bell.arm();
                        armed = true;
                        continue;
                    }
                    // Block on the channel doorbell (sliced so stop()
                    // and new connections are never missed for long).
                    LOAD.exit();
                    self.core
                        .bell
                        .wait_past(seen, Duration::from_micros(PARK_SLICE_US));
                    LOAD.enter();
                }
            } else {
                let us = policy.sleep_us(LOAD.load());
                if us > 0 {
                    std::thread::sleep(Duration::from_micros(us));
                } else {
                    std::hint::spin_loop();
                }
            }
        }
        LOAD.exit();
        if armed {
            self.core.bell.disarm();
        }
    }

    /// Spawn the listen loop on a server thread.
    pub fn spawn_listener(&self) -> std::thread::JoinHandle<()> {
        let s = RpcServer { core: Arc::clone(&self.core) };
        std::thread::spawn(move || s.listen())
    }

    /// Spawn `k` listener workers serving the channel in parallel
    /// (the multi-worker drain a sharded data path is built for).
    /// Worker `i` starts its shard sweep at offset `i`; all workers
    /// may take from any shard, so one stalled shard never idles the
    /// rest. Join all handles after `stop()`.
    pub fn spawn_listeners(&self, k: usize) -> Vec<std::thread::JoinHandle<()>> {
        if let Some(p) = &self.core.pool {
            // Pooled channel: no per-channel threads at all — the
            // daemon-wide pool (grown to at least k workers, capped
            // at MAX_POOL_WORKERS) serves this channel through the
            // waiter tree. Nothing to join.
            p.ensure_workers(k.max(1));
            return Vec::new();
        }
        (0..k.max(1))
            .map(|w| {
                let s = RpcServer { core: Arc::clone(&self.core) };
                std::thread::spawn(move || s.listen_worker(w))
            })
            .collect()
    }

    pub fn stop(&self) {
        self.core.stop.store(true, Ordering::Release);
        self.core.accept_cv.notify_all();
        // Pooled channel: withdraw every tree slot now so pool
        // workers stop touching this core (idempotent; sweeps also
        // self-clean on the stop flag).
        if let Some(p) = &self.core.pool {
            p.forget_core(&self.core);
        }
        // Wake a parked listener so it observes the stop flag now
        // rather than at the end of its park slice.
        self.core.bell.ring();
    }

    /// Accept all pending connections without blocking (used together
    /// with inline serving, where no listener thread runs).
    pub fn accept_pending(&self) {
        let mut acc = self.core.accepting.lock().unwrap();
        while let Some(c) = acc.queue.pop() {
            c.accepted.store(true, Ordering::Release);
            self.core.conns.lock().unwrap().push(c);
        }
    }

    /// Handle to the server core (for `Connection::attach_inline`).
    pub fn core(&self) -> Arc<ServerCore> {
        Arc::clone(&self.core)
    }

    pub fn served(&self) -> u64 {
        self.core.served.load(Ordering::Relaxed)
    }

    pub fn connection_count(&self) -> usize {
        self.core.conns.lock().unwrap().len()
    }

    pub fn name(&self) -> &str {
        &self.core.name
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        // A core killed by fault injection died *without cleanup*:
        // its surviving in-process handles must not run the clean
        // teardown a real crashed process could never run — the
        // recovery sweep (and a registered standby adopter) owns its
        // pieces. Unregistering/closing here would clobber the
        // resurrected channel's state (stale-death latching).
        if self.core.killed.load(Ordering::Acquire) {
            return;
        }
        // Last handle (beyond any listener threads' core refs) tears
        // the channel down: 38.4ms-class destroy in Table 1b.
        self.stop();
        if Arc::strong_count(&self.core) <= 2 {
            let rack = &self.core.env.rack;
            let charger = &rack.pool.charger;
            charger.charge_ns(charger.cost.channel_destroy_us * 1000);
            // Both removals are owner/identity-guarded: a stale
            // handle dropped after another proc re-registered the
            // same name must not evict the new registration.
            rack.orch.unregister_channel_owned(&self.core.name, self.core.env.proc);
            directory_remove_if(rack.id, &self.core.name, &self.core);
            for c in self.core.conns.lock().unwrap().iter() {
                c.closed.store(true, Ordering::Release);
            }
        }
    }
}

/// Failure plane: register this core's recovery obligation with the
/// lease sweep. For a dead *client*, the channel reaps whatever the
/// client stranded. For the dead *owner*, either a registered standby
/// adopts the channel (resurrection) or every surviving client is
/// failed promptly. Weak so a closed channel prunes itself from the
/// hook list; a successfully adopted channel prunes its old core's
/// hook too (the resurrected core registered its own).
fn register_death_hook(core: &Arc<ServerCore>) {
    let rack = &core.env.rack;
    let weak = Arc::downgrade(core);
    let fault = rack.orch.fault_counters();
    rack.orch.on_proc_death(Box::new(move |dead| {
        let Some(core) = weak.upgrade() else { return false };
        if dead == core.env.proc {
            // The channel owner itself died. A registered standby
            // resurrects the channel instead of tearing it down...
            if core.opts.standby.is_some() {
                match adopt_channel(&core, &fault) {
                    Ok(_) => return false,
                    Err(e) => {
                        eprintln!(
                            "standby adoption of '{}' failed ({e}); tearing down",
                            core.name
                        );
                    }
                }
            }
            // ...otherwise (or if adoption failed): stop the core,
            // withdraw its worker-pool slots, fail every surviving
            // client promptly (their in-flight waits resolve with
            // PeerFailed, not a full timeout), and reclaim any DSM
            // pages the dead owner's node still holds so survivors
            // sharing the heap never fault against a corpse.
            core.stop.store(true, Ordering::Release);
            core.accept_cv.notify_all();
            if let Some(p) = &core.pool {
                p.forget_core(&core);
            }
            for c in core.conns.lock().unwrap().iter() {
                c.fail_peer();
                if let Some(dsm) = &c.dsm {
                    let (bumps, pages) = dsm.reclaim_dead(c.server_node, c.client_node);
                    if bumps > 0 {
                        fault.add(crate::orchestrator::FLT_EPOCH_BUMPS, bumps);
                        fault.add(crate::orchestrator::FLT_PAGES_RECLAIMED, pages);
                    }
                }
            }
            core.bell.ring();
        } else {
            core.reap_dead_client(dead, &fault);
        }
        true
    }));
}

/// Crash resurrection (the paper's CoolDB restart story): adopt a
/// dead owner's channel into its registered standby proc. The standby
/// re-opens the same shared heap (mapping it under its own lease),
/// inherits the handler table (modeling the standby re-registering
/// the same handler set), drains every surviving connection's rings
/// via [`RpcRing::reap_server_death`] — stranded requests answer
/// `ST_CLOSED` so live idempotent callers retry, stranded replies get
/// the doorbell ring the corpse never sent — and starts serving again
/// on the *same* channel doorbell, so existing clients' publishes
/// wake the resurrected listener with no client-side rebinding.
/// Driven by the sweep's death hook; [`Daemon::adopt_channel`] is the
/// public entry.
pub(crate) fn adopt_channel(
    old: &Arc<ServerCore>,
    fault: &crate::metrics::CounterSet,
) -> Result<Arc<ServerCore>> {
    use crate::orchestrator::{FLT_ADOPTIONS, FLT_SLOTS_REAPED};
    let standby = old
        .opts
        .standby
        .clone()
        .ok_or_else(|| RpcError::Config("channel has no registered standby".into()))?;
    let rack = &old.env.rack;
    // The standby maps the owner's heaps directly, so it must live in
    // the owner's pod (cross-pod standby would need a DSM remap of
    // every surviving mapping — not modeled).
    if rack.pod_of(standby.host) != rack.pod_of(old.env.host) {
        return Err(RpcError::Config(format!(
            "standby host {} is outside the dead owner's pod",
            standby.host
        )));
    }
    // The corpse's core stops serving for good.
    old.stop.store(true, Ordering::Release);
    old.accept_cv.notify_all();
    if let Some(p) = &old.pool {
        p.forget_core(old);
    }
    // Adoption models the standby re-opening the channel.
    let charger = &rack.pool.charger;
    charger.charge_ns(charger.cost.channel_create_us * 1000);
    let daemon = Daemon::new(standby.host, Arc::clone(&rack.orch));
    let wpool = if old.opts.pool_workers > 0 {
        Some(daemon.worker_pool(old.opts.pool_workers))
    } else {
        None
    };
    // One resurrection per registration: the adopted core comes up
    // with no standby of its own unless re-armed by the operator.
    let mut opts = old.opts.clone();
    opts.standby = None;
    let shared = old.shared_heap.lock().unwrap().clone();
    let handlers = std::mem::take(&mut *old.handlers.write().unwrap());
    let conns: Vec<Arc<ConnShared>> = std::mem::take(&mut *old.conns.lock().unwrap());
    let queued: Vec<Arc<ConnShared>> =
        std::mem::take(&mut old.accepting.lock().unwrap().queue);
    let core = Arc::new(ServerCore {
        name: old.name.clone(),
        env: standby.clone(),
        opts,
        handlers: RwLock::new(handlers),
        conns: Mutex::new(Vec::new()),
        accepting: Mutex::new(Accepting { queue: queued }),
        accept_cv: Condvar::new(),
        stop: AtomicBool::new(false),
        killed: AtomicBool::new(false),
        next_conn_id: AtomicU64::new(old.next_conn_id.load(Ordering::Relaxed)),
        daemon,
        shared_heap: Mutex::new(shared.clone()),
        served: AtomicU64::new(old.served.load(Ordering::Relaxed)),
        // Existing clients' publishes ring the *old* channel bell
        // (dedicated-mode shards hold clones of it): the resurrected
        // listener must wake on the same bell, so it is inherited,
        // not replaced.
        bell: Arc::clone(&old.bell),
        pool: wpool,
    });
    // Re-register under the standby's identity. Sweep phase 1 removed
    // the dead owner's registration before any hook ran, so the name
    // is normally free; losing it to a faster re-opener aborts the
    // adoption (the re-opener owns the name now).
    rack.orch.register_channel(ChannelReg {
        name: core.name.clone(),
        owner_proc: standby.proc,
        owner_uid: standby.uid,
        acl: core.opts.acl.clone().unwrap_or_else(|| Acl::open(standby.uid)),
        heap_id: shared.as_ref().map_or(0, |h| h.id),
    })?;
    directory_insert(rack.id, &core.name, &core);
    // Map the surviving heaps for the standby: it acquires its own
    // leases, so the resurrection stays up only while the standby
    // renews — exactly a first-class participant.
    let spod = rack.pod_of(standby.host);
    if let Some(h) = &shared {
        core.daemon.map_heap_from(h.id, standby.proc, spod)?;
    }
    // Adopt every surviving connection, reaping the dead server's
    // half of each ring *before* any resurrected worker serves:
    // stranded PROCESSING slots answer ST_CLOSED (live callers retry)
    // and never-flushed replies finally get their doorbell ring.
    let mut reaped = 0u64;
    for c in &conns {
        if !core.opts.shared_heap {
            core.daemon.map_heap_from(c.heap.id, standby.proc, spod)?;
        }
        for sh in &c.shards {
            reaped += sh.ring.reap_server_death();
        }
        core.conns.lock().unwrap().push(Arc::clone(c));
    }
    if reaped > 0 {
        fault.add(FLT_SLOTS_REAPED, reaped);
    }
    // Serve again: pooled channels re-enter the waiter tree with
    // every adopted connection re-attached; dedicated channels get
    // one resurrected listener thread under the standby's identity.
    if let Some(p) = &core.pool {
        p.register_accept(&core);
        let adopted: Vec<Arc<ConnShared>> = core.conns.lock().unwrap().clone();
        for c in adopted {
            p.adopt(&core, c);
        }
        p.ensure_workers(core.opts.pool_workers.max(1));
    } else {
        let s = RpcServer { core: Arc::clone(&core) };
        std::thread::spawn(move || s.listen());
    }
    register_death_hook(&core);
    adopted_insert(rack.id, &core.name, &core);
    fault.add(FLT_ADOPTIONS, 1);
    core.bell.ring();
    Ok(core)
}

/// [`adopt_channel`] wrapped in a server handle — the public entry,
/// via [`Daemon::adopt_channel`].
pub(crate) fn adopt_channel_into(
    old: &Arc<ServerCore>,
    fault: &crate::metrics::CounterSet,
) -> Result<RpcServer> {
    adopt_channel(old, fault).map(|core| RpcServer { core })
}

impl ServerCore {
    /// Process one request slot of one shard (the server's hot path),
    /// ringing the response doorbell per reply. Public so inline
    /// serving can drive it from the caller thread (inline serving
    /// stays eager: the caller *is* the waiter, so deferring its
    /// wakeup would only add latency).
    pub fn handle_slot(&self, conn: &Arc<ConnShared>, shard: usize, slot: usize) {
        self.handle_slot_opts(conn, shard, slot, false)
    }

    /// Quiet variant for the drain-k serving loop: replies via
    /// `respond_quiet`/`respond_fault_quiet`, leaving the single
    /// coalesced `flush_respond` per shard per sweep to the caller.
    pub fn handle_slot_quiet(&self, conn: &Arc<ConnShared>, shard: usize, slot: usize) {
        self.handle_slot_opts(conn, shard, slot, true)
    }

    /// Drain up to `budget` requests from one shard with quiet
    /// replies, then one coalesced response doorbell — the worker
    /// pool's unit of serving (one shard iteration of
    /// `listen_worker`'s sweep, factored out). Returns the number
    /// drained; a full-budget return means the shard may still hold
    /// requests whose publish rings were already consumed, so pooled
    /// callers must reschedule it (`WaiterTree::kick`).
    pub(crate) fn serve_shard(&self, conn: &Arc<ConnShared>, si: usize, budget: usize) -> usize {
        let sh = &conn.shards[si];
        let mut drained = 0usize;
        while drained < budget {
            match sh.ring.take_request() {
                Some(slot) => {
                    self.handle_slot_quiet(conn, si, slot);
                    drained += 1;
                }
                None => break,
            }
        }
        if self.killed.load(Ordering::Acquire) {
            // A kill point fired inside the drain (mid_serve /
            // dsm_owner): the proc is dead mid-sweep, so even earlier
            // quiet replies of this sweep go unflushed — exactly the
            // stranding the recovery path undoes.
            return drained;
        }
        if drained > 0 {
            // Kill point: die between the sweep's quiet responds and
            // the coalesced flush — every reply of the sweep is
            // written (state-wise complete) but the doorbell never
            // rings, so the waiters sleep through their own answers
            // until recovery wakes them.
            if crate::fault::should_die(crate::fault::KillPoint::MidRespond) {
                self.stop.store(true, Ordering::Release);
                self.killed.store(true, Ordering::Release);
                crate::memory::heap::park_thread_magazines(self.env.proc);
                return drained;
            }
            // `post_respond` rides inside the probed flush: the
            // signal cost is charged, the bell never rings.
            if sh.ring.flush_respond_probed() {
                self.stop.store(true, Ordering::Release);
                self.killed.store(true, Ordering::Release);
                crate::memory::heap::park_thread_magazines(self.env.proc);
            }
        }
        drained
    }

    /// Accept every queued connection without blocking and return the
    /// newly accepted batch (the worker pool's adoption path; the
    /// dedicated listener inlines the same dance in its sweep).
    pub(crate) fn adopt_pending(&self) -> Vec<Arc<ConnShared>> {
        let mut out = Vec::new();
        let mut acc = self.accepting.lock().unwrap();
        while let Some(c) = acc.queue.pop() {
            c.accepted.store(true, Ordering::Release);
            self.conns.lock().unwrap().push(Arc::clone(&c));
            out.push(c);
        }
        out
    }

    /// The per-host daemon mediating this channel's heap mappings
    /// (lease renewal rides through it — crash tests drive survivor
    /// renewals here).
    pub fn daemon(&self) -> &Arc<Daemon> {
        &self.daemon
    }

    /// Live connections from this channel's point of view: accepted,
    /// not yet closed, **and lease-backed** — a connection whose
    /// client proc no longer holds a live lease is a crash in
    /// progress and stops counting against the admission ceiling the
    /// instant its lease lapses, before the recovery sweep even runs.
    /// Anything still queued for accept counts too.
    fn live_conns(&self) -> usize {
        let orch = &self.env.rack.orch;
        self.conns
            .lock()
            .unwrap()
            .iter()
            .filter(|c| !c.closed() && orch.proc_holds_lease(c.client_proc))
            .count()
            + self.accepting.lock().unwrap().queue.len()
    }

    /// Failure plane: one dead *client* proc's stranded state, reaped
    /// from every connection it held on this channel. Ring slots it
    /// left CLAIMED / published / mid-serve are tombstoned
    /// ([`RpcRing::reap_dead`]), its installed seals are revoked
    /// through the descriptor ring, the server's own mapping of a
    /// per-connection heap is released so the orphaned heap can be
    /// reclaimed, and the dead connections leave the serving list.
    fn reap_dead_client(&self, dead: u32, fault: &crate::metrics::CounterSet) {
        use crate::orchestrator::{
            FLT_EPOCH_BUMPS, FLT_PAGES_RECLAIMED, FLT_SEALS_FORCED, FLT_SLOTS_REAPED,
        };
        let victims: Vec<Arc<ConnShared>> = {
            let mut conns = self.conns.lock().unwrap();
            let v = conns.iter().filter(|c| c.client_proc == dead).cloned().collect();
            conns.retain(|c| c.client_proc != dead);
            v
        };
        for c in victims {
            // Peer flag first: a waiter woken by the reap's doorbell
            // rings must classify the death correctly.
            c.fail_peer();
            let mut reaped = 0u64;
            for sh in &c.shards {
                reaped += sh.ring.reap_dead();
            }
            if reaped > 0 {
                fault.add(FLT_SLOTS_REAPED, reaped);
            }
            let seals = c.sealer.revoke_proc(dead);
            if seals > 0 {
                fault.add(FLT_SEALS_FORCED, seals);
            }
            // DSM connections: every page the dead client's node
            // still owns is reclaimed to the surviving server's node
            // with an epoch bump, so no future accessor faults
            // against a corpse (and the corpse's own late transfer
            // CAS, if any, can never land).
            if let Some(dsm) = &c.dsm {
                let (bumps, pages) = dsm.reclaim_dead(c.client_node, c.server_node);
                if bumps > 0 {
                    fault.add(FLT_EPOCH_BUMPS, bumps);
                    fault.add(FLT_PAGES_RECLAIMED, pages);
                }
            }
            // Mirror Connection::drop's server-side unmap: with the
            // client gone for good, holding our lease would pin the
            // orphaned per-connection heap forever.
            if !self.opts.shared_heap {
                self.daemon.unmap_heap(c.heap.id, self.env.proc);
            }
        }
    }

    /// Admission decision for one incoming connect: what happens once
    /// `conn_limit` live connections exist (tentpole part 3 — overload
    /// degrades by policy, not collapse). Returns whether the new
    /// connection is **shed-class**. Policy table (DESIGN.md §12):
    /// Open always admits; Reject fails fast; Queue waits (bounded)
    /// for a slot to free; Shed admits but marks the connection for
    /// minimal serving budget.
    fn admit(&self) -> Result<bool> {
        use crate::orchestrator::{ADM_ADMITTED, ADM_QUEUED, ADM_REJECTED, ADM_SHED};
        let orch = &self.env.rack.orch;
        let limit = self.opts.conn_limit;
        if limit == 0 || self.live_conns() < limit {
            orch.admission().add(ADM_ADMITTED, 1);
            return Ok(false);
        }
        match self.opts.admission {
            AdmissionPolicy::Open => {
                orch.admission().add(ADM_ADMITTED, 1);
                Ok(false)
            }
            AdmissionPolicy::Reject => {
                orch.admission().add(ADM_REJECTED, 1);
                Err(RpcError::ConnectionRefused(
                    self.name.clone(),
                    format!("admission: channel at capacity ({limit} connections)"),
                ))
            }
            AdmissionPolicy::Queue => {
                orch.admission().add(ADM_QUEUED, 1);
                let deadline = Instant::now() + ADMIT_QUEUE_WAIT;
                while limit != 0 && self.live_conns() >= limit {
                    if self.stop.load(Ordering::Acquire) {
                        return Err(RpcError::ConnectionClosed);
                    }
                    if Instant::now() >= deadline {
                        return Err(RpcError::Timeout(
                            "admission queue (channel at capacity)".into(),
                        ));
                    }
                    std::thread::sleep(Duration::from_micros(50));
                }
                orch.admission().add(ADM_ADMITTED, 1);
                Ok(false)
            }
            AdmissionPolicy::Shed => {
                orch.admission().add(ADM_SHED, 1);
                Ok(true)
            }
        }
    }

    fn handle_slot_opts(&self, conn: &Arc<ConnShared>, shard: usize, slot: usize, quiet: bool) {
        // Kill point: the serving proc dies *after* `take_request`
        // moved the slot to PROCESSING, before any reply. The slot
        // stays stranded (no respond, no tombstone) until recovery
        // tombstones it; the core stops as the dead server's threads
        // unwind, and the thread's heap magazines strand like a real
        // crash would leave them.
        if crate::fault::should_die(crate::fault::KillPoint::MidServe) {
            self.stop.store(true, Ordering::Release);
            self.killed.store(true, Ordering::Release);
            crate::memory::heap::park_thread_magazines(self.env.proc);
            return;
        }
        let sh = &conn.shards[shard];
        let s = sh.ring.slot(slot);
        let func = s.func.load(Ordering::Relaxed);
        let flags = s.flags.load(Ordering::Relaxed);
        let seal_idx = s.seal_idx.load(Ordering::Relaxed);
        let arg = s.arg.load(Ordering::Relaxed) as usize;
        let arg_len = s.arg_len.load(Ordering::Relaxed) as usize;

        // Reply through the quiet (sweep-flushed) or eager doorbell,
        // same tombstone arbitration either way.
        let reply = |st: u32, ret: u64| -> bool {
            if quiet {
                sh.ring.respond_quiet(slot, st, ret)
            } else {
                sh.ring.respond(slot, st, ret)
            }
        };
        let reply_fault = |st: u32, ret: u64, aux_lo: u64, aux_hi: u64| -> bool {
            if quiet {
                sh.ring.respond_fault_quiet(slot, st, ret, aux_lo, aux_hi)
            } else {
                sh.ring.respond_fault(slot, st, ret, aux_lo, aux_hi)
            }
        };

        // RDMA fallback: fault the argument pages over to the server
        // (paper §5.6 — load triggers fault, fetch, re-execute).
        if let Some(dsm) = &conn.dsm {
            if arg != 0 {
                if let Err(e) = dsm.ensure_owned(conn.server_node, arg, arg_len.max(1)) {
                    if matches!(e, RpcError::Killed(_)) {
                        // Kill point `dsm_owner` fired inside the
                        // transfer: the serving proc died *holding*
                        // the page it just took (the owner word names
                        // a corpse) and mid-slot (PROCESSING, no
                        // reply) — the sweep's epoch reclamation and
                        // ring reap own both pieces.
                        self.stop.store(true, Ordering::Release);
                        self.killed.store(true, Ordering::Release);
                        return;
                    }
                    reply(ST_HANDLER_ERROR, 0);
                    return;
                }
            }
        }

        // Seal verification (receiver side, §5.3): refuse to process
        // if the sender claims a seal that doesn't check out.
        let sealed = flags & FLAG_SEALED != 0;
        if sealed && !conn.sealer.verify(seal_idx, arg, arg_len.max(1)) {
            reply(ST_SEAL_INVALID, 0);
            return;
        }

        let handlers = self.handlers.read().unwrap();
        let Some(handler) = handlers.get(&func) else {
            reply(ST_NO_HANDLER, 0);
            return;
        };

        let result = if flags & FLAG_SANDBOXED != 0 {
            // Enter the MPK sandbox over the argument window; a
            // violation surfaces as Err and becomes an error response
            // (the SIGSEGV → RPC-error path of §5.2).
            match conn.sandbox.begin(arg, arg_len.max(1)) {
                Ok(guard) => {
                    let ctx = CallCtx {
                        heap: &conn.heap,
                        arena: sh.arena.as_ref(),
                        func,
                        arg,
                        arg_len,
                        sealed,
                        sandboxed: true,
                        temp: Some(guard.temp()),
                    };
                    let r = handler(&ctx);
                    drop(guard);
                    r
                }
                Err(e) => Err(e),
            }
        } else {
            let ctx = CallCtx {
                heap: &conn.heap,
                arena: sh.arena.as_ref(),
                func,
                arg,
                arg_len,
                sealed,
                sandboxed: false,
                temp: None,
            };
            handler(&ctx)
        };

        // Mark the seal complete *before* responding so the sender's
        // release() check passes as soon as it sees the response.
        if sealed {
            conn.sealer.complete(seal_idx);
        }

        self.served.fetch_add(1, Ordering::Relaxed);
        match result {
            Ok(ret) => {
                let discarded = reply(ST_OK, ret);
                // The caller timed out and this response went nowhere:
                // reclaim an arena-allocated reply so one abandoned
                // call can't pin the arena forever.
                if discarded {
                    conn.reclaim_discarded_reply(ret, arg, arg_len);
                }
            }
            Err(RpcError::SandboxViolation { addr, lo, hi }) => {
                // Carry the real fault back: address in `ret`, the
                // sandbox window in the (now dead) argument words.
                reply_fault(ST_SANDBOX_VIOLATION, addr as u64, lo as u64, hi as u64);
            }
            Err(_) => {
                reply(ST_HANDLER_ERROR, 0);
            }
        }
    }
}

// ---------------------------------------------------------------------
// client connection

/// Timeout-detail marker for a claim-phase timeout (ring full, no
/// slot ever claimed): distinguishes "argument never published — safe
/// to release now" from a response timeout, where the server may
/// still read the argument and it must be quarantined.
pub(crate) const TIMEOUT_SLOT: &str = "rpc slot";

/// Does this call outcome leave its argument(s) possibly still
/// readable by the server? A response timeout or mid-call teardown ⇒
/// yes (quarantine); a claim-phase timeout ([`TIMEOUT_SLOT`] — the
/// address was never published) or any completed outcome ⇒ no.
fn arg_outstanding<T>(r: &Result<T>) -> bool {
    match r {
        Err(RpcError::Timeout(what)) => what != TIMEOUT_SLOT,
        Err(RpcError::ConnectionClosed) => true,
        // A peer-failure teardown raced the call mid-flight, and an
        // injected kill abandons whatever it already published — both
        // leave the address possibly server-readable.
        Err(RpcError::PeerFailed(_)) => true,
        Err(RpcError::Killed(_)) => true,
        _ => false,
    }
}

/// Client-side connection handle (the paper's `conn`).
pub struct Connection {
    pub shared: Arc<ConnShared>,
    env: ProcEnv,
    opts: ChannelOpts,
    daemon: Arc<Daemon>,
    calls: AtomicU64,
    /// Inline serving: after publishing a request, the caller thread
    /// runs the server's handler directly (under the server's
    /// identity). On a one-core simulation host this is the *correct*
    /// latency model — a real RPC is sequential (client → wire →
    /// server → wire → client), and all hardware costs are charged by
    /// spinning either way. Benchmarks use this; concurrency tests use
    /// `spawn_listener`.
    inline_server: Mutex<Option<Arc<ServerCore>>>,
    /// Arguments of timed-out calls the server may still read. They
    /// are released (recycling the arena) on a later call once the
    /// ring is quiescent — i.e. provably nobody is reading them.
    quarantine: Mutex<Vec<usize>>,
    /// Lock-free gate for the quarantine sweep (0 = nothing pending,
    /// so the hot path pays one relaxed load).
    quarantined: AtomicU64,
}

impl Connection {
    /// Connect to a channel by name (paper Table 1b: 0.4s-class —
    /// daemon maps the heap, orchestrator grants the lease).
    /// Transport is selected automatically: CXL inside the server's
    /// pod, RDMA/DSM across pods or beyond the rack.
    pub fn connect(env: &ProcEnv, name: &str) -> Result<Connection> {
        Self::connect_with(env, name, TransportSel::Auto)
    }

    /// Connect with reconnect semantics (failure plane): a client that
    /// lost its server to a crash spins here while the replacement
    /// re-opens the channel. Transient failures — channel not (yet)
    /// in the directory, admission rejection, a torn-down or
    /// peer-failed endpoint, timeouts — back off (jittered, seeded)
    /// and try again, up to the policy's attempt budget; anything
    /// else (ACL denial, config errors) fails immediately. Each
    /// re-attempt counts as a reconnect on the rack's fault counters.
    pub fn connect_retry(env: &ProcEnv, name: &str, policy: RetryPolicy) -> Result<Connection> {
        let mut attempt = 0u32;
        loop {
            let e = match Self::connect(env, name) {
                Ok(c) => return Ok(c),
                Err(e) => e,
            };
            attempt += 1;
            let transient = matches!(
                e,
                RpcError::ChannelNotFound(_)
                    | RpcError::ConnectionRefused(_, _)
                    | RpcError::ConnectionClosed
                    | RpcError::PeerFailed(_)
                    | RpcError::Timeout(_)
            );
            if attempt >= policy.attempts || !transient {
                return Err(e);
            }
            env.rack.orch.fault().add(crate::orchestrator::FLT_RECONNECTS, 1);
            std::thread::sleep(policy.backoff(attempt));
        }
    }

    pub fn connect_with(env: &ProcEnv, name: &str, sel: TransportSel) -> Result<Connection> {
        let rack = &env.rack;
        let core = directory_get(rack.id, name)
            .ok_or_else(|| RpcError::ChannelNotFound(name.to_string()))?;

        // ACL check through the orchestrator.
        rack.orch.check_connect(name, env.uid)?;

        // Admission policy (before any heap is created or cost
        // charged): over the channel's live-connection ceiling the
        // connect is rejected, queued, or admitted shed-class — by
        // policy, never by collapse.
        let shed = core.admit()?;

        let charger = &rack.pool.charger;
        charger.charge_ns(charger.cost.channel_connect_us * 1000);

        // Daemon creates (or reuses the shared) heap — homed in the
        // server's pod — and maps it for both endpoints. The client's
        // mapping carries its own pod, so a cross-pod client gets a
        // DSM-backed mapping instead of a direct CXL one.
        let cfg = &rack.cfg;
        let opts = core.opts.clone();
        let client_pod = rack.pod_of(env.host);
        let server_pod = rack.pod_of(core.env.host);
        let (heap, map_kind) = if opts.shared_heap {
            let mut sh = core.shared_heap.lock().unwrap();
            match &*sh {
                Some(h) => {
                    let (_, kind) = core.daemon.map_heap_from(h.id, env.proc, client_pod)?;
                    (Arc::clone(h), kind)
                }
                None => {
                    let h = core.daemon.create_heap_opts(
                        &format!("{name}/shared"),
                        opts.heap_bytes,
                        core.env.proc,
                        opts.magazine_cap,
                    )?;
                    let (_, kind) = core.daemon.map_heap_from(h.id, env.proc, client_pod)?;
                    *sh = Some(Arc::clone(&h));
                    (h, kind)
                }
            }
        } else {
            let id = core.next_conn_id.load(Ordering::Relaxed);
            let h = core.daemon.create_heap_opts(
                &format!("{name}/conn{id}"),
                opts.heap_bytes,
                core.env.proc,
                opts.magazine_cap,
            )?;
            let (_, kind) = core.daemon.map_heap_from(h.id, env.proc, client_pod)?;
            (h, kind)
        };

        // Fabric selection (paper §4.7): CXL if the client's mapping
        // of the server-pod heap is direct (same pod), otherwise the
        // RDMA-fallback coherence layer.
        let use_dsm = match sel {
            TransportSel::Cxl => false,
            TransportSel::Rdma => true,
            TransportSel::Auto => map_kind == MapKind::Dsm,
        };
        // Sharded data path: `ring_shards` rings + arg arenas, every
        // ring's publish() ringing the channel's bell so one parked
        // listener covers all connections and all shards.
        let signal_ns = if use_dsm { cfg.cost.rdma_oneway_ns } else { cfg.cost.cxl_signal_ns };
        let nshards = opts.ring_shards.clamp(1, 64).next_power_of_two();
        // The lock-free argument arenas ride in the connection heap;
        // cap the total so small heaps keep most of their space, and
        // degrade to heap-only allocation when a carve fails — or when
        // the per-shard share would round up past the cap (an arena is
        // at least one page, so many shards over a small heap would
        // otherwise multiply the carve beyond the budget).
        let arena_bytes = if opts.arg_arena_bytes == 0 {
            0
        } else {
            opts.arg_arena_bytes.min(heap.len() / 8) / nshards
        };
        let mut shards = Vec::with_capacity(nshards);
        for _ in 0..nshards {
            // Pooled channel: each shard gets a private request bell,
            // attached to the pool's waiter tree at adoption — the
            // tree records *which* shard rang, which the one shared
            // channel-wide bell cannot carry. Dedicated listeners
            // keep the shared bell (one parked listener covers all
            // connections and shards), byte for byte as before.
            let req_bell = if core.pool.is_some() {
                Doorbell::new_arc()
            } else {
                Arc::clone(&core.bell)
            };
            let ring = RpcRing::create_opts(&heap, opts.ring_slots, signal_ns, Some(req_bell))?;
            let arena = if arena_bytes < heap.page_size() {
                None
            } else {
                ArgArena::create(&heap, arena_bytes).ok()
            };
            shards.push(Shard::new(ring, arena));
        }
        // DSM node ids are the endpoints' pod ids; the forced-RDMA
        // same-pod case is a topology fact (see
        // `Topology::dsm_peer_nodes`), not a connect-site sentinel.
        let (client_node, server_node) = Topology::dsm_peer_nodes(client_pod, server_pod);
        let dsm = if use_dsm {
            Some(DsmState::new_multi(&heap, cfg.page_bytes, &[client_node, server_node], client_node))
        } else {
            None
        };

        let shared = Arc::new(ConnShared {
            id: core.next_conn_id.fetch_add(1, Ordering::Relaxed),
            shards,
            sealer: Sealer::new(cfg, Arc::clone(&heap), Arc::clone(charger))?,
            sandbox: SandboxMgr::new(cfg, Arc::clone(&heap), Arc::clone(charger)),
            heap,
            client_proc: env.proc,
            server_proc: core.env.proc,
            dsm,
            client_node,
            server_node,
            born: Instant::now(),
            closed: AtomicBool::new(false),
            peer_failed: AtomicBool::new(false),
            accepted: AtomicBool::new(false),
            elastic: opts.elastic_shards,
            // Elastic connections start narrow (one shard) and earn
            // width under pressure; fixed connections route over the
            // whole capacity from the first call, as always.
            active_shards: AtomicUsize::new(if opts.elastic_shards { 1 } else { nshards }),
            route_ops: AtomicU64::new(0),
            shed: AtomicBool::new(shed),
        });

        // Hand the connection to the server. The daemon+orchestrator
        // handshake (already charged above) completes the connect;
        // the server's accept/listen loop picks the connection up from
        // the queue before serving it.
        if core.stop.load(Ordering::Acquire) {
            return Err(RpcError::ConnectionRefused(
                name.to_string(),
                "server is shutting down".into(),
            ));
        }
        {
            let mut acc = core.accepting.lock().unwrap();
            acc.queue.push(Arc::clone(&shared));
            core.accept_cv.notify_one();
        }
        // A parked listener must wake to adopt the new connection.
        core.bell.ring();
        shared.accepted.store(true, Ordering::Release);

        Ok(Connection {
            shared,
            env: env.clone(),
            opts,
            daemon: Arc::clone(&core.daemon),
            calls: AtomicU64::new(0),
            inline_server: Mutex::new(None),
            quarantine: Mutex::new(Vec::new()),
            quarantined: AtomicU64::new(0),
        })
    }

    /// Switch this connection to inline serving (see field docs).
    pub fn attach_inline(&self, server: &RpcServer) {
        server.accept_pending();
        *self.inline_server.lock().unwrap() = Some(server.core());
    }

    pub fn heap(&self) -> &Arc<Heap> {
        &self.shared.heap
    }

    /// Allocate a value in the connection heap (paper's `conn->new_<T>`).
    pub fn new_val<T: Pod>(&self, v: T) -> Result<ShmPtr<T>> {
        Ok(ShmPtr::from_addr(self.shared.heap.new_val(v)?))
    }

    pub fn new_string(&self, s: &str) -> Result<ShmPtr<ShmString>> {
        let shm = ShmString::from_str(&self.shared.heap, s)?;
        self.new_val(shm)
    }

    pub fn new_vec<T: Pod>(&self, xs: &[T]) -> Result<ShmPtr<ShmVec<T>>> {
        let mut v: ShmVec<T> = ShmVec::with_capacity(&self.shared.heap, xs.len())?;
        v.extend_from_slice(&self.shared.heap, xs)?;
        self.new_val(v)
    }

    /// Create a scope in the connection heap (`create_scope`, §5.1).
    pub fn create_scope(&self, bytes: usize) -> Result<Scope> {
        Scope::create(&self.shared.heap, bytes)
    }

    /// Create a scope pool with batched seal release (§5.3).
    pub fn create_scope_pool(&self, scope_bytes: usize) -> Arc<ScopePool> {
        ScopePool::new(
            Arc::clone(&self.shared.heap),
            Arc::clone(&self.shared.sealer),
            scope_bytes,
            self.env.rack.cfg.batch_release_threshold,
        )
    }

    /// The fabric this connection resolved to: `Cxl` for in-rack
    /// shared memory, `Rdma` for the DSM fallback (§4.7). Never `Auto`.
    pub fn transport(&self) -> TransportSel {
        if self.shared.is_dsm() {
            TransportSel::Rdma
        } else {
            TransportSel::Cxl
        }
    }

    fn check_transport(&self, want: TransportSel) -> Result<()> {
        let have = self.transport();
        if want == TransportSel::Auto || want == have {
            return Ok(());
        }
        Err(RpcError::Config(format!(
            "call pinned to {want:?} but connection negotiated {have:?}"
        )))
    }

    /// Route a call (or a batch of `weight` calls) to a shard. With
    /// two-choice striping off — or a single shard — this is the
    /// fixed thread stripe and the lease is untracked (`weight 0`).
    /// With it on: if this thread already has calls in flight on this
    /// connection, the call **stays pinned** to that shard (responses
    /// within one shard complete in publish order, so the pin is what
    /// preserves per-thread FIFO across re-striping); once the thread
    /// has drained, it re-picks the less-loaded of its home shard and
    /// one random probe shard (power of two choices).
    ///
    /// Every `route` must be balanced by exactly one
    /// [`Connection::unroute`] when the routed call(s) complete —
    /// that is what keeps the `depth` occupancy signal honest.
    pub(crate) fn route(&self, weight: u64) -> Route {
        // Elastic connections always take the tracked path, over the
        // *active* window: the depth/claim-fail signals are what
        // drive grow/shrink, so they must be fed even while the
        // window is one shard wide. The fixed path keeps its
        // untracked fast outs byte for byte.
        let elastic = self.shared.elastic;
        if elastic {
            self.shared.elastic_tick();
        }
        let n = self.shared.route_shards();
        if !elastic && (n == 1 || !self.opts.two_choice) {
            let (si, _) = self.shared.shard_for_thread();
            return Route { si, weight: 0, pin: None };
        }
        let weight = weight.max(1);
        let key = Arc::as_ptr(&self.shared) as usize;
        let (si, pin) = SHARD_PINS.with(|cell| {
            let mut pins = cell.borrow_mut();
            if let Some(e) = pins.iter_mut().find(|e| e.key == key) {
                if e.outstanding.load(Ordering::Relaxed) == 0 {
                    // Drained: this thread is free to re-stripe.
                    e.shard = self.pick_two_choice(n);
                }
                e.outstanding.fetch_add(weight, Ordering::Relaxed);
                // The Arc clone is what lets a CallHandle moved to
                // another thread balance the books at completion —
                // one refcount bump here, one drop at unroute.
                (e.shard, Arc::clone(&e.outstanding))
            } else {
                // Miss (first call on this connection from this
                // thread): prune drained entries of dead connections
                // here, off the per-call hit path, so the table stays
                // a handful of live rows without a scan per call.
                pins.retain(|e| e.outstanding.load(Ordering::Relaxed) > 0);
                let si = self.pick_two_choice(n);
                let out = Arc::new(AtomicU64::new(weight));
                let pin = Arc::clone(&out);
                pins.push(PinEntry { key, shard: si, outstanding: out });
                (si, pin)
            }
        });
        self.shared.shards[si].depth.fetch_add(weight, Ordering::Relaxed);
        Route { si, weight, pin: Some(pin) }
    }

    /// Release a shard lease at call completion (consume, abandon, or
    /// any error after routing). Safe from any thread — a moved
    /// `CallHandle` completes elsewhere and still balances the books.
    pub(crate) fn unroute(&self, r: &Route) {
        if r.weight == 0 {
            return;
        }
        self.shared.shards[r.si].depth.fetch_sub(r.weight, Ordering::Relaxed);
        if let Some(p) = &r.pin {
            p.fetch_sub(r.weight, Ordering::Relaxed);
        }
    }

    /// The pick itself: home = thread stripe, plus `d-1` pseudo-random
    /// *other* probe shards (d = 2, growing to 4 on wide channels
    /// where two choices leave measurable imbalance on the table);
    /// least-loaded wins, home wins ties.
    ///
    /// Probes come from a per-thread xorshift64 stream — no shared
    /// atomic, no per-call mix of the call counter — so the pick adds
    /// three shifts and two xors to the fast path instead of a
    /// cross-core cache-line read.
    fn pick_two_choice(&self, n: usize) -> usize {
        // A one-wide window (elastic connections start here) has no
        // second choice to probe.
        if n == 1 {
            return 0;
        }
        let home = thread_stripe() & (n - 1);
        // d-1 distinct-from-home probes; wide channels (≥16 shards)
        // get d=4 — with only two choices the expected max load still
        // grows with shard count, and three extra relaxed loads are
        // cheap next to one mis-striped call.
        let extra = if n >= 16 { 3 } else { 1 };
        let now = self.shared.now_ns();
        // Lazy time-based decay on every candidate: a once-congested
        // shard must not sit exiled behind a stale counter when light
        // traffic never gives it the claim success that would decay it.
        self.shared.shards[home].decay_claim_fails_by_time(now);
        let mut best = home;
        let mut best_load = self.shared.shards[home].load_estimate();
        for _ in 0..extra {
            let r = probe_rng_next();
            let probe = (home + 1 + (r as usize % (n - 1))) & (n - 1);
            self.shared.shards[probe].decay_claim_fails_by_time(now);
            let load = self.shared.shards[probe].load_estimate();
            if load < best_load {
                best = probe;
                best_load = load;
            }
        }
        best
    }

    /// The one call core: argument is a native pointer into the
    /// connection heap (or a sealed scope), behaviour is composed from
    /// [`CallOpts`]. Returns the handler's raw `ret` word; the typed
    /// layers ([`Connection::call_typed`], [`Connection::call_scalar`])
    /// build on this.
    pub fn invoke(&self, func: u32, arg: impl Into<CallArg>, opts: CallOpts) -> Result<u64> {
        let arg = arg.into();
        self.with_retry(&opts, || {
            let route = self.route(1);
            let r = self.invoke_routed(&route, func, arg, opts);
            self.unroute(&route);
            r
        })
    }

    /// Run one call attempt under `opts`' [`RetryPolicy`] (failure
    /// plane): without one, exactly one attempt. Each retry counts on
    /// the rack's fault counters and sleeps the policy's jittered
    /// backoff first; which errors qualify is the policy's call
    /// ([`RetryPolicy::should_retry`] — claim-phase timeouts always,
    /// transport failures only if declared idempotent, app errors
    /// never).
    fn with_retry<T>(&self, opts: &CallOpts, mut f: impl FnMut() -> Result<T>) -> Result<T> {
        let Some(policy) = opts.retry_policy() else {
            return f();
        };
        let mut attempt = 0u32;
        loop {
            let e = match f() {
                Ok(v) => return Ok(v),
                Err(e) => e,
            };
            attempt += 1;
            if attempt >= policy.attempts || !policy.should_retry(&e) {
                return Err(e);
            }
            self.env.rack.orch.fault().add(crate::orchestrator::FLT_RETRIES, 1);
            std::thread::sleep(policy.backoff(attempt));
        }
    }

    /// [`Connection::invoke`] against a pre-picked shard (the typed
    /// layers route first so the argument allocation and the
    /// descriptor ride the same shard).
    fn invoke_routed(&self, route: &Route, func: u32, arg: CallArg, opts: CallOpts) -> Result<u64> {
        self.check_transport(opts.transport)?;
        let mut flags = 0u32;
        if opts.sandbox {
            flags |= FLAG_SANDBOXED;
        }
        match opts.seal {
            None => {
                self.call_inner_on(route, func, flags, NO_SEAL, arg.addr, arg.len, opts.timeout)
            }
            Some(scope) => {
                // Kill point: die holding a live scope — its pages
                // leak until the recovery sweep frees them through
                // the scope registry.
                if crate::fault::should_die(crate::fault::KillPoint::HoldingScope) {
                    crate::memory::heap::park_thread_magazines(self.env.proc);
                    return Err(crate::fault::killed_err(crate::fault::KillPoint::HoldingScope));
                }
                let h = self.seal_scope(scope)?;
                let r = self.call_inner_on(
                    route,
                    func,
                    flags | FLAG_SEALED,
                    h.idx,
                    arg.addr,
                    arg.len,
                    opts.timeout,
                );
                // Kill point: die still holding the installed seal —
                // it is never released, so its page-protection words
                // stay set until the sweep revokes the descriptor.
                if crate::fault::should_die(crate::fault::KillPoint::HoldingSeal) {
                    crate::memory::heap::park_thread_magazines(self.env.proc);
                    return Err(crate::fault::killed_err(crate::fault::KillPoint::HoldingSeal));
                }
                self.release_seal_forced(h);
                r
            }
        }
    }

    /// Release a seal after the call finished or aborted: normally the
    /// receiver marked it complete and `release` succeeds; on seal
    /// rejection (or any path where the receiver never completed)
    /// force-complete first so the sender reclaims write access.
    fn release_seal_forced(&self, h: SealHandle) {
        if self.shared.sealer.release(h).is_err() {
            self.shared.sealer.complete(h.idx);
            let _ = self.shared.sealer.release(h);
        }
    }

    /// Sealed call with *batched* release: `scope` is sealed for the
    /// call and then parked (still sealed) in `pool`; the pool
    /// releases a whole batch with one TLB shootdown when its
    /// threshold hits (§5.3). Composes with the remaining [`CallOpts`]
    /// knobs; the seal comes from `scope`, so passing
    /// `opts.sealed(..)` here is a contradiction and is rejected.
    pub fn invoke_pooled(
        &self,
        func: u32,
        pool: &ScopePool,
        scope: Scope,
        arg: impl Into<CallArg>,
        opts: CallOpts,
    ) -> Result<u64> {
        let arg = arg.into();
        if opts.seal.is_some() {
            return Err(RpcError::Config(
                "invoke_pooled seals the pooled scope itself; don't pass CallOpts::sealed".into(),
            ));
        }
        self.check_transport(opts.transport)?;
        let mut flags = FLAG_SEALED;
        if opts.sandbox {
            flags |= FLAG_SANDBOXED;
        }
        let h = self.seal_scope(&scope)?;
        let route = self.route(1);
        let r = self.call_inner_on(&route, func, flags, h.idx, arg.addr, arg.len, opts.timeout);
        self.unroute(&route);
        match r {
            Ok(r) => {
                pool.push_sealed(scope, h)?;
                Ok(r)
            }
            Err(e) => {
                // Don't park a failed call in the pool — release the
                // seal now so the scope's pages go back to the heap
                // writable.
                self.release_seal_forced(h);
                Err(e)
            }
        }
    }

    /// Typed-argument call with a raw `u64` reply: allocates a copy of
    /// `arg` (in the sealed scope when `opts` carries one — so the
    /// argument is actually covered by the seal — else lock-free from
    /// the connection's argument arena, spilling to the heap only
    /// when the arena is full) and invokes. The argument is released
    /// as soon as the call returns; arena space recycles when the
    /// last outstanding argument/reply is dropped.
    pub fn call_scalar<A: Pod>(&self, func: u32, arg: &A, opts: CallOpts) -> Result<u64> {
        self.with_retry(&opts, || self.call_scalar_once(func, arg, opts))
    }

    fn call_scalar_once<A: Pod>(&self, func: u32, arg: &A, opts: CallOpts) -> Result<u64> {
        // A dead connection fails fast *before* allocating, so retry
        // loops against it can't grow the quarantine (post-publish
        // teardown still quarantines, bounded by in-flight calls).
        if self.shared.closed() {
            return Err(self.shared.dead_err("call"));
        }
        self.sweep_quarantine();
        // Route before allocating: the argument must come from the
        // arena of the shard the descriptor will actually ride, so
        // the release hint stays exact under two-choice re-striping.
        let route = self.route(1);
        let r = (|| {
            let (addr, owned_on) = match opts.seal {
                Some(scope) => (scope.new_val(*arg)?, None),
                None => (self.alloc_arg_on(route.si, *arg)?, Some(route.si)),
            };
            let r = self.invoke_routed(
                &route,
                func,
                CallArg::new(addr, std::mem::size_of::<A>()),
                opts,
            );
            // On a response timeout / teardown the request may still be
            // queued or in flight server-side — recycling the argument
            // now would hand the server freshly-reused memory (the arena
            // resets to offset 0 on its last release, making reuse
            // immediate, and the heap free list is just as unsafe). Such
            // arguments go to the quarantine and are released once the
            // rings are provably quiet. A claim-phase timeout
            // (TIMEOUT_SLOT) never published the address, so it releases
            // right away, as does every outcome where the server finished.
            if let Some(si) = owned_on {
                if arg_outstanding(&r) {
                    self.quarantine_arg(addr);
                } else {
                    self.release_arg(si, addr);
                }
            }
            r
        })();
        self.unroute(&route);
        r
    }

    /// Allocate a typed-call argument on shard `si`: lock-free from
    /// that shard's arena, spilling to the heap mutex only when the
    /// arena is full. The shard index doubles as the release hint for
    /// [`Connection::release_arg`], so the common release is one
    /// range check instead of a scan over every shard's arena.
    fn alloc_arg_on<A: Pod>(&self, si: usize, arg: A) -> Result<usize> {
        match self.shared.shards[si].arena.as_ref().and_then(|a| a.alloc_val(arg)) {
            Some(addr) => Ok(addr),
            None => self.shared.heap.new_val(arg),
        }
    }

    /// Release an owned typed-call argument allocated by `alloc_arg`
    /// on shard `si` (the shard recorded at allocation time, so the
    /// hint stays exact even when a `CallHandle` completes on another
    /// thread): one arena range check, falling back to the heap for
    /// spilled allocations. Quarantined releases still route through
    /// `free_reply`'s full scan.
    pub(super) fn release_arg(&self, si: usize, addr: usize) {
        if let Some(a) = &self.shared.shards[si].arena {
            if a.contains(addr) {
                a.release(addr);
                return;
            }
        }
        self.shared.heap.free_bytes(addr);
    }

    /// Park a (possibly still server-readable) argument address for
    /// release once the rings are quiescent. Counter maintained under
    /// the lock: it's only an advisory fast-path gate, but keeping it
    /// exact avoids under/overflow races with the sweep.
    fn quarantine_arg(&self, addr: usize) {
        let mut q = self.quarantine.lock().unwrap();
        q.push(addr);
        self.quarantined.store(q.len() as u64, Ordering::Release);
    }

    /// Release quarantined (timed-out) arguments once nothing is in
    /// flight on the ring — at that point no handler can still be
    /// reading them. Called from the call path behind a single atomic
    /// load, so the common (empty-quarantine) case is free.
    fn sweep_quarantine(&self) {
        if self.quarantined.load(Ordering::Acquire) == 0 {
            return;
        }
        let pending = {
            // The quiescence check must run under the quarantine lock:
            // entries are pushed under the same lock, so everything in
            // the vec at check time belongs to a call whose slot we
            // are observing — a fresh timeout can't slip its (still
            // in-flight) argument into the batch after the check.
            // All shards must be quiet: the quarantined call rode one
            // of them, and we don't track which.
            let mut q = self.quarantine.lock().unwrap();
            if q.is_empty() || !self.shared.quiescent() {
                return;
            }
            let taken = std::mem::take(&mut *q);
            self.quarantined.store(0, Ordering::Release);
            taken
        };
        for addr in pending {
            self.free_reply(addr); // provenance-aware: arena or heap
        }
    }

    /// Fully typed call: `A` in, [`Reply<R>`] out. The reply borrows
    /// this connection and decodes the returned address through the
    /// checked-MMU path — no raw casts in user code.
    pub fn call_typed<'c, A: Pod, R: Pod>(
        &'c self,
        func: u32,
        arg: &A,
        opts: CallOpts,
    ) -> Result<Reply<'c, R>> {
        let ret = self.call_scalar(func, arg, opts)?;
        Ok(Reply::new(self, ret as usize))
    }

    /// Wrap a raw `ret` word (from [`Connection::invoke`]) as a typed
    /// [`Reply<R>`] — for call sites that build their argument by hand
    /// (e.g. in a scratch scope) but still want the safe reply decode.
    pub fn reply_from<R: Pod>(&self, ret: u64) -> Reply<'_, R> {
        Reply::new(self, ret as usize)
    }

    // -----------------------------------------------------------------
    // amortized submission: batched and asynchronous calls

    /// Batched submission: publish a slice of calls (same `func`,
    /// same `opts`) to this thread's shard with **one** doorbell
    /// signal per published chunk instead of one per call, then
    /// collect every response. Returns the raw `ret` words in
    /// argument order.
    ///
    /// Sealing is rejected (a seal's release is tied to a single
    /// call's return); compose per-call seals with [`Connection::invoke`].
    /// If any call in the batch fails, the first error is returned
    /// after every published slot has been consumed — arena-allocated
    /// replies of the other calls are reclaimed, so a failed batch
    /// cannot pin the arena. On a response timeout the remaining
    /// slots are abandoned (tombstoned) and the arguments may still
    /// be read by the server — callers that own them must quarantine,
    /// as [`Connection::call_scalar_batch`] does.
    pub fn invoke_batch(&self, func: u32, args: &[CallArg], opts: CallOpts) -> Result<Vec<u64>> {
        if opts.seal.is_some() {
            return Err(RpcError::Config(
                "invoke_batch cannot seal; use invoke for per-call seals".into(),
            ));
        }
        self.check_transport(opts.transport)?;
        if self.shared.closed() {
            return Err(self.shared.dead_err("call"));
        }
        if args.is_empty() {
            return Ok(Vec::new());
        }
        self.sweep_quarantine();
        let route = self.route(args.len() as u64);
        let r = self.invoke_batch_on(&route, func, args, opts);
        self.unroute(&route);
        r
    }

    /// [`Connection::invoke_batch`] against a pre-picked shard (the
    /// typed batch layer routes first, for the same argument/descriptor
    /// shard-coherence reason as `call_scalar`).
    fn invoke_batch_on(
        &self,
        route: &Route,
        func: u32,
        args: &[CallArg],
        opts: CallOpts,
    ) -> Result<Vec<u64>> {
        let timeout = opts.timeout.unwrap_or(self.opts.call_timeout);
        let deadline = Instant::now() + timeout;
        let mut flags = 0u32;
        if opts.sandbox {
            flags |= FLAG_SANDBOXED;
        }
        self.calls.fetch_add(args.len() as u64, Ordering::Relaxed);
        if let Some(dsm) = &self.shared.dsm {
            for a in args {
                if a.addr != 0 {
                    dsm.ensure_owned(self.shared.client_node, a.addr, a.len.max(1))?;
                }
            }
        }
        let shard_idx = route.si;
        let shard = &self.shared.shards[shard_idx];
        let ring = &shard.ring;
        let inline: Option<Arc<ServerCore>> =
            self.inline_server.lock().unwrap().as_ref().map(Arc::clone);

        let mut out: Vec<u64> = Vec::with_capacity(args.len());
        let mut first_err: Option<RpcError> = None;
        let mut idx = 0;
        while idx < args.len() && first_err.is_none() {
            // Kill point: die between chunks — earlier chunks are
            // fully in flight (the server may serve them into
            // abandoned-nothing), later ones never happen, and no
            // cleanup of either runs.
            if idx > 0 && crate::fault::should_die(crate::fault::KillPoint::MidBatch) {
                crate::memory::heap::park_thread_magazines(self.env.proc);
                return Err(crate::fault::killed_err(crate::fault::KillPoint::MidBatch));
            }
            // Claim a chunk: at least one slot (waiting on the
            // response doorbell if the ring is full), then as many
            // more as are free right now.
            let mut slots = Vec::new();
            let remain = deadline.saturating_duration_since(Instant::now());
            match self.claim_tracked(route, remain, inline.as_ref()) {
                Ok(i) => slots.push(i),
                Err(e) => {
                    // Nothing of this chunk published; earlier chunks
                    // were fully consumed — reclaim their replies,
                    // which would otherwise leak through the error
                    // return.
                    self.reclaim_batch_replies(&out, args);
                    return Err(e);
                }
            }
            while slots.len() < args.len() - idx {
                match ring.claim() {
                    Some(i) => slots.push(i),
                    None => break,
                }
            }
            // k quiet publishes, one flush: the whole point.
            for (k, &slot) in slots.iter().enumerate() {
                let a = args[idx + k];
                ring.publish_quiet(slot, func, flags, NO_SEAL, a.addr, a.len);
            }
            // Kill point: requests sit fully written in their slots
            // but the coalesced doorbell never rings — the server
            // sleeps through them until recovery reaps the ring.
            if crate::fault::should_die(crate::fault::KillPoint::PreFlush) {
                crate::memory::heap::park_thread_magazines(self.env.proc);
                return Err(crate::fault::killed_err(crate::fault::KillPoint::PreFlush));
            }
            ring.flush_publish();
            // Collect the chunk in claim order.
            for (k, &slot) in slots.iter().enumerate() {
                let a = args[idx + k];
                let remain = deadline.saturating_duration_since(Instant::now());
                let w = waiter::wait_on(self.opts.sleep, remain, None, Some(ring.resp_bell()), || {
                    if ring.response_ready(slot) || self.shared.closed() {
                        return true;
                    }
                    if let Some(core) = &inline {
                        self.drain_inline(core, Some((shard_idx, slot)));
                        if ring.response_ready(slot) {
                            return true;
                        }
                    }
                    false
                });
                if w == WaitOutcome::TimedOut
                    || (self.shared.closed() && !ring.response_ready(slot))
                {
                    // Abandon this and every later slot of the chunk
                    // (the late responses retire the laps), and
                    // reclaim the replies already collected — the
                    // batch fails as a whole, so they would leak
                    // through the error return.
                    for (j, &s) in slots.iter().enumerate().skip(k) {
                        let aj = args[idx + j];
                        self.abandon_and_reclaim(shard_idx, s, aj.addr, aj.len);
                    }
                    self.reclaim_batch_replies(&out, args);
                    return Err(if w == WaitOutcome::TimedOut {
                        RpcError::Timeout(format!("rpc batch response (func {func})"))
                    } else {
                        self.shared.dead_err("rpc batch response")
                    });
                }
                let (st, ret, lo, hi) = ring.consume_detail(slot);
                if st == ST_OK {
                    if first_err.is_some() {
                        // The batch already failed: don't leak this
                        // call's arena reply into the error return.
                        self.shared.reclaim_discarded_reply(ret, a.addr, a.len);
                    } else {
                        out.push(ret);
                    }
                } else if first_err.is_none() {
                    first_err = Some(status_to_error(st, func, ret, lo, hi));
                    // Replies collected before the failure would leak
                    // through the error return too.
                    self.reclaim_batch_replies(&out, args);
                    out.clear();
                }
            }
            idx += slots.len();
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    /// A failing batch returns `Err`, so the replies it already
    /// collected have no owner — reclaim the provably-owned (arena)
    /// ones so a failed batch cannot pin a shard arena. `out[j]`
    /// corresponds to `args[j]`: replies are collected in argument
    /// order and only while no error has been recorded.
    fn reclaim_batch_replies(&self, out: &[u64], args: &[CallArg]) {
        for (j, &r) in out.iter().enumerate() {
            let aj = args[j];
            self.shared.reclaim_discarded_reply(r, aj.addr, aj.len);
        }
    }

    /// Typed batched submission: allocate every argument (lock-free
    /// from this thread's shard arena, spilling to the heap), submit
    /// the whole slice with one doorbell per chunk, return the raw
    /// `ret` words in order. Arguments are released when the batch
    /// completes; on a response timeout / teardown they are
    /// quarantined exactly like [`Connection::call_scalar`]'s.
    pub fn call_scalar_batch<A: Pod>(
        &self,
        func: u32,
        args: &[A],
        opts: CallOpts,
    ) -> Result<Vec<u64>> {
        self.with_retry(&opts, || self.call_scalar_batch_once(func, args, opts))
    }

    fn call_scalar_batch_once<A: Pod>(
        &self,
        func: u32,
        args: &[A],
        opts: CallOpts,
    ) -> Result<Vec<u64>> {
        if opts.seal.is_some() {
            return Err(RpcError::Config(
                "call_scalar_batch cannot seal; use call_scalar for per-call seals".into(),
            ));
        }
        self.check_transport(opts.transport)?;
        if self.shared.closed() {
            return Err(self.shared.dead_err("call"));
        }
        if args.is_empty() {
            return Ok(Vec::new());
        }
        self.sweep_quarantine();
        // Route the whole batch once (one shard, pinned while in
        // flight), then stage every argument on that shard's arena.
        let route = self.route(args.len() as u64);
        let stripe = route.si;
        let mut addrs = Vec::with_capacity(args.len());
        let mut cargs = Vec::with_capacity(args.len());
        for a in args {
            match self.alloc_arg_on(stripe, *a) {
                Ok(addr) => {
                    addrs.push(addr);
                    cargs.push(CallArg::new(addr, std::mem::size_of::<A>()));
                }
                Err(e) => {
                    // Nothing published yet: the already-allocated
                    // arguments release immediately.
                    for &p in &addrs {
                        self.release_arg(stripe, p);
                    }
                    self.unroute(&route);
                    return Err(e);
                }
            }
        }
        let r = self.invoke_batch_on(&route, func, &cargs, opts);
        self.unroute(&route);
        if arg_outstanding(&r) {
            // Some slot may still be read by the server; which ones is
            // unknowable here, so quarantine the lot (the sweep frees
            // them once the rings are quiet).
            for &p in &addrs {
                self.quarantine_arg(p);
            }
        } else {
            for &p in &addrs {
                self.release_arg(stripe, p);
            }
        }
        r
    }

    /// Asynchronous submission: claim + publish now, return a
    /// [`CallHandle`] to `poll()`/`wait()` the completion later —
    /// callers pipeline RPCs instead of blocking one at a time.
    /// Sealing is rejected (its release is tied to a synchronous
    /// return); sandbox/timeout/transport compose as usual. Dropping
    /// the handle abandons the call safely.
    pub fn invoke_async(
        &self,
        func: u32,
        arg: impl Into<CallArg>,
        opts: CallOpts,
    ) -> Result<CallHandle<'_>> {
        let route = self.route(1);
        self.submit_async(route, func, arg.into(), opts, false)
    }

    /// Typed asynchronous submission: the argument is allocated like
    /// [`Connection::call_scalar`]'s and owned by the handle — it is
    /// released when the call completes (or quarantined if the handle
    /// is dropped while the server may still read it).
    pub fn call_scalar_async<A: Pod>(
        &self,
        func: u32,
        arg: &A,
        opts: CallOpts,
    ) -> Result<CallHandle<'_>> {
        // Seal/transport rejection lives in submit_async (one place);
        // a dead connection still fails fast before allocating, like
        // call_scalar.
        if self.shared.closed() {
            return Err(self.shared.dead_err("call"));
        }
        self.sweep_quarantine();
        let route = self.route(1);
        let addr = match self.alloc_arg_on(route.si, *arg) {
            Ok(a) => a,
            Err(e) => {
                self.unroute(&route);
                return Err(e);
            }
        };
        let si = route.si;
        match self.submit_async(route, func, CallArg::new(addr, std::mem::size_of::<A>()), opts, true)
        {
            Ok(h) => Ok(h),
            Err(e) => {
                // Every submit failure precedes the publish, so the
                // argument is provably unread and releases now (the
                // route was already released inside submit_async).
                self.release_arg(si, addr);
                Err(e)
            }
        }
    }

    /// Fully typed asynchronous submission: `A` in now, a
    /// [`TypedCallHandle<R>`] out, which resolves to the same
    /// [`Reply<R>`] a synchronous [`Connection::call_typed`] returns —
    /// apps pipeline pointer-returning RPCs (reads, scans, document
    /// fetches) instead of blocking one at a time. Completion, drop,
    /// and abandon semantics are [`CallHandle`]'s.
    pub fn call_typed_async<'c, A: Pod, R: Pod>(
        &'c self,
        func: u32,
        arg: &A,
        opts: CallOpts,
    ) -> Result<TypedCallHandle<'c, R>> {
        Ok(TypedCallHandle::new(self.call_scalar_async(func, arg, opts)?))
    }

    /// Takes ownership of `route` and releases it itself on every
    /// pre-publish failure; after a successful publish the lease
    /// transfers to the returned handle (released at `finish`/
    /// `abandon`).
    fn submit_async(
        &self,
        route: Route,
        func: u32,
        arg: CallArg,
        opts: CallOpts,
        own_arg: bool,
    ) -> Result<CallHandle<'_>> {
        match self.submit_async_inner(&route, func, arg, opts) {
            Ok((slot, timeout)) => Ok(CallHandle::new(self, route, slot, func, arg, own_arg, timeout)),
            Err(e) => {
                self.unroute(&route);
                Err(e)
            }
        }
    }

    fn submit_async_inner(
        &self,
        route: &Route,
        func: u32,
        arg: CallArg,
        opts: CallOpts,
    ) -> Result<(usize, Duration)> {
        if opts.seal.is_some() {
            return Err(RpcError::Config(
                "async calls cannot seal; use invoke for sealed calls".into(),
            ));
        }
        self.check_transport(opts.transport)?;
        if self.shared.closed() {
            return Err(self.shared.dead_err("call"));
        }
        self.calls.fetch_add(1, Ordering::Relaxed);
        let timeout = opts.timeout.unwrap_or(self.opts.call_timeout);
        if let Some(dsm) = &self.shared.dsm {
            if arg.addr != 0 {
                dsm.ensure_owned(self.shared.client_node, arg.addr, arg.len.max(1))?;
            }
        }
        let mut flags = 0u32;
        if opts.sandbox {
            flags |= FLAG_SANDBOXED;
        }
        let shard = &self.shared.shards[route.si];
        let inline: Option<Arc<ServerCore>> =
            self.inline_server.lock().unwrap().as_ref().map(Arc::clone);
        let slot = self.claim_tracked(route, timeout, inline.as_ref())?;
        shard.ring.publish(slot, func, flags, NO_SEAL, arg.addr, arg.len);
        Ok((slot, timeout))
    }

    /// Reclaim a server-allocated reply buffer (or an owned typed-call
    /// argument), resolving its provenance: arena addresses recycle
    /// lock-free in whichever shard arena holds them, heap addresses
    /// go back through `free_bytes`. (`Reply::free`/`take` route here
    /// — arena addresses must never reach the heap's header-tagged
    /// free path.)
    pub(crate) fn free_reply(&self, addr: usize) {
        match self.shared.arena_containing(addr) {
            Some(a) => a.release(addr),
            None => self.shared.heap.free_bytes(addr),
        }
    }

    /// The raw call. Deprecated: use [`Connection::invoke`].
    #[deprecated(note = "use `invoke(func, (arg, arg_len), CallOpts::new())`")]
    pub fn call(&self, func: u32, arg: usize, arg_len: usize) -> Result<u64> {
        self.invoke(func, (arg, arg_len), CallOpts::new())
    }

    /// Deprecated: use [`Connection::invoke`] (or `call_typed`).
    #[deprecated(note = "use `invoke(func, ptr, CallOpts::new())` or `call_typed`")]
    pub fn call_ptr<T: Pod>(&self, func: u32, arg: ShmPtr<T>) -> Result<u64> {
        self.invoke(func, arg, CallOpts::new())
    }

    /// Deprecated: use [`Connection::invoke`] with
    /// `CallOpts::new().sealed(&scope)`.
    #[deprecated(note = "use `invoke(func, (arg, arg_len), CallOpts::new().sealed(scope))`")]
    pub fn call_sealed(&self, func: u32, scope: &Scope, arg: usize, arg_len: usize) -> Result<u64> {
        self.invoke(func, (arg, arg_len), CallOpts::new().sealed(scope))
    }

    /// Deprecated: use [`Connection::invoke_pooled`].
    #[deprecated(note = "use `invoke_pooled(func, pool, scope, (arg, arg_len), CallOpts::new())`")]
    pub fn call_sealed_pooled(
        &self,
        func: u32,
        pool: &ScopePool,
        scope: Scope,
        arg: usize,
        arg_len: usize,
    ) -> Result<u64> {
        self.invoke_pooled(func, pool, scope, (arg, arg_len), CallOpts::new())
    }

    /// Deprecated: use [`Connection::invoke`] with
    /// `CallOpts::secure(&scope)`.
    #[deprecated(note = "use `invoke(func, (arg, arg_len), CallOpts::secure(scope))`")]
    pub fn call_secure(&self, func: u32, scope: &Scope, arg: usize, arg_len: usize) -> Result<u64> {
        self.invoke(func, (arg, arg_len), CallOpts::secure(scope))
    }

    /// Deprecated: use [`Connection::invoke`] with
    /// `CallOpts::new().sandboxed()`.
    #[deprecated(note = "use `invoke(func, (arg, arg_len), CallOpts::new().sandboxed())`")]
    pub fn call_sandboxed(&self, func: u32, arg: usize, arg_len: usize) -> Result<u64> {
        self.invoke(func, (arg, arg_len), CallOpts::new().sandboxed())
    }

    fn seal_scope(&self, scope: &Scope) -> Result<SealHandle> {
        // Seal only the touched pages (that is the whole point of
        // scopes), but at least one.
        let pages = scope.used_pages().max(1);
        let len = pages * self.env.rack.cfg.page_bytes;
        self.shared.sealer.seal(scope.base(), len, self.env.proc)
    }

    #[allow(clippy::too_many_arguments)]
    fn call_inner_on(
        &self,
        route: &Route,
        func: u32,
        flags: u32,
        seal_idx: u64,
        arg: usize,
        arg_len: usize,
        timeout: Option<Duration>,
    ) -> Result<u64> {
        let timeout = timeout.unwrap_or(self.opts.call_timeout);
        if self.shared.closed() {
            return Err(self.shared.dead_err("call"));
        }
        self.calls.fetch_add(1, Ordering::Relaxed);
        // RDMA fallback: the client must own the argument pages before
        // the server can be told about them (it wrote them, so any
        // pages the server took on a previous RPC fault back now).
        if let Some(dsm) = &self.shared.dsm {
            if arg != 0 {
                dsm.ensure_owned(self.shared.client_node, arg, arg_len.max(1))?;
            }
        }
        let shard_idx = route.si;
        let shard = &self.shared.shards[shard_idx];
        let ring = &shard.ring;
        // Inline serving: run the server's handlers on this thread
        // under the server's identity (the sequential-RTT model).
        // Serving stays *inside* the wait loops: requests are taken
        // in FIFO order per shard, so this thread may need to drain
        // other threads' earlier requests — on any shard — before its
        // own comes up.
        let inline: Option<Arc<ServerCore>> =
            self.inline_server.lock().unwrap().as_ref().map(Arc::clone);
        // Claim a slot (a full ring parks on the response doorbell —
        // consume() rings it when a slot frees). A full ring feeds
        // the shard's contention signal, which is what steers later
        // two-choice picks away from it.
        let slot = self.claim_tracked(route, timeout, inline.as_ref())?;
        ring.publish(slot, func, flags, seal_idx, arg, arg_len);
        let out = waiter::wait_on(
            self.opts.sleep,
            timeout,
            None,
            Some(ring.resp_bell()),
            || {
                if ring.response_ready(slot) || self.shared.closed() {
                    return true;
                }
                if let Some(core) = &inline {
                    self.drain_inline(core, Some((shard_idx, slot)));
                    if ring.response_ready(slot) {
                        return true;
                    }
                }
                false
            },
        );
        if out == WaitOutcome::TimedOut {
            // We will never consume this slot: leave a tombstone so a
            // late response retires the lap instead of wedging the
            // sequence-gated ring once `head` wraps back around.
            self.abandon_and_reclaim(shard_idx, slot, arg, arg_len);
            return Err(RpcError::Timeout(format!("rpc response (func {func})")));
        }
        if self.shared.closed() && !ring.response_ready(slot) {
            self.abandon_and_reclaim(shard_idx, slot, arg, arg_len);
            return Err(self.shared.dead_err("rpc response"));
        }
        let (status, ret, aux_lo, aux_hi) = ring.consume_detail(slot);
        match status {
            ST_OK => Ok(ret),
            other => Err(status_to_error(other, func, ret, aux_lo, aux_hi)),
        }
    }

    /// Claim a slot on the routed shard, feeding the two-choice
    /// contention signal: a first-try success decays the stale
    /// penalty, a full ring charges it once before falling into the
    /// doorbell-parked slow path. Every connection claim site routes
    /// through here so the load signal can't drift between call
    /// flavours. Untracked (fixed-striping) routes skip the counters
    /// entirely — the fixed baseline pays nothing, as documented.
    fn claim_tracked(
        &self,
        route: &Route,
        timeout: Duration,
        inline: Option<&Arc<ServerCore>>,
    ) -> Result<usize> {
        let shard = &self.shared.shards[route.si];
        let tracked = route.weight != 0;
        match shard.ring.claim() {
            Some(i) => {
                if tracked {
                    shard.decay_claim_fails();
                }
                Ok(i)
            }
            None => {
                if tracked {
                    shard.note_claim_fail(self.shared.now_ns());
                    // Elastic growth hook (no-op on fixed
                    // connections): sustained full-ring pressure on
                    // the routed shard doubles the active window.
                    self.shared.note_pressure(route.si);
                }
                self.claim_slow(&shard.ring, timeout, inline)
            }
        }
    }

    /// Wait for a claim ticket on a full ring, draining the server
    /// inline while waiting (without the drain, inline-served
    /// responses could never land and free a slot).
    fn claim_slow(
        &self,
        ring: &RpcRing,
        timeout: Duration,
        inline: Option<&Arc<ServerCore>>,
    ) -> Result<usize> {
        let mut got = None;
        let out = waiter::wait_on(self.opts.sleep, timeout, None, Some(ring.resp_bell()), || {
            if let Some(core) = inline {
                self.drain_inline(core, None);
            }
            got = ring.claim();
            // A connection torn down by the recovery sweep never
            // frees a slot again — wake and fail instead of parking
            // until the claim timeout.
            got.is_some() || self.shared.closed()
        });
        if out == WaitOutcome::TimedOut {
            return Err(RpcError::Timeout(TIMEOUT_SLOT.into()));
        }
        got.ok_or_else(|| self.shared.dead_err(TIMEOUT_SLOT))
    }

    /// Inline serving: drain pending requests across ALL shards
    /// (another thread's earlier request may sit on a different
    /// shard). With a `watch`ed `(shard, slot)`, stop as soon as that
    /// slot's response lands; with `None` (claim-phase waits), drain
    /// until nothing is pending.
    pub(super) fn drain_inline(&self, core: &Arc<ServerCore>, watch: Option<(usize, usize)>) {
        loop {
            let mut progress = false;
            for (si, sh) in self.shared.shards.iter().enumerate() {
                while let Some(i) = sh.ring.take_request() {
                    progress = true;
                    crate::simproc::with_identity(core.env.proc, core.env.host, || {
                        core.handle_slot(&self.shared, si, i)
                    });
                    if let Some((ws, slot)) = watch {
                        if self.shared.shards[ws].ring.response_ready(slot) {
                            return;
                        }
                    }
                }
            }
            if !progress {
                return;
            }
        }
    }

    /// Abandon a slot this caller will never consume and reclaim the
    /// orphaned reply if the response had already landed (only an OK
    /// response carries one; provenance resolved by `ConnShared`).
    /// Returns `true` when the response had landed — the server is
    /// provably done with the call, so its argument may be released
    /// immediately instead of quarantined.
    pub(super) fn abandon_and_reclaim(
        &self,
        shard: usize,
        slot: usize,
        arg: usize,
        arg_len: usize,
    ) -> bool {
        if let Some((st, ret)) = self.shared.shards[shard].ring.abandon(slot) {
            if st == ST_OK {
                self.shared.reclaim_discarded_reply(ret, arg, arg_len);
            }
            return true;
        }
        false
    }

    /// Clean close: unmap the heap (lease surrendered, quota credited).
    pub fn close(self) {
        // Drop runs the unmap.
    }

    pub fn calls_made(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Simulate a client crash: the connection vanishes without
    /// unmapping — leases must expire for cleanup (test hook).
    pub fn crash(self) {
        self.daemon.crash_proc(self.env.proc);
        std::mem::forget(self);
    }
}

impl Drop for Connection {
    fn drop(&mut self) {
        self.shared.closed.store(true, Ordering::Release);
        self.daemon.unmap_heap(self.shared.heap.id, self.env.proc);
        // A per-connection heap dies with the connection: release the
        // server's mapping too so the orchestrator can reclaim it and
        // credit the server's quota (paper §5.7: "When the last
        // process with access to a channel heap closes it, the heap is
        // automatically freed"). Channel-wide shared heaps live until
        // the channel goes down.
        if !self.opts.shared_heap {
            self.daemon.unmap_heap(self.shared.heap.id, self.shared.server_proc);
        }
    }
}

/// Paper-shaped facade (Fig. 6): `Rpc::open`, `rpc.add`, `rpc.accept`,
/// client `Rpc::connect`.
pub struct Rpc;

impl Rpc {
    pub fn open(env: &ProcEnv, name: &str) -> Result<RpcServer> {
        RpcServer::open(env, name, ChannelOpts::from_config(&env.rack.cfg))
    }

    pub fn connect(env: &ProcEnv, name: &str) -> Result<Connection> {
        Connection::connect(env, name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rack::Rack;

    fn serve_echo(rack: &Arc<Rack>, name: &str) -> (RpcServer, std::thread::JoinHandle<()>) {
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, name).unwrap();
        // 100 = ping→pong; 101 = typed u64 increment.
        server.add(100, |ctx| ctx.reply_string("pong"));
        server.serve::<u64, u64>(101, |_ctx, v| Ok(*v + 1));
        let t = server.spawn_listener();
        (server, t)
    }

    /// An echo channel whose handler 1 reports which safety flags the
    /// call arrived with: bit 0 = sealed, bit 1 = sandboxed.
    fn serve_flags(rack: &Arc<Rack>, name: &str) -> (RpcServer, std::thread::JoinHandle<()>) {
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, name).unwrap();
        server.add(1, |ctx| Ok((ctx.sealed as u64) | ((ctx.sandboxed as u64) << 1)));
        let t = server.spawn_listener();
        (server, t)
    }

    #[test]
    fn ping_pong_roundtrip() {
        // The paper's Fig. 6 program, end to end — typed, no raw casts.
        let rack = Rack::for_tests();
        let (server, t) = serve_echo(&rack, "mychannel");
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "mychannel").unwrap();
        cenv.run(|| {
            let ping = ShmString::from_str(conn.heap().as_ref(), "ping").unwrap();
            let reply = conn.call_typed::<ShmString, ShmString>(100, &ping, CallOpts::new()).unwrap();
            // Lifetime-bound view first, then take ownership of the buffer.
            assert!(reply.view().read().unwrap().eq_str("pong"));
            let pong: ShmString = reply.take().unwrap();
            assert_eq!(pong.to_string().unwrap(), "pong");
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn numeric_rpc_and_counters() {
        let rack = Rack::for_tests();
        let (server, t) = serve_echo(&rack, "nums");
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "nums").unwrap();
        cenv.run(|| {
            for i in 0..200u64 {
                let r = conn.call_typed::<u64, u64>(101, &i, CallOpts::new()).unwrap();
                assert_eq!(r.take().unwrap(), i + 1);
            }
        });
        assert_eq!(conn.calls_made(), 200);
        assert_eq!(server.served(), 200);
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn unknown_function_and_channel() {
        let rack = Rack::for_tests();
        let (server, t) = serve_echo(&rack, "known");
        let cenv = rack.proc_env(1);
        assert!(matches!(
            Rpc::connect(&cenv, "unknown"),
            Err(RpcError::ChannelNotFound(_))
        ));
        let conn = Rpc::connect(&cenv, "known").unwrap();
        let e = cenv.run(|| conn.call_scalar::<u64>(999, &1, CallOpts::new()));
        assert!(matches!(e, Err(RpcError::NoSuchHandler(999))));
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn callopts_compose_all_legacy_variants() {
        // The four legacy call shapes are exactly the 2×2 seal/sandbox
        // matrix — all expressible (and composable) through CallOpts,
        // including the sealed+sandboxed "secure" combination.
        let rack = Rack::for_tests();
        let (server, t) = serve_flags(&rack, "compose");
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "compose").unwrap();
        cenv.run(|| {
            let scope = conn.create_scope(4096).unwrap();
            let addr = scope.new_val(0u64).unwrap();
            // plain (old `call`)
            assert_eq!(conn.invoke(1, (), CallOpts::new()).unwrap(), 0b00);
            // sealed only (old `call_sealed`)
            assert_eq!(
                conn.invoke(1, (addr, 8), CallOpts::new().sealed(&scope)).unwrap(),
                0b01
            );
            // sandboxed only (old `call_sandboxed`)
            assert_eq!(
                conn.invoke(1, (addr, 8), CallOpts::new().sandboxed()).unwrap(),
                0b10
            );
            // sealed + sandboxed (old `call_secure`)
            assert_eq!(conn.invoke(1, (addr, 8), CallOpts::secure(&scope)).unwrap(), 0b11);
            let o = CallOpts::secure(&scope);
            assert!(o.is_sealed() && o.is_sandboxed());
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_shims_route_through_invoke() {
        let rack = Rack::for_tests();
        let (server, t) = serve_flags(&rack, "shims");
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "shims").unwrap();
        cenv.run(|| {
            let scope = conn.create_scope(4096).unwrap();
            let addr = scope.new_val(0u64).unwrap();
            assert_eq!(conn.call(1, 0, 0).unwrap(), 0b00);
            assert_eq!(conn.call_ptr(1, ShmPtr::<u64>::from_addr(addr)).unwrap(), 0b00);
            assert_eq!(conn.call_sealed(1, &scope, addr, 8).unwrap(), 0b01);
            assert_eq!(conn.call_sandboxed(1, addr, 8).unwrap(), 0b10);
            assert_eq!(conn.call_secure(1, &scope, addr, 8).unwrap(), 0b11);
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn ctx_malloc_requires_sandbox() {
        // Regression: `CallCtx::malloc` used to silently fall back to
        // the connection heap outside a sandbox; it must now fail.
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, "malloc").unwrap();
        server.add(2, |ctx| Ok(ctx.malloc(64)? as u64));
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "malloc").unwrap();
        cenv.run(|| {
            let addr = conn.heap().new_val(0u64).unwrap();
            let e = conn.invoke(2, (addr, 8), CallOpts::new());
            assert!(
                matches!(e, Err(RpcError::Remote(_))),
                "unsandboxed malloc must surface a handler error: {e:?}"
            );
            let a = conn.invoke(2, (addr, 8), CallOpts::new().sandboxed()).unwrap();
            assert_ne!(a, 0, "sandboxed malloc allocates from the temp heap");
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn typed_optional_reply() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, "optional").unwrap();
        server.serve_opt::<u64, u64>(9, |_ctx, v| {
            Ok(if *v == 0 { None } else { Some(*v * 7) })
        });
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "optional").unwrap();
        cenv.run(|| {
            let some = conn.call_typed::<u64, u64>(9, &6, CallOpts::new()).unwrap();
            assert_eq!(some.opt().unwrap(), Some(42));
            some.free();
            let none = conn.call_typed::<u64, u64>(9, &0, CallOpts::new()).unwrap();
            assert!(none.is_none());
            assert_eq!(none.opt().unwrap(), None);
            assert!(none.read().is_err(), "reading a null reply must fail, not cast");
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn reply_vec_roundtrip() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, "vecs").unwrap();
        server.add(5, |ctx| {
            let n: u64 = ctx.arg_typed()?;
            let xs: Vec<u64> = (0..n).collect();
            ctx.reply_vec(&xs)
        });
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "vecs").unwrap();
        cenv.run(|| {
            let reply = conn.call_typed::<u64, ShmVec<u64>>(5, &4, CallOpts::new()).unwrap();
            let mut v = reply.read().unwrap();
            assert_eq!(v.to_vec().unwrap(), vec![0, 1, 2, 3]);
            v.destroy(conn.heap().as_ref());
            reply.free();
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn typed_sealed_arg_lands_in_scope() {
        let rack = Rack::for_tests();
        let (server, t) = serve_echo(&rack, "typed-sealed");
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "typed-sealed").unwrap();
        cenv.run(|| {
            let scope = conn.create_scope(4096).unwrap();
            let before = scope.used();
            let r = conn
                .call_typed::<u64, u64>(101, &4, CallOpts::new().sealed(&scope))
                .unwrap();
            assert_eq!(r.take().unwrap(), 5);
            assert!(scope.used() > before, "typed arg must land in the sealed scope");
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn per_call_timeout_overrides_default() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, "slow").unwrap();
        server.add(1, |_| Ok(0));
        // No listener thread, no inline serving: no response arrives.
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "slow").unwrap();
        let t0 = std::time::Instant::now();
        let e =
            cenv.run(|| conn.invoke(1, (), CallOpts::new().timeout(Duration::from_millis(50))));
        assert!(matches!(e, Err(RpcError::Timeout(_))));
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "50ms per-call timeout must override the 10s connection default"
        );
        drop(conn);
        server.stop();
    }

    #[test]
    fn sealed_call_blocks_sender_writes_during_flight() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, "sealed").unwrap();
        // Handler verifies it sees a sealed argument and that the
        // value cannot be changed by the sender mid-flight (we can't
        // interleave here, but the seal state is asserted).
        server.add(7, |ctx| {
            assert!(ctx.sealed);
            let v: u64 = ctx.arg_val()?;
            Ok(v * 2)
        });
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "sealed").unwrap();
        cenv.run(|| {
            let scope = conn.create_scope(4096).unwrap();
            let addr = scope.new_val(21u64).unwrap();
            let ret = conn.invoke(7, (addr, 8), CallOpts::new().sealed(&scope)).unwrap();
            assert_eq!(ret, 42);
            // After release the sender can write again.
            let p: ShmPtr<u64> = ShmPtr::from_addr(addr);
            p.write(5).unwrap();
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn secure_call_catches_wild_pointer() {
        use crate::memory::containers::ShmList;
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, "secure").unwrap();
        // Handler traverses an untrusted list inside the sandbox.
        server.add(8, |ctx| {
            let list: ShmList<u64> = ctx.arg_ptr::<ShmList<u64>>().read()?;
            let sum: u64 = list.iter_collect()?.iter().sum();
            Ok(sum)
        });
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "secure").unwrap();
        cenv.run(|| {
            // Honest list: works.
            let scope = conn.create_scope(8192).unwrap();
            let mut list: ShmList<u64> = ShmList::new();
            for i in 1..=4 {
                list.push_back(&scope, i).unwrap();
            }
            let laddr = scope.new_val(list).unwrap();
            assert_eq!(conn.invoke(8, (laddr, 24), CallOpts::secure(&scope)).unwrap(), 10);

            // Malicious list: tail points outside the scope (at the
            // connection heap — could be a server secret). The sandbox
            // catches it and the client gets an error, not data.
            let scope2 = conn.create_scope(8192).unwrap();
            let mut evil: ShmList<u64> = ShmList::new();
            for i in 1..=4 {
                evil.push_back(&scope2, i).unwrap();
            }
            let secret = conn.heap().new_val(0xDEAD_u64).unwrap();
            evil.corrupt_tail(secret).unwrap();
            let eaddr = scope2.new_val(evil).unwrap();
            let e = conn.invoke(8, (eaddr, 24), CallOpts::secure(&scope2));
            assert!(
                matches!(e, Err(RpcError::SandboxViolation { .. })),
                "expected sandbox violation, got {e:?}"
            );
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn multiple_clients_share_server() {
        let rack = Rack::for_tests();
        let (server, t) = serve_echo(&rack, "multi");
        let mut handles = Vec::new();
        for c in 0..4 {
            let rack = Arc::clone(&rack);
            handles.push(std::thread::spawn(move || {
                let cenv = rack.proc_env(1 + c);
                let conn = Rpc::connect(&cenv, "multi").unwrap();
                cenv.run(|| {
                    for i in 0..50u64 {
                        let r = conn.call_typed::<u64, u64>(101, &i, CallOpts::new()).unwrap();
                        assert_eq!(r.take().unwrap(), i + 1);
                    }
                });
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.served(), 200);
        assert_eq!(server.connection_count(), 4);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn shared_heap_mode_single_heap() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .shared_heap(true)
            .open(&env, "shared-heap")
            .unwrap();
        server.add(1, |_| Ok(0));
        let t = server.spawn_listener();
        let c1 = Connection::connect(&rack.proc_env(1), "shared-heap").unwrap();
        let c2 = Connection::connect(&rack.proc_env(2), "shared-heap").unwrap();
        assert_eq!(c1.heap().id, c2.heap().id, "Fig 4b: one channel-wide heap");
        drop((c1, c2));
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn acl_blocks_unauthorized_connect() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .acl(Acl::private(env.uid))
            .open(&env, "private-ch")
            .unwrap();
        let _t = server.spawn_listener();
        let e = Connection::connect(&rack.proc_env(1), "private-ch");
        assert!(matches!(e, Err(RpcError::AccessDenied(_))));
        server.stop();
    }

    #[test]
    fn transport_auto_selection_and_pinning() {
        // Paper §4.7 through the CallOpts.transport path: Auto resolves
        // to CXL in-rack and to the DSM/RDMA fallback beyond it; a call
        // pinned to the other fabric fails fast.
        let rack = Rack::for_tests();
        let (server, t) = serve_echo(&rack, "tsel");

        let near = rack.proc_env(1);
        let c1 = Connection::connect_with(&near, "tsel", TransportSel::Auto).unwrap();
        assert_eq!(c1.transport(), TransportSel::Cxl, "same rack ⇒ CXL");
        near.run(|| {
            let r = c1
                .call_typed::<u64, u64>(101, &1, CallOpts::new().transport(TransportSel::Cxl))
                .unwrap();
            assert_eq!(r.take().unwrap(), 2);
            let e = c1.invoke(101, (), CallOpts::new().transport(TransportSel::Rdma));
            assert!(matches!(e, Err(RpcError::Config(_))));
        });

        let far = rack.remote_proc_env();
        let c2 = Connection::connect_with(&far, "tsel", TransportSel::Auto).unwrap();
        assert_eq!(c2.transport(), TransportSel::Rdma, "out of rack ⇒ DSM fallback");
        assert!(c2.shared.is_dsm());
        far.run(|| {
            let r = c2
                .call_typed::<u64, u64>(101, &5, CallOpts::new().transport(TransportSel::Rdma))
                .unwrap();
            assert_eq!(r.take().unwrap(), 6);
            let e = c2.invoke(101, (), CallOpts::new().transport(TransportSel::Cxl));
            assert!(matches!(e, Err(RpcError::Config(_))));
        });

        drop((c1, c2));
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn rdma_fallback_auto_selected_beyond_rack() {
        // Paper §4.7: the same API transparently falls back to RDMA
        // when the client is outside the CXL domain.
        let rack = Rack::for_tests();
        let (server, t) = serve_echo(&rack, "faraway");
        let cenv = rack.remote_proc_env();
        let conn = Rpc::connect(&cenv, "faraway").unwrap();
        assert!(conn.shared.is_dsm(), "out-of-rack ⇒ DSM transport");
        cenv.run(|| {
            for i in 0..20u64 {
                let r = conn.call_typed::<u64, u64>(101, &i, CallOpts::new()).unwrap();
                assert_eq!(r.take().unwrap(), i + 1);
            }
        });
        let (faults, pages) = conn.shared.dsm.as_ref().unwrap().stats();
        assert!(faults > 0 && pages > 0, "server must have faulted pages over");
        drop(conn);
        server.stop();
        t.join().unwrap();

        // In-rack clients stay on CXL.
        let (server2, t2) = serve_echo(&rack, "nearby");
        let conn2 = Rpc::connect(&rack.proc_env(3), "nearby").unwrap();
        assert!(!conn2.shared.is_dsm());
        drop(conn2);
        server2.stop();
        t2.join().unwrap();
    }

    #[test]
    fn dsm_sealing_and_sandboxing_work_identically() {
        // Paper §5.6: "Sealing and sandboxing for RDMA-based shared
        // memory pages works similarly to RPCool's CXL implementation."
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, "dsm-secure").unwrap();
        server.add(7, |ctx| {
            assert!(ctx.sealed && ctx.sandboxed);
            let v: u64 = ctx.arg_val()?;
            Ok(v + 100)
        });
        let t = server.spawn_listener();
        let cenv = rack.remote_proc_env();
        let conn = Connection::connect_with(&cenv, "dsm-secure", TransportSel::Rdma).unwrap();
        cenv.run(|| {
            let scope = conn.create_scope(4096).unwrap();
            let addr = scope.new_val(1u64).unwrap();
            assert_eq!(conn.invoke(7, (addr, 8), CallOpts::secure(&scope)).unwrap(), 101);
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn pod_aware_auto_transport_across_topology() {
        // The tentpole invariant: one typed call site under
        // TransportSel::Auto, unchanged, rides CXL from an in-pod
        // client and RDMA/DSM from a cross-pod one.
        let mut cfg = SimConfig::for_tests();
        cfg.rack_hosts = 4;
        cfg.pods = 2; // hosts {0,1} = pod 0, {2,3} = pod 1
        let rack = Rack::new(cfg);
        assert_eq!(rack.pod_of(1), 0);
        assert_eq!(rack.pod_of(2), 1);
        assert!(rack.same_cxl_domain(0, 1));
        assert!(!rack.same_cxl_domain(1, 2));

        let (server, t) = serve_echo(&rack, "pods"); // server on host 0, pod 0

        let call_site = |env: &ProcEnv, conn: &Connection, v: u64| -> u64 {
            env.run(|| {
                conn.call_typed::<u64, u64>(101, &v, CallOpts::new()).unwrap().take().unwrap()
            })
        };

        // In-pod client (host 1): Auto ⇒ CXL.
        let near = rack.pod_env(0, 1);
        let c_near = Connection::connect(&near, "pods").unwrap();
        assert_eq!(c_near.transport(), TransportSel::Cxl, "same pod ⇒ CXL");
        assert!(!c_near.shared.is_dsm());
        assert_eq!(call_site(&near, &c_near, 7), 8);

        // Cross-pod client (host 2): the very same connect + call
        // site ⇒ RDMA/DSM.
        let far = rack.pod_env(1, 0);
        let c_far = Connection::connect(&far, "pods").unwrap();
        assert_eq!(c_far.transport(), TransportSel::Rdma, "cross-pod ⇒ RDMA/DSM");
        assert!(c_far.shared.is_dsm());
        assert_eq!(call_site(&far, &c_far, 7), 8);
        assert_eq!(c_far.shared.client_node, 1, "client node = its pod id");
        assert_eq!(c_far.shared.server_node, 0, "server node = its pod id");
        let (faults, pages) = c_far.shared.dsm.as_ref().unwrap().stats();
        assert!(faults > 0 && pages > 0, "argument pages faulted across pods");

        drop((c_near, c_far));
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn wide_channels_probe_more_shards() {
        // d>2 probing on ≥16 shards: with the home shard artificially
        // loaded, a fresh pick must escape to some other shard — and
        // with all loads equal, it must stay home (ties favour home).
        let mut cfg = SimConfig::for_tests();
        cfg.ring_shards = 16;
        let rack = Rack::new(cfg);
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg).open(&env, "wide").unwrap();
        server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v));
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Connection::connect(&cenv, "wide").unwrap();
        assert_eq!(conn.shared.shard_count(), 16);

        let n = 16;
        let home = thread_stripe() & (n - 1);
        assert_eq!(conn.pick_two_choice(n), home, "all-idle pick stays home");
        conn.shared.shards[home].depth.fetch_add(1000, Ordering::Relaxed);
        for _ in 0..8 {
            let picked = conn.pick_two_choice(n);
            assert_ne!(picked, home, "probes never return the loaded home shard");
        }
        conn.shared.shards[home].depth.fetch_sub(1000, Ordering::Relaxed);

        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn invoke_pooled_batches_releases() {
        let mut cfg = SimConfig::for_tests();
        cfg.batch_release_threshold = 16;
        let rack = Rack::new(cfg);
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg).open(&env, "pooled").unwrap();
        server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v));
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Connection::connect(&cenv, "pooled").unwrap();
        let pool = conn.create_scope_pool(4096);
        cenv.run(|| {
            for i in 0..40u64 {
                let scope = pool.pop().unwrap();
                let addr = scope.new_val(i).unwrap();
                assert_eq!(
                    conn.invoke_pooled(1, &pool, scope, (addr, 8), CallOpts::new()).unwrap(),
                    i
                );
            }
        });
        assert_eq!(pool.flushes(), 2, "40 calls / threshold 16 = 2 flushes");
        pool.flush().unwrap();
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    /// N threads share ONE connection whose ring is far smaller than
    /// the in-flight demand: the MPMC ticket protocol must deliver
    /// every response to exactly its caller across many ring laps,
    /// and a full ring must block claims, never corrupt them.
    #[test]
    fn concurrent_callers_share_ring_across_laps() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .ring_slots(4)
            .open(&env, "mpmc")
            .unwrap();
        server.serve::<u64, u64>(101, |_ctx, v| Ok(*v + 1));
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Arc::new(Rpc::connect(&cenv, "mpmc").unwrap());

        const THREADS: u64 = 4;
        const CALLS: u64 = 64; // 256 calls through a 4-slot ring
        let mut handles = Vec::new();
        for tid in 0..THREADS {
            let conn = Arc::clone(&conn);
            let env = cenv.clone();
            handles.push(std::thread::spawn(move || {
                env.run(|| {
                    for k in 0..CALLS {
                        let v = tid * 10_000 + k;
                        let r = conn.call_typed::<u64, u64>(101, &v, CallOpts::new()).unwrap();
                        assert_eq!(r.take().unwrap(), v + 1, "thread {tid} call {k}");
                    }
                });
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.served(), THREADS * CALLS);
        assert_eq!(conn.calls_made(), THREADS * CALLS);
        assert!(conn.shared.quiescent(), "all laps retired");
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn park_policy_serves_and_wakes() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .sleep(SleepPolicy::Park)
            .open(&env, "parked")
            .unwrap();
        server.serve::<u64, u64>(101, |_ctx, v| Ok(*v * 2));
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "parked").unwrap();
        cenv.run(|| {
            // Two bursts separated by an idle window long enough for
            // the listener to park: the publish doorbell must wake it.
            for burst in 0..2u64 {
                for i in 0..20u64 {
                    let r = conn.call_typed::<u64, u64>(101, &i, CallOpts::new()).unwrap();
                    assert_eq!(r.take().unwrap(), i * 2, "burst {burst}");
                }
                std::thread::sleep(Duration::from_millis(30));
            }
        });
        assert_eq!(server.served(), 40);
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn sandbox_violation_carries_fault_detail() {
        use crate::memory::containers::ShmList;
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, "fault-detail").unwrap();
        server.add(8, |ctx| {
            let list: ShmList<u64> = ctx.arg_ptr::<ShmList<u64>>().read()?;
            Ok(list.iter_collect()?.iter().sum())
        });
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "fault-detail").unwrap();
        cenv.run(|| {
            let scope = conn.create_scope(8192).unwrap();
            let mut evil: ShmList<u64> = ShmList::new();
            for i in 1..=4 {
                evil.push_back(&scope, i).unwrap();
            }
            let secret = conn.heap().new_val(0xDEAD_u64).unwrap();
            evil.corrupt_tail(secret).unwrap();
            let eaddr = scope.new_val(evil).unwrap();
            let e = conn.invoke(8, (eaddr, 24), CallOpts::secure(&scope));
            match e {
                Err(RpcError::SandboxViolation { addr, lo, hi }) => {
                    // The satellite fix: real remote detail, not zeros.
                    assert_eq!(addr, secret, "fault address must name the wild pointer");
                    assert!(lo != 0 && hi > lo, "sandbox window must come back: [{lo:#x},{hi:#x})");
                    assert!(
                        addr < lo || addr >= hi,
                        "reported address must lie outside the reported window"
                    );
                }
                other => panic!("expected detailed sandbox violation, got {other:?}"),
            }
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    /// A timed-out call's argument may still be read by the (slow)
    /// server, so it must be quarantined, not recycled — and then
    /// reclaimed once the ring is quiet, so one timeout doesn't
    /// disable the arena for the connection's lifetime.
    #[test]
    fn timed_out_argument_quarantined_then_reclaimed() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, "slowpoke").unwrap();
        server.serve_scalar::<u64>(1, |_ctx, v| {
            std::thread::sleep(Duration::from_millis(120));
            Ok(*v)
        });
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "slowpoke").unwrap();
        let arena = conn.shared.shards[0].arena.as_ref().expect("arena on");
        cenv.run(|| {
            let e = conn.call_scalar::<u64>(
                1,
                &7,
                CallOpts::new().timeout(Duration::from_millis(20)),
            );
            assert!(matches!(e, Err(RpcError::Timeout(_))), "got {e:?}");
            assert_eq!(arena.live(), 1, "argument quarantined, not recycled");
            // Let the slow handler finish; its (stale) response
            // retires the abandoned lap.
            std::thread::sleep(Duration::from_millis(500));
            // The next call sweeps the quarantine once the ring is
            // quiet, then completes normally.
            let r = conn.call_scalar::<u64>(1, &8, CallOpts::new()).unwrap();
            assert_eq!(r, 8);
            assert_eq!(arena.live(), 0, "quarantined argument reclaimed");
            assert_eq!(arena.used(), 0, "arena reset after reclamation");
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    /// Thread striping is deterministic: a thread always lands on
    /// `stripe % nshards`, and repeated lookups agree (per-thread
    /// FIFO order depends on this stability).
    #[test]
    fn shard_striping_is_stable_per_thread() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .ring_shards(4)
            .open(&env, "striping")
            .unwrap();
        server.add(1, |_| Ok(0));
        let t = server.spawn_listener();
        let conn = Arc::new(Rpc::connect(&rack.proc_env(1), "striping").unwrap());
        assert_eq!(conn.shared.shard_count(), 4);
        let mut handles = Vec::new();
        for _ in 0..8 {
            let conn = Arc::clone(&conn);
            handles.push(std::thread::spawn(move || {
                let (i1, _) = conn.shared.shard_for_thread();
                let (i2, _) = conn.shared.shard_for_thread();
                assert_eq!(i1, i2, "stripe must be stable within a thread");
                assert_eq!(i1, thread_stripe() & 3, "stripe must be thread-id derived");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        server.stop();
        t.join().unwrap();
    }

    /// The tentpole end to end: a 4-shard connection served by two
    /// listener workers under multi-threaded callers. Every response
    /// reaches its caller, all shards retire, and the per-shard claim
    /// counters account for every call.
    #[test]
    fn sharded_connection_scales_across_threads_and_workers() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .ring_shards(4)
            .ring_slots(4)
            .open(&env, "sharded")
            .unwrap();
        server.serve::<u64, u64>(101, |_ctx, v| Ok(*v + 1));
        let listeners = server.spawn_listeners(2);
        let cenv = rack.proc_env(1);
        let conn = Arc::new(Rpc::connect(&cenv, "sharded").unwrap());

        const THREADS: u64 = 8;
        const CALLS: u64 = 48; // 384 calls through 4×4-slot rings
        let mut handles = Vec::new();
        for tid in 0..THREADS {
            let conn = Arc::clone(&conn);
            let env = cenv.clone();
            handles.push(std::thread::spawn(move || {
                env.run(|| {
                    for k in 0..CALLS {
                        let v = tid * 10_000 + k;
                        let r = conn.call_typed::<u64, u64>(101, &v, CallOpts::new()).unwrap();
                        assert_eq!(r.take().unwrap(), v + 1, "thread {tid} call {k}");
                    }
                });
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.served(), THREADS * CALLS);
        assert!(conn.shared.quiescent(), "every shard retired every lap");
        let claims = conn.shared.shard_claims();
        assert_eq!(claims.iter().sum::<u64>(), THREADS * CALLS, "claims account: {claims:?}");
        server.stop();
        for l in listeners {
            l.join().unwrap();
        }
    }

    #[test]
    fn batched_calls_roundtrip_and_recycle_arena() {
        let rack = Rack::for_tests();
        let (server, t) = serve_echo(&rack, "batched");
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "batched").unwrap();
        let arena = conn.shared.shards[0].arena.as_ref().expect("arena on");
        cenv.run(|| {
            assert_eq!(
                conn.invoke_batch(101, &[], CallOpts::new()).unwrap(),
                Vec::<u64>::new(),
                "empty batch is a no-op"
            );
            let vals: Vec<u64> = (0..20).collect();
            let rets = conn.call_scalar_batch::<u64>(101, &vals, CallOpts::new()).unwrap();
            assert_eq!(rets.len(), vals.len());
            for (v, ret) in vals.iter().zip(&rets) {
                let reply: Reply<u64> = conn.reply_from(*ret);
                assert_eq!(reply.take().unwrap(), v + 1);
            }
        });
        assert_eq!(server.served(), 20);
        assert_eq!(arena.live(), 0, "batch args and replies all released");
        assert_eq!(arena.used(), 0, "arena fully recycled after the batch");
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn batch_surfaces_errors_and_rejects_seal() {
        let rack = Rack::for_tests();
        let (server, t) = serve_echo(&rack, "batch-err");
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "batch-err").unwrap();
        cenv.run(|| {
            let e = conn.call_scalar_batch::<u64>(999, &[1, 2, 3], CallOpts::new());
            assert!(matches!(e, Err(RpcError::NoSuchHandler(999))), "got {e:?}");
            // The failed batch must not wedge the shard.
            let r = conn.call_typed::<u64, u64>(101, &5, CallOpts::new()).unwrap();
            assert_eq!(r.take().unwrap(), 6);
            assert!(conn.shared.quiescent());
            let scope = conn.create_scope(4096).unwrap();
            let e = conn.call_scalar_batch::<u64>(101, &[1], CallOpts::new().sealed(&scope));
            assert!(matches!(e, Err(RpcError::Config(_))), "sealed batches are rejected: {e:?}");
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn async_calls_pipeline_and_complete_out_of_order() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, "async").unwrap();
        server.serve_scalar::<u64>(7, |_ctx, v| Ok(*v * 3));
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "async").unwrap();
        let arena = conn.shared.shards[0].arena.as_ref().expect("arena on");
        cenv.run(|| {
            // Pipeline 4 calls, then complete them newest-first.
            let mut handles: Vec<CallHandle> = (0..4u64)
                .map(|i| conn.call_scalar_async(7, &i, CallOpts::new()).unwrap())
                .collect();
            let mut expect: Vec<u64> = (0..4u64).map(|i| i * 3).collect();
            while let (Some(h), Some(want)) = (handles.pop(), expect.pop()) {
                assert_eq!(h.wait().unwrap(), want);
            }
            // poll() completes without blocking once the response lands.
            let mut h = conn.call_scalar_async(7, &11u64, CallOpts::new()).unwrap();
            let got = loop {
                if let Some(r) = h.poll() {
                    break r;
                }
                std::hint::spin_loop();
            };
            assert_eq!(got.unwrap(), 33);
        });
        assert_eq!(server.served(), 5);
        assert!(conn.shared.quiescent());
        assert_eq!(arena.live(), 0, "async args released on completion");
        assert_eq!(arena.used(), 0);
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    /// Dropping an unfinished handle must abandon the slot (ring keeps
    /// cycling) and quarantine the argument (server may still read it)
    /// — a dropped handle can never wedge or corrupt the connection.
    #[test]
    fn dropped_async_handle_abandons_cleanly() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, "async-drop").unwrap();
        server.serve_scalar::<u64>(7, |_ctx, v| {
            std::thread::sleep(Duration::from_millis(60));
            Ok(*v)
        });
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "async-drop").unwrap();
        let arena = conn.shared.shards[0].arena.as_ref().expect("arena on");
        cenv.run(|| {
            let h = conn.call_scalar_async(7, &1u64, CallOpts::new()).unwrap();
            drop(h); // give up while the call is still in flight
            assert_eq!(arena.live(), 1, "abandoned argument quarantined, not recycled");
            // Let the slow handler finish; its response retires the lap.
            std::thread::sleep(Duration::from_millis(400));
            let r = conn.call_scalar::<u64>(7, &2, CallOpts::new()).unwrap();
            assert_eq!(r, 2);
            assert_eq!(arena.live(), 0, "quarantined argument reclaimed");
            assert_eq!(arena.used(), 0, "arena reset after reclamation");
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn batch_and_async_drive_inline_serving() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = Rpc::open(&env, "inline-batch").unwrap();
        server.serve_scalar::<u64>(7, |_ctx, v| Ok(*v + 100));
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "inline-batch").unwrap();
        conn.attach_inline(&server); // no listener thread at all
        cenv.run(|| {
            let vals: Vec<u64> = (0..8).collect();
            let rets = conn.call_scalar_batch::<u64>(7, &vals, CallOpts::new()).unwrap();
            assert_eq!(rets, (100..108).collect::<Vec<u64>>());
            let h = conn.call_scalar_async(7, &1u64, CallOpts::new()).unwrap();
            assert_eq!(h.wait().unwrap(), 101, "wait() must drain the server inline");
        });
        assert_eq!(server.served(), 9);
        assert!(conn.shared.quiescent());
        drop(conn);
        server.stop();
    }

    /// The response-path tentpole, charged end to end: a batch
    /// submitted through one publish doorbell and served by the
    /// drain-k loop must cost far fewer than the historical 2 signals
    /// per RPC. Even the worst serving interleaving (one flush per
    /// reply) is ≤ 1 + 1/32; the old behaviour was exactly 2.
    #[test]
    fn drain_k_coalesces_reply_doorbells() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .ring_slots(64)
            .drain_k(16)
            .open(&env, "drain-k")
            .unwrap();
        server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "drain-k").unwrap();
        let charger = Arc::clone(&rack.pool.charger);
        let signal = rack.cfg.cost.cxl_signal_ns as f64;
        cenv.run(|| {
            let vals: Vec<u64> = (0..32).collect();
            let before = charger.total_charged_ns();
            let rets = conn.call_scalar_batch::<u64>(1, &vals, CallOpts::new()).unwrap();
            let charged = (charger.total_charged_ns() - before) as f64;
            for (v, r) in vals.iter().zip(&rets) {
                assert_eq!(*r, v + 1);
            }
            let per_rpc = charged / signal / vals.len() as f64;
            assert!(
                per_rpc > 0.0 && per_rpc <= 1.2,
                "batched submit + drain-k replies must amortize both doorbells \
                 (got {per_rpc} signals/RPC, pre-batching was 2)"
            );
        });
        assert_eq!(server.served(), 32);
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    /// drain_k(1) must restore the pre-batching accounting exactly:
    /// one publish signal + one reply signal per unbatched RPC.
    #[test]
    fn drain_k_one_restores_per_reply_signals() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .drain_k(1)
            .open(&env, "drain-1")
            .unwrap();
        server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v));
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "drain-1").unwrap();
        let charger = Arc::clone(&rack.pool.charger);
        let signal = rack.cfg.cost.cxl_signal_ns;
        cenv.run(|| {
            let before = charger.total_charged_ns();
            for i in 0..20u64 {
                assert_eq!(conn.call_scalar::<u64>(1, &i, CallOpts::new()).unwrap(), i);
            }
            // The final sweep's flush_respond may still be in flight
            // on the listener thread when the last call returns.
            std::thread::sleep(Duration::from_millis(50));
            let charged = charger.total_charged_ns() - before;
            assert_eq!(
                charged,
                2 * 20 * signal,
                "drain_k=1 keeps the historical 2-signals-per-RPC accounting"
            );
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    /// The sharp coalescing pin (the statistical bounds above can't
    /// distinguish per-reply flushing from real coalescing): stage a
    /// 32-call backlog with no listener running, then serve it — the
    /// drain-16 loop must answer it in exactly ceil(32/16) = 2 sweeps
    /// = 2 coalesced reply doorbells. Per-reply flushing would charge
    /// 32; this is the regression tripwire for the ISSUE 4 tentpole.
    #[test]
    fn drain_k_sweep_coalesces_backlogged_replies() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .ring_slots(64)
            .drain_k(16)
            .open(&env, "drain-backlog")
            .unwrap();
        server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "drain-backlog").unwrap();
        let charger = Arc::clone(&rack.pool.charger);
        let signal = rack.cfg.cost.cxl_signal_ns;
        cenv.run(|| {
            // Stage the backlog first: 32 eager publishes, no replies.
            let handles: Vec<CallHandle> = (0..32u64)
                .map(|v| conn.call_scalar_async(1, &v, CallOpts::new()).unwrap())
                .collect();
            let staged = charger.total_charged_ns();
            // Only now start serving: the whole backlog is visible to
            // the listener's first pass, so the sweep count (and with
            // it the reply-signal count) is deterministic.
            let t = server.spawn_listener();
            for (h, v) in handles.into_iter().zip(0..32u64) {
                assert_eq!(h.wait().unwrap(), v + 1);
            }
            // The final sweep's flush may trail the last consume.
            std::thread::sleep(Duration::from_millis(50));
            let reply_signals = (charger.total_charged_ns() - staged) / signal;
            assert_eq!(
                reply_signals, 2,
                "a 32-deep backlog under drain-16 must cost exactly 2 coalesced reply \
                 doorbells (per-reply flushing charges 32)"
            );
            server.stop();
            t.join().unwrap();
        });
        drop(conn);
    }

    /// Two-choice striping routes new callers around a wedged shard
    /// (its held claims never publish, so its ring stays full and its
    /// contention counter stays hot) while preserving per-thread FIFO
    /// across the reroute: the rerouted calls ride one pinned shard
    /// and are served in submission order.
    #[test]
    fn two_choice_reroutes_around_wedged_shard_preserving_fifo() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let order = Arc::new(Mutex::new(Vec::<u64>::new()));
        let server = ChannelBuilder::from_config(&rack.cfg)
            .ring_shards(2)
            .ring_slots(8)
            .two_choice(true)
            .open(&env, "wedge")
            .unwrap();
        let ord = Arc::clone(&order);
        server.serve_scalar::<u64>(1, move |_ctx, v| {
            ord.lock().unwrap().push(*v);
            Ok(*v)
        });
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "wedge").unwrap();
        cenv.run(|| {
            let (home, _) = conn.shared.shard_for_thread();
            let probe = home ^ 1;
            // Wedge the home shard: hold every claim ticket (claimed,
            // never published) so its ring is full and stays full.
            let held: Vec<usize> =
                (0..8).map(|_| conn.shared.shards[home].ring.claim().unwrap()).collect();
            assert_eq!(held.len(), 8);
            assert!(conn.shared.shards[home].ring.claim().is_none(), "home shard wedged");

            // First call still probes home (no contention recorded
            // yet, ties go home): it fails at the claim phase, which
            // is exactly what charges the wedged shard's counter.
            let e = conn.call_scalar::<u64>(
                1,
                &0,
                CallOpts::new().timeout(Duration::from_millis(30)),
            );
            assert!(matches!(e, Err(RpcError::Timeout(_))), "got {e:?}");
            assert!(
                conn.shared.shards[home].claim_fails.load(Ordering::Relaxed) > 0,
                "failed claim must charge the contention signal"
            );

            // New calls now reroute to the probe shard — and because
            // they pipeline (async, all in flight from one thread),
            // the pin keeps every one of them on that single shard.
            let before = conn.shared.shard_claims();
            let handles: Vec<CallHandle> = (1..=6u64)
                .map(|v| conn.call_scalar_async(1, &v, CallOpts::new()).unwrap())
                .collect();
            for (h, want) in handles.into_iter().zip(1..=6u64) {
                assert_eq!(h.shard(), probe, "rerouted call must ride the probe shard");
                assert_eq!(h.wait().unwrap(), want);
            }
            let after = conn.shared.shard_claims();
            assert_eq!(after[home], before[home], "wedged shard gets no new claims");
            assert_eq!(after[probe], before[probe] + 6, "all rerouted calls rode the probe");
            // FIFO across the reroute: service order == submission
            // order (the wedged call 0 was never published, so it
            // never appears).
            assert_eq!(*order.lock().unwrap(), (1..=6).collect::<Vec<u64>>());
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    /// The lazy time-based claim-fail decay (ROADMAP open item): one
    /// halving per elapsed window, nothing inside a window, stamp
    /// advanced so repeated sweeps don't over-decay.
    #[test]
    fn claim_fail_decay_halves_per_elapsed_window() {
        let rack = Rack::for_tests();
        let (server, t) = serve_echo(&rack, "decay-unit");
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "decay-unit").unwrap();
        let sh = &conn.shared.shards[0];
        let win = CLAIM_FAIL_DECAY.as_nanos() as u64;

        sh.note_claim_fail(0);
        sh.claim_fails.store(8, Ordering::Relaxed);
        sh.decay_claim_fails_by_time(win / 2);
        assert_eq!(sh.claim_fails.load(Ordering::Relaxed), 8, "inside the window: no decay");
        sh.decay_claim_fails_by_time(3 * win + win / 2);
        assert_eq!(sh.claim_fails.load(Ordering::Relaxed), 1, "three windows → three halvings");
        // The stamp advanced: an immediate re-sweep must not decay again.
        sh.decay_claim_fails_by_time(3 * win + win / 2 + 1);
        assert_eq!(sh.claim_fails.load(Ordering::Relaxed), 1);
        // A fresh fail re-stamps the clock, restarting the half-life.
        sh.note_claim_fail(4 * win);
        sh.decay_claim_fails_by_time(4 * win + win / 2);
        assert_eq!(sh.claim_fails.load(Ordering::Relaxed), 2, "no decay inside the new window");
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    /// End to end: a once-congested shard decays back under *light*
    /// traffic — routing alone (no claim success on the exiled shard)
    /// clears the stale penalty after the half-life elapses. This was
    /// the traffic-driven decay's blind spot.
    #[test]
    fn time_decay_reclaims_exiled_shard_under_light_traffic() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .ring_shards(2)
            .two_choice(true)
            .open(&env, "decay-reclaim")
            .unwrap();
        server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v));
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "decay-reclaim").unwrap();
        cenv.run(|| {
            let (home, _) = conn.shared.shard_for_thread();
            // A past congestion episode, stamped on the real clock.
            conn.shared.shards[home].note_claim_fail(conn.shared.now_ns());
            conn.shared.shards[home].claim_fails.store(8, Ordering::Relaxed);
            std::thread::sleep(CLAIM_FAIL_DECAY * 3);
            // One light-traffic routing decision is enough: the pick
            // path lazily decays both candidates (no claim success on
            // the home shard required).
            let route = conn.route(1);
            conn.unroute(&route);
            assert!(
                conn.shared.shards[home].claim_fails.load(Ordering::Relaxed) <= 2,
                "stale penalty must drain by half-lives, got {}",
                conn.shared.shards[home].claim_fails.load(Ordering::Relaxed)
            );
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    /// With two-choice off, the fixed thread stripe routes every call
    /// of one thread to its home shard — the load-aware path must not
    /// engage (regression guard for the fixed-striping baseline).
    #[test]
    fn fixed_striping_ignores_load() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .ring_shards(2)
            .two_choice(false)
            .open(&env, "fixed")
            .unwrap();
        server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v));
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "fixed").unwrap();
        cenv.run(|| {
            let (home, _) = conn.shared.shard_for_thread();
            for i in 0..10u64 {
                assert_eq!(conn.call_scalar::<u64>(1, &i, CallOpts::new()).unwrap(), i);
            }
            let claims = conn.shared.shard_claims();
            assert_eq!(claims[home], 10, "fixed striping pins the thread to its home shard");
            assert_eq!(claims[home ^ 1], 0);
            assert_eq!(
                conn.shared.shards[home].depth.load(Ordering::Relaxed),
                0,
                "untracked routes must not touch the occupancy counter"
            );
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    /// Typed async replies (the ROADMAP satellite): pipeline
    /// pointer-returning RPCs, resolve each handle to a `Reply<R>`,
    /// out of order, with the arena fully recycled afterwards.
    #[test]
    fn typed_async_resolves_to_replies() {
        let rack = Rack::for_tests();
        let (server, t) = serve_echo(&rack, "typed-async");
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "typed-async").unwrap();
        let arena = conn.shared.shards[0].arena.as_ref().expect("arena on");
        cenv.run(|| {
            // Pipeline 4 typed calls, then complete them newest-first.
            let mut handles: Vec<TypedCallHandle<u64>> = (0..4u64)
                .map(|i| conn.call_typed_async::<u64, u64>(101, &i, CallOpts::new()).unwrap())
                .collect();
            let mut expect: Vec<u64> = (0..4u64).map(|i| i + 1).collect();
            while let (Some(h), Some(want)) = (handles.pop(), expect.pop()) {
                let reply = h.wait().unwrap();
                assert_eq!(reply.take().unwrap(), want);
            }
            // poll() path, plus the null-reply decode through opt().
            let mut h = conn.call_typed_async::<u64, u64>(101, &10, CallOpts::new()).unwrap();
            let reply = loop {
                if let Some(r) = h.poll() {
                    break r.unwrap();
                }
                std::hint::spin_loop();
            };
            assert_eq!(reply.take().unwrap(), 11);
            // Dropping an unfinished typed handle abandons cleanly.
            let h = conn.call_typed_async::<u64, u64>(101, &20, CallOpts::new()).unwrap();
            drop(h);
            std::thread::sleep(Duration::from_millis(100));
            let r = conn.call_typed::<u64, u64>(101, &30, CallOpts::new()).unwrap();
            assert_eq!(r.take().unwrap(), 31, "connection healthy after dropped typed handle");
        });
        assert!(conn.shared.quiescent());
        assert_eq!(arena.live(), 0, "typed async args and replies all released");
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn arena_recycles_typed_call_allocations() {
        let rack = Rack::for_tests();
        let (server, t) = serve_echo(&rack, "arena");
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "arena").unwrap();
        let arena = conn.shared.shards[0].arena.as_ref().expect("default opts carve an arena");
        cenv.run(|| {
            for i in 0..200u64 {
                let r = conn.call_typed::<u64, u64>(101, &i, CallOpts::new()).unwrap();
                assert_eq!(r.take().unwrap(), i + 1);
            }
        });
        assert_eq!(arena.live(), 0, "args and replies all released");
        assert_eq!(arena.used(), 0, "arena fully recycled in place");
        assert_eq!(arena.spills(), 0, "steady-state traffic never hits the heap mutex");
        assert!(arena.resets() > 0, "recycling actually happened");
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    /// The ISSUE 7 capacity acceptance row as a deterministic unit
    /// test: one daemon-wide pool of ≤ 8 workers serves 1024
    /// concurrent channels through the waiter tree, with zero
    /// per-channel listener threads (`spawn_listeners` returns no
    /// handles in pooled mode — asserted per channel).
    #[test]
    fn pooled_workers_serve_a_thousand_channels_without_listener_threads() {
        let mut cfg = SimConfig::for_tests();
        cfg.pool_bytes = 1 << 30; // 1024 connection heaps
        let rack = Rack::new(cfg);
        let env = rack.proc_env(0);
        const CHANNELS: usize = 1024;
        let mut servers = Vec::with_capacity(CHANNELS);
        for i in 0..CHANNELS {
            let s = ChannelBuilder::from_config(&rack.cfg)
                .heap_bytes(192 << 10)
                .ring_slots(8)
                .ring_shards(1)
                .arg_arena_bytes(0)
                .pool_workers(8)
                .open(&env, &format!("pool{i}"))
                .unwrap();
            s.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 7));
            assert!(
                s.spawn_listeners(4).is_empty(),
                "pooled channels must not spawn listener threads"
            );
            servers.push(s);
        }
        let cenv = rack.proc_env(1);
        let conns: Vec<Connection> = (0..CHANNELS)
            .map(|i| Rpc::connect(&cenv, &format!("pool{i}")).unwrap())
            .collect();
        cenv.run(|| {
            for round in 0..2u64 {
                for (i, conn) in conns.iter().enumerate() {
                    let v = round * 1_000_000 + i as u64;
                    let r = conn.call_scalar::<u64>(1, &v, CallOpts::new()).unwrap();
                    assert_eq!(r, v + 7, "channel {i} round {round}");
                }
            }
        });
        let served: u64 = servers.iter().map(|s| s.served()).sum();
        assert_eq!(served, 2 * CHANNELS as u64, "every channel served through the pool");
        drop(conns);
        for s in &servers {
            s.stop();
        }
    }

    /// Pooled workers must park when idle and wake through the
    /// aggregated doorbell tree — bursts separated by idle windows
    /// longer than the park spin budget all get served.
    #[test]
    fn pooled_channel_wakes_after_idle() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .pool_workers(2)
            .sleep(SleepPolicy::Park)
            .open(&env, "pool-parked")
            .unwrap();
        server.serve::<u64, u64>(101, |_ctx, v| Ok(*v * 2));
        assert!(server.spawn_listeners(1).is_empty());
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "pool-parked").unwrap();
        cenv.run(|| {
            for burst in 0..2u64 {
                for i in 0..20u64 {
                    let r = conn.call_typed::<u64, u64>(101, &i, CallOpts::new()).unwrap();
                    assert_eq!(r.take().unwrap(), i * 2, "burst {burst}");
                }
                std::thread::sleep(Duration::from_millis(30));
            }
        });
        assert_eq!(server.served(), 40);
        drop(conn);
        server.stop();
    }

    /// Elastic shard-window state machine, driven deterministically
    /// through its crate-internal hooks: grow doubles under recorded
    /// claim-fail pressure (resetting the triggering shard's
    /// evidence), saturates at capacity, and the periodic shrink
    /// check halves the window only while the upper half is fully
    /// quiescent — one halving per period.
    #[test]
    fn elastic_window_grows_under_pressure_and_shrinks_when_idle() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .ring_shards(4)
            .ring_slots(4)
            .elastic_shards(true)
            .open(&env, "elastic-fsm")
            .unwrap();
        server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "elastic-fsm").unwrap();
        let sh = &conn.shared;
        assert_eq!(sh.shard_count(), 4, "capacity unchanged by elastic");
        assert_eq!(sh.active_shard_count(), 1, "elastic connections start narrow");

        // Below the evidence threshold nothing moves.
        sh.shards[0].claim_fails.store(ELASTIC_GROW_FAILS - 1, Ordering::Relaxed);
        sh.note_pressure(0);
        assert_eq!(sh.active_shard_count(), 1);
        // At the threshold the window doubles and the evidence resets.
        sh.shards[0].claim_fails.store(ELASTIC_GROW_FAILS, Ordering::Relaxed);
        sh.note_pressure(0);
        assert_eq!(sh.active_shard_count(), 2);
        assert_eq!(sh.shards[0].claim_fails.load(Ordering::Relaxed), 0, "evidence consumed");
        sh.shards[0].claim_fails.store(ELASTIC_GROW_FAILS, Ordering::Relaxed);
        sh.note_pressure(0);
        assert_eq!(sh.active_shard_count(), 4);
        // Saturated: more pressure is a no-op.
        sh.shards[0].claim_fails.store(ELASTIC_GROW_FAILS, Ordering::Relaxed);
        sh.note_pressure(0);
        assert_eq!(sh.active_shard_count(), 4);
        sh.shards[0].claim_fails.store(0, Ordering::Relaxed);

        // Calls work at full width (servers sweep all capacity
        // shards, so width changes need no server coordination).
        cenv.run(|| {
            for i in 0..8u64 {
                let r = conn.call_scalar::<u64>(1, &i, CallOpts::new()).unwrap();
                assert_eq!(r, i + 1);
            }
        });

        // Idle: one shrink check fires per ELASTIC_SHRINK_PERIOD
        // route ticks, each halving at most once — 4 → 2 → 1.
        for _ in 0..ELASTIC_SHRINK_PERIOD {
            sh.elastic_tick();
        }
        assert_eq!(sh.active_shard_count(), 2, "one period, one halving");
        for _ in 0..ELASTIC_SHRINK_PERIOD {
            sh.elastic_tick();
        }
        assert_eq!(sh.active_shard_count(), 1, "fully idle window collapses to one shard");

        // And the narrow window still serves.
        cenv.run(|| {
            let r = conn.call_scalar::<u64>(1, &99, CallOpts::new()).unwrap();
            assert_eq!(r, 100);
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    /// Elastic off (the default): the window is pinned to capacity
    /// and never moves — the pressure/shrink hooks are inert.
    #[test]
    fn elastic_off_pins_window_to_capacity() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .ring_shards(4)
            .open(&env, "elastic-off")
            .unwrap();
        server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "elastic-off").unwrap();
        assert_eq!(conn.shared.active_shard_count(), 4, "full width from the first call");
        conn.shared.shards[0].claim_fails.store(ELASTIC_GROW_FAILS * 4, Ordering::Relaxed);
        conn.shared.note_pressure(0);
        assert_eq!(conn.shared.active_shard_count(), 4, "pressure hook inert");
        conn.shared.shards[0].claim_fails.store(0, Ordering::Relaxed);
        cenv.run(|| {
            for i in 0..4u64 {
                let r = conn.call_scalar::<u64>(1, &i, CallOpts::new()).unwrap();
                assert_eq!(r, i + 1);
            }
        });
        drop(conn);
        server.stop();
        t.join().unwrap();
    }

    /// Admission accounting: the orchestrator's counters partition
    /// connects exactly across admitted/rejected under `Reject`.
    #[test]
    fn admission_counters_partition_connects() {
        use crate::orchestrator::{ADM_ADMITTED, ADM_REJECTED};
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .admission(AdmissionPolicy::Reject)
            .conn_limit(2)
            .open(&env, "adm-count")
            .unwrap();
        server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let before_adm = rack.orch.admission().get(ADM_ADMITTED);
        let before_rej = rack.orch.admission().get(ADM_REJECTED);
        let held: Vec<Connection> =
            (0..2).map(|_| Rpc::connect(&cenv, "adm-count").unwrap()).collect();
        for k in 0..3 {
            match Rpc::connect(&cenv, "adm-count") {
                Err(RpcError::ConnectionRefused(name, why)) => {
                    assert_eq!(name, "adm-count");
                    assert!(why.contains("admission"), "attempt {k}: {why}");
                }
                other => panic!("expected refusal over the ceiling, got {other:?}"),
            }
        }
        assert_eq!(rack.orch.admission().get(ADM_ADMITTED) - before_adm, 2);
        assert_eq!(rack.orch.admission().get(ADM_REJECTED) - before_rej, 3);
        drop(held);
        server.stop();
        t.join().unwrap();
    }

    /// Failure plane (satellite): a crashed client's connection stops
    /// counting against `conn_limit` the instant its lease lapses —
    /// the admission slot frees on expiry alone, with no recovery
    /// sweep involved.
    #[test]
    fn expired_client_lease_frees_admission_slot() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = ChannelBuilder::from_config(&rack.cfg)
            .admission(AdmissionPolicy::Reject)
            .conn_limit(1)
            .open(&env, "adm-lease")
            .unwrap();
        server.serve_scalar::<u64>(1, |_ctx, v| Ok(*v + 1));
        let c1 = Rpc::connect(&rack.proc_env(1), "adm-lease").unwrap();
        server.accept_pending();
        // Slot held and lease live: the next connect bounces.
        assert!(matches!(
            Rpc::connect(&rack.proc_env(1), "adm-lease"),
            Err(RpcError::ConnectionRefused(_, _))
        ));
        // The client dies without cleanup; nothing renews its lease.
        c1.crash();
        std::thread::sleep(Duration::from_millis(rack.cfg.lease_ttl_ms + 25));
        let c3 = Rpc::connect(&rack.proc_env(1), "adm-lease").unwrap();
        drop(c3);
        server.stop();
    }

    /// Failure plane: once the sweep declares a proc dead, its
    /// connections fail as *peer failures* — survivors (and late
    /// callers) observe `PeerFailed`, not a bland `ConnectionClosed`.
    #[test]
    fn sweep_turns_expired_leases_into_peer_failures() {
        let rack = Rack::for_tests();
        let (server, t) = serve_echo(&rack, "sweep-pf");
        let cenv = rack.proc_env(1);
        let conn = Rpc::connect(&cenv, "sweep-pf").unwrap();
        server.accept_pending();
        // Nobody renews: both endpoints' leases lapse and the sweep
        // declares both procs dead, tearing the connection down with
        // the peer-failed classification.
        std::thread::sleep(Duration::from_millis(rack.cfg.lease_ttl_ms + 25));
        rack.orch.tick();
        assert!(conn.shared.peer_failed());
        let e = cenv.run(|| conn.call_scalar::<u64>(101, &1, CallOpts::new()));
        assert!(matches!(e, Err(RpcError::PeerFailed(_))), "{e:?}");
        drop(conn);
        server.stop();
        t.join().unwrap();
    }
}
