//! The simulated rack: up to ~32 hosts sharing one CXL memory pool
//! (paper Fig. 2), plus the cluster-global orchestrator.
//!
//! A `Rack` owns the pool and the orchestrator. "Procs" (simulated OS
//! processes) are created via `proc_env` and run on caller threads; a
//! `ProcEnv` carries the identity (`ProcId`, uid, host) that the
//! protection layers key on. Hosts beyond the rack (for RDMA-fallback
//! experiments) are modelled by marking the env's host id `>= rack_hosts`.

use crate::config::SimConfig;
use crate::memory::pool::Pool;
use crate::orchestrator::{Orchestrator, Uid};
use crate::simproc::{self};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

static NEXT_RACK_ID: AtomicU64 = AtomicU64::new(1);

pub struct Rack {
    pub id: u64,
    pub cfg: SimConfig,
    pub pool: Arc<Pool>,
    pub orch: Arc<Orchestrator>,
}

impl Rack {
    pub fn new(cfg: SimConfig) -> Arc<Rack> {
        let pool = Pool::new(&cfg).expect("pool mmap");
        let orch = Orchestrator::new(&cfg, Arc::clone(&pool));
        simproc::set_enforcement(cfg.enforce_protection);
        Arc::new(Rack { id: NEXT_RACK_ID.fetch_add(1, Ordering::Relaxed), cfg, pool, orch })
    }

    /// Convenience constructors matching the two standard configs.
    pub fn for_tests() -> Arc<Rack> {
        Rack::new(SimConfig::for_tests())
    }

    pub fn for_bench() -> Arc<Rack> {
        Rack::new(SimConfig::for_bench())
    }

    /// Create a new simulated process on `host`.
    pub fn proc_env(self: &Arc<Self>, host: u32) -> ProcEnv {
        let proc = simproc::fresh_proc_id();
        ProcEnv { rack: Arc::clone(self), proc, uid: proc, host }
    }

    /// A process on a host *outside* this rack's CXL domain (RDMA only).
    pub fn remote_proc_env(self: &Arc<Self>) -> ProcEnv {
        self.proc_env(self.cfg.rack_hosts as u32 + 1)
    }

    /// Are two hosts CXL-reachable (same rack)?
    pub fn same_cxl_domain(&self, host_a: u32, host_b: u32) -> bool {
        (host_a as usize) < self.cfg.rack_hosts && (host_b as usize) < self.cfg.rack_hosts
    }
}

/// A simulated process: identity + rack handle. Cheap to clone; bind
/// to the current thread with `enter()` (or run closures via `run`).
#[derive(Clone)]
pub struct ProcEnv {
    pub rack: Arc<Rack>,
    pub proc: u32,
    pub uid: Uid,
    pub host: u32,
}

impl ProcEnv {
    /// Bind this proc identity to the current thread.
    pub fn enter(&self) {
        simproc::bind(self.proc, self.host);
    }

    /// Run `f` under this proc's identity, restoring the previous one.
    pub fn run<R>(&self, f: impl FnOnce() -> R) -> R {
        simproc::with_identity(self.proc, self.host, f)
    }

    /// Spawn an OS thread bound to this proc identity.
    pub fn spawn<F, R>(&self, f: F) -> std::thread::JoinHandle<R>
    where
        F: FnOnce() -> R + Send + 'static,
        R: Send + 'static,
    {
        let env = self.clone();
        std::thread::spawn(move || {
            env.enter();
            f()
        })
    }

    pub fn in_rack(&self) -> bool {
        (self.host as usize) < self.rack.cfg.rack_hosts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn procs_get_distinct_ids() {
        let rack = Rack::for_tests();
        let a = rack.proc_env(0);
        let b = rack.proc_env(1);
        assert_ne!(a.proc, b.proc);
        a.run(|| {
            assert_eq!(simproc::current_proc(), a.proc);
            assert_eq!(simproc::current_host(), 0);
        });
    }

    #[test]
    fn cxl_domain_boundaries() {
        let rack = Rack::for_tests();
        assert!(rack.same_cxl_domain(0, 31));
        let remote = rack.remote_proc_env();
        assert!(!remote.in_rack());
        assert!(!rack.same_cxl_domain(0, remote.host));
    }

    #[test]
    fn spawned_thread_carries_identity() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(2);
        let p = env.proc;
        env.spawn(move || {
            assert_eq!(simproc::current_proc(), p);
            assert_eq!(simproc::current_host(), 2);
        })
        .join()
        .unwrap();
    }
}
