//! The simulated rack: up to ~32 hosts sharing one CXL memory pool
//! (paper Fig. 2), partitioned into pods, plus the cluster-global
//! orchestrator.
//!
//! A `Rack` owns the pool, the orchestrator, and a [`Topology`]: the
//! rack's hosts are split into `cfg.pods` CXL coherence domains, and
//! only hosts in the same pod see each other over CXL — everything
//! else (cross-pod, out-of-rack) falls back to RDMA/DSM (see
//! `crate::cluster`). "Procs" (simulated OS processes) are created via
//! `proc_env` and run on caller threads; a `ProcEnv` carries the
//! identity (`ProcId`, uid, host) that the protection layers key on.
//! Hosts beyond the rack are modelled by host ids `>= rack_hosts`,
//! each allocated freshly by `remote_proc_env` so distinct remote
//! machines stay distinct.

use crate::cluster::{PodId, Topology};
use crate::config::SimConfig;
use crate::memory::pool::Pool;
use crate::orchestrator::{Orchestrator, Uid};
use crate::simproc::{self};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

static NEXT_RACK_ID: AtomicU64 = AtomicU64::new(1);

pub struct Rack {
    pub id: u64,
    pub cfg: SimConfig,
    pub pool: Arc<Pool>,
    pub orch: Arc<Orchestrator>,
    pub topo: Topology,
    /// Next out-of-rack host id handed out by `remote_proc_env`.
    next_ext_host: AtomicU32,
}

impl Rack {
    pub fn new(cfg: SimConfig) -> Arc<Rack> {
        let pool = Pool::new(&cfg).expect("pool mmap");
        let orch = Orchestrator::new(&cfg, Arc::clone(&pool));
        simproc::set_enforcement(cfg.enforce_protection);
        // Arm the crash-fault injector when the config names a kill
        // point; kills count on this rack's fault counters.
        if let Some(plan) = crate::fault::FaultPlan::from_config(&cfg) {
            crate::fault::arm_with_sink(plan, Arc::downgrade(&orch.fault_counters()));
        }
        let topo = Topology::from_config(&cfg);
        let next_ext_host = AtomicU32::new(cfg.rack_hosts as u32);
        Arc::new(Rack {
            id: NEXT_RACK_ID.fetch_add(1, Ordering::Relaxed),
            cfg,
            pool,
            orch,
            topo,
            next_ext_host,
        })
    }

    /// Convenience constructors matching the two standard configs.
    pub fn for_tests() -> Arc<Rack> {
        Rack::new(SimConfig::for_tests())
    }

    pub fn for_bench() -> Arc<Rack> {
        Rack::new(SimConfig::for_bench())
    }

    /// Create a new simulated process on `host`.
    pub fn proc_env(self: &Arc<Self>, host: u32) -> ProcEnv {
        let proc = simproc::fresh_proc_id();
        ProcEnv { rack: Arc::clone(self), proc, uid: proc, host }
    }

    /// A process on a fresh host *outside* this rack's CXL domains
    /// (RDMA only). Every call allocates a new out-of-rack host — its
    /// own singleton pod — so two "remote datacenters" are never
    /// accidentally coherent with each other.
    pub fn remote_proc_env(self: &Arc<Self>) -> ProcEnv {
        self.proc_env(self.next_ext_host.fetch_add(1, Ordering::Relaxed))
    }

    /// A process on the `idx`-th host of in-rack pod `pod`.
    pub fn pod_env(self: &Arc<Self>, pod: PodId, idx: usize) -> ProcEnv {
        self.proc_env(self.topo.host_in_pod(pod, idx))
    }

    /// Pod id of `host` (out-of-rack hosts get synthetic singleton pods).
    pub fn pod_of(&self, host: u32) -> PodId {
        self.topo.pod_of(host)
    }

    /// Are two hosts CXL-reachable (same rack *and* same pod)?
    pub fn same_cxl_domain(&self, host_a: u32, host_b: u32) -> bool {
        self.topo.cxl_reachable(host_a, host_b)
    }
}

/// A simulated process: identity + rack handle. Cheap to clone; bind
/// to the current thread with `enter()` (or run closures via `run`).
#[derive(Clone)]
pub struct ProcEnv {
    pub rack: Arc<Rack>,
    pub proc: u32,
    pub uid: Uid,
    pub host: u32,
}

impl ProcEnv {
    /// Bind this proc identity to the current thread.
    pub fn enter(&self) {
        simproc::bind(self.proc, self.host);
    }

    /// Run `f` under this proc's identity, restoring the previous one.
    pub fn run<R>(&self, f: impl FnOnce() -> R) -> R {
        simproc::with_identity(self.proc, self.host, f)
    }

    /// Spawn an OS thread bound to this proc identity.
    pub fn spawn<F, R>(&self, f: F) -> std::thread::JoinHandle<R>
    where
        F: FnOnce() -> R + Send + 'static,
        R: Send + 'static,
    {
        let env = self.clone();
        std::thread::spawn(move || {
            env.enter();
            f()
        })
    }

    pub fn in_rack(&self) -> bool {
        (self.host as usize) < self.rack.cfg.rack_hosts
    }

    /// This proc's pod.
    pub fn pod(&self) -> PodId {
        self.rack.pod_of(self.host)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn procs_get_distinct_ids() {
        let rack = Rack::for_tests();
        let a = rack.proc_env(0);
        let b = rack.proc_env(1);
        assert_ne!(a.proc, b.proc);
        a.run(|| {
            assert_eq!(simproc::current_proc(), a.proc);
            assert_eq!(simproc::current_host(), 0);
        });
    }

    #[test]
    fn cxl_domain_boundaries() {
        let rack = Rack::for_tests();
        assert!(rack.same_cxl_domain(0, 31));
        let remote = rack.remote_proc_env();
        assert!(!remote.in_rack());
        assert!(!rack.same_cxl_domain(0, remote.host));
    }

    #[test]
    fn remote_envs_get_distinct_hosts_and_pods() {
        let rack = Rack::for_tests();
        let a = rack.remote_proc_env();
        let b = rack.remote_proc_env();
        assert_ne!(a.host, b.host, "no more single magic remote host");
        assert_ne!(a.pod(), b.pod(), "each remote host is its own pod");
        assert!(!rack.same_cxl_domain(a.host, b.host));
    }

    #[test]
    fn pods_partition_the_rack() {
        let mut cfg = SimConfig::for_tests();
        cfg.rack_hosts = 4;
        cfg.pods = 2;
        let rack = Rack::new(cfg);
        assert_eq!(rack.pod_of(0), 0);
        assert_eq!(rack.pod_of(1), 0);
        assert_eq!(rack.pod_of(2), 1);
        assert_eq!(rack.pod_of(3), 1);
        assert!(rack.same_cxl_domain(0, 1));
        assert!(rack.same_cxl_domain(2, 3));
        assert!(!rack.same_cxl_domain(1, 2), "pods are separate CXL domains");
        let e = rack.pod_env(1, 0);
        assert_eq!(e.host, 2);
        assert_eq!(e.pod(), 1);
        assert!(e.in_rack());
    }

    #[test]
    fn spawned_thread_carries_identity() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(2);
        let p = env.proc;
        env.spawn(move || {
            assert_eq!(simproc::current_proc(), p);
            assert_eq!(simproc::current_host(), 2);
        })
        .join()
        .unwrap();
    }
}
