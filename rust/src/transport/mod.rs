//! Simulated network transports (DESIGN.md §1: the RDMA / TCP / UDS
//! substitution).
//!
//! Every baseline RPC framework and RPCool's RDMA fallback move bytes
//! through a `SimNic`: an in-process message queue that charges the
//! calibrated wire costs (one-way latency + per-page bandwidth) of the
//! link it models. Figure 1's RTT ladder (CXL < RDMA < TCP) comes from
//! these models; the endpoint code on top is what differs per
//! framework (serialization, framing, coherence).

pub mod simnet;

pub use simnet::{LinkKind, SimNic, SimNicPair};

use crate::error::Result;

/// A bidirectional byte transport between two endpoints.
pub trait Transport: Send + Sync {
    /// Send a message (blocking; charges wire costs).
    fn send(&self, payload: &[u8]) -> Result<()>;
    /// Receive the next message (blocking with timeout).
    fn recv(&self, timeout: std::time::Duration) -> Result<Vec<u8>>;
    /// Non-blocking receive.
    fn try_recv(&self) -> Option<Vec<u8>>;
    /// The link this transport models (for reporting).
    fn kind(&self) -> LinkKind;
}
