//! The simulated NIC: an in-process duplex message channel with a
//! calibrated latency/bandwidth cost model per link type.
//!
//! RDMA models a Mellanox CX-5-class NIC (paper's testbed), TCP models
//! the kernel stack over the same wire (IPoIB), UDS models a local
//! UNIX domain socket, and HTTP2 layers gRPC's framing cost on TCP.

use crate::config::CostModel;
use crate::error::{Result, RpcError};
use crate::memory::pool::Charger;
use crate::transport::Transport;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// Kernel-bypass verbs (eRPC / RPCool-DSM class).
    Rdma,
    /// Kernel TCP over the same fabric (IPoIB).
    Tcp,
    /// UNIX domain socket (same host).
    Uds,
    /// TCP + HTTP/2 framing (gRPC class).
    Http2,
}

impl LinkKind {
    /// One-way cost of a message of `bytes` under this link model.
    pub fn oneway_ns(&self, cost: &CostModel, bytes: usize) -> u64 {
        let pages = (bytes as u64).div_ceil(4096);
        match self {
            LinkKind::Rdma => cost.rdma_oneway_ns + pages.saturating_sub(1) * cost.rdma_page_ns
                + if bytes > 0 { (bytes as u64 % 4096) * cost.rdma_page_ns / 4096 } else { 0 },
            LinkKind::Tcp => cost.tcp_oneway_ns + pages.saturating_sub(1) * cost.tcp_page_ns,
            LinkKind::Uds => cost.uds_oneway_ns + pages.saturating_sub(1) * cost.uds_page_ns,
            LinkKind::Http2 => {
                cost.tcp_oneway_ns
                    + cost.http2_framing_ns
                    + pages.saturating_sub(1) * cost.tcp_page_ns
            }
        }
    }
}

struct Queue {
    msgs: Mutex<VecDeque<Vec<u8>>>,
    cv: Condvar,
}

impl Queue {
    fn new() -> Arc<Queue> {
        Arc::new(Queue { msgs: Mutex::new(VecDeque::new()), cv: Condvar::new() })
    }

    fn push(&self, m: Vec<u8>) {
        self.msgs.lock().unwrap().push_back(m);
        self.cv.notify_one();
    }

    fn pop(&self, timeout: Duration) -> Option<Vec<u8>> {
        let mut q = self.msgs.lock().unwrap();
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if let Some(m) = q.pop_front() {
                return Some(m);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (qq, _t) = self.cv.wait_timeout(q, deadline - now).unwrap();
            q = qq;
        }
    }

    fn try_pop(&self) -> Option<Vec<u8>> {
        self.msgs.lock().unwrap().pop_front()
    }
}

/// One endpoint of a simulated link.
pub struct SimNic {
    kind: LinkKind,
    tx: Arc<Queue>,
    rx: Arc<Queue>,
    charger: Arc<Charger>,
}

impl Transport for SimNic {
    fn send(&self, payload: &[u8]) -> Result<()> {
        // Charge the one-way wire cost on the sender (models DMA +
        // serialization onto the wire; the receiver's poll observes it
        // after the charge completes, which orders like a real wire).
        self.charger
            .charge_ns(self.kind.oneway_ns(&self.charger.cost, payload.len()));
        self.tx.push(payload.to_vec());
        Ok(())
    }

    fn recv(&self, timeout: Duration) -> Result<Vec<u8>> {
        self.rx
            .pop(timeout)
            .ok_or_else(|| RpcError::Timeout(format!("{:?} recv", self.kind)))
    }

    fn try_recv(&self) -> Option<Vec<u8>> {
        self.rx.try_pop()
    }

    fn kind(&self) -> LinkKind {
        self.kind
    }
}

/// Both ends of a link.
pub struct SimNicPair {
    pub a: Arc<SimNic>,
    pub b: Arc<SimNic>,
}

impl SimNicPair {
    pub fn new(kind: LinkKind, charger: Arc<Charger>) -> SimNicPair {
        let q_ab = Queue::new();
        let q_ba = Queue::new();
        SimNicPair {
            a: Arc::new(SimNic {
                kind,
                tx: Arc::clone(&q_ab),
                rx: Arc::clone(&q_ba),
                charger: Arc::clone(&charger),
            }),
            b: Arc::new(SimNic { kind, tx: q_ba, rx: q_ab, charger }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChargePolicy, CostModel};

    fn pair(kind: LinkKind, policy: ChargePolicy) -> SimNicPair {
        SimNicPair::new(kind, Arc::new(Charger::new(CostModel::default(), policy)))
    }

    #[test]
    fn duplex_message_passing() {
        let p = pair(LinkKind::Rdma, ChargePolicy::Skip);
        p.a.send(b"hello").unwrap();
        assert_eq!(p.b.recv(Duration::from_secs(1)).unwrap(), b"hello");
        p.b.send(b"world").unwrap();
        assert_eq!(p.a.recv(Duration::from_secs(1)).unwrap(), b"world");
        assert!(p.a.try_recv().is_none());
    }

    #[test]
    fn recv_timeout() {
        let p = pair(LinkKind::Tcp, ChargePolicy::Skip);
        let e = p.a.recv(Duration::from_millis(5));
        assert!(matches!(e, Err(RpcError::Timeout(_))));
    }

    #[test]
    fn cross_thread_pingpong() {
        let p = pair(LinkKind::Rdma, ChargePolicy::Skip);
        let b = Arc::clone(&p.b);
        let t = std::thread::spawn(move || {
            for _ in 0..100 {
                let m = b.recv(Duration::from_secs(5)).unwrap();
                b.send(&m).unwrap();
            }
        });
        for i in 0..100u32 {
            p.a.send(&i.to_le_bytes()).unwrap();
            let r = p.a.recv(Duration::from_secs(5)).unwrap();
            assert_eq!(r, i.to_le_bytes());
        }
        t.join().unwrap();
    }

    #[test]
    fn cost_ladder_matches_fig1() {
        // CXL signal < RDMA < TCP < HTTP2 (Figure 1's RTT ordering).
        let c = CostModel::default();
        let rdma = LinkKind::Rdma.oneway_ns(&c, 64);
        let tcp = LinkKind::Tcp.oneway_ns(&c, 64);
        let http = LinkKind::Http2.oneway_ns(&c, 64);
        assert!(c.cxl_signal_ns < rdma);
        assert!(rdma < tcp);
        assert!(tcp < http);
    }

    #[test]
    fn bandwidth_term_scales_with_pages() {
        let c = CostModel::default();
        let small = LinkKind::Rdma.oneway_ns(&c, 64);
        let big = LinkKind::Rdma.oneway_ns(&c, 64 * 4096);
        assert!(big > small + 60 * c.rdma_page_ns);
    }
}
