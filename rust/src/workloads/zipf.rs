//! Key-choice distributions for YCSB (Cooper et al., SoCC'10):
//! scrambled Zipfian (the default "zipfian"), "latest", and uniform.
//!
//! The Zipfian sampler is the standard Gray et al. rejection-free
//! construction used by the reference YCSB implementation, with FNV
//! scrambling so hot keys are spread across the keyspace.

use crate::util::rng::{mix64, Rng};

pub const ZIPF_THETA: f64 = 0.99;

/// Rejection-free Zipfian over [0, n).
#[derive(Clone, Debug)]
pub struct Zipfian {
    n: u64,
    theta: f64,
    zetan: f64,
    zeta2: f64,
    alpha: f64,
    eta: f64,
}

fn zeta(n: u64, theta: f64) -> f64 {
    // Direct sum; n ≤ a few million in our experiments.
    let mut z = 0.0;
    for i in 1..=n {
        z += 1.0 / (i as f64).powf(theta);
    }
    z
}

impl Zipfian {
    pub fn new(n: u64) -> Zipfian {
        Self::with_theta(n, ZIPF_THETA)
    }

    pub fn with_theta(n: u64, theta: f64) -> Zipfian {
        assert!(n > 0);
        let zetan = zeta(n, theta);
        let zeta2 = zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Zipfian { n, theta, zetan, zeta2, alpha, eta }
    }

    /// Next rank (0 = most popular).
    pub fn next(&self, rng: &mut Rng) -> u64 {
        let u = rng.next_f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let v = (self.n as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64;
        v.min(self.n - 1)
    }

    #[inline]
    pub fn zeta2(&self) -> f64 {
        self.zeta2
    }
}

/// YCSB key-choice distributions.
#[derive(Clone, Debug)]
pub enum KeyDist {
    Uniform { n: u64 },
    /// Scrambled Zipfian: popular *ranks* hashed over the keyspace.
    Zipfian(Zipfian),
    /// "Latest": Zipfian biased toward the most recently inserted key.
    Latest(Zipfian),
}

impl KeyDist {
    pub fn uniform(n: u64) -> KeyDist {
        KeyDist::Uniform { n }
    }
    pub fn zipfian(n: u64) -> KeyDist {
        KeyDist::Zipfian(Zipfian::new(n))
    }
    pub fn latest(n: u64) -> KeyDist {
        KeyDist::Latest(Zipfian::new(n))
    }

    /// Sample a key in [0, current_n).
    pub fn next(&self, rng: &mut Rng, current_n: u64) -> u64 {
        match self {
            KeyDist::Uniform { .. } => rng.next_below(current_n.max(1)),
            KeyDist::Zipfian(z) => {
                let rank = z.next(rng);
                mix64(rank) % current_n.max(1)
            }
            KeyDist::Latest(z) => {
                let back = z.next(rng);
                current_n.saturating_sub(1).saturating_sub(back % current_n.max(1))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipfian_is_skewed() {
        let z = Zipfian::new(10_000);
        let mut rng = Rng::new(1);
        let mut counts = vec![0u64; 10_000];
        for _ in 0..100_000 {
            counts[z.next(&mut rng) as usize] += 1;
        }
        // Rank 0 should dominate; top-10 ranks take a large share.
        let top10: u64 = counts[..10].iter().sum();
        assert!(counts[0] > counts[100] * 5, "rank0={} rank100={}", counts[0], counts[100]);
        assert!(top10 as f64 / 100_000.0 > 0.15, "top10 share {top10}");
    }

    #[test]
    fn zipfian_within_bounds() {
        let z = Zipfian::new(100);
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            assert!(z.next(&mut rng) < 100);
        }
    }

    #[test]
    fn scrambled_spreads_hotkeys() {
        let d = KeyDist::zipfian(1000);
        let mut rng = Rng::new(3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..1000 {
            seen.insert(d.next(&mut rng, 1000));
        }
        // Scrambling must not collapse onto a handful of keys.
        assert!(seen.len() > 100, "only {} distinct keys", seen.len());
    }

    #[test]
    fn latest_prefers_recent() {
        let d = KeyDist::latest(10_000);
        let mut rng = Rng::new(4);
        let mut newer = 0;
        for _ in 0..10_000 {
            if d.next(&mut rng, 10_000) >= 5_000 {
                newer += 1;
            }
        }
        assert!(newer > 7_000, "latest skew too weak: {newer}");
    }

    #[test]
    fn uniform_covers_space() {
        let d = KeyDist::uniform(100);
        let mut rng = Rng::new(5);
        let mut counts = [0u32; 100];
        for _ in 0..100_000 {
            counts[d.next(&mut rng, 100) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 500), "uniform too lumpy");
    }
}
