//! Workload generators: YCSB A–F (Figs. 9–10), NoBench documents
//! (Fig. 11), and the key-choice distributions underneath.

pub mod nobench;
pub mod ycsb;
pub mod zipf;

pub use nobench::{NoBench, NumRangeQuery};
pub use ycsb::{Op, OpSpec, WorkloadKind, Ycsb};
pub use zipf::{KeyDist, Zipfian};
