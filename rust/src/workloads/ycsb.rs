//! YCSB core workloads A–F (Cooper et al., SoCC'10) — the generator
//! behind Figures 9 and 10.
//!
//! Paper setup: 100K keys loaded, 1M operations per workload.
//! Memcached cannot run E (no SCAN); MongoDB runs all six.

use crate::util::rng::Rng;
use crate::workloads::zipf::KeyDist;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    Read,
    Update,
    Insert,
    Scan { len: usize },
    ReadModifyWrite,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    A, // 50/50 read/update, zipfian
    B, // 95/5 read/update, zipfian
    C, // 100 read, zipfian
    D, // 95/5 read/insert, latest
    E, // 95/5 scan/insert, zipfian
    F, // 50/50 read/rmw, zipfian
}

impl WorkloadKind {
    pub fn all() -> [WorkloadKind; 6] {
        [
            WorkloadKind::A,
            WorkloadKind::B,
            WorkloadKind::C,
            WorkloadKind::D,
            WorkloadKind::E,
            WorkloadKind::F,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::A => "A",
            WorkloadKind::B => "B",
            WorkloadKind::C => "C",
            WorkloadKind::D => "D",
            WorkloadKind::E => "E",
            WorkloadKind::F => "F",
        }
    }

    pub fn has_scan(&self) -> bool {
        matches!(self, WorkloadKind::E)
    }
}

/// Operation stream for one workload.
pub struct Ycsb {
    kind: WorkloadKind,
    dist: KeyDist,
    rng: Rng,
    /// Keys currently loaded (inserts grow it).
    pub nkeys: u64,
    pub value_len: usize,
    max_scan: usize,
}

/// One concrete operation against keyspace key ids.
#[derive(Clone, Debug)]
pub struct OpSpec {
    pub op: Op,
    pub key: u64,
}

impl Ycsb {
    pub fn new(kind: WorkloadKind, nkeys: u64, seed: u64) -> Ycsb {
        let dist = match kind {
            WorkloadKind::D => KeyDist::latest(nkeys),
            _ => KeyDist::zipfian(nkeys),
        };
        Ycsb { kind, dist, rng: Rng::new(seed), nkeys, value_len: 100, max_scan: 100 }
    }

    /// YCSB key format.
    pub fn key_name(id: u64) -> String {
        format!("user{id:019}")
    }

    /// Deterministic value bytes for a key (load phase).
    pub fn value_for(&mut self, len: usize) -> Vec<u8> {
        let mut v = vec![0u8; len];
        self.rng.fill_bytes(&mut v);
        v
    }

    pub fn next_op(&mut self) -> OpSpec {
        let p = self.rng.next_f64();
        let (op, key) = match self.kind {
            WorkloadKind::A => {
                if p < 0.5 {
                    (Op::Read, self.pick())
                } else {
                    (Op::Update, self.pick())
                }
            }
            WorkloadKind::B => {
                if p < 0.95 {
                    (Op::Read, self.pick())
                } else {
                    (Op::Update, self.pick())
                }
            }
            WorkloadKind::C => (Op::Read, self.pick()),
            WorkloadKind::D => {
                if p < 0.95 {
                    (Op::Read, self.pick())
                } else {
                    (Op::Insert, self.insert_key())
                }
            }
            WorkloadKind::E => {
                if p < 0.95 {
                    let len = 1 + self.rng.next_below(self.max_scan as u64) as usize;
                    (Op::Scan { len }, self.pick())
                } else {
                    (Op::Insert, self.insert_key())
                }
            }
            WorkloadKind::F => {
                if p < 0.5 {
                    (Op::Read, self.pick())
                } else {
                    (Op::ReadModifyWrite, self.pick())
                }
            }
        };
        OpSpec { op, key }
    }

    fn pick(&mut self) -> u64 {
        self.dist.next(&mut self.rng, self.nkeys)
    }

    fn insert_key(&mut self) -> u64 {
        let k = self.nkeys;
        self.nkeys += 1;
        k
    }
}

/// Mix statistics (for tests and reporting).
pub fn mix_of(kind: WorkloadKind, n: usize, seed: u64) -> std::collections::HashMap<&'static str, usize> {
    let mut w = Ycsb::new(kind, 1000, seed);
    let mut m = std::collections::HashMap::new();
    for _ in 0..n {
        let name = match w.next_op().op {
            Op::Read => "read",
            Op::Update => "update",
            Op::Insert => "insert",
            Op::Scan { .. } => "scan",
            Op::ReadModifyWrite => "rmw",
        };
        *m.entry(name).or_insert(0) += 1;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn share(m: &std::collections::HashMap<&str, usize>, k: &str, n: usize) -> f64 {
        *m.get(k).unwrap_or(&0) as f64 / n as f64
    }

    #[test]
    fn workload_a_is_50_50() {
        let m = mix_of(WorkloadKind::A, 20_000, 1);
        assert!((share(&m, "read", 20_000) - 0.5).abs() < 0.02);
        assert!((share(&m, "update", 20_000) - 0.5).abs() < 0.02);
    }

    #[test]
    fn workload_b_reads_dominate() {
        let m = mix_of(WorkloadKind::B, 20_000, 2);
        assert!((share(&m, "read", 20_000) - 0.95).abs() < 0.01);
    }

    #[test]
    fn workload_c_read_only() {
        let m = mix_of(WorkloadKind::C, 5_000, 3);
        assert_eq!(share(&m, "read", 5_000), 1.0);
    }

    #[test]
    fn workload_d_inserts_grow_keyspace() {
        let mut w = Ycsb::new(WorkloadKind::D, 1000, 4);
        let n0 = w.nkeys;
        for _ in 0..10_000 {
            w.next_op();
        }
        assert!(w.nkeys > n0 + 300, "inserts grew only to {}", w.nkeys);
    }

    #[test]
    fn workload_e_scans() {
        let mut w = Ycsb::new(WorkloadKind::E, 1000, 5);
        let mut scans = 0;
        for _ in 0..1000 {
            if let Op::Scan { len } = w.next_op().op {
                assert!(len >= 1 && len <= 100);
                scans += 1;
            }
        }
        assert!(scans > 900);
    }

    #[test]
    fn keys_within_space() {
        for kind in WorkloadKind::all() {
            let mut w = Ycsb::new(kind, 500, 6);
            for _ in 0..5_000 {
                let op = w.next_op();
                assert!(op.key < w.nkeys, "{kind:?} key {} ≥ {}", op.key, w.nkeys);
            }
        }
    }

    #[test]
    fn key_names_stable() {
        assert_eq!(Ycsb::key_name(7), "user0000000000000000007");
    }
}
