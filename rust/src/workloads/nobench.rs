//! NoBench-style JSON document generator (Chasseur et al., WebDB'13)
//! — the load generator the paper uses to populate CoolDB with 100K
//! documents and drive 1K search queries (Figure 11).
//!
//! Documents follow NoBench's schema: common string/numeric/bool
//! attributes, a dynamically-typed attribute, a nested array of
//! strings, a nested object, and sparse attributes drawn from a wide
//! space so most documents lack most of them.

use crate::apps::doc::Val;
use crate::util::rng::Rng;

pub struct NoBench {
    rng: Rng,
    next_id: u64,
}

impl NoBench {
    pub fn new(seed: u64) -> NoBench {
        NoBench { rng: Rng::new(seed), next_id: 0 }
    }

    /// Generate the next document.
    pub fn doc(&mut self) -> Val {
        let id = self.next_id;
        self.next_id += 1;
        let r = &mut self.rng;

        let mut fields: Vec<(String, Val)> = vec![
            ("_id".into(), Val::Num(id as f64)),
            ("str1".into(), Val::Str(r.alnum_string(12))),
            ("str2".into(), Val::Str(format!("GROUP-{}", r.next_below(100)))),
            ("num".into(), Val::Num(r.next_below(100_000) as f64)),
            ("bool".into(), Val::Bool(r.chance(0.5))),
        ];

        // dyn1: dynamically typed (string or number).
        fields.push((
            "dyn1".into(),
            if r.chance(0.5) {
                Val::Str(r.alnum_string(8))
            } else {
                Val::Num(r.next_below(1000) as f64)
            },
        ));

        // nested_arr: array of strings (variable length).
        let alen = 1 + r.next_below(8) as usize;
        fields.push((
            "nested_arr".into(),
            Val::Arr((0..alen).map(|_| Val::Str(r.alnum_string(6))).collect()),
        ));

        // nested_obj: object with two inner fields.
        fields.push((
            "nested_obj".into(),
            Val::Obj(vec![
                ("str".into(), Val::Str(r.alnum_string(10))),
                ("num".into(), Val::Num(r.next_below(10_000) as f64)),
            ]),
        ));

        // Sparse attributes: 10 of 1000 possible, clustered by id.
        let cluster = (id % 100) * 10;
        for j in 0..10 {
            fields.push((
                format!("sparse_{:03}", cluster + j),
                Val::Str(r.alnum_string(8)),
            ));
        }

        Val::Obj(fields)
    }

    /// Generate `n` documents keyed "key<id>".
    pub fn corpus(&mut self, n: usize) -> Vec<(String, Val)> {
        (0..n)
            .map(|_| {
                let d = self.doc();
                let id = d.get("_id").and_then(Val::as_num).unwrap() as u64;
                (format!("key{id}"), d)
            })
            .collect()
    }
}

/// A NoBench-style search predicate: `num` within a range — the
/// query shape of the paper's "search" phase.
#[derive(Clone, Copy, Debug)]
pub struct NumRangeQuery {
    pub lo: f64,
    pub hi: f64,
}

impl NumRangeQuery {
    pub fn random(rng: &mut Rng) -> NumRangeQuery {
        let lo = rng.next_below(90_000) as f64;
        NumRangeQuery { lo, hi: lo + 1000.0 }
    }

    pub fn matches(&self, doc: &Val) -> bool {
        doc.get("num").and_then(Val::as_num).map(|n| n >= self.lo && n < self.hi).unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn docs_have_nobench_schema() {
        let mut g = NoBench::new(1);
        let d = g.doc();
        for key in ["_id", "str1", "str2", "num", "bool", "dyn1", "nested_arr", "nested_obj"] {
            assert!(d.get(key).is_some(), "missing {key}");
        }
        // 8 common + 10 sparse
        if let Val::Obj(f) = &d {
            assert_eq!(f.len(), 18);
        } else {
            panic!("doc must be an object");
        }
    }

    #[test]
    fn ids_are_sequential_and_corpus_keys_match() {
        let mut g = NoBench::new(2);
        let c = g.corpus(100);
        assert_eq!(c.len(), 100);
        assert_eq!(c[37].0, "key37");
        assert_eq!(c[37].1.get("_id").unwrap().as_num(), Some(37.0));
    }

    #[test]
    fn sparse_attrs_are_sparse() {
        let mut g = NoBench::new(3);
        let docs = g.corpus(200);
        let with_sparse_000 =
            docs.iter().filter(|(_, d)| d.get("sparse_000").is_some()).count();
        assert!(with_sparse_000 < 10, "sparse_000 in {with_sparse_000}/200 docs");
    }

    #[test]
    fn range_query_selects_subset() {
        let mut g = NoBench::new(4);
        let docs = g.corpus(1000);
        let q = NumRangeQuery { lo: 0.0, hi: 1000.0 };
        let hits = docs.iter().filter(|(_, d)| q.matches(d)).count();
        assert!(hits > 0 && hits < 100, "selectivity off: {hits}");
    }
}
