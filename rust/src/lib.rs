//! # RPCool — fast RPCs over shared CXL memory (reproduction)
//!
//! A from-scratch reproduction of *Telepathic Datacenters: Fast RPCs
//! using Shared CXL Memory* (Mahar et al., 2024) as a three-layer
//! Rust + JAX + Pallas stack. The Rust layer implements the paper's
//! system: zero-serialization RPCs whose arguments are native
//! pointer-rich data structures in (simulated) CXL shared memory,
//! made safe by **seals** (senders lose write access to in-flight
//! arguments) and **MPK sandboxes** (receivers dereference untrusted
//! pointers inside a memory window), scaled beyond a CXL pod by the
//! **cluster plane** (`cluster`): a pod-aware rack topology whose
//! cross-pod data path is an RDMA-backed software-coherence (DSM)
//! layer, and kept leak-free by a global **orchestrator** (leases,
//! quotas, orphaned-heap GC). The same `TransportSel::Auto` call site
//! rides CXL inside a pod and RDMA/DSM across pods.
//!
//! See `DESIGN.md` at the repository root for the
//! hardware-substitution map and the per-experiment index.

// `pjrt_runtime` is an opt-in compile-time cfg (see src/runtime/mod.rs);
// older toolchains don't know the unexpected_cfgs lint itself.
#![allow(unknown_lints)]
#![allow(unexpected_cfgs)]

pub mod apps;
pub mod baselines;
pub mod benchkit;
pub mod channel;
pub mod cluster;
pub mod config;
pub mod daemon;
pub mod dsm;
pub mod error;
pub mod fault;
pub mod inference;
pub mod memory;
pub mod metrics;
pub mod mpk;
pub mod orchestrator;
pub mod rack;
pub mod runtime;
pub mod sandbox;
pub mod seal;
pub mod simproc;
pub mod transport;
pub mod util;
pub mod workloads;

pub use channel::{
    CallArg, CallCtx, CallHandle, CallOpts, ChannelBuilder, ChannelOpts, Connection, Reply,
    RetryPolicy, Rpc, RpcServer, Shard, TransportSel, TypedCallHandle,
};
pub use rack::{ProcEnv, Rack};

pub use config::{ChargePolicy, CostModel, SimConfig};
pub use error::{Result, RpcError};
