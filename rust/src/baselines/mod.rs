//! Baseline RPC frameworks the paper evaluates against (§6):
//! RDMA-based eRPC, TCP-based gRPC and ThriftRPC, UNIX-domain-socket
//! RPC, and the CXL-based ZhangRPC — all re-implemented over the
//! simulated substrates so every Table 1a / Figure 9–12 comparison can
//! be regenerated.

pub mod netrpc;
pub mod wire;
pub mod zhang;

pub use netrpc::{pair, Flavor, NetRpcClient, NetRpcServer};
pub use wire::{charge_serialize, Wire, WireBuf, WireCur};
pub use zhang::{CxlRef, ZhangAlloc, ZhangClient};
