//! ZhangRPC — the failure-resilient CXL RPC baseline (Zhang et al.,
//! SOSP'23 [40], as characterized in the paper's §6.2).
//!
//! Differences from RPCool that Table 1a attributes the 7.2× gap to:
//!  * every CXL object carries an 8-byte header (failure-resilience
//!    metadata), created through their allocator;
//!  * references are fat pointers (`CxlRef`), not native pointers, and
//!    linking a child into a parent requires `link_reference()` on the
//!    critical path;
//!  * each RPC commits a failure-resilience journal entry.
//!
//! We reproduce that object model over our CXL substrate and charge
//! the calibrated costs for the header/ref/link/commit work.

use crate::channel::{CallCtx, CallOpts, Connection, RpcServer};
use crate::error::Result;
use crate::memory::heap::Heap;
use crate::memory::pod::Pod;
use crate::memory::ptr::ShmPtr;
use crate::memory::scope::ShmAlloc;
use crate::rack::ProcEnv;
use std::sync::Arc;

/// Fat pointer: address + object id + generation (what breaks native
/// pointer compatibility in their design).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CxlRef<T> {
    pub addr: usize,
    pub obj_id: u64,
    pub generation: u32,
    _m: std::marker::PhantomData<fn() -> T>,
}

unsafe impl<T: Pod> Pod for CxlRef<T> {}

impl<T> CxlRef<T> {
    pub const fn null() -> Self {
        CxlRef { addr: 0, obj_id: 0, generation: 0, _m: std::marker::PhantomData }
    }

    pub fn is_null(&self) -> bool {
        self.addr == 0
    }
}

/// Per-object header their allocator prepends (8 bytes).
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct ObjHeader {
    pub obj_id: u32,
    pub type_and_flags: u32,
}

unsafe impl Pod for ObjHeader {}

/// ZhangRPC's allocator facade over a connection heap.
pub struct ZhangAlloc {
    heap: Arc<Heap>,
    next_obj: std::sync::atomic::AtomicU64,
}

impl ZhangAlloc {
    pub fn new(heap: Arc<Heap>) -> ZhangAlloc {
        ZhangAlloc { heap, next_obj: std::sync::atomic::AtomicU64::new(1) }
    }

    /// Allocate a CXL object: header + payload, returns a fat ref.
    pub fn create<T: Pod>(&self, val: T) -> Result<CxlRef<T>> {
        let charger = &self.heap.pool().charger;
        charger.charge_ns(charger.cost.zhang_obj_ns);
        let obj_id = self.next_obj.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let total = std::mem::size_of::<ObjHeader>() + std::mem::size_of::<T>().max(1);
        let base = ShmAlloc::alloc_bytes(&self.heap, total)?;
        let hdr: ShmPtr<ObjHeader> = ShmPtr::from_addr(base);
        hdr.write(ObjHeader { obj_id: obj_id as u32, type_and_flags: 0 })?;
        let payload = base + std::mem::size_of::<ObjHeader>();
        let p: ShmPtr<T> = ShmPtr::from_addr(payload);
        p.write(val)?;
        Ok(CxlRef { addr: payload, obj_id, generation: 1, _m: std::marker::PhantomData })
    }

    /// Their `link_reference()` API: installing a child ref into a
    /// parent object is a tracked operation (for failure resilience),
    /// charged on the critical path.
    pub fn link_reference<P: Pod, C: Pod>(
        &self,
        parent: CxlRef<P>,
        slot: ShmPtr<CxlRef<C>>,
        child: CxlRef<C>,
    ) -> Result<()> {
        let charger = &self.heap.pool().charger;
        charger.charge_ns(charger.cost.zhang_obj_ns);
        let _ = parent; // journal would record parent obj id
        slot.write(child)
    }

    pub fn read<T: Pod>(&self, r: CxlRef<T>) -> Result<T> {
        ShmPtr::<T>::from_addr(r.addr).read()
    }

    pub fn heap(&self) -> &Arc<Heap> {
        &self.heap
    }
}

/// Client handle: an RPCool connection driven through ZhangRPC's
/// object model + per-RPC commit cost.
pub struct ZhangClient {
    pub conn: Connection,
    pub alloc: ZhangAlloc,
}

impl ZhangClient {
    pub fn connect(env: &ProcEnv, name: &str) -> Result<ZhangClient> {
        let conn = Connection::connect(env, name)?;
        let alloc = ZhangAlloc::new(Arc::clone(conn.heap()));
        Ok(ZhangClient { conn, alloc })
    }

    /// An RPC in their system: journal commit + the CXL transport.
    pub fn call<T: Pod>(&self, func: u32, arg: CxlRef<T>) -> Result<u64> {
        let charger = &self.conn.heap().pool().charger;
        charger.charge_ns(charger.cost.zhang_commit_ns);
        self.conn.invoke(func, (arg.addr, std::mem::size_of::<T>()), CallOpts::new())
    }
}

/// Serve a ZhangRPC channel (same server loop; handlers read fat refs).
pub fn open_server(env: &ProcEnv, name: &str) -> Result<RpcServer> {
    crate::channel::Rpc::open(env, name)
}

/// Handler-side helper: interpret the argument as a fat-ref payload.
pub fn arg_payload<T: Pod>(ctx: &CallCtx) -> Result<T> {
    ShmPtr::<T>::from_addr(ctx.arg).read()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rack::Rack;

    #[test]
    fn object_model_roundtrip() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = open_server(&env, "zhang-objs").unwrap();
        server.add(1, |ctx| {
            let v: u64 = arg_payload(ctx)?;
            Ok(v * 3)
        });
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let client = ZhangClient::connect(&cenv, "zhang-objs").unwrap();
        cenv.run(|| {
            let r = client.alloc.create(14u64).unwrap();
            assert_eq!(client.alloc.read(r).unwrap(), 14);
            assert_eq!(client.call(1, r).unwrap(), 42);
        });
        drop(client);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn tree_building_needs_link_reference() {
        // The paper's example: building a tree requires a CXL object +
        // CxlRef per node plus link_reference per edge — all charged.
        #[derive(Clone, Copy)]
        struct Node {
            value: u64,
            left: CxlRef<Node>,
            right: CxlRef<Node>,
        }
        unsafe impl Pod for Node {}

        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let heap = rack.orch.create_heap("zhang-tree", 1 << 20, env.proc).unwrap().0;
        let alloc = ZhangAlloc::new(heap);
        let charged_before = alloc.heap().pool().charger.total_charged_ns();

        let leaf_l = alloc.create(Node { value: 1, left: CxlRef::null(), right: CxlRef::null() }).unwrap();
        let leaf_r = alloc.create(Node { value: 2, left: CxlRef::null(), right: CxlRef::null() }).unwrap();
        let root = alloc.create(Node { value: 0, left: CxlRef::null(), right: CxlRef::null() }).unwrap();
        // Link children via the tracked API.
        let left_slot: ShmPtr<CxlRef<Node>> = ShmPtr::from_addr(root.addr + 8);
        let right_slot: ShmPtr<CxlRef<Node>> =
            ShmPtr::from_addr(root.addr + 8 + std::mem::size_of::<CxlRef<Node>>());
        alloc.link_reference(root, left_slot, leaf_l).unwrap();
        alloc.link_reference(root, right_slot, leaf_r).unwrap();

        let r = alloc.read(root).unwrap();
        assert_eq!(alloc.read(r.left).unwrap().value, 1);
        assert_eq!(alloc.read(r.right).unwrap().value, 2);
        let charged = alloc.heap().pool().charger.total_charged_ns() - charged_before;
        // 3 objects + 2 links, each with the per-object charge.
        assert!(charged >= 5 * crate::config::CostModel::default().zhang_obj_ns);
    }
}
