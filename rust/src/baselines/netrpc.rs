//! Network-based baseline RPC frameworks (eRPC, gRPC, ThriftRPC,
//! plain TCP, UNIX-domain-socket RPC).
//!
//! One generic request/response engine over a `SimNic`, specialized by
//! a `Flavor`: the link model plus the framework's per-direction stack
//! cost (calibrated to Table 1a). Every call serializes its request
//! and deserializes the response — the overhead RPCool exists to
//! avoid.

use crate::baselines::wire::{charge_serialize, Wire};
use crate::error::{Result, RpcError};
use crate::memory::pool::Charger;
use crate::transport::{LinkKind, SimNicPair, Transport};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

/// A baseline framework's identity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flavor {
    ERpc,
    Grpc,
    Thrift,
    Tcp,
    Uds,
}

impl Flavor {
    pub fn link(&self) -> LinkKind {
        match self {
            Flavor::ERpc => LinkKind::Rdma,
            Flavor::Grpc => LinkKind::Http2,
            Flavor::Thrift | Flavor::Tcp => LinkKind::Tcp,
            Flavor::Uds => LinkKind::Uds,
        }
    }

    /// Per-direction stack cost beyond the wire itself.
    pub fn stack_ns(&self, charger: &Charger) -> u64 {
        let c = &charger.cost;
        match self {
            Flavor::ERpc => c.erpc_stack_ns,
            Flavor::Grpc => c.grpc_stack_ns,
            Flavor::Thrift => c.thrift_stack_ns,
            Flavor::Tcp | Flavor::Uds => 0,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Flavor::ERpc => "eRPC",
            Flavor::Grpc => "gRPC",
            Flavor::Thrift => "ThriftRPC",
            Flavor::Tcp => "TCP-RPC",
            Flavor::Uds => "UDS-RPC",
        }
    }
}

/// Message framing: [seq u64][func u32][payload...].
fn frame(seq: u64, func: u32, payload: &[u8]) -> Vec<u8> {
    let mut m = Vec::with_capacity(12 + payload.len());
    m.extend_from_slice(&seq.to_le_bytes());
    m.extend_from_slice(&func.to_le_bytes());
    m.extend_from_slice(payload);
    m
}

fn unframe(m: &[u8]) -> Result<(u64, u32, &[u8])> {
    if m.len() < 12 {
        return Err(RpcError::Serialization("short frame".into()));
    }
    let seq = u64::from_le_bytes(m[0..8].try_into().unwrap());
    let func = u32::from_le_bytes(m[8..12].try_into().unwrap());
    Ok((seq, func, &m[12..]))
}

pub type NetHandler = Box<dyn Fn(&[u8]) -> Result<Vec<u8>> + Send + Sync>;

/// Server half: owns one end of the link, serves until stopped.
pub struct NetRpcServer {
    flavor: Flavor,
    nic: Arc<crate::transport::SimNic>,
    handlers: Arc<RwLock<HashMap<u32, NetHandler>>>,
    stop: Arc<AtomicBool>,
    charger: Arc<Charger>,
    served: Arc<AtomicU64>,
}

impl NetRpcServer {
    pub fn add(&self, func: u32, f: impl Fn(&[u8]) -> Result<Vec<u8>> + Send + Sync + 'static) {
        self.handlers.write().unwrap().insert(func, Box::new(f));
    }

    /// Typed handler registration — the serialized baselines' mirror
    /// of `RpcServer::serve`: decode the request as `A`, encode the
    /// reply from `R` (paying the real encode/decode work the channel
    /// surface avoids).
    pub fn serve<A: Wire, R: Wire>(
        &self,
        func: u32,
        f: impl Fn(A) -> Result<R> + Send + Sync + 'static,
    ) {
        self.add(func, move |req| Ok(f(A::from_bytes(req)?)?.to_bytes()));
    }

    pub fn spawn_listener(&self) -> std::thread::JoinHandle<()> {
        let nic = Arc::clone(&self.nic);
        let handlers = Arc::clone(&self.handlers);
        let stop = Arc::clone(&self.stop);
        let charger = Arc::clone(&self.charger);
        let served = Arc::clone(&self.served);
        let flavor = self.flavor;
        std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                let Ok(msg) = nic.recv(Duration::from_millis(20)) else { continue };
                let Ok((seq, func, payload)) = unframe(&msg) else { continue };
                // Receive-side stack + deserialize charge.
                charger.charge_ns(flavor.stack_ns(&charger));
                charge_serialize(&charger, payload.len(), 1);
                let reply = {
                    let h = handlers.read().unwrap();
                    match h.get(&func) {
                        Some(f) => match f(payload) {
                            Ok(bytes) => {
                                let mut r = vec![0u8];
                                r.extend_from_slice(&bytes);
                                r
                            }
                            Err(e) => {
                                let mut r = vec![1u8];
                                r.extend_from_slice(e.to_string().as_bytes());
                                r
                            }
                        },
                        None => vec![2u8],
                    }
                };
                served.fetch_add(1, Ordering::Relaxed);
                // Send-side stack + serialize charge.
                charger.charge_ns(flavor.stack_ns(&charger));
                charge_serialize(&charger, reply.len(), 1);
                let _ = nic.send(&frame(seq, func, &reply));
            }
        })
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::Release);
    }

    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }
}

/// Client half.
pub struct NetRpcClient {
    flavor: Flavor,
    nic: Arc<crate::transport::SimNic>,
    charger: Arc<Charger>,
    seq: AtomicU64,
    pub timeout: Duration,
    /// Inline serving (sequential-RTT model on a 1-core simulation
    /// host, mirroring `Connection::attach_inline`): the caller thread
    /// runs the handler, charging both directions' wire+stack costs.
    inline: std::sync::Mutex<Option<(Arc<RwLock<HashMap<u32, NetHandler>>>, Arc<AtomicU64>)>>,
}

impl NetRpcClient {
    /// Switch to inline serving against `server`'s handler table.
    pub fn attach_inline(&self, server: &NetRpcServer) {
        *self.inline.lock().unwrap() =
            Some((Arc::clone(&server.handlers), Arc::clone(&server.served)));
    }

    fn call_inline(
        &self,
        func: u32,
        payload: &[u8],
        handlers: &RwLock<HashMap<u32, NetHandler>>,
        served: &AtomicU64,
    ) -> Result<Vec<u8>> {
        let link = self.flavor.link();
        let stack = self.flavor.stack_ns(&self.charger);
        // Client send: stack + serialize + wire.
        self.charger.charge_ns(stack);
        charge_serialize(&self.charger, payload.len(), 1);
        self.charger.charge_ns(link.oneway_ns(&self.charger.cost, payload.len() + 12));
        // Server: recv stack + deserialize, handler, send stack + serialize.
        self.charger.charge_ns(stack);
        charge_serialize(&self.charger, payload.len(), 1);
        let reply = {
            let h = handlers.read().unwrap();
            match h.get(&func) {
                Some(f) => f(payload).map_err(|e| RpcError::Remote(e.to_string())),
                None => Err(RpcError::NoSuchHandler(func)),
            }
        };
        served.fetch_add(1, Ordering::Relaxed);
        let reply = reply?;
        self.charger.charge_ns(stack);
        charge_serialize(&self.charger, reply.len(), 1);
        // Response wire + client recv stack + deserialize.
        self.charger.charge_ns(link.oneway_ns(&self.charger.cost, reply.len() + 12));
        self.charger.charge_ns(stack);
        charge_serialize(&self.charger, reply.len(), 1);
        Ok(reply)
    }
    /// Serialize-request → wire → deserialize-response (the whole
    /// layer cake RPCool skips).
    pub fn call(&self, func: u32, payload: &[u8]) -> Result<Vec<u8>> {
        if let Some((handlers, served)) = self.inline.lock().unwrap().as_ref() {
            let (h, s) = (Arc::clone(handlers), Arc::clone(served));
            return self.call_inline(func, payload, &h, &s);
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        // Client send-side stack + serialize.
        self.charger.charge_ns(self.flavor.stack_ns(&self.charger));
        charge_serialize(&self.charger, payload.len(), 1);
        self.nic.send(&frame(seq, func, payload))?;
        loop {
            let msg = self.nic.recv(self.timeout)?;
            let (rseq, _func, body) = unframe(&msg)?;
            if rseq != seq {
                continue; // stale response from a timed-out call
            }
            // Client receive-side stack + deserialize.
            self.charger.charge_ns(self.flavor.stack_ns(&self.charger));
            charge_serialize(&self.charger, body.len(), 1);
            return match body.first() {
                Some(0) => Ok(body[1..].to_vec()),
                Some(1) => Err(RpcError::Remote(
                    String::from_utf8_lossy(&body[1..]).to_string(),
                )),
                Some(2) => Err(RpcError::NoSuchHandler(func)),
                _ => Err(RpcError::Serialization("bad reply".into())),
            };
        }
    }

    /// Typed call — mirror of `Connection::call_typed` for the
    /// serialize/deserialize world: encode `A`, call, decode `R`.
    pub fn call_typed<A: Wire, R: Wire>(&self, func: u32, arg: &A) -> Result<R> {
        R::from_bytes(&self.call(func, &arg.to_bytes())?)
    }

    pub fn flavor(&self) -> Flavor {
        self.flavor
    }
}

/// Build a connected client/server pair of the given flavor.
pub fn pair(flavor: Flavor, charger: Arc<Charger>) -> (NetRpcServer, NetRpcClient) {
    let nics = SimNicPair::new(flavor.link(), Arc::clone(&charger));
    let server = NetRpcServer {
        flavor,
        nic: nics.b,
        handlers: Arc::new(RwLock::new(HashMap::new())),
        stop: Arc::new(AtomicBool::new(false)),
        charger: Arc::clone(&charger),
        served: Arc::new(AtomicU64::new(0)),
    };
    let client = NetRpcClient {
        flavor,
        nic: nics.a,
        charger,
        seq: AtomicU64::new(1),
        timeout: Duration::from_secs(10),
        inline: std::sync::Mutex::new(None),
    };
    (server, client)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::wire::Wire;
    use crate::config::{ChargePolicy, CostModel};

    fn charger() -> Arc<Charger> {
        Arc::new(Charger::new(CostModel::default(), ChargePolicy::Skip))
    }

    #[test]
    fn echo_roundtrip_all_flavors() {
        for flavor in [Flavor::ERpc, Flavor::Grpc, Flavor::Thrift, Flavor::Tcp, Flavor::Uds] {
            let (server, client) = pair(flavor, charger());
            server.add(1, |req| Ok(req.to_vec()));
            let t = server.spawn_listener();
            let out = client.call(1, b"payload").unwrap();
            assert_eq!(out, b"payload", "{}", flavor.name());
            server.stop();
            t.join().unwrap();
        }
    }

    #[test]
    fn typed_payloads_serialize() {
        let (server, client) = pair(Flavor::ERpc, charger());
        server.add(2, |req| {
            let v: Vec<u64> = Wire::from_bytes(req)?;
            let sum: u64 = v.iter().sum();
            Ok(sum.to_bytes())
        });
        let t = server.spawn_listener();
        let v: Vec<u64> = (1..=100).collect();
        let out = client.call(2, &v.to_bytes()).unwrap();
        let sum: u64 = Wire::from_bytes(&out).unwrap();
        assert_eq!(sum, 5050);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn typed_surface_mirrors_channel_api() {
        // serve::<A, R> / call_typed::<A, R> — same ergonomics as the
        // shared-memory surface, with real serialization underneath.
        let (server, client) = pair(Flavor::Grpc, charger());
        server.serve::<Vec<u64>, u64>(4, |v| Ok(v.iter().sum()));
        let t = server.spawn_listener();
        let v: Vec<u64> = (1..=10).collect();
        let sum: u64 = client.call_typed(4, &v).unwrap();
        assert_eq!(sum, 55);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn handler_error_propagates() {
        let (server, client) = pair(Flavor::Tcp, charger());
        server.add(3, |_req| Err(RpcError::Remote("boom".into())));
        let t = server.spawn_listener();
        let e = client.call(3, b"").unwrap_err();
        assert!(matches!(e, RpcError::Remote(_)));
        let e2 = client.call(99, b"").unwrap_err();
        assert!(matches!(e2, RpcError::NoSuchHandler(99)));
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn charged_costs_reflect_stack_ladder() {
        // eRPC (RDMA) must charge less than gRPC (HTTP2 + big stack).
        let run = |flavor: Flavor| {
            let ch = charger();
            let (server, client) = pair(flavor, Arc::clone(&ch));
            server.add(1, |r| Ok(r.to_vec()));
            let t = server.spawn_listener();
            let before = ch.total_charged_ns();
            for _ in 0..10 {
                client.call(1, b"x").unwrap();
            }
            let cost = ch.total_charged_ns() - before;
            server.stop();
            t.join().unwrap();
            cost
        };
        let erpc = run(Flavor::ERpc);
        let grpc = run(Flavor::Grpc);
        let uds = run(Flavor::Uds);
        assert!(erpc < uds, "eRPC {erpc} < UDS {uds}");
        assert!(uds < grpc, "UDS {uds} < gRPC {grpc}");
    }
}
