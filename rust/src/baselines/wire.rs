//! Wire serialization for the baseline frameworks.
//!
//! The paper's core claim is that serialization dominates RPC cost for
//! pointer-rich data. The baselines therefore *actually serialize*: a
//! varint-based tag-length-value encoding in the protobuf/Thrift
//! compact family. Encoding cost is twofold: the real CPU work of the
//! encoder below, plus the calibrated per-byte/per-object charge of
//! the heavier production encoders it stands in for.

use crate::error::{Result, RpcError};
use crate::memory::pool::Charger;

/// Encode/decode buffer (LEB128 varints, little-endian fixed ints).
#[derive(Default)]
pub struct WireBuf {
    pub bytes: Vec<u8>,
}

impl WireBuf {
    pub fn new() -> Self {
        WireBuf { bytes: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        WireBuf { bytes: Vec::with_capacity(n) }
    }

    pub fn put_varint(&mut self, mut v: u64) {
        loop {
            let b = (v & 0x7F) as u8;
            v >>= 7;
            if v == 0 {
                self.bytes.push(b);
                return;
            }
            self.bytes.push(b | 0x80);
        }
    }

    pub fn put_u32(&mut self, v: u32) {
        self.bytes.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.bytes.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.bytes.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_varint(b.len() as u64);
        self.bytes.extend_from_slice(b);
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_bytes(s.as_bytes());
    }
}

/// Cursor over received bytes.
pub struct WireCur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireCur<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        WireCur { buf, pos: 0 }
    }

    fn need(&self, n: usize) -> Result<()> {
        if self.pos + n > self.buf.len() {
            return Err(RpcError::Serialization(format!(
                "short read at {} (+{n} > {})",
                self.pos,
                self.buf.len()
            )));
        }
        Ok(())
    }

    pub fn varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0;
        loop {
            self.need(1)?;
            let b = self.buf[self.pos];
            self.pos += 1;
            v |= ((b & 0x7F) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift >= 64 {
                return Err(RpcError::Serialization("varint overflow".into()));
            }
        }
    }

    pub fn u32(&mut self) -> Result<u32> {
        self.need(4)?;
        let v = u32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        Ok(v)
    }

    pub fn u64(&mut self) -> Result<u64> {
        self.need(8)?;
        let v = u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        Ok(v)
    }

    pub fn f64(&mut self) -> Result<f64> {
        self.need(8)?;
        let v = f64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        Ok(v)
    }

    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.varint()? as usize;
        self.need(n)?;
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn str(&mut self) -> Result<&'a str> {
        std::str::from_utf8(self.bytes()?)
            .map_err(|e| RpcError::Serialization(e.to_string()))
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Types the baselines can put on the wire.
pub trait Wire: Sized {
    fn encode(&self, out: &mut WireBuf);
    fn decode(cur: &mut WireCur) -> Result<Self>;

    fn to_bytes(&self) -> Vec<u8> {
        let mut b = WireBuf::new();
        self.encode(&mut b);
        b.bytes
    }

    fn from_bytes(buf: &[u8]) -> Result<Self> {
        Self::decode(&mut WireCur::new(buf))
    }
}

impl Wire for u64 {
    fn encode(&self, out: &mut WireBuf) {
        out.put_varint(*self);
    }
    fn decode(cur: &mut WireCur) -> Result<Self> {
        cur.varint()
    }
}

impl Wire for String {
    fn encode(&self, out: &mut WireBuf) {
        out.put_str(self);
    }
    fn decode(cur: &mut WireCur) -> Result<Self> {
        Ok(cur.str()?.to_string())
    }
}

impl Wire for Vec<u8> {
    fn encode(&self, out: &mut WireBuf) {
        out.put_bytes(self);
    }
    fn decode(cur: &mut WireCur) -> Result<Self> {
        Ok(cur.bytes()?.to_vec())
    }
}

impl<T: Wire> Wire for Vec<T> {
    fn encode(&self, out: &mut WireBuf) {
        out.put_varint(self.len() as u64);
        for x in self {
            x.encode(out);
        }
    }
    fn decode(cur: &mut WireCur) -> Result<Self> {
        let n = cur.varint()? as usize;
        let mut v = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            v.push(T::decode(cur)?);
        }
        Ok(v)
    }
}

impl<A: Wire, B: Wire> Wire for (A, B) {
    fn encode(&self, out: &mut WireBuf) {
        self.0.encode(out);
        self.1.encode(out);
    }
    fn decode(cur: &mut WireCur) -> Result<Self> {
        Ok((A::decode(cur)?, B::decode(cur)?))
    }
}

/// Charge the calibrated serializer cost for a message of `bytes`
/// containing ~`objs` objects (what a protobuf-class encoder costs on
/// the paper's testbed, on top of the real work done here).
pub fn charge_serialize(charger: &Charger, bytes: usize, objs: usize) {
    let c = &charger.cost;
    charger.charge_ns(
        (bytes as u64 * c.serialize_per_byte_ns_x100) / 100 + objs as u64 * c.serialize_per_obj_ns,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrip_extremes() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u64::MAX] {
            let mut b = WireBuf::new();
            b.put_varint(v);
            assert_eq!(WireCur::new(&b.bytes).varint().unwrap(), v);
        }
    }

    #[test]
    fn composite_roundtrip() {
        let val: Vec<(u64, String)> =
            vec![(1, "one".into()), (2, "two".into()), (99, "ninety-nine".into())];
        let bytes = val.to_bytes();
        let back: Vec<(u64, String)> = Wire::from_bytes(&bytes).unwrap();
        assert_eq!(val, back);
    }

    #[test]
    fn short_read_detected() {
        let mut b = WireBuf::new();
        b.put_str("hello");
        let r: Result<String> = Wire::from_bytes(&b.bytes[..3]);
        assert!(r.is_err());
    }

    #[test]
    fn varint_overflow_detected() {
        let bad = [0xFFu8; 11];
        assert!(WireCur::new(&bad).varint().is_err());
    }
}
