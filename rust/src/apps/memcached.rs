//! Memcached (paper §6.3, Figure 9): a slab-style KV cache whose
//! network front-end is swapped between RPCool shared memory and
//! socket transports (UDS for local, TCP/IPoIB for remote).
//!
//! Faithful to the paper's integration notes: memcached moves small,
//! non-pointer-rich values, so the RPCool version uses `memcpy()` in
//! and out of the connection heap instead of sealing+sandboxing
//! (§6.2's crossover analysis: below ~2 pages, copying wins). No SCAN
//! operation exists, so YCSB-E is skipped (Fig. 9 note).

use crate::baselines::netrpc::{self, Flavor, NetRpcClient, NetRpcServer};
use crate::baselines::wire::{WireBuf, WireCur};
use crate::channel::{CallArg, CallOpts, ChannelBuilder, Connection, Reply, RpcServer};
use crate::error::{Result, RpcError};
use crate::memory::containers::{ShmString, ShmVec};
use crate::memory::pod::Pod;
use crate::memory::pool::Charger;
use crate::rack::ProcEnv;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

pub const F_SET: u32 = 1;
pub const F_GET: u32 = 2;
pub const F_DEL: u32 = 3;

/// The cache itself (host memory, hash table + LRU-less slab model).
pub struct Cache {
    shards: Vec<RwLock<HashMap<String, Vec<u8>>>>,
}

impl Cache {
    pub fn new(nshards: usize) -> Arc<Cache> {
        Arc::new(Cache {
            shards: (0..nshards.next_power_of_two()).map(|_| RwLock::new(HashMap::new())).collect(),
        })
    }

    #[inline]
    fn shard(&self, key: &str) -> &RwLock<HashMap<String, Vec<u8>>> {
        let mut h = 0xcbf29ce484222325u64;
        for b in key.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        &self.shards[(h as usize) & (self.shards.len() - 1)]
    }

    pub fn set(&self, key: &str, val: Vec<u8>) {
        self.shard(key).write().unwrap().insert(key.to_string(), val);
    }

    pub fn get(&self, key: &str) -> Option<Vec<u8>> {
        self.shard(key).read().unwrap().get(key).cloned()
    }

    pub fn delete(&self, key: &str) -> bool {
        self.shard(key).write().unwrap().remove(key).is_some()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Client interface every transport implements (the YCSB driver and
/// the benches are generic over this).
pub trait KvClient: Send + Sync {
    fn set(&self, key: &str, val: &[u8]) -> Result<()>;
    fn get(&self, key: &str) -> Result<Option<Vec<u8>>>;
    fn delete(&self, key: &str) -> Result<bool>;
    fn transport_name(&self) -> &'static str;

    /// Bulk SET. The default loops one RPC per pair; transports with
    /// an amortized submission path (RPCool's `invoke_batch`) override
    /// it to pipeline the whole slice per doorbell.
    fn set_many(&self, pairs: &[(String, Vec<u8>)]) -> Result<()> {
        for (k, v) in pairs {
            self.set(k, v)?;
        }
        Ok(())
    }

    /// Bulk GET, results in key order (`None` = miss). The default
    /// loops one blocking RPC per key; transports with pipelined
    /// replies (RPCool's `call_typed_async`) override it so a window
    /// of GETs is in flight before the first reply is awaited.
    fn get_many(&self, keys: &[String]) -> Result<Vec<Option<Vec<u8>>>> {
        keys.iter().map(|k| self.get(k)).collect()
    }
}

// ------------------------------------------------------------- RPCool

/// SET argument in shared memory: key + value, memcpy'd by the server.
#[derive(Clone, Copy)]
pub struct KvPair {
    pub key: ShmString,
    pub val: ShmVec<u8>,
}

unsafe impl Pod for KvPair {}

/// Spin up a memcached server behind an RPCool channel.
pub fn serve_rpcool(env: &ProcEnv, name: &str, cache: Arc<Cache>) -> Result<RpcServer> {
    let server = ChannelBuilder::for_env(env).open(env, name)?;
    let charger: Arc<Charger> = Arc::clone(&env.rack.pool.charger);

    let c = Arc::clone(&cache);
    let ch = Arc::clone(&charger);
    server.serve_scalar::<KvPair>(F_SET, move |_ctx, pair| {
        // memcpy out of shared memory (charged as CXL bulk reads).
        let key = pair.key.to_string()?;
        let val = pair.val.to_vec()?;
        ch.charge_cxl_copy(key.len() + val.len());
        c.set(&key, val);
        Ok(0)
    });

    let c = Arc::clone(&cache);
    let ch = Arc::clone(&charger);
    server.serve_opt::<ShmString, ShmVec<u8>>(F_GET, move |ctx, key| {
        let key = key.to_string()?;
        match c.get(&key) {
            Some(val) => {
                // memcpy the value into the connection heap for the
                // client to read (reply buffer).
                ch.charge_cxl_copy(val.len());
                let mut out: ShmVec<u8> = ShmVec::with_capacity(ctx.heap, val.len())?;
                out.extend_from_slice(ctx.heap, &val)?;
                Ok(Some(out))
            }
            None => Ok(None),
        }
    });

    let c = Arc::clone(&cache);
    server.serve_scalar::<ShmString>(F_DEL, move |_ctx, key| {
        Ok(c.delete(&key.to_string()?) as u64)
    });

    Ok(server)
}

/// RPCool-backed client. Reuses a scratch scope per call (memcpy
/// discipline — no seal, no sandbox, exactly as the paper's
/// integration does).
pub struct RpcoolKv {
    conn: Connection,
    scratch: Mutex<crate::memory::scope::Scope>,
}

impl RpcoolKv {
    pub fn connect(env: &ProcEnv, name: &str) -> Result<RpcoolKv> {
        Self::from_conn(Connection::connect(env, name)?)
    }

    /// Wrap an existing connection (e.g. one opened over the RDMA
    /// fallback with `connect_with`).
    pub fn from_conn(conn: Connection) -> Result<RpcoolKv> {
        let scratch = Mutex::new(conn.create_scope(64 * 1024)?);
        Ok(RpcoolKv { conn, scratch })
    }

    pub fn conn(&self) -> &Connection {
        &self.conn
    }
}

impl KvClient for RpcoolKv {
    fn set(&self, key: &str, val: &[u8]) -> Result<()> {
        let scope = self.scratch.lock().unwrap();
        scope.reset();
        let k = ShmString::from_str(&*scope, key)?;
        let mut v: ShmVec<u8> = ShmVec::with_capacity(&*scope, val.len())?;
        v.extend_from_slice(&*scope, val)?;
        let arg = scope.new_val(KvPair { key: k, val: v })?;
        self.conn.invoke(F_SET, (arg, std::mem::size_of::<KvPair>()), CallOpts::new())?;
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Option<Vec<u8>>> {
        let scope = self.scratch.lock().unwrap();
        scope.reset();
        let k = ShmString::from_str(&*scope, key)?;
        let arg = scope.new_val(k)?;
        let ret =
            self.conn.invoke(F_GET, (arg, std::mem::size_of::<ShmString>()), CallOpts::new())?;
        let reply: Reply<ShmVec<u8>> = self.conn.reply_from(ret);
        let Some(out) = reply.opt()? else {
            return Ok(None);
        };
        let bytes = out.to_vec()?;
        // Server-allocated reply buffer: free it after copying out.
        let mut out = out;
        out.destroy(self.conn.heap().as_ref());
        reply.free();
        Ok(Some(bytes))
    }

    fn delete(&self, key: &str) -> Result<bool> {
        let scope = self.scratch.lock().unwrap();
        scope.reset();
        let k = ShmString::from_str(&*scope, key)?;
        let arg = scope.new_val(k)?;
        Ok(self.conn.invoke(F_DEL, (arg, std::mem::size_of::<ShmString>()), CallOpts::new())?
            == 1)
    }

    fn transport_name(&self) -> &'static str {
        if self.conn.shared.is_dsm() {
            "RPCool(DSM)"
        } else {
            "RPCool"
        }
    }

    /// Batched SET: stage a chunk of pairs in the scratch scope, then
    /// submit the whole chunk with one doorbell via `invoke_batch`
    /// (the paper's memcpy discipline, amortized). Chunked so the
    /// scratch scope bounds staging memory; the scope resets only
    /// after the previous chunk's batch fully completed (the server
    /// has already memcpy'd every staged pair out).
    fn set_many(&self, pairs: &[(String, Vec<u8>)]) -> Result<()> {
        const CHUNK: usize = 16;
        let scope = self.scratch.lock().unwrap();
        for chunk in pairs.chunks(CHUNK) {
            scope.reset();
            let mut args = Vec::with_capacity(chunk.len());
            for (key, val) in chunk {
                let k = ShmString::from_str(&*scope, key)?;
                let mut v: ShmVec<u8> = ShmVec::with_capacity(&*scope, val.len())?;
                v.extend_from_slice(&*scope, val)?;
                let arg = scope.new_val(KvPair { key: k, val: v })?;
                args.push(CallArg::new(arg, std::mem::size_of::<KvPair>()));
            }
            self.conn.invoke_batch(F_SET, &args, CallOpts::new())?;
        }
        Ok(())
    }

    /// Pipelined GET (the ROADMAP "batched/pipelined reads" item):
    /// stage a window of keys in the scratch scope, issue every GET
    /// through `call_typed_async` *before* the first wait, then
    /// resolve the typed replies in order — the server's drain-k loop
    /// answers the whole window with coalesced reply doorbells, so a
    /// read-heavy phase stops paying one blocking round trip per key.
    /// Windowed so the scratch scope bounds staging memory; the scope
    /// resets only after the previous window fully completed (every
    /// reply consumed ⇒ the server is done reading the staged keys).
    fn get_many(&self, keys: &[String]) -> Result<Vec<Option<Vec<u8>>>> {
        const WINDOW: usize = 16;
        let scope = self.scratch.lock().unwrap();
        let mut out = Vec::with_capacity(keys.len());
        for window in keys.chunks(WINDOW) {
            scope.reset();
            let mut handles = Vec::with_capacity(window.len());
            for key in window {
                let k = ShmString::from_str(&*scope, key)?;
                handles.push(self.conn.call_typed_async::<ShmString, ShmVec<u8>>(
                    F_GET,
                    &k,
                    CallOpts::new(),
                )?);
            }
            for h in handles {
                let reply = h.wait()?;
                match reply.opt()? {
                    Some(val) => {
                        let bytes = val.to_vec()?;
                        // Server-allocated reply buffer: free it after
                        // copying out, exactly as `get` does.
                        let mut val = val;
                        val.destroy(self.conn.heap().as_ref());
                        reply.free();
                        out.push(Some(bytes));
                    }
                    None => {
                        reply.free();
                        out.push(None);
                    }
                }
            }
        }
        Ok(out)
    }
}

// ------------------------------------------------------- socket flavors

/// Memcached over a socket transport (UDS or TCP): the classic
/// serialize-send-deserialize path.
pub fn serve_net(flavor: Flavor, charger: Arc<Charger>, cache: Arc<Cache>) -> (NetRpcServer, NetKv) {
    let (server, client) = netrpc::pair(flavor, charger);
    let c = Arc::clone(&cache);
    server.add(F_SET, move |req| {
        let mut cur = WireCur::new(req);
        let key = cur.str()?.to_string();
        let val = cur.bytes()?.to_vec();
        c.set(&key, val);
        Ok(vec![])
    });
    let c = Arc::clone(&cache);
    server.add(F_GET, move |req| {
        let mut cur = WireCur::new(req);
        let key = cur.str()?;
        match c.get(key) {
            Some(v) => {
                let mut out = WireBuf::new();
                out.put_varint(1);
                out.put_bytes(&v);
                Ok(out.bytes)
            }
            None => {
                let mut out = WireBuf::new();
                out.put_varint(0);
                Ok(out.bytes)
            }
        }
    });
    let c = Arc::clone(&cache);
    server.add(F_DEL, move |req| {
        let mut cur = WireCur::new(req);
        let key = cur.str()?;
        Ok(vec![c.delete(key) as u8])
    });
    (server, NetKv { client })
}

pub struct NetKv {
    client: NetRpcClient,
}

impl NetKv {
    /// Sequential-RTT model (mirrors `Connection::attach_inline`).
    pub fn client_inline(&self, server: &NetRpcServer) {
        self.client.attach_inline(server);
    }
}

impl KvClient for NetKv {
    fn set(&self, key: &str, val: &[u8]) -> Result<()> {
        let mut b = WireBuf::new();
        b.put_str(key);
        b.put_bytes(val);
        self.client.call(F_SET, &b.bytes)?;
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Option<Vec<u8>>> {
        let mut b = WireBuf::new();
        b.put_str(key);
        let reply = self.client.call(F_GET, &b.bytes)?;
        let mut cur = WireCur::new(&reply);
        match cur.varint()? {
            0 => Ok(None),
            1 => Ok(Some(cur.bytes()?.to_vec())),
            t => Err(RpcError::Serialization(format!("bad GET reply {t}"))),
        }
    }

    fn delete(&self, key: &str) -> Result<bool> {
        let mut b = WireBuf::new();
        b.put_str(key);
        Ok(self.client.call(F_DEL, &b.bytes)?.first() == Some(&1))
    }

    fn transport_name(&self) -> &'static str {
        match self.client.flavor() {
            Flavor::Uds => "UDS",
            Flavor::Tcp => "TCP(IPoIB)",
            other => other.name(),
        }
    }
}

// ---------------------------------------------------------- YCSB driver

use crate::workloads::ycsb::{Op, WorkloadKind, Ycsb};

/// Load + run one YCSB workload; returns (load, run) wall times.
pub fn run_ycsb(
    client: &dyn KvClient,
    kind: WorkloadKind,
    nkeys: u64,
    nops: usize,
    seed: u64,
) -> Result<(std::time::Duration, std::time::Duration)> {
    assert!(!kind.has_scan(), "memcached cannot run YCSB-E (no SCAN)");
    let mut w = Ycsb::new(kind, nkeys, seed);
    let t0 = std::time::Instant::now();
    // Bulk load rides the batched path (one doorbell per chunk on
    // RPCool; plain loop on socket transports).
    let mut batch: Vec<(String, Vec<u8>)> = Vec::with_capacity(64);
    for id in 0..nkeys {
        batch.push((Ycsb::key_name(id), w.value_for(100)));
        if batch.len() == 64 {
            client.set_many(&batch)?;
            batch.clear();
        }
    }
    if !batch.is_empty() {
        client.set_many(&batch)?;
    }
    let load = t0.elapsed();
    let t1 = std::time::Instant::now();
    // The read phase rides the pipelined path: consecutive READs
    // accumulate and flush through `get_many` (one in-flight window
    // instead of one blocking round trip per key). Any write flushes
    // the pending reads first, so the observable read/write order is
    // exactly the sequential schedule's.
    const READ_WINDOW: usize = 16;
    let mut reads: Vec<String> = Vec::with_capacity(READ_WINDOW);
    for _ in 0..nops {
        let spec = w.next_op();
        let key = Ycsb::key_name(spec.key);
        match spec.op {
            Op::Read => {
                reads.push(key);
                if reads.len() == READ_WINDOW {
                    client.get_many(&reads)?;
                    reads.clear();
                }
            }
            Op::Update | Op::Insert => {
                if !reads.is_empty() {
                    client.get_many(&reads)?;
                    reads.clear();
                }
                let v = w.value_for(100);
                client.set(&key, &v)?;
            }
            Op::ReadModifyWrite => {
                if !reads.is_empty() {
                    client.get_many(&reads)?;
                    reads.clear();
                }
                let mut v = client.get(&key)?.unwrap_or_default();
                if v.is_empty() {
                    v = w.value_for(100);
                }
                v[0] = v[0].wrapping_add(1);
                client.set(&key, &v)?;
            }
            Op::Scan { .. } => unreachable!(),
        }
    }
    if !reads.is_empty() {
        client.get_many(&reads)?;
    }
    Ok((load, t1.elapsed()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChargePolicy, CostModel, SimConfig};
    use crate::rack::Rack;

    #[test]
    fn cache_basics() {
        let c = Cache::new(8);
        c.set("a", vec![1, 2, 3]);
        assert_eq!(c.get("a"), Some(vec![1, 2, 3]));
        assert!(c.delete("a"));
        assert!(!c.delete("a"));
        assert_eq!(c.get("a"), None);
    }

    #[test]
    fn rpcool_kv_end_to_end() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let cache = Cache::new(8);
        let server = serve_rpcool(&env, "memcached", Arc::clone(&cache)).unwrap();
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let kv = RpcoolKv::connect(&cenv, "memcached").unwrap();
        cenv.run(|| {
            kv.set("hello", b"world").unwrap();
            assert_eq!(kv.get("hello").unwrap(), Some(b"world".to_vec()));
            assert_eq!(kv.get("nope").unwrap(), None);
            assert!(kv.delete("hello").unwrap());
            assert_eq!(kv.get("hello").unwrap(), None);
        });
        assert_eq!(cache.len(), 0);
        drop(kv);
        server.stop();
        t.join().unwrap();
    }

    /// The batched path end to end, on a sharded channel with two
    /// listener workers: one doorbell per chunk, every pair readable
    /// afterwards, and the socket transports' default loop agrees.
    #[test]
    fn set_many_batches_through_sharded_channel() {
        let mut cfg = SimConfig::for_tests();
        cfg.ring_shards = 2;
        let rack = Rack::new(cfg);
        let env = rack.proc_env(0);
        let cache = Cache::new(8);
        let server = serve_rpcool(&env, "mc-batch", Arc::clone(&cache)).unwrap();
        let listeners = server.spawn_listeners(2);
        let cenv = rack.proc_env(1);
        let kv = RpcoolKv::connect(&cenv, "mc-batch").unwrap();
        assert_eq!(kv.conn().shared.shard_count(), 2);
        cenv.run(|| {
            // 40 pairs → three chunks of ≤16 through invoke_batch.
            let pairs: Vec<(String, Vec<u8>)> = (0..40)
                .map(|i| (format!("bk{i}"), format!("bv{i}").into_bytes()))
                .collect();
            kv.set_many(&pairs).unwrap();
            for (k, v) in &pairs {
                assert_eq!(kv.get(k).unwrap().as_ref(), Some(v), "key {k}");
            }
        });
        assert_eq!(cache.len(), 40);
        assert!(kv.conn().shared.quiescent());
        drop(kv);
        server.stop();
        for l in listeners {
            l.join().unwrap();
        }
    }

    /// The pipelined read path end to end, on a sharded channel with
    /// two listener workers: hits and misses come back in key order,
    /// the window boundary (17 keys > one window of 16) is exercised,
    /// and the connection is fully recycled afterwards. The socket
    /// transports' default per-key loop must agree on semantics.
    #[test]
    fn get_many_pipelines_reads_in_order() {
        let mut cfg = SimConfig::for_tests();
        cfg.ring_shards = 2;
        let rack = Rack::new(cfg);
        let env = rack.proc_env(0);
        let cache = Cache::new(8);
        let server = serve_rpcool(&env, "mc-getmany", Arc::clone(&cache)).unwrap();
        let listeners = server.spawn_listeners(2);
        let cenv = rack.proc_env(1);
        let kv = RpcoolKv::connect(&cenv, "mc-getmany").unwrap();
        cenv.run(|| {
            for i in 0..12 {
                kv.set(&format!("gk{i}"), format!("gv{i}").as_bytes()).unwrap();
            }
            // 17 keys: every third one a miss; spans two windows.
            let keys: Vec<String> = (0..17).map(|i| format!("gk{i}")).collect();
            let got = kv.get_many(&keys).unwrap();
            assert_eq!(got.len(), 17);
            for (i, v) in got.iter().enumerate() {
                if i < 12 {
                    assert_eq!(v.as_deref(), Some(format!("gv{i}").as_bytes()), "key gk{i}");
                } else {
                    assert_eq!(v.as_deref(), None, "gk{i} must miss");
                }
            }
        });
        assert!(kv.conn().shared.quiescent(), "pipelined window fully drained");
        drop(kv);
        server.stop();
        for l in listeners {
            l.join().unwrap();
        }
    }

    #[test]
    fn net_kv_end_to_end() {
        let charger = Arc::new(crate::memory::pool::Charger::new(
            CostModel::default(),
            ChargePolicy::Skip,
        ));
        let cache = Cache::new(8);
        let (server, kv) = serve_net(Flavor::Uds, charger, Arc::clone(&cache));
        let t = server.spawn_listener();
        kv.set("k1", b"v1").unwrap();
        assert_eq!(kv.get("k1").unwrap(), Some(b"v1".to_vec()));
        assert!(kv.delete("k1").unwrap());
        assert_eq!(kv.get("k1").unwrap(), None);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn ycsb_a_runs_on_both_transports() {
        let rack = Rack::new(SimConfig::for_tests());
        let env = rack.proc_env(0);
        let cache = Cache::new(8);
        let server = serve_rpcool(&env, "mc-ycsb", Arc::clone(&cache)).unwrap();
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let kv = RpcoolKv::connect(&cenv, "mc-ycsb").unwrap();
        cenv.run(|| {
            let (_load, _run) = run_ycsb(&kv, WorkloadKind::A, 200, 500, 7).unwrap();
        });
        assert!(cache.len() >= 200);
        drop(kv);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    #[should_panic(expected = "cannot run YCSB-E")]
    fn ycsb_e_rejected() {
        let charger = Arc::new(crate::memory::pool::Charger::new(
            CostModel::default(),
            ChargePolicy::Skip,
        ));
        let cache = Cache::new(8);
        let (_server, kv) = serve_net(Flavor::Uds, charger, cache);
        let _ = run_ycsb(&kv, WorkloadKind::E, 10, 10, 1);
    }
}
