//! DeathStarBench SocialNetwork (paper §6.3, Figures 12–13): the
//! compose-post microservice graph, with every inter-service RPC
//! riding either RPCool channels or ThriftRPC (the paper's swap).
//!
//! Service graph (Gan et al., ASPLOS'19), compose-post path:
//!
//!   nginx → ComposePost → { UniqueId, User, Text(UrlShorten +
//!   UserMention) } → PostStorage (MongoDB) → UserTimeline (MongoDB)
//!   → HomeTimeline → SocialGraph (followers) → per-follower
//!   timeline updates (Memcached/Redis class)
//!
//! Per the paper's modification, a **thread pool** serves requests
//! (new-thread-per-request contends on the page-table lock with
//! seal/release) — our drivers use a fixed worker pool. Databases and
//! Nginx dominate the critical path (~66% by their tracing); the
//! `nginx_ns` / `socialnet_db_extra_ns` cost-model knobs reproduce
//! that balance.

use crate::apps::doc::Val;
use crate::apps::memcached::Cache;
use crate::apps::mongodb::DocStore;
use crate::baselines::netrpc::{self, Flavor, NetRpcClient, NetRpcServer};
use crate::baselines::wire::{Wire, WireBuf, WireCur};
use crate::channel::{
    waiter::SleepPolicy, CallArg, CallOpts, ChannelBuilder, Connection, RpcServer,
};
use crate::error::Result;
use crate::memory::containers::ShmString;
use crate::memory::pod::Pod;
use crate::memory::pool::Charger;
use crate::rack::Rack;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Which RPC fabric links the services.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Rpcool,
    /// RPCool with sealing+sandboxing on every hop ("RPCool (Secure)").
    RpcoolSecure,
    Thrift,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Rpcool => "RPCool",
            Backend::RpcoolSecure => "RPCool (Secure)",
            Backend::Thrift => "ThriftRPC",
        }
    }
}

// ------------------------------------------------------------ services

/// Shared backing state for the whole deployment.
pub struct SocialState {
    pub unique: AtomicU64,
    pub users: RwLock<Vec<String>>,
    /// user → follower user-ids.
    pub graph: RwLock<Vec<Vec<u64>>>,
    pub posts: Arc<DocStore>,
    pub user_timelines: Mutex<Vec<Vec<u64>>>,
    pub home_cache: Arc<Cache>,
    pub composed: AtomicU64,
}

impl SocialState {
    pub fn new(nusers: usize, followers_per_user: usize, seed: u64) -> Arc<SocialState> {
        let mut rng = crate::util::rng::Rng::new(seed);
        let users: Vec<String> = (0..nusers).map(|i| format!("user-{i}")).collect();
        let graph: Vec<Vec<u64>> = (0..nusers)
            .map(|_| {
                (0..followers_per_user).map(|_| rng.next_below(nusers as u64)).collect()
            })
            .collect();
        Arc::new(SocialState {
            unique: AtomicU64::new(1),
            users: RwLock::new(users),
            graph: RwLock::new(graph),
            posts: DocStore::new(),
            user_timelines: Mutex::new(vec![Vec::new(); nusers]),
            home_cache: Cache::new(16),
            composed: AtomicU64::new(0),
        })
    }
}

/// Text-service work: mention + URL extraction (real string work, the
/// same on every backend).
pub fn process_text(text: &str) -> (Vec<String>, Vec<String>) {
    let mut mentions = Vec::new();
    let mut urls = Vec::new();
    for tok in text.split_whitespace() {
        if let Some(m) = tok.strip_prefix('@') {
            mentions.push(m.to_string());
        } else if tok.starts_with("http://") || tok.starts_with("https://") {
            // "Shorten": keep a hash suffix, like the real service.
            urls.push(format!("http://short/{:x}", crate::util::rng::mix64(tok.len() as u64 * 31)));
        }
    }
    (mentions, urls)
}

/// The database work shared by both backends (post insert + timelines
/// + fanout), charged with the paper's db-dominance factor.
fn do_db_work(state: &SocialState, charger: &Charger, user_id: u64, post_id: u64, text: &str) {
    let extra = charger.cost.socialnet_db_extra_ns;
    // PostStorage (MongoDB) insert.
    state.posts.insert(
        format!("post{post_id:012}"),
        Val::Obj(vec![
            ("post_id".into(), Val::Num(post_id as f64)),
            ("creator".into(), Val::Num(user_id as f64)),
            ("text".into(), Val::Str(text.to_string())),
        ]),
    );
    charger.charge_ns(extra);
    // UserTimeline (MongoDB) update.
    {
        let mut tl = state.user_timelines.lock().unwrap();
        if let Some(v) = tl.get_mut(user_id as usize) {
            v.push(post_id);
        }
    }
    charger.charge_ns(extra);
    // HomeTimeline fanout via SocialGraph + cache (Memcached/Redis).
    let followers: Vec<u64> = state
        .graph
        .read()
        .unwrap()
        .get(user_id as usize)
        .cloned()
        .unwrap_or_default();
    for f in &followers {
        let key = format!("home:{f}");
        let mut tl = state.home_cache.get(&key).unwrap_or_default();
        tl.extend_from_slice(&post_id.to_le_bytes());
        state.home_cache.set(&key, tl);
    }
    charger.charge_ns(extra);
    state.composed.fetch_add(1, Ordering::Relaxed);
}

// ------------------------------------------------------------- RPCool

const F_UNIQUE: u32 = 1;
const F_USER: u32 = 2;
const F_TEXT: u32 = 3;
const F_STORE_POST: u32 = 4;

#[derive(Clone, Copy)]
struct StorePostArg {
    user_id: u64,
    post_id: u64,
    text: ShmString,
}
unsafe impl Pod for StorePostArg {}

/// One RPCool-linked deployment: four channels (id/user/text/storage),
/// compose logic runs in the front-end driver (as nginx + compose do).
pub struct RpcoolSocial {
    pub state: Arc<SocialState>,
    servers: Vec<RpcServer>,
    listeners: Vec<std::thread::JoinHandle<()>>,
    conns: SocialConns,
    secure: bool,
    charger: Arc<Charger>,
}

pub struct SocialConns {
    unique: Connection,
    user: Connection,
    text: Connection,
    storage: Connection,
}

impl RpcoolSocial {
    pub fn start(
        rack: &Arc<Rack>,
        state: Arc<SocialState>,
        sleep: SleepPolicy,
        secure: bool,
        tag: &str,
    ) -> Result<RpcoolSocial> {
        let mut servers = Vec::new();
        let mut listeners = Vec::new();
        let builder = ChannelBuilder::from_config(&rack.cfg).sleep(sleep);

        // UniqueId service.
        let env = rack.proc_env(1);
        let s = builder.clone().open(&env, &format!("social/{tag}/unique"))?;
        let st = Arc::clone(&state);
        s.add(F_UNIQUE, move |_ctx| Ok(st.unique.fetch_add(1, Ordering::Relaxed)));
        listeners.push(s.spawn_listener());
        servers.push(s);

        // User service.
        let env = rack.proc_env(2);
        let s = builder.clone().open(&env, &format!("social/{tag}/user"))?;
        let st = Arc::clone(&state);
        s.add(F_USER, move |ctx| {
            let uid: u64 = ctx.arg_typed()?;
            let users = st.users.read().unwrap();
            let name = users.get(uid as usize).cloned().unwrap_or_default();
            ctx.reply_string(&name)
        });
        listeners.push(s.spawn_listener());
        servers.push(s);

        // Text service (urls + mentions).
        let env = rack.proc_env(3);
        let s = builder.clone().open(&env, &format!("social/{tag}/text"))?;
        s.serve_scalar::<ShmString>(F_TEXT, move |_ctx, text| {
            let (mentions, urls) = process_text(&text.to_string()?);
            Ok((mentions.len() + urls.len()) as u64)
        });
        listeners.push(s.spawn_listener());
        servers.push(s);

        // Post storage + timelines + fanout.
        let env = rack.proc_env(4);
        let s = builder.clone().open(&env, &format!("social/{tag}/storage"))?;
        let st = Arc::clone(&state);
        let ch = Arc::clone(&rack.pool.charger);
        s.serve_scalar::<StorePostArg>(F_STORE_POST, move |_ctx, arg| {
            let text = arg.text.to_string()?;
            do_db_work(&st, &ch, arg.user_id, arg.post_id, &text);
            Ok(0)
        });
        listeners.push(s.spawn_listener());
        servers.push(s);

        // Front-end connections (the compose service's client side).
        let fenv = rack.proc_env(0);
        fenv.enter();
        let conns = SocialConns {
            unique: Connection::connect(&fenv, &format!("social/{tag}/unique"))?,
            user: Connection::connect(&fenv, &format!("social/{tag}/user"))?,
            text: Connection::connect(&fenv, &format!("social/{tag}/text"))?,
            storage: Connection::connect(&fenv, &format!("social/{tag}/storage"))?,
        };

        Ok(RpcoolSocial {
            state,
            servers,
            listeners,
            conns,
            secure,
            charger: Arc::clone(&rack.pool.charger),
        })
    }

    /// Switch every service link to inline serving (sequential-RTT
    /// model for single-core benchmarking; see `Connection` docs).
    pub fn inline_mode(&self) {
        self.conns.unique.attach_inline(&self.servers[0]);
        self.conns.user.attach_inline(&self.servers[1]);
        self.conns.text.attach_inline(&self.servers[2]);
        self.conns.storage.attach_inline(&self.servers[3]);
        for s in &self.servers {
            s.stop(); // listener threads exit; inline takes over
        }
    }

    /// One compose-post request (nginx + the full service chain).
    pub fn compose_post(&self, user_id: u64, text: &str) -> Result<u64> {
        self.charger.charge_ns(self.charger.cost.nginx_ns);

        // Text service.
        let c = &self.conns.text;
        if self.secure {
            let scope = c.create_scope(4096)?;
            let t = ShmString::from_str(&scope, text)?;
            c.call_scalar(F_TEXT, &t, CallOpts::secure(&scope))?;
        } else {
            let t = ShmString::from_str(c.heap().as_ref(), text)?;
            c.call_scalar(F_TEXT, &t, CallOpts::new())?;
        }

        // UniqueId.
        let post_id = self.conns.unique.invoke(F_UNIQUE, (), CallOpts::new())?;

        // User lookup.
        self.conns.user.call_scalar(F_USER, &user_id, CallOpts::new())?;

        // Storage chain (post + user timeline + home fanout).
        let c = &self.conns.storage;
        if self.secure {
            let scope = c.create_scope(4096)?;
            let arg = StorePostArg {
                user_id,
                post_id,
                text: ShmString::from_str(&scope, text)?,
            };
            c.call_scalar(F_STORE_POST, &arg, CallOpts::secure(&scope))?;
        } else {
            let arg = StorePostArg {
                user_id,
                post_id,
                text: ShmString::from_str(c.heap().as_ref(), text)?,
            };
            c.call_scalar(F_STORE_POST, &arg, CallOpts::new())?;
        }
        Ok(post_id)
    }

    /// Batched compose: the whole slice of posts walks the same
    /// service chain, but each hop rides the amortized submission
    /// path (`invoke_batch`/`call_scalar_batch`) — one publish
    /// doorbell per chunk per service instead of one per post, with
    /// the servers' drain-k loops coalescing the reply doorbells.
    /// Per-post observable semantics are identical to looping
    /// [`RpcoolSocial::compose_post`]; the secure configuration keeps
    /// its per-call seals and falls back to exactly that loop.
    pub fn compose_post_batch(&self, posts: &[(u64, String)]) -> Result<Vec<u64>> {
        if self.secure || posts.len() < 2 {
            return posts.iter().map(|(u, t)| self.compose_post(*u, t)).collect();
        }
        self.charger.charge_ns(self.charger.cost.nginx_ns * posts.len() as u64);

        // Text service: mention/URL extraction for the whole slice.
        let c = &self.conns.text;
        let texts: Vec<ShmString> = posts
            .iter()
            .map(|(_, t)| ShmString::from_str(c.heap().as_ref(), t))
            .collect::<Result<_>>()?;
        c.call_scalar_batch(F_TEXT, &texts, CallOpts::new())?;

        // UniqueId: one batch of k empty-argument calls, k post ids.
        let ids = self.conns.unique.invoke_batch(
            F_UNIQUE,
            &vec![CallArg::NONE; posts.len()],
            CallOpts::new(),
        )?;

        // User lookups.
        let users: Vec<u64> = posts.iter().map(|(u, _)| *u).collect();
        self.conns.user.call_scalar_batch(F_USER, &users, CallOpts::new())?;

        // Storage chain (post + user timeline + home fanout).
        let c = &self.conns.storage;
        let args: Vec<StorePostArg> = posts
            .iter()
            .zip(&ids)
            .map(|((user_id, text), post_id)| {
                Ok(StorePostArg {
                    user_id: *user_id,
                    post_id: *post_id,
                    text: ShmString::from_str(c.heap().as_ref(), text)?,
                })
            })
            .collect::<Result<_>>()?;
        c.call_scalar_batch(F_STORE_POST, &args, CallOpts::new())?;
        Ok(ids)
    }

    pub fn stop(self) {
        drop(self.conns.unique);
        drop(self.conns.user);
        drop(self.conns.text);
        drop(self.conns.storage);
        for s in &self.servers {
            s.stop();
        }
        for l in self.listeners {
            let _ = l.join();
        }
    }
}

// ------------------------------------------------------------- Thrift

pub struct ThriftSocial {
    pub state: Arc<SocialState>,
    servers: Vec<NetRpcServer>,
    listeners: Vec<std::thread::JoinHandle<()>>,
    unique: NetRpcClient,
    user: NetRpcClient,
    text: NetRpcClient,
    storage: NetRpcClient,
    charger: Arc<Charger>,
}

impl ThriftSocial {
    pub fn start(charger: Arc<Charger>, state: Arc<SocialState>) -> ThriftSocial {
        let mut servers = Vec::new();
        let mut listeners = Vec::new();

        let (s, unique) = netrpc::pair(Flavor::Thrift, Arc::clone(&charger));
        let st = Arc::clone(&state);
        s.add(F_UNIQUE, move |_req| {
            Ok(st.unique.fetch_add(1, Ordering::Relaxed).to_bytes())
        });
        listeners.push(s.spawn_listener());
        servers.push(s);

        let (s, user) = netrpc::pair(Flavor::Thrift, Arc::clone(&charger));
        let st = Arc::clone(&state);
        s.add(F_USER, move |req| {
            let uid: u64 = Wire::from_bytes(req)?;
            let users = st.users.read().unwrap();
            Ok(users.get(uid as usize).cloned().unwrap_or_default().to_bytes())
        });
        listeners.push(s.spawn_listener());
        servers.push(s);

        let (s, text) = netrpc::pair(Flavor::Thrift, Arc::clone(&charger));
        s.add(F_TEXT, move |req| {
            let t: String = Wire::from_bytes(req)?;
            let (m, u) = process_text(&t);
            Ok(((m.len() + u.len()) as u64).to_bytes())
        });
        listeners.push(s.spawn_listener());
        servers.push(s);

        let (s, storage) = netrpc::pair(Flavor::Thrift, Arc::clone(&charger));
        let st = Arc::clone(&state);
        let ch = Arc::clone(&charger);
        s.add(F_STORE_POST, move |req| {
            let mut cur = WireCur::new(req);
            let user_id = cur.u64()?;
            let post_id = cur.u64()?;
            let text = cur.str()?;
            do_db_work(&st, &ch, user_id, post_id, text);
            Ok(vec![])
        });
        listeners.push(s.spawn_listener());
        servers.push(s);

        ThriftSocial { state, servers, listeners, unique, user, text, storage, charger }
    }

    /// Sequential-RTT model (see `RpcoolSocial::inline_mode`).
    pub fn inline_mode(&self) {
        self.unique.attach_inline(&self.servers[0]);
        self.user.attach_inline(&self.servers[1]);
        self.text.attach_inline(&self.servers[2]);
        self.storage.attach_inline(&self.servers[3]);
        for s in &self.servers {
            s.stop();
        }
    }

    pub fn compose_post(&self, user_id: u64, text: &str) -> Result<u64> {
        self.charger.charge_ns(self.charger.cost.nginx_ns);
        self.text.call(F_TEXT, &text.to_string().to_bytes())?;
        let post_id: u64 = Wire::from_bytes(&self.unique.call(F_UNIQUE, &[])?)?;
        self.user.call(F_USER, &user_id.to_bytes())?;
        let mut b = WireBuf::new();
        b.put_u64(user_id);
        b.put_u64(post_id);
        b.put_str(text);
        self.storage.call(F_STORE_POST, &b.bytes)?;
        Ok(post_id)
    }

    pub fn stop(self) {
        for s in &self.servers {
            s.stop();
        }
        for l in self.listeners {
            let _ = l.join();
        }
    }
}

/// Sample post text with mentions and a URL (the benchmark's shape).
pub fn sample_post(rng: &mut crate::util::rng::Rng, nusers: usize) -> (u64, String) {
    let user = rng.next_below(nusers as u64);
    let mention = rng.next_below(nusers as u64);
    let text = format!(
        "@user-{mention} check this out https://example.com/{} {}",
        rng.alnum_string(8),
        rng.alnum_string(64),
    );
    (user, text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChargePolicy, CostModel, SimConfig};

    #[test]
    fn text_processing_extracts_entities() {
        let (m, u) = process_text("hi @alice see https://x.io/a and @bob");
        assert_eq!(m, vec!["alice", "bob"]);
        assert_eq!(u.len(), 1);
        assert!(u[0].starts_with("http://short/"));
    }

    #[test]
    fn rpcool_compose_post_full_chain() {
        let rack = Rack::new(SimConfig::for_tests());
        let state = SocialState::new(100, 8, 1);
        let net = RpcoolSocial::start(
            &rack,
            Arc::clone(&state),
            SleepPolicy::Fixed(1),
            false,
            "t1",
        )
        .unwrap();
        let mut rng = crate::util::rng::Rng::new(2);
        for _ in 0..20 {
            let (user, text) = sample_post(&mut rng, 100);
            net.compose_post(user, &text).unwrap();
        }
        assert_eq!(state.composed.load(Ordering::Relaxed), 20);
        assert_eq!(state.posts.len(), 20);
        // Fanout reached follower home timelines.
        assert!(state.home_cache.len() > 0);
        net.stop();
    }

    #[test]
    fn batched_compose_matches_loop_semantics() {
        let rack = Rack::new(SimConfig::for_tests());
        let state = SocialState::new(100, 8, 9);
        let net = RpcoolSocial::start(
            &rack,
            Arc::clone(&state),
            SleepPolicy::Fixed(1),
            false,
            "tb",
        )
        .unwrap();
        let mut rng = crate::util::rng::Rng::new(10);
        let posts: Vec<(u64, String)> = (0..24).map(|_| sample_post(&mut rng, 100)).collect();
        let ids = net.compose_post_batch(&posts).unwrap();
        assert_eq!(ids.len(), 24);
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 24, "unique ids must stay unique through the batch");
        assert_eq!(state.composed.load(Ordering::Relaxed), 24);
        assert_eq!(state.posts.len(), 24);
        assert!(state.home_cache.len() > 0, "fanout reached follower timelines");
        // A single-post batch degrades to the plain path.
        let (user, text) = sample_post(&mut rng, 100);
        net.compose_post_batch(&[(user, text)]).unwrap();
        assert_eq!(state.composed.load(Ordering::Relaxed), 25);
        net.stop();
    }

    #[test]
    fn secure_backend_seals_and_sandboxes() {
        let rack = Rack::new(SimConfig::for_tests());
        let state = SocialState::new(50, 4, 3);
        let net = RpcoolSocial::start(
            &rack,
            Arc::clone(&state),
            SleepPolicy::Fixed(1),
            true,
            "t2",
        )
        .unwrap();
        let mut rng = crate::util::rng::Rng::new(4);
        for _ in 0..5 {
            let (user, text) = sample_post(&mut rng, 50);
            net.compose_post(user, &text).unwrap();
        }
        assert_eq!(state.composed.load(Ordering::Relaxed), 5);
        net.stop();
    }

    #[test]
    fn thrift_backend_equivalent_semantics() {
        let charger =
            Arc::new(Charger::new(CostModel::default(), ChargePolicy::Skip));
        let state = SocialState::new(100, 8, 5);
        let net = ThriftSocial::start(charger, Arc::clone(&state));
        let mut rng = crate::util::rng::Rng::new(6);
        for _ in 0..20 {
            let (user, text) = sample_post(&mut rng, 100);
            net.compose_post(user, &text).unwrap();
        }
        assert_eq!(state.composed.load(Ordering::Relaxed), 20);
        assert_eq!(state.posts.len(), 20);
        net.stop();
    }
}
